"""Roofline table over the dry-run artifacts (§Roofline deliverable).

Reads dryrun_results.json (produced by ``repro.launch.dryrun``) and
emits the three-term roofline per (arch × shape × mesh) cell."""

from __future__ import annotations

import os

from repro.roofline import analyze_file

DEFAULT_PATH = os.path.join(os.path.dirname(__file__), "..",
                            "dryrun_results.json")


def run(path: str = DEFAULT_PATH) -> list[str]:
    if not os.path.exists(path):
        return ["roofline,SKIPPED: run `python -m repro.launch.dryrun` first"]
    rows = ["roofline,arch,shape,mesh,compute_s,memory_s,collective_s,"
            "dominant,useful_ratio,roofline_frac"]
    # single-pod only (per the brief): multi-pod cells skip the scan-
    # extrapolation cost pass, so their raw numbers are not roofline-grade.
    for t in analyze_file(path):
        if "2pod" in t.mesh:
            continue
        rows.append(
            f"roofline,{t.arch},{t.shape},{t.mesh},{t.compute_s:.5f},"
            f"{t.memory_s:.5f},{t.collective_s:.5f},{t.dominant},"
            f"{t.useful_ratio:.3f},{t.roofline_fraction:.3f}")
    return rows


if __name__ == "__main__":
    print("\n".join(run()))
