"""Benchmark harness: one module per paper table/figure.

Emits CSV rows ``name,...`` per benchmark; see each module's docstring
for the paper artifact it reproduces.
"""

from __future__ import annotations

import os
import sys

# Allow `python benchmarks/run.py` from the repo root: the script dir is
# on sys.path, the package's parent is not.
sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))


def main() -> None:
    from benchmarks import (fig3_functional, fig4_area_power, kernel_bench,
                            roofline_table, serve_bench, table2_cycles)
    for mod in (table2_cycles, fig3_functional, fig4_area_power,
                kernel_bench, roofline_table, serve_bench):
        print(f"\n# === {mod.__name__} ===")
        for row in mod.run():
            print(row)


if __name__ == '__main__':
    main()
