"""Serving throughput benchmark: the engine-level view of the paper.

PR 1 made the nibble kernels single-pass; this benchmark measures where
that shows up end to end — tokens/second and per-request latency out of
the continuous-batching engine, per workload shape:

* ``uniform``   — all requests arrive at t=0 (lockstep-like best case);
* ``staggered`` — arrivals spaced by a fixed gap, so slots free up and
                  refill mid-stream (the continuous-batching case; the
                  per-slot position vector is what makes it possible).

Grid: {dense, w8a8_nibble} × {xla, pallas} × {uniform, staggered} ×
{dense, paged} cache on a reduced config, plus an **overcommitted
pool** pair: the same paged pool sized well below the sum of worst-case
page counts, driven once with ``alloc_mode="reserve"`` (admission must
serialize on worst-case bookings) and once with
``alloc_mode="incremental"`` (pages booked per live token,
evict-and-resume preemption when the pool runs dry).  The
``concurrency`` and ``occupancy`` columns are the point: incremental
admits more concurrent requests per page of pool.

A **prefix-cache pair** rides along: a shared-system-prompt workload
(75% of requests begin with one fixed prompt head) through the same
paged engine with ``prefix_cache`` off and on.  ``prefix_hit_rate`` is
the fraction of prompt tokens served from cached read-only pages
instead of being re-prefilled, and the ``ttft_p50_ms`` delta is what
that saves the median request.

A **self-speculative pair** (off/on, uniform and bursty arrivals)
measures speculative decoding: the baseline runs the dense engine at
``decode_chunk=1`` (one forward per token — the standard comparison
for speculative decoding, since a spec round replaces per-token
forwards with one drafted batch), the spec side drafts ``spec_k=4``
tokens with the w8a8 nibble program and verifies them in ONE dense
multi-token forward.  ``acceptance_rate`` and ``tokens_per_step`` are
the spec columns; greedy acceptance keeps the emitted streams
bit-identical to the baseline's.

A **tail-latency pair** (``workload=burst_tail``) drives the bursty
heavy-tail workload over the overcommitted incremental pool with the
tail mechanisms off vs on — chunked wave prefill (``prefill_chunk=4``),
grouped admission (``admit_group=4``) and the host-tier page swap
(``swap_mode="host"``).  The p99 TTFT/ITL columns are the headline;
``swap_out``/``swap_in``/``replay_steps_saved`` count the swap traffic
and the replayed decode steps it saved.

CPU wall-clock is a functional proxy (pallas runs in interpret mode —
correctness, not speed); the uniform-vs-staggered *ratio*, the latency
percentiles and the per-request cache HBM column are the transferable
signal.  ``cache_kb_per_req`` is the point of the paged cache: dense
reserves the full ``max_len`` slab per request, paged reserves only the
pages its live tokens touch.  Results land in ``BENCH_serve.json``.

    PYTHONPATH=src python benchmarks/serve_bench.py [--json out.json]
"""

from __future__ import annotations

import argparse
import json
import os
import sys

sys.path.insert(0, os.path.join(
    os.path.dirname(os.path.dirname(os.path.abspath(__file__))), "src"))

import jax

ARCH = "yi-6b"
SLOTS = 4
PROMPT_BUDGET = 16
NEW_TOKENS = 16
REQUESTS = 8
STAGGER_S = 0.05
PAGE_SIZE = 4
# the slot budget is provisioned for a worst case twice the actual
# workload (as a production deployment must be): dense reserves the
# whole slab per request, paged reserves only live pages — the gap is
# the cache_kb_per_req column
MAX_LEN = 2 * (PROMPT_BUDGET + NEW_TOKENS)
# overcommitted pool: every request's worst case is ceil((16+16-1)/4)
# = 8 pages, so 4 slots want 32 + trash; 17 pages (capacity 16 = two
# worst-case requests) forces reserve-mode admission to serialize while
# incremental mode keeps more slots live off the same pool
OVERCOMMIT_PAGES = 17
GRID = [("dense", "xla"), ("dense", "pallas"),
        ("w8a8_nibble", "xla"), ("w8a8_nibble", "pallas")]

SHARED_PREFIX = 0.75
SPEC_K = 4
SPEC_DRAFT = "w8a8_nibble"

_HEADER = ("workload,quant,backend,cache,alloc,prefix,spec,tail,pool_pages,"
           "requests,slots,tok_per_s,req_p50_ms,req_p99_ms,ttft_p50_ms,"
           "ttft_p99_ms,itl_p50_ms,itl_p99_ms,cache_kb_per_req,occupancy,"
           "concurrency,preemptions,swap_out,swap_in,replay_steps_saved,"
           "prefix_hit_rate,acceptance_rate,"
           "tokens_per_step,compile_s,device_count,mesh,dp_replicas,"
           "predicted_tok_s,predicted_ttft_p50_ms,prediction_err_pct")


def _attach_capacity(row, engine, scfg, *, requests, stagger,
                     shared_prefix, arrival_mode, prefix_cache, tp, dp):
    """Predict the row's own workload with the analytic capacity model
    (calibrated per-dispatch stage costs from this very engine) and
    embed the full replay blob, so ``tools/autotune.py --validate`` and
    ``tests/test_capacity.py`` can re-check model-vs-measured from the
    committed JSON alone.  Mesh/router rows (tp/dp > 1) carry no
    prediction — the capacity model covers the single-device engine."""
    row.update({"predicted_tok_s": None, "predicted_ttft_p50_ms": None,
                "prediction_err_pct": None})
    if tp > 1 or dp > 1:
        return
    from repro.capacity import Knobs, WorkloadShape, predict
    from repro.capacity.calibrate import calibrate_engine
    shape = WorkloadShape(requests=requests, prompt_budget=PROMPT_BUDGET,
                          new_tokens=NEW_TOKENS, stagger_s=stagger,
                          shared_prefix=shared_prefix,
                          arrival_mode=arrival_mode)
    knobs = Knobs.from_serve_config(scfg)
    costs = calibrate_engine(engine)
    acceptance = (float(row["acceptance_rate"]) if scfg.spec_decode
                  else None)
    ctb = int(engine.cache_token_bytes)
    pred = predict(knobs, shape, costs, cache_token_bytes=ctb,
                   acceptance=acceptance)
    row["capacity"] = {
        # gated rows are the model-vs-measured regression surface; the
        # prefix-cache rows stay ungated (page sharing is unmodeled)
        "gated": not prefix_cache and shared_prefix == 0.0,
        "knobs": knobs.to_dict(), "shape": shape.to_dict(),
        "costs": costs.to_dict(), "acceptance": acceptance,
        "cache_token_bytes": ctb,
        "predicted": {k: (round(v, 4) if isinstance(v, float) else v)
                      for k, v in pred.items()},
    }
    if pred.get("feasible") and "tok_per_s" in pred:
        row["predicted_tok_s"] = round(pred["tok_per_s"], 1)
        row["predicted_ttft_p50_ms"] = round(pred["ttft_p50_ms"], 1)
        row["prediction_err_pct"] = round(
            100.0 * abs(pred["tok_per_s"] - row["tok_per_s"])
            / max(row["tok_per_s"], 1e-9), 1)


def _bench_one(cfg, params, quant, backend, workload, cache_mode,
               alloc_mode="reserve", num_pages=None, prefix_cache=False,
               shared_prefix=0.0, arrival_mode="uniform", decode_chunk=8,
               spec=False, tp=1, dp=1, prefill_chunk=0, admit_group=1,
               swap_mode="off", requests=REQUESTS):
    from repro.serve import Engine, Router, ServeConfig, run_timed_workload
    scfg = ServeConfig(batch=SLOTS, max_len=MAX_LEN,
                       prefill_len=PROMPT_BUDGET, decode_chunk=decode_chunk,
                       alloc_mode=alloc_mode, prefix_cache=prefix_cache,
                       quant_mode=quant, quant_backend=backend,
                       cache_mode=cache_mode, page_size=PAGE_SIZE,
                       num_pages=num_pages, spec_decode=spec,
                       spec_k=SPEC_K,
                       spec_quant_mode=SPEC_DRAFT if spec else None,
                       tp=tp, prefill_chunk=prefill_chunk,
                       admit_group=admit_group, swap_mode=swap_mode)
    if dp > 1:
        engine = Router(cfg, params, scfg, replicas=dp)
    else:
        engine = Engine(cfg, params, scfg)
    stagger = STAGGER_S if (workload in ("staggered", "mesh")
                            or arrival_mode == "bursty") else 0.0
    r = run_timed_workload(engine, cfg.vocab_size, requests=requests,
                           prompt_budget=PROMPT_BUDGET,
                           new_tokens=NEW_TOKENS, stagger_s=stagger,
                           shared_prefix=shared_prefix,
                           arrival_mode=arrival_mode)
    counts = r.pop("compile_counts")
    # compile counts come from the engine's own signature tracker; a
    # negative value would mean introspection is unavailable (it never
    # is for the engine counter, but degrade to a warning rather than
    # killing the whole benchmark the way the old jax-private probe did)
    warn = None
    # the pinned per-mode contract: spec engines build exactly one
    # draft and one verify program and never the plain decode chunk;
    # wave engines (chunked/grouped prefill) build exactly one wave
    # program and never the monolithic prefill
    wave = prefill_chunk > 0 or admit_group > 1
    if wave and spec:
        expected = {"prefill": 0, "decode_chunk": 0, "prefill_chunk": 1,
                    "draft": 1, "verify": 1}
    elif wave:
        expected = {"prefill": 0, "decode_chunk": 1, "prefill_chunk": 1}
    elif spec:
        expected = {"prefill": 1, "decode_chunk": 0, "draft": 1,
                    "verify": 1}
    else:
        expected = {"prefill": 1, "decode_chunk": 1}
    if any(v < 0 for v in counts.values()):
        warn = "# warning: compile-count introspection unavailable"
    elif counts != expected:
        raise RuntimeError(f"engine recompiled during benchmark: {counts} "
                           f"(expected {expected})")
    # a paged drain must hand every page back once the prefix index
    # lets go — a leak in a benchmark run invalidates its numbers
    if cache_mode == "paged":
        engine.release_prefix_cache()
        leaked = engine.leaked_pages()
        if leaked:
            raise RuntimeError(f"page leak: {leaked} page(s) still booked "
                               f"after drain")
    row = {"workload": workload, "quant": quant, "backend": backend,
           "cache": cache_mode, "alloc": alloc_mode if cache_mode == "paged"
           else "-", "prefix": "on" if prefix_cache else "-", **r}
    row["spec"] = "on" if spec else "-"
    row["tail"] = "on" if (wave or swap_mode != "off") else "-"
    _attach_capacity(row, engine, scfg, requests=requests, stagger=stagger,
                     shared_prefix=shared_prefix, arrival_mode=arrival_mode,
                     prefix_cache=prefix_cache, tp=tp, dp=dp)
    return row, warn


def _csv(r):
    mesh = f"{r['mesh_shape'][0]}x{r['mesh_shape'][1]}"
    return (f"{r['workload']},{r['quant']},{r['backend']},{r['cache']},"
            f"{r['alloc']},{r['prefix']},{r['spec']},{r.get('tail', '-')},"
            f"{r['pool_pages'] or '-'},{r['requests']},"
            f"{r['slots']},{r['tok_per_s']},{r['req_p50_ms']},"
            f"{r['req_p99_ms']},{r['ttft_p50_ms']},{r['ttft_p99_ms']},"
            f"{r['itl_p50_ms']},{r['itl_p99_ms']},{r['cache_kb_per_req']},"
            f"{r['occupancy']},{r['concurrency']},{r['preemptions']},"
            f"{r.get('swap_out', 0)},{r.get('swap_in', 0)},"
            f"{r.get('replay_steps_saved', 0)},"
            f"{r['prefix_hit_rate']},{r['acceptance_rate']},"
            f"{r['tokens_per_step']},{r['compile_s']},"
            f"{r['device_count']},{mesh},{r['dp_replicas']},"
            f"{r.get('predicted_tok_s') or '-'},"
            f"{r.get('predicted_ttft_p50_ms') or '-'},"
            f"{r.get('prediction_err_pct') or '-'}")


MESH_TRIO = [(1, 1), (2, 1), (1, 2)]          # (tp, dp) per row


def _mesh_rows():
    """The mesh trio itself — runs inside the forced-host child."""
    from repro.configs import get_config, reduced
    from repro.models import model_init

    cfg = reduced(get_config(ARCH))
    params = model_init(jax.random.PRNGKey(0), cfg)
    rows = []
    for tp, dp in MESH_TRIO:
        r, _ = _bench_one(cfg, params, "w8a8_nibble", "xla", "mesh",
                          "paged", alloc_mode="incremental",
                          prefix_cache=True, shared_prefix=SHARED_PREFIX,
                          tp=tp, dp=dp)
        rows.append(r)
    return rows


def _run_mesh_child(rows):
    """Spawn this file with --mesh-child under a forced 8-device host
    platform, merge the child's JSON rows, and yield their CSV lines."""
    import subprocess
    env = dict(os.environ,
               XLA_FLAGS="--xla_force_host_platform_device_count=8")
    out = subprocess.run(
        [sys.executable, os.path.abspath(__file__), "--mesh-child"],
        env=env, capture_output=True, text=True, timeout=1800)
    if out.returncode:
        raise RuntimeError(f"mesh child failed:\n{out.stderr[-2000:]}")
    for r in json.loads(out.stdout.strip().splitlines()[-1]):
        rows.append(r)
        yield _csv(r)


def run(json_path: str | None = None):
    from repro.configs import get_config, reduced
    from repro.models import model_init

    cfg = reduced(get_config(ARCH))
    params = model_init(jax.random.PRNGKey(0), cfg)
    yield _HEADER
    rows = []
    for quant, backend in GRID:
        for workload in ("uniform", "staggered"):
            for cache_mode in ("dense", "paged"):
                r, warn = _bench_one(cfg, params, quant, backend, workload,
                                     cache_mode)
                rows.append(r)
                if warn:
                    yield warn
                yield _csv(r)
    # overcommitted pool: same pool, reserve vs incremental bookkeeping,
    # plus incremental with the host-tier swap — its preemptions resume
    # by page copy, so swap_out/swap_in fire and replay_steps_saved
    # shows up as fewer decode-chunk dispatches for the same streams
    for alloc_mode, swap in (("reserve", "off"), ("incremental", "off"),
                             ("incremental", "host")):
        r, warn = _bench_one(cfg, params, "dense", "xla", "overcommit",
                             "paged", alloc_mode=alloc_mode,
                             num_pages=OVERCOMMIT_PAGES, swap_mode=swap)
        rows.append(r)
        if warn:
            yield warn
        yield _csv(r)
    # prefix caching: shared-system-prompt workload, cache off vs on —
    # the hit-rate column and the ttft delta are the payoff
    for prefix_cache in (False, True):
        r, warn = _bench_one(cfg, params, "dense", "xla", "shared",
                             "paged", prefix_cache=prefix_cache,
                             shared_prefix=SHARED_PREFIX)
        rows.append(r)
        if warn:
            yield warn
        yield _csv(r)
    # self-speculative decoding: off/on at decode_chunk=1 (the dense
    # baseline pays one forward per token — the standard speculative
    # comparison) under uniform and bursty arrivals; greedy spec
    # streams are bit-identical to the baseline's, so tok_per_s,
    # acceptance_rate and tokens_per_step are the whole story
    for arrival in ("uniform", "bursty"):
        for spec in (False, True):
            r, warn = _bench_one(cfg, params, "dense", "xla", arrival,
                                 "paged", alloc_mode="incremental",
                                 arrival_mode=arrival, decode_chunk=1,
                                 spec=spec)
            rows.append(r)
            if warn:
                yield warn
            yield _csv(r)
    # tail-latency pair: the same bursty heavy-tail workload over the
    # same overcommitted incremental pool, with the tail mechanisms off
    # (monolithic prefill, replay-resume) vs on (4-token chunked wave
    # prefill, 4-wide grouped admission, host-tier page swap).  The
    # p99 TTFT/ITL columns are the headline; swap_out/swap_in/
    # replay_steps_saved show where the win comes from
    # 2x the grid's request count: with only 8 requests the p99 columns
    # are the per-run maximum and burst luck dominates the comparison
    for tail in (False, True):
        r, warn = _bench_one(cfg, params, "dense", "xla", "burst_tail",
                             "paged", alloc_mode="incremental",
                             num_pages=OVERCOMMIT_PAGES,
                             arrival_mode="bursty", requests=2 * REQUESTS,
                             prefill_chunk=4 if tail else 0,
                             admit_group=4 if tail else 1,
                             swap_mode="host" if tail else "off")
        rows.append(r)
        if warn:
            yield warn
        yield _csv(r)
    # mesh trio: the same shared-prefix staggered workload as a
    # single-device baseline, TP-sharded (one engine over a (1, 2)
    # mesh), and DP-replicated (two engines behind the router, with
    # per-replica prefix-affinity hit rates in the JSON row).  Runs in
    # a child process because the forced-host device count must be set
    # before jax initializes — the parent already owns a 1-device jax.
    for line in _run_mesh_child(rows):
        yield line
    if json_path:
        payload = {
            "note": "Continuous-batching engine throughput on the reduced "
                    f"{ARCH} config (CPU functional proxy; pallas = "
                    "interpret mode). uniform = all arrivals at t=0; "
                    "staggered = arrivals every "
                    f"{int(STAGGER_S * 1e3)}ms, exercising slot refill "
                    "via per-slot decode positions. Latencies are "
                    "per-request (arrival to completion). The slot "
                    f"budget (max_len={MAX_LEN}) is provisioned for a "
                    "worst case 2x the workload; cache=paged uses "
                    f"page_size={PAGE_SIZE} pools + page-table "
                    "indirection and cache_kb_per_req is the per-request "
                    "KV reservation (dense: the max_len slab; paged: "
                    "allocated pages only). occupancy = mean fraction of "
                    "pool pages in use per decode chunk; concurrency = "
                    "mean admitted requests per chunk. The overcommit "
                    f"rows share one {OVERCOMMIT_PAGES}-page pool — "
                    "below the 4-slot worst-case sum of 33 pages (and "
                    "far below the 65-page dense-parity default): "
                    "alloc=reserve must serialize admissions on "
                    "worst-case bookings, alloc=incremental books pages "
                    "per live token (evict-and-resume preemption when "
                    "the pool runs dry) and sustains more concurrent "
                    "requests per page of pool; the third overcommit "
                    "row adds swap_mode=host — the same preemptions "
                    "resume by host-tier page copy (swap_out/swap_in), "
                    "and replay_steps_saved decode steps disappear from "
                    "the run while the streams stay bit-identical. On "
                    "this CPU proxy the copy costs more wall-clock than "
                    "the replay it saves (a tiny model makes replayed "
                    "decode steps nearly free — they ride along in "
                    "chunks that run anyway — while the host round-trip "
                    "pays real per-event dispatches); the counters, not "
                    "the swap row's tok_per_s, are the transferable "
                    "signal: at HBM scale each replayed step is a full "
                    "forward and the copy is O(pages). The "
                    "workload=shared pair "
                    f"gives {int(SHARED_PREFIX * 100)}% of requests one "
                    "fixed system-prompt head: prefix=on shares its "
                    "pages read-only across requests (refcounted, "
                    "copy-on-write tail) and prefix_hit_rate is the "
                    "fraction of prompt tokens served from cached pages "
                    "instead of re-prefilled. The spec=on rows run "
                    f"self-speculative decoding (spec_k={SPEC_K}, "
                    f"{SPEC_DRAFT} draft, dense verify) against a "
                    "decode_chunk=1 dense baseline — one forward per "
                    "token, the standard speculative-decoding "
                    "comparison; acceptance_rate = fresh drafts accepted "
                    "/ proposed, tokens_per_step = tokens emitted per "
                    "sequence per draft+verify round, and greedy "
                    "acceptance keeps spec streams bit-identical to the "
                    "baseline's. bursty arrivals cluster Poisson bursts "
                    "with Pareto heavy-tail prompt lengths at the same "
                    "mean load (ttft_p99_ms / itl percentile columns). "
                    "The workload=burst_tail pair runs that bursty "
                    f"workload over the same {OVERCOMMIT_PAGES}-page "
                    "overcommitted incremental pool with the "
                    "tail-latency mechanisms off vs on (tail=on: "
                    "prefill_chunk=4 chunked wave prefill interleaving "
                    "decode between prompt slices, admit_group=4 "
                    "grouped admission, swap_mode=host parking evicted "
                    "slots' KV pages in a host pool so resume is a page "
                    "copy instead of a token replay) — greedy streams "
                    "are bit-identical between the two rows, "
                    "ttft_p99_ms/itl_p99_ms are the headline, and "
                    "swap_out/swap_in/replay_steps_saved count the swap "
                    "traffic and the decode steps the page-copy resume "
                    "did not have to replay. "
                    "Every row records its topology: device_count, "
                    "mesh_shape = the per-engine (data, model) mesh, and "
                    "dp_replicas = engine replicas behind the router "
                    "(1 / [1, 1] / 1 for plain single-device rows). The "
                    "workload=mesh trio re-runs the shared-prefix "
                    "staggered workload on a forced 8-device host "
                    "platform: a single-device baseline, tp=2 (weights "
                    "and paged KV pools sharded over the mesh's model "
                    "axis), and dp=2 (two replicas behind the admission "
                    "router — its row carries per_replica placement and "
                    "prefix-affinity hit rates). CPU wall-clock across "
                    "forced-host shards is a functional proxy, not a "
                    "speedup claim. Every single-device row also carries "
                    "the analytic capacity model's prediction "
                    "(predicted_tok_s / predicted_ttft_p50_ms / "
                    "prediction_err_pct) plus the full replay blob "
                    "(knobs, workload shape, calibrated per-dispatch "
                    "stage costs) under its 'capacity' key; rows with "
                    "capacity.gated=true are the model-vs-measured "
                    "regression surface that tools/autotune.py "
                    "--validate and tests/test_capacity.py replay — see "
                    "docs/capacity.md for the tolerance policy.",
            "arch": ARCH,
            "results": rows,
        }
        with open(json_path, "w") as f:
            json.dump(payload, f, indent=1)
        yield f"# wrote {json_path}"


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--json", default=None,
                    help="also write results to this JSON file")
    ap.add_argument("--mesh-child", action="store_true",
                    help="internal: run only the mesh trio and print its "
                         "rows as JSON (invoked by the parent benchmark "
                         "under a forced multi-device host platform)")
    args = ap.parse_args()
    if args.mesh_child:
        print(json.dumps(_mesh_rows()))
        return
    for row in run(json_path=args.json):
        print(row)


if __name__ == "__main__":
    main()
