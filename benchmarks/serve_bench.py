"""Serving throughput benchmark: the engine-level view of the paper.

PR 1 made the nibble kernels single-pass; this benchmark measures where
that shows up end to end — tokens/second and per-request latency out of
the continuous-batching engine, per workload shape:

* ``uniform``   — all requests arrive at t=0 (lockstep-like best case);
* ``staggered`` — arrivals spaced by a fixed gap, so slots free up and
                  refill mid-stream (the continuous-batching case; the
                  per-slot position vector is what makes it possible).

Grid: {dense, w8a8_nibble} × {xla, pallas} × {uniform, staggered} on a
reduced config.  CPU wall-clock is a functional proxy (pallas runs in
interpret mode — correctness, not speed); the uniform-vs-staggered
*ratio* and the latency percentiles are the transferable signal.
Results land in ``BENCH_serve.json``.

    PYTHONPATH=src python benchmarks/serve_bench.py [--json out.json]
"""

from __future__ import annotations

import argparse
import json
import os
import sys

sys.path.insert(0, os.path.join(
    os.path.dirname(os.path.dirname(os.path.abspath(__file__))), "src"))

import jax

ARCH = "yi-6b"
SLOTS = 4
PROMPT_BUDGET = 16
NEW_TOKENS = 16
REQUESTS = 8
STAGGER_S = 0.05
GRID = [("dense", "xla"), ("dense", "pallas"),
        ("w8a8_nibble", "xla"), ("w8a8_nibble", "pallas")]

_HEADER = ("workload,quant,backend,requests,slots,tok_per_s,"
           "req_p50_ms,req_p99_ms,ttft_p50_ms,compile_s")


def _bench_one(cfg, params, quant, backend, workload):
    from repro.serve import Engine, ServeConfig, run_timed_workload
    scfg = ServeConfig(batch=SLOTS, max_len=PROMPT_BUDGET + NEW_TOKENS,
                       prefill_len=PROMPT_BUDGET, decode_chunk=8,
                       quant_mode=quant, quant_backend=backend)
    engine = Engine(cfg, params, scfg)
    stagger = STAGGER_S if workload == "staggered" else 0.0
    r = run_timed_workload(engine, cfg.vocab_size, requests=REQUESTS,
                           prompt_budget=PROMPT_BUDGET,
                           new_tokens=NEW_TOKENS, stagger_s=stagger)
    counts = r.pop("compile_counts")
    if -1 in counts.values():
        raise RuntimeError("compile-count introspection unavailable on "
                           "this jax version")
    if counts != {"prefill": 1, "decode_chunk": 1}:
        raise RuntimeError(f"engine recompiled during benchmark: {counts}")
    return {"workload": workload, "quant": quant, "backend": backend, **r}


def run(json_path: str | None = None):
    from repro.configs import get_config, reduced
    from repro.models import model_init

    cfg = reduced(get_config(ARCH))
    params = model_init(jax.random.PRNGKey(0), cfg)
    yield _HEADER
    rows = []
    for quant, backend in GRID:
        for workload in ("uniform", "staggered"):
            r = _bench_one(cfg, params, quant, backend, workload)
            rows.append(r)
            yield (f"{r['workload']},{r['quant']},{r['backend']},"
                   f"{r['requests']},{r['slots']},{r['tok_per_s']},"
                   f"{r['req_p50_ms']},{r['req_p99_ms']},"
                   f"{r['ttft_p50_ms']},{r['compile_s']}")
    if json_path:
        payload = {
            "note": "Continuous-batching engine throughput on the reduced "
                    f"{ARCH} config (CPU functional proxy; pallas = "
                    "interpret mode). uniform = all arrivals at t=0; "
                    "staggered = arrivals every "
                    f"{int(STAGGER_S * 1e3)}ms, exercising slot refill "
                    "via per-slot decode positions. Latencies are "
                    "per-request (arrival to completion).",
            "arch": ARCH,
            "results": rows,
        }
        with open(json_path, "w") as f:
            json.dump(payload, f, indent=1)
        yield f"# wrote {json_path}"


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--json", default=None,
                    help="also write results to this JSON file")
    args = ap.parse_args()
    for row in run(json_path=args.json):
        print(row)


if __name__ == "__main__":
    main()
