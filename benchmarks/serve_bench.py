"""Serving throughput benchmark: the engine-level view of the paper.

PR 1 made the nibble kernels single-pass; this benchmark measures where
that shows up end to end — tokens/second and per-request latency out of
the continuous-batching engine, per workload shape:

* ``uniform``   — all requests arrive at t=0 (lockstep-like best case);
* ``staggered`` — arrivals spaced by a fixed gap, so slots free up and
                  refill mid-stream (the continuous-batching case; the
                  per-slot position vector is what makes it possible).

Grid: {dense, w8a8_nibble} × {xla, pallas} × {uniform, staggered} ×
{dense, paged} cache on a reduced config.  CPU wall-clock is a
functional proxy (pallas runs in interpret mode — correctness, not
speed); the uniform-vs-staggered *ratio*, the latency percentiles and
the per-request cache HBM column are the transferable signal.  The
``cache_kb_per_req`` column is the point of the paged cache: dense
reserves the full ``max_len`` slab per request, paged reserves only the
pages its live tokens need (requests here draw prompts from
[budget/2, budget], so the paged figure sits measurably below the
slab).  Results land in ``BENCH_serve.json``.

    PYTHONPATH=src python benchmarks/serve_bench.py [--json out.json]
"""

from __future__ import annotations

import argparse
import json
import os
import sys

sys.path.insert(0, os.path.join(
    os.path.dirname(os.path.dirname(os.path.abspath(__file__))), "src"))

import jax

ARCH = "yi-6b"
SLOTS = 4
PROMPT_BUDGET = 16
NEW_TOKENS = 16
REQUESTS = 8
STAGGER_S = 0.05
PAGE_SIZE = 4
# the slot budget is provisioned for a worst case twice the actual
# workload (as a production deployment must be): dense reserves the
# whole slab per request, paged reserves only live pages — the gap is
# the cache_kb_per_req column
MAX_LEN = 2 * (PROMPT_BUDGET + NEW_TOKENS)
GRID = [("dense", "xla"), ("dense", "pallas"),
        ("w8a8_nibble", "xla"), ("w8a8_nibble", "pallas")]

_HEADER = ("workload,quant,backend,cache,requests,slots,tok_per_s,"
           "req_p50_ms,req_p99_ms,ttft_p50_ms,cache_kb_per_req,compile_s")


def _bench_one(cfg, params, quant, backend, workload, cache_mode):
    from repro.serve import Engine, ServeConfig, run_timed_workload
    scfg = ServeConfig(batch=SLOTS, max_len=MAX_LEN,
                       prefill_len=PROMPT_BUDGET, decode_chunk=8,
                       quant_mode=quant, quant_backend=backend,
                       cache_mode=cache_mode, page_size=PAGE_SIZE)
    engine = Engine(cfg, params, scfg)
    stagger = STAGGER_S if workload == "staggered" else 0.0
    r = run_timed_workload(engine, cfg.vocab_size, requests=REQUESTS,
                           prompt_budget=PROMPT_BUDGET,
                           new_tokens=NEW_TOKENS, stagger_s=stagger)
    counts = r.pop("compile_counts")
    if -1 in counts.values():
        raise RuntimeError("compile-count introspection unavailable on "
                           "this jax version")
    if counts != {"prefill": 1, "decode_chunk": 1}:
        raise RuntimeError(f"engine recompiled during benchmark: {counts}")
    return {"workload": workload, "quant": quant, "backend": backend,
            "cache": cache_mode, **r}


def run(json_path: str | None = None):
    from repro.configs import get_config, reduced
    from repro.models import model_init

    cfg = reduced(get_config(ARCH))
    params = model_init(jax.random.PRNGKey(0), cfg)
    yield _HEADER
    rows = []
    for quant, backend in GRID:
        for workload in ("uniform", "staggered"):
            for cache_mode in ("dense", "paged"):
                r = _bench_one(cfg, params, quant, backend, workload,
                               cache_mode)
                rows.append(r)
                yield (f"{r['workload']},{r['quant']},{r['backend']},"
                       f"{r['cache']},{r['requests']},{r['slots']},"
                       f"{r['tok_per_s']},{r['req_p50_ms']},"
                       f"{r['req_p99_ms']},{r['ttft_p50_ms']},"
                       f"{r['cache_kb_per_req']},{r['compile_s']}")
    if json_path:
        payload = {
            "note": "Continuous-batching engine throughput on the reduced "
                    f"{ARCH} config (CPU functional proxy; pallas = "
                    "interpret mode). uniform = all arrivals at t=0; "
                    "staggered = arrivals every "
                    f"{int(STAGGER_S * 1e3)}ms, exercising slot refill "
                    "via per-slot decode positions. Latencies are "
                    "per-request (arrival to completion). The slot "
                    f"budget (max_len={MAX_LEN}) is provisioned for a "
                    "worst case 2x the workload; cache=paged uses "
                    f"page_size={PAGE_SIZE} pools + page-table "
                    "indirection and cache_kb_per_req is the per-request "
                    "KV reservation (dense: the max_len slab; paged: "
                    "allocated pages only — the HBM win on requests "
                    "shorter than the provisioned worst case).",
            "arch": ARCH,
            "results": rows,
        }
        with open(json_path, "w") as f:
            json.dump(payload, f, indent=1)
        yield f"# wrote {json_path}"


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--json", default=None,
                    help="also write results to this JSON file")
    args = ap.parse_args()
    for row in run(json_path=args.json):
        print(row)


if __name__ == "__main__":
    main()
