"""Fig. 4 reproduction: area (µm²) and power (mW) across 4/8/16-operand
configurations from the calibrated analytical model, with the paper's
reported values and relative error side by side; plus the 128-lane
extrapolation the abstract alludes to."""

from __future__ import annotations

from repro.core import cycle_model as cm


def run() -> list[str]:
    rows = ["fig4,arch,metric,n_ops,model,paper,rel_err"]
    for metric, fn in (("area_um2", cm.area_um2), ("power_mw", cm.power_mw)):
        for arch in cm.ARCHES:
            reported = cm.paper_reported(
                "area" if metric == "area_um2" else "power", arch)
            for n, paper in zip((4, 8, 16), reported):
                model = fn(arch, n)
                err = "" if paper is None else f"{abs(model-paper)/paper:.4f}"
                paper_s = "" if paper is None else f"{paper}"
                rows.append(f"fig4,{arch},{metric},{n},{model:.4f},"
                            f"{paper_s},{err}")

    # headline claims
    rows.append("fig4_claim,area_vs_shift_add_16,"
                f"{cm.improvement_vs('shift_add', 'nibble_precompute', 'area', 16):.3f},paper,1.69")
    rows.append("fig4_claim,power_vs_shift_add_16,"
                f"{cm.improvement_vs('shift_add', 'nibble_precompute', 'power', 16):.3f},paper,1.63")
    rows.append("fig4_claim,area_vs_lut_16,"
                f"{cm.area_um2('lut_array', 16) / cm.area_um2('nibble_precompute', 16):.3f},paper,2.6")
    rows.append("fig4_claim,power_vs_lut_16,"
                f"{cm.power_mw('lut_array', 16) / cm.power_mw('nibble_precompute', 16):.3f},"
                "paper,2.7 (inconsistent with paper Fig4b data = 4.56)")
    # 128-lane extrapolation (abstract's truncated '128-' sentence)
    for arch in cm.ARCHES:
        rows.append(f"fig4_extrap128,{arch},area_um2,128,"
                    f"{cm.area_um2(arch, 128):.1f},,")
        rows.append(f"fig4_extrap128,{arch},power_mw,128,"
                    f"{cm.power_mw(arch, 128):.4f},,")
    return rows


if __name__ == "__main__":
    print("\n".join(run()))
