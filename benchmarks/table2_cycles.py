"""Table 2 reproduction: analytical complexity + cycle latency, plus the
*measured* cycle accounting from the executable multiplier models."""

from __future__ import annotations

import jax.numpy as jnp

from repro.core import cycle_model as cm
from repro.core.multipliers import MULTIPLIERS

PAPER_TABLE2 = {  # arch: (complexity, 1-op cycles, 16-op cycles)
    "shift_add": ("O(W)", 8, 128),
    "booth_radix2": ("O(W/2)", 4, 64),
    "nibble_precompute": ("O(W/4)", 2, 32),
    "wallace": ("O(1)", 1, 1),
    "lut_array": ("O(1)", 1, 1),
}


def run() -> list[str]:
    rows = ["table2,arch,complexity,cyc_1op_model,cyc_1op_paper,"
            "cyc_16op_model,cyc_16op_paper,match"]
    a16 = jnp.arange(16, dtype=jnp.int32)
    for arch, (cx, c1_paper, c16_paper) in PAPER_TABLE2.items():
        tr = MULTIPLIERS[arch](a16, 7)
        c1_model = cm.cycles_per_operand(arch)
        c16_model = cm.total_cycles(arch, 16)
        assert tr.cycles == c16_model, (arch, tr.cycles, c16_model)
        match = (c1_model == c1_paper) and (c16_model == c16_paper)
        rows.append(f"table2,{arch},{cx},{c1_model},{c1_paper},"
                    f"{c16_model},{c16_paper},{match}")
    return rows


if __name__ == "__main__":
    print("\n".join(run()))
