"""Kernel-level benchmark: the paper's design points at tensor scale.

Per (M, N, K) shape, times the XLA formulations of each multiplier
design point (CPU wall-clock is a functional proxy — the structural
numbers that transfer to TPU are the flops/bytes derived alongside):

* dense bf16 matmul             — no-paper baseline
* w8a8 nibble (2-pass)          — the paper's precompute-reuse design
* w8a8 one-shot int8 dot        — "shift-add equivalent" monolithic int
* LUT one-hot selection         — the paper's LUT array design
* w4a8 nibble (packed weights)  — nibble storage win (HBM bytes halved)

Pallas-kernel variants run in interpret mode for correctness, not speed;
their per-design flops/bytes columns are the TPU-side cost model.
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.linear import lut_matmul_xla, nibble_matmul_xla
from repro.core.nibble import pack_int4, unpack_int4

SHAPES = [(256, 1024, 1024), (512, 4096, 1024)]


def _time(fn, *args, iters=5):
    fn(*args)[0].block_until_ready() if isinstance(fn(*args), tuple) else \
        fn(*args).block_until_ready()
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    out.block_until_ready()
    return (time.perf_counter() - t0) / iters * 1e6


def run() -> list[str]:
    rows = ["kernel,design,M,N,K,us_per_call,int_flops,weight_bytes,"
            "mxu_passes"]
    rng = np.random.default_rng(0)
    for m, n, k in SHAPES:
        x8 = jnp.asarray(rng.integers(-128, 128, (m, k)), jnp.int8)
        w8 = jnp.asarray(rng.integers(-128, 128, (k, n)), jnp.int8)
        w4 = jnp.asarray(rng.integers(-8, 8, (k, n)), jnp.int8)
        w4p = pack_int4(w4)
        xb = jnp.asarray(rng.normal(size=(m, k)), jnp.bfloat16)
        wb = jnp.asarray(rng.normal(size=(k, n)), jnp.bfloat16)

        flops = 2 * m * n * k

        dense = jax.jit(lambda a, b: a @ b)
        t = _time(dense, xb, wb)
        rows.append(f"kernel,dense_bf16,{m},{n},{k},{t:.1f},{flops},"
                    f"{k * n * 2},1")

        one_shot = jax.jit(lambda a, b: jax.lax.dot_general(
            a, b, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.int32))
        t = _time(one_shot, x8, w8)
        rows.append(f"kernel,int8_monolithic,{m},{n},{k},{t:.1f},{flops},"
                    f"{k * n},1")

        nib = jax.jit(nibble_matmul_xla)
        t = _time(nib, x8, w8)
        rows.append(f"kernel,w8a8_nibble,{m},{n},{k},{t:.1f},{2 * flops},"
                    f"{k * n},2")

        lut = jax.jit(lut_matmul_xla)
        t = _time(lut, x8, w8)
        rows.append(f"kernel,lut_onehot,{m},{n},{k},{t:.1f},"
                    f"{flops * 16 + flops},{k * n},1")

        w4nib = jax.jit(lambda a, wp: nibble_matmul_xla(a, unpack_int4(wp)))
        t = _time(w4nib, x8, w4p)
        rows.append(f"kernel,w4a8_nibble_packed,{m},{n},{k},{t:.1f},"
                    f"{2 * flops},{k * n // 2},2")
    return rows


if __name__ == "__main__":
    print("\n".join(run()))
