"""Kernel-level benchmark: the paper's design points at tensor scale.

Per (M, N, K) shape, times the XLA formulations of each multiplier
design point (CPU wall-clock is a functional proxy — the structural
numbers that transfer to TPU are the flops/bytes derived alongside):

* dense bf16 matmul             — no-paper baseline
* w8a8 nibble (plane-fused)     — the paper's precompute-reuse design,
                                  single MXU pass per K step (the lo/hi
                                  planes are concatenated along K with
                                  the << 4 folded into the operand)
* w8a8 one-shot int8 dot        — "shift-add equivalent" monolithic int
* LUT one-hot selection         — the paper's LUT array design
* w4a8 nibble (packed weights)  — nibble storage win (HBM bytes halved)
* w8a8 fused dequant epilogue   — quantize → nibble matmul → bf16 out in
                                  one pass: no int32 HBM materialization

Pallas-kernel variants run in interpret mode for correctness, not speed;
their per-design flops/bytes columns are the TPU-side cost model.
Columns: ``mxu_passes`` counts dot issues per K step; ``out_bytes`` is
the modeled HBM output traffic (int32 paths write — and with the seed's
revisit scheme, re-read — the int32 block; the fused path writes bf16
exactly once).
"""

from __future__ import annotations

import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.linear import lut_matmul_xla, nibble_matmul_xla
from repro.core.nibble import pack_int4, unpack_int4

SHAPES = [(256, 1024, 1024), (512, 4096, 1024)]

_HEADER = ("kernel,design,M,N,K,us_per_call,int_flops,weight_bytes,"
           "out_bytes,mxu_passes")


def _time(fn, *args, iters=5):
    """Mean per-call microseconds.  One warmup call (compiles + blocks),
    then a timed loop that blocks once at the end — `jax.block_until_ready`
    handles tuple/pytree outputs."""
    jax.block_until_ready(fn(*args))          # warmup, exactly once
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters * 1e6


def _fused_dequant_xla(x, w_q, w_scale):
    """XLA analog of the fused kernel: per-row quantize → plane-fused
    single-dot → scale epilogue → bf16.  int32 never leaves registers."""
    amax = jnp.maximum(jnp.max(jnp.abs(x), axis=-1, keepdims=True), 1e-8)
    x_scale = amax / 127.0
    x_q = jnp.clip(jnp.round(x / x_scale), -128, 127).astype(jnp.int8)
    acc = nibble_matmul_xla(x_q, w_q)
    return (acc.astype(jnp.float32) * x_scale * w_scale) \
        .astype(jnp.bfloat16)


def run_structured() -> list[dict]:
    recs = []
    rng = np.random.default_rng(0)
    for m, n, k in SHAPES:
        x8 = jnp.asarray(rng.integers(-128, 128, (m, k)), jnp.int8)
        w8 = jnp.asarray(rng.integers(-128, 128, (k, n)), jnp.int8)
        w4 = jnp.asarray(rng.integers(-8, 8, (k, n)), jnp.int8)
        w4p = pack_int4(w4)
        xb = jnp.asarray(rng.normal(size=(m, k)), jnp.bfloat16)
        wb = jnp.asarray(rng.normal(size=(k, n)), jnp.bfloat16)
        xf = jnp.asarray(rng.normal(size=(m, k)), jnp.float32)
        ws = jnp.asarray(rng.uniform(0.01, 0.1, (1, n)), jnp.float32)

        flops = 2 * m * n * k
        int32_out = m * n * 4
        bf16_out = m * n * 2

        def rec(design, t, int_flops, weight_bytes, out_bytes, passes):
            recs.append(dict(design=design, M=m, N=n, K=k,
                             us_per_call=round(t, 1), int_flops=int_flops,
                             weight_bytes=weight_bytes, out_bytes=out_bytes,
                             mxu_passes=passes))

        dense = jax.jit(lambda a, b: a @ b)
        rec("dense_bf16", _time(dense, xb, wb), flops, k * n * 2,
            bf16_out, 1)

        one_shot = jax.jit(lambda a, b: jax.lax.dot_general(
            a, b, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.int32))
        rec("int8_monolithic", _time(one_shot, x8, w8), flops, k * n,
            int32_out, 1)

        # plane-fused: one MXU pass over a 2K-wide contraction — the
        # int_flops stay 2·flops (both planes are evaluated), the issue
        # count drops to 1.
        nib = jax.jit(nibble_matmul_xla)
        rec("w8a8_nibble", _time(nib, x8, w8), 2 * flops, k * n,
            int32_out, 1)

        lut = jax.jit(lut_matmul_xla)
        rec("lut_onehot", _time(lut, x8, w8), flops * 16 + flops, k * n,
            int32_out, 1)

        w4nib = jax.jit(lambda a, wp: nibble_matmul_xla(a, unpack_int4(wp)))
        rec("w4a8_nibble_packed", _time(w4nib, x8, w4p), 2 * flops,
            k * n // 2, int32_out, 1)

        fused = jax.jit(_fused_dequant_xla)
        rec("w8a8_nibble_fused_dequant", _time(fused, xf, w8, ws),
            2 * flops, k * n, bf16_out, 1)
    return recs


def _format_row(rec: dict) -> str:
    return ("kernel,{design},{M},{N},{K},{us_per_call:.1f},{int_flops},"
            "{weight_bytes},{out_bytes},{mxu_passes}".format(**rec))


def run() -> list[str]:
    return [_HEADER] + [_format_row(r) for r in run_structured()]


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--json", metavar="PATH", default=None,
                    help="also dump structured records as JSON")
    args = ap.parse_args()
    recs = run_structured()
    if args.json:
        with open(args.json, "w") as f:
            json.dump(recs, f, indent=1)
    print(_HEADER)
    for r in recs:
        print(_format_row(r))
