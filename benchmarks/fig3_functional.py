"""Fig. 3 reproduction: 8-operand vector-scalar functional verification
with cycle-exact execution profiles for both proposed designs."""

from __future__ import annotations

import numpy as np
import jax.numpy as jnp

from repro.core.multipliers import lut_array, nibble_precompute


def run() -> list[str]:
    rows = ["fig3,design,n_operands,cycles,all_products_exact"]
    rng = np.random.default_rng(42)
    a = jnp.asarray(rng.integers(0, 256, 8), jnp.int32)   # Fig. 3 stimulus
    b = 0xB7
    expected = np.asarray(a) * b

    nib = nibble_precompute(a, b)
    rows.append(f"fig3,nibble_precompute,8,{nib.cycles},"
                f"{bool(np.array_equal(np.asarray(nib.products), expected))}")
    lm = lut_array(a, b)
    rows.append(f"fig3,lut_array,8,{lm.cycles},"
                f"{bool(np.array_equal(np.asarray(lm.products), expected))}")
    # paper: nibble = 2 cycles/element × 8 = 16; LUT array = 1 cycle
    assert nib.cycles == 16 and lm.cycles == 1
    return rows


if __name__ == "__main__":
    print("\n".join(run()))
