"""Shard-aware checkpointing with manifest validation and elastic restore.

Layout: ``<dir>/step_<N>/`` holding one ``.npz`` per host-shard plus a
``manifest.json`` (step, pytree structure, shapes/dtypes, shard map,
framework fingerprint).  Writes go to a temp dir + atomic rename, so a
host dying mid-save never corrupts the latest-complete checkpoint —
``latest_step`` only ever sees fully committed directories.

Elastic restore: the manifest records the mesh the state was saved
under; ``restore`` re-shards (pure host-side reshape of the gathered
arrays) when the new mesh differs, which is the checkpoint/restart path
for node-count changes.

Double-buffered "async" save: ``save`` returns immediately after the
host-local serialization thread is handed the arrays (CPU container has
no real DMA to overlap, but the structure — snapshot, hand off, rotate
old checkpoints — is the production one).
"""

from __future__ import annotations

import json
import os
import shutil
import tempfile
import threading
from typing import Any

import jax
import numpy as np

__all__ = ["Checkpointer"]

_MANIFEST = "manifest.json"


def _flatten_with_paths(tree) -> dict[str, Any]:
    flat = {}

    def walk(prefix, node):
        if isinstance(node, dict):
            for k, v in node.items():
                walk(f"{prefix}/{k}" if prefix else str(k), v)
        elif isinstance(node, (list, tuple)):
            for i, v in enumerate(node):
                walk(f"{prefix}/{i}", v)
        else:
            flat[prefix] = node

    walk("", tree)
    return flat


def _unflatten_into(template, flat: dict[str, Any]):
    def walk(prefix, node):
        if isinstance(node, dict):
            return {k: walk(f"{prefix}/{k}" if prefix else str(k), v)
                    for k, v in node.items()}
        if isinstance(node, list):
            return [walk(f"{prefix}/{i}", v) for i, v in enumerate(node)]
        if isinstance(node, tuple):
            return tuple(walk(f"{prefix}/{i}", v)
                         for i, v in enumerate(node))
        return flat[prefix]

    return walk("", template)


class Checkpointer:
    def __init__(self, directory: str, *, keep: int = 3,
                 async_save: bool = True):
        self.dir = directory
        self.keep = keep
        self.async_save = async_save
        self._thread: threading.Thread | None = None
        os.makedirs(directory, exist_ok=True)

    # ------------------------------------------------------------------
    def latest_step(self) -> int | None:
        steps = []
        for name in os.listdir(self.dir):
            if name.startswith("step_") and os.path.exists(
                    os.path.join(self.dir, name, _MANIFEST)):
                steps.append(int(name.split("_")[1]))
        return max(steps) if steps else None

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    # ------------------------------------------------------------------
    def save(self, step: int, state, *, mesh_shape=None,
             host_id: int = 0, n_hosts: int = 1):
        """Snapshot → (optionally async) serialize → atomic rename."""
        flat = _flatten_with_paths(state)
        # snapshot to host memory NOW (donation-safe)
        arrays = {k: np.asarray(v) for k, v in flat.items()}

        def write():
            tmp = tempfile.mkdtemp(dir=self.dir)
            try:
                np.savez(os.path.join(tmp, f"shard_{host_id}.npz"), **{
                    k.replace("/", "__"): v for k, v in arrays.items()})
                manifest = {
                    "step": step,
                    "n_hosts": n_hosts,
                    "mesh_shape": list(mesh_shape or []),
                    "keys": sorted(arrays.keys()),
                    "shapes": {k: list(v.shape) for k, v in arrays.items()},
                    "dtypes": {k: str(v.dtype) for k, v in arrays.items()},
                }
                with open(os.path.join(tmp, _MANIFEST), "w") as f:
                    json.dump(manifest, f)
                final = os.path.join(self.dir, f"step_{step}")
                if os.path.exists(final):
                    shutil.rmtree(final)
                os.rename(tmp, final)
            finally:
                if os.path.exists(tmp):
                    shutil.rmtree(tmp, ignore_errors=True)
            self._gc()

        self.wait()
        if self.async_save:
            self._thread = threading.Thread(target=write, daemon=True)
            self._thread.start()
        else:
            write()

    def _gc(self):
        steps = sorted(
            int(n.split("_")[1]) for n in os.listdir(self.dir)
            if n.startswith("step_")
            and os.path.exists(os.path.join(self.dir, n, _MANIFEST)))
        for s in steps[:-self.keep]:
            shutil.rmtree(os.path.join(self.dir, f"step_{s}"),
                          ignore_errors=True)

    # ------------------------------------------------------------------
    def restore(self, template, step: int | None = None, *,
                host_id: int = 0):
        """Restore into the structure of ``template``.  Validates the
        manifest against the template (missing/extra keys, shape drift)
        and raises with a precise diff on mismatch."""
        self.wait()
        if step is None:
            step = self.latest_step()
            if step is None:
                raise FileNotFoundError(f"no checkpoints in {self.dir}")
        path = os.path.join(self.dir, f"step_{step}")
        with open(os.path.join(path, _MANIFEST)) as f:
            manifest = json.load(f)

        flat_t = _flatten_with_paths(template)
        missing = sorted(set(flat_t) - set(manifest["keys"]))
        extra = sorted(set(manifest["keys"]) - set(flat_t))
        if missing or extra:
            raise ValueError(
                f"checkpoint/template structure mismatch at step {step}: "
                f"missing={missing[:5]} extra={extra[:5]}")

        data = np.load(os.path.join(path, f"shard_{host_id}.npz"))
        flat = {}
        for k in manifest["keys"]:
            arr = data[k.replace("/", "__")]
            want_shape = tuple(flat_t[k].shape)
            if arr.shape != want_shape:
                # elastic re-shard: only leading (batch-like) axis resize
                raise ValueError(
                    f"shape drift for {k}: ckpt {arr.shape} vs "
                    f"template {want_shape}; re-shard before restore")
            flat[k] = jax.numpy.asarray(arr, dtype=flat_t[k].dtype)
        return _unflatten_into(template, flat), manifest["step"]
