"""Mamba2 (SSD — state-space duality) mixer block.

Training/prefill uses the chunked SSD algorithm: quadratic attention-like
math *within* fixed-size chunks, linear state recurrence *across* chunks
(lax.scan).  Decode is the O(1)-per-token recurrence on the (H, N, P)
state — no KV growth, which is why the SSM archs own the ``long_500k``
shape cell.

Parameter layout follows mamba2: fused in_proj producing
(z, x, B, C, dt), causal conv over (x, B, C), per-head A/D scalars,
gated RMSNorm, out_proj.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.linear import linear_apply, linear_init
from repro.models.layers import rms_norm, rms_norm_init

__all__ = ["mamba_init", "mamba_apply", "mamba_step", "init_mamba_cache"]


def _dims(cfg):
    d_in = cfg.d_inner
    h = cfg.ssm_heads
    p = cfg.ssm_head_dim
    g = cfg.ssm_groups
    n = cfg.ssm_state
    conv_ch = d_in + 2 * g * n
    return d_in, h, p, g, n, conv_ch


def mamba_init(key, cfg) -> dict:
    d = cfg.d_model
    d_in, h, p, g, n, conv_ch = _dims(cfg)
    ks = jax.random.split(key, 4)
    proj_out = 2 * d_in + 2 * g * n + h      # z, x, B, C, dt
    return {
        "in_proj": linear_init(ks[0], d, proj_out),
        "conv_w": (jax.random.normal(ks[1], (cfg.conv_width, conv_ch),
                                     jnp.float32) * 0.1).astype(jnp.bfloat16),
        "conv_b": jnp.zeros((conv_ch,), jnp.bfloat16),
        "A_log": jnp.log(jnp.arange(1, h + 1, dtype=jnp.float32)),
        "D": jnp.ones((h,), jnp.float32),
        "dt_bias": jnp.zeros((h,), jnp.float32),
        "norm": rms_norm_init(d_in),
        "out_proj": linear_init(ks[2], d_in, d),
    }


def _split_proj(cfg, proj):
    d_in, h, p, g, n, _ = _dims(cfg)
    z, xbc_dt = jnp.split(proj, [d_in], axis=-1)
    xbc, dt = jnp.split(xbc_dt, [d_in + 2 * g * n], axis=-1)
    return z, xbc, dt


def _causal_conv(xbc, conv_w, conv_b, carry=None):
    """Depthwise causal conv, width W.  carry: (B, W-1, C) history or None."""
    w = conv_w.shape[0]
    if carry is None:
        pad = jnp.zeros((xbc.shape[0], w - 1, xbc.shape[-1]), xbc.dtype)
    else:
        pad = carry.astype(xbc.dtype)
    xp = jnp.concatenate([pad, xbc], axis=1)
    out = sum(xp[:, i:i + xbc.shape[1]] * conv_w[i][None, None, :]
              for i in range(w))
    out = jax.nn.silu((out + conv_b[None, None, :]).astype(jnp.float32))
    new_carry = xp[:, -(w - 1):] if w > 1 else pad
    return out.astype(xbc.dtype), new_carry


def _segsum(a):
    """Lower-triangular pairwise cumulative sums: out[..., i, j] =
    sum(a[..., j+1:i+1]), -inf above the diagonal.  a: (..., L)."""
    l = a.shape[-1]
    cum = jnp.cumsum(a, axis=-1)
    diff = cum[..., :, None] - cum[..., None, :]
    mask = jnp.tril(jnp.ones((l, l), bool))
    return jnp.where(mask, diff, -jnp.inf)


def _ssd_chunked(x, dt, a_coef, b_in, c_in, chunk):
    """Chunked SSD scan.

    x: (B,S,H,P); dt: (B,S,H) (post-softplus); a_coef: (H,) negative;
    b_in/c_in: (B,S,H,N) (already broadcast from groups to heads).
    Returns y: (B,S,H,P) and final state (B,H,N,P).
    """
    bsz, s, h, p = x.shape
    n = b_in.shape[-1]
    nc = s // chunk
    assert s % chunk == 0, (s, chunk)

    def to_chunks(t):
        return t.reshape(bsz, nc, chunk, *t.shape[2:])

    xc, dtc, bc, cc = map(to_chunks, (x, dt, b_in, c_in))
    a = dtc * a_coef[None, None, None, :]            # (B,NC,L,H) log-decay
    a = a.transpose(0, 1, 3, 2)                      # (B,NC,H,L)
    a_cum = jnp.cumsum(a, axis=-1)

    xdt = xc * dtc[..., None]                        # dt-weighted input

    # --- intra-chunk (diagonal) term -------------------------------------
    l_mat = jnp.exp(_segsum(a))                      # (B,NC,H,L,L) lower-tri
    y_diag = jnp.einsum("bclhn,bcshn,bchls,bcshp->bclhp",
                        cc.astype(jnp.float32), bc.astype(jnp.float32),
                        l_mat, xdt.astype(jnp.float32))

    # --- chunk-final states -------------------------------------------------
    decay_to_end = jnp.exp(a_cum[..., -1:] - a_cum)  # (B,NC,H,L)
    states = jnp.einsum("bcshn,bchs,bcshp->bchnp",
                        bc.astype(jnp.float32), decay_to_end,
                        xdt.astype(jnp.float32))

    # --- inter-chunk recurrence (scan over chunks) -----------------------
    chunk_decay = jnp.exp(a_cum[..., -1])            # (B,NC,H)

    def body(prev, inp):
        st, dec = inp                                # (B,H,N,P), (B,H)
        new = st + dec[..., None, None] * prev
        return new, prev                             # emit state *entering* c

    init = jnp.zeros((bsz, h, n, p), jnp.float32)
    final, prev_states = jax.lax.scan(
        body, init,
        (states.transpose(1, 0, 2, 3, 4), chunk_decay.transpose(1, 0, 2)))
    prev_states = prev_states.transpose(1, 0, 2, 3, 4)  # (B,NC,H,N,P)

    # --- inter-chunk (off-diagonal) output ---------------------------------
    y_off = jnp.einsum("bclhn,bchnp,bchl->bclhp",
                       cc.astype(jnp.float32), prev_states,
                       jnp.exp(a_cum))
    y = (y_diag + y_off).reshape(bsz, s, h, p)
    return y, final


def mamba_apply(params, cfg, x, *, return_cache: bool = False):
    """Full-sequence SSD pass.  Returns (out, final_cache_or_None).

    With ``return_cache`` (prefill), the returned dict holds the conv
    tail and final SSM state for decode continuation.
    """
    bsz, s, d = x.shape
    d_in, h, p, g, n, conv_ch = _dims(cfg)
    quant = cfg.quant_mode
    qbackend = cfg.quant_backend

    proj = linear_apply(params["in_proj"], x, mode=quant, backend=qbackend)
    z, xbc, dt_raw = _split_proj(cfg, proj)
    xbc, conv_carry = _causal_conv(xbc, params["conv_w"].astype(jnp.float32),
                                   params["conv_b"].astype(jnp.float32))

    xs, b_in, c_in = jnp.split(xbc, [d_in, d_in + g * n], axis=-1)
    xs = xs.reshape(bsz, s, h, p)
    rep = h // g
    b_in = jnp.repeat(b_in.reshape(bsz, s, g, n), rep, axis=2)
    c_in = jnp.repeat(c_in.reshape(bsz, s, g, n), rep, axis=2)

    dt = jax.nn.softplus(dt_raw.astype(jnp.float32)
                         + params["dt_bias"][None, None, :])
    a_coef = -jnp.exp(params["A_log"])

    chunk = min(cfg.ssm_chunk, s)
    if s % chunk:
        chunk = s                                      # tiny smoke shapes
    y, final_state = _ssd_chunked(xs, dt, a_coef, b_in, c_in, chunk)
    y = y + params["D"][None, None, :, None] * xs.astype(jnp.float32)

    y = y.reshape(bsz, s, d_in)
    y = y * jax.nn.silu(z.astype(jnp.float32))         # gated
    y = rms_norm(params["norm"], y.astype(x.dtype), cfg.norm_eps)
    out = linear_apply(params["out_proj"], y, mode=quant, backend=qbackend)

    new_cache = None
    if return_cache:
        new_cache = {"conv": conv_carry.astype(jnp.bfloat16),
                     "ssm": final_state.astype(jnp.float32)}
    return out, new_cache


def init_mamba_cache(cfg, batch: int) -> dict:
    d_in, h, p, g, n, conv_ch = _dims(cfg)
    return {
        "conv": jnp.zeros((batch, cfg.conv_width - 1, conv_ch), jnp.bfloat16),
        "ssm": jnp.zeros((batch, h, n, p), jnp.float32),
    }


def mamba_step(params, cfg, x, cache):
    """Single-token decode: O(1) state update.  x: (B, 1, D)."""
    bsz = x.shape[0]
    d_in, h, p, g, n, conv_ch = _dims(cfg)
    quant = cfg.quant_mode
    qbackend = cfg.quant_backend

    proj = linear_apply(params["in_proj"], x, mode=quant, backend=qbackend)
    z, xbc, dt_raw = _split_proj(cfg, proj)
    xbc, conv_carry = _causal_conv(
        xbc, params["conv_w"].astype(jnp.float32),
        params["conv_b"].astype(jnp.float32), carry=cache["conv"])

    xs, b_in, c_in = jnp.split(xbc[:, 0], [d_in, d_in + g * n], axis=-1)
    xs = xs.reshape(bsz, h, p).astype(jnp.float32)
    rep = h // g
    b_in = jnp.repeat(b_in.reshape(bsz, g, n), rep, axis=1) \
        .astype(jnp.float32)
    c_in = jnp.repeat(c_in.reshape(bsz, g, n), rep, axis=1) \
        .astype(jnp.float32)

    dt = jax.nn.softplus(dt_raw[:, 0].astype(jnp.float32)
                         + params["dt_bias"][None, :])        # (B,H)
    a_coef = -jnp.exp(params["A_log"])
    decay = jnp.exp(dt * a_coef[None, :])                     # (B,H)

    state = cache["ssm"]
    state = decay[..., None, None] * state \
        + jnp.einsum("bhn,bh,bhp->bhnp", b_in, dt, xs)
    y = jnp.einsum("bhn,bhnp->bhp", c_in, state) \
        + params["D"][None, :, None] * xs

    y = y.reshape(bsz, 1, d_in)
    y = y * jax.nn.silu(z.astype(jnp.float32))
    y = rms_norm(params["norm"], y.astype(x.dtype), cfg.norm_eps)
    out = linear_apply(params["out_proj"], y, mode=quant, backend=qbackend)
    return out, {"conv": conv_carry.astype(jnp.bfloat16), "ssm": state}
