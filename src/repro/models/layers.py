"""Shared neural building blocks (pure-JAX functional modules).

Parameters are nested dicts of arrays; every projection goes through
``repro.core.linear`` so the paper's quantized execution modes apply
uniformly across the zoo.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.linear import linear_apply, linear_init

__all__ = ["rms_norm_init", "rms_norm", "mlp_init", "mlp_apply",
           "embed_init", "embed_apply", "rope", "apply_rope"]


# ---------------------------------------------------------------------------
# RMSNorm
# ---------------------------------------------------------------------------

def rms_norm_init(dim: int) -> dict:
    return {"scale": jnp.zeros((dim,), jnp.float32)}


def rms_norm(params: dict, x: jax.Array, eps: float = 1e-6) -> jax.Array:
    dtype = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    x = x * jax.lax.rsqrt(var + eps)
    return (x * (1.0 + params["scale"])).astype(dtype)


# ---------------------------------------------------------------------------
# Rotary position embedding
# ---------------------------------------------------------------------------

def rope(positions: jax.Array, head_dim: int,
         theta: float = 10_000.0) -> tuple[jax.Array, jax.Array]:
    """(sin, cos) tables for integer positions, shape (..., head_dim//2)."""
    freqs = theta ** (-jnp.arange(0, head_dim // 2, dtype=jnp.float32)
                      / (head_dim // 2))
    angles = positions.astype(jnp.float32)[..., None] * freqs
    return jnp.sin(angles), jnp.cos(angles)


def apply_rope(x: jax.Array, sin: jax.Array, cos: jax.Array) -> jax.Array:
    """x: (..., S, H, D); sin/cos: (..., S, D/2) broadcast over heads."""
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    sin = sin[..., None, :]
    cos = cos[..., None, :]
    return jnp.concatenate([x1 * cos - x2 * sin,
                            x2 * cos + x1 * sin], axis=-1)


# ---------------------------------------------------------------------------
# Gated MLP (SwiGLU / GeGLU)
# ---------------------------------------------------------------------------

def mlp_init(key, d_model: int, d_ff: int) -> dict:
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "gate": linear_init(k1, d_model, d_ff),
        "up": linear_init(k2, d_model, d_ff),
        "down": linear_init(k3, d_ff, d_model),
    }


def mlp_apply(params: dict, x: jax.Array, *, act: str = "silu",
              quant_mode: str = "dense",
              quant_backend: str = "xla") -> jax.Array:
    g = linear_apply(params["gate"], x, mode=quant_mode,
                     backend=quant_backend)
    u = linear_apply(params["up"], x, mode=quant_mode,
                     backend=quant_backend)
    if act == "gelu":
        g = jax.nn.gelu(g.astype(jnp.float32), approximate=True).astype(x.dtype)
    else:
        g = jax.nn.silu(g.astype(jnp.float32)).astype(x.dtype)
    return linear_apply(params["down"], g * u, mode=quant_mode,
                        backend=quant_backend)


# ---------------------------------------------------------------------------
# Token embedding (+ tied LM head)
# ---------------------------------------------------------------------------

def embed_init(key, vocab: int, d_model: int) -> dict:
    emb = jax.random.normal(key, (vocab, d_model), jnp.float32) * 0.02
    return {"emb": emb.astype(jnp.bfloat16)}


def embed_apply(params: dict, tokens: jax.Array, *,
                scale_by_sqrt_dim: bool = False) -> jax.Array:
    x = params["emb"][tokens]
    if scale_by_sqrt_dim:
        x = x * jnp.sqrt(jnp.float32(x.shape[-1])).astype(x.dtype)
    return x


def embed_logits(params: dict, x: jax.Array) -> jax.Array:
    """Tied LM head: x @ emb^T with f32 accumulation (no f32 copy of the
    embedding table — ``preferred_element_type`` upcasts in the MXU)."""
    return jnp.dot(x, params["emb"].T,
                   preferred_element_type=jnp.float32)
