"""Mixture-of-Experts block: top-k routing, sort-based dispatch, EP-ready.

Two execution paths, same mathematics:

* **local** (no mesh): fixed-shape sort/scatter dispatch on the whole
  token set — used by unit tests and single-device runs.
* **sharded** (ambient mesh with a "model" axis and E % tp == 0): a
  ``shard_map`` over the mesh.  Tokens stay sharded on the data axes and
  *replicated* across "model"; each model shard owns E/tp experts,
  locally dispatches only the (token, k) assignments routed to its
  experts, and the combine is a single ``psum`` over "model".  This
  keeps every buffer local-token-sized — the naive global formulation
  makes XLA all-gather the full 1M-token batch for the argsort (measured
  726 GB/device temps on deepseek-v3 before this path existed).

Dispatch details (both paths): flatten (token, k) assignments, sort by
expert id (stable), position-in-segment via cumsum offsets, capacity
``C = ceil(k·T/E · capacity_factor)`` with overflow dropped, scatter-add
into the (E, C, D) buffer (add, not set: dropped entries contribute
zeros at slot (0,0) and must not overwrite a real resident).

Expert weights are stacked (E, ·, ·) arrays → EP is one PartitionSpec.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.layers import mlp_apply, mlp_init

__all__ = ["moe_init", "moe_apply"]


def moe_init(key, cfg) -> dict:
    d, f, e = cfg.d_model, cfg.d_ff_expert, cfg.n_experts
    ks = jax.random.split(key, 5)
    scale = (2.0 / (d + f)) ** 0.5

    def w(k, shape):
        return (jax.random.normal(k, shape, jnp.float32) * scale) \
            .astype(jnp.bfloat16)

    p = {
        "router": (jax.random.normal(ks[0], (d, e), jnp.float32) * 0.02)
        .astype(jnp.float32),
        "w_gate": w(ks[1], (e, d, f)),
        "w_up": w(ks[2], (e, d, f)),
        "w_down": w(ks[3], (e, f, d)),
    }
    if cfg.n_shared_experts:
        p["shared"] = mlp_init(ks[4], d,
                               cfg.d_ff_expert * cfg.n_shared_experts)
    return p


def _capacity(cfg, n_tokens: int, n_experts: int) -> int:
    c = int(cfg.top_k * n_tokens / n_experts * cfg.capacity_factor)
    return max(8, c)


def _route(params, cfg, xt):
    """Shared routing math.  xt: (T, D) → (top_w, top_e, aux_loss).

    The router dot upcasts in the MXU (``preferred_element_type``)
    instead of materializing an f32 copy of xt — that copy was being
    saved as a shard_map residual across every scanned layer (measured:
    a 101 GiB/device f32[58,B,S,D] stack on deepseek-v3 train)."""
    e, k = cfg.n_experts, cfg.top_k
    t = xt.shape[0]
    logits = jnp.dot(xt, params["router"].astype(xt.dtype),
                     preferred_element_type=jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    top_w, top_e = jax.lax.top_k(probs, k)
    top_w = top_w / jnp.maximum(top_w.sum(-1, keepdims=True), 1e-9)
    me = probs.mean(0)
    ce = jnp.zeros((e,)).at[top_e.reshape(-1)].add(1.0) / (t * k)
    aux = e * jnp.sum(me * ce)
    return top_w, top_e, aux


def _dispatch_compute_combine(cfg, xt, top_w, top_e, w_gate, w_up, w_down,
                              *, e_lo: int, e_count: int, cap: int):
    """Sort-dispatch the assignments in [e_lo, e_lo+e_count) onto the
    local expert stack, run the FFN, combine back to (T, D) (zeros for
    tokens routed elsewhere)."""
    t, d = xt.shape
    k = cfg.top_k

    flat_e = top_e.reshape(-1)
    flat_w = top_w.reshape(-1).astype(jnp.float32)
    flat_tok = jnp.arange(t * k, dtype=jnp.int32) // k

    mine = (flat_e >= e_lo) & (flat_e < e_lo + e_count)
    loc_e = jnp.where(mine, flat_e - e_lo, e_count)      # e_count = overflow

    order = jnp.argsort(loc_e, stable=True)
    e_sorted = loc_e[order]
    tok_sorted = flat_tok[order]
    w_sorted = flat_w[order]

    counts = jnp.zeros((e_count + 1,), jnp.int32).at[e_sorted].add(1)
    seg_start = jnp.concatenate([jnp.zeros((1,), jnp.int32),
                                 jnp.cumsum(counts)[:-1]])
    pos_in_e = jnp.arange(t * k, dtype=jnp.int32) - seg_start[e_sorted]
    keep = (pos_in_e < cap) & (e_sorted < e_count)

    slot_e = jnp.where(keep, e_sorted, 0)
    slot_c = jnp.where(keep, pos_in_e, 0)
    w_eff = jnp.where(keep, w_sorted, 0.0)

    contrib_in = jnp.where(keep[:, None], xt[tok_sorted], 0).astype(xt.dtype)
    expert_in = jnp.zeros((e_count, cap, d), xt.dtype) \
        .at[slot_e, slot_c].add(contrib_in)

    def ffn(h):
        g = jnp.einsum("ecd,edf->ecf", h, w_gate.astype(h.dtype))
        u = jnp.einsum("ecd,edf->ecf", h, w_up.astype(h.dtype))
        g = jax.nn.silu(g.astype(jnp.float32)).astype(h.dtype)
        return jnp.einsum("ecf,efd->ecd", g * u, w_down.astype(h.dtype))

    expert_out = ffn(expert_in)

    gathered = expert_out[slot_e, slot_c]
    contrib = gathered.astype(jnp.float32) * w_eff[:, None]
    out = jnp.zeros((t, d), jnp.float32).at[tok_sorted].add(contrib)
    return out.astype(xt.dtype)


# ---------------------------------------------------------------------------
# Execution paths
# ---------------------------------------------------------------------------

def _moe_local(params, cfg, x):
    b, s, d = x.shape
    xt = x.reshape(b * s, d)
    top_w, top_e, aux = _route(params, cfg, xt)
    cap = _capacity(cfg, b * s, cfg.n_experts)
    out = _dispatch_compute_combine(
        cfg, xt, top_w, top_e, params["w_gate"], params["w_up"],
        params["w_down"], e_lo=0, e_count=cfg.n_experts, cap=cap)
    return out.reshape(b, s, d), aux


def _moe_sharded(params, cfg, x, mesh):
    """shard_map EP: tokens on data axes, experts on the model axis."""
    from jax.sharding import PartitionSpec as P
    try:
        from jax import shard_map as _shard_map
        shard_map = _shard_map
    except ImportError:  # pragma: no cover
        from jax.experimental.shard_map import shard_map

    from repro.distributed.sharding import TP_AXIS

    tp = mesh.shape[TP_AXIS]
    e_per = cfg.n_experts // tp
    dp = tuple(a for a in mesh.axis_names if a != TP_AXIS)
    b = x.shape[0]
    dp_size = 1
    for a in dp:
        dp_size *= mesh.shape[a]
    x_spec = P(dp, None, None) if b % dp_size == 0 else P(None, None, None)

    def local_fn(x_loc, router, w_gate, w_up, w_down):
        bl, sl, d = x_loc.shape
        xt = x_loc.reshape(bl * sl, d)
        top_w, top_e, aux = _route({"router": router}, cfg, xt)
        # capacity is per *local* token count: same expected load per
        # expert as the global formulation, locally bounded buffers.
        cap = _capacity(cfg, bl * sl, cfg.n_experts)
        m_idx = jax.lax.axis_index(TP_AXIS)
        e_lo = m_idx * e_per
        out = _dispatch_compute_combine(
            cfg, xt, top_w, top_e, w_gate, w_up, w_down,
            e_lo=e_lo, e_count=e_per, cap=cap)
        out = jax.lax.psum(out, TP_AXIS)     # combine across expert shards
        return out.reshape(bl, sl, d), aux

    # remat inside the shard_map: its residuals are otherwise saved by
    # the *forward* layer scan (the outer jax.checkpoint does not make
    # shard_map internals primal-only), stacking per-layer buffers.
    local_fn = jax.checkpoint(local_fn, prevent_cse=False)

    out, aux = shard_map(
        local_fn, mesh=mesh,
        in_specs=(x_spec, P(None, None), P(TP_AXIS, None, None),
                  P(TP_AXIS, None, None), P(TP_AXIS, None, None)),
        out_specs=(x_spec, P()),
        check_vma=False,
    )(x, params["router"], params["w_gate"], params["w_up"],
      params["w_down"])
    return out, aux


def moe_apply(params, cfg, x, *, rng=None):
    """x: (B, S, D) → (out (B,S,D), aux_loss scalar)."""
    from repro.distributed import sharding as shr

    mesh = shr._AMBIENT_MESH
    if (mesh is not None and shr.TP_AXIS in mesh.axis_names
            and cfg.n_experts % mesh.shape[shr.TP_AXIS] == 0):
        out, aux = _moe_sharded(params, cfg, x, mesh)
    else:
        out, aux = _moe_local(params, cfg, x)

    if cfg.n_shared_experts:
        b, s, d = x.shape
        shared = mlp_apply(params["shared"], x.reshape(b * s, d),
                           act=cfg.act, quant_mode=cfg.quant_mode,
                           quant_backend=cfg.quant_backend)
        out = out + shared.reshape(b, s, d)
    return out, aux
