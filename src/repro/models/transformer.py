"""Model assembly: heterogeneous layer stacks, scan-over-blocks, caches.

The layer stack is ``prefix + block×n + suffix`` (configs/base.py); the
repeated blocks run under ``jax.lax.scan`` with parameters stacked on a
leading block axis — compile time stays flat in depth (one HLO body per
distinct block), which is what makes the 61-layer deepseek dry-run
tractable.  Heterogeneous layers *within* a block (gemma3's 5 local + 1
global, jamba's mamba/attn + mlp/moe interleave) are unrolled inside the
scan body.

Three entry points per model, matching the dry-run shapes:
* ``forward``      — full-sequence logits (training);
* ``prefill``      — full-sequence pass that also returns decode caches;
* ``decode_step``  — one token against the caches.
"""

from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import LayerSpec, ModelConfig
from repro.models.attention import (
    attn_apply,
    attn_init,
    init_kv_cache,
    init_mla_cache,
    init_paged_kv_cache,
    init_paged_mla_cache,
    mla_apply,
    mla_init,
)
from repro.models.layers import (
    embed_apply,
    embed_init,
    embed_logits,
    mlp_apply,
    mlp_init,
    rms_norm,
    rms_norm_init,
)
from repro.models.mamba import (
    init_mamba_cache,
    mamba_apply,
    mamba_init,
    mamba_step,
)
from repro.models.moe import moe_apply, moe_init

__all__ = ["model_init", "forward", "prefill", "decode_step", "init_caches",
           "init_paged_caches", "merge_slot_caches",
           "merge_slot_paged_caches", "scatter_prefill_paged_caches",
           "copy_paged_cache_page", "extract_cache_pages",
           "insert_cache_pages", "encode", "unrolled_blocks"]

# When True, the block stack is a Python loop instead of lax.scan, so the
# compiled HLO contains every layer body.  Used by the dry-run cost pass:
# XLA cost_analysis excludes while-loop bodies (measured: gemma-7b flops
# identical at 1, 2 and 3 scanned blocks), so scanned programs are costed
# by lowering 1- and 2-block *unrolled* variants and extrapolating.
_UNROLL_BLOCKS = False

import contextlib


@contextlib.contextmanager
def unrolled_blocks():
    global _UNROLL_BLOCKS
    prev, _UNROLL_BLOCKS = _UNROLL_BLOCKS, True
    try:
        yield
    finally:
        _UNROLL_BLOCKS = prev


# ---------------------------------------------------------------------------
# Single layer
# ---------------------------------------------------------------------------

def _layer_init(key, cfg: ModelConfig, spec: LayerSpec, *,
                cross: bool = False) -> dict:
    ks = jax.random.split(key, 4)
    p: dict[str, Any] = {"mixer_norm": rms_norm_init(cfg.d_model)}
    if spec.mixer == "attn":
        if spec.attn_kind == "mla":
            p["attn"] = mla_init(ks[0], cfg)
        else:
            p["attn"] = attn_init(ks[0], cfg)
    elif spec.mixer == "mamba":
        p["mamba"] = mamba_init(ks[0], cfg)
    if cross:
        p["cross_norm"] = rms_norm_init(cfg.d_model)
        p["cross"] = attn_init(ks[2], cfg)
    if spec.ffn != "none":
        p["ffn_norm"] = rms_norm_init(cfg.d_model)
        if spec.ffn == "moe":
            p["moe"] = moe_init(ks[1], cfg)
        else:
            p["mlp"] = mlp_init(ks[1], cfg.d_model, cfg.d_ff)
    return p


def _layer_apply(params, cfg: ModelConfig, spec: LayerSpec, x, *,
                 positions, cache=None, cache_index=None, enc_out=None,
                 causal=True, mode="train", page_table=None,
                 context_start=None):
    """Returns (x, new_cache, aux_loss)."""
    aux = jnp.zeros((), jnp.float32)
    new_cache: dict = {}
    cache = cache or {}

    if spec.mixer == "attn":
        h = rms_norm(params["mixer_norm"], x, cfg.norm_eps)
        if spec.attn_kind == "mla":
            out, c = mla_apply(params["attn"], cfg, h, positions=positions,
                               cache=cache.get("attn"),
                               cache_index=cache_index,
                               return_cache=(mode == "prefill"),
                               page_table=page_table,
                               context_start=context_start)
        else:
            out, c = attn_apply(params["attn"], cfg, h, positions=positions,
                                kind=spec.attn_kind,
                                cache=cache.get("attn"),
                                cache_index=cache_index, causal=causal,
                                return_cache=(mode == "prefill"),
                                page_table=page_table,
                                context_start=context_start)
        if c is not None:
            new_cache["attn"] = c
        x = x + out
    elif spec.mixer == "mamba":
        h = rms_norm(params["mixer_norm"], x, cfg.norm_eps)
        if mode == "decode":
            out, c = mamba_step(params["mamba"], cfg, h, cache["mamba"])
            new_cache["mamba"] = c
        else:
            out, c = mamba_apply(params["mamba"], cfg, h,
                                 return_cache=(mode == "prefill"))
            if c is not None:
                new_cache["mamba"] = c
        x = x + out

    if "cross" in params and enc_out is not None:
        h = rms_norm(params["cross_norm"], x, cfg.norm_eps)
        out, _ = attn_apply(params["cross"], cfg, h, positions=positions,
                            kv_source=enc_out, causal=False)
        x = x + out

    if spec.ffn != "none":
        h = rms_norm(params["ffn_norm"], x, cfg.norm_eps)
        if spec.ffn == "moe":
            out, aux = moe_apply(params["moe"], cfg, h)
        else:
            out = mlp_apply(params["mlp"], h, act=cfg.act,
                            quant_mode=cfg.quant_mode,
                            quant_backend=cfg.quant_backend)
        x = x + out
    return x, new_cache, aux


# ---------------------------------------------------------------------------
# Stack = prefix + scan(blocks) + suffix
# ---------------------------------------------------------------------------

def _stack_init(key, cfg: ModelConfig, *, cross: bool = False) -> dict:
    n_blk = cfg.n_blocks
    keys = jax.random.split(key, 3)

    prefix = [
        _layer_init(k, cfg, spec, cross=cross)
        for k, spec in zip(jax.random.split(keys[0],
                                            max(1, len(cfg.prefix_pattern))),
                           cfg.prefix_pattern)
    ]
    suffix = [
        _layer_init(k, cfg, spec, cross=cross)
        for k, spec in zip(jax.random.split(keys[2],
                                            max(1, len(cfg.suffix_pattern))),
                           cfg.suffix_pattern)
    ]

    # blocks: per pattern position, vmapped init over the block axis
    blk_keys = jax.random.split(keys[1], n_blk * len(cfg.block_pattern)) \
        .reshape(n_blk, len(cfg.block_pattern), 2)
    blocks = {}
    for j, spec in enumerate(cfg.block_pattern):
        init_j = functools.partial(_layer_init, cfg=cfg, spec=spec,
                                   cross=cross)
        blocks[str(j)] = jax.vmap(lambda k: init_j(k))(blk_keys[:, j])
    return {"prefix": prefix, "blocks": blocks, "suffix": suffix}


def _stack_apply(params, cfg: ModelConfig, x, *, positions, caches=None,
                 cache_index=None, enc_out=None, causal=True, mode="train",
                 page_table=None, context_start=None):
    """Returns (x, new_caches, total_aux)."""
    total_aux = jnp.zeros((), jnp.float32)
    want_cache = mode in ("prefill", "decode")
    new_caches: dict = {"prefix": [], "blocks": None, "suffix": []}
    caches = caches or {"prefix": [None] * len(cfg.prefix_pattern),
                        "blocks": None,
                        "suffix": [None] * len(cfg.suffix_pattern)}

    from repro.distributed.sharding import maybe_shard

    def run_layer(p, spec, x, cache):
        x = maybe_shard(x, "activation")   # pin (dp, ∅, ∅) between layers
        return _layer_apply(p, cfg, spec, x, positions=positions,
                            cache=cache, cache_index=cache_index,
                            enc_out=enc_out, causal=causal, mode=mode,
                            page_table=page_table,
                            context_start=context_start)

    # prefix/suffix layers run OUTSIDE the scanned-and-checkpointed
    # blocks; without their own remat, all their attention internals
    # (f32 probability chunks: ~34 GiB per chunk on deepseek's MLA
    # prefix) are saved for backward.
    fixed_layer = run_layer
    if cfg.remat and mode == "train":
        fixed_layer = jax.checkpoint(run_layer, prevent_cse=False,
                                     static_argnums=(1,))

    for p, spec, c in zip(params["prefix"], cfg.prefix_pattern,
                          caches["prefix"]):
        x, nc, aux = fixed_layer(p, spec, x, c)
        total_aux += aux
        new_caches["prefix"].append(nc)

    # --- scanned blocks -----------------------------------------------------
    if cfg.n_blocks:
        def block_body(carry, xs):
            h, aux_acc = carry
            blk_params, blk_caches = xs
            blk_new = {}
            for j, spec in enumerate(cfg.block_pattern):
                c = blk_caches[str(j)] if blk_caches is not None else None
                h, nc, aux = run_layer(blk_params[str(j)], spec, h, c)
                aux_acc += aux
                blk_new[str(j)] = nc
            return (h, aux_acc), (blk_new if want_cache else 0)

        body = block_body
        if cfg.remat and mode == "train":
            body = jax.checkpoint(block_body, prevent_cse=False)

        xs = (params["blocks"], caches["blocks"])
        if _UNROLL_BLOCKS:
            emitted = []
            carry = (x, total_aux)
            for i in range(cfg.n_blocks):
                xs_i = jax.tree_util.tree_map(lambda a: a[i], xs)
                carry, y = body(carry, xs_i)
                emitted.append(y)
            (x, total_aux) = carry
            blk_caches_out = (jax.tree_util.tree_map(
                lambda *ys: jnp.stack(ys), *emitted)
                if want_cache else None)
        else:
            (x, total_aux), blk_caches_out = jax.lax.scan(
                body, (x, total_aux), xs)
        if want_cache:
            new_caches["blocks"] = blk_caches_out

    for p, spec, c in zip(params["suffix"], cfg.suffix_pattern,
                          caches["suffix"]):
        x, nc, aux = fixed_layer(p, spec, x, c)
        total_aux += aux
        new_caches["suffix"].append(nc)

    return x, (new_caches if want_cache else None), total_aux


# ---------------------------------------------------------------------------
# Whole models
# ---------------------------------------------------------------------------

def model_init(key, cfg: ModelConfig) -> dict:
    ks = jax.random.split(key, 5)
    params = {
        "embed": embed_init(ks[0], cfg.vocab_size, cfg.d_model),
        "stack": _stack_init(ks[1], cfg, cross=cfg.is_encdec),
        "final_norm": rms_norm_init(cfg.d_model),
    }
    if not cfg.tie_embeddings:
        from repro.core.linear import linear_init
        params["lm_head"] = linear_init(ks[2], cfg.d_model, cfg.vocab_size)
    if cfg.is_encdec:
        enc_cfg = cfg.replace(n_layers=cfg.n_enc_layers,
                              block_pattern=(LayerSpec(),),
                              prefix_pattern=(), suffix_pattern=())
        params["encoder"] = {
            "stack": _stack_init(ks[3], enc_cfg),
            "norm": rms_norm_init(cfg.d_model),
        }
    return params


def _logits(params, cfg, x):
    from repro.distributed.sharding import maybe_shard
    x = rms_norm(params["final_norm"], x, cfg.norm_eps)
    if cfg.tie_embeddings:
        out = embed_logits(params["embed"], x)
    else:
        from repro.core.linear import linear_apply
        out = linear_apply(params["lm_head"], x, mode="dense") \
            .astype(jnp.float32)
    # vocab-sharded logits: keeps the softmax/CE temporaries distributed
    return maybe_shard(out, "logits")


def encode(params, cfg: ModelConfig, frames: jax.Array) -> jax.Array:
    """Encoder pass over stub-frontend frame embeddings (B, S_enc, D)."""
    enc_cfg = cfg.replace(n_layers=cfg.n_enc_layers,
                          block_pattern=(LayerSpec(),),
                          prefix_pattern=(), suffix_pattern=())
    b, s, _ = frames.shape
    pos = jnp.broadcast_to(jnp.arange(s)[None, :], (b, s))
    x, _, _ = _stack_apply(params["encoder"]["stack"], enc_cfg, frames,
                           positions=pos, causal=False, mode="train")
    return rms_norm(params["encoder"]["norm"], x, cfg.norm_eps)


def _embed_inputs(params, cfg, tokens, extra_embeds):
    x = embed_apply(params["embed"], tokens,
                    scale_by_sqrt_dim=cfg.emb_scale_by_sqrt_dim)
    if extra_embeds is not None:   # VLM stub frontend: prepend patch embeds
        x = jnp.concatenate([extra_embeds.astype(x.dtype), x], axis=1)
    return x


def forward(params, cfg: ModelConfig, tokens, *, extra_embeds=None,
            frames=None):
    """Training logits.  tokens: (B, S) int32.

    * VLM: ``extra_embeds`` (B, P, D) prepended (logits cover P+S).
    * enc-dec: ``frames`` (B, S_enc, D) run through the encoder first.
    """
    enc_out = encode(params, cfg, frames) if frames is not None else None
    x = _embed_inputs(params, cfg, tokens, extra_embeds)
    b, s, _ = x.shape
    pos = jnp.broadcast_to(jnp.arange(s)[None, :], (b, s))
    x, _, aux = _stack_apply(params["stack"], cfg, x, positions=pos,
                             enc_out=enc_out, mode="train")
    return _logits(params, cfg, x), aux


def init_caches(cfg: ModelConfig, batch: int, max_len: int) -> dict:
    """Decode caches: dense per-slot slabs, or — when
    ``cfg.cache_mode == "paged"`` — shared page pools (see
    :func:`init_paged_caches`) addressed through a page table.  Mamba
    recurrent state has no sequence axis and stays per-slot either way.
    """
    if cfg.cache_mode == "paged":
        page_size = cfg.page_size
        if page_size < 1:
            raise ValueError(f"page_size must be >= 1, got {page_size}")
        if max_len % page_size:
            raise ValueError(f"max_len {max_len} must be a multiple of "
                             f"page_size {page_size}")
        # auto pool: capacity parity with the dense slab + trash page
        num_pages = cfg.num_pages or batch * (max_len // page_size) + 1
        return init_paged_caches(cfg, batch, num_pages, page_size)

    def layer_cache(spec: LayerSpec):
        if spec.mixer == "attn":
            if spec.attn_kind == "mla":
                return {"attn": init_mla_cache(cfg, batch, max_len)}
            return {"attn": init_kv_cache(cfg, batch, max_len)}
        if spec.mixer == "mamba":
            return {"mamba": init_mamba_cache(cfg, batch)}
        return {}

    def stacked(spec: LayerSpec):
        one = layer_cache(spec)
        return jax.tree_util.tree_map(
            lambda a: jnp.broadcast_to(a[None], (cfg.n_blocks, *a.shape))
            .copy() if cfg.n_blocks else a, one)

    return {
        "prefix": [layer_cache(s) for s in cfg.prefix_pattern],
        "blocks": {str(j): stacked(s)
                   for j, s in enumerate(cfg.block_pattern)}
        if cfg.n_blocks else None,
        "suffix": [layer_cache(s) for s in cfg.suffix_pattern],
    }


def init_paged_caches(cfg: ModelConfig, batch: int, num_pages: int,
                      page_size: int) -> dict:
    """Paged dual of :func:`init_caches`: every attention/MLA leaf is a
    shared ``(num_pages, page_size, ...)`` pool (one pool per layer; the
    scanned blocks stack pools on their leading block axis exactly like
    the dense slabs).  Capacity scales with *live* tokens: ``num_pages``
    is a workload knob, not ``batch × max_len / page_size``."""
    def layer_cache(spec: LayerSpec):
        if spec.mixer == "attn":
            if spec.attn_kind == "mla":
                return {"attn": init_paged_mla_cache(cfg, num_pages,
                                                     page_size)}
            return {"attn": init_paged_kv_cache(cfg, num_pages, page_size)}
        if spec.mixer == "mamba":
            return {"mamba": init_mamba_cache(cfg, batch)}
        return {}

    def stacked(spec: LayerSpec):
        one = layer_cache(spec)
        return jax.tree_util.tree_map(
            lambda a: jnp.broadcast_to(a[None], (cfg.n_blocks, *a.shape))
            .copy() if cfg.n_blocks else a, one)

    return {
        "prefix": [layer_cache(s) for s in cfg.prefix_pattern],
        "blocks": {str(j): stacked(s)
                   for j, s in enumerate(cfg.block_pattern)}
        if cfg.n_blocks else None,
        "suffix": [layer_cache(s) for s in cfg.suffix_pattern],
    }


def prefill(params, cfg: ModelConfig, tokens, *, extra_embeds=None,
            frames=None, max_len: int | None = None, logits_index=None,
            ctx_caches=None, ctx_table=None, ctx_start=None):
    """Run the prompt, return (next-token logits, caches, enc_out).

    ``logits_index`` selects which position's logits to return (default:
    the last).  It may be a traced scalar or ``(B,)`` vector, which is
    what lets a serving engine prefill prompts *padded* to a fixed slot
    budget — the real prompt length is data, not shape, so one
    compilation serves every request.  (Cache rows written by the pad
    tokens are harmless: decode overwrites row ``p`` before any query
    can attend to it.)

    Context prefill (prefix caching): with ``ctx_caches`` (paged cache
    pools), ``ctx_table`` (the slot's (1, max_pages) page-table row) and
    ``ctx_start`` (traced scalar), ``tokens`` holds only the *uncached
    suffix* of a prompt whose first ``ctx_start`` rows already sit in
    shared pool pages.  Queries run at global positions ``ctx_start +
    [0, S)`` and every attention layer splices the gathered cached rows
    below the fresh ones (see ``attn_apply``); the returned caches hold
    the suffix rows only.  ``ctx_start`` is data, not shape — one
    compilation serves hit and miss alike, and a miss (``ctx_start ==
    0``) is bit-identical to a plain full-prompt prefill.
    """
    enc_out = encode(params, cfg, frames) if frames is not None else None
    x = _embed_inputs(params, cfg, tokens, extra_embeds)
    b, s, _ = x.shape
    pos = jnp.arange(s)[None, :]
    if ctx_start is not None:
        pos = pos + jnp.asarray(ctx_start, jnp.int32)
    pos = jnp.broadcast_to(pos, (b, s))
    x, caches, _ = _stack_apply(params["stack"], cfg, x, positions=pos,
                                enc_out=enc_out, mode="prefill",
                                caches=ctx_caches, page_table=ctx_table,
                                context_start=ctx_start)
    if logits_index is None:
        x_last = x[:, -1:]
    else:
        idx = jnp.broadcast_to(jnp.asarray(logits_index, jnp.int32)
                               .reshape(-1), (b,))
        x_last = jnp.take_along_axis(x, idx[:, None, None], axis=1)
    logits = _logits(params, cfg, x_last)
    if max_len is not None and max_len > s:
        caches = _grow_caches(cfg, caches, s, max_len)
    return logits, caches, enc_out


# Cache leaves with a sequence axis (always axis 1 after any block-stack
# leading axis is accounted for) — padded out to the decode budget.
_SEQ_CACHE_KEYS = {"k", "v", "c_kv", "k_rope", "k_scale", "v_scale"}


def _is_block_leaf(path) -> bool:
    """True when a cache-tree path points inside the scanned-block
    subtree, whose leaves carry a leading ``n_blocks`` axis.  The cache
    tree is ``{"prefix": [...], "blocks": {...}, "suffix": [...]}``, so
    the top-level dict key decides the layout — structurally, never by
    comparing coincidental sizes (``batch == prompt_len`` makes axis 1
    of a block-stacked leaf look like a sequence axis)."""
    head = path[0]
    return isinstance(head, jax.tree_util.DictKey) and head.key == "blocks"


def _grow_caches(cfg, caches, cur_len, max_len):
    """Pad prefill KV caches out to the decode budget (key-aware: SSM
    conv/state caches have no sequence axis and are left alone)."""
    def pad_leaf(path, a):
        key = path[-1].key if hasattr(path[-1], "key") else None
        if key not in _SEQ_CACHE_KEYS:
            return a
        # seq axis: 1 for per-layer caches, 2 under the block-stack axis
        axis = 2 if _is_block_leaf(path) else 1
        pad_width = [(0, 0)] * a.ndim
        pad_width[axis] = (0, max_len - cur_len)
        return jnp.pad(a, pad_width)

    return jax.tree_util.tree_map_with_path(pad_leaf, caches)


def merge_slot_caches(big, one, slot):
    """Scatter a single-sequence cache tree into slot ``slot`` of a
    batched cache tree (same max_len; ``one`` has batch 1 where ``big``
    has batch B).  The batch axis is found structurally: axis 0 for
    prefix/suffix leaves, axis 1 under the block-stack leading axis."""
    def put(path, b_leaf, s_leaf):
        b_ax = 1 if _is_block_leaf(path) else 0
        start = [0] * b_leaf.ndim
        start[b_ax] = slot
        return jax.lax.dynamic_update_slice(
            b_leaf, s_leaf.astype(b_leaf.dtype), tuple(start))

    return jax.tree_util.tree_map_with_path(put, big, one)


def merge_slot_paged_caches(big, one, slot, pages):
    """Paged dual of :func:`merge_slot_caches`: copy a prefilled
    single-sequence cache into the shared page pools instead of a slab
    row.  ``one``'s sequence leaves (length ``S``, a multiple of the
    pool page size) are reshaped into ``S / page_size`` whole pages and
    scattered to the page ids in ``pages`` (a ``(max_pages,)`` traced
    vector — entries past the request's live pages point at the trash
    page, so pad-token pages land somewhere harmless and one
    compilation serves every prompt length).  Non-sequence leaves
    (mamba conv/ssm state) scatter at batch slot ``slot`` exactly as in
    the dense path."""
    pages = jnp.asarray(pages, jnp.int32)

    def put(path, b_leaf, s_leaf):
        key = path[-1].key if hasattr(path[-1], "key") else None
        blk = _is_block_leaf(path)
        if key not in _SEQ_CACHE_KEYS:
            b_ax = 1 if blk else 0
            start = [0] * b_leaf.ndim
            start[b_ax] = slot
            return jax.lax.dynamic_update_slice(
                b_leaf, s_leaf.astype(b_leaf.dtype), tuple(start))
        ps = b_leaf.shape[2] if blk else b_leaf.shape[1]
        s = s_leaf.shape[2] if blk else s_leaf.shape[1]
        if s % ps:
            raise ValueError(f"prefill cache length {s} is not a whole "
                             f"number of pages (page_size {ps})")
        n_p = s // ps
        if blk:
            nb = b_leaf.shape[0]
            rows = s_leaf.reshape(nb, n_p, ps, *s_leaf.shape[3:])
            return b_leaf.at[:, pages[:n_p]].set(rows.astype(b_leaf.dtype))
        rows = s_leaf.reshape(n_p, ps, *s_leaf.shape[2:])
        return b_leaf.at[pages[:n_p]].set(rows.astype(b_leaf.dtype))

    return jax.tree_util.tree_map_with_path(put, big, one)


def scatter_prefill_paged_caches(big, one, slot, row, start):
    """Row-granular dual of :func:`merge_slot_paged_caches` for prefix
    caching: write a context-prefilled suffix cache (rows for global
    positions ``start + [0, S)``) through one slot's page-table ``row``
    into the shared pools.  Unlike the whole-page merge, writes are per
    row, so the shared prefix pages *below* ``start`` — and the cached
    rows a copy-on-write tail page carries below ``start`` — are never
    touched.  Non-sequence leaves (none on the archs prefix caching
    admits, but kept for shape parity) scatter at batch slot ``slot``
    exactly as in the merge."""
    from repro.models.attention import scatter_prefill_rows
    row = jnp.asarray(row, jnp.int32)

    def put(path, b_leaf, s_leaf):
        key = path[-1].key if hasattr(path[-1], "key") else None
        blk = _is_block_leaf(path)
        if key not in _SEQ_CACHE_KEYS:
            b_ax = 1 if blk else 0
            start_idx = [0] * b_leaf.ndim
            start_idx[b_ax] = slot
            return jax.lax.dynamic_update_slice(
                b_leaf, s_leaf.astype(b_leaf.dtype), tuple(start_idx))
        if blk:
            return jax.vmap(
                lambda pool, new: scatter_prefill_rows(pool, new, row,
                                                       start)
            )(b_leaf, s_leaf)
        return scatter_prefill_rows(b_leaf, s_leaf, row, start)

    return jax.tree_util.tree_map_with_path(put, big, one)


def copy_paged_cache_page(caches, src, dst):
    """Copy pool page ``src`` onto ``dst`` in every sequence-cache pool
    (the copy-on-write primitive: duplicate a shared tail page into a
    slot's private page before the slot's first write can land on
    shared storage).  ``src``/``dst`` are traced scalars, so the copy
    lives inside the compiled prefill program; the no-COW default is
    ``src == dst == 0`` — rewriting the trash page with itself, a
    bit-exact no-op — which keeps the program count at one."""
    src = jnp.asarray(src, jnp.int32)
    dst = jnp.asarray(dst, jnp.int32)

    def cp(path, leaf):
        key = path[-1].key if hasattr(path[-1], "key") else None
        if key not in _SEQ_CACHE_KEYS:
            return leaf
        if _is_block_leaf(path):
            return leaf.at[:, dst].set(leaf[:, src])
        return leaf.at[dst].set(leaf[src])

    return jax.tree_util.tree_map_with_path(cp, caches)


def extract_cache_pages(caches, pages, pad_to: int | None = None) -> list[dict]:
    """Copy pool pages ``pages`` out of every sequence-cache pool into
    host memory: returns one payload per page, a ``{flat_leaf_index:
    np.ndarray}`` dict covering exactly the sequence leaves (page axis
    removed — a payload entry is ``(page_size, ...)``, or ``(n_blocks,
    page_size, ...)`` under the block stack).  This is the preemption
    swap-out / prefix-demotion primitive: together with
    :func:`insert_cache_pages` it round-trips a page's rows through a
    host cold tier bit-exactly (device→host→device is a copy, never a
    recompute).  Keying payloads by flattened leaf index keeps them
    structure-free; the restoring engine re-derives block-ness from its
    own cache tree, which is by construction the same tree.

    ``pad_to`` fixes the gather width by padding the page-id vector
    with the trash page (id 0): these are eager dispatches, and XLA
    compiles one kernel per shape — a serving engine pads every call
    to one width so the whole swap tier costs exactly one compilation
    (pre-paid at reset), not one per distinct page count mid-run.  The
    padded rows are dropped before returning."""
    pages = list(pages)
    padded = pages + [0] * (max(0, (pad_to or 0) - len(pages)))
    idx = jnp.asarray(np.asarray(padded, np.int32))
    leaves = jax.tree_util.tree_flatten_with_path(caches)[0]
    cols: dict[int, tuple[np.ndarray, bool]] = {}
    for i, (path, leaf) in enumerate(leaves):
        key = path[-1].key if hasattr(path[-1], "key") else None
        if key not in _SEQ_CACHE_KEYS:
            continue
        blk = _is_block_leaf(path)
        gathered = leaf[:, idx] if blk else leaf[idx]
        cols[i] = (np.asarray(jax.device_get(gathered)), blk)
    return [{i: (a[:, j] if blk else a[j]) for i, (a, blk) in cols.items()}
            for j in range(len(pages))]


def insert_cache_pages(caches, pages, payloads, pad_to: int | None = None):
    """Write host page payloads (from :func:`extract_cache_pages`) back
    into pool pages ``pages`` of every sequence-cache leaf — the swap-in
    / prefix-promotion dual.  Runs eagerly outside the compiled stages:
    swaps are rare scheduler events, and page ids are host integers
    here, not traced values.

    ``pad_to`` pins the scatter width like the extract side: padded
    entries write zero rows onto the trash page (id 0), which no query
    ever attends — the same idempotent-write invariant that lets idle
    slots decode into it."""
    pages = list(pages)
    if len(pages) != len(payloads):
        raise ValueError(f"{len(pages)} pages but {len(payloads)} "
                         f"payloads")
    pad = max(0, (pad_to or 0) - len(pages))
    idx = jnp.asarray(np.asarray(pages + [0] * pad, np.int32))
    leaves, treedef = jax.tree_util.tree_flatten_with_path(caches)
    out = []
    for i, (path, leaf) in enumerate(leaves):
        key = path[-1].key if hasattr(path[-1], "key") else None
        if key in _SEQ_CACHE_KEYS and i in payloads[0]:
            blk = _is_block_leaf(path)
            rows = np.stack([p[i] for p in payloads],
                            axis=1 if blk else 0)
            if pad:
                shp = list(rows.shape)
                shp[1 if blk else 0] = pad
                rows = np.concatenate(
                    [rows, np.zeros(shp, rows.dtype)], axis=1 if blk else 0)
            if blk:
                leaf = leaf.at[:, idx].set(rows.astype(leaf.dtype))
            else:
                leaf = leaf.at[idx].set(rows.astype(leaf.dtype))
        out.append(leaf)
    return jax.tree_util.tree_unflatten(treedef, out)


def decode_step(params, cfg: ModelConfig, token, caches, index, *,
                enc_out=None, page_table=None):
    """One decode step.  token: (B, S) int32 (classically S == 1).

    ``index`` is the cache write position of ``token[:, 0]`` — a scalar
    (every sequence at the same position, the lockstep special case) or
    a ``(B,)`` int32 vector of *per-slot* positions (continuous
    batching: each batch slot is an independent sequence).  Positions
    are data, not shape: both forms compile once and serve every
    position assignment.  Attention caches scatter per slot; mamba
    layers carry per-sequence recurrent state and never index by
    position, so their semantics are unchanged.

    With ``S > 1`` the step evaluates ``S`` consecutive tokens per slot
    in one forward — row ``j`` writes cache position ``index + j`` and
    attends everything at or below it (per-position causal masking) —
    which is the speculative-decode *verify* shape: all ``k`` draft
    positions plus the bonus position get their next-token logits in a
    single batched dense dispatch.  S > 1 requires attention-only
    stacks (a mamba mixer would need ``S`` recurrent sub-steps; the
    serve engine rejects spec decode on mamba models up front).

    With ``page_table`` (a ``(B, max_pages)`` int32 table), ``caches``
    are shared page pools: the scatter routes through the table
    (``page = table[slot, pos // page_size]``) and attention gathers
    pages back into position order — page ids are data, not shape, so
    the same compilation serves every allocation pattern.
    """
    x = embed_apply(params["embed"], token,
                    scale_by_sqrt_dim=cfg.emb_scale_by_sqrt_dim)
    b, s = x.shape[0], token.shape[1]
    index = jnp.asarray(index, jnp.int32)
    pos = (jnp.broadcast_to(index.reshape(-1, 1), (b, 1))
           + jnp.arange(s, dtype=jnp.int32)[None, :])
    x, new_caches, _ = _stack_apply(params["stack"], cfg, x, positions=pos,
                                    caches=caches, cache_index=index,
                                    enc_out=enc_out, mode="decode",
                                    page_table=page_table)
    return _logits(params, cfg, x), new_caches
