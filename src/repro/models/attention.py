"""Attention: GQA/MQA, sliding-window, qk-norm, softcap, MLA, cross-attn.

One implementation serves training (full causal), prefill (causal +
cache write-out) and decode (single query vs cache).  Long sequences use
query-chunked evaluation (lax.scan over query blocks) so activation
memory stays O(S·chunk) instead of O(S²) — required for the 32k cells.

Grouped-query attention is computed in grouped form (queries reshaped to
(KV-heads × group)) so K/V are never materialised at full head count —
this matters for the decode roofline, where KV bytes dominate.
"""

from __future__ import annotations

import functools
import jax
import jax.numpy as jnp

from repro.core.linear import linear_apply, linear_init
from repro.models.layers import apply_rope, rms_norm, rms_norm_init, rope

__all__ = ["attn_init", "attn_apply", "mla_init", "mla_apply",
           "init_kv_cache", "init_mla_cache", "scatter_cache_rows",
           "init_paged_kv_cache", "init_paged_mla_cache",
           "scatter_paged_rows", "scatter_prefill_rows", "gather_pages"]

_NEG_INF = -2.0 ** 30

# When True, the query-chunk loop is a Python loop (static unroll) so the
# compiled HLO contains every chunk — used by the dry-run's cost pass,
# because XLA's cost_analysis counts a while body once regardless of trip
# count.  Production lowering keeps lax.scan (flat compile time).
_UNROLL_CHUNKS = False

import contextlib


@contextlib.contextmanager
def unrolled_chunks():
    global _UNROLL_CHUNKS
    prev, _UNROLL_CHUNKS = _UNROLL_CHUNKS, True
    try:
        yield
    finally:
        _UNROLL_CHUNKS = prev


# ---------------------------------------------------------------------------
# Cache row scatter: scalar (whole-batch) or per-slot write positions
# ---------------------------------------------------------------------------

def scatter_cache_rows(buf, new, index):
    """Write ``new`` (B, S_new, ...) into ``buf`` (B, L, ...) at ``index``.

    ``index`` is either a scalar (every sequence writes at the same
    offset — the classic lockstep decode) or a ``(B,)`` int32 vector of
    per-slot offsets (continuous batching: each slot is an independent
    sequence at its own position).  The single-row vector case is a
    vmapped ``dynamic_update_slice`` over the batch axis, so the
    compiled program is shape-identical for every position assignment.

    Multi-row vector writes (S_new > 1 — the speculative-decode verify
    forward scatters ``k+1`` rows per slot at once) use a positional
    scatter with each row's target clipped to the last slab row:
    ``dynamic_update_slice`` would *shift the whole window down* when
    ``index > L - S_new``, corrupting live rows below the write
    position, whereas clipping collapses only the overflowing rows onto
    row ``L - 1`` — a row no query ever attends before its owner
    rewrites it (the idempotent-write invariant; the engine never lets
    a request's *accepted* stream write past the slab).
    """
    new = new.astype(buf.dtype)
    index = jnp.asarray(index)
    if index.ndim == 0:
        start = (0, index) + (0,) * (buf.ndim - 2)
        return jax.lax.dynamic_update_slice(buf, new, start)
    if new.shape[1] == 1:
        def one(b, n, i):
            return jax.lax.dynamic_update_slice(
                b, n, (i,) + (0,) * (b.ndim - 1))

        return jax.vmap(one)(buf, new, index)
    b, s = new.shape[:2]
    pos = jnp.clip(index[:, None].astype(jnp.int32)
                   + jnp.arange(s, dtype=jnp.int32)[None, :],
                   0, buf.shape[1] - 1)
    return buf.at[jnp.arange(b)[:, None], pos].set(new)


# ---------------------------------------------------------------------------
# Paged KV cache: shared page pool + per-slot page-table indirection
# ---------------------------------------------------------------------------
#
# Layout: each layer's cache leaf is a shared ``(num_pages, page_size,
# ...)`` pool; a ``(B, max_pages)`` int32 page table (built by
# ``serve.paging``) maps slot positions to pool pages.  Page ids are
# data, not shape — one compilation serves every allocation pattern, so
# slot refill and page recycling never recompile.

def scatter_paged_rows(pool, new, table, index):
    """Write decode rows per slot through the page table.

    ``pool``: (num_pages, page_size, ...); ``new``: (B, S, ...);
    ``table``: (B, max_pages) int32; ``index``: scalar or (B,) start
    position.  Row ``index[b] + j`` of slot ``b`` lands at pool position
    ``(table[b, pos // page_size], pos % page_size)``.  Distinct live
    slots own distinct pages, so the scatter never collides; idle
    slots' table rows all point at the trash page, where their frozen
    idempotent rewrites are harmless.

    The multi-row case (S > 1 — the speculative-decode verify forward
    writes ``k+1`` rows per slot in one dispatch) clips each row's
    logical position to the table's addressable range, so overflowing
    rows collapse onto logical row ``max_len - 1`` — resolved through
    the row's last table entry to either the trash page (unbooked tail)
    or the slot's final row, which no query attends before its owner's
    final write rewrites it (the idempotent-write invariant).
    """
    ps = pool.shape[1]
    b, s = new.shape[:2]
    index = jnp.broadcast_to(jnp.asarray(index, jnp.int32).reshape(-1), (b,))
    if s == 1:
        page = jnp.take_along_axis(table, (index // ps)[:, None],
                                   axis=1)[:, 0]
        return pool.at[page, index % ps].set(new[:, 0].astype(pool.dtype))
    pos = jnp.clip(index[:, None] + jnp.arange(s, dtype=jnp.int32)[None, :],
                   0, table.shape[1] * ps - 1)
    page = jnp.take_along_axis(table, pos // ps, axis=1)
    return pool.at[page, pos % ps].set(new.astype(pool.dtype))


def gather_pages(pool, table):
    """Reassemble per-slot contiguous caches from the page pool.

    (num_pages, page_size, ...) gathered through (B, max_pages) →
    (B, max_pages · page_size, ...): the XLA reference decode path —
    after the gather the attention math is bit-identical to the dense
    slab (rows beyond a slot's live length hold garbage from the trash
    page or stale pages, exactly where the causal mask already writes
    ``-inf``).
    """
    b, mp = table.shape
    g = jnp.take(pool, table, axis=0)           # (B, MP, page_size, ...)
    return g.reshape(b, mp * pool.shape[1], *pool.shape[2:])


def scatter_prefill_rows(pool, new, row, start):
    """Write a prefilled KV segment through one slot's page-table row.

    ``pool``: (num_pages, page_size, ...); ``new``: (1, S, ...) rows for
    global positions ``start + [0, S)``; ``row``: (max_pages,) int32.
    Row-granular (unlike the whole-page merge), so a copy-on-write tail
    page keeps its cached rows below ``start`` while the fresh suffix
    rows land beside them.  Positions past the slot's budget clamp to
    the last logical row — those writes carry pad-token garbage and land
    on the row's trailing entry (an unbooked slot points it at the trash
    page; a fully booked slot's final row is rewritten by its final
    decode step before any query can attend to it).
    """
    ps = pool.shape[1]
    s = new.shape[1]
    pos = jnp.clip(jnp.asarray(start, jnp.int32) + jnp.arange(s), 0,
                   row.shape[0] * ps - 1)
    page = jnp.take(row, pos // ps)
    return pool.at[page, pos % ps].set(new[0].astype(pool.dtype))


def _splice_context(ctx, new, context_start):
    """Fixed-length prefix splice: position ``j`` takes the *cached* row
    ``ctx[:, j]`` below ``context_start`` and the freshly computed row
    ``new[:, j - context_start]`` at or above it.  The buffer length
    stays exactly ``new``'s, so the attention reduction downstream is
    shape-identical to an uncached full prefill — with ``context_start
    == 0`` the splice returns ``new``'s values bit-for-bit, which is
    what keeps cache-miss prefills bit-identical to a no-cache engine's.
    """
    s = new.shape[1]
    shape = (1, s) + (1,) * (new.ndim - 2)
    is_ctx = (jnp.arange(s) < context_start).reshape(shape)
    shifted = jnp.roll(new, context_start, axis=1)
    return jnp.where(is_ctx, ctx[:, :s].astype(new.dtype), shifted)


# ---------------------------------------------------------------------------
# Masked online-softmax attention core (query-chunked)
# ---------------------------------------------------------------------------

def _attend_block(q, k, v, q_pos, k_pos, *, scale, causal, window, softcap):
    """q: (B,Sq,KVH,G,D); k/v: (B,Sk,KVH,Dk/Dv); returns (B,Sq,KVH,G,Dv).

    QK^T upcasts in the contraction (``preferred_element_type``) — no
    f32 copies of Q/K are materialized (those copies were ~10 GiB each
    on the deepseek-v3 MLA prefix layers)."""
    logits = jnp.einsum("bqhgd,bkhd->bhgqk", q, k,
                        preferred_element_type=jnp.float32) * scale
    if softcap:
        logits = jnp.tanh(logits / softcap) * softcap
    mask = jnp.ones((), jnp.bool_)
    dq = q_pos[:, None, None, :, None]          # (B,1,1,Sq,1)
    dk = k_pos[:, None, None, None, :]          # (B,1,1,1,Sk)
    if causal:
        mask = mask & (dk <= dq)
    if window:
        mask = mask & (dq - dk < window)
    logits = jnp.where(mask, logits, _NEG_INF)
    probs = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhgqk,bkhd->bqhgd", probs.astype(v.dtype), v,
                     preferred_element_type=jnp.float32)
    return out


_PROBS_BUDGET_BYTES = 2 * 2 ** 30  # per-chunk f32 logits budget


def attention_core(q, k, v, q_pos, k_pos, *, scale, causal=True,
                   window=0, softcap=0.0, q_chunk=1024):
    """q: (B,Sq,H,Dk) grouped against k/v: (B,Sk,KVH,·).  f32 math.

    The query-chunk size adapts so one chunk's f32 logits stay under
    ~2 GiB per device: the backward pass re-materializes (B,H,qc,Sk)
    logits + their gradient for the live chunk, and at deepseek scale
    (H=128, S=4096) a 1024-chunk makes that a ~70 GiB transient — the
    dominant training-memory term (EXPERIMENTS.md §Perf iter 7)."""
    b, sq, h, dk = q.shape
    kvh = k.shape[2]
    g = h // kvh
    dv = v.shape[-1]
    sk = k.shape[1]
    per_row = b * h * sk * 4                    # f32 logits bytes per q row
    budget_rows = max(1, _PROBS_BUDGET_BYTES // max(per_row, 1))
    while q_chunk > 128 and q_chunk > budget_rows:
        q_chunk //= 2
    qg = q.reshape(b, sq, kvh, g, dk)

    if _UNROLL_CHUNKS:
        # cost-pass lowering: total attention FLOPs/bytes are invariant
        # to the chunk split (every chunk scores against full K), so use
        # the minimum unroll (2 chunks) to keep compile time flat.
        q_chunk = max(q_chunk, sq // 2)

    if sq <= q_chunk or sq % q_chunk:
        out = _attend_block(qg, k, v, q_pos, k_pos, scale=scale,
                            causal=causal, window=window, softcap=softcap)
        return out.reshape(b, sq, h, dv).astype(v.dtype)

    # query-chunked: scan over Sq blocks, full K/V per block
    nc = sq // q_chunk
    qg_c = qg.reshape(b, nc, q_chunk, kvh, g, dk).transpose(1, 0, 2, 3, 4, 5)
    qp_c = q_pos.reshape(b, nc, q_chunk).transpose(1, 0, 2)

    def body(_, qc):
        q_blk, qp_blk = qc
        o = _attend_block(q_blk, k, v, qp_blk, k_pos, scale=scale,
                          causal=causal, window=window, softcap=softcap)
        return None, o

    if _UNROLL_CHUNKS:
        outs = jnp.stack([body(None, (qg_c[i], qp_c[i]))[1]
                          for i in range(nc)])
    else:
        _, outs = jax.lax.scan(body, None, (qg_c, qp_c))
    out = outs.transpose(1, 0, 2, 3, 4, 5).reshape(b, sq, h, dv)
    return out.astype(v.dtype)


# ---------------------------------------------------------------------------
# Standard (GQA) attention layer
# ---------------------------------------------------------------------------

def attn_init(key, cfg, *, cross: bool = False) -> dict:
    d, h, kvh, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    ks = jax.random.split(key, 4)
    p = {
        "wq": linear_init(ks[0], d, h * hd),
        "wk": linear_init(ks[1], d, kvh * hd),
        "wv": linear_init(ks[2], d, kvh * hd),
        "wo": linear_init(ks[3], h * hd, d),
    }
    if cfg.qk_norm:
        p["q_norm"] = rms_norm_init(hd)
        p["k_norm"] = rms_norm_init(hd)
    return p


def init_kv_cache(cfg, batch: int, max_len: int, dtype=jnp.bfloat16) -> dict:
    shape = (batch, max_len, cfg.n_kv_heads, cfg.head_dim)
    if cfg.kv_cache_dtype == "int8":
        # the paper's low-precision-storage idea on the decode
        # bottleneck: int8 values + per-(token, head) f32 scales halve
        # the KV bytes the decode step streams from HBM.
        return {"k": jnp.zeros(shape, jnp.int8),
                "v": jnp.zeros(shape, jnp.int8),
                "k_scale": jnp.zeros(shape[:3] + (1,), jnp.float32),
                "v_scale": jnp.zeros(shape[:3] + (1,), jnp.float32)}
    return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)}


def init_paged_kv_cache(cfg, num_pages: int, page_size: int,
                        dtype=jnp.bfloat16) -> dict:
    """Paged dual of :func:`init_kv_cache`: K/V live in a shared
    ``(num_pages, page_size, ...)`` pool addressed through a page table
    instead of a dense per-slot ``max_len`` slab."""
    shape = (num_pages, page_size, cfg.n_kv_heads, cfg.head_dim)
    if cfg.kv_cache_dtype == "int8":
        return {"k": jnp.zeros(shape, jnp.int8),
                "v": jnp.zeros(shape, jnp.int8),
                "k_scale": jnp.zeros(shape[:3] + (1,), jnp.float32),
                "v_scale": jnp.zeros(shape[:3] + (1,), jnp.float32)}
    return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)}


def _q8_heads(t):
    """Symmetric int8 per-(token, head): t (B,S,KVH,D) → (q, scale)."""
    amax = jnp.maximum(jnp.max(jnp.abs(t.astype(jnp.float32)), axis=-1,
                               keepdims=True), 1e-8)
    scale = amax / 127.0
    q = jnp.clip(jnp.round(t.astype(jnp.float32) / scale), -128, 127) \
        .astype(jnp.int8)
    return q, scale


def attn_apply(params, cfg, x, *, positions, kind: str = "full",
               cache: dict | None = None, cache_index=None,
               kv_source: jax.Array | None = None, causal: bool = True,
               return_cache: bool = False, page_table=None,
               context_start=None):
    """Returns (out, new_cache).  Modes:

    * train/prefill: ``cache=None`` → K/V from ``x`` (or ``kv_source``
      for cross-attn); prefill callers build the cache via ``positions``.
    * decode: ``cache`` given, ``cache_index`` = write offset (scalar,
      or a ``(B,)`` vector of per-slot offsets for continuous batching);
      the new token's K/V are scattered in and attention runs against
      the cache with per-slot causal masking (``positions`` carries each
      slot's query position).
    * paged decode: additionally ``page_table`` (B, max_pages) int32 —
      ``cache`` leaves are shared (num_pages, page_size, ...) pools; the
      scatter routes through the table and attention either gathers
      pages back into position order (XLA reference path, bit-identical
      to the dense slab) or, under ``attn_impl="flash"``, runs the
      Pallas paged-decode kernel that walks the table directly.
    * context prefill (prefix caching): ``cache`` + ``page_table`` +
      ``context_start`` — ``x`` holds a prompt *suffix* whose queries
      sit at global positions ``context_start + [0, S)``; the cached
      prefix rows are gathered from the pools through the table and
      spliced below the fresh K/V at the same fixed buffer length, so
      the attention math (and, with ``context_start == 0``, every bit
      of it) matches an uncached full-prompt prefill.  The computed
      suffix K/V is returned as ``new_cache`` for the caller to scatter
      into its own pages — the shared prefix pages are never written.
    """
    b, s, d = x.shape
    h, kvh, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    quant = cfg.quant_mode
    qbackend = cfg.quant_backend

    q = linear_apply(params["wq"], x, mode=quant, backend=qbackend).reshape(b, s, h, hd)
    kv_in = x if kv_source is None else kv_source
    sk_new = kv_in.shape[1]
    k = linear_apply(params["wk"], kv_in, mode=quant, backend=qbackend).reshape(b, sk_new, kvh, hd)
    v = linear_apply(params["wv"], kv_in, mode=quant, backend=qbackend).reshape(b, sk_new, kvh, hd)

    if cfg.qk_norm:
        q = rms_norm(params["q_norm"], q, cfg.norm_eps)
        k = rms_norm(params["k_norm"], k, cfg.norm_eps)

    from repro.distributed.sharding import maybe_shard
    q = maybe_shard(q, "heads", kv_heads=kvh)
    k = maybe_shard(k, "heads", kv_heads=kvh)
    v = maybe_shard(v, "heads", kv_heads=kvh)

    use_rope = kv_source is None  # no RoPE on cross-attention
    if use_rope:
        theta = cfg.rope_theta_local if kind == "local" else cfg.rope_theta
        sin_q, cos_q = rope(positions, hd, theta)
        q = apply_rope(q, sin_q, cos_q).astype(x.dtype)
        k_pos_new = positions[:, -sk_new:] if cache is None else positions
        sin_k, cos_k = rope(k_pos_new, hd, theta)
        k = apply_rope(k, sin_k, cos_k).astype(x.dtype)

    scale = cfg.attn_scale or (1.0 / hd ** 0.5)
    window = cfg.sliding_window if kind == "local" else 0
    is_causal_self = causal and kv_source is None

    new_cache = cache
    paged_kernel = False
    if cache is not None and context_start is not None:
        # prefix-cache suffix prefill: splice the gathered cached prefix
        # below the fresh suffix K/V at the fixed buffer length (see
        # _splice_context — bit-identical to a full prefill on a miss).
        # k_pos is the buffer index: cached row j sits at position j,
        # fresh row i at context_start + i, exactly where the splice put
        # them, so plain causal masking covers both.
        if "k_scale" in cache:
            raise NotImplementedError(
                "prefix caching over the int8 KV cache is unsupported: "
                "cached rows would be dequantized while a solo prefill "
                "attends full-precision rows, breaking the bit-match "
                "contract")
        k_full = _splice_context(gather_pages(cache["k"], page_table), k,
                                 context_start)
        v_full = _splice_context(gather_pages(cache["v"], page_table), v,
                                 context_start)
        k_pos = jnp.broadcast_to(jnp.arange(s)[None, :], (b, s))
        if return_cache:  # suffix rows only; the caller scatters them
            new_cache = {"k": k.astype(jnp.bfloat16),
                         "v": v.astype(jnp.bfloat16)}
    elif cache is not None and page_table is not None:
        # paged decode: scatter through the page table into the shared
        # pool, then either gather pages back into position order (XLA
        # reference — bit-identical to the dense slab) or let the Pallas
        # flash-decode kernel walk the table (fast path, no gather copy)
        quant_kv = "k_scale" in cache
        if quant_kv:
            kq, ks = _q8_heads(k)
            vq, vs = _q8_heads(v)
            writes = {"k": kq, "v": vq, "k_scale": ks, "v_scale": vs}
        else:
            writes = {"k": k, "v": v}
        new_cache = {key: scatter_paged_rows(cache[key], val, page_table,
                                             cache_index)
                     for key, val in writes.items()}
        paged_kernel = (cfg.attn_impl == "flash" and not quant_kv
                        and is_causal_self and s == 1
                        and not cfg.attn_core_bypass)
        if paged_kernel:
            k_full = v_full = None
        elif quant_kv:
            k_full = (gather_pages(new_cache["k"], page_table)
                      .astype(jnp.float32)
                      * gather_pages(new_cache["k_scale"], page_table)) \
                .astype(x.dtype)
            v_full = (gather_pages(new_cache["v"], page_table)
                      .astype(jnp.float32)
                      * gather_pages(new_cache["v_scale"], page_table)) \
                .astype(x.dtype)
        else:
            k_full = gather_pages(new_cache["k"], page_table)
            v_full = gather_pages(new_cache["v"], page_table)
        sk_total = page_table.shape[1] * cache["k"].shape[1]
        k_pos = jnp.broadcast_to(jnp.arange(sk_total)[None, :],
                                 (b, sk_total))
    elif cache is not None:
        # decode: scatter the new K/V at cache_index (scalar or per-slot
        # vector), attend to the cache
        quant_kv = "k_scale" in cache
        if quant_kv:
            kq, ks = _q8_heads(k)
            vq, vs = _q8_heads(v)
            new_cache = {
                "k": scatter_cache_rows(cache["k"], kq, cache_index),
                "v": scatter_cache_rows(cache["v"], vq, cache_index),
                "k_scale": scatter_cache_rows(cache["k_scale"], ks,
                                              cache_index),
                "v_scale": scatter_cache_rows(cache["v_scale"], vs,
                                              cache_index),
            }
            k_full = (new_cache["k"].astype(jnp.float32)
                      * new_cache["k_scale"]).astype(x.dtype)
            v_full = (new_cache["v"].astype(jnp.float32)
                      * new_cache["v_scale"]).astype(x.dtype)
            k_cache = new_cache["k"]
        else:
            k_cache = scatter_cache_rows(cache["k"], k, cache_index)
            v_cache = scatter_cache_rows(cache["v"], v, cache_index)
            new_cache = {"k": k_cache, "v": v_cache}
            k_full, v_full = k_cache, v_cache
        k_pos = jnp.broadcast_to(jnp.arange(k_cache.shape[1])[None, :],
                                 (b, k_cache.shape[1]))
    else:
        k_full, v_full = k, v
        k_pos = positions if kv_source is None else jnp.broadcast_to(
            jnp.arange(sk_new)[None, :], (b, sk_new))
        if return_cache:  # prefill: hand the (post-RoPE) K/V to decode
            if cfg.kv_cache_dtype == "int8":
                kq, ks = _q8_heads(k)
                vq, vs = _q8_heads(v)
                new_cache = {"k": kq, "v": vq,
                             "k_scale": ks, "v_scale": vs}
            else:
                new_cache = {"k": k.astype(jnp.bfloat16),
                             "v": v.astype(jnp.bfloat16)}

    if cfg.attn_core_bypass:
        out = jnp.zeros((b, s, h, hd), x.dtype)
    elif paged_kernel:
        from repro.kernels.ops import paged_flash_decode
        out = paged_flash_decode(q, new_cache["k"], new_cache["v"],
                                 page_table, positions[:, -1], scale=scale,
                                 window=window,
                                 softcap=cfg.attn_logit_softcap)
    elif cfg.attn_impl == "flash" and cache is None and is_causal_self:
        out = _flash_self_attention(q, k, v, scale=scale, window=window,
                                    softcap=cfg.attn_logit_softcap)
    else:
        out = attention_core(q, k_full, v_full, positions, k_pos,
                             scale=scale, causal=is_causal_self,
                             window=window,
                             softcap=cfg.attn_logit_softcap)
    out = linear_apply(params["wo"], out.reshape(b, s, h * hd), mode=quant, backend=qbackend)
    return out, new_cache


def _flash_local(q, k, v, *, scale, window, softcap):
    """Device-local flash call: head-major flatten → kernel → restore.

    Heads are ordered (kv_head, group) on the flat axis so the kernel's
    BlockSpec pulls K/V block ``bh // group`` (no materialized repeat)."""
    from repro.kernels.ops import flash_mha
    b, s, h, d = q.shape
    kvh = k.shape[2]
    g = h // kvh
    dv = v.shape[-1]
    qf = q.reshape(b, s, kvh, g, d).transpose(0, 2, 3, 1, 4) \
        .reshape(b * kvh * g, s, d)
    kf = k.transpose(0, 2, 1, 3).reshape(b * kvh, s, d)
    vf = v.transpose(0, 2, 1, 3).reshape(b * kvh, s, dv)
    of = flash_mha(qf, kf, vf, scale, True, window, softcap, g, None)
    return of.reshape(b, kvh, g, s, dv).transpose(0, 3, 1, 2, 4) \
        .reshape(b, s, h, dv)


def _flash_self_attention(q, k, v, *, scale, window=0, softcap=0.0):
    """Flash attention, sharded: under a mesh the call runs inside
    shard_map (batch on DP, heads on TP when the KV count divides — the
    same layout the "heads" constraint pins), so the head-major
    flatten/transpose is device-local.  Done naively under GSPMD, those
    reshapes of doubly-sharded axes trigger full q/k/v relayouts —
    measured 114 TB/device of collectives on deepseek-v3 train."""
    from repro.distributed import sharding as shr

    mesh = shr._AMBIENT_MESH
    if mesh is None or shr.TP_AXIS not in mesh.axis_names:
        return _flash_local(q, k, v, scale=scale, window=window,
                            softcap=softcap)

    from jax.sharding import PartitionSpec as P
    try:
        from jax import shard_map
    except ImportError:  # pragma: no cover
        from jax.experimental.shard_map import shard_map

    tp = mesh.shape[shr.TP_AXIS]
    dp = tuple(a for a in mesh.axis_names if a != shr.TP_AXIS)
    dp_size = 1
    for a in dp:
        dp_size *= mesh.shape[a]
    b, _, h, _ = q.shape
    kvh = k.shape[2]
    b_ax = dp if b % dp_size == 0 else None
    h_ax = shr.TP_AXIS if kvh % tp == 0 else None
    spec = P(b_ax, None, h_ax, None)

    fn = functools.partial(_flash_local, scale=scale, window=window,
                           softcap=softcap)
    return shard_map(fn, mesh=mesh, in_specs=(spec, spec, spec),
                     out_specs=spec, check_vma=False)(q, k, v)


# ---------------------------------------------------------------------------
# Multi-head Latent Attention (deepseek-v3)
# ---------------------------------------------------------------------------

def mla_init(key, cfg) -> dict:
    d, h = cfg.d_model, cfg.n_heads
    r_q, r_kv = cfg.q_lora_rank, cfg.kv_lora_rank
    d_nope, d_rope, d_v = cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim
    ks = jax.random.split(key, 6)
    return {
        "wq_a": linear_init(ks[0], d, r_q),
        "q_a_norm": rms_norm_init(r_q),
        "wq_b": linear_init(ks[1], r_q, h * (d_nope + d_rope)),
        "wkv_a": linear_init(ks[2], d, r_kv + d_rope),
        "kv_a_norm": rms_norm_init(r_kv),
        "wkv_b": linear_init(ks[3], r_kv, h * (d_nope + d_v)),
        "wo": linear_init(ks[4], h * d_v, d),
    }


def init_mla_cache(cfg, batch: int, max_len: int, dtype=jnp.bfloat16) -> dict:
    """MLA caches the *compressed* latent + shared rope key: per-token
    bytes = kv_lora_rank + qk_rope_dim — the paper-adjacent footprint win."""
    return {
        "c_kv": jnp.zeros((batch, max_len, cfg.kv_lora_rank), dtype),
        "k_rope": jnp.zeros((batch, max_len, cfg.qk_rope_dim), dtype),
    }


def init_paged_mla_cache(cfg, num_pages: int, page_size: int,
                         dtype=jnp.bfloat16) -> dict:
    """Paged dual of :func:`init_mla_cache`: compressed latents + shared
    rope key in page pools."""
    return {
        "c_kv": jnp.zeros((num_pages, page_size, cfg.kv_lora_rank), dtype),
        "k_rope": jnp.zeros((num_pages, page_size, cfg.qk_rope_dim), dtype),
    }


def mla_apply(params, cfg, x, *, positions, cache=None, cache_index=None,
              return_cache: bool = False, page_table=None,
              context_start=None):
    b, s, d = x.shape
    h = cfg.n_heads
    d_nope, d_rope, d_v = cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim
    quant = cfg.quant_mode
    qbackend = cfg.quant_backend

    # --- queries (low-rank) ------------------------------------------------
    q_a = rms_norm(params["q_a_norm"],
                   linear_apply(params["wq_a"], x, mode=quant, backend=qbackend), cfg.norm_eps)
    q = linear_apply(params["wq_b"], q_a, mode=quant, backend=qbackend) \
        .reshape(b, s, h, d_nope + d_rope)
    q_nope, q_rope = q[..., :d_nope], q[..., d_nope:]
    sin, cos = rope(positions, d_rope, cfg.rope_theta)
    q_rope = apply_rope(q_rope, sin, cos).astype(x.dtype)

    # --- compressed KV -------------------------------------------------------
    kv_a = linear_apply(params["wkv_a"], x, mode=quant, backend=qbackend)
    c_kv = rms_norm(params["kv_a_norm"], kv_a[..., :cfg.kv_lora_rank],
                    cfg.norm_eps)
    k_rope_new = kv_a[..., cfg.kv_lora_rank:].reshape(b, s, 1, d_rope)
    k_pos_new = positions
    sin_k, cos_k = rope(k_pos_new, d_rope, cfg.rope_theta)
    k_rope_new = apply_rope(k_rope_new, sin_k, cos_k).astype(x.dtype) \
        .reshape(b, s, d_rope)

    new_cache = cache
    if cache is not None and context_start is not None:
        # prefix-cache suffix prefill: splice cached latents + rope keys
        # below the fresh rows at the fixed buffer length, then let the
        # shared decompression matmul expand the spliced latents exactly
        # as a full prefill would (cached latents are the bf16 rows a
        # solo prefill computes, so the splice is bit-transparent)
        c_kv_f = _splice_context(gather_pages(cache["c_kv"], page_table),
                                 c_kv, context_start)
        k_rope_f = _splice_context(gather_pages(cache["k_rope"],
                                                page_table),
                                   k_rope_new, context_start)
        sk = s
        k_pos = jnp.broadcast_to(jnp.arange(s)[None, :], (b, s))
        if return_cache:  # suffix rows only; the caller scatters them
            new_cache = {"c_kv": c_kv.astype(jnp.bfloat16),
                         "k_rope": k_rope_new.astype(jnp.bfloat16)}
    elif cache is not None and page_table is not None:
        # paged decode: scatter the latent row through the page table,
        # gather pages back for the shared decompression matmul (the
        # latent is re-expanded per step anyway, so the XLA gather is
        # the natural reference path for MLA)
        new_cache = {
            "c_kv": scatter_paged_rows(cache["c_kv"], c_kv, page_table,
                                       cache_index),
            "k_rope": scatter_paged_rows(cache["k_rope"], k_rope_new,
                                         page_table, cache_index),
        }
        c_kv_f = gather_pages(new_cache["c_kv"], page_table)
        k_rope_f = gather_pages(new_cache["k_rope"], page_table)
        sk = c_kv_f.shape[1]
        k_pos = jnp.broadcast_to(jnp.arange(sk)[None, :], (b, sk))
    elif cache is not None:
        c_kv_f = scatter_cache_rows(cache["c_kv"], c_kv, cache_index)
        k_rope_f = scatter_cache_rows(cache["k_rope"], k_rope_new,
                                      cache_index)
        new_cache = {"c_kv": c_kv_f, "k_rope": k_rope_f}
        sk = c_kv_f.shape[1]
        k_pos = jnp.broadcast_to(jnp.arange(sk)[None, :], (b, sk))
    else:
        c_kv_f, k_rope_f = c_kv, k_rope_new
        k_pos = positions
        sk = s
        if return_cache:  # prefill: cache the compressed latents
            new_cache = {"c_kv": c_kv.astype(jnp.bfloat16),
                         "k_rope": k_rope_new.astype(jnp.bfloat16)}

    # --- decompress K/V (from latent) ---------------------------------------
    kv = linear_apply(params["wkv_b"], c_kv_f, mode=quant, backend=qbackend) \
        .reshape(b, sk, h, d_nope + d_v)
    k_nope, v = kv[..., :d_nope], kv[..., d_nope:]
    k_rope_b = jnp.broadcast_to(k_rope_f[:, :, None, :], (b, sk, h, d_rope))

    q_full = jnp.concatenate([q_nope.astype(jnp.float32),
                              q_rope.astype(jnp.float32)], axis=-1)
    k_full = jnp.concatenate([k_nope.astype(jnp.float32),
                              k_rope_b.astype(jnp.float32)], axis=-1)

    from repro.distributed.sharding import maybe_shard
    q_full = maybe_shard(q_full, "heads")
    k_full = maybe_shard(k_full, "heads")
    v = maybe_shard(v, "heads")

    scale = 1.0 / (d_nope + d_rope) ** 0.5
    if cfg.attn_core_bypass:
        out = jnp.zeros((b, s, h, d_v), x.dtype)
    elif cfg.attn_impl == "flash" and cache is None:
        out = _flash_self_attention(q_full.astype(x.dtype),
                                    k_full.astype(x.dtype), v, scale=scale)
    else:
        out = attention_core(q_full.astype(x.dtype), k_full.astype(x.dtype),
                             v, positions, k_pos, scale=scale, causal=True)
    out = linear_apply(params["wo"], out.reshape(b, s, h * d_v), mode=quant, backend=qbackend)
    return out, new_cache
