"""Analytical parameter counts (total and active) for roofline math.

MODEL_FLOPS per trained token = 6·N (dense) / 6·N_active (MoE), per the
roofline brief; these counters walk the same layer specs the builders
use, so they stay consistent with the actual parameter pytrees (verified
against real init in the smoke tests for the reduced configs).
"""

from __future__ import annotations

from repro.configs.base import LayerSpec, ModelConfig

__all__ = ["count_params_analytical"]


def _attn_params(cfg: ModelConfig, spec: LayerSpec) -> int:
    d, h, kvh, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    if spec.attn_kind == "mla":
        r_q, r_kv = cfg.q_lora_rank, cfg.kv_lora_rank
        d_qk = cfg.qk_nope_dim + cfg.qk_rope_dim
        n = d * r_q + r_q * h * d_qk                      # q down/up
        n += d * (r_kv + cfg.qk_rope_dim)                 # kv down
        n += r_kv * h * (cfg.qk_nope_dim + cfg.v_head_dim)  # kv up
        n += h * cfg.v_head_dim * d                       # o
        return n
    return d * h * hd + 2 * d * kvh * hd + h * hd * d


def _mlp_params(d: int, f: int) -> int:
    return 3 * d * f


def _moe_params(cfg: ModelConfig, active: bool) -> int:
    d, f = cfg.d_model, cfg.d_ff_expert
    e_used = cfg.top_k if active else cfg.n_experts
    n = e_used * _mlp_params(d, f)
    n += cfg.n_shared_experts * _mlp_params(d, f)
    if not active:
        n += d * cfg.n_experts                            # router
    return n


def _mamba_params(cfg: ModelConfig) -> int:
    d = cfg.d_model
    d_in = cfg.d_inner
    g, n, h = cfg.ssm_groups, cfg.ssm_state, cfg.ssm_heads
    conv_ch = d_in + 2 * g * n
    total = d * (2 * d_in + 2 * g * n + h)                # in_proj
    total += cfg.conv_width * conv_ch + conv_ch           # conv
    total += 3 * h + d_in                                 # A, D, dt_bias, norm
    total += d_in * d                                     # out_proj
    return total


def count_params_analytical(cfg: ModelConfig, active_only: bool = False) -> int:
    total = cfg.vocab_size * cfg.d_model                  # embedding
    if not cfg.tie_embeddings:
        total += cfg.d_model * cfg.vocab_size

    def layer(spec: LayerSpec) -> int:
        n = 0
        if spec.mixer == "attn":
            n += _attn_params(cfg, spec)
        elif spec.mixer == "mamba":
            n += _mamba_params(cfg)
        if spec.ffn == "moe":
            n += _moe_params(cfg, active_only)
        elif spec.ffn == "mlp":
            n += _mlp_params(cfg.d_model, cfg.d_ff)
        if cfg.is_encdec:                                  # cross attention
            n += _attn_params(cfg, LayerSpec())
        return n

    total += sum(layer(s) for s in cfg.layer_specs)

    if cfg.is_encdec:  # encoder stack (self-attn + mlp, no cross)
        enc_layer = _attn_params(cfg, LayerSpec()) \
            + _mlp_params(cfg.d_model, cfg.d_ff)
        total += cfg.n_enc_layers * enc_layer
    return total
