"""Model zoo: shared layers + heterogeneous-stack assembly."""

from repro.models.transformer import (  # noqa: F401
    copy_paged_cache_page,
    decode_step,
    encode,
    extract_cache_pages,
    forward,
    init_caches,
    init_paged_caches,
    insert_cache_pages,
    merge_slot_caches,
    merge_slot_paged_caches,
    model_init,
    prefill,
    scatter_prefill_paged_caches,
)
