from repro.roofline.analysis import RooflineTerms, analyze_cell, analyze_file, format_table  # noqa: F401
