"""Roofline analysis over the dry-run artifacts.

Three terms per (arch × shape × mesh) cell, all in seconds:

    compute    = HLO_FLOPs / (chips × peak_FLOP/s)
    memory     = HLO_bytes / (chips × HBM_bw)
    collective = collective_bytes / (chips × link_bw)

Notes on provenance: ``cost_analysis()`` on the compiled SPMD module is
*per-device program*; the collective bytes from the HLO text are
likewise per-device.  So the "chips ×" division is already done by
SPMD partitioning — we divide by 1 and document the convention.  (The
formulas in the brief assume whole-model numbers; per-device numbers /
per-device rates give the identical seconds.)

MODEL_FLOPS = 6·N·D (dense) or 6·N_active·D (MoE) per processed token —
the useful-work yardstick; HLO_FLOPs / chips vs MODEL_FLOPS / chips
exposes remat/redundancy waste.
"""

from __future__ import annotations

import dataclasses
import json

from repro.launch.mesh import HW

__all__ = ["RooflineTerms", "analyze_cell", "analyze_file",
           "format_table", "stage_roofline"]


@dataclasses.dataclass
class RooflineTerms:
    arch: str
    shape: str
    mesh: str
    compute_s: float
    memory_s: float
    collective_s: float
    dominant: str
    model_flops_per_device: float
    hlo_flops_per_device: float
    useful_ratio: float          # MODEL_FLOPS / HLO_FLOPs (≤ ~1 is good)
    roofline_fraction: float     # dominant-bound utilization estimate
    note: str = ""

    @property
    def total_s(self) -> float:
        # optimistic perfectly-overlapped lower bound = max of terms
        return max(self.compute_s, self.memory_s, self.collective_s)


def _chips(mesh_tag: str) -> int:
    return 512 if "2pod" in mesh_tag else 256


def _tokens(shape: str) -> int:
    return {
        "train_4k": 256 * 4096,
        "prefill_32k": 32 * 32_768,
        "decode_32k": 128 * 1,          # one new token per sequence
        "long_500k": 1 * 1,
    }[shape]


def _model_flops(record: dict) -> float:
    """6·N(_active)·tokens; backward ≈ 2× forward → train gets 3× 2·N·D."""
    n = record["params_active"]
    toks = _tokens(record["shape"])
    if record["shape"] == "train_4k":
        return 6.0 * n * toks
    return 2.0 * n * toks                # inference: forward only


def analyze_cell(record: dict) -> RooflineTerms | None:
    if record.get("status") != "ok":
        return None
    chips = _chips(record["mesh"])
    # scan-corrected numbers (see dryrun.scan_extrapolated_cost); raw
    # cost_analysis excludes while bodies entirely.
    flops_dev = record.get("flops_extrapolated", record["flops"])
    bytes_dev = record.get("bytes_extrapolated", record["bytes_accessed"])
    coll = record.get("collective_bytes_extrapolated",
                      record.get("collective_bytes", {}))
    coll_dev = sum(v for k, v in coll.items() if k != "n_ops")

    compute_s = flops_dev / HW.PEAK_BF16_FLOPS
    memory_s = bytes_dev / HW.HBM_BW
    collective_s = coll_dev / HW.ICI_BW

    terms = {"compute": compute_s, "memory": memory_s,
             "collective": collective_s}
    dominant = max(terms, key=terms.get)

    model_flops = _model_flops(record) / chips
    useful = model_flops / max(flops_dev, 1.0)
    # roofline fraction: useful compute time over the bound step time
    frac = (model_flops / HW.PEAK_BF16_FLOPS) / max(max(terms.values()),
                                                    1e-12)
    return RooflineTerms(
        arch=record["arch"], shape=record["shape"], mesh=record["mesh"],
        compute_s=compute_s, memory_s=memory_s, collective_s=collective_s,
        dominant=dominant, model_flops_per_device=model_flops,
        hlo_flops_per_device=flops_dev, useful_ratio=useful,
        roofline_fraction=frac)


def stage_roofline(stage_cost: dict) -> dict:
    """Roofline terms for one ``staticcheck`` stage-cost row — the
    static front-end: flops/bytes come from the lowered jaxpr walk
    (``repro.staticcheck.flops``) instead of a dry-run artifact, so a
    serving stage gets its compute/memory bound *before* it ever runs.
    Single-device serving dispatches have no collective term; the
    fully-multiplied flop total and the top-level aval bytes give the
    per-dispatch step-time floor."""
    flops = float(stage_cost["total_flops"])
    io_bytes = float(stage_cost["io_bytes"])
    compute_s = flops / HW.PEAK_BF16_FLOPS
    memory_s = io_bytes / HW.HBM_BW
    return {
        "compute_s": compute_s,
        "memory_s": memory_s,
        "step_s": max(compute_s, memory_s),   # overlapped lower bound
        "dominant": "compute" if compute_s >= memory_s else "memory",
        "arithmetic_intensity": flops / max(io_bytes, 1.0),
        "ridge_intensity": HW.PEAK_BF16_FLOPS / HW.HBM_BW,
    }


def analyze_file(path: str) -> list[RooflineTerms]:
    with open(path) as f:
        records = json.load(f)
    out = []
    for r in records:
        t = analyze_cell(r)
        if t:
            out.append(t)
    return out


def format_table(terms: list[RooflineTerms]) -> str:
    hdr = (f"{'arch':28s} {'shape':12s} {'mesh':12s} "
           f"{'compute':>10s} {'memory':>10s} {'collect':>10s} "
           f"{'dominant':>10s} {'useful':>7s} {'roofl%':>7s}")
    lines = [hdr, "-" * len(hdr)]
    for t in sorted(terms, key=lambda t: (t.mesh, t.arch, t.shape)):
        lines.append(
            f"{t.arch:28s} {t.shape:12s} {t.mesh:12s} "
            f"{t.compute_s:10.4f} {t.memory_s:10.4f} "
            f"{t.collective_s:10.4f} {t.dominant:>10s} "
            f"{t.useful_ratio:7.3f} {100 * t.roofline_fraction:6.1f}%")
    return "\n".join(lines)
