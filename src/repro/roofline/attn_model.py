"""Analytic per-device FLOPs/bytes for flash attention cells.

interpret-mode Pallas lowers its grid as a loop whose body XLA's
cost_analysis does not multiply out (same exclusion as lax.scan — see
dryrun.scan_extrapolated_cost), so a flash-attention lowering simply
*hides* the attention work from the measured numbers.  The optimized
roofline therefore uses:

    flops  = measured(flash lowering) + analytic_flash_flops
    bytes  = measured(flash lowering) + analytic_flash_io_bytes

The analytic terms are the standard flash-2 accounting — probs never
touch HBM; per pass the kernel reads Q, K, V (and in backward O, dO) and
writes O (dQ, dK, dV), K/V read once per query-block row is a VMEM
concern, not HBM (grid streams each K/V block once per q-block: we
charge the conservative nq-fold K/V re-read, matching the kernel's
actual BlockSpec schedule).
"""

from __future__ import annotations

from repro.configs.base import ModelConfig
from repro.launch.shapes import ShapeCase

__all__ = ["flash_attn_cost"]

_BQ = 128  # kernel block size (kernels/flash_attention.py defaults)


def _per_layer(cfg: ModelConfig, b_loc: int, s: int, h_loc: int,
               kvh_loc: int, d_qk: int, d_v: int, train: bool):
    """(flops, hbm_bytes) for one attention layer on one device."""
    # FLOPs: QK^T + PV per forward = 2·2·B·H·S²·d (causal halves it)
    fwd = 2 * b_loc * h_loc * s * s * (d_qk + d_v)          # 2·(S²d) × 2 mat
    fwd = fwd // 2                                          # causal
    # backward ≈ 2.5× forward (dq, dk, dv, p-recompute ×2 passes)
    flops = fwd * (1 + 1 + 2.5) if train else fwd           # +remat fwd
    nq = max(1, s // _BQ)
    q_bytes = b_loc * s * h_loc * d_qk * 2
    kv_bytes = b_loc * s * kvh_loc * (d_qk + d_v) * 2
    o_bytes = b_loc * s * h_loc * d_v * 2
    lse = b_loc * s * h_loc * 4
    # fwd: read Q once, stream K/V once per q-row of the grid, write O.
    pass_io = q_bytes + nq * kv_bytes + o_bytes + lse
    if train:
        # primal fwd + remat fwd + bwd (reads Q,K,V,O,dO; writes dQ,dK,dV)
        io = 2 * pass_io + (q_bytes + nq * kv_bytes + 2 * o_bytes
                            + q_bytes + kv_bytes + lse)
    else:
        io = pass_io
    return flops, io


def flash_attn_cost(cfg: ModelConfig, case: ShapeCase, *,
                    dp: int = 16, tp: int = 16) -> tuple[float, float]:
    """(flops, bytes) per device for the whole model's attention under
    the flash kernels, matching the sharding rules (heads on TP when the
    KV head count divides, else replicated)."""
    train = case.kind == "train"
    s = case.seq
    b_loc = max(1, case.batch // dp)
    total_f, total_b = 0.0, 0.0
    for spec in cfg.layer_specs:
        if spec.mixer != "attn":
            continue
        if spec.attn_kind == "mla":
            h, kvh = cfg.n_heads, cfg.n_heads
            d_qk = cfg.qk_nope_dim + cfg.qk_rope_dim
            d_v = cfg.v_head_dim
        else:
            h, kvh = cfg.n_heads, cfg.n_kv_heads
            d_qk = d_v = cfg.head_dim
        if kvh % tp == 0:
            h_loc, kvh_loc = h // tp, kvh // tp
        else:                                   # replicated heads
            h_loc, kvh_loc = h, kvh
        f, by = _per_layer(cfg, b_loc, s, h_loc, kvh_loc, d_qk, d_v, train)
        total_f += f
        total_b += by
    if cfg.is_encdec:   # encoder self-attn + decoder cross-attn (stub sizes)
        pass            # whisper is not a hillclimb cell; omitted
    return total_f, total_b
