"""Data pipeline: deterministic synthetic token streams, host-sharded.

Production shape: each host produces only its shard of the global batch
(``host_batch_slice``), the stream is deterministic in (seed, step) so a
restarted host reproduces exactly the batches it owes — which is what
makes checkpoint-restart exact (no data-order drift after failover).

The synthetic distribution is a Zipf-like ramp over the vocab with a
Markov backbone so the LM loss actually decreases during the example
training runs (unlike uniform noise).
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

__all__ = ["DataConfig", "SyntheticLM", "host_batch_slice"]


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 1234


def host_batch_slice(global_batch: int, host_id: int, n_hosts: int) \
        -> tuple[int, int]:
    """[start, size) of this host's slice of the global batch."""
    assert global_batch % n_hosts == 0, (global_batch, n_hosts)
    per = global_batch // n_hosts
    return host_id * per, per


class SyntheticLM:
    """Deterministic synthetic LM batches: batch(step) is a pure function.

    ``tokens[t+1] = (a * tokens[t] + noise) % vocab`` with step-seeded
    noise — learnable short-range structure, zero I/O.
    """

    def __init__(self, cfg: DataConfig, host_id: int = 0, n_hosts: int = 1):
        self.cfg = cfg
        self.start, self.local_batch = host_batch_slice(
            cfg.global_batch, host_id, n_hosts)

    def batch(self, step: int) -> dict:
        cfg = self.cfg
        rng = np.random.default_rng(
            np.uint64(cfg.seed) + np.uint64(step) * np.uint64(1_000_003)
            + np.uint64(self.start))
        b, s, v = self.local_batch, cfg.seq_len, cfg.vocab_size
        first = rng.integers(0, v, (b, 1))
        noise = rng.integers(0, 17, (b, s - 1))
        toks = [first]
        for t in range(s - 1):
            toks.append((toks[-1] * 31 + noise[:, t:t + 1] + 7) % v)
        tokens = np.concatenate(toks, axis=1).astype(np.int32)
        return {
            "tokens": jnp.asarray(tokens),
            "labels": jnp.asarray(np.concatenate(
                [tokens[:, 1:], np.full((b, 1), -1, np.int32)], axis=1)),
        }

    def __iter__(self):
        step = 0
        while True:
            yield self.batch(step)
            step += 1
