"""AdamW in pure JAX, with optional int8-quantized moments.

The quantized-moment mode is the paper's low-precision idea applied to
optimizer state: both Adam moments are stored as int8 with per-tensor
scales (block-wise abs-max, error kept implicitly by re-quantising after
each update).  At 671B parameters this is the difference between
optimizer state fitting the 512-chip mesh or not:
fp32 moments = 8 bytes/param → int8 moments = 2 bytes/param (+ scales).
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

__all__ = ["AdamWConfig", "adamw_init", "adamw_update"]


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    quantize_moments: bool = False   # int8 moment storage (ZeRO-friendly)


def _q8(x):
    scale = jnp.maximum(jnp.max(jnp.abs(x)), 1e-12) / 127.0
    return jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8), \
        scale.astype(jnp.float32)


def _dq8(q, scale):
    return q.astype(jnp.float32) * scale


def adamw_init(params: Any, cfg: AdamWConfig) -> dict:
    def zeros_like_moment(p):
        if cfg.quantize_moments:
            return {"q": jnp.zeros(p.shape, jnp.int8),
                    "scale": jnp.zeros((), jnp.float32)}
        return jnp.zeros(p.shape, jnp.float32)

    return {
        "step": jnp.zeros((), jnp.int32),
        "mu": jax.tree_util.tree_map(zeros_like_moment, params),
        "nu": jax.tree_util.tree_map(zeros_like_moment, params),
    }


def _global_norm(tree):
    return jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                        for g in jax.tree_util.tree_leaves(tree)))


def adamw_update(params: Any, grads: Any, state: dict,
                 cfg: AdamWConfig, lr_scale=1.0):
    """Returns (new_params, new_state, metrics)."""
    step = state["step"] + 1
    gnorm = _global_norm(grads)
    clip = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gnorm, 1e-12)) \
        if cfg.grad_clip else 1.0

    b1c = 1.0 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1.0 - cfg.b2 ** step.astype(jnp.float32)
    lr = cfg.lr * lr_scale

    def upd(p, g, mu, nu):
        g = g.astype(jnp.float32) * clip
        mu_f = _dq8(mu["q"], mu["scale"]) if cfg.quantize_moments else mu
        nu_f = _dq8(nu["q"], nu["scale"]) if cfg.quantize_moments else nu
        mu_f = cfg.b1 * mu_f + (1 - cfg.b1) * g
        nu_f = cfg.b2 * nu_f + (1 - cfg.b2) * jnp.square(g)
        mhat = mu_f / b1c
        vhat = nu_f / b2c
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps)
        if p.ndim >= 2:  # decoupled weight decay on matrices only
            delta = delta + cfg.weight_decay * p.astype(jnp.float32)
        new_p = (p.astype(jnp.float32) - lr * delta).astype(p.dtype)
        if cfg.quantize_moments:
            mq, ms = _q8(mu_f)
            nq, ns = _q8(nu_f)
            return new_p, {"q": mq, "scale": ms}, {"q": nq, "scale": ns}
        return new_p, mu_f, nu_f

    flat_p, tdef = jax.tree_util.tree_flatten(params)
    flat_g = tdef.flatten_up_to(grads)
    flat_mu = tdef.flatten_up_to(state["mu"])
    flat_nu = tdef.flatten_up_to(state["nu"])
    out = [upd(p, g, m, n)
           for p, g, m, n in zip(flat_p, flat_g, flat_mu, flat_nu)]
    new_params = tdef.unflatten([o[0] for o in out])
    new_mu = tdef.unflatten([o[1] for o in out])
    new_nu = tdef.unflatten([o[2] for o in out])
    new_state = {"step": step, "mu": new_mu, "nu": new_nu}
    return new_params, new_state, {"grad_norm": gnorm,
                                   "lr": jnp.asarray(lr, jnp.float32)}
