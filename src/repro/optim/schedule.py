"""LR schedules (pure functions of the step counter, scan/jit-safe)."""

from __future__ import annotations

import jax.numpy as jnp

__all__ = ["warmup_cosine", "warmup_constant"]


def warmup_cosine(step, *, warmup: int = 100, total: int = 10_000,
                  min_ratio: float = 0.1):
    """Linear warmup → cosine decay to min_ratio.  Returns an lr *scale*."""
    step = jnp.asarray(step, jnp.float32)
    warm = step / jnp.maximum(warmup, 1)
    progress = jnp.clip((step - warmup) / jnp.maximum(total - warmup, 1),
                        0.0, 1.0)
    cos = min_ratio + (1 - min_ratio) * 0.5 * (1 + jnp.cos(jnp.pi * progress))
    return jnp.where(step < warmup, warm, cos)


def warmup_constant(step, *, warmup: int = 100):
    step = jnp.asarray(step, jnp.float32)
    return jnp.minimum(step / jnp.maximum(warmup, 1), 1.0)
