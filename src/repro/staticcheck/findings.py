"""Finding records and the suppression baseline.

A finding's ``key`` deliberately excludes line numbers and message
text: it is ``rule:path:where``, where ``where`` is a qualified name
(AST layer) or ``cell/stage`` context (jaxpr layer).  Keys therefore
survive unrelated edits to the same file, and a suppression only goes
stale when the flagged construct itself disappears — which the gate
detects and fails on (stale suppressions hide regressions).
"""

from __future__ import annotations

import dataclasses
import json
from pathlib import Path


@dataclasses.dataclass(frozen=True)
class Finding:
    rule: str      # e.g. "SC101"
    path: str      # repo-relative posix path, or "jaxpr:<cell>" context
    where: str     # qualname (AST) or stage/detail (jaxpr)
    message: str   # human-readable; NOT part of the key
    line: int = 0  # source line (AST layer only); NOT part of the key

    @property
    def key(self) -> str:
        return f"{self.rule}:{self.path}:{self.where}"

    def render(self) -> str:
        loc = f"{self.path}:{self.line}" if self.line else self.path
        return f"{self.rule} {loc} [{self.where}] {self.message}"

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)


def load_baseline(path: str | Path) -> dict:
    """Read a suppression baseline.  Shape::

        {"version": 1,
         "suppressions": [{"key": "SC101:src/...:fn", "reason": "..."}]}

    A missing file is an empty baseline (the gate still runs)."""
    p = Path(path)
    if not p.exists():
        return {"version": 1, "suppressions": []}
    data = json.loads(p.read_text())
    if not isinstance(data.get("suppressions"), list):
        raise ValueError(f"malformed baseline {p}: expected a "
                         f"'suppressions' list")
    return data


def apply_baseline(findings: list[Finding], baseline: dict):
    """Split findings by the baseline.

    Returns ``(unsuppressed, suppressed, stale_keys)`` where
    ``stale_keys`` are baseline entries that matched nothing — each of
    those is itself a gate failure, so fixed findings must be removed
    from the baseline in the same change."""
    keys = {e["key"] for e in baseline.get("suppressions", [])}
    unsuppressed = [f for f in findings if f.key not in keys]
    suppressed = [f for f in findings if f.key in keys]
    fired = {f.key for f in findings}
    stale = sorted(keys - fired)
    return unsuppressed, suppressed, stale
