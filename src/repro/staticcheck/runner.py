"""Gate orchestration: AST layer + the quant x backend x mode grid.

Each grid cell builds a tiny (``reduced``) engine, drives a few
requests through it so every stage records its abstract signatures,
then hands the stages to the jaxpr rules.  The cells mirror the
benched serving grid (``benchmarks/serve_bench.py``): dense and
nibble-quantized programs on both matmul backends, plus the spec and
wave modes whose compile-pin contracts differ.
"""

from __future__ import annotations

import dataclasses
from pathlib import Path

import numpy as np

from repro.staticcheck.findings import Finding
from repro.staticcheck.ast_rules import run_ast_rules
from repro.staticcheck import jaxpr_rules
from repro.staticcheck.flops import analytic_macs, cycle_bridge
from repro.roofline.analysis import stage_roofline

# benched serving shape (reduced yi-6b), kept tiny: the contracts are
# shape-independent, so the gate runs in CI seconds, not bench minutes
ARCH = "yi-6b"
BATCH = 4
MAX_LEN = 32
PREFILL_LEN = 8
DECODE_CHUNK = 4
PAGE_SIZE = 4
SPEC_K = 4
WAVE_CHUNK = 4
WAVE_GROUP = 2


@dataclasses.dataclass(frozen=True)
class GridCell:
    name: str
    quant_mode: str
    backend: str
    mode: str                     # "plain" | "spec" | "wave"

    @property
    def expected_pins(self) -> dict:
        if self.mode == "spec":
            return {"prefill": 1, "decode_chunk": 0, "draft": 1,
                    "verify": 1}
        if self.mode == "wave":
            return {"prefill": 0, "decode_chunk": 1, "prefill_chunk": 1}
        return {"prefill": 1, "decode_chunk": 1}


GRID_CELLS = (
    GridCell("dense-xla", "dense", "xla", "plain"),
    GridCell("nibble-xla", "w8a8_nibble", "xla", "plain"),
    GridCell("nibble-pallas", "w8a8_nibble", "pallas", "plain"),
    GridCell("nibble-xla-spec", "w8a8_nibble", "xla", "spec"),
    GridCell("nibble-xla-wave", "w8a8_nibble", "xla", "wave"),
)


def build_cell_engine(cell: GridCell):
    """Build the cell's engine and run a tiny workload so every stage
    records its signatures (3 requests, mixed prompt lengths)."""
    import jax
    from repro.configs import get_config, reduced
    from repro.models import model_init
    from repro.serve.engine import Engine, ServeConfig

    cfg = reduced(get_config(ARCH))
    scfg = ServeConfig(
        batch=BATCH, max_len=MAX_LEN, prefill_len=PREFILL_LEN,
        decode_chunk=DECODE_CHUNK, cache_mode="paged",
        page_size=PAGE_SIZE, quant_mode=cell.quant_mode,
        quant_backend=cell.backend,
        spec_decode=(cell.mode == "spec"), spec_k=SPEC_K,
        prefill_chunk=(WAVE_CHUNK if cell.mode == "wave" else 0),
        admit_group=(WAVE_GROUP if cell.mode == "wave" else 1),
    )
    params = model_init(jax.random.PRNGKey(0), cfg)
    engine = Engine(cfg, params, scfg)
    rng = np.random.default_rng(0)
    for n, length in enumerate((5, 8, 3)):
        engine.submit(rng.integers(1, cfg.vocab_size,
                                   size=length).astype(np.int32),
                      max_new_tokens=4)
    engine.run()
    return engine


# per-stage geometry for the analytic cross-check: tokens processed,
# padded attention context, and LM-head positions per dispatch
def _stage_geometry(stage: str, cell: GridCell) -> dict | None:
    if stage == "prefill":
        return dict(tokens=PREFILL_LEN, kv_len=PREFILL_LEN,
                    logit_positions=1)
    if stage == "prefill_chunk":
        # the wave program projects the LM head over every (G, C)
        # position and gathers per-lane last tokens afterwards
        return dict(tokens=WAVE_GROUP * WAVE_CHUNK, kv_len=MAX_LEN,
                    logit_positions=WAVE_GROUP * WAVE_CHUNK)
    if stage == "decode_chunk":
        return dict(tokens=BATCH * DECODE_CHUNK, kv_len=MAX_LEN,
                    logit_positions=BATCH * DECODE_CHUNK)
    if stage == "draft":
        return dict(tokens=BATCH * SPEC_K, kv_len=MAX_LEN,
                    logit_positions=BATCH * SPEC_K)
    if stage == "verify":
        return dict(tokens=BATCH * (SPEC_K + 1), kv_len=MAX_LEN,
                    logit_positions=BATCH * (SPEC_K + 1))
    return None


def _stage_quantized(stage: str, cell: GridCell) -> bool:
    if cell.quant_mode == "dense":
        return False
    if cell.mode == "spec":
        # spec pins prefill/verify to dense; only the draft runs
        # quantized
        return stage == "draft"
    return True


def analytic_stage_macs(stage: str, cell: GridCell) -> dict | None:
    """Closed-form MACs for one stage dispatch on the gate's shapes."""
    from repro.configs import get_config, reduced
    geo = _stage_geometry(stage, cell)
    if geo is None:
        return None
    cfg = reduced(get_config(ARCH))
    return analytic_macs(cfg, quantized=_stage_quantized(stage, cell),
                         **geo)


# SC306: static jaxpr MACs vs the closed-form analytic model derived
# from ModelConfig geometry.  On the xla cells these two independent
# derivations agree EXACTLY (every projection/attention/head dot is
# accounted); the tolerance absorbs future benign reassociations.
# Pallas cells are exempt: the 128-wide kernel blocks pad the reduced
# model's 64-wide operands, so the grid genuinely executes ~48-78x the
# useful MACs — that padding blow-up is visible in the report table
# instead.
ANALYTIC_RTOL = 0.02


def run_jaxpr_layer(cells=GRID_CELLS):
    """Build + drive every grid cell, run the jaxpr rules, and emit the
    per-stage cost table (static walk + analytic model + cycle
    bridge)."""
    findings: list = []
    stage_table: list = []
    for cell in cells:
        engine = build_cell_engine(cell)
        findings += jaxpr_rules.check_pins(engine, cell.expected_pins,
                                           cell.name)
        for name, stage in sorted(engine.stage_programs().items()):
            # pallas cells: the kernel grid executes padded tiles, so
            # the flop cross-checks (SC305/SC306) are xla-cells-only;
            # the contract rules still run
            f, costs = jaxpr_rules.check_stage(stage, name, cell.name)
            if cell.backend != "xla":
                f = [x for x in f if x.rule != "SC305"]
            findings += f
            analytic = analytic_stage_macs(name, cell)
            for c in costs:
                if analytic is not None:
                    c["analytic_macs"] = analytic["total_macs"]
                    c["analytic_detail"] = analytic
                    rel = (abs(c["dot_macs"] - analytic["total_macs"])
                           / max(analytic["total_macs"], 1))
                    c["analytic_rel_err"] = rel
                    if cell.backend == "xla" and rel > ANALYTIC_RTOL:
                        findings.append(Finding(
                            "SC306", f"jaxpr:{cell.name}", name,
                            f"static dot MACs {c['dot_macs']} vs "
                            f"analytic {analytic['total_macs']} "
                            f"disagree by {rel:.1%} "
                            f"(> {ANALYTIC_RTOL:.0%}): the stage "
                            "geometry or the MAC model drifted"))
                c["nibble_cycles"] = cycle_bridge(
                    c["dot_macs"], "nibble_precompute")
                c["shift_add_cycles"] = cycle_bridge(
                    c["dot_macs"], "shift_add")
                c["roofline"] = stage_roofline(c)
            stage_table += costs
    return findings, stage_table


def run_gate(src_root: str | Path, repo_root: str | Path | None = None,
             ast_only: bool = False, cells=GRID_CELLS):
    """The full gate: AST layer + (optionally) the jaxpr grid.

    Returns ``(findings, report)`` where ``report`` is the
    JSON-serializable summary ``--report`` emits."""
    findings = run_ast_rules(src_root, repo_root)
    stage_table: list = []
    if not ast_only:
        jf, stage_table = run_jaxpr_layer(cells)
        findings += jf
    report = {
        "rules": {
            "ast": ["SC101", "SC102", "SC103", "SC104", "SC105",
                    "SC201", "SC202"],
            "jaxpr": [] if ast_only else
                     ["SC301", "SC302", "SC303", "SC304", "SC305",
                      "SC306"],
        },
        "grid": [] if ast_only else [c.name for c in cells],
        "findings": [f.to_dict() for f in findings],
        "stage_costs": stage_table,
    }
    return findings, report
