"""Layer 2: rules over lowered stage programs.

Each rule takes a stage's recorded abstract signature (see
``_CountingJit.signatures``), re-lowers it, and inspects the jaxpr /
StableHLO — no workload re-run, no runtime counters.

* **SC301** — a ``convert_element_type`` that widens an integer
  (quantized) operand to float and feeds a ``dot_general`` through
  layout-only ops: the nibble contract is ONE int8 x int8 dot with
  ``preferred_element_type=int32``; an int->float convert on a dot
  operand means the quantized matmul silently runs in f32.
* **SC302** — donation that failed to alias: every leaf of the donated
  caches argument must appear as a ``tf.aliasing_output`` parameter
  attribute in the lowered module (JAX only *warns* when donation is
  unusable — this turns the warning into a gate failure).  Donation
  warnings captured during lowering/compilation fail the rule too.
* **SC303** — host callbacks / transfers in a compiled body
  (``pure_callback`` & friends, infeed/outfeed): the engine step paths
  must be pure device programs.
* **SC304** — the abstract-signature pin: the number of *distinct
  recorded signatures* (blake2b-hashed) per stage must equal the
  pinned ``compile_counts`` contract for the mode.  This proves the
  refill-without-recompile claim from signatures, independent of the
  runtime counter.
* **SC305** — the static flop model must bracket XLA's own
  ``cost_analysis()`` count (scan-once .. fully-multiplied totals,
  widened by ``FLOPS_RTOL``; ``io_bytes`` must not exceed ``bytes
  accessed``): if the jaxpr walk and the compiler disagree about how
  much work a stage does, the capacity model's front-end is lying.

(**SC306**, the static-vs-analytic MAC cross-check against
``core.cycle_model``'s geometry, lives in ``runner`` — it needs the
grid cell's stage geometry, which the jaxpr alone doesn't carry.)
"""

from __future__ import annotations

import hashlib
import math
import warnings

import jax

from repro.staticcheck.findings import Finding
from repro.staticcheck.flops import walk_jaxpr, StageCost

# ops that only rearrange bytes: a convert on the far side of these is
# still "the same operand" for dtype-contract purposes
_LAYOUT_OPS = {
    "reshape", "transpose", "broadcast_in_dim", "squeeze",
    "expand_dims", "slice", "dynamic_slice", "concatenate", "rev",
    "copy", "pad",
}
_INT_DTYPES = ("int8", "int4", "uint8", "uint4")
# XLA's flop count and the static walk are independent models of the
# same program.  XLA's HloCostAnalysis counts a while-loop body ONCE
# (it has no static trip-count model), and CPU fusion duplicates
# producers into multiple consumers (~35% observed on the benched
# grid), so the sound invariant is a bracket: XLA's number must lie
# between the scan-once static total and the fully-multiplied static
# total, each side widened by FLOPS_RTOL.  For loop-free stages the
# bracket collapses to a plain two-sided check.  io_bytes (top-level
# avals) must be a lower bound on the compiler's "bytes accessed" up
# to the same slack.
FLOPS_RTOL = 0.50


def signature_hash(signature) -> str:
    """Deterministic digest of one abstract call signature."""
    treedef, leaf_sigs = signature
    h = hashlib.blake2b(digest_size=12)
    h.update(repr(str(treedef)).encode())
    h.update(repr(leaf_sigs).encode())
    return h.hexdigest()


def _jaxprs_with_producers(jaxpr):
    """Yield (jaxpr, {var: producing eqn}) for the tree of sub-jaxprs."""
    stack = [jaxpr]
    while stack:
        jx = stack.pop()
        producers = {}
        for eqn in jx.eqns:
            for out in eqn.outvars:
                producers[out] = eqn
            for val in eqn.params.values():
                vals = val if isinstance(val, (tuple, list)) else [val]
                for v in vals:
                    if isinstance(v, jax.core.ClosedJaxpr):
                        stack.append(v.jaxpr)
                    elif isinstance(v, jax.core.Jaxpr):
                        stack.append(v)
        yield jx, producers


def _trace_operand(var, producers, depth=24):
    """Walk back through layout-only ops; yield the converts found at
    the frontier."""
    frontier = [(var, depth)]
    while frontier:
        v, d = frontier.pop()
        eqn = producers.get(v)
        if eqn is None or d <= 0:
            continue
        name = eqn.primitive.name
        if name == "convert_element_type":
            yield eqn
        elif name in _LAYOUT_OPS:
            for iv in eqn.invars:
                if hasattr(iv, "aval"):
                    frontier.append((iv, d - 1))


def check_quant_widening(jaxpr, path: str, where: str) -> list:
    """SC301 over one (closed) jaxpr."""
    jx = jaxpr.jaxpr if isinstance(jaxpr, jax.core.ClosedJaxpr) else jaxpr
    findings = []
    for sub, producers in _jaxprs_with_producers(jx):
        for eqn in sub.eqns:
            if eqn.primitive.name != "dot_general":
                continue
            for operand in eqn.invars[:2]:
                if not hasattr(operand, "aval"):
                    continue
                for conv in _trace_operand(operand, producers):
                    src = str(conv.invars[0].aval.dtype)
                    dst = str(conv.outvars[0].aval.dtype)
                    if any(src.startswith(t) for t in _INT_DTYPES) \
                            and "float" in dst:
                        findings.append(Finding(
                            "SC301", path, where,
                            f"quantized operand widened {src}->{dst} "
                            f"feeding dot_general "
                            f"{tuple(operand.aval.shape)}: the "
                            "nibble contract is one int8 dot with "
                            "preferred_element_type=int32"))
    return findings


def check_callbacks(jaxpr, path: str, where: str) -> list:
    """SC303 over one (closed) jaxpr."""
    jx = jaxpr.jaxpr if isinstance(jaxpr, jax.core.ClosedJaxpr) else jaxpr
    findings = []
    for sub, _producers in _jaxprs_with_producers(jx):
        for eqn in sub.eqns:
            name = eqn.primitive.name
            if ("callback" in name or "infeed" in name
                    or "outfeed" in name):
                findings.append(Finding(
                    "SC303", path, where,
                    f"host primitive {name!r} in a compiled stage "
                    "body: engine step programs must be pure device "
                    "code"))
    return findings


def check_stage(stage, stage_name: str, cell: str,
                donate_arg_index: int = 1):
    """Run SC301/SC302/SC303/SC305 over every recorded signature of one
    stage.  Returns ``(findings, costs)`` where ``costs`` is a list of
    per-signature dicts (static + compiler-reported numbers)."""
    path = f"jaxpr:{cell}"
    findings: list = []
    costs: list = []
    for sig in stage.signatures:
        args = stage.abstract_args(sig)
        where = f"{stage_name}"
        with warnings.catch_warnings(record=True) as caught:
            warnings.simplefilter("always")
            lowered = stage.jit_fn.lower(*args)
            traced = stage.jit_fn.trace(*args)
            compiled = lowered.compile()
        jaxpr = traced.jaxpr

        findings += check_quant_widening(jaxpr, path, where)
        findings += check_callbacks(jaxpr, path, where)

        # SC302: donation must have established aliasing
        donated_leaves = len(jax.tree_util.tree_leaves(
            args[donate_arg_index])) if len(args) > donate_arg_index \
            else 0
        alias_count = lowered.as_text().count("tf.aliasing_output")
        donation_warnings = [str(w.message) for w in caught
                             if "donat" in str(w.message).lower()]
        if donation_warnings:
            findings.append(Finding(
                "SC302", path, where,
                f"donation warning during lowering: "
                f"{donation_warnings[0][:160]}"))
        if alias_count < donated_leaves:
            findings.append(Finding(
                "SC302", path, where,
                f"only {alias_count}/{donated_leaves} donated cache "
                "leaves aliased to outputs in the lowered module: the "
                "unaliased pools are copied every dispatch"))

        # SC305: static flop model vs the compiler's own count
        cost = walk_jaxpr(jaxpr)
        ca = compiled.cost_analysis()
        if isinstance(ca, (list, tuple)):
            ca = ca[0] if ca else {}
        xla_flops = float(ca.get("flops", 0.0) or 0.0)
        xla_bytes = float(ca.get("bytes accessed", 0.0) or 0.0)
        lo = cost.scan_once_flops * (1 - FLOPS_RTOL)
        hi = cost.total_flops * (1 + FLOPS_RTOL)
        if xla_flops > 0 and not (lo <= xla_flops <= hi):
            findings.append(Finding(
                "SC305", path, where,
                f"XLA cost_analysis flops {xla_flops:.0f} outside the "
                f"static bracket [{lo:.0f}, {hi:.0f}] (scan-once "
                f"{cost.scan_once_flops} .. full {cost.total_flops} "
                f"+/- {FLOPS_RTOL:.0%}): the capacity model's static "
                "front-end is off"))
        if xla_bytes > 0 and cost.io_bytes > xla_bytes * (1 + FLOPS_RTOL):
            findings.append(Finding(
                "SC305", path, where,
                f"static io_bytes {cost.io_bytes} exceeds XLA "
                f"bytes-accessed {xla_bytes:.0f}"))

        costs.append({
            "stage": stage_name,
            "cell": cell,
            "signature": signature_hash(sig),
            **cost.to_dict(),
            "xla_flops": xla_flops,
            "xla_bytes_accessed": xla_bytes,
            "aliased_outputs": alias_count,
            "donated_leaves": donated_leaves,
        })
    return findings, costs


def check_pins(engine, expected: dict, cell: str) -> list:
    """SC304: distinct recorded signatures per stage == the pinned
    compile-count contract, proven by hashing the signatures."""
    findings = []
    path = f"jaxpr:{cell}"
    stages = engine.stage_programs()
    for name, pin in expected.items():
        stage = stages.get(name)
        n_sigs = len(stage.signatures) if stage is not None else 0
        hashes = sorted(signature_hash(s) for s in stage.signatures) \
            if stage is not None else []
        if n_sigs != pin:
            findings.append(Finding(
                "SC304", path, name,
                f"{n_sigs} distinct abstract signatures recorded "
                f"(hashes {hashes[:4]}) but the compile-count pin is "
                f"{pin}: a new signature means a recompile edge"))
    for name, stage in stages.items():
        if name not in expected and len(stage.signatures) > 0:
            findings.append(Finding(
                "SC304", path, name,
                f"stage ran {len(stage.signatures)} signatures but has "
                "no pinned compile count for this mode"))
    return findings
