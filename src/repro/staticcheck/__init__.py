"""Static-analysis gate for the serving stack's hot-path contracts.

Two layers:

* **AST rules** (``ast_rules``) lint the ``src/repro`` tree for
  tracer-leak / host-sync / recompile-risk patterns and repo contracts
  (cache-carrying jit sites must donate; ``serve/paging.py`` stays
  host-side numpy).
* **jaxpr rules** (``jaxpr_rules``) build tiny engines across a
  quant x backend x mode grid, re-lower every stage program from its
  recorded abstract signatures, and verify the dtype / donation /
  callback / compile-pin contracts on the lowered artifacts.  The same
  walk extracts per-stage flop/byte counts (``flops``) cross-checked
  against ``core.cycle_model`` and XLA's own cost analysis — the
  static front-end for the analytic capacity model.

Findings flow through a committed suppression baseline
(``tools/staticcheck_baseline.json``); the CLI is
``tools/staticcheck.py`` and the gate runs in CI.
"""

from repro.staticcheck.findings import (Finding, load_baseline,
                                        apply_baseline)
from repro.staticcheck.ast_rules import run_ast_rules
from repro.staticcheck.runner import (GRID_CELLS, run_gate,
                                      run_jaxpr_layer)

__all__ = ["Finding", "load_baseline", "apply_baseline",
           "run_ast_rules", "GRID_CELLS", "run_gate",
           "run_jaxpr_layer"]
