"""Layer 1: AST lint rules over the ``src/repro`` tree.

Rule catalog (see docs/staticcheck.md):

* **SC101** — ``.item()`` on a traced value inside a jitted body
  (host sync + tracer leak).
* **SC102** — ``float()`` / ``int()`` / ``bool()`` on a traced value
  inside a jitted body (concretization error at trace time, or a
  silent host sync outside it).
* **SC103** — ``np.*`` call on a traced value inside a jitted body
  (implicit device-to-host transfer; numpy on closure constants or
  static shapes is fine and not flagged).
* **SC104** — Python ``if`` / ``while`` branching on a traced value
  inside a jitted body (recompile per boolean or TracerBoolError;
  branching on ``.shape``-derived ints and ``static_argnames`` is
  static and not flagged).
* **SC105** — ``jax.device_get`` / ``.block_until_ready()`` inside the
  engine step paths (``src/repro/serve``): the engine's host boundary
  is ``np.asarray`` on stage outputs, by design exactly once per
  dispatch; ad-hoc syncs hide dispatch stalls.
* **SC201** — a cache-carrying jit site (the wrapped function has a
  ``caches``-like parameter) that does not pass ``donate_argnums``
  covering it: the pool is then copied every dispatch.
* **SC202** — ``jax``/``jnp`` import in ``serve/paging.py``: the page
  table is host-side numpy by contract (O(1) bookkeeping, never
  traced).

Traced-ness is a per-function taint pass: the jitted body's parameters
(minus ``static_argnames``) are traced, assignments propagate taint,
and attribute reads of ``.shape`` / ``.ndim`` / ``.dtype`` / ``.size``
*stop* it (those are static at trace time).  Jit sites are discovered
from ``jax.jit(fn)`` calls, ``_CountingJit(fn)`` calls,
``@jax.jit`` / ``@partial(jax.jit, ...)`` decorators, and
``self._build_*()`` stage builders (whose nested ``def``s are the
jitted closures).
"""

from __future__ import annotations

import ast
from pathlib import Path

from repro.staticcheck.findings import Finding

# attribute reads that yield static (trace-time Python) values
_STATIC_ATTRS = {"shape", "ndim", "dtype", "size", "aval"}
# callables whose result on a traced array is a host-side cast
_CAST_BUILTINS = {"float", "int", "bool"}
_NUMPY_ALIASES = {"np", "numpy", "onp"}


def _dotted(node: ast.AST) -> str:
    """'jax.jit' for Attribute(Name('jax'), 'jit'); '' if not a plain
    dotted name."""
    parts = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return ""


def _tainted_names(node: ast.AST) -> set:
    """Names read by ``node``, excluding any inside a static-attribute
    access (``x.shape[...]`` reads no traced value)."""
    out: set = set()

    class V(ast.NodeVisitor):
        def visit_Attribute(self, n):
            if n.attr in _STATIC_ATTRS:
                return  # do not descend: static at trace time
            self.generic_visit(n)

        def visit_Compare(self, n):
            # `x is None` / `x is not None` yields a static Python bool
            # even when x is traced (tracers are never None), and
            # `"key" in batch` is dict-key membership — both are
            # idiomatic static branches, not tracer reads
            if all(isinstance(op, (ast.Is, ast.IsNot)) for op in n.ops) \
                    and all(isinstance(c, ast.Constant)
                            and c.value is None for c in n.comparators):
                return
            if all(isinstance(op, (ast.In, ast.NotIn)) for op in n.ops) \
                    and isinstance(n.left, ast.Constant):
                return
            self.generic_visit(n)

        def visit_Name(self, n):
            out.add(n.id)

    V().visit(node)
    return out


class _JitSite:
    def __init__(self, node, body, static_names, donate, qual, has_donate):
        self.node = node              # the Call / FunctionDef site
        self.body = body              # resolved FunctionDef or None
        self.static_names = static_names
        self.donate = donate          # set of donated arg indices
        self.qual = qual              # qualname of the site
        self.has_donate = has_donate  # donate kwarg present at all


class _ModuleIndex(ast.NodeVisitor):
    """Qualname index of every function/method def in a module."""

    def __init__(self):
        self.funcs: dict[str, ast.FunctionDef] = {}
        self.parents: dict[ast.AST, str] = {}
        self._stack: list[str] = []

    def _visit_scoped(self, node):
        qual = ".".join(self._stack + [node.name])
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            self.funcs.setdefault(qual, node)
            # also index by bare name for intra-module resolution
            self.funcs.setdefault(node.name, node)
        self._stack.append(node.name)
        self.generic_visit(node)
        self._stack.pop()

    visit_FunctionDef = _visit_scoped
    visit_AsyncFunctionDef = _visit_scoped
    visit_ClassDef = _visit_scoped


def _const_indices(node: ast.AST) -> set:
    """Constant int / tuple-of-int value of a donate_argnums kwarg."""
    if isinstance(node, ast.Constant) and isinstance(node.value, int):
        return {node.value}
    if isinstance(node, (ast.Tuple, ast.List)):
        out = set()
        for e in node.elts:
            if isinstance(e, ast.Constant) and isinstance(e.value, int):
                out.add(e.value)
        return out
    return set()


def _const_strs(node: ast.AST) -> set:
    if isinstance(node, ast.Constant) and isinstance(node.value, str):
        return {node.value}
    if isinstance(node, (ast.Tuple, ast.List)):
        return {e.value for e in node.elts
                if isinstance(e, ast.Constant) and isinstance(e.value, str)}
    return set()


def _jit_kwargs(call: ast.Call):
    static, donate, has_donate = set(), set(), False
    for kw in call.keywords:
        if kw.arg in ("static_argnames", "static_argnums"):
            static |= _const_strs(kw.value)
        elif kw.arg in ("donate_argnums", "donate_argnames"):
            has_donate = True
            donate |= _const_indices(kw.value)
            # donate_argnames contributes names, map later via body
            donate |= {s for s in _const_strs(kw.value)}
    return static, donate, has_donate


def _nested_defs(fn: ast.FunctionDef) -> list:
    out = []
    for node in ast.walk(fn):
        if node is fn:
            continue
        if isinstance(node, ast.FunctionDef):
            out.append(node)
    return out


def _resolve_builder(index: _ModuleIndex, qual_prefix: str,
                     name: str, seen: set) -> list:
    """``self._build_X()`` -> the nested defs of method ``_build_X``
    (following one ``return self._build_Y()`` level of indirection)."""
    if name in seen:
        return []
    seen.add(name)
    fn = index.funcs.get(name)
    if fn is None:
        return []
    bodies = _nested_defs(fn)
    for node in ast.walk(fn):
        if (isinstance(node, ast.Call)
                and isinstance(node.func, ast.Attribute)
                and isinstance(node.func.value, ast.Name)
                and node.func.value.id == "self"
                and node.func.attr.startswith("_build_")):
            bodies += _resolve_builder(index, qual_prefix,
                                       node.func.attr, seen)
    return bodies


def _find_jit_sites(tree: ast.Module, index: _ModuleIndex) -> list:
    sites = []
    for node in ast.walk(tree):
        # decorator form: @jax.jit / @partial(jax.jit, ...)
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            for dec in node.decorator_list:
                static, donate, has_donate = set(), set(), False
                is_jit = False
                if _dotted(dec) in ("jax.jit", "jit"):
                    is_jit = True
                elif isinstance(dec, ast.Call):
                    fname = _dotted(dec.func)
                    if fname in ("jax.jit", "jit"):
                        is_jit = True
                        static, donate, has_donate = _jit_kwargs(dec)
                    elif (fname in ("functools.partial", "partial")
                          and dec.args
                          and _dotted(dec.args[0]) in ("jax.jit", "jit")):
                        is_jit = True
                        static, donate, has_donate = _jit_kwargs(dec)
                if is_jit:
                    sites.append(_JitSite(dec, node, static, donate,
                                          node.name, has_donate))
        # call form: jax.jit(fn, ...) / _CountingJit(fn, ...)
        if isinstance(node, ast.Call):
            fname = _dotted(node.func)
            if fname in ("jax.jit", "jit", "_CountingJit"):
                static, donate, has_donate = _jit_kwargs(node)
                bodies: list = []
                qual = fname
                if node.args:
                    arg = node.args[0]
                    if isinstance(arg, ast.Name):
                        body = index.funcs.get(arg.id)
                        if body is not None:
                            bodies = [body] + _nested_defs(body)
                        qual = arg.id
                    elif (isinstance(arg, ast.Call)
                          and isinstance(arg.func, ast.Attribute)
                          and isinstance(arg.func.value, ast.Name)
                          and arg.func.value.id == "self"):
                        qual = arg.func.attr
                        bodies = _resolve_builder(index, "", arg.func.attr,
                                                  set())
                    elif isinstance(arg, ast.Call):
                        callee = _dotted(arg.func)
                        body = index.funcs.get(callee)
                        qual = callee or qual
                        if body is not None:
                            bodies = _nested_defs(body)
                for body in bodies or [None]:
                    sites.append(_JitSite(node, body, static, donate,
                                          qual, has_donate))
    return sites


def _param_names(fn: ast.FunctionDef) -> list:
    a = fn.args
    return ([p.arg for p in a.posonlyargs] + [p.arg for p in a.args])


def _lint_jitted_body(fn: ast.FunctionDef, static_names: set,
                      relpath: str, qual: str) -> list:
    """SC101-SC104 over one jitted closure (incl. nested scan/loop
    bodies, whose params are traced too)."""
    findings = []
    traced = {p for p in _param_names(fn) if p not in static_names}
    for nested in _nested_defs(fn):
        traced |= set(_param_names(nested))

    # forward taint propagation through assignments, in source order
    for node in ast.walk(fn):
        if isinstance(node, (ast.Assign, ast.AugAssign, ast.AnnAssign)):
            value = node.value
            if value is None:
                continue
            if _tainted_names(value) & traced:
                targets = (node.targets if isinstance(node, ast.Assign)
                           else [node.target])
                for t in targets:
                    for n in ast.walk(t):
                        if isinstance(n, ast.Name):
                            traced.add(n.id)

    def flag(rule, node, msg):
        findings.append(Finding(rule, relpath, qual, msg,
                                getattr(node, "lineno", 0)))

    for node in ast.walk(fn):
        if isinstance(node, ast.Call):
            # SC101: traced.item()
            if (isinstance(node.func, ast.Attribute)
                    and node.func.attr == "item"
                    and _tainted_names(node.func.value) & traced):
                flag("SC101", node, ".item() on a traced value forces a "
                     "host sync inside a jitted body")
            fname = _dotted(node.func)
            # SC102: float(traced) / int(traced) / bool(traced)
            if (fname in _CAST_BUILTINS and node.args
                    and _tainted_names(node.args[0]) & traced):
                flag("SC102", node, f"{fname}() on a traced value "
                     "concretizes (or host-syncs) inside a jitted body")
            # SC103: np.f(traced)
            root = fname.split(".", 1)[0] if fname else ""
            if (root in _NUMPY_ALIASES and "." in fname):
                args_tainted = any(_tainted_names(a) & traced
                                   for a in node.args)
                if args_tainted:
                    flag("SC103", node, f"{fname}() on a traced value "
                         "is an implicit device transfer inside a "
                         "jitted body")
        # SC104: if/while on a traced predicate
        if isinstance(node, (ast.If, ast.While, ast.IfExp)):
            if _tainted_names(node.test) & traced:
                kind = type(node).__name__.lower()
                flag("SC104", node, f"python {kind} on a traced value "
                     "inside a jitted body (recompile per value or "
                     "TracerBoolError)")
    return findings


def _lint_serve_host_sync(tree: ast.Module, relpath: str) -> list:
    """SC105 over a serve/ module (whole file, not just jitted
    bodies)."""
    findings = []
    for node in ast.walk(tree):
        if isinstance(node, ast.Call):
            if _dotted(node.func) in ("jax.device_get", "device_get"):
                findings.append(Finding(
                    "SC105", relpath, "module",
                    "jax.device_get in an engine step path; the "
                    "engine's host boundary is np.asarray on stage "
                    "outputs", node.lineno))
            elif (isinstance(node.func, ast.Attribute)
                  and node.func.attr == "block_until_ready"):
                findings.append(Finding(
                    "SC105", relpath, "module",
                    ".block_until_ready() in an engine step path "
                    "serializes dispatch", node.lineno))
    return findings


def _lint_paging_numpy_only(tree: ast.Module, relpath: str) -> list:
    """SC202: serve/paging.py must not import jax."""
    findings = []
    for node in ast.walk(tree):
        bad = None
        if isinstance(node, ast.Import):
            for alias in node.names:
                if alias.name == "jax" or alias.name.startswith("jax."):
                    bad = alias.name
        elif isinstance(node, ast.ImportFrom):
            if node.module and (node.module == "jax"
                                or node.module.startswith("jax.")):
                bad = node.module
        if bad:
            findings.append(Finding(
                "SC202", relpath, "module",
                f"import of {bad!r}: page-table logic is host-side "
                "numpy by contract", node.lineno))
    return findings


def _lint_donation(sites: list, relpath: str) -> list:
    """SC201 over the module's jit sites."""
    findings = []
    seen = set()
    for site in sites:
        if site.body is None:
            continue
        params = _param_names(site.body)
        cache_idx = [i for i, p in enumerate(params)
                     if "cache" in p or p == "pools"]
        if not cache_idx:
            continue
        key = (site.qual, tuple(cache_idx))
        if key in seen:
            continue
        seen.add(key)
        donated = set()
        for d in site.donate:
            if isinstance(d, int):
                donated.add(d)
            elif isinstance(d, str) and d in params:
                donated.add(params.index(d))
        missing = [params[i] for i in cache_idx if i not in donated]
        if not site.has_donate or missing:
            names = ", ".join(missing or [params[i] for i in cache_idx])
            findings.append(Finding(
                "SC201", relpath, site.qual,
                f"cache-carrying jit site does not donate {names!r}: "
                "the pool is copied on every dispatch",
                getattr(site.node, "lineno", 0)))
    return findings


def run_ast_rules(root: str | Path, repo_root: str | Path | None = None
                  ) -> list:
    """Run every AST rule over the ``.py`` files under ``root``.

    ``repo_root`` anchors the repo-relative paths used in finding keys
    (defaults to the directory containing ``src``, inferred from
    ``root``)."""
    root = Path(root).resolve()
    if repo_root is None:
        repo_root = root
        while repo_root.name not in ("", "repo") and \
                not (repo_root / ".git").exists():
            if repo_root.parent == repo_root:
                break
            repo_root = repo_root.parent
    repo_root = Path(repo_root).resolve()

    findings: list = []
    for path in sorted(root.rglob("*.py")):
        if "__pycache__" in path.parts:
            continue
        try:
            rel = path.relative_to(repo_root).as_posix()
        except ValueError:
            rel = path.as_posix()
        try:
            tree = ast.parse(path.read_text())
        except SyntaxError as e:
            findings.append(Finding("SC000", rel, "module",
                                    f"syntax error: {e}", e.lineno or 0))
            continue
        index = _ModuleIndex()
        index.visit(tree)
        sites = _find_jit_sites(tree, index)

        linted = set()
        for site in sites:
            if site.body is None or id(site.body) in linted:
                continue
            linted.add(id(site.body))
            findings += _lint_jitted_body(site.body, site.static_names,
                                          rel, site.body.name)
        findings += _lint_donation(sites, rel)

        parts = path.parts
        if "serve" in parts:
            findings += _lint_serve_host_sync(tree, rel)
            if path.name == "paging.py":
                findings += _lint_paging_numpy_only(tree, rel)
    return findings
