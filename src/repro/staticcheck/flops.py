"""Static flop/byte extraction from stage jaxprs + the analytic bridge.

``walk_jaxpr`` recursively walks a ``ClosedJaxpr`` (descending into
``pjit`` / ``scan`` / ``while`` / ``cond`` / ``remat`` / ``pallas_call``
sub-jaxprs, multiplying scan bodies by their static ``length``) and
accumulates:

* ``dot_flops`` / ``dot_macs`` — ``2 * batch * M * N * K`` per
  ``dot_general``, split by operand dtype class (``int`` vs ``float``
  dots: the nibble plane-concat contract makes quantized stages carry
  exactly 2x the dense int-MAC count through a *single* int8 dot);
* ``elementwise_flops`` — one flop per output element of arithmetic
  primitives (mirrors XLA's convention closely enough for a static
  cross-check against ``cost_analysis()``);
* ``io_bytes`` — bytes of the top-level jaxpr's input + output avals
  (the dispatch's HBM traffic floor; donated buffers still count once
  on each side, matching how XLA's ``bytes accessed`` treats aliased
  pairs).

``analytic_macs`` computes the same MAC count in closed form from the
``ModelConfig`` + stage geometry — two independent derivations of one
number.  ``cycle_bridge`` converts MACs into multiplier cycles via
``core.cycle_model.cycles_per_operand``, which is what the capacity
model consumes.
"""

from __future__ import annotations

import dataclasses
import math

import jax
import numpy as np

from repro.core.cycle_model import cycles_per_operand

# primitives counted at one flop per output element
_ELEMENTWISE = {
    "add", "sub", "mul", "div", "rem", "max", "min", "pow",
    "integer_pow", "exp", "log", "log1p", "expm1", "tanh", "logistic",
    "rsqrt", "sqrt", "sign", "abs", "neg", "floor", "ceil", "round",
    "erf", "erf_inv", "cos", "sin", "select_n", "clamp", "nextafter",
    "atan2", "square",
}
# sub-jaxpr-carrying params worth descending into
_SUBJAXPR_PARAMS = ("jaxpr", "call_jaxpr", "cond_jaxpr", "body_jaxpr",
                    "branches")


@dataclasses.dataclass
class DotRecord:
    lhs_shape: tuple
    rhs_shape: tuple
    lhs_dtype: str
    rhs_dtype: str
    out_dtype: str
    flops: int          # already multiplied by enclosing scan lengths
    macs: int


@dataclasses.dataclass
class StageCost:
    dot_flops: int = 0
    int_dot_macs: int = 0       # integer-operand dots (the quant path)
    float_dot_macs: int = 0     # float-operand dots (attention, dense)
    elementwise_flops: int = 0
    # the same totals with every scan body counted ONCE: XLA's
    # HloCostAnalysis does not multiply while-loop trip counts, so the
    # compiler cross-check brackets its number between `scan_once_*`
    # and the fully-multiplied totals
    scan_once_dot_flops: int = 0
    scan_once_elementwise_flops: int = 0
    io_bytes: int = 0
    has_unbounded_loop: bool = False   # a `while` whose trip count is
    #   not static: its body is counted ONCE (lower bound)
    dots: list = dataclasses.field(default_factory=list)

    @property
    def dot_macs(self) -> int:
        return self.int_dot_macs + self.float_dot_macs

    @property
    def total_flops(self) -> int:
        return self.dot_flops + self.elementwise_flops

    @property
    def scan_once_flops(self) -> int:
        return self.scan_once_dot_flops + self.scan_once_elementwise_flops

    def to_dict(self) -> dict:
        return {
            "dot_flops": self.dot_flops,
            "dot_macs": self.dot_macs,
            "int_dot_macs": self.int_dot_macs,
            "float_dot_macs": self.float_dot_macs,
            "elementwise_flops": self.elementwise_flops,
            "total_flops": self.total_flops,
            "scan_once_flops": self.scan_once_flops,
            "io_bytes": self.io_bytes,
            "has_unbounded_loop": self.has_unbounded_loop,
        }


def _aval_bytes(aval) -> int:
    shape = getattr(aval, "shape", None)
    dtype = getattr(aval, "dtype", None)
    if shape is None or dtype is None:
        return 0
    return int(math.prod(shape)) * np.dtype(dtype).itemsize


def _dot_cost(eqn, mult: int) -> DotRecord:
    (lc, rc), (lb, _rb) = eqn.params["dimension_numbers"]
    lhs, rhs = (v.aval for v in eqn.invars[:2])
    contract = math.prod(lhs.shape[i] for i in lc)
    batch = math.prod(lhs.shape[i] for i in lb)
    lhs_free = math.prod(lhs.shape[i] for i in range(len(lhs.shape))
                         if i not in lc and i not in lb)
    r_used = set(rc) | set(_rb)
    rhs_free = math.prod(rhs.shape[i] for i in range(len(rhs.shape))
                         if i not in r_used)
    macs = batch * lhs_free * rhs_free * contract * mult
    out_dtype = str(eqn.outvars[0].aval.dtype)
    return DotRecord(tuple(lhs.shape), tuple(rhs.shape),
                     str(lhs.dtype), str(rhs.dtype), out_dtype,
                     flops=2 * macs, macs=macs)


def _iter_subjaxprs(eqn):
    for key in _SUBJAXPR_PARAMS:
        val = eqn.params.get(key)
        if val is None:
            continue
        vals = val if isinstance(val, (tuple, list)) else [val]
        for v in vals:
            if isinstance(v, jax.core.ClosedJaxpr):
                yield v.jaxpr
            elif isinstance(v, jax.core.Jaxpr):
                yield v
    # catch-all for params not in the known list (e.g. custom prims)
    for key, val in eqn.params.items():
        if key in _SUBJAXPR_PARAMS:
            continue
        vals = val if isinstance(val, (tuple, list)) else [val]
        for v in vals:
            if isinstance(v, jax.core.ClosedJaxpr):
                yield v.jaxpr
            elif isinstance(v, jax.core.Jaxpr):
                yield v


def _grid_size(eqn) -> int:
    """Static grid product of a pallas_call, 1 if unavailable."""
    gm = eqn.params.get("grid_mapping")
    grid = getattr(gm, "grid", None) if gm is not None else None
    if grid is None:
        grid = eqn.params.get("grid")
    if not grid:
        return 1
    try:
        return int(math.prod(int(g) for g in grid))
    except (TypeError, ValueError):
        return 1


def _walk(jaxpr, cost: StageCost, mult: int, once_mult: int) -> None:
    for eqn in jaxpr.eqns:
        name = eqn.primitive.name
        if name == "dot_general":
            base = _dot_cost(eqn, 1)
            rec = dataclasses.replace(base, flops=base.flops * mult,
                                      macs=base.macs * mult)
            cost.dots.append(rec)
            cost.dot_flops += rec.flops
            cost.scan_once_dot_flops += base.flops * once_mult
            if "int" in rec.lhs_dtype and "int" in rec.rhs_dtype:
                cost.int_dot_macs += rec.macs
            else:
                cost.float_dot_macs += rec.macs
            continue
        if name in _ELEMENTWISE:
            out = eqn.outvars[0].aval
            n = int(math.prod(getattr(out, "shape", ())))
            cost.elementwise_flops += n * mult
            cost.scan_once_elementwise_flops += n * once_mult
            continue
        sub_mult, sub_once = mult, once_mult
        if name == "scan":
            sub_mult = mult * int(eqn.params.get("length", 1))
        elif name == "while":
            cost.has_unbounded_loop = True
        elif name == "pallas_call":
            grid = _grid_size(eqn)
            sub_mult = mult * grid
            sub_once = once_mult * grid
        for sub in _iter_subjaxprs(eqn):
            _walk(sub, cost, sub_mult, sub_once)


def walk_jaxpr(closed) -> StageCost:
    """Accumulate static costs over a ``ClosedJaxpr`` (or ``Jaxpr``)."""
    jaxpr = closed.jaxpr if isinstance(closed, jax.core.ClosedJaxpr) \
        else closed
    cost = StageCost()
    _walk(jaxpr, cost, 1, 1)
    cost.io_bytes = (sum(_aval_bytes(v.aval) for v in jaxpr.invars)
                     + sum(_aval_bytes(v.aval) for v in jaxpr.outvars))
    return cost


# ---------------------------------------------------------------------------
# Analytic closed-form MACs from ModelConfig — the independent derivation
# ---------------------------------------------------------------------------

def _per_token_linear_macs(cfg) -> int:
    """Projection MACs per token for one full forward through the
    repeated attention/MLP stack (dense counting: one MAC per
    multiply-accumulate, quantization factored in by the caller)."""
    d, hd = cfg.d_model, cfg.head_dim
    q = d * cfg.n_heads * hd
    kv = 2 * d * cfg.n_kv_heads * hd
    o = cfg.n_heads * hd * d
    ffn = 3 * d * cfg.d_ff          # SwiGLU/GeGLU: gate + up + down
    return cfg.n_layers * (q + kv + o + ffn)


def _lm_head_macs(cfg, logit_positions: int) -> int:
    return logit_positions * cfg.d_model * cfg.vocab_size


def analytic_macs(cfg, tokens: int, kv_len: int, logit_positions: int,
                  quantized: bool) -> dict:
    """Closed-form per-dispatch MACs for a stage that runs ``tokens``
    tokens, attends over a padded ``kv_len`` context, and projects
    ``logit_positions`` positions through the LM head.

    The nibble plane-concat contract doubles the *integer* contraction
    length of every projection (lo/hi planes along K), so quantized
    stages report 2x linear MACs — that factor is the paper's
    W/4-cycles-per-operand trade made visible in the MAC count."""
    linear = tokens * _per_token_linear_macs(cfg)
    head = _lm_head_macs(cfg, logit_positions)
    attn = (tokens * kv_len * cfg.n_heads * cfg.head_dim * 2
            * cfg.n_layers)
    weight_factor = 2 if quantized else 1
    return {
        "linear_macs": linear * weight_factor,
        "attn_macs": attn,
        "head_macs": head,
        "total_macs": linear * weight_factor + attn + head,
    }


def cycle_bridge(macs: int, arch: str = "nibble_precompute",
                 width: int = 8) -> int:
    """MACs -> multiplier cycles via the paper's Table 2 model: each
    MAC streams one operand through the multiplier at
    ``cycles_per_operand(arch, width)`` cycles."""
    return macs * cycles_per_operand(arch, width)
