"""Fault tolerance: heartbeats, straggler detection, elastic re-mesh plan.

On a real multi-pod deployment these hooks wire into the cluster
manager; in this container they are driven by the trainer loop and unit
tests with simulated clocks.  The *logic* — what to detect, when to
checkpoint-restart, how to rebalance — is the deliverable:

* ``HeartbeatMonitor``   — per-host step heartbeats; a host silent for
  ``timeout_s`` is declared dead → restart-from-checkpoint decision.
* ``StragglerDetector``  — EWMA of per-host step times; hosts slower
  than ``threshold ×`` the fleet median get flagged; the mitigation is
  microbatch rebalancing (move grad-accum steps off the slow host) and,
  if persistent, eviction (treated as failure → elastic re-mesh).
* ``plan_elastic_mesh``  — given surviving host count, pick the largest
  valid (data, model) mesh ≤ survivors and the batch re-sharding plan;
  restore then proceeds from the last checkpoint on the new mesh.
"""

from __future__ import annotations

import dataclasses
import math

__all__ = ["HeartbeatMonitor", "StragglerDetector", "plan_elastic_mesh",
           "ElasticPlan"]


class HeartbeatMonitor:
    def __init__(self, n_hosts: int, timeout_s: float = 60.0):
        self.n_hosts = n_hosts
        self.timeout_s = timeout_s
        self.last_beat: dict[int, float] = {}

    def beat(self, host_id: int, now: float):
        self.last_beat[host_id] = now

    def dead_hosts(self, now: float) -> list[int]:
        return [h for h in range(self.n_hosts)
                if now - self.last_beat.get(h, -math.inf) > self.timeout_s]

    def healthy(self, now: float) -> bool:
        return not self.dead_hosts(now)


class StragglerDetector:
    """EWMA step-time tracking with median-relative flagging."""

    def __init__(self, n_hosts: int, threshold: float = 1.5,
                 alpha: float = 0.2, patience: int = 3):
        self.n_hosts = n_hosts
        self.threshold = threshold
        self.alpha = alpha
        self.patience = patience
        self.ewma: dict[int, float] = {}
        self.strikes: dict[int, int] = {}

    def record(self, host_id: int, step_time_s: float):
        prev = self.ewma.get(host_id)
        self.ewma[host_id] = (step_time_s if prev is None
                              else self.alpha * step_time_s
                              + (1 - self.alpha) * prev)

    def _median(self) -> float:
        vals = sorted(self.ewma.values())
        return vals[len(vals) // 2] if vals else 0.0

    def stragglers(self) -> list[int]:
        med = self._median()
        if med <= 0:
            return []
        out = []
        for h, t in self.ewma.items():
            if t > self.threshold * med:
                self.strikes[h] = self.strikes.get(h, 0) + 1
                if self.strikes[h] >= self.patience:
                    out.append(h)
            else:
                self.strikes[h] = 0
        return out

    def rebalance_microbatches(self, total_micro: int) -> dict[int, int]:
        """Assign grad-accum microbatches inversely to EWMA step time."""
        if not self.ewma:
            return {}
        inv = {h: 1.0 / max(t, 1e-9) for h, t in self.ewma.items()}
        z = sum(inv.values())
        raw = {h: total_micro * v / z for h, v in inv.items()}
        out = {h: max(1, int(round(r))) for h, r in raw.items()}
        # fix rounding drift deterministically (fastest hosts absorb it)
        drift = total_micro - sum(out.values())
        for h in sorted(out, key=lambda h: -inv[h]):
            if drift == 0:
                break
            out[h] += 1 if drift > 0 else -1
            drift += -1 if drift > 0 else 1
        return out


@dataclasses.dataclass(frozen=True)
class ElasticPlan:
    data_axis: int
    model_axis: int
    hosts_used: int
    global_batch: int


def plan_elastic_mesh(surviving_hosts: int, chips_per_host: int,
                      model_axis: int, global_batch: int) -> ElasticPlan:
    """Largest power-of-two data axis that the survivors support, with
    the model (TP) axis preserved — TP degree is a model property, DP
    shrinks.  The global batch is kept if divisible, else halved until
    it divides the new data axis (documented optimizer-scale caveat)."""
    chips = surviving_hosts * chips_per_host
    if chips < model_axis:
        raise ValueError(
            f"survivors ({chips} chips) cannot hold model axis "
            f"{model_axis}; restore requires re-sharding to smaller TP")
    data = 1 << int(math.log2(chips // model_axis))
    batch = global_batch
    while batch % data:
        batch //= 2
    hosts_used = data * model_axis // chips_per_host
    return ElasticPlan(data, model_axis, hosts_used, batch)
