from repro.runtime.fault_tolerance import (  # noqa: F401
    ElasticPlan, HeartbeatMonitor, StragglerDetector, plan_elastic_mesh)
