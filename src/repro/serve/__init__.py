from repro.serve.engine import (  # noqa: F401
    Engine,
    Request,
    ServeConfig,
    make_serve_step,
)
from repro.serve.workload import run_timed_workload  # noqa: F401
