from repro.serve.engine import (  # noqa: F401
    Engine,
    Request,
    ServeConfig,
    make_serve_step,
)
from repro.serve.paging import (  # noqa: F401
    PageAllocator,
    PageTable,
    PrefixCache,
    hash_chunks,
    pages_needed,
)
from repro.serve.router import Router  # noqa: F401
from repro.serve.workload import run_timed_workload  # noqa: F401
