"""Timed request-level workload driver for the serve engine.

One harness shared by the serving launcher (``repro.launch.serve
--workload uniform|staggered``) and the serving benchmark
(``benchmarks/serve_bench.py``), so the warmup protocol and the latency
definitions cannot drift apart:

* warmup: one short request end-to-end (compiles prefill + decode
  chunk), timed separately as ``compile_s``, then ``engine.reset()``;
* request latency = arrival → completion; ttft = arrival → first token;
* ``tok_per_s`` counts generated tokens over the timed ``run()`` wall
  clock (for staggered workloads that includes arrival gaps — the
  continuous-batching question is how much refill recovers of them).
"""

from __future__ import annotations

import time

import numpy as np

__all__ = ["run_timed_workload"]


def run_timed_workload(engine, vocab_size: int, *, requests: int,
                       prompt_budget: int, new_tokens: int,
                       stagger_s: float = 0.0, seed: int = 0) -> dict:
    """Submit ``requests`` random prompts (lengths in
    [prompt_budget/2, prompt_budget], arrivals spaced ``stagger_s``
    apart), drain the engine, and return throughput/latency stats."""
    rng = np.random.default_rng(seed)
    lens = rng.integers(max(2, prompt_budget // 2), prompt_budget + 1,
                        requests)

    # warmup: trigger every compilation outside the timed window
    engine.submit(rng.integers(0, vocab_size, int(lens[0])), 2)
    t0 = time.perf_counter()
    engine.run()
    compile_s = time.perf_counter() - t0
    engine.reset()

    ids = [engine.submit(rng.integers(0, vocab_size, int(n)), new_tokens,
                         arrival=i * stagger_s)
           for i, n in enumerate(lens)]
    t0 = time.perf_counter()
    done = engine.run()
    wall = time.perf_counter() - t0

    toks = sum(len(done[i].tokens) for i in ids)
    lat = np.asarray([done[i].t_done - done[i].arrival for i in ids])
    ttft = np.asarray([done[i].t_first - done[i].arrival for i in ids])
    return {
        "requests": requests,
        "slots": engine.scfg.batch,
        "prompt_budget": prompt_budget,
        "new_tokens": new_tokens,
        "tokens": toks,
        "wall_s": round(wall, 3),
        "tok_per_s": round(toks / wall, 1),
        "req_p50_ms": round(float(np.percentile(lat, 50)) * 1e3, 1),
        "req_p99_ms": round(float(np.percentile(lat, 99)) * 1e3, 1),
        "ttft_p50_ms": round(float(np.percentile(ttft, 50)) * 1e3, 1),
        "compile_s": round(compile_s, 2),
        "compile_counts": engine.compile_counts,
    }
