"""Timed request-level workload driver for the serve engine.

One harness shared by the serving launcher (``repro.launch.serve
--workload uniform|staggered``) and the serving benchmark
(``benchmarks/serve_bench.py``), so the warmup protocol and the latency
definitions cannot drift apart:

* warmup: one short request end-to-end (compiles prefill + decode
  chunk), timed separately as ``compile_s``, then ``engine.reset()``;
* request latency = arrival → completion; ttft = arrival → first token;
* ``tok_per_s`` counts generated tokens over the timed ``run()`` wall
  clock (for staggered workloads that includes arrival gaps — the
  continuous-batching question is how much refill recovers of them);
* ``cache_kb_per_req`` is the mean per-request KV-cache reservation
  (dense: the full ``max_len`` slab; paged: allocated pages ×
  page_size) times the engine's per-token cache bytes — the HBM-
  footprint axis the paged cache exists to shrink;
* ``priority_mix`` marks that fraction of requests priority 1 (rest 0)
  and splits the latency percentiles per class, so the priority
  scheduler's effect is visible in one run;
* ``shared_prefix`` gives that fraction of requests a common "system
  prompt" head of ``prompt_budget // 2`` tokens (the rest of each
  prompt stays random) — the workload prefix caching exists for; the
  engine's ``prefix_hit_rate`` (prompt tokens served from cached pages)
  and ``prefill_tokens`` (tokens actually run through prefill) ride
  along in the stats so the cache's effect is measurable;
* ``arrival_mode="bursty"`` replaces the even ``stagger_s`` spacing
  with a Poisson-burst process (exponential inter-burst gaps at the
  same mean load, geometric burst sizes, simultaneous arrivals inside
  a burst) and draws prompt lengths from a clipped Pareto heavy tail
  instead of the uniform band — the tail-latency stressor the p99
  TTFT/ITL columns exist for (bursts queue behind full slots; one
  Pareto-tail prompt monopolises a prefill);
* ITL (inter-token latency) percentiles come from per-token emission
  timestamps (``Request.t_tokens``), pooled across requests —
  speculative decoding moves these: a round emits its accepted run of
  tokens at one instant, then pays a draft+verify gap;
* scheduling counters ride along from ``engine.stats``: ``preemptions``
  (evict-and-resume events), ``occupancy`` (mean fraction of pool pages
  in use per decode chunk — the axis incremental allocation raises) and
  ``concurrency`` (mean admitted requests per chunk — what overcommit
  buys from the same pool), plus ``truncated`` (requests whose
  ``max_new_tokens`` was clamped to the ``max_len`` budget at submit —
  flagged explicitly so a short stream is never misread as early EOS).
"""

from __future__ import annotations

import dataclasses
import time

import numpy as np

__all__ = ["WorkloadDraw", "draw_workload", "run_timed_workload"]


def _pct(a, q) -> float | None:
    """Percentile that survives an empty sample: ``None`` instead of
    numpy's NaN-with-RuntimeWarning.  Per-class latency splits hit this
    whenever a priority class drew zero requests (priority_mix near 0
    or 1 with few requests)."""
    if a is None or len(a) == 0:
        return None
    return float(np.percentile(a, q))


def _ms(x: float | None, digits: int = 1) -> float | None:
    """Seconds → rounded milliseconds, passing ``None`` through."""
    return None if x is None else round(x * 1e3, digits)


@dataclasses.dataclass
class WorkloadDraw:
    """One fully-drawn workload: the pure function of ``(seed, knobs)``
    both the timed driver and the analytic capacity model consume, so
    the simulated arrival/length process can never drift from the one
    the engine is actually driven with."""

    lens: np.ndarray            # drawn prompt lengths (pre shared-head)
    arrivals: np.ndarray        # arrival offsets, seconds from t=0
    prios: np.ndarray           # 0/1 priority class per request
    shared: np.ndarray          # bool: carries the shared system head
    sys_len: int                # shared system-prompt head length
    prompts: list | None        # token arrays (None when not drawn)

    @property
    def eff_lens(self) -> np.ndarray:
        """Effective prompt lengths as submitted: a shared-head prompt
        is re-drawn to at least ``sys_len + 1`` tokens."""
        return np.where(self.shared,
                        np.maximum(self.lens, self.sys_len + 1),
                        self.lens).astype(np.int64)

    def summary(self, new_tokens: int) -> dict:
        """Workload-shape summary for result rows: the realized
        length/arrival distribution behind the percentile columns."""
        eff = self.eff_lens
        span = float(self.arrivals.max() - self.arrivals.min())
        return {
            "prompt_len_mean": round(float(eff.mean()), 2),
            "prompt_len_max": int(eff.max()),
            "prompt_tokens": int(eff.sum()),
            "decode_tokens": int(len(eff) * new_tokens),
            "arrival_span_s": round(span, 3),
            "peak_burst": int(np.max(np.unique(self.arrivals,
                                               return_counts=True)[1])),
        }


def _validate_workload(requests: int, prompt_budget: int,
                       new_tokens: int, priority_mix: float,
                       shared_prefix: float, arrival_mode: str) -> None:
    # validate up front: requests == 0 crashes the percentile math and
    # prompt_budget < 2 turns the rng.integers bounds inside out
    # (low = max(2, budget // 2) would exceed high = budget + 1)
    if requests < 1:
        raise ValueError(f"requests must be >= 1, got {requests}")
    if prompt_budget < 2:
        raise ValueError(f"prompt_budget must be >= 2 (prompt lengths are "
                         f"drawn from [max(2, prompt_budget // 2), "
                         f"prompt_budget]), got {prompt_budget}")
    if new_tokens < 1:
        raise ValueError(f"new_tokens must be >= 1, got {new_tokens}")
    if not 0.0 <= priority_mix <= 1.0:
        raise ValueError(f"priority_mix must be in [0, 1], got "
                         f"{priority_mix}")
    if not 0.0 <= shared_prefix <= 1.0:
        raise ValueError(f"shared_prefix must be in [0, 1], got "
                         f"{shared_prefix}")
    if arrival_mode not in ("uniform", "bursty"):
        raise ValueError(f"arrival_mode must be 'uniform' or 'bursty', "
                         f"got {arrival_mode!r}")


def draw_workload(vocab_size: int, *, requests: int, prompt_budget: int,
                  new_tokens: int = 1, stagger_s: float = 0.0,
                  seed: int = 0, priority_mix: float = 0.0,
                  shared_prefix: float = 0.0,
                  arrival_mode: str = "uniform",
                  materialize: bool = True) -> WorkloadDraw:
    """Draw the whole workload — lengths, arrivals, priorities, shared
    mask and (when ``materialize``) the prompt token arrays — from one
    seeded rng.  ``arrival_mode="uniform"`` spaces arrivals
    ``stagger_s`` apart with lengths uniform in
    [prompt_budget/2, prompt_budget]; ``"bursty"`` keeps the same mean
    offered load but clusters arrivals into Poisson bursts and draws
    lengths from a clipped Pareto(1.5) heavy tail.

    The draw order is frozen: lens → arrivals → prios → shared mask →
    system head → prompt bodies.  ``materialize=False`` (the capacity
    model) stops before the prompt bodies — everything the scheduler
    simulation needs is already drawn, bit-identical to the driver's
    stream."""
    _validate_workload(requests, prompt_budget, new_tokens, priority_mix,
                       shared_prefix, arrival_mode)
    rng = np.random.default_rng(seed)
    if arrival_mode == "uniform":
        lens = rng.integers(max(2, prompt_budget // 2), prompt_budget + 1,
                            requests)
        arrivals = np.arange(requests) * stagger_s
    else:
        # heavy-tail lengths: Pareto(1.5) scaled so the typical prompt
        # sits near prompt_budget/2 but a fat tail pins the budget cap
        lens = np.clip(
            (2 + rng.pareto(1.5, requests) * (prompt_budget // 4))
            .astype(np.int64), 2, prompt_budget)
        # Poisson bursts at the same mean load as uniform spacing:
        # burst sizes ~ geometric (mean _BURST_MEAN, simultaneous
        # arrivals inside a burst), exponential inter-burst gaps with
        # mean burst_size × stagger_s
        _BURST_MEAN = 3
        arrivals = np.zeros(requests)
        t, i = 0.0, 0
        while i < requests:
            size = min(int(rng.geometric(1.0 / _BURST_MEAN)),
                       requests - i)
            arrivals[i:i + size] = t
            i += size
            t += rng.exponential(_BURST_MEAN * stagger_s) \
                if stagger_s > 0 else 0.0
    prios = (rng.random(requests) < priority_mix).astype(np.int32)
    shared = rng.random(requests) < shared_prefix
    sys_prompt = rng.integers(0, vocab_size, prompt_budget // 2)

    def make_prompt(i):
        n = int(lens[i])
        if not shared[i]:
            return rng.integers(0, vocab_size, n)
        # shared head + ≥1 private token so every prompt stays distinct
        # from the bare system prompt (lengths are re-drawn up to the
        # budget, never past it)
        n = max(n, sys_prompt.size + 1)
        tail = rng.integers(0, vocab_size, n - sys_prompt.size)
        return np.concatenate([sys_prompt, tail])

    prompts = ([make_prompt(i) for i in range(requests)]
               if materialize else None)
    return WorkloadDraw(lens=lens, arrivals=arrivals, prios=prios,
                        shared=shared, sys_len=int(sys_prompt.size),
                        prompts=prompts)


def run_timed_workload(engine, vocab_size: int, *, requests: int,
                       prompt_budget: int, new_tokens: int,
                       stagger_s: float = 0.0, seed: int = 0,
                       priority_mix: float = 0.0,
                       shared_prefix: float = 0.0,
                       arrival_mode: str = "uniform",
                       collect_streams: bool = False) -> dict:
    """Submit ``requests`` random prompts and drain the engine; returns
    throughput/latency stats.  The workload itself comes from
    :func:`draw_workload` (shared with ``repro.capacity``'s analytic
    predictor); ``shared_prefix`` requests begin with one fixed
    system-prompt head of ``prompt_budget // 2`` tokens."""
    # draw every prompt BEFORE warmup, so the timed workload is a pure
    # function of (seed, workload knobs) — the warmup below submits a
    # replica-count-dependent number of requests from its own rng, and
    # must not shift the main stream (a dp=2 fleet and a solo engine
    # must see byte-identical prompts for the launcher's --verify)
    draw = draw_workload(vocab_size, requests=requests,
                         prompt_budget=prompt_budget,
                         new_tokens=new_tokens, stagger_s=stagger_s,
                         seed=seed, priority_mix=priority_mix,
                         shared_prefix=shared_prefix,
                         arrival_mode=arrival_mode)
    lens, arrivals, prios = draw.lens, draw.arrivals, draw.prios
    prompts = draw.prompts

    # warmup: trigger every compilation outside the timed window — one
    # request per engine replica (a Router's JSQ placement spreads the
    # batch one-per-replica over an idle fleet, so every replica
    # compiles its programs here, not inside the timed run)
    n_warm = len(getattr(engine, "replicas", ())) or 1
    wrng = np.random.default_rng(np.random.SeedSequence([seed, 0xC0]))
    for _ in range(n_warm):
        engine.submit(wrng.integers(0, vocab_size, int(lens[0])), 2)
    t0 = time.perf_counter()
    engine.run()
    compile_s = time.perf_counter() - t0
    engine.reset()           # also empties the prefix index: the timed
    #                          run starts from a cold cache

    ids = [engine.submit(prompts[i], new_tokens,
                         arrival=float(arrivals[i]),
                         priority=int(prios[i]))
           for i in range(requests)]
    t0 = time.perf_counter()
    done = engine.run()
    wall = time.perf_counter() - t0

    toks = sum(len(done[i].tokens) for i in ids)
    lat = np.asarray([done[i].t_done - done[i].arrival for i in ids])
    ttft = np.asarray([done[i].t_first - done[i].arrival for i in ids])
    cache_rows = np.asarray([done[i].cache_rows for i in ids])
    # inter-token latency: gaps between consecutive emission stamps,
    # pooled across requests.  A spec round emits its accepted run at
    # one instant (zero gaps) then pays the draft+verify gap — the ITL
    # distribution is how that trade shows up.
    itl = np.concatenate(
        [np.diff(done[i].t_tokens) for i in ids
         if len(done[i].t_tokens) >= 2]) \
        if any(len(done[i].t_tokens) >= 2 for i in ids) \
        else np.zeros(1)
    stats = engine.stats
    out = {
        "requests": requests,
        "slots": engine.scfg.batch,
        "prompt_budget": prompt_budget,
        "new_tokens": new_tokens,
        "arrival_mode": arrival_mode,
        "tokens": toks,
        "wall_s": round(wall, 3),
        "tok_per_s": round(toks / wall, 1),
        "req_p50_ms": _ms(_pct(lat, 50)),
        "req_p99_ms": _ms(_pct(lat, 99)),
        "ttft_p50_ms": _ms(_pct(ttft, 50)),
        "ttft_p99_ms": _ms(_pct(ttft, 99)),
        "itl_p50_ms": _ms(_pct(itl, 50), 2),
        "itl_p99_ms": _ms(_pct(itl, 99), 2),
        "cache_kb_per_req": round(float(cache_rows.mean())
                                  * engine.cache_token_bytes / 1024.0, 1),
        "preemptions": stats["preemptions"],
        "occupancy": round(stats["occupancy"], 3),
        "concurrency": round(stats["concurrency"], 2),
        "pool_pages": stats["pool_pages"],
        "prefix_hit_rate": round(stats["prefix_hit_rate"], 3),
        "prefill_tokens": stats["prefill_tokens"],
        "spec": bool(engine.scfg.spec_decode),
        "acceptance_rate": round(stats["acceptance_rate"], 3),
        "tokens_per_step": round(stats["tokens_per_step"], 3),
        "spec_rollback_pages": stats["spec_rollback_pages"],
        # tail-latency mechanisms (all zero with them off): wave/chunk
        # dispatch counts, host-tier swap traffic, and the decode steps
        # a page-copy resume did not have to replay
        "prefill_waves": stats.get("prefill_waves", 0),
        "decode_chunks": stats.get("decode_chunks", 0),
        "swap_out": stats.get("swap_out", 0),
        "swap_in": stats.get("swap_in", 0),
        "replay_steps_saved": stats.get("replay_steps_saved", 0),
        "prefix_cold_hits": stats.get("prefix_cold_hits", 0),
        "truncated": int(sum(done[i].truncated for i in ids)),
        "compile_s": round(compile_s, 2),
        "compile_counts": engine.compile_counts,
        # topology: 1 / [1, 1] / 1 for a plain single-device engine, so
        # every result row names the hardware it ran on
        "device_count": int(getattr(engine, "device_count", 1)),
        "mesh_shape": list(getattr(engine, "mesh_shape", (1, 1))),
        "dp_replicas": stats.get("dp_replicas", 1),
        # realized workload shape (lengths/arrivals actually drawn) —
        # the capacity model's input, recorded so every result row
        # carries the distribution its percentiles were measured under
        "workload_shape": {
            "seed": seed,
            "stagger_s": stagger_s,
            "priority_mix": priority_mix,
            "shared_prefix": shared_prefix,
            **draw.summary(new_tokens),
        },
    }
    if priority_mix > 0.0:
        # always emit both class keys when a split was requested — an
        # empty class (mix rounded to all-hi or all-lo) reports None
        # rather than vanishing, so downstream readers see a stable
        # schema
        for cls, name in ((1, "hi"), (0, "lo")):
            out[f"{name}_req_p50_ms"] = _ms(_pct(lat[prios == cls], 50))
    if "per_replica" in stats:
        out["per_replica"] = stats["per_replica"]
    if collect_streams:
        # keyed by submission index, not engine id — ids are topology-
        # dependent (warmup consumes a replica-count worth of them), and
        # --verify compares streams across topologies
        out["streams"] = {n: list(done[i].tokens)
                          for n, i in enumerate(ids)}
    return out
