"""Timed request-level workload driver for the serve engine.

One harness shared by the serving launcher (``repro.launch.serve
--workload uniform|staggered``) and the serving benchmark
(``benchmarks/serve_bench.py``), so the warmup protocol and the latency
definitions cannot drift apart:

* warmup: one short request end-to-end (compiles prefill + decode
  chunk), timed separately as ``compile_s``, then ``engine.reset()``;
* request latency = arrival → completion; ttft = arrival → first token;
* ``tok_per_s`` counts generated tokens over the timed ``run()`` wall
  clock (for staggered workloads that includes arrival gaps — the
  continuous-batching question is how much refill recovers of them);
* ``cache_kb_per_req`` is the mean per-request KV-cache reservation
  (dense: the full ``max_len`` slab; paged: allocated pages ×
  page_size) times the engine's per-token cache bytes — the HBM-
  footprint axis the paged cache exists to shrink;
* ``priority_mix`` marks that fraction of requests priority 1 (rest 0)
  and splits the latency percentiles per class, so the priority
  scheduler's effect is visible in one run;
* ``shared_prefix`` gives that fraction of requests a common "system
  prompt" head of ``prompt_budget // 2`` tokens (the rest of each
  prompt stays random) — the workload prefix caching exists for; the
  engine's ``prefix_hit_rate`` (prompt tokens served from cached pages)
  and ``prefill_tokens`` (tokens actually run through prefill) ride
  along in the stats so the cache's effect is measurable;
* scheduling counters ride along from ``engine.stats``: ``preemptions``
  (evict-and-resume events), ``occupancy`` (mean fraction of pool pages
  in use per decode chunk — the axis incremental allocation raises) and
  ``concurrency`` (mean admitted requests per chunk — what overcommit
  buys from the same pool), plus ``truncated`` (requests whose
  ``max_new_tokens`` was clamped to the ``max_len`` budget at submit —
  flagged explicitly so a short stream is never misread as early EOS).
"""

from __future__ import annotations

import time

import numpy as np

__all__ = ["run_timed_workload"]


def run_timed_workload(engine, vocab_size: int, *, requests: int,
                       prompt_budget: int, new_tokens: int,
                       stagger_s: float = 0.0, seed: int = 0,
                       priority_mix: float = 0.0,
                       shared_prefix: float = 0.0) -> dict:
    """Submit ``requests`` random prompts (lengths in
    [prompt_budget/2, prompt_budget], arrivals spaced ``stagger_s``
    apart), drain the engine, and return throughput/latency stats.
    ``shared_prefix`` requests begin with one fixed system-prompt head
    of ``prompt_budget // 2`` tokens."""
    # validate up front: requests == 0 crashes the percentile math below
    # and prompt_budget < 2 turns the rng.integers bounds inside out
    # (low = max(2, budget // 2) would exceed high = budget + 1)
    if requests < 1:
        raise ValueError(f"requests must be >= 1, got {requests}")
    if prompt_budget < 2:
        raise ValueError(f"prompt_budget must be >= 2 (prompt lengths are "
                         f"drawn from [max(2, prompt_budget // 2), "
                         f"prompt_budget]), got {prompt_budget}")
    if new_tokens < 1:
        raise ValueError(f"new_tokens must be >= 1, got {new_tokens}")
    if not 0.0 <= priority_mix <= 1.0:
        raise ValueError(f"priority_mix must be in [0, 1], got "
                         f"{priority_mix}")
    if not 0.0 <= shared_prefix <= 1.0:
        raise ValueError(f"shared_prefix must be in [0, 1], got "
                         f"{shared_prefix}")
    rng = np.random.default_rng(seed)
    lens = rng.integers(max(2, prompt_budget // 2), prompt_budget + 1,
                        requests)
    prios = (rng.random(requests) < priority_mix).astype(np.int32)
    shared = rng.random(requests) < shared_prefix
    sys_prompt = rng.integers(0, vocab_size, prompt_budget // 2)

    def make_prompt(i):
        n = int(lens[i])
        if not shared[i]:
            return rng.integers(0, vocab_size, n)
        # shared head + ≥1 private token so every prompt stays distinct
        # from the bare system prompt (lengths are re-drawn up to the
        # budget, never past it)
        n = max(n, sys_prompt.size + 1)
        tail = rng.integers(0, vocab_size, n - sys_prompt.size)
        return np.concatenate([sys_prompt, tail])

    # warmup: trigger every compilation outside the timed window
    engine.submit(rng.integers(0, vocab_size, int(lens[0])), 2)
    t0 = time.perf_counter()
    engine.run()
    compile_s = time.perf_counter() - t0
    engine.reset()           # also empties the prefix index: the timed
    #                          run starts from a cold cache

    ids = [engine.submit(make_prompt(i), new_tokens,
                         arrival=i * stagger_s, priority=int(prios[i]))
           for i in range(requests)]
    t0 = time.perf_counter()
    done = engine.run()
    wall = time.perf_counter() - t0

    toks = sum(len(done[i].tokens) for i in ids)
    lat = np.asarray([done[i].t_done - done[i].arrival for i in ids])
    ttft = np.asarray([done[i].t_first - done[i].arrival for i in ids])
    cache_rows = np.asarray([done[i].cache_rows for i in ids])
    stats = engine.stats
    out = {
        "requests": requests,
        "slots": engine.scfg.batch,
        "prompt_budget": prompt_budget,
        "new_tokens": new_tokens,
        "tokens": toks,
        "wall_s": round(wall, 3),
        "tok_per_s": round(toks / wall, 1),
        "req_p50_ms": round(float(np.percentile(lat, 50)) * 1e3, 1),
        "req_p99_ms": round(float(np.percentile(lat, 99)) * 1e3, 1),
        "ttft_p50_ms": round(float(np.percentile(ttft, 50)) * 1e3, 1),
        "cache_kb_per_req": round(float(cache_rows.mean())
                                  * engine.cache_token_bytes / 1024.0, 1),
        "preemptions": stats["preemptions"],
        "occupancy": round(stats["occupancy"], 3),
        "concurrency": round(stats["concurrency"], 2),
        "pool_pages": stats["pool_pages"],
        "prefix_hit_rate": round(stats["prefix_hit_rate"], 3),
        "prefill_tokens": stats["prefill_tokens"],
        "truncated": int(sum(done[i].truncated for i in ids)),
        "compile_s": round(compile_s, 2),
        "compile_counts": engine.compile_counts,
    }
    if priority_mix > 0.0 and prios.any() and not prios.all():
        for cls, name in ((1, "hi"), (0, "lo")):
            sel = lat[prios == cls]
            out[f"{name}_req_p50_ms"] = round(
                float(np.percentile(sel, 50)) * 1e3, 1)
    return out
