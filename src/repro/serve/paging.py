"""Paged KV cache bookkeeping: a host-side block allocator + page table.

The paper replaces one monolithic wide multiplier with fixed-width
nibble units composed through cheap indexing; the serving analogue
replaces the dense per-slot ``max_len`` KV slab with fixed-size *pages*
composed through a page table.  The storage unit is small, uniform and
reused, so cache capacity scales with *live* tokens instead of the
worst-case request shape.

Device-side layout (built in ``models.attention`` / ``models.transformer``):

* every attention layer's K/V (or MLA latent) lives in a shared
  ``(num_pages, page_size, ...)`` pool;
* one ``(batch, max_pages)`` int32 page table maps each decode slot's
  logical positions to pool pages: row ``pos`` of slot ``b`` lives at
  ``(table[b, pos // page_size], pos % page_size)``.

Page ids are **data, not shape** — one compiled program serves every
allocation pattern, so slot refill and page recycling never recompile.

This module is the *host* side: a free-list allocator with admission
backpressure (``alloc`` returns ``None`` instead of OOMing) and the
mutable table mirror the engine ships to the device each decode chunk.
Page 0 is reserved as the **trash page**: idle slots' table rows point
at it, so their frozen idempotent cache writes land somewhere harmless
instead of corrupting a recycled page.
"""

from __future__ import annotations

import numpy as np

__all__ = ["PageAllocator", "PageTable", "pages_needed"]


def pages_needed(rows: int, page_size: int) -> int:
    """Pages required to hold ``rows`` cache rows."""
    if rows <= 0:
        return 0
    return -(-rows // page_size)


class PageAllocator:
    """LIFO free-list over a fixed pool of ``num_pages`` pages.

    The first ``reserved`` page ids are never handed out (the engine
    uses page 0 as the trash page).  ``alloc`` is all-or-nothing and
    returns ``None`` when the pool cannot satisfy the request — the
    caller defers admission (backpressure) instead of overcommitting.
    Double-free and foreign-page frees raise: a page leak in the engine
    is a correctness bug (recycled pages carry live KV rows), so the
    allocator is strict enough for tests to assert ``in_use == 0``.
    """

    def __init__(self, num_pages: int, reserved: int = 1):
        if num_pages <= reserved:
            raise ValueError(f"num_pages {num_pages} must exceed the "
                             f"{reserved} reserved page(s)")
        self.num_pages = num_pages
        self.reserved = reserved
        # LIFO: freshly freed pages are reused first (their rows are the
        # most likely to still be resident in any cache hierarchy)
        self._free: list[int] = list(range(num_pages - 1, reserved - 1, -1))
        self._live: set[int] = set()

    @property
    def capacity(self) -> int:
        """Allocatable pages (pool minus reserved)."""
        return self.num_pages - self.reserved

    @property
    def available(self) -> int:
        return len(self._free)

    @property
    def in_use(self) -> int:
        return len(self._live)

    def can_alloc(self, n: int) -> bool:
        return n <= len(self._free)

    def alloc(self, n: int) -> list[int] | None:
        """Pop ``n`` pages, or ``None`` (backpressure) if unavailable."""
        if n < 0:
            raise ValueError(f"cannot allocate {n} pages")
        if n > len(self._free):
            return None
        pages = [self._free.pop() for _ in range(n)]
        self._live.update(pages)
        return pages

    def free(self, pages) -> None:
        """Return pages to the pool.  Raises on double-free or on a page
        the allocator never handed out."""
        pages = list(pages)
        bad = [p for p in pages if p not in self._live]
        if bad:
            raise ValueError(f"freeing pages not currently allocated: {bad}")
        for p in pages:
            self._live.remove(p)
            self._free.append(p)


class PageTable:
    """Mutable host mirror of the ``(batch, max_pages)`` device table.

    Every entry defaults to ``trash_page``; ``assign`` fills a slot's
    row prefix with its allocated pages (positions past the prefix —
    and every position of an idle slot — resolve to the trash page,
    where stale idempotent decode writes are harmless).
    """

    def __init__(self, batch: int, max_pages: int, trash_page: int = 0):
        self.batch = batch
        self.max_pages = max_pages
        self.trash_page = trash_page
        self.table = np.full((batch, max_pages), trash_page, np.int32)

    def assign(self, slot: int, pages) -> None:
        pages = np.asarray(pages, np.int32)
        if pages.size > self.max_pages:
            raise ValueError(f"{pages.size} pages exceed the per-slot "
                             f"maximum of {self.max_pages}")
        self.table[slot] = self.trash_page
        self.table[slot, :pages.size] = pages

    def clear(self, slot: int) -> None:
        self.table[slot] = self.trash_page

    def row(self, slot: int) -> np.ndarray:
        return self.table[slot].copy()

    def asarray(self) -> np.ndarray:
        return self.table
