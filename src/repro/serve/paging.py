"""Paged KV cache bookkeeping: a host-side block allocator + page table.

The paper replaces one monolithic wide multiplier with fixed-width
nibble units composed through cheap indexing; the serving analogue
replaces the dense per-slot ``max_len`` KV slab with fixed-size *pages*
composed through a page table.  The storage unit is small, uniform and
reused, so cache capacity scales with *live* tokens instead of the
worst-case request shape.

Device-side layout (built in ``models.attention`` / ``models.transformer``):

* every attention layer's K/V (or MLA latent) lives in a shared
  ``(num_pages, page_size, ...)`` pool;
* one ``(batch, max_pages)`` int32 page table maps each decode slot's
  logical positions to pool pages: row ``pos`` of slot ``b`` lives at
  ``(table[b, pos // page_size], pos % page_size)``.

Page ids are **data, not shape** — one compiled program serves every
allocation pattern, so slot refill, mid-stream page growth
(``PageTable.extend``) and page recycling never recompile.

This module is the *host* side: a free-list allocator with admission
backpressure (``alloc`` returns ``None`` instead of OOMing) and the
mutable table mirror the engine ships to the device each decode chunk.
Page 0 is reserved as the **trash page**: idle slots' table rows point
at it, so their frozen idempotent cache writes land somewhere harmless
instead of corrupting a recycled page.

**Reference counting & prefix sharing.**  The paper's core trick is
logic reuse — compute the broadcast operand's scaled multiples once and
reuse them across every vector lane.  Prefix caching applies the same
principle to KV storage: requests sharing a page-aligned prompt prefix
map the *same* read-only pool pages instead of recomputing and storing
identical rows per request.  That makes page ownership plural, so the
allocator counts references: ``alloc`` hands a page out at refcount 1,
``share`` adds a holder, ``free`` *decrements* and recycles the page
only when the count reaches zero.  The refcount rules are:

* a page is **writable only by its sole holder at refcount 1** — the
  engine guarantees shared (prefix) pages are never written by mapping
  them strictly below every holder's first write position, and
  copy-on-writes the partial tail page (duplicate, remap, then write the
  private copy) whenever a request's writes would land on shared rows;
* ``free`` on a page the caller does not hold (refcount already zero →
  the page went back to the free list) raises — the double-decrement
  class stays loud;
* leak detection extends to refcounts: ``in_use`` counts pages with any
  holder, so a drained engine asserts ``in_use == 0`` only after the
  prefix index drops its own references (``PrefixCache.drop``).

``PrefixCache`` is the host-side prefix index: prompt tokens are split
into page-aligned chunks, each chunk keyed by a running hash chain (so a
chunk's key commits to the whole prefix before it), and mapped to the
pool page that holds its KV rows.  The cache holds one reference per
indexed page; cold entries are reclaimed leaf-first in LRU order under
pool pressure (an interior chunk is never dropped before its
descendants, so every cached chain stays contiguous from chunk 0).

Both table classes are strict: double-frees, foreign pages, out-of-range
or reserved page ids, and cross-slot aliasing (outside the declared
shared set) all raise.  A page-table corruption silently aliases one
slot's live KV rows into another's attention window — the worst failure
mode preemption, incremental growth and prefix sharing make easier to
hit — so the bookkeeping refuses instead.
"""

from __future__ import annotations

import hashlib

import numpy as np

__all__ = ["PageAllocator", "PageTable", "PrefixCache", "HostPagePool",
           "pages_needed", "hash_chunks"]


def pages_needed(rows: int, page_size: int) -> int:
    """Pages required to hold ``rows`` cache rows."""
    if rows <= 0:
        return 0
    return -(-rows // page_size)


def hash_chunks(tokens, page_size: int) -> list[bytes]:
    """Hash-chain keys for every *full* page-aligned chunk of ``tokens``
    (the partial tail chunk is never indexed).  Chunk ``j``'s key
    digests chunk ``j-1``'s key plus chunk ``j``'s tokens, so one key
    commits to the entire prefix before it.  Module-level because the
    keys identify *token content*, not any one engine's pool: the serve
    router hashes a prompt once and probes every replica's
    ``PrefixCache.match`` with the same chain (prefix affinity)."""
    tokens = np.asarray(tokens, np.int32).reshape(-1)
    keys, prev = [], b""
    for j in range(tokens.size // page_size):
        chunk = tokens[j * page_size:(j + 1) * page_size]
        prev = hashlib.blake2b(prev + chunk.tobytes(),
                               digest_size=16).digest()
        keys.append(prev)
    return keys


class PageAllocator:
    """Refcounted LIFO free-list over a fixed pool of ``num_pages`` pages.

    The first ``reserved`` page ids are never handed out (the engine
    uses page 0 as the trash page).  ``alloc`` is all-or-nothing and
    returns ``None`` when the pool cannot satisfy the request — the
    caller defers admission (backpressure) or preempts a running slot
    instead of overcommitting the device pool.

    Pages are reference counted so prefix caching can map one page into
    several holders (sharing slots plus the prefix index itself):
    ``alloc`` hands pages out at refcount 1, ``share`` registers an
    extra holder, and ``free`` decrements — a page returns to the free
    list only when its count reaches zero.  Holders that never share
    see the classic alloc/free contract unchanged.

    Double-decrements and foreign-page frees raise: a page leak in the
    engine is a correctness bug (recycled pages carry live KV rows), so
    the allocator is strict enough for tests to assert ``in_use == 0``
    once every holder — including the prefix index — has released its
    references.
    """

    def __init__(self, num_pages: int, reserved: int = 1):
        if num_pages <= reserved:
            raise ValueError(f"num_pages {num_pages} must exceed the "
                             f"{reserved} reserved page(s)")
        self.num_pages = num_pages
        self.reserved = reserved
        # LIFO: freshly freed pages are reused first (their rows are the
        # most likely to still be resident in any cache hierarchy)
        self._free: list[int] = list(range(num_pages - 1, reserved - 1, -1))
        self._refs: dict[int, int] = {}

    @property
    def capacity(self) -> int:
        """Allocatable pages (pool minus reserved)."""
        return self.num_pages - self.reserved

    @property
    def available(self) -> int:
        return len(self._free)

    @property
    def in_use(self) -> int:
        """Pages with at least one holder (shared pages count once)."""
        return len(self._refs)

    def refcount(self, page: int) -> int:
        """Holders of ``page`` (0 = on the free list / never handed out)."""
        return self._refs.get(page, 0)

    def can_alloc(self, n: int) -> bool:
        return n <= len(self._free)

    def alloc(self, n: int) -> list[int] | None:
        """Pop ``n`` pages at refcount 1 each, or ``None``
        (backpressure) if unavailable."""
        if n < 0:
            raise ValueError(f"cannot allocate {n} pages")
        if n > len(self._free):
            return None
        pages = [self._free.pop() for _ in range(n)]
        for p in pages:
            self._refs[p] = 1
        return pages

    def share(self, pages) -> None:
        """Register one extra holder per page (prefix reuse: a new slot
        maps an already-live page read-only, or the prefix index pins a
        freshly written prompt page).  Raises on pages with no current
        holder — only live pages can be shared."""
        pages = list(pages)
        bad = [p for p in pages if p not in self._refs]
        if bad:
            raise ValueError(f"sharing pages not currently allocated: {bad}")
        for p in pages:
            self._refs[p] += 1

    def free(self, pages) -> None:
        """Drop one reference per page; a page is recycled to the free
        list only when its last holder releases it.  Raises on a page
        with no outstanding references (double-decrement, or a page the
        allocator never handed out)."""
        pages = list(pages)
        bad = [p for p in pages if p not in self._refs]
        if bad:
            raise ValueError(f"freeing pages not currently allocated: {bad}")
        for p in pages:
            self._refs[p] -= 1
            if self._refs[p] == 0:
                del self._refs[p]
                self._free.append(p)


class HostPagePool:
    """Host-memory cold tier: refcounted page ids whose contents live in
    host RAM as gathered KV-row payloads instead of device pools.

    Two clients share it: preemption swap-out parks an evicted slot's
    live pages here so resume is an O(pages) copy instead of an
    O(generated_len) replay, and the prefix index demotes reclaimed
    entries here instead of recomputing them on the next hit.  Page ids
    are a namespace of their own — a host page is never mapped into a
    device page table, so there is no trash page (``reserved=0``) and no
    interaction with ``PageTable`` validation.

    The refcount discipline is ``PageAllocator``'s, delegated verbatim
    (alloc at 1, ``share`` adds a holder, ``free`` decrements, double
    frees raise), plus per-page payload storage: ``store`` attaches a
    page's gathered rows, ``load`` reads them back, and recycling a page
    (refcount reaching zero) drops its payload so leaked host memory is
    exactly leaked pages — a drained engine asserts ``in_use == 0`` on
    this pool too.
    """

    def __init__(self, num_pages: int):
        if num_pages < 1:
            raise ValueError(f"num_pages must be >= 1, got {num_pages}")
        self._alloc = PageAllocator(num_pages, reserved=0)
        self._data: dict[int, object] = {}

    @property
    def capacity(self) -> int:
        return self._alloc.capacity

    @property
    def available(self) -> int:
        return self._alloc.available

    @property
    def in_use(self) -> int:
        return self._alloc.in_use

    def refcount(self, page: int) -> int:
        return self._alloc.refcount(page)

    def can_alloc(self, n: int) -> bool:
        return self._alloc.can_alloc(n)

    def alloc(self, n: int) -> list[int] | None:
        """Pop ``n`` host pages at refcount 1 each, or ``None`` — the
        cold tier is backpressured exactly like the device pool (a full
        host tier falls back to replay-resume / plain reclaim)."""
        return self._alloc.alloc(n)

    def share(self, pages) -> None:
        self._alloc.share(pages)

    def free(self, pages) -> None:
        """Drop one reference per page; payloads die with their last
        holder so the pool never pins stale KV rows."""
        pages = list(pages)
        self._alloc.free(pages)
        for p in pages:
            if self._alloc.refcount(p) == 0:
                self._data.pop(p, None)

    def store(self, page: int, payload) -> None:
        """Attach ``payload`` (a gathered per-page cache pytree) to a
        held page.  Raises on a page with no current holder — storing
        into a recycled id would silently resurrect freed data."""
        if self._alloc.refcount(page) < 1:
            raise ValueError(f"storing into host page {page} with no "
                             f"outstanding references")
        self._data[page] = payload

    def load(self, page: int):
        """Payload of a held page; raises if nothing was stored (a
        swap-in of a page that was never swapped out is a scheduler
        bug, not a cache miss)."""
        if page not in self._data:
            raise ValueError(f"host page {page} has no stored payload")
        return self._data[page]


class PageTable:
    """Mutable host mirror of the ``(batch, max_pages)`` device table.

    Every entry defaults to ``trash_page``; ``assign`` fills a slot's
    row prefix with its allocated pages and ``extend`` appends pages to
    a live row mid-stream (incremental allocation: a decode chunk about
    to cross a page boundary grows its slot by exactly the pages the
    new rows need).  Positions past the live prefix — and every
    position of an idle slot — resolve to the trash page, where stale
    idempotent decode writes are harmless.

    Page ids are validated on every mutation: out of pool bounds
    (``num_pages``, when given), inside the reserved range (the trash
    page must never carry live rows), duplicated within a row, or
    already live in *another* slot's row — all raise ``ValueError``
    rather than silently aliasing another request's KV.  Prefix caching
    makes some aliasing legitimate: ``assign`` takes a ``shared`` set of
    page ids that are *declared* read-only multi-holder pages (the
    refcounted prefix pages), which are exempt from the cross-slot check
    — every other page id must still be exclusively owned.
    """

    def __init__(self, batch: int, max_pages: int, trash_page: int = 0,
                 num_pages: int | None = None, reserved: int = 1):
        self.batch = batch
        self.max_pages = max_pages
        self.trash_page = trash_page
        self.num_pages = num_pages
        self.reserved = reserved
        self.table = np.full((batch, max_pages), trash_page, np.int32)
        self._live_len = np.zeros((batch,), np.int64)

    def _validate(self, slot: int, pages: np.ndarray,
                  shared=frozenset()) -> None:
        if not 0 <= slot < self.batch:
            raise ValueError(f"slot {slot} out of range [0, {self.batch})")
        if pages.ndim != 1:
            raise ValueError(f"pages must be a flat id list, got shape "
                             f"{pages.shape}")
        if self.num_pages is not None:
            oob = pages[(pages < 0) | (pages >= self.num_pages)]
            if oob.size:
                raise ValueError(f"page ids {sorted(set(oob.tolist()))} out "
                                 f"of pool range [0, {self.num_pages})")
        rsv = pages[pages < self.reserved]
        if rsv.size:
            raise ValueError(f"page ids {sorted(set(rsv.tolist()))} are in "
                             f"the reserved range [0, {self.reserved}) "
                             f"(trash page {self.trash_page} cannot carry "
                             f"live rows)")
        if np.unique(pages).size != pages.size:
            dup = sorted({int(p) for p in pages
                          if (pages == p).sum() > 1})
            raise ValueError(f"duplicate page ids within one row: {dup}")
        # cross-slot aliasing: a page live in any *other* slot's prefix
        # must not be assigned again (two slots' decode writes would
        # corrupt each other's KV rows) — unless it is a declared
        # read-only shared prefix page, whose holders never write it
        for other in range(self.batch):
            if other == slot:
                continue
            live = self.table[other, :self._live_len[other]]
            alias = np.intersect1d(pages, live)
            alias = alias[~np.isin(alias, list(shared))] if shared else alias
            if alias.size:
                raise ValueError(f"page ids {alias.tolist()} are already "
                                 f"live in slot {other}")

    def assign(self, slot: int, pages, shared=frozenset()) -> None:
        """Point slot ``slot``'s row prefix at ``pages`` (rest trash).
        ``shared`` declares which of the ids are refcounted read-only
        prefix pages, legitimately mapped into other rows too."""
        pages = np.asarray(pages, np.int32).reshape(-1)
        if pages.size > self.max_pages:
            raise ValueError(f"{pages.size} pages exceed the per-slot "
                             f"maximum of {self.max_pages}")
        self._validate(slot, pages, frozenset(shared))
        self.table[slot] = self.trash_page
        self.table[slot, :pages.size] = pages
        self._live_len[slot] = pages.size

    def extend(self, slot: int, pages) -> None:
        """Append ``pages`` to slot ``slot``'s live prefix (incremental
        growth; the new pages cover the rows the next decode chunk will
        write past the current boundary)."""
        pages = np.asarray(pages, np.int32).reshape(-1)
        self._validate(slot, pages)
        n = int(self._live_len[slot])
        if n + pages.size > self.max_pages:
            raise ValueError(f"extending slot {slot} to {n + pages.size} "
                             f"pages exceeds the per-slot maximum of "
                             f"{self.max_pages}")
        dup = np.intersect1d(pages, self.table[slot, :n])
        if dup.size:
            raise ValueError(f"page ids {dup.tolist()} are already live in "
                             f"slot {slot}")
        self.table[slot, n:n + pages.size] = pages
        self._live_len[slot] = n + pages.size

    def truncate(self, slot: int, n_pages: int) -> list[int]:
        """Shrink slot ``slot``'s live prefix to its first ``n_pages``
        pages, re-pointing the removed tail entries at the trash page,
        and return the removed page ids (position order preserved).

        This is the speculative-decode rollback primitive: rejected
        draft tokens past the accepted length only ever touched rows in
        the slot's *tail* pages, so un-mapping those pages (and letting
        the caller return them to the allocator) rolls the cache back
        without copying a single row — the rows themselves are junk the
        idempotent-write invariant already tolerates.  Prefix pages
        (prompt rows) sit strictly below any rollback target, so shared
        refcounted pages are never part of the removed tail.  A
        ``n_pages`` at or above the live length is a no-op."""
        if n_pages < 0:
            raise ValueError(f"cannot truncate slot {slot} to {n_pages} "
                             f"pages")
        n = int(self._live_len[slot])
        if n_pages >= n:
            return []
        removed = self.table[slot, n_pages:n].tolist()
        self.table[slot, n_pages:n] = self.trash_page
        self._live_len[slot] = n_pages
        return removed

    def live_len(self, slot: int) -> int:
        """Live (non-trash) prefix length of a slot's row."""
        return int(self._live_len[slot])

    def clear(self, slot: int) -> None:
        self.table[slot] = self.trash_page
        self._live_len[slot] = 0

    def row(self, slot: int) -> np.ndarray:
        return self.table[slot].copy()

    def asarray(self) -> np.ndarray:
        return self.table


class _PrefixEntry:
    __slots__ = ("page", "parent", "children", "last_used")

    def __init__(self, page: int, parent: bytes | None):
        self.page = page
        self.parent = parent
        self.children = 0
        self.last_used = 0


class PrefixCache:
    """Host-side prefix index: page-aligned prompt chunks → pool pages.

    A prompt's first ``len(prompt) // page_size`` full chunks are keyed
    by a running hash chain — chunk ``j``'s key digests chunk ``j-1``'s
    key plus chunk ``j``'s tokens, so one key commits to the *entire*
    prefix before it and two prompts share an entry only when every
    earlier token matches.  Each entry maps its key to the pool page
    holding that chunk's KV rows; the cache itself holds **one
    allocator reference per indexed page** (``insert`` shares, ``drop``
    / ``reclaim`` free), so a page survives the request that wrote it
    and later requests can map it read-only.

    Reclaim is LRU over *leaf* entries only (an interior chunk is never
    dropped before its descendants — a chain with a hole would be
    unreachable but still pinned), and only entries whose page has no
    holder besides the cache (refcount 1) are dropped: evicting a page
    another slot still maps would gain the pool nothing.

    **Cold tier.**  With :meth:`attach_cold_tier`, a reclaimed entry is
    *demoted* instead of forgotten: its page's rows are copied to a host
    page (the ``demote`` callback, backed by :class:`HostPagePool`) and
    the key survives in a cold index.  The device page is freed either
    way — reclaim's pool math is unchanged — but a later prompt whose
    hash chain reaches a cold run promotes those chunks back with an
    O(pages) host→device copy instead of recomputing their prefill.
    Demotion drops leaf-first, so the cold index holds contiguous chain
    *tails* whose hot prefix is still resident — exactly the shape
    :meth:`match_cold` extends a hot hit run with.  When the host pool
    is full the oldest cold entries die to make room; if it is still
    full the entry is simply dropped (the cold tier degrades to the old
    behaviour, never blocks reclaim).
    """

    def __init__(self, page_size: int, allocator: PageAllocator):
        if page_size < 1:
            raise ValueError(f"page_size must be >= 1, got {page_size}")
        self.page_size = page_size
        self.allocator = allocator
        self._entries: dict[bytes, _PrefixEntry] = {}
        self._clock = 0
        # cold tier: key -> host page id, in demotion order (oldest
        # first); installed by attach_cold_tier, absent by default
        self._cold: dict[bytes, int] = {}
        self._demote = None
        self._release = None

    def __len__(self) -> int:
        return len(self._entries)

    @property
    def pages(self) -> list[int]:
        """Pool pages currently pinned by the index."""
        return [e.page for e in self._entries.values()]

    def chunk_keys(self, tokens) -> list[bytes]:
        """Hash-chain keys for every *full* page-aligned chunk of
        ``tokens`` (the partial tail chunk is never indexed)."""
        return hash_chunks(tokens, self.page_size)

    def match(self, keys: list[bytes]) -> list[int]:
        """Pages of the longest cached *consecutive* chunk run from
        chunk 0.  Read-only: no references taken, no LRU bump — safe
        for admission-feasibility probes."""
        pages = []
        for key in keys:
            e = self._entries.get(key)
            if e is None:
                break
            pages.append(e.page)
        return pages

    def acquire(self, keys: list[bytes]) -> list[int]:
        """``match`` + take one reference per hit page for the caller
        (released through the allocator's normal ``free``) and bump the
        hit entries' LRU clocks."""
        pages = self.match(keys)
        self.allocator.share(pages)
        self._clock += 1
        for key in keys[:len(pages)]:
            self._entries[key].last_used = self._clock
        return pages

    def insert(self, keys: list[bytes], pages) -> int:
        """Index chunk ``j`` → ``pages[j]`` for every not-yet-cached
        chunk, taking the cache's own reference on each newly indexed
        page.  Returns the number of entries added.  ``pages`` must be
        live position-ordered pages of one slot's row (the caller just
        wrote — or mapped — those chunks' KV rows)."""
        pages = list(pages)
        if len(pages) < len(keys):
            raise ValueError(f"{len(keys)} chunk keys but only "
                             f"{len(pages)} pages")
        self._clock += 1
        added, prev = 0, None
        for key, page in zip(keys, pages):
            e = self._entries.get(key)
            if e is None:
                self.allocator.share([page])
                e = _PrefixEntry(page, prev)
                self._entries[key] = e
                if prev is not None:
                    self._entries[prev].children += 1
                added += 1
            e.last_used = self._clock
            prev = key
        return added

    def _droppable(self, keep=frozenset()):
        """Cold leaf entries whose page only the cache still holds."""
        return [(e.last_used, k) for k, e in self._entries.items()
                if e.children == 0 and e.page not in keep
                and self.allocator.refcount(e.page) == 1]

    def attach_cold_tier(self, demote, release) -> None:
        """Install host-tier callbacks: ``demote(page) -> host_id |
        None`` copies a device page's rows to a host page (None = host
        pool full), ``release(host_id)`` frees one.  The engine owns
        both — the index never touches cache tensors itself."""
        self._demote = demote
        self._release = release

    @property
    def cold_size(self) -> int:
        """Entries currently parked in the cold tier."""
        return len(self._cold)

    def match_cold(self, keys: list[bytes], skip: int) -> int:
        """Length of the consecutive cold-run extension of a hot hit
        run: how many of ``keys[skip:]`` sit in the cold index without a
        gap.  Read-only, like :meth:`match`."""
        n = 0
        for key in keys[skip:]:
            if key not in self._cold:
                break
            n += 1
        return n

    def pop_cold(self, keys: list[bytes]) -> list[int]:
        """Remove ``keys`` from the cold index and hand their host pages
        to the caller (promotion: the engine loads each payload into a
        fresh device page, then frees the host page).  Raises on a key
        that is not cold — promotion plans come from ``match_cold``."""
        missing = [k for k in keys if k not in self._cold]
        if missing:
            raise ValueError(f"{len(missing)} promotion key(s) not in the "
                             f"cold index")
        return [self._cold.pop(k) for k in keys]

    def _drop_entry(self, key: bytes) -> None:
        e = self._entries.pop(key)
        if e.parent is not None and e.parent in self._entries:
            self._entries[e.parent].children -= 1
        if self._demote is not None and key not in self._cold:
            hid = self._demote(e.page)
            while hid is None and self._cold:
                # cold tier full: the oldest demotions die to make room
                oldest = next(iter(self._cold))
                self._release(self._cold.pop(oldest))
                hid = self._demote(e.page)
            if hid is not None:
                self._cold[key] = hid
        self.allocator.free([e.page])

    def reclaim(self, n: int, keep=frozenset()) -> int:
        """Free up to ``n`` cold pages back to the pool, LRU leaf-first
        (dropping a leaf may expose its parent for the next round).
        ``keep`` protects pages an in-flight admission plan counts as
        hits.  Returns the number of pages actually freed."""
        keep = frozenset(keep)
        freed = 0
        while freed < n:
            cold = self._droppable(keep)
            if not cold:
                break
            cold.sort()
            for _, key in cold[:n - freed]:
                self._drop_entry(key)
                freed += 1
        return freed

    def reclaimable(self) -> int:
        """Pages ``reclaim`` could free right now (iterated to a fixed
        point on a shadow of the children counts — a cold chain frees
        its interior chunks once the leaves go)."""
        children = {k: e.children for k, e in self._entries.items()}
        dropped: set[bytes] = set()
        while True:
            cold = [k for k, e in self._entries.items()
                    if k not in dropped and children[k] == 0
                    and self.allocator.refcount(e.page) == 1]
            if not cold:
                return len(dropped)
            for k in cold:
                dropped.add(k)
                parent = self._entries[k].parent
                if parent in children:
                    children[parent] -= 1

    def drop(self) -> None:
        """Release every cache-held reference and clear the index (leak
        checks and engine teardown: after ``drop`` a drained engine's
        allocator must report ``in_use == 0``)."""
        for e in self._entries.values():
            self.allocator.free([e.page])
        self._entries.clear()
        if self._release is not None:
            for hid in self._cold.values():
                self._release(hid)
        self._cold.clear()
