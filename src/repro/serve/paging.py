"""Paged KV cache bookkeeping: a host-side block allocator + page table.

The paper replaces one monolithic wide multiplier with fixed-width
nibble units composed through cheap indexing; the serving analogue
replaces the dense per-slot ``max_len`` KV slab with fixed-size *pages*
composed through a page table.  The storage unit is small, uniform and
reused, so cache capacity scales with *live* tokens instead of the
worst-case request shape.

Device-side layout (built in ``models.attention`` / ``models.transformer``):

* every attention layer's K/V (or MLA latent) lives in a shared
  ``(num_pages, page_size, ...)`` pool;
* one ``(batch, max_pages)`` int32 page table maps each decode slot's
  logical positions to pool pages: row ``pos`` of slot ``b`` lives at
  ``(table[b, pos // page_size], pos % page_size)``.

Page ids are **data, not shape** — one compiled program serves every
allocation pattern, so slot refill, mid-stream page growth
(``PageTable.extend``) and page recycling never recompile.

This module is the *host* side: a free-list allocator with admission
backpressure (``alloc`` returns ``None`` instead of OOMing) and the
mutable table mirror the engine ships to the device each decode chunk.
Page 0 is reserved as the **trash page**: idle slots' table rows point
at it, so their frozen idempotent cache writes land somewhere harmless
instead of corrupting a recycled page.

Both classes are strict: double-frees, foreign pages, out-of-range or
reserved page ids, and cross-slot aliasing all raise.  A page-table
corruption silently aliases one slot's live KV rows into another's
attention window — the worst failure mode preemption and incremental
growth make easier to hit — so the bookkeeping refuses instead.
"""

from __future__ import annotations

import numpy as np

__all__ = ["PageAllocator", "PageTable", "pages_needed"]


def pages_needed(rows: int, page_size: int) -> int:
    """Pages required to hold ``rows`` cache rows."""
    if rows <= 0:
        return 0
    return -(-rows // page_size)


class PageAllocator:
    """LIFO free-list over a fixed pool of ``num_pages`` pages.

    The first ``reserved`` page ids are never handed out (the engine
    uses page 0 as the trash page).  ``alloc`` is all-or-nothing and
    returns ``None`` when the pool cannot satisfy the request — the
    caller defers admission (backpressure) or preempts a running slot
    instead of overcommitting the device pool.
    Double-free and foreign-page frees raise: a page leak in the engine
    is a correctness bug (recycled pages carry live KV rows), so the
    allocator is strict enough for tests to assert ``in_use == 0``.
    """

    def __init__(self, num_pages: int, reserved: int = 1):
        if num_pages <= reserved:
            raise ValueError(f"num_pages {num_pages} must exceed the "
                             f"{reserved} reserved page(s)")
        self.num_pages = num_pages
        self.reserved = reserved
        # LIFO: freshly freed pages are reused first (their rows are the
        # most likely to still be resident in any cache hierarchy)
        self._free: list[int] = list(range(num_pages - 1, reserved - 1, -1))
        self._live: set[int] = set()

    @property
    def capacity(self) -> int:
        """Allocatable pages (pool minus reserved)."""
        return self.num_pages - self.reserved

    @property
    def available(self) -> int:
        return len(self._free)

    @property
    def in_use(self) -> int:
        return len(self._live)

    def can_alloc(self, n: int) -> bool:
        return n <= len(self._free)

    def alloc(self, n: int) -> list[int] | None:
        """Pop ``n`` pages, or ``None`` (backpressure) if unavailable."""
        if n < 0:
            raise ValueError(f"cannot allocate {n} pages")
        if n > len(self._free):
            return None
        pages = [self._free.pop() for _ in range(n)]
        self._live.update(pages)
        return pages

    def free(self, pages) -> None:
        """Return pages to the pool.  Raises on double-free or on a page
        the allocator never handed out."""
        pages = list(pages)
        bad = [p for p in pages if p not in self._live]
        if bad:
            raise ValueError(f"freeing pages not currently allocated: {bad}")
        for p in pages:
            self._live.remove(p)
            self._free.append(p)


class PageTable:
    """Mutable host mirror of the ``(batch, max_pages)`` device table.

    Every entry defaults to ``trash_page``; ``assign`` fills a slot's
    row prefix with its allocated pages and ``extend`` appends pages to
    a live row mid-stream (incremental allocation: a decode chunk about
    to cross a page boundary grows its slot by exactly the pages the
    new rows need).  Positions past the live prefix — and every
    position of an idle slot — resolve to the trash page, where stale
    idempotent decode writes are harmless.

    Page ids are validated on every mutation: out of pool bounds
    (``num_pages``, when given), inside the reserved range (the trash
    page must never carry live rows), duplicated within a row, or
    already live in *another* slot's row — all raise ``ValueError``
    rather than silently aliasing another request's KV.
    """

    def __init__(self, batch: int, max_pages: int, trash_page: int = 0,
                 num_pages: int | None = None, reserved: int = 1):
        self.batch = batch
        self.max_pages = max_pages
        self.trash_page = trash_page
        self.num_pages = num_pages
        self.reserved = reserved
        self.table = np.full((batch, max_pages), trash_page, np.int32)
        self._live_len = np.zeros((batch,), np.int64)

    def _validate(self, slot: int, pages: np.ndarray) -> None:
        if not 0 <= slot < self.batch:
            raise ValueError(f"slot {slot} out of range [0, {self.batch})")
        if pages.ndim != 1:
            raise ValueError(f"pages must be a flat id list, got shape "
                             f"{pages.shape}")
        if self.num_pages is not None:
            oob = pages[(pages < 0) | (pages >= self.num_pages)]
            if oob.size:
                raise ValueError(f"page ids {sorted(set(oob.tolist()))} out "
                                 f"of pool range [0, {self.num_pages})")
        rsv = pages[pages < self.reserved]
        if rsv.size:
            raise ValueError(f"page ids {sorted(set(rsv.tolist()))} are in "
                             f"the reserved range [0, {self.reserved}) "
                             f"(trash page {self.trash_page} cannot carry "
                             f"live rows)")
        if np.unique(pages).size != pages.size:
            dup = sorted({int(p) for p in pages
                          if (pages == p).sum() > 1})
            raise ValueError(f"duplicate page ids within one row: {dup}")
        # cross-slot aliasing: a page live in any *other* slot's prefix
        # must not be assigned again (two slots' decode writes would
        # corrupt each other's KV rows)
        for other in range(self.batch):
            if other == slot:
                continue
            live = self.table[other, :self._live_len[other]]
            alias = np.intersect1d(pages, live)
            if alias.size:
                raise ValueError(f"page ids {alias.tolist()} are already "
                                 f"live in slot {other}")

    def assign(self, slot: int, pages) -> None:
        """Point slot ``slot``'s row prefix at ``pages`` (rest trash)."""
        pages = np.asarray(pages, np.int32).reshape(-1)
        if pages.size > self.max_pages:
            raise ValueError(f"{pages.size} pages exceed the per-slot "
                             f"maximum of {self.max_pages}")
        self._validate(slot, pages)
        self.table[slot] = self.trash_page
        self.table[slot, :pages.size] = pages
        self._live_len[slot] = pages.size

    def extend(self, slot: int, pages) -> None:
        """Append ``pages`` to slot ``slot``'s live prefix (incremental
        growth; the new pages cover the rows the next decode chunk will
        write past the current boundary)."""
        pages = np.asarray(pages, np.int32).reshape(-1)
        self._validate(slot, pages)
        n = int(self._live_len[slot])
        if n + pages.size > self.max_pages:
            raise ValueError(f"extending slot {slot} to {n + pages.size} "
                             f"pages exceeds the per-slot maximum of "
                             f"{self.max_pages}")
        dup = np.intersect1d(pages, self.table[slot, :n])
        if dup.size:
            raise ValueError(f"page ids {dup.tolist()} are already live in "
                             f"slot {slot}")
        self.table[slot, n:n + pages.size] = pages
        self._live_len[slot] = n + pages.size

    def live_len(self, slot: int) -> int:
        """Live (non-trash) prefix length of a slot's row."""
        return int(self._live_len[slot])

    def clear(self, slot: int) -> None:
        self.table[slot] = self.trash_page
        self._live_len[slot] = 0

    def row(self, slot: int) -> np.ndarray:
        return self.table[slot].copy()

    def asarray(self) -> np.ndarray:
        return self.table
