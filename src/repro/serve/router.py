"""DP serve fleet: N engine replicas behind one admission queue.

The mesh work in ``serve.engine`` scales one engine *down* into shards
(TP: every device holds a slice of the weights and page pools); this
module scales *out* — data parallelism at the request level.  N
independent ``Engine`` replicas (each single-device or TP-meshed on its
own disjoint device group) sit behind a single arrival-gated priority
queue, and a host-side router decides, per arrived request, which
replica serves it:

* **Least-loaded / join-shortest-queue.**  The default placement key is
  a replica's queued plus running request count — the classic JSQ rule,
  which keeps per-replica queues balanced under bursty arrivals without
  any coordination between replicas.
* **Priorities.**  The router pops its queue best-effective-priority
  first (the same aging rule the engine scheduler uses), so a
  high-priority arrival is *placed* before a low-priority one that
  arrived earlier — and keeps its priority inside the replica, where
  the engine's preemptive scheduler takes over.
* **Prefix-cache affinity.**  With ``prefix_cache=True`` the router
  hashes each prompt's page-aligned chunk chain once
  (``paging.hash_chunks`` — the same keys every replica's index uses)
  and probes every replica's ``PrefixCache.match``: the replica holding
  the longest cached prefix wins (ties fall back to JSQ).  Requests
  sharing a system prompt therefore converge on the replica that
  already holds its pages instead of re-prefilling it N times.
* **Fleet stats.**  ``Router.stats`` aggregates ``Engine.stats`` across
  replicas (summed counters, pooled rates weighted by their true
  denominators) and adds placement accounting: per-replica placement
  counts and prefix-affinity hit rates.  The workload driver
  (``serve.workload``) runs unchanged against a ``Router`` — it mirrors
  the engine's ``submit`` / ``run`` / ``reset`` / ``stats`` surface —
  so fleet-level tok/s and p50/p99 TTFT/ITL come from the same
  definitions as single-engine numbers.

One thread drives the whole fleet: ``run`` round-robins
``Engine.step(wait=False)`` over the replicas, so a replica mid-chunk
never blocks another's admission.  Requests keep their submission ids
(the router's global ids), while each replica's internal ids live in a
disjoint range — request stream keys are index-derived from the id, so
two replicas can never draw correlated sampling streams.
"""

from __future__ import annotations

import time

import jax

from repro.serve.engine import Engine, Request, ServeConfig, _PriorityQueue
from repro.serve.paging import hash_chunks

__all__ = ["Router"]

# replica-local request ids live in disjoint blocks so the
# index-derived stream key fold_in(base_key, id) never collides
# across replicas (a collision would correlate two requests' sampled
# streams); 2**20 ids per replica is far beyond any drain cycle
_ID_BLOCK = 1 << 20


class Router:
    """Admission router over ``replicas`` engine replicas.  Mirrors the
    engine's serving surface (``submit`` / ``run`` / ``start`` /
    ``step`` / ``drain`` / ``reset`` / ``stats`` / ``compile_counts``)
    so callers — the workload driver, the launcher, the benchmark —
    drive a fleet exactly like one engine."""

    def __init__(self, cfg, params, scfg: ServeConfig, *,
                 replicas: int = 2, devices=None):
        if replicas < 1:
            raise ValueError(f"replicas must be >= 1, got {replicas}")
        self.cfg = cfg
        self.scfg = scfg
        groups = self._device_groups(scfg, replicas, devices)
        self.replicas = [Engine(cfg, params, scfg, devices=g)
                         for g in groups]
        for i, eng in enumerate(self.replicas):
            eng._next_id = i * _ID_BLOCK
        self._prefix = bool(scfg.prefix_cache)
        self._page_size = (self.replicas[0].cfg.page_size
                           if self._prefix else 0)
        self._queue = _PriorityQueue(scfg.priority_aging_s)
        self._next_gid = 0
        self._placed: dict[int, tuple[int, int]] = {}
        self.placements = [0] * replicas
        self.affinity_hits = [0] * replicas
        self.placement_order: list[int] = []

    @staticmethod
    def _device_groups(scfg: ServeConfig, replicas: int, devices):
        """Disjoint device slices, one per replica.  Unmeshed engines
        (tp=1, no mesh_shape) share the default device — N CPU-process
        replicas on one chip is the functional-testing case; meshed
        engines must each get their full complement of devices or the
        fleet cannot be placed at all."""
        shape = scfg.mesh_shape or (1, scfg.tp)
        per = int(shape[0]) * int(shape[1])
        if per <= 1 and scfg.mesh_shape is None:
            return [None] * replicas
        if devices is None:
            devices = jax.devices()
        devices = list(devices)
        if replicas * per > len(devices):
            raise ValueError(
                f"{replicas} replicas x {per} devices/replica needs "
                f"{replicas * per} devices but only {len(devices)} are "
                f"available")
        return [devices[i * per:(i + 1) * per] for i in range(replicas)]

    # ------------------------------------------------------------------
    # admission
    # ------------------------------------------------------------------

    def submit(self, prompt, max_new_tokens: int, arrival: float = 0.0,
               priority: int = 0) -> int:
        """Queue one request fleet-wide; returns its global id (the key
        of the ``run()`` result).  Validation happens here — an
        unserveable request is rejected at the router's front door, not
        at placement inside a replica."""
        prompt, clamped, truncated = self.replicas[0].validate(
            prompt, max_new_tokens)
        req = Request(id=self._next_gid, prompt=prompt,
                      max_new_tokens=clamped, arrival=arrival,
                      priority=priority, truncated=truncated)
        self._next_gid += 1
        self._queue.push(req)
        return req.id

    def _load(self, eng: Engine) -> int:
        """JSQ key: queued plus running requests on a replica."""
        return len(eng._queue) + sum(r is not None for r in eng._slots)

    def _pick_replica(self, req: Request) -> int:
        """Prefix affinity first (most cached chunks of this prompt),
        then least-loaded, then lowest index — a total order, so
        placement is deterministic for a given fleet state."""
        keys = (hash_chunks(req.prompt, self._page_size)
                if self._prefix else None)
        best, best_key = 0, None
        for i, eng in enumerate(self.replicas):
            hits = (len(eng.prefix_cache.match(keys))
                    if keys else 0)
            key = (-hits, self._load(eng), i)
            if best_key is None or key < best_key:
                best, best_key = i, key
        if best_key[0] < 0:
            self.affinity_hits[best] += 1
        return best

    def _dispatch(self, req: Request, now: float) -> None:
        ri = self._pick_replica(req)
        lid = self.replicas[ri].submit(req.prompt, req.max_new_tokens,
                                       arrival=req.arrival,
                                       priority=req.priority)
        self._placed[req.id] = (ri, lid)
        self.placements[ri] += 1
        self.placement_order.append(req.id)

    # ------------------------------------------------------------------
    # fleet loop
    # ------------------------------------------------------------------

    def start(self, t0: float | None = None) -> None:
        """Anchor one shared run clock across every replica, so fleet
        latency percentiles pool comparable per-request stamps."""
        self._t0 = time.perf_counter() if t0 is None else t0
        for eng in self.replicas:
            eng.start(self._t0)

    def step(self, wait: bool = True) -> bool:
        """One fleet iteration: place every arrived request (best
        effective priority first), then give every replica one
        non-blocking scheduler step.  Returns ``False`` when the whole
        fleet is drained."""
        now = time.perf_counter() - self._t0
        while True:
            req = self._queue.pop(now)
            if req is None:
                break
            self._dispatch(req, now)
        alive = False
        for eng in self.replicas:
            alive = eng.step(wait=False) or alive
        if alive:
            return True
        if not len(self._queue):
            return False
        if wait:                       # fleet idle until the next arrival
            nxt = self._queue.next_arrival()
            wait_s = nxt - (time.perf_counter() - self._t0)
            if wait_s > 0:
                time.sleep(min(wait_s, 0.05))
        return True

    def drain(self) -> dict[int, Request]:
        """Collect finished requests from every replica, re-keyed by
        their global ids."""
        drained = [eng.drain() for eng in self.replicas]
        out = {}
        for gid, (ri, lid) in list(self._placed.items()):
            req = drained[ri].get(lid)
            if req is not None:
                out[gid] = req
                del self._placed[gid]
        return out

    def run(self) -> dict[int, Request]:
        """Drain the fleet; returns {global_id: Request} with the same
        per-request timing contract as ``Engine.run``."""
        self.start()
        while self.step():
            pass
        return self.drain()

    def reset(self, rng=None) -> None:
        for eng in self.replicas:
            eng.reset(rng)
        self._queue = _PriorityQueue(self.scfg.priority_aging_s)
        self._placed = {}
        self.placements = [0] * len(self.replicas)
        self.affinity_hits = [0] * len(self.replicas)
        self.placement_order = []

    # ------------------------------------------------------------------
    # fleet-level reporting
    # ------------------------------------------------------------------

    @property
    def compile_counts(self) -> dict:
        """Element-wise max over replicas: every replica must hold the
        per-stage pins, so the fleet-level count equals the single-
        engine contract ({prefill: 1, ...}) — any replica recompiling
        pushes a key above its pin and the benchmark raises."""
        out: dict[str, int] = {}
        for eng in self.replicas:
            for k, v in eng.compile_counts.items():
                out[k] = max(out.get(k, 0), v)
        return out

    @property
    def stats(self) -> dict:
        """Fleet aggregation of ``Engine.stats``: counters are summed,
        rates are re-derived from their summed numerators and
        denominators (never averaged — a replica that served one
        request must not weigh as much as one that served a hundred),
        occupancy is the mean over replicas (same pool size each), and
        ``per_replica`` carries the placement split: how many requests
        each replica got and what fraction of them hit its prefix
        index (the affinity metric)."""
        per = [eng.stats for eng in self.replicas]
        n = len(self.replicas)
        cached = sum(e._cached_prompt_tokens for e in self.replicas)
        total_p = sum(e._total_prompt_tokens for e in self.replicas)
        proposed = sum(e.spec_proposed for e in self.replicas)
        accepted = sum(e.spec_accepted for e in self.replicas)
        spec_toks = sum(e.spec_tokens for e in self.replicas)
        slot_rounds = sum(e.spec_slot_rounds for e in self.replicas)
        return {
            "preemptions": sum(s["preemptions"] for s in per),
            "occupancy": sum(s["occupancy"] for s in per) / n,
            "concurrency": sum(s["concurrency"] for s in per),
            "pool_pages": sum(s["pool_pages"] for s in per),
            "prefix_hits": sum(s["prefix_hits"] for s in per),
            "prefix_hit_rate": cached / max(1, total_p),
            "prefill_tokens": sum(s["prefill_tokens"] for s in per),
            "cow_copies": sum(s["cow_copies"] for s in per),
            "prefix_pages": sum(s["prefix_pages"] for s in per),
            "spec_rounds": sum(s["spec_rounds"] for s in per),
            "acceptance_rate": accepted / max(1, proposed),
            "tokens_per_step": spec_toks / max(1, slot_rounds),
            "spec_rollback_pages": sum(s["spec_rollback_pages"]
                                       for s in per),
            "prefill_waves": sum(s["prefill_waves"] for s in per),
            "decode_chunks": sum(s["decode_chunks"] for s in per),
            "swap_out": sum(s["swap_out"] for s in per),
            "swap_in": sum(s["swap_in"] for s in per),
            "replay_steps_saved": sum(s["replay_steps_saved"]
                                      for s in per),
            "host_pages": sum(s["host_pages"] for s in per),
            "prefix_cold_pages": sum(s["prefix_cold_pages"] for s in per),
            "prefix_cold_hits": sum(s["prefix_cold_hits"] for s in per),
            "prefix_demotions": sum(s["prefix_demotions"] for s in per),
            "dp_replicas": n,
            "placements": list(self.placements),
            "per_replica": [
                {"replica": i,
                 "placed": self.placements[i],
                 "affinity_hits": self.affinity_hits[i],
                 "affinity_hit_rate": round(
                     self.affinity_hits[i] / max(1, self.placements[i]),
                     3),
                 "prefix_hit_rate": round(per[i]["prefix_hit_rate"], 3),
                 "preemptions": per[i]["preemptions"],
                 "occupancy": round(per[i]["occupancy"], 3),
                 "concurrency": round(per[i]["concurrency"], 2)}
                for i in range(n)],
        }

    @property
    def cache_token_bytes(self) -> int:
        return self.replicas[0].cache_token_bytes

    @property
    def mesh_shape(self) -> tuple:
        """Per-replica (data, model) mesh shape (replicas are uniform)."""
        return self.replicas[0].mesh_shape

    @property
    def device_count(self) -> int:
        """Distinct devices the fleet spans (unmeshed replicas share
        the default device, so N unmeshed CPU replicas report 1)."""
        devs = set()
        for eng in self.replicas:
            if eng._mesh is None:
                devs.add(jax.devices()[0])
            else:
                devs.update(eng._mesh.devices.flat)
        return len(devs)

    def release_prefix_cache(self) -> None:
        for eng in self.replicas:
            eng.release_prefix_cache()

    def leaked_pages(self) -> int:
        """Sum of per-replica leak counters (release the prefix caches
        first; non-zero after a full drain is a bug)."""
        return sum(eng.leaked_pages() for eng in self.replicas)
