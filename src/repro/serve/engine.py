"""Slot-based continuous-batching serve engine.

The paper treats each vector lane as an independent low-precision
element sharing one datapath; the serving analogue implemented here
treats each batch slot as an independent *sequence* sharing one compiled
program.  Concretely:

* **Per-slot decode positions.**  ``decode_step`` takes a ``(B,)``
  position vector, so every slot decodes at its own offset — positions
  are data, not shape, and one compilation serves every mix of request
  lengths.
* **Prefill into a free slot.**  A new request is prefilled alone
  (batch 1), padded to the slot prompt budget (``prefill_len``), and its
  caches are scattered into the free slot of the shared batched cache
  (``merge_slot_caches``).  Pad-token cache rows are harmless: decode
  overwrites row ``p`` before any query can attend to it.
* **Per-slot completion.**  Each slot tracks its own remaining-token
  budget and optional ``eos_id``; finished slots are refilled from the
  request queue between decode chunks without recompiling anything
  (``Engine.compile_counts`` stays at one entry per function).
* **Jitted multi-token decode.**  The inner loop is a ``lax.scan`` over
  ``decode_chunk`` tokens inside a single ``jax.jit`` — one dispatch
  per chunk, not per token.
* **Sampling.**  Every generated token, including the first one after
  prefill, goes through the same temperature/greedy path.

Limits (tracked in ROADMAP "Open items"): the KV cache is a dense
per-slot ``max_len`` slab (no paging), the queue is FIFO (no request
priorities), and models with mamba mixers prefill at exact prompt length
(end-padding would pollute the SSM state), which recompiles per distinct
prompt length.

``make_serve_step`` remains the single-token jit-able step the decode
dry-run cells lower.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models import (
    decode_step,
    init_caches,
    merge_slot_caches,
    prefill,
)

__all__ = ["ServeConfig", "Request", "make_serve_step", "Engine"]


@dataclasses.dataclass(frozen=True)
class ServeConfig:
    batch: int                        # concurrent decode slots
    max_len: int                      # per-slot cache budget (tokens)
    temperature: float = 0.0          # 0 = greedy
    eos_id: int = -1                  # -1 = no EOS (length-only stopping)
    prefill_len: int = 0              # slot prompt budget: prompts are
    #   padded to this length so one prefill compilation serves every
    #   request.  0 = prefill at exact prompt length (recompiles per
    #   distinct length; always used for mamba-mixer models, where
    #   end-padding would corrupt the recurrent state).
    decode_chunk: int = 8             # tokens per jitted scan dispatch
    # Serving-time quantization overrides: deploy any checkpoint under a
    # different execution mode/backend than it was configured with (the
    # params stay bf16; integer modes quantize on the fly).  ``None``
    # keeps the model config's setting.  ``quant_backend="pallas"``
    # routes every projection through ``ops.quant_matmul`` — the
    # single-pass plane-fused kernel with the in-kernel dequant epilogue.
    quant_mode: str | None = None
    quant_backend: str | None = None


@dataclasses.dataclass
class Request:
    """One generation request (host-side bookkeeping)."""
    id: int
    prompt: np.ndarray                # (S,) int32
    max_new_tokens: int
    arrival: float = 0.0              # seconds after Engine.run() starts
    tokens: list = dataclasses.field(default_factory=list)  # generated
    t_first: float = -1.0             # time to first token (from run t0)
    t_done: float = -1.0

    @property
    def text_len(self) -> int:
        return len(self.prompt) + len(self.tokens)


def _apply_quant_overrides(cfg: ModelConfig, scfg: ServeConfig) -> ModelConfig:
    updates = {}
    if scfg.quant_mode is not None:
        updates["quant_mode"] = scfg.quant_mode
    if scfg.quant_backend is not None:
        updates["quant_backend"] = scfg.quant_backend
    return dataclasses.replace(cfg, **updates) if updates else cfg


def _sampler(scfg: ServeConfig) -> Callable:
    """(B, V) logits → (B,) int32 token, greedy or temperature."""
    def sample(logits, rng):
        logits = logits.astype(jnp.float32)
        if scfg.temperature > 0.0:
            nxt = jax.random.categorical(rng, logits / scfg.temperature)
        else:
            nxt = jnp.argmax(logits, axis=-1)
        return nxt.astype(jnp.int32)

    return sample


def make_serve_step(cfg: ModelConfig, scfg: ServeConfig) -> Callable:
    """serve_step(params, caches, token, index, rng) → (next_token, caches).

    ``index`` is a traced scalar *or* ``(B,)`` per-slot position vector —
    one compilation serves every decode position assignment.  Greedy or
    temperature sampling on-device.
    """
    cfg = _apply_quant_overrides(cfg, scfg)
    sample = _sampler(scfg)

    def serve_step(params, caches, token, index, rng):
        logits, caches = decode_step(params, cfg, token, caches, index)
        nxt = sample(logits[:, -1], rng)
        return nxt[:, None], caches

    return serve_step


class Engine:
    """Continuous-batching engine: request queue + slot refill + chunked
    jitted decode.  See the module docstring for the execution model."""

    def __init__(self, cfg: ModelConfig, params, scfg: ServeConfig):
        if scfg.prefill_len > scfg.max_len:
            raise ValueError(f"prefill_len {scfg.prefill_len} exceeds "
                             f"max_len {scfg.max_len}")
        if scfg.decode_chunk < 1:
            raise ValueError(f"decode_chunk must be >= 1, got "
                             f"{scfg.decode_chunk}")
        self.cfg = _apply_quant_overrides(cfg, scfg)
        self.params = params
        self.scfg = scfg
        specs = (*cfg.prefix_pattern, *cfg.block_pattern,
                 *cfg.suffix_pattern)
        self._has_mamba = any(s.mixer == "mamba" for s in specs)
        # the cache slab is donated: both stages rebind it from the
        # return value, so the update happens in place instead of
        # copying every unmodified row of (batch × max_len × layers)
        self._prefill_fn = jax.jit(self._build_prefill(), donate_argnums=1)
        self._chunk_fn = jax.jit(self._build_decode_chunk(),
                                 donate_argnums=1)
        self._caches = init_caches(self.cfg, scfg.batch, scfg.max_len)
        self._next_id = 0
        self.reset()

    # ------------------------------------------------------------------
    # compiled stages
    # ------------------------------------------------------------------

    def _build_prefill(self):
        cfg, scfg = self.cfg, self.scfg
        sample = _sampler(scfg)

        def prefill_into_slot(params, caches, prompt, prompt_len, slot, rng):
            """prompt: (1, P) — padded; prompt_len/slot: traced scalars."""
            logits, one, _ = prefill(params, cfg, prompt,
                                     max_len=scfg.max_len,
                                     logits_index=prompt_len - 1)
            caches = merge_slot_caches(caches, one, slot)
            first = sample(logits[:, -1], rng)[0]
            return caches, first

        return prefill_into_slot

    def _build_decode_chunk(self):
        cfg, scfg = self.cfg, self.scfg
        sample = _sampler(scfg)
        max_pos = scfg.max_len - 1

        def chunk(params, caches, token, positions, active, remaining, rng):
            """Scan ``decode_chunk`` tokens; inactive slots are frozen
            (their rewrites of already-written cache rows are idempotent)
            and emit -1."""
            def body(carry, _):
                caches, token, positions, active, remaining, rng = carry
                rng, sub = jax.random.split(rng)
                logits, caches = decode_step(params, cfg, token, caches,
                                             positions)
                nxt = sample(logits[:, -1], sub)
                emitted = jnp.where(active, nxt, -1)
                remaining = remaining - active.astype(jnp.int32)
                alive = remaining > 0
                if scfg.eos_id >= 0:
                    alive = alive & (nxt != scfg.eos_id)
                new_active = active & alive
                positions = jnp.where(
                    active, jnp.minimum(positions + 1, max_pos), positions)
                token = jnp.where(active[:, None], nxt[:, None], token)
                carry = (caches, token, positions, new_active, remaining,
                         rng)
                return carry, (emitted, active)

            init = (caches, token, positions, active, remaining, rng)
            carry, (toks, valid) = jax.lax.scan(
                body, init, None, length=scfg.decode_chunk)
            return carry + (toks, valid)

        return chunk

    # ------------------------------------------------------------------
    # host-side state
    # ------------------------------------------------------------------

    def reset(self, rng=None) -> None:
        """Clear queue/slots (compiled functions and cache buffers are
        kept — stale cache rows are invisible: decode overwrites row
        ``p`` before any query can attend to it)."""
        b = self.scfg.batch
        self._rng = rng if rng is not None else jax.random.PRNGKey(0)
        self._queue: list[Request] = []
        self._slots: list[Request | None] = [None] * b
        self._token = np.zeros((b, 1), np.int32)
        self._positions = np.zeros((b,), np.int32)
        self._active = np.zeros((b,), bool)
        self._remaining = np.zeros((b,), np.int32)
        self._finished: dict[int, Request] = {}

    @property
    def compile_counts(self) -> dict:
        """Compilations per stage — the refill-without-recompile claim
        is checkable: counts stay at 1 across arbitrary request mixes
        (given a fixed ``prefill_len`` slot budget)."""
        def count(fn):
            # _cache_size is jax-private; report -1 rather than crash
            # the engine if an upgrade moves it
            return getattr(fn, "_cache_size", lambda: -1)()

        return {"prefill": count(self._prefill_fn),
                "decode_chunk": count(self._chunk_fn)}

    def submit(self, prompt, max_new_tokens: int, arrival: float = 0.0) -> int:
        """Queue one request; returns its id.  ``arrival`` (seconds from
        ``run()`` start) models staggered workloads — the request is not
        admitted to a slot before its arrival time."""
        prompt = np.asarray(prompt, np.int32).reshape(-1)
        scfg = self.scfg
        if max_new_tokens < 1:
            raise ValueError(f"max_new_tokens must be >= 1, got "
                             f"{max_new_tokens}")
        if prompt.size == 0 or prompt.size >= scfg.max_len:
            raise ValueError(f"prompt length {prompt.size} must be in "
                             f"[1, max_len={scfg.max_len})")
        if scfg.prefill_len and prompt.size > scfg.prefill_len \
                and not self._has_mamba:
            raise ValueError(f"prompt length {prompt.size} exceeds the "
                             f"slot budget prefill_len={scfg.prefill_len}")
        max_new_tokens = min(max_new_tokens, scfg.max_len - prompt.size)
        req = Request(id=self._next_id, prompt=prompt,
                      max_new_tokens=max_new_tokens, arrival=arrival)
        self._next_id += 1
        self._queue.append(req)
        self._queue.sort(key=lambda r: r.arrival)
        return req.id

    # ------------------------------------------------------------------
    # scheduling loop
    # ------------------------------------------------------------------

    def _admit(self, now: float) -> None:
        """Prefill arrived requests into free slots (FIFO)."""
        for slot in range(self.scfg.batch):
            if self._slots[slot] is not None or not self._queue:
                continue
            if self._queue[0].arrival > now:
                break
            req = self._queue.pop(0)
            p_len = int(req.prompt.size)
            if self._has_mamba or not self.scfg.prefill_len:
                pad_len = p_len          # exact-length prefill
            else:
                pad_len = self.scfg.prefill_len
            padded = np.zeros((1, pad_len), np.int32)
            padded[0, :p_len] = req.prompt
            self._rng, sub = jax.random.split(self._rng)
            self._caches, first = self._prefill_fn(
                self.params, self._caches, jnp.asarray(padded), p_len,
                slot, sub)
            tok = int(first)
            req.tokens.append(tok)
            req.t_first = time.perf_counter() - self._t0
            done = (req.max_new_tokens <= 1
                    or (self.scfg.eos_id >= 0 and tok == self.scfg.eos_id))
            if done:
                self._finish(req)
            else:
                self._slots[slot] = req
                self._token[slot, 0] = tok
                self._positions[slot] = p_len
                self._active[slot] = True
                self._remaining[slot] = req.max_new_tokens - 1

    def _finish(self, req: Request) -> None:
        req.t_done = time.perf_counter() - self._t0
        self._finished[req.id] = req

    def _run_chunk(self) -> None:
        (self._caches, token, positions, active, remaining, self._rng,
         toks, valid) = self._chunk_fn(
            self.params, self._caches, jnp.asarray(self._token),
            jnp.asarray(self._positions), jnp.asarray(self._active),
            jnp.asarray(self._remaining), self._rng)
        self._token = np.array(token)        # copies: host state is mutable
        self._positions = np.array(positions)
        self._active = np.array(active)
        self._remaining = np.array(remaining)
        toks, valid = np.asarray(toks), np.asarray(valid)
        for slot, req in enumerate(self._slots):
            if req is None:
                continue
            for t in range(toks.shape[0]):
                if not valid[t, slot]:
                    break
                tok = int(toks[t, slot])
                req.tokens.append(tok)
                if (len(req.tokens) >= req.max_new_tokens
                        or (self.scfg.eos_id >= 0
                            and tok == self.scfg.eos_id)):
                    self._finish(req)
                    self._slots[slot] = None
                    break

    def run(self) -> dict[int, Request]:
        """Drain the queue: admit → chunked decode → refill, until every
        submitted request has finished.  Returns {id: Request} with
        per-request timing (t_first / t_done relative to run start)."""
        self._t0 = time.perf_counter()
        while self._queue or any(r is not None for r in self._slots):
            now = time.perf_counter() - self._t0
            self._admit(now)
            if not self._active.any():
                if self._queue:   # idle until the next arrival
                    wait = self._queue[0].arrival \
                        - (time.perf_counter() - self._t0)
                    if wait > 0:
                        time.sleep(min(wait, 0.05))
                    continue
                break
            self._run_chunk()
        out, self._finished = self._finished, {}
        return out

    # ------------------------------------------------------------------
    # batch convenience API (examples / tests)
    # ------------------------------------------------------------------

    def generate(self, prompts: jax.Array, n_new: int,
                 rng=None) -> jax.Array:
        """prompts: (B, S) int32 → (B, S + n_new) tokens.

        Uniform-workload wrapper over submit/run: B must equal the slot
        count and every request decodes exactly ``n_new`` tokens, so
        the output is rectangular (build the engine with the default
        ``eos_id=-1``; early EOS stops raise)."""
        prompts = np.asarray(prompts, np.int32)
        b, s = prompts.shape
        if b != self.scfg.batch:
            raise ValueError(f"prompts batch {b} != ServeConfig.batch "
                             f"{self.scfg.batch}")
        if s + n_new > self.scfg.max_len:
            raise ValueError(f"prompt_len {s} + n_new {n_new} exceeds "
                             f"max_len {self.scfg.max_len}")
        self.reset(rng=rng if rng is not None else jax.random.PRNGKey(0))
        ids = [self.submit(prompts[i], n_new) for i in range(b)]
        done = self.run()
        if any(len(done[i].tokens) != n_new for i in ids):
            raise RuntimeError(
                "generate() needs rectangular output but EOS stopped a "
                "request early; use submit()/run() for ragged workloads")
        gen = np.stack([np.asarray(done[i].tokens, np.int32) for i in ids])
        return jnp.concatenate([jnp.asarray(prompts), jnp.asarray(gen)],
                               axis=1)
