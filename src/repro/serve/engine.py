"""Serving: batched prefill→decode engine + the jit-able ``serve_step``.

``make_serve_step`` builds the function the decode dry-run cells lower:
one new token for every sequence in the batch against a seq_len-sized
KV cache (exactly the ``decode_32k`` / ``long_500k`` shape semantics).

The engine adds continuous batching on top for the example scripts:
requests at different positions share the cache; finished slots are
refilled without recompiling (positions are data, not shape).
"""

from __future__ import annotations

import dataclasses
from typing import Callable

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import decode_step, init_caches, prefill

__all__ = ["ServeConfig", "make_serve_step", "Engine"]


@dataclasses.dataclass(frozen=True)
class ServeConfig:
    batch: int
    max_len: int
    temperature: float = 0.0          # 0 = greedy
    # Serving-time quantization overrides: deploy any checkpoint under a
    # different execution mode/backend than it was configured with (the
    # params stay bf16; integer modes quantize on the fly).  ``None``
    # keeps the model config's setting.  ``quant_backend="pallas"``
    # routes every projection through ``ops.quant_matmul`` — the
    # single-pass plane-fused kernel with the in-kernel dequant epilogue.
    quant_mode: str | None = None
    quant_backend: str | None = None


def _apply_quant_overrides(cfg: ModelConfig, scfg: ServeConfig) -> ModelConfig:
    updates = {}
    if scfg.quant_mode is not None:
        updates["quant_mode"] = scfg.quant_mode
    if scfg.quant_backend is not None:
        updates["quant_backend"] = scfg.quant_backend
    return dataclasses.replace(cfg, **updates) if updates else cfg


def make_serve_step(cfg: ModelConfig, scfg: ServeConfig) -> Callable:
    """serve_step(params, caches, token, index) → (next_token, caches).

    ``index`` is a traced scalar — one compilation serves every decode
    position.  Greedy or temperature sampling on-device.
    """
    cfg = _apply_quant_overrides(cfg, scfg)

    def serve_step(params, caches, token, index, rng):
        logits, caches = decode_step(params, cfg, token, caches, index)
        logits = logits[:, -1].astype(jnp.float32)
        if scfg.temperature > 0.0:
            nxt = jax.random.categorical(rng, logits / scfg.temperature)
        else:
            nxt = jnp.argmax(logits, axis=-1)
        return nxt[:, None].astype(jnp.int32), caches

    return serve_step


class Engine:
    """Minimal continuous-batching engine for the example drivers."""

    def __init__(self, cfg: ModelConfig, params, scfg: ServeConfig):
        self.cfg = _apply_quant_overrides(cfg, scfg)
        self.params = params
        self.scfg = scfg
        self._step = jax.jit(make_serve_step(cfg, scfg))

    def generate(self, prompts: jax.Array, n_new: int,
                 rng=None) -> jax.Array:
        """prompts: (B, S) int32 → (B, S + n_new) tokens."""
        cfg, scfg = self.cfg, self.scfg
        rng = rng if rng is not None else jax.random.PRNGKey(0)
        b, s = prompts.shape
        logits, caches, _ = prefill(self.params, cfg, prompts,
                                    max_len=scfg.max_len)
        token = jnp.argmax(logits[:, -1].astype(jnp.float32),
                           axis=-1)[:, None].astype(jnp.int32)
        out = [prompts, token]
        for i in range(n_new - 1):
            rng, sub = jax.random.split(rng)
            token, caches = self._step(self.params, caches, token,
                                       s + i, sub)
            out.append(token)
        return jnp.concatenate(out, axis=1)
