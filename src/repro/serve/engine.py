"""Slot-based continuous-batching serve engine.

The paper treats each vector lane as an independent low-precision
element sharing one datapath; the serving analogue implemented here
treats each batch slot as an independent *sequence* sharing one compiled
program.  Concretely:

* **Per-slot decode positions.**  ``decode_step`` takes a ``(B,)``
  position vector, so every slot decodes at its own offset — positions
  are data, not shape, and one compilation serves every mix of request
  lengths.
* **Paged KV cache.**  With ``cache_mode="paged"`` the per-slot
  ``max_len`` slab is replaced by shared ``(num_pages, page_size, ...)``
  pools plus a ``(batch, max_pages)`` int32 page table — the paper's
  fixed-width-reusable-unit idea applied to KV storage.  Page ids are
  data, not shape, so allocation, refill and recycling never recompile;
  a host-side free-list allocator (``serve.paging``) hands pages out at
  admission and takes them back at completion, and admission *defers*
  (backpressure) instead of OOMing when the pool is exhausted.  Cache
  HBM then scales with live tokens, not ``batch × max_len``.
* **Incremental page allocation.**  ``alloc_mode="reserve"`` books a
  request's worst-case page count up front; ``alloc_mode="incremental"``
  books only the prompt pages (plus the first decode page) and tops a
  slot up right before any decode chunk whose writes would cross its
  allocated page boundary (``PageTable.extend`` — still data, not
  shape).  Early-EOS requests never touch their unbooked tail, so the
  same pool sustains more concurrent requests (overcommit: ``num_pages``
  may sit below the sum of worst-case page counts).
* **Preemption.**  When an incremental top-up finds the pool dry, or a
  strictly-higher-effective-priority arrival cannot get a slot or
  pages, the weakest running slot is evicted: its pages return to the
  pool and the request re-enters the queue *carrying its generated
  tokens*.  On re-admission the prompt is re-prefilled (same compiled
  prefill) and the generated tokens are teacher-forced back through the
  decode chunk — the client-visible stream is preserved verbatim and a
  preempted greedy stream resumes **bit-identically** to an
  uninterrupted one.  Eviction uses the same aging-adjusted effective
  priority as admission, so equal-priority requests never ping-pong.
* **Prefill into a free slot.**  A new request is prefilled alone
  (batch 1), padded to the slot prompt budget (``prefill_len``), and its
  caches are scattered into the free slot of the shared batched cache
  (``merge_slot_caches``; the paged dual copies whole prompt *pages*
  into the pools instead of padding a dense slab to ``max_len``).
  Pad-token cache rows are harmless: decode overwrites row ``p`` before
  any query can attend to it.
* **Prefix caching (copy-on-write pages).**  With
  ``prefix_cache=True`` (paged mode only) the engine keeps a host-side
  index from page-aligned prompt-chunk hashes to the pool pages holding
  their KV rows (``serve.paging.PrefixCache``).  Admission maps the
  longest cached prefix into the new slot's page-table row *read-only*
  (the allocator refcounts holders) and prefills **only the uncached
  suffix** through the same compiled prefill program — the suffix sits
  in the padded prompt buffer, a traced ``start`` carries its global
  position, and every attention layer splices the gathered cached rows
  below the fresh ones at the fixed buffer length, so a cache miss is
  bit-identical to a no-cache engine and a hit reuses the paper's
  logic-reuse idea one level up (compute the shared operand once,
  reuse it across consumers).  When a prompt is *fully* covered by
  cached pages, the tail page is **copy-on-written** inside the same
  program (duplicated into a private page before the last token's KV
  write could land on shared storage).  Completion and eviction
  *decrement* refcounts instead of freeing outright, so a victim's
  shared pages survive for their other holders, and cold index entries
  are reclaimed LRU-leaf-first under pool pressure.
* **Priority scheduling.**  The request queue is a priority heap
  (``Request.priority``, higher first; arrival time then submission
  order break ties) with simple aging — every ``priority_aging_s``
  seconds of waiting adds one effective priority level, so long prompts
  can no longer head-of-line-block short high-priority ones and starved
  low-priority requests eventually win.
* **Per-slot completion.**  Each slot tracks its own remaining-token
  budget and optional ``eos_id``; finished slots are refilled from the
  request queue between decode chunks without recompiling anything
  (``Engine.compile_counts`` stays at one entry per function — counted
  by an engine-owned signature tracker, not a jax-private probe).
* **Jitted multi-token decode.**  The inner loop is a ``lax.scan`` over
  ``decode_chunk`` tokens inside a single ``jax.jit`` — one dispatch
  per chunk, not per token.
* **Sampling.**  Every generated token, including the first one after
  prefill, goes through the same temperature/greedy path.  The rng for
  stream index ``i`` of request ``r`` is *index-derived* —
  ``fold_in(fold_in(base_key, r.id), i)`` — never a split chain
  threaded through the decode loop, so a draw depends only on (request,
  position), not on batch composition, admission order, or how many
  chunks ran before it.  Sampled streams are therefore bit-stable under
  preemption and resume, exactly like greedy ones.
* **Self-speculative decoding.**  With ``spec_decode=True`` the
  *quantized* execution mode of the same weights drafts ``spec_k``
  tokens per slot (a ``lax.scan`` under the draft config), and ONE
  dense multi-token ``decode_step`` forward verifies all draft
  positions at once (the multi-position machinery above, at
  ``S = spec_k + 1``) — the paper's logic-reuse pairing: the low-power
  nibble datapath proposes, the full-precision datapath it was carved
  from disposes.  Greedy acceptance is exact-match, so a spec stream is
  bit-identical to the non-spec dense stream; at temperature > 0
  rejection sampling preserves the dense distribution.  Rejected draft
  tails roll back as a **page-table operation** — ``PageTable.truncate``
  re-points the dead tail at the trash page and the allocator takes the
  pages back; no cache rows are copied (the dense verify already
  overwrote the draft's rows, and junk rows past the accepted prefix
  are never attended before their owner rewrites them).  The engine
  compiles exactly one draft and one verify program (``compile_counts``
  keeps ``{"prefill": 1, "draft": 1, "verify": 1}``; the plain decode
  chunk is never built in spec mode).

**Tail latency** (``prefill_chunk`` / ``admit_group`` / ``swap_mode``).
Three mechanisms bound the scheduler-level stalls heavy traffic hits:

* *Chunked prefill*: with ``prefill_chunk > 0`` (or ``admit_group >
  1``) admission only books pages and parks the slot in a *prefilling*
  state; each scheduler step then advances up to ``admit_group`` such
  slots by one ``prefill_chunk``-token chunk through ONE compiled wave
  program — ``decode_step`` with an (G, C) token block at per-lane
  global positions, the same multi-position paged scatter/gather the
  spec verify forward uses — *before* the running slots' decode chunk.
  A giant admitted prompt therefore costs running slots one chunk of
  latency per step instead of one monolithic prefill, and the
  monolithic prefill program is never built (``compile_counts`` pins
  ``{"prefill": 0, "prefill_chunk": 1}``).
* *Grouped admission*: simultaneous arrivals admitted in one window
  become multiple prefilling slots, and every wave batches up to
  ``admit_group`` of them into one padded (G, C) dispatch — burst
  admission costs one program launch, not G serialized batch-1
  prefills.  Greedy wave streams bit-match monolithic serialized
  admission (the dense chunk computation is bit-exact; quantized modes
  are argmax-stable, as everywhere per-tensor activation scales make
  streams batch-composition-dependent).
* *Host-tier page swap*: with ``swap_mode="host"`` eviction copies the
  victim's live KV pages into a ``HostPagePool`` (host RAM, same
  refcount discipline as the device allocator) and resume copies them
  back into fresh pages and re-points the table — O(pages) copies
  replace the O(generated_len) replay decode steps, and the restore is
  a bit-copy, so even temperature/spec streams resume bit-stable.  The
  same pool backs the prefix cache's *cold tier*: reclaimed index
  entries demote to host pages instead of vanishing and promote back
  on a later hit, giving the index a capacity tier bigger than HBM.
  A full host pool degrades gracefully to replay-resume / plain
  reclaim.

Limits (tracked in ROADMAP "Open items"): models with mamba mixers
prefill at exact prompt length (end-padding would pollute the SSM
state), which recompiles per distinct prompt length, and cannot draft
multi-token speculative rounds (conv/SSM state rollback is not a
page-table operation), so ``spec_decode`` rejects them — and their
recurrent state is per-slot rather than paged, so chunked/grouped
prefill and ``swap_mode="host"`` reject them too; resume-after-
preemption with ``swap_mode="off"`` (the default) still replays the
generated tokens through the decode chunk, and spec streams at
temperature > 0 are then distribution-preserving but not bit-stable
across preemption (the draft model's cache after resume differs from
the uninterrupted run's, which can shift acceptance boundaries —
greedy spec streams stay bit-identical; ``swap_mode="host"`` removes
the replay, and with it this caveat, whenever the host tier has room).

``make_serve_step`` remains the single-token jit-able step the decode
dry-run cells lower.
"""

from __future__ import annotations

import dataclasses
import heapq
import time
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig, spec_split
from repro.models import (
    copy_paged_cache_page,
    decode_step,
    extract_cache_pages,
    init_caches,
    insert_cache_pages,
    merge_slot_caches,
    merge_slot_paged_caches,
    prefill,
    scatter_prefill_paged_caches,
)
from repro.models.transformer import _SEQ_CACHE_KEYS
from repro.serve.paging import (
    HostPagePool,
    PageAllocator,
    PageTable,
    PrefixCache,
    pages_needed,
)

__all__ = ["ServeConfig", "Request", "make_serve_step", "Engine"]


@dataclasses.dataclass(frozen=True)
class ServeConfig:
    batch: int                        # concurrent decode slots
    max_len: int                      # per-slot cache budget (tokens)
    temperature: float = 0.0          # 0 = greedy
    eos_id: int = -1                  # -1 = no EOS (length-only stopping)
    prefill_len: int = 0              # slot prompt budget: prompts are
    #   padded to this length so one prefill compilation serves every
    #   request.  0 = prefill at exact prompt length (recompiles per
    #   distinct length; always used for mamba-mixer models, where
    #   end-padding would corrupt the recurrent state).
    decode_chunk: int = 8             # tokens per jitted scan dispatch
    priority_aging_s: float = 0.0     # seconds since arrival per +1
    #   effective priority level (0 = aging off, strict priorities).
    #   Applied to queued AND running requests alike: the same measure
    #   gates preemption, so a long-waiting request climbs toward
    #   admission and, once admitted, becomes correspondingly harder to
    #   evict — equal-priority requests can never evict each other.
    alloc_mode: str = "reserve"       # paged-mode page accounting:
    #   "reserve" books every request's worst-case page count at
    #   admission; "incremental" books only the prompt pages (plus the
    #   first decode page) and tops slots up per decode chunk,
    #   preempting the weakest runner when the pool runs dry — the same
    #   pool then sustains more concurrent requests (overcommit).
    # Serving-time overrides: deploy any checkpoint under a different
    # execution mode/backend/cache layout than it was configured with
    # (the params stay bf16; integer modes quantize on the fly).
    # ``None`` keeps the model config's setting.
    # ``quant_backend="pallas"`` routes every projection through
    # ``ops.quant_matmul`` — the single-pass plane-fused kernel with the
    # in-kernel dequant epilogue.  ``cache_mode="paged"`` switches the
    # KV cache to page pools + page-table indirection; ``page_size`` /
    # ``num_pages`` size the pool (num_pages=0 → capacity parity with
    # the dense slab).
    prefix_cache: bool = False        # paged mode only: share read-only
    #   prompt-prefix pages across requests (hash-indexed page-aligned
    #   chunks, refcounted pages, copy-on-write on a fully covered
    #   prompt's tail page).  Admission prefills only the uncached
    #   suffix through the same compiled prefill; greedy streams stay
    #   bit-identical to an uncached engine's.  Incompatible with
    #   mamba-mixer models (recurrent state cannot compose with a
    #   cached prefix) and the int8 KV cache (cached rows would be
    #   dequantized where a solo prefill attends full precision).
    quant_mode: str | None = None
    quant_backend: str | None = None
    cache_mode: str | None = None
    page_size: int | None = None
    num_pages: int | None = None
    spec_decode: bool = False         # self-speculative decoding: the
    #   quantized (nibble) program drafts ``spec_k`` tokens per slot,
    #   then ONE multi-token dense forward verifies all draft positions
    #   at once.  Greedy acceptance keeps the emitted stream bit-equal
    #   to the non-spec dense engine's; temperature > 0 switches to
    #   rejection sampling (distribution-preserving, not bit-matching).
    #   Rejected drafts roll back as a page-table truncation — never a
    #   cache copy.  Incompatible with mamba-mixer models (the verify
    #   forward needs position-indexed caches, not recurrent state).
    spec_k: int = 4                   # draft tokens per speculation round
    spec_quant_mode: str | None = None  # draft-side QuantLinear mode;
    #   None = the engine's effective quant_mode (the deployment drafts
    #   for itself).  The verifier always runs dense — in spec mode the
    #   engine pins its prefill/verify config to quant_mode="dense" and
    #   the quant knobs configure the *draft* program only.
    prefill_chunk: int = 0            # chunked prefill: > 0 splits every
    #   admitted prompt into chunks of this many tokens, one chunk per
    #   scheduler step through a single compiled wave program
    #   (interleaved with running slots' decode chunks, so a long
    #   prompt bounds other slots' ITL impact to one chunk's latency).
    #   0 keeps the classic monolithic one-dispatch prefill — unless
    #   ``admit_group > 1``, which also enables the wave program with
    #   chunk width ``prefill_len``.  Paged cache only; incompatible
    #   with mamba mixers (chunk boundaries are cache positions, not
    #   recurrent state) and the int8 KV cache.
    admit_group: int = 1              # grouped admission: up to this
    #   many prefilling slots advance per wave as one padded (G, chunk)
    #   batch — a simultaneous burst costs one program launch instead of
    #   G serialized batch-1 prefills.  The group budget is fixed, so
    #   the wave program compiles exactly once.  > 1 requires the paged
    #   cache and (when ``prefill_chunk`` is 0) a ``prefill_len`` budget
    #   to serve as the wave width.
    swap_mode: str = "off"            # "host": on eviction copy the
    #   victim's live KV pages to a host-memory cold pool
    #   (``HostPagePool``) and restore them on resume — preemption
    #   resume becomes an O(pages) copy instead of an
    #   O(generated_len) replay, and the restore is a bit-copy, so
    #   sampled/spec streams also resume bit-stable.  The same pool
    #   gives the prefix cache a cold tier: reclaimed entries demote to
    #   host pages and promote back on a later hit.  A full host pool
    #   falls back to replay-resume.  "off" keeps replay-only resume.
    #   Paged cache only; incompatible with mamba mixers (recurrent
    #   state is per-slot, not paged — a restore cannot rebuild it).
    host_pages: int = 0               # host cold-pool capacity in pages
    #   for ``swap_mode="host"``; 0 = twice the device pool's
    #   allocatable capacity (host RAM is the bigger tier by design).
    prefix_cache_pages: int = 0       # capacity cap on pages the prefix
    #   index may pin: after every insert the index reclaims (LRU
    #   leaf-first, demoting to the cold tier when one is attached)
    #   down to this budget instead of only under pool pressure.
    #   Best-effort: entries whose page a live slot still maps are not
    #   reclaimable and may hold the index above the cap until that
    #   slot finishes.  0 = uncapped (pressure-driven reclaim only).
    tp: int = 1                       # tensor-parallel width: shard the
    #   weights (param_specs rules) and the paged KV/scale pools'
    #   KV-head dimension (cache_specs paged rules; in-page sequence
    #   fallback when heads don't divide) over the mesh's "model" axis,
    #   and build every compiled program with explicit in/out shardings
    #   under a (1, tp) local mesh.  The page table stays host-side and
    #   replicated.  1 = no mesh — the single-device engine, unchanged.
    #   Greedy streams under tp > 1 bit-match the single-device engine
    #   token-for-token (argmax is stable under the reduction-order
    #   shifts TP's partial-sum collectives introduce).
    mesh_shape: tuple | None = None   # explicit (data, model) in-engine
    #   mesh shape; overrides ``tp`` (the two must agree when both are
    #   given).  None = derived from ``tp``.  Data-parallelism across
    #   *requests* belongs one level up — ``serve.router.Router`` runs
    #   N single- or TP-meshed engine replicas behind one queue.


@dataclasses.dataclass
class Request:
    """One generation request (host-side bookkeeping)."""
    id: int
    prompt: np.ndarray                # (S,) int32
    max_new_tokens: int
    arrival: float = 0.0              # seconds after Engine.run() starts
    priority: int = 0                 # higher = served first (with aging)
    tokens: list = dataclasses.field(default_factory=list)  # generated
    t_first: float = -1.0             # time to first token (from run t0)
    t_done: float = -1.0
    t_tokens: list = dataclasses.field(default_factory=list)  # per-token
    #   emission times (from run t0; replayed tokens keep their original
    #   stamps) — consecutive diffs are the inter-token latencies the
    #   workload driver aggregates into ITL percentiles
    cache_rows: int = 0               # peak cache rows reserved for this
    #   request: max_len in dense mode, pages × page_size in paged mode
    #   (the per-request HBM footprint the benchmark reports)
    truncated: bool = False           # max_new_tokens was cut to fit the
    #   max_len budget at submit (explicit, so short output is never
    #   misread as an early EOS)
    preemptions: int = 0              # times this request was evicted
    #   mid-stream and later resumed
    chunk_keys: list | None = None    # memoized prefix-index hash chain
    #   of the prompt's page-aligned chunks (computed on first admission
    #   probe; the prompt is immutable, and admission re-plans several
    #   times per placement)
    swap_pages: list | None = None    # host page ids holding this
    #   request's swapped-out KV rows while it waits re-admission
    #   (``swap_mode="host"``); None = resume replays instead
    swap_rows: int = 0                # live cache rows captured at
    #   swap-out (= the slot's decode position then); tokens beyond
    #   ``swap_rows - len(prompt) + 1`` were not yet written back and
    #   re-enter the teacher-forcing lane on resume

    @property
    def text_len(self) -> int:
        return len(self.prompt) + len(self.tokens)


class _PriorityQueue:
    """Arrival-gated max-priority queue with lazy aging.

    Backed by a heap keyed ``(-priority, arrival, seq)``; ``pop`` takes
    the current time so not-yet-arrived requests are invisible and
    waiting requests age: every ``aging_s`` seconds in the queue adds
    one effective priority level (aging off when 0).  The common case —
    every queued request arrived, aging off — pops straight off the
    heap; otherwise the effective keys are recomputed over the (small)
    queue."""

    def __init__(self, aging_s: float = 0.0):
        self.aging_s = aging_s
        self._heap: list[tuple] = []
        self._seq = 0

    def __len__(self) -> int:
        return len(self._heap)

    def push(self, req: Request) -> None:
        heapq.heappush(self._heap, (-req.priority, req.arrival, self._seq,
                                    req))
        self._seq += 1

    def effective(self, req: Request, now: float) -> int:
        """Aging-adjusted priority.  The engine applies the same measure
        to *running* requests when picking preemption victims, so two
        equal-priority requests can never evict each other back and
        forth (both age at the same rate; strict inequality gates every
        eviction)."""
        if self.aging_s <= 0:
            return req.priority
        return req.priority + int(max(0.0, now - req.arrival)
                                  / self.aging_s)

    def next_arrival(self) -> float | None:
        return min((e[1] for e in self._heap), default=None)

    def _best_index(self, now: float) -> int | None:
        if not self._heap:
            return None
        if self.aging_s <= 0 and self._heap[0][1] <= now:
            return 0                  # heap order is the effective order
        best_i, best_key = None, None
        for i, (_, arr, seq, req) in enumerate(self._heap):
            if arr > now:
                continue
            key = (-self.effective(req, now), arr, seq)
            if best_key is None or key < best_key:
                best_i, best_key = i, key
        return best_i

    def peek(self, now: float) -> Request | None:
        """Best arrived request without removing it (the engine checks
        whether it is worth preempting a running slot for)."""
        i = self._best_index(now)
        return None if i is None else self._heap[i][3]

    def pop(self, now: float, admit: Callable[[Request], bool] = None):
        """Remove and return the best arrived request, or ``None``.
        ``admit`` vetoes the winner without removing it (admission
        backpressure defers strictly in priority order)."""
        best_i = self._best_index(now)
        if best_i is None:
            return None
        req = self._heap[best_i][3]
        if admit is not None and not admit(req):
            return None
        self._heap[best_i] = self._heap[-1]
        self._heap.pop()
        heapq.heapify(self._heap)
        return req


class _CountingJit:
    """Engine-owned compile counter around ``jax.jit``.

    ``jax.jit`` compiles once per abstract call signature — the pytree
    structure plus every leaf's shape/dtype/weak-type.  The wrapper
    derives that key per call and counts distinct keys, which makes the
    refill-without-recompile invariant checkable without the jax-private
    ``_cache_size`` probe (whose absence used to crash the serving
    benchmark on any jax upgrade that moved it).

    The recorded signatures are themselves the static-analysis surface:
    each one reconstructs (via ``abstract_args``) into a tree of
    ``ShapeDtypeStruct`` leaves that can be fed to ``jit_fn.lower`` /
    ``jit_fn.trace`` long after the run, so ``repro.staticcheck`` can
    re-lower every stage program a live engine actually compiled and
    inspect the jaxpr/HLO without re-running the workload."""

    def __init__(self, fn, **jit_kwargs):
        self._fn = jax.jit(fn, **jit_kwargs)
        self._keys: set = set()

    @staticmethod
    def _leaf_sig(leaf):
        if hasattr(leaf, "shape") and hasattr(leaf, "dtype"):
            return (tuple(leaf.shape), str(leaf.dtype),
                    bool(getattr(leaf, "weak_type", False)))
        return (type(leaf).__name__,)

    def __call__(self, *args):
        leaves, treedef = jax.tree_util.tree_flatten(args)
        self._keys.add((treedef, tuple(map(self._leaf_sig, leaves))))
        return self._fn(*args)

    @property
    def compile_count(self) -> int:
        return len(self._keys)

    @property
    def jit_fn(self):
        """The underlying ``jax.jit``-wrapped callable (for ``.lower`` /
        ``.trace`` against signatures returned by ``abstract_args``)."""
        return self._fn

    @property
    def signatures(self) -> tuple:
        """The distinct abstract call signatures recorded so far, in a
        deterministic order.  Each is ``(treedef, leaf_sigs)`` where
        array leaves carry ``(shape, dtype, weak_type)`` and non-array
        leaves carry ``(type_name,)``."""
        return tuple(sorted(self._keys, key=repr))

    # non-array leaves lose their value in the signature; any concrete
    # stand-in lowers to the same program because stage bodies consume
    # scalars as traced data, never as shapes
    _SCALAR_STANDIN = {"int": 0, "float": 0.0, "bool": False,
                       "NoneType": None}

    @classmethod
    def abstract_args(cls, signature) -> tuple:
        """Rebuild a recorded signature into the positional-args tuple
        of ``ShapeDtypeStruct`` leaves that ``jax.jit`` saw."""
        treedef, leaf_sigs = signature
        leaves = []
        for sig in leaf_sigs:
            if len(sig) == 3:
                shape, dtype, _weak = sig
                leaves.append(jax.ShapeDtypeStruct(shape, dtype))
            else:
                leaves.append(cls._SCALAR_STANDIN[sig[0]])
        return jax.tree_util.tree_unflatten(treedef, leaves)


def _apply_overrides(cfg: ModelConfig, scfg: ServeConfig) -> ModelConfig:
    updates = {}
    for field in ("quant_mode", "quant_backend", "cache_mode", "page_size",
                  "num_pages"):
        val = getattr(scfg, field)
        if val is not None:
            updates[field] = val
    return cfg.replace(**updates) if updates else cfg


def _sampler(scfg: ServeConfig) -> Callable:
    """(B, V) logits → (B,) int32 token, greedy or temperature."""
    def sample(logits, rng):
        logits = logits.astype(jnp.float32)
        if scfg.temperature > 0.0:
            nxt = jax.random.categorical(rng, logits / scfg.temperature)
        else:
            nxt = jnp.argmax(logits, axis=-1)
        return nxt.astype(jnp.int32)

    return sample


def _slot_sampler(scfg: ServeConfig) -> Callable:
    """(B, V) logits + (B, 2) per-slot uint32 keys → (B,) int32 token.

    The per-slot keys are the index-derived stream keys (see
    ``Engine._slot_keys``): slot ``b``'s draw for stream index ``i``
    uses ``fold_in(request_key, i)``, so the draw depends only on the
    request identity and the token's position in its stream — never on
    admission order, batch composition or preemption history.  That is
    what makes *sampled* streams bit-stable under evict-and-resume."""
    def sample(logits, keys):
        logits = logits.astype(jnp.float32)
        if scfg.temperature > 0.0:
            nxt = jax.vmap(jax.random.categorical)(
                keys, logits / scfg.temperature)
        else:
            nxt = jnp.argmax(logits, axis=-1)
        return nxt.astype(jnp.int32)

    return sample


def _fold_counts(keys, counts):
    """Per-slot stream-index keys: ``fold_in(keys[b], counts[b])``."""
    return jax.vmap(jax.random.fold_in)(keys, counts)


# Sub-draw tags folded *below* the stream-index key when one token index
# needs several independent draws (speculative decoding): the chunk
# sampler's draw for index i is fold_in(req_key, i); the spec path's
# draft proposal, acceptance uniform and rejection resample for the same
# index fold one more tag in, so no draw ever aliases another.
_TAG_ACCEPT = 1
_TAG_RESAMPLE = 2
_TAG_DRAFT = 3


def make_serve_step(cfg: ModelConfig, scfg: ServeConfig) -> Callable:
    """serve_step(params, caches, token, index, rng) → (next_token, caches).

    ``index`` is a traced scalar *or* ``(B,)`` per-slot position vector —
    one compilation serves every decode position assignment.  Greedy or
    temperature sampling on-device.  Dense caches only: the paged layout
    needs a page table threaded per step, which this single-token
    dry-run entry point does not carry — use ``Engine`` for paged mode.
    """
    cfg = _apply_overrides(cfg, scfg)
    if cfg.cache_mode == "paged":
        raise ValueError("make_serve_step does not support "
                         "cache_mode='paged' (no page-table plumbing); "
                         "use Engine for the paged cache")
    sample = _sampler(scfg)

    def serve_step(params, caches, token, index, rng):
        logits, caches = decode_step(params, cfg, token, caches, index)
        nxt = sample(logits[:, -1], rng)
        return nxt[:, None], caches

    return serve_step


class Engine:
    """Continuous-batching engine: priority request queue + slot refill +
    chunked jitted decode, over a dense or paged KV cache, with
    incremental page allocation, evict-and-resume preemption and
    refcounted prefix caching (copy-on-write pages) in paged mode.  See
    the module docstring for the execution model and ``docs/serving.md``
    for the operator-facing reference."""

    def __init__(self, cfg: ModelConfig, params, scfg: ServeConfig,
                 devices=None):
        if scfg.prefill_len > scfg.max_len:
            raise ValueError(f"prefill_len {scfg.prefill_len} exceeds "
                             f"max_len {scfg.max_len}")
        if scfg.decode_chunk < 1:
            raise ValueError(f"decode_chunk must be >= 1, got "
                             f"{scfg.decode_chunk}")
        if scfg.alloc_mode not in ("reserve", "incremental"):
            raise ValueError(f"alloc_mode must be 'reserve' or "
                             f"'incremental', got {scfg.alloc_mode!r}")
        self.cfg = _apply_overrides(cfg, scfg)
        self.params = params
        self.scfg = scfg
        specs = (*cfg.prefix_pattern, *cfg.block_pattern,
                 *cfg.suffix_pattern)
        self._has_mamba = any(s.mixer == "mamba" for s in specs)
        self._paged = self.cfg.cache_mode == "paged"
        self._incremental = scfg.alloc_mode == "incremental"
        if self._incremental and not self._paged:
            raise ValueError("alloc_mode='incremental' requires "
                             "cache_mode='paged' (the dense slab has no "
                             "pages to grow)")
        if self._paged:
            ps = self.cfg.page_size
            if ps < 1:
                raise ValueError(f"page_size must be >= 1, got {ps}")
            if scfg.max_len % ps:
                raise ValueError(f"max_len {scfg.max_len} must be a "
                                 f"multiple of page_size {ps}")
            self._page_size = ps
            self._max_pages = scfg.max_len // ps
            self._num_pages = (self.cfg.num_pages
                               or scfg.batch * self._max_pages + 1)
            # page 0 is the trash page: idle slots' table rows point at
            # it so their frozen idempotent cache writes never corrupt a
            # recycled page
            self.cfg = self.cfg.replace(num_pages=self._num_pages)
        elif self.cfg.cache_mode != "dense":
            raise ValueError(f"cache_mode must be 'dense' or 'paged', "
                             f"got {self.cfg.cache_mode!r}")
        if scfg.prefix_cache:
            if not self._paged:
                raise ValueError("prefix_cache=True requires "
                                 "cache_mode='paged' (the dense slab has "
                                 "no pages to share)")
            if self._has_mamba:
                raise ValueError("prefix_cache=True is incompatible with "
                                 "mamba-mixer models: SSM state is "
                                 "sequential and cannot be composed from "
                                 "a cached prefix")
            if self.cfg.kv_cache_dtype == "int8":
                raise ValueError("prefix_cache=True is incompatible with "
                                 "kv_cache_dtype='int8': cached rows are "
                                 "attended dequantized while a solo "
                                 "prefill attends full precision, "
                                 "breaking the bit-match contract")
        self._spec = scfg.spec_decode
        self._draft_cfg = None
        if self._spec:
            if scfg.spec_k < 1:
                raise ValueError(f"spec_k must be >= 1, got {scfg.spec_k}")
            if self._has_mamba:
                raise ValueError(
                    "spec_decode=True is incompatible with mamba-mixer "
                    "models: the multi-token verify forward needs "
                    "position-indexed caches, and the recurrent state "
                    "cannot roll back a rejected draft")
            # the quantized deployment drafts for its own dense
            # verifier: the engine's effective quant knobs configure the
            # DRAFT program, while prefill + verify run pinned dense
            # (acceptance is defined against the dense model's output)
            self._draft_cfg, self.cfg = spec_split(self.cfg,
                                                   scfg.spec_quant_mode)
        if scfg.prefill_chunk < 0:
            raise ValueError(f"prefill_chunk must be >= 0, got "
                             f"{scfg.prefill_chunk}")
        if scfg.admit_group < 1:
            raise ValueError(f"admit_group must be >= 1, got "
                             f"{scfg.admit_group}")
        if scfg.swap_mode not in ("off", "host"):
            raise ValueError(f"swap_mode must be 'off' or 'host', got "
                             f"{scfg.swap_mode!r}")
        if scfg.host_pages < 0:
            raise ValueError(f"host_pages must be >= 0, got "
                             f"{scfg.host_pages}")
        if scfg.prefix_cache_pages < 0:
            raise ValueError(f"prefix_cache_pages must be >= 0, got "
                             f"{scfg.prefix_cache_pages}")
        # wave mode: chunked and/or grouped prefill through one shared
        # (G, C) decode-step program at explicit global positions; the
        # monolithic prefill stage is then never built, so its pinned
        # compile count is 0 (see ``compile_counts``)
        self._wave = scfg.prefill_chunk > 0 or scfg.admit_group > 1
        if self._wave:
            if not self._paged:
                raise ValueError("prefill_chunk/admit_group require "
                                 "cache_mode='paged': the wave program "
                                 "writes prompt rows through page-table "
                                 "rows, not a dense slab")
            if self._has_mamba:
                raise ValueError("prefill_chunk/admit_group are "
                                 "incompatible with mamba-mixer models: "
                                 "chunk boundaries are cache positions, "
                                 "and recurrent state has none")
            if self.cfg.kv_cache_dtype == "int8":
                raise ValueError("prefill_chunk/admit_group are "
                                 "incompatible with kv_cache_dtype="
                                 "'int8': earlier chunks are attended "
                                 "dequantized while a monolithic "
                                 "prefill attends full precision, "
                                 "breaking the bit-match contract")
            self._wave_chunk = scfg.prefill_chunk or scfg.prefill_len
            if self._wave_chunk < 1:
                raise ValueError("admit_group > 1 with prefill_chunk=0 "
                                 "needs prefill_len > 0 to serve as the "
                                 "wave width")
            self._wave_group = scfg.admit_group
        self._swap = scfg.swap_mode == "host"
        if self._swap:
            if not self._paged:
                raise ValueError("swap_mode='host' requires "
                                 "cache_mode='paged': the dense slab "
                                 "has no pages to swap")
            if self._has_mamba:
                raise ValueError("swap_mode='host' is incompatible with "
                                 "mamba-mixer models: recurrent state "
                                 "is per-slot, not paged, so a page "
                                 "restore cannot rebuild it")
        # TP mesh: built before the compiled stages so their explicit
        # in/out shardings can reference the sharded param/cache trees
        # (None = no mesh, the single-device engine — every jit is then
        # built without sharding kwargs, so its signatures, and with
        # them the compile_counts pins, are untouched)
        self._mesh = self._build_mesh(devices)
        self._caches = init_caches(self.cfg, scfg.batch, scfg.max_len)
        if self._mesh is not None:
            self._shard_state()
        # the cache slab/pool is donated: both stages rebind it from the
        # return value, so the update happens in place instead of
        # copying every unmodified row (the out_shardings under a mesh
        # match the donated input's, so donation still applies)
        if self._wave:
            self._prefill_fn = None
            self._wave_fn = _CountingJit(self._build_wave_prefill(),
                                         donate_argnums=1,
                                         **self._stage_shardings(9, 2))
        else:
            self._wave_fn = None
            n_pre = 10 if scfg.prefix_cache else 7
            self._prefill_fn = _CountingJit(
                self._build_prefill(), donate_argnums=1,
                **self._stage_shardings(n_pre, 2))
        if self._spec:
            # exactly two decode-side programs — one quantized draft,
            # one dense verify; _chunk_fn is never built or called, so
            # its pinned compile count is 0 (see ``compile_counts``)
            self._chunk_fn = None
            self._draft_fn = _CountingJit(self._build_draft(),
                                          donate_argnums=1,
                                          **self._stage_shardings(10, 3))
            self._verify_fn = _CountingJit(self._build_verify(),
                                           donate_argnums=1,
                                           **self._stage_shardings(10, 3))
        else:
            self._chunk_fn = _CountingJit(self._build_decode_chunk(),
                                          donate_argnums=1,
                                          **self._stage_shardings(11, 7))
        self._next_id = 0
        self.reset()

    # ------------------------------------------------------------------
    # mesh / sharding plumbing
    # ------------------------------------------------------------------

    def _build_mesh(self, devices):
        scfg = self.scfg
        if scfg.tp < 1:
            raise ValueError(f"tp must be >= 1, got {scfg.tp}")
        shape = scfg.mesh_shape
        if shape is not None:
            shape = tuple(int(x) for x in shape)
            if len(shape) != 2:
                raise ValueError(f"mesh_shape must be (data, model), got "
                                 f"{scfg.mesh_shape!r}")
            if scfg.tp != 1 and shape[1] != scfg.tp:
                raise ValueError(f"mesh_shape {shape} disagrees with "
                                 f"tp={scfg.tp} on the model axis")
        elif scfg.tp > 1:
            shape = (1, scfg.tp)
        if shape is None or shape == (1, 1):
            return None
        from repro.launch.mesh import make_local_mesh
        return make_local_mesh(dp=shape[0], tp=shape[1], devices=devices)

    def _shard_state(self):
        """Commit the params and the cache slab/pools to the mesh with
        the repo's partition rules: weights via ``param_specs``
        (megatron col/row TP pairs), caches via ``cache_specs`` (paged
        branch: KV heads on "model" when divisible, in-page sequence
        axis otherwise; the page table ships replicated with every
        dispatch — see ``distributed.sharding.page_table_spec``)."""
        from jax.sharding import NamedSharding
        from jax.sharding import PartitionSpec as P

        from repro.distributed.sharding import cache_specs, param_specs

        def to_shardings(specs):
            return jax.tree_util.tree_map(
                lambda s: NamedSharding(self._mesh, s), specs,
                is_leaf=lambda x: isinstance(x, P))

        self._param_sh = to_shardings(param_specs(self.params, self._mesh))
        self._cache_sh = to_shardings(
            cache_specs(self.cfg, self._caches, self._mesh,
                        batch=self.scfg.batch))
        self._repl = NamedSharding(self._mesh, P())
        self.params = jax.device_put(self.params, self._param_sh)
        self._caches = jax.device_put(self._caches, self._cache_sh)

    def _stage_shardings(self, n_args: int, n_outs: int) -> dict:
        """jit kwargs for one compiled stage: params and caches keep
        their committed shardings, every other argument and output
        (tokens, positions, page-table rows, rng keys — all host-
        authored) is replicated.  Empty without a mesh, so the
        single-device jit signature is byte-identical to before."""
        if self._mesh is None:
            return {}
        r = self._repl
        return {"in_shardings": (self._param_sh, self._cache_sh)
                + (r,) * (n_args - 2),
                "out_shardings": (self._cache_sh,) + (r,) * (n_outs - 1)}

    @property
    def mesh_shape(self) -> tuple:
        """(data, model) shape of the in-engine mesh; (1, 1) unmeshed."""
        if self._mesh is None:
            return (1, 1)
        return (int(self._mesh.shape["data"]),
                int(self._mesh.shape["model"]))

    @property
    def device_count(self) -> int:
        """Devices this engine's programs span (1 without a mesh)."""
        return 1 if self._mesh is None else int(self._mesh.devices.size)

    # ------------------------------------------------------------------
    # compiled stages
    # ------------------------------------------------------------------

    def _prefill_pad_len(self, pad_len: int) -> int:
        """Cache length the prefill stage grows to: the prompt budget,
        rounded up to whole pages in paged mode (the page merge copies
        whole pages; rows past the real prompt are pad garbage that
        decode overwrites or the causal mask hides)."""
        if not self._paged:
            return self.scfg.max_len
        ps = self._page_size
        return -(-pad_len // ps) * ps

    def _build_prefill(self):
        cfg, scfg = self.cfg, self.scfg
        sample = _sampler(scfg)
        paged = self._paged
        if scfg.prefix_cache:
            return self._build_prefix_prefill()

        def prefill_into_slot(params, caches, prompt, prompt_len, slot,
                              pages, rng):
            """prompt: (1, P) — padded; prompt_len/slot: traced scalars;
            pages: (max_pages,) traced page-id row (trash-filled past the
            request's live pages; ignored in dense mode)."""
            grow_to = self._prefill_pad_len(prompt.shape[1])
            logits, one, _ = prefill(params, cfg, prompt,
                                     max_len=grow_to,
                                     logits_index=prompt_len - 1)
            if paged:
                caches = merge_slot_paged_caches(caches, one, slot, pages)
            else:
                caches = merge_slot_caches(caches, one, slot)
            first = sample(logits[:, -1], rng)[0]
            return caches, first

        return prefill_into_slot

    def _build_prefix_prefill(self):
        """Prefix-cache variant of the prefill stage: one compiled
        program serves cache miss, partial hit and fully-covered (COW)
        admissions alike — the suffix start, the page-table row and the
        COW page pair are all data, not shape."""
        cfg, scfg = self.cfg, self.scfg
        sample = _sampler(scfg)

        def prefill_into_slot(params, caches, suffix, suffix_len, slot,
                              row, start, cow_src, cow_dst, rng):
            """suffix: (1, P) padded uncached prompt tail whose first
            token sits at global position ``start`` (= rows already
            mapped read-only through ``row``); ``cow_src``/``cow_dst``
            duplicate a shared tail page into a private one *before*
            any write (the no-COW default 0/0 rewrites the trash page
            with itself — a bit-exact no-op)."""
            caches = copy_paged_cache_page(caches, cow_src, cow_dst)
            logits, one, _ = prefill(params, cfg, suffix,
                                     logits_index=suffix_len - 1,
                                     ctx_caches=caches,
                                     ctx_table=row[None],
                                     ctx_start=start)
            caches = scatter_prefill_paged_caches(caches, one, slot, row,
                                                  start)
            first = sample(logits[:, -1], rng)[0]
            return caches, first

        return prefill_into_slot

    def _build_wave_prefill(self):
        """The wave program: one compiled stage advances up to
        ``admit_group`` prefilling slots by one prompt chunk each —
        chunked prefill and grouped admission are the same dispatch at
        different (G, C) fill levels.  Built on the multi-position
        ``decode_step`` path (per-position causal masking +
        scatter-before-gather through the page table), so chunk rows are
        bit-identical to a monolithic prefill's; every composition of
        chunk width and lane occupancy reuses this one program because
        lengths, start positions, table rows and COW pairs are all data,
        not shape."""
        cfg, scfg = self.cfg, self.scfg
        sample = _slot_sampler(scfg)

        def wave(params, caches, tokens, lens, starts, rows, cow_src,
                 cow_dst, keys):
            """tokens: (G, C) prompt chunks, zero-padded; lens: (G,)
            real widths; starts: (G,) each chunk's global position;
            rows: (G, max_pages) page-table rows (all-trash for pad
            lanes, so their writes are harmless); cow_src/cow_dst: (G,)
            shared-tail duplication pairs applied before any write (the
            no-COW default 0/0 rewrites the trash page with itself);
            keys: (G, 2) per-request stream keys — the first-token draw
            folds in stream index 0, exactly the monolithic prefill's
            draw.  Returns the updated caches and each lane's sampled
            first token — meaningful only for lanes whose chunk
            completed the prompt."""
            caches = copy_paged_cache_page(caches, cow_src, cow_dst)
            logits, caches = decode_step(params, cfg, tokens, caches,
                                         starts, page_table=rows)
            last = jnp.take_along_axis(
                logits, (lens - 1)[:, None, None], axis=1)[:, 0]
            sub = jax.vmap(lambda k: jax.random.fold_in(k, 0))(keys)
            return caches, sample(last, sub)

        return wave

    def _build_decode_chunk(self):
        cfg, scfg = self.cfg, self.scfg
        sample = _slot_sampler(scfg)
        max_pos = scfg.max_len - 1
        paged = self._paged

        def chunk(params, caches, token, positions, active, remaining,
                  table, forced, forced_on, keys, counts):
            """Scan ``decode_chunk`` tokens; inactive slots are frozen
            (their rewrites land on already-written rows — or, paged, on
            the trash page) and emit -1.  ``table`` is the (B, max_pages)
            page table (all-trash dummy in dense mode).  ``forced`` /
            ``forced_on`` are (decode_chunk, B) teacher-forcing lanes:
            where ``forced_on`` a preempted request's stored token
            replaces the sampled one, replaying its stream verbatim so
            the rebuilt KV matches an uninterrupted run's.  ``keys`` /
            ``counts`` are the per-slot request keys and stream indices:
            step ``t`` of slot ``b`` draws with
            ``fold_in(keys[b], counts[b] + t)``, so replayed draws are
            discarded and fresh draws after a resume land on exactly
            the keys an uninterrupted run would have used — sampled
            streams are bit-stable under preemption."""
            page_table = table if paged else None

            def body(carry, xs):
                f_tok, f_on = xs
                caches, token, positions, active, remaining, counts = carry
                logits, caches = decode_step(params, cfg, token, caches,
                                             positions,
                                             page_table=page_table)
                nxt = sample(logits[:, -1], _fold_counts(keys, counts))
                nxt = jnp.where(f_on, f_tok, nxt)
                emitted = jnp.where(active, nxt, -1)
                remaining = remaining - active.astype(jnp.int32)
                alive = remaining > 0
                if scfg.eos_id >= 0:
                    alive = alive & (nxt != scfg.eos_id)
                new_active = active & alive
                positions = jnp.where(
                    active, jnp.minimum(positions + 1, max_pos), positions)
                token = jnp.where(active[:, None], nxt[:, None], token)
                carry = (caches, token, positions, new_active, remaining,
                         counts + 1)
                return carry, (emitted, active)

            init = (caches, token, positions, active, remaining, counts)
            carry, (toks, valid) = jax.lax.scan(
                body, init, (forced, forced_on), length=scfg.decode_chunk)
            return carry[:-1] + (toks, valid)

        return chunk

    def _build_draft(self):
        """The quantized draft program: a ``lax.scan`` of ``spec_k``
        decode steps under the *draft* config (nibble/quantized
        projections), proposing one token per step per slot.  Returns
        the drafted tokens and (temperature mode) each draw's full
        draft distribution — the verifier needs ``q(d)`` for rejection
        sampling.  Draft K/V writes land on rows the dense verify
        forward rewrites in the same round, so no quantized row ever
        survives into the attended history."""
        cfg, scfg = self._draft_cfg, self.scfg
        k = scfg.spec_k
        temp = scfg.temperature
        max_pos = scfg.max_len - 1
        paged = self._paged

        def draft(params, caches, token, positions, active, table,
                  forced, forced_on, keys, counts):
            """token: (B, 1) last emitted per slot; positions: (B,) its
            row; forced/forced_on: (spec_k, B) replay lanes (a resumed
            request's committed tokens are re-proposed verbatim and
            force-accepted in verify); keys/counts: per-slot stream
            keys + the stream index of each slot's first draft."""
            page_table = table if paged else None

            def body(carry, xs):
                f_tok, f_on = xs
                caches, token, positions, counts = carry
                logits, caches = decode_step(params, cfg, token, caches,
                                             positions,
                                             page_table=page_table)
                lg = logits[:, -1].astype(jnp.float32)
                if temp > 0.0:
                    probs = jax.nn.softmax(lg / temp, axis=-1)
                    dkeys = jax.vmap(jax.random.fold_in, (0, None))(
                        _fold_counts(keys, counts), _TAG_DRAFT)
                    nxt = jax.vmap(jax.random.categorical)(
                        dkeys, lg / temp).astype(jnp.int32)
                else:
                    # greedy drafts carry no distribution; a width-1
                    # dummy keeps the verify signature uniform
                    probs = jnp.zeros((lg.shape[0], 1), jnp.float32)
                    nxt = jnp.argmax(lg, axis=-1).astype(jnp.int32)
                nxt = jnp.where(f_on, f_tok, nxt)
                positions = jnp.where(
                    active, jnp.minimum(positions + 1, max_pos), positions)
                token = jnp.where(active[:, None], nxt[:, None], token)
                return (caches, token, positions, counts + 1), (nxt, probs)

            init = (caches, token, positions, counts)
            (caches, _, _, _), (drafts, dprobs) = jax.lax.scan(
                body, init, (forced, forced_on), length=k)
            return caches, drafts, dprobs     # (k, B), (k, B, V or 1)

        return draft

    def _build_verify(self):
        """The dense verify program: ONE multi-token forward evaluates
        the last emitted token plus all ``spec_k`` drafts per slot
        (``decode_step`` with S = k+1 — the same multi-position
        machinery the prefill path uses, not a third program shape per
        request mix), rewriting rows ``start .. start+k`` with dense
        K/V and returning, per slot, the emission candidates and the
        per-draft acceptance mask.

        Greedy: draft j is accepted iff it equals the dense argmax at
        its position, so every accepted token — and the correction
        token emitted at the first mismatch — is *exactly* the token
        the non-spec dense engine would have produced (bit-match by
        construction).  Temperature > 0: standard rejection sampling —
        accept draft ``d`` with probability ``min(1, p(d)/q(d))``,
        resample rejections from ``normalize(max(p - q, 0))``, and draw
        a bonus token from the dense distribution when every draft
        survives; the emitted stream is distributed exactly as the
        dense model's.  All draws are index-derived (stream keys +
        tags), never split-chained.  Replayed (forced) drafts are
        force-accepted: they are committed history, not proposals."""
        cfg, scfg = self.cfg, self.scfg
        k = scfg.spec_k
        temp = scfg.temperature
        paged = self._paged

        def verify(params, caches, token, drafts, start, table,
                   forced_on, dprobs, keys, counts):
            """token: (B, 1); drafts: (k, B) from the draft program;
            start: (B,) row of ``token``; forced_on: (k, B);
            dprobs: (k, B, V) draft distributions ((k, B, 1) dummy in
            greedy mode); counts: stream index of ``drafts[0]``."""
            page_table = table if paged else None
            tokens = jnp.concatenate([token, drafts.T], axis=1)  # (B,k+1)
            logits, caches = decode_step(params, cfg, tokens, caches,
                                         start, page_table=page_table)
            lg = logits.astype(jnp.float32)                   # (B,k+1,V)
            d = tokens[:, 1:]                                 # (B, k)
            f_on = forced_on.T                                # (B, k)
            if temp > 0.0:
                p = jax.nn.softmax(lg / temp, axis=-1)
                q = jnp.moveaxis(dprobs, 0, 1)                # (B, k, V)
                pd = jnp.take_along_axis(p[:, :-1], d[..., None],
                                         axis=-1)[..., 0]
                qd = jnp.take_along_axis(q, d[..., None], axis=-1)[..., 0]
                idx = counts[:, None] + jnp.arange(k)[None, :]
                step_keys = jax.vmap(lambda key, ii: jax.vmap(
                    lambda i: jax.random.fold_in(key, i))(ii))(keys, idx)
                u = jax.vmap(jax.vmap(lambda sk: jax.random.uniform(
                    jax.random.fold_in(sk, _TAG_ACCEPT), ())))(step_keys)
                accept = f_on | (u * qd <= pd)
                resid = jnp.maximum(p[:, :-1] - q, 0.0)
                rs = jnp.sum(resid, axis=-1, keepdims=True)
                resid = jnp.where(rs > 0, resid / rs, p[:, :-1])
                corr = jax.vmap(jax.vmap(
                    lambda sk, pr: jax.random.categorical(
                        jax.random.fold_in(sk, _TAG_RESAMPLE),
                        jnp.log(pr + 1e-30))))(step_keys, resid)
                bonus = jax.vmap(jax.random.categorical)(
                    _fold_counts(keys, counts + k), lg[:, -1] / temp)
                out = jnp.concatenate(
                    [jnp.where(accept, d, corr.astype(jnp.int32)),
                     bonus.astype(jnp.int32)[:, None]], axis=1)
            else:
                g = jnp.argmax(lg, axis=-1).astype(jnp.int32)  # (B,k+1)
                accept = f_on | (d == g[:, :-1])
                out = jnp.concatenate([jnp.where(f_on, d, g[:, :-1]),
                                       g[:, -1:]], axis=1)
            return caches, out, accept

        return verify

    # ------------------------------------------------------------------
    # host-side state
    # ------------------------------------------------------------------

    def reset(self, rng=None) -> None:
        """Clear queue/slots (compiled functions and cache buffers are
        kept — stale cache rows are invisible: decode overwrites row
        ``p`` before any query can attend to it, and recycled pages are
        re-filled by their next owner's prefill)."""
        b = self.scfg.batch
        # index-derived RNG: one base key per run; request r's stream
        # key is fold_in(base, r.id) and every draw folds in the token's
        # stream index (plus a tag for spec sub-draws).  No split chain
        # to advance means no draw can shift with admission order,
        # batch composition or preemption — sampled streams are
        # bit-stable under evict-and-resume.
        self._base_key = rng if rng is not None else jax.random.PRNGKey(0)
        self._req_keys: dict[int, np.ndarray] = {}
        self._slot_keys = np.zeros((b, 2), np.uint32)
        self._queue = _PriorityQueue(self.scfg.priority_aging_s)
        self._slots: list[Request | None] = [None] * b
        self._token = np.zeros((b, 1), np.int32)
        self._positions = np.zeros((b,), np.int32)
        self._active = np.zeros((b,), bool)
        self._remaining = np.zeros((b,), np.int32)
        self._finished: dict[int, Request] = {}
        # teacher-forcing lanes for resumed requests: tokens generated
        # before a preemption, waiting to be replayed through the chunk
        self._slot_forced: list[list[int]] = [[] for _ in range(b)]
        self.preemptions = 0
        self._stat_samples = 0
        self._stat_running = 0
        self._stat_in_use = 0
        # speculative-decoding accounting (zero when spec_decode off):
        # proposed/accepted count *fresh* drafts only (replayed forced
        # tokens are committed history, force-accepted by contract, and
        # would inflate the acceptance rate), and only up to each
        # round's emission clamp (EOS / remaining budget) — positions a
        # round could never emit are not proposals.
        self.spec_rounds = 0
        self.spec_slot_rounds = 0
        self.spec_tokens = 0
        self.spec_proposed = 0
        self.spec_accepted = 0
        self.spec_rollback_pages = 0
        # prefix-cache accounting: real tokens run through the prefill
        # stage (suffixes only, on a hit) vs prompt tokens served from
        # cached pages — the observable "prefilled only the suffix"
        self.prefill_tokens = 0
        self.cow_copies = 0
        self._prefix_hits = 0
        self._cached_prompt_tokens = 0
        self._total_prompt_tokens = 0
        # tail-latency accounting: wave/chunk dispatch counts, host-tier
        # swap traffic, and the decode steps a swap-in did NOT have to
        # replay (= generated rows restored by page copy)
        self.prefill_waves = 0
        self.decode_chunks = 0
        self.swap_outs = 0
        self.swap_ins = 0
        self.replay_steps_saved = 0
        self.prefix_demotions = 0
        self.prefix_cold_hits = 0
        self.prefix_capacity_reclaims = 0
        # wave-mode per-slot prefill cursor: next prompt position to
        # run, -1 = not prefilling; _slot_cow holds each lane's pending
        # (cow_src, cow_dst) pair until its final chunk applies it
        self._prefill_next = np.full((b,), -1, np.int64)
        self._slot_cow: list[tuple[int, int]] = [(0, 0)] * b
        self.prefix_cache: PrefixCache | None = None
        self.host_pool: HostPagePool | None = None
        if self._paged:
            self.allocator = PageAllocator(self._num_pages, reserved=1)
            self.page_table = PageTable(b, self._max_pages, trash_page=0,
                                        num_pages=self._num_pages,
                                        reserved=1)
            self._slot_pages: list[list[int] | None] = [None] * b
            if self._swap:
                self.host_pool = HostPagePool(
                    self.scfg.host_pages or 2 * self.allocator.capacity)
                # every host-tier extract/insert pads its page vector to
                # one fixed width (the per-slot maximum), so the eager
                # gather/scatter pair compiles exactly one shape — and
                # that compile is pre-paid here, on a trash-page
                # round-trip, instead of inside the serving loop at the
                # first preemption
                self._swap_pad = self._max_pages
                warm = extract_cache_pages(self._caches, [0],
                                           pad_to=self._swap_pad)
                self._caches = insert_cache_pages(self._caches, [0], warm,
                                                  pad_to=self._swap_pad)
                if self._mesh is not None:
                    self._caches = jax.device_put(self._caches,
                                                  self._cache_sh)
            if self.scfg.prefix_cache:
                self.prefix_cache = PrefixCache(self._page_size,
                                                self.allocator)
                if self.host_pool is not None:
                    self.prefix_cache.attach_cold_tier(
                        self._demote_page,
                        lambda hid: self.host_pool.free([hid]))
        else:
            # dense mode ships an all-zero dummy table so the chunk
            # signature (and its single compilation) is layout-invariant
            self.page_table = PageTable(b, 1, trash_page=0)

    @property
    def compile_counts(self) -> dict:
        """Compilations per stage — the refill-without-recompile claim
        is checkable: counts stay at 1 across arbitrary request mixes,
        page recyclings and preemptions (given a fixed ``prefill_len``
        slot budget).  Counted engine-side from distinct abstract call
        signatures (see ``_CountingJit``) — no jax-private probe.

        The pinned contract: a non-spec engine runs exactly
        ``{"prefill": 1, "decode_chunk": 1}`` once warm.  A spec engine
        replaces the chunk with the draft-side pair and runs exactly
        ``{"prefill": 1, "decode_chunk": 0, "draft": 1, "verify": 1}``
        — one quantized draft program, one dense multi-token verify
        program, and the chunk program never built or called.  Wave
        mode (``prefill_chunk``/``admit_group``) replaces the
        monolithic prefill with the wave program and runs exactly
        ``{"prefill": 0, "decode_chunk": 1, "prefill_chunk": 1}`` —
        every chunk width, lane occupancy and prefix-hit mix hits the
        same (G, C) signature.  Any other value is a recompile bug
        (``benchmarks/serve_bench.py`` raises on deviation)."""
        counts = {"prefill": (self._prefill_fn.compile_count
                              if self._prefill_fn is not None else 0),
                  "decode_chunk": (self._chunk_fn.compile_count
                                   if self._chunk_fn is not None else 0)}
        if self._wave:
            counts["prefill_chunk"] = self._wave_fn.compile_count
        if self._spec:
            counts["draft"] = self._draft_fn.compile_count
            counts["verify"] = self._verify_fn.compile_count
        return counts

    def stage_programs(self) -> dict:
        """The stage programs this engine actually built, as
        ``{stage_name: _CountingJit}`` — the entry point for
        ``repro.staticcheck``'s jaxpr layer, which re-lowers each
        recorded abstract signature and inspects the result.  Stages a
        mode never constructs (e.g. ``decode_chunk`` under spec
        decoding) are absent, mirroring ``compile_counts``."""
        stages = {}
        if self._prefill_fn is not None:
            stages["prefill"] = self._prefill_fn
        if self._wave_fn is not None:
            stages["prefill_chunk"] = self._wave_fn
        if self._chunk_fn is not None:
            stages["decode_chunk"] = self._chunk_fn
        if self._spec:
            stages["draft"] = self._draft_fn
            stages["verify"] = self._verify_fn
        return stages

    @property
    def stats(self) -> dict:
        """Scheduling counters for the run since the last ``reset``:
        ``preemptions`` (evict-and-resume events), ``occupancy`` (mean
        fraction of allocatable pool pages in use, sampled at each
        decode chunk; 0 in dense mode), ``concurrency`` (mean admitted
        requests per chunk) and ``pool_pages`` (device pool size)."""
        n = max(1, self._stat_samples)
        occ = (self._stat_in_use / (n * self.allocator.capacity)
               if self._paged else 0.0)
        return {"preemptions": self.preemptions,
                "occupancy": occ,
                "concurrency": self._stat_running / n,
                "pool_pages": self.allocator.num_pages if self._paged
                else 0,
                # prefix-cache counters (zero / empty without the cache):
                # hit_rate = prompt tokens served from cached pages over
                # all prompt tokens admitted; prefill_tokens = real
                # tokens actually run through the prefill stage
                "prefix_hits": self._prefix_hits,
                "prefix_hit_rate": (self._cached_prompt_tokens
                                    / max(1, self._total_prompt_tokens)),
                "prefill_tokens": self.prefill_tokens,
                "cow_copies": self.cow_copies,
                "prefix_pages": (len(self.prefix_cache)
                                 if self.prefix_cache is not None else 0),
                # speculative decoding (zeros with spec_decode off):
                # acceptance_rate = fresh drafts accepted / proposed;
                # tokens_per_step = tokens emitted per *sequence* per
                # draft+verify round (per slot-round, so it is
                # comparable to tools/spec_report's per-sequence
                # estimator; > 1 means each dense forward emitted more
                # than one token for that sequence)
                "spec_rounds": self.spec_rounds,
                "acceptance_rate": (self.spec_accepted
                                    / max(1, self.spec_proposed)),
                "tokens_per_step": (self.spec_tokens
                                    / max(1, self.spec_slot_rounds)),
                "spec_rollback_pages": self.spec_rollback_pages,
                # tail-latency counters: prefill_waves/decode_chunks =
                # program dispatches per stage; swap_out/swap_in =
                # host-tier page-swap events; replay_steps_saved =
                # decode rows restored by page copy instead of replay;
                # prefix_cold_* = cold-tier demotions and promoted-hit
                # pages (both 0 with the mechanisms off)
                "prefill_waves": self.prefill_waves,
                "decode_chunks": self.decode_chunks,
                "swap_out": self.swap_outs,
                "swap_in": self.swap_ins,
                "replay_steps_saved": self.replay_steps_saved,
                "host_pages": (self.host_pool.capacity
                               if self.host_pool is not None else 0),
                "prefix_cold_pages": (self.prefix_cache.cold_size
                                      if self.prefix_cache is not None
                                      else 0),
                "prefix_cold_hits": self.prefix_cold_hits,
                "prefix_demotions": self.prefix_demotions}

    @property
    def cache_token_bytes(self) -> int:
        """KV-cache bytes per cached token, summed over every layer's
        sequence-axis leaves (scales and block stacking included) —
        multiply by a request's ``cache_rows`` for its HBM footprint."""
        rows = (self._num_pages * self._page_size if self._paged
                else self.scfg.batch * self.scfg.max_len)
        total = 0
        for path, leaf in jax.tree_util.tree_flatten_with_path(
                self._caches)[0]:
            key = path[-1].key if hasattr(path[-1], "key") else None
            if key in _SEQ_CACHE_KEYS:
                total += leaf.size * leaf.dtype.itemsize
        return total // rows

    def _pages_for(self, req: Request) -> int:
        """Worst-case page count for a request: prompt rows plus one row
        per decode step except the last token (which is sampled but
        never written back)."""
        rows = len(req.prompt) + req.max_new_tokens - 1
        return pages_needed(rows, self._page_size)

    def _alloc_pages_for(self, req: Request) -> int:
        """Pages booked at admission: the worst case in reserve mode;
        the prompt pages plus the first decode page in incremental mode
        (later pages arrive via per-chunk top-up — resumed requests
        regrow the same way while their tokens replay).  A swapped-out
        request restores ``swap_rows`` live rows by page copy and then
        writes its next decode row, so incremental mode books exactly
        those; reserve mode keeps the worst case, which covers the
        swapped rows by construction (they were live under the same
        booking before eviction)."""
        if not self._incremental:
            return self._pages_for(req)
        if req.swap_pages is not None:
            return pages_needed(req.swap_rows + 1, self._page_size)
        rows = len(req.prompt)
        if req.max_new_tokens > 1:
            rows += 1                 # first decode write lands at row p_len
        return pages_needed(rows, self._page_size)

    # ------------------------------------------------------------------
    # host cold tier (swap_mode="host")
    # ------------------------------------------------------------------

    def _demote_page(self, page: int) -> int | None:
        """Prefix-cache demotion hook: copy one reclaimed device page
        into a fresh host page, returning its id (``None`` when the
        host pool is full — the caller then evicts its own oldest cold
        entry and retries, or drops the chunk outright)."""
        hids = self.host_pool.alloc(1)
        if hids is None:
            return None
        self.host_pool.store(
            hids[0], extract_cache_pages(self._caches, [page],
                                         pad_to=self._swap_pad)[0])
        self.prefix_demotions += 1
        return hids[0]

    def _promote_cold(self, keys: list, pages: list) -> None:
        """Load a run of cold prefix chunks back into freshly allocated
        device pages (which admission has already mapped behind the hot
        prefix, so global row order is preserved) and insert them into
        the hot index under their original chain keys."""
        hids = self.prefix_cache.pop_cold(keys)
        payloads = [self.host_pool.load(h) for h in hids]
        self._caches = insert_cache_pages(self._caches, pages, payloads,
                                          pad_to=self._swap_pad)
        if self._mesh is not None:
            # the eager scatter may drop the committed sharding; re-pin
            # before the next donating dispatch sees a layout mismatch
            self._caches = jax.device_put(self._caches, self._cache_sh)
        self.host_pool.free(hids)
        self.prefix_cold_hits += len(pages)

    def _prefix_insert(self, keys: list, pages: list) -> None:
        """Index a prompt's chunk chain, then enforce the optional
        ``prefix_cache_pages`` capacity cap: reclaim (LRU leaf-first,
        demoting to the cold tier when attached) down to the budget.
        Best-effort — pages still mapped by live slots are pinned and
        may hold the index above the cap until their slot finishes."""
        self.prefix_cache.insert(keys, pages)
        cap = self.scfg.prefix_cache_pages
        if cap and len(self.prefix_cache) > cap:
            self.prefix_capacity_reclaims += self.prefix_cache.reclaim(
                len(self.prefix_cache) - cap)

    def validate(self, prompt, max_new_tokens: int):
        """Submit-time validation, shared with the router (which must
        reject an unserveable request at *its* front door rather than
        crash a replica at placement): returns the canonicalized
        ``(prompt, clamped_new_tokens, truncated)`` triple or raises."""
        prompt = np.asarray(prompt, np.int32).reshape(-1)
        scfg = self.scfg
        if max_new_tokens < 1:
            raise ValueError(f"max_new_tokens must be >= 1, got "
                             f"{max_new_tokens}")
        if prompt.size == 0 or prompt.size >= scfg.max_len:
            raise ValueError(f"prompt length {prompt.size} must be in "
                             f"[1, max_len={scfg.max_len})")
        if scfg.prefill_len and prompt.size > scfg.prefill_len \
                and not self._has_mamba:
            raise ValueError(f"prompt length {prompt.size} exceeds the "
                             f"slot budget prefill_len={scfg.prefill_len}")
        budget = scfg.max_len - prompt.size
        truncated = max_new_tokens > budget
        clamped = min(max_new_tokens, budget)
        if self._paged:
            rows = prompt.size + clamped - 1
            need = pages_needed(rows, self._page_size)
            if need > self.allocator.capacity:
                raise ValueError(
                    f"request needs {need} pages but the pool capacity "
                    f"is {self.allocator.capacity}; raise num_pages or "
                    f"shorten the request")
        return prompt, clamped, truncated

    def submit(self, prompt, max_new_tokens: int, arrival: float = 0.0,
               priority: int = 0) -> int:
        """Queue one request; returns its id.  ``arrival`` (seconds from
        ``run()`` start) models staggered workloads — the request is not
        admitted to a slot before its arrival time.  ``priority`` orders
        admission (higher first; see ``ServeConfig.priority_aging_s``)
        and preemption (a strictly-higher-priority arrival may evict a
        running slot).  A ``max_new_tokens`` that cannot fit the
        ``max_len`` budget is clamped and flagged on the returned
        request (``Request.truncated``) — explicit, never mistaken for
        an early EOS."""
        prompt, clamped, truncated = self.validate(prompt, max_new_tokens)
        req = Request(id=self._next_id, prompt=prompt,
                      max_new_tokens=clamped,
                      arrival=arrival, priority=priority,
                      truncated=truncated)
        self._next_id += 1
        self._queue.push(req)
        return req.id

    # ------------------------------------------------------------------
    # scheduling loop
    # ------------------------------------------------------------------

    def _prefix_plan(self, req: Request):
        """(chunk_keys, shared_pages, cow_src, start, n_cold) for the
        longest usable cached prefix of ``req.prompt``.  Read-only (no
        refs taken, no promotions): ``_can_admit`` probes it,
        ``_place`` re-derives it and acquires.  A fully covered prompt
        caps sharing at every page but keeps the tail as ``cow_src``:
        the last token must still run through the model for its logits,
        and its KV write needs a private copy-on-write page.  With a
        cold tier attached, ``n_cold`` chunks demoted to host pages
        extend the hot run and are promoted into fresh device pages at
        placement — except a cold *tail* chunk that would fully cover
        the prompt, which is cheaper to re-prefill than to promote and
        then COW-duplicate."""
        if req.chunk_keys is None:
            req.chunk_keys = self.prefix_cache.chunk_keys(req.prompt)
        keys = req.chunk_keys
        hits = self.prefix_cache.match(keys)
        p_len = int(req.prompt.size)
        if hits and len(hits) * self._page_size == p_len:
            return keys, hits[:-1], hits[-1], p_len - 1, 0
        n_cold = self.prefix_cache.match_cold(keys, len(hits))
        if n_cold and (len(hits) + n_cold) * self._page_size == p_len:
            n_cold -= 1
        start = (len(hits) + n_cold) * self._page_size
        return keys, hits, 0, start, n_cold

    def _admission_pages(self, req: Request) -> int:
        """Fresh pages admission must allocate: the booked count minus
        pages served read-only from the prefix cache.  A swapped-out
        request restores its own private pages — the prefix plan does
        not apply (its prompt pages come back by copy, not mapping)."""
        booked = self._alloc_pages_for(req)
        if self.prefix_cache is None or req.swap_pages is not None:
            return booked
        _, shared, _, _, _ = self._prefix_plan(req)
        return booked - len(shared)

    def _can_admit(self, req: Request) -> bool:
        """Admission backpressure: in paged mode the pool must cover the
        request's booked pages (freed pages un-defer it later).  With
        the prefix cache, cached pages do not need allocating, and cold
        index entries are reclaimed (LRU, never this plan's own hits)
        before deferring."""
        if not self._paged:
            return True
        need = self._admission_pages(req)
        if self.allocator.can_alloc(need):
            return True
        if self.prefix_cache is not None:
            if req.swap_pages is not None:
                keep = set()
            else:
                _, shared, cow_src, _, _ = self._prefix_plan(req)
                keep = set(shared) | ({cow_src} if cow_src else set())
            self.prefix_cache.reclaim(need - self.allocator.available,
                                      keep=keep)
        return self.allocator.can_alloc(need)

    def _pick_victim(self, now: float, below: int | None = None
                     ) -> int | None:
        """Slot of the weakest running request — lowest aging-adjusted
        effective priority, ties broken by evicting the youngest.  With
        ``below``, only slots *strictly* weaker qualify (admission-time
        preemption must not thrash equal-priority requests)."""
        best, best_key = None, None
        for slot, req in enumerate(self._slots):
            if req is None:
                continue
            eff = self._queue.effective(req, now)
            if below is not None and eff >= below:
                continue
            key = (eff, -req.arrival, -req.id)
            if best_key is None or key < best_key:
                best, best_key = slot, key
        return best

    def _evict(self, slot: int, now: float) -> None:
        """Preempt a running slot: return its pages to the pool and
        requeue the request carrying every token generated so far (the
        replay lane restores them on re-admission)."""
        req = self._slots[slot]
        if self._slot_forced[slot]:
            # evicted mid-replay: splice the unreplayed tail back so the
            # requeued request carries the full generated stream
            req.tokens.extend(self._slot_forced[slot])
            self._slot_forced[slot] = []
        if self._wave and self._prefill_next[slot] >= 0:
            # evicted mid-prefill: nothing generated yet — drop the
            # partial chunk rows with the pages and restart the prompt
            # on re-admission
            self._prefill_next[slot] = -1
            self._slot_cow[slot] = (0, 0)
        elif self._swap and req.tokens and self._slot_pages[slot]:
            # host-tier swap: copy the live KV rows out so resume is an
            # O(pages) restore instead of an O(generated) replay.  The
            # slot's decode position — not len(tokens) — is the row
            # count: a mid-replay victim carries spliced tokens whose
            # rows were never rebuilt yet.  A full host pool silently
            # falls back to replay-resume.
            rows = int(self._positions[slot])
            hids = self.host_pool.alloc(
                pages_needed(rows, self._page_size))
            if hids is not None:
                payloads = extract_cache_pages(
                    self._caches, self._slot_pages[slot][:len(hids)],
                    pad_to=self._swap_pad)
                for h, pl in zip(hids, payloads):
                    self.host_pool.store(h, pl)
                req.swap_pages = hids
                req.swap_rows = rows
                self.swap_outs += 1
        if self._paged and self._slot_pages[slot] is not None:
            self.allocator.free(self._slot_pages[slot])
            self._slot_pages[slot] = None
            self.page_table.clear(slot)
        self._slots[slot] = None
        self._active[slot] = False
        req.preemptions += 1
        self.preemptions += 1
        self._queue.push(req)

    def _evictable_pages(self, now: float, cutoff: int) -> int:
        """Pages the pool could recover by evicting every runner whose
        effective priority sits strictly below ``cutoff`` — the
        feasibility bound both preemption paths check before evicting
        anyone, so no runner is ever sacrificed for an arrival that
        still could not fit afterwards.

        Refcount-aware: a shared prefix page counts once, and only when
        every reference to it belongs to the would-be victims — plus,
        at most, the prefix index, whose pin the LRU reclaim can drop
        once the victims are gone (a holder that survives keeps the
        page off the free list, so such pages recover nothing).  Cold
        index entries reclaimable *today* are counted separately; the
        sets are disjoint (reclaimable-now pages have no slot holder),
        so no page is counted twice.  The index-pin credit cannot
        overcount either: a pinned chunk only becomes droppable when
        its whole descendant chain goes cold, and any surviving holder
        of a descendant chunk necessarily holds every ancestor too —
        which would show up in this very refcount check."""
        held: dict[int, int] = {}
        for s, r in enumerate(self._slots):
            if r is not None and self._queue.effective(r, now) < cutoff:
                for p in self._slot_pages[s] or ():
                    held[p] = held.get(p, 0) + 1
        pinned = (set(self.prefix_cache.pages)
                  if self.prefix_cache is not None else set())
        freed = sum(1 for p, c in held.items()
                    if self.allocator.refcount(p) == c + (p in pinned))
        cold = (self.prefix_cache.reclaimable()
                if self.prefix_cache is not None else 0)
        return self.allocator.available + freed + cold

    def _admit(self, now: float) -> None:
        """Admit arrived requests into free slots, best effective
        priority first; a strictly-higher-priority arrival blocked on a
        slot or on pages preempts the weakest runner(s)."""
        while True:
            free = next((s for s in range(self.scfg.batch)
                         if self._slots[s] is None), None)
            cand = self._queue.peek(now)
            if cand is None:
                return
            cutoff = self._queue.effective(cand, now)
            if free is None:
                # all slots busy: evict for the slot only if the
                # arrival's pages are also coverable, else the victim
                # would lose its slot to an inadmissible head-of-queue
                if self._paged and (self._evictable_pages(now, cutoff)
                                    < self._admission_pages(cand)):
                    return
                victim = self._pick_victim(now, below=cutoff)
                if victim is None:
                    return
                self._evict(victim, now)
                continue
            req = self._queue.pop(now, admit=self._can_admit)
            if req is None:
                # arrived but backpressured on pages: evict strictly
                # weaker runners until the pool covers it, else defer
                # (same feasibility bound before any eviction)
                if (self._evictable_pages(now, cutoff)
                        < self._admission_pages(cand)):
                    return
                while not self._can_admit(cand):
                    victim = self._pick_victim(now, below=cutoff)
                    if victim is None:
                        return
                    self._evict(victim, now)
                req = self._queue.pop(now, admit=self._can_admit)
                if req is None:
                    return
            self._place(free, req, now)

    def _prefix_place(self, slot: int, req: Request, rng):
        """Prefix-cache admission: map the cached prefix read-only, book
        only the remaining pages, and run the uncached suffix through
        the shared compiled prefill (a miss is simply ``start == 0``).
        Afterwards the prompt's full page-aligned chunks — freshly
        written and mapped alike — are inserted into the index, which
        takes its own page references so they outlive this request.
        Returns the first-token logits sample."""
        p_len = int(req.prompt.size)
        keys, shared, cow_src, start, n_cold = self._prefix_plan(req)
        shared = self.prefix_cache.acquire(keys[:len(shared)])
        fresh = self.allocator.alloc(self._alloc_pages_for(req)
                                     - len(shared))
        if fresh is None:             # _can_admit vouched for this plan
            raise RuntimeError("page pool changed between admission "
                               "check and placement")
        if n_cold:
            # promote the cold run into the first fresh pages — they
            # sit right behind the hot prefix in the table row, so the
            # restored rows land at their original global positions
            self._promote_cold(keys[len(shared):len(shared) + n_cold],
                               fresh[:n_cold])
        cow_dst = fresh[0] if cow_src else 0
        pages = shared + fresh
        self.page_table.assign(slot, pages, shared=set(shared))
        self._slot_pages[slot] = pages
        req.cache_rows = max(req.cache_rows,
                             len(pages) * self._page_size)
        sfx = req.prompt[start:]
        sfx_len = p_len - start
        # the splice buffer must span every key position [0, p_len):
        # cached rows occupy [0, start) and the fresh suffix lands at
        # [start, p_len), so without a fixed slot budget the buffer
        # pads to the FULL prompt length, not the suffix length (which
        # would roll the fresh keys off the end of a short buffer)
        pad_len = self.scfg.prefill_len or p_len
        padded = np.zeros((1, pad_len), np.int32)
        padded[0, :sfx_len] = sfx
        self._caches, first = self._prefill_fn(
            self.params, self._caches, jnp.asarray(padded), sfx_len,
            slot, jnp.asarray(self.page_table.row(slot)), start,
            cow_src, cow_dst, rng)
        self._prefix_insert(keys, pages)
        self.prefill_tokens += sfx_len
        self._cached_prompt_tokens += start
        self._prefix_hits += bool(shared or n_cold or cow_src)
        self.cow_copies += bool(cow_src)
        return first

    def _place(self, slot: int, req: Request, now: float) -> None:
        """Prefill a request into a free slot.  Fresh requests sample
        their first token from the prefill logits; resumed requests
        (non-empty ``tokens``) reuse their stored first token and queue
        the rest on the slot's teacher-forcing lane, so the rebuilt KV
        — and, for greedy decode, every later token — bit-matches an
        uninterrupted run."""
        p_len = int(req.prompt.size)
        resumed = bool(req.tokens)
        # index-derived stream key: the same request always gets the
        # same key, whether fresh or re-admitted after a preemption.
        # The prefill's first-token draw is stream index 0.
        key = self._req_keys.get(req.id)
        if key is None:
            key = np.asarray(jax.random.fold_in(self._base_key, req.id),
                             np.uint32)
            self._req_keys[req.id] = key
        self._slot_keys[slot] = key
        if req.swap_pages is not None:
            # O(pages) resume: restore the swapped rows by copy — no
            # prefill, no replay (the prompt tokens were counted at the
            # original admission)
            self._swap_in(slot, req)
            return
        self._total_prompt_tokens += p_len
        if self._wave:
            # wave mode: map pages now, then advance one chunk per
            # scheduler step through the shared wave program
            self._wave_admit(slot, req)
            return
        sub = jax.random.fold_in(jnp.asarray(key), 0)
        if self.prefix_cache is not None:
            first = self._prefix_place(slot, req, sub)
        else:
            if self._has_mamba or not self.scfg.prefill_len:
                pad_len = p_len          # exact-length prefill
            else:
                pad_len = self.scfg.prefill_len
            if self._paged:
                # tokens stay at pad_len (page-rounding them would feed
                # extra pad tokens through mamba mixers); the prefill
                # stage zero-grows the cache to whole pages instead
                pages = self.allocator.alloc(self._alloc_pages_for(req))
                self.page_table.assign(slot, pages)
                self._slot_pages[slot] = pages
                req.cache_rows = max(req.cache_rows,
                                     len(pages) * self._page_size)
            else:
                req.cache_rows = self.scfg.max_len
            padded = np.zeros((1, pad_len), np.int32)
            padded[0, :p_len] = req.prompt
            self.prefill_tokens += p_len
            self._caches, first = self._prefill_fn(
                self.params, self._caches, jnp.asarray(padded), p_len,
                slot, jnp.asarray(self.page_table.row(slot)), sub)
        if resumed:
            tok = req.tokens[0]
            self._slot_forced[slot] = req.tokens[1:]
            req.tokens = [tok]
        else:
            self._slot_forced[slot] = []
            tok = int(first)
            req.tokens.append(tok)
            req.t_first = time.perf_counter() - self._t0
            req.t_tokens.append(req.t_first)
        done = (req.max_new_tokens <= 1
                or (self.scfg.eos_id >= 0 and tok == self.scfg.eos_id))
        if done:
            self._finish(req, slot)
        else:
            self._slots[slot] = req
            self._token[slot, 0] = tok
            self._positions[slot] = p_len
            self._active[slot] = True
            self._remaining[slot] = req.max_new_tokens - 1

    def _swap_in(self, slot: int, req: Request) -> None:
        """Resume a swapped-out request by page copy: restore its live
        KV rows from the host tier into freshly allocated device pages
        and re-point the slot's table row — O(pages) host↔device
        traffic in place of O(generated) replayed decode steps.  The
        restore is a bit-copy, so the resumed stream (greedy, sampled
        or speculative) continues exactly where it stopped; tokens
        emitted but never written back re-enter the teacher-forcing
        lane as usual."""
        p_len = int(req.prompt.size)
        pages = self.allocator.alloc(self._alloc_pages_for(req))
        if pages is None:             # _can_admit vouched for this plan
            raise RuntimeError("page pool changed between admission "
                               "check and placement")
        n = len(req.swap_pages)
        payloads = [self.host_pool.load(h) for h in req.swap_pages]
        self._caches = insert_cache_pages(self._caches, pages[:n],
                                          payloads, pad_to=self._swap_pad)
        if self._mesh is not None:
            # the eager scatter may drop the committed sharding; re-pin
            # before the next donating dispatch sees a layout mismatch
            self._caches = jax.device_put(self._caches, self._cache_sh)
        self.host_pool.free(req.swap_pages)
        req.swap_pages = None
        self.page_table.assign(slot, pages)
        self._slot_pages[slot] = pages
        req.cache_rows = max(req.cache_rows,
                             len(pages) * self._page_size)
        # rows [0, swap_rows) are restored; tokens past the last one
        # written back replay through the forced lane, and the stream
        # resumes at the position the eviction interrupted
        committed = req.swap_rows - p_len + 1
        self._slot_forced[slot] = list(req.tokens[committed:])
        req.tokens = req.tokens[:committed]
        self._slots[slot] = req
        self._token[slot, 0] = int(req.tokens[-1])
        self._positions[slot] = req.swap_rows
        self._active[slot] = True
        self._remaining[slot] = req.max_new_tokens - committed
        self.swap_ins += 1
        self.replay_steps_saved += req.swap_rows - p_len
        req.swap_rows = 0

    def _wave_admit(self, slot: int, req: Request) -> None:
        """Wave-mode admission: allocate and map the request's pages
        now, but run no model code — the slot parks as an inactive
        *prefilling* lane (``_prefill_next`` ≥ 0) and advances one
        prompt chunk per scheduler step through the shared wave
        program.  Frozen-slot safety: the lane's decode position parks
        at ``max_len - 1``, whose garbage rewrites land past every row
        a prompt chunk attends and are overwritten by the slot's own
        decode before they could ever be read."""
        p_len = int(req.prompt.size)
        cow = (0, 0)
        start = 0
        if self.prefix_cache is not None:
            keys, shared, cow_src, start, n_cold = self._prefix_plan(req)
            shared = self.prefix_cache.acquire(keys[:len(shared)])
            fresh = self.allocator.alloc(self._alloc_pages_for(req)
                                         - len(shared))
            if fresh is None:         # _can_admit vouched for this plan
                raise RuntimeError("page pool changed between admission "
                                   "check and placement")
            if n_cold:
                self._promote_cold(
                    keys[len(shared):len(shared) + n_cold],
                    fresh[:n_cold])
            if cow_src:
                cow = (cow_src, fresh[0])
            pages = shared + fresh
            self.page_table.assign(slot, pages, shared=set(shared))
            self._cached_prompt_tokens += start
            self._prefix_hits += bool(shared or n_cold or cow_src)
        else:
            pages = self.allocator.alloc(self._alloc_pages_for(req))
            if pages is None:
                raise RuntimeError("page pool changed between admission "
                                   "check and placement")
            self.page_table.assign(slot, pages)
        self._slot_pages[slot] = pages
        req.cache_rows = max(req.cache_rows,
                             len(pages) * self._page_size)
        self._slots[slot] = req
        self._prefill_next[slot] = start
        self._slot_cow[slot] = cow
        self._slot_forced[slot] = []
        self._token[slot, 0] = 0
        self._positions[slot] = self.scfg.max_len - 1
        self._active[slot] = False
        self._remaining[slot] = 0

    def _run_wave(self, now: float) -> None:
        """One wave: advance up to ``admit_group`` prefilling lanes by
        one prompt chunk each through the single compiled wave program.
        Pad lanes ride along as no-ops (all-trash table rows, so their
        writes are harmless); a lane whose chunk completes its prompt
        samples its first token and unfreezes into decode."""
        G, C = self._wave_group, self._wave_chunk
        lanes = [s for s in range(self.scfg.batch)
                 if self._prefill_next[s] >= 0][:G]
        tokens = np.zeros((G, C), np.int32)
        lens = np.ones((G,), np.int32)
        starts = np.zeros((G,), np.int32)
        rows = np.zeros((G, self._max_pages), np.int32)
        cow_src = np.zeros((G,), np.int32)
        cow_dst = np.zeros((G,), np.int32)
        keys = np.zeros((G, 2), np.uint32)
        real = []
        for i, s in enumerate(lanes):
            req = self._slots[s]
            st = int(self._prefill_next[s])
            p_len = int(req.prompt.size)
            n = min(C, p_len - st)
            tokens[i, :n] = req.prompt[st:st + n]
            lens[i] = n
            starts[i] = st
            rows[i] = self.page_table.row(s)
            cs, cd = self._slot_cow[s]
            if cs and st + n == p_len:
                # the COW pair applies with the final chunk — the only
                # one that writes into the duplicated tail page
                cow_src[i], cow_dst[i] = cs, cd
                self.cow_copies += 1
            keys[i] = self._slot_keys[s]
            real.append(n)
            self.prefill_tokens += n
        self.prefill_waves += 1
        self._caches, first = self._wave_fn(
            self.params, self._caches, jnp.asarray(tokens),
            jnp.asarray(lens), jnp.asarray(starts), jnp.asarray(rows),
            jnp.asarray(cow_src), jnp.asarray(cow_dst),
            jnp.asarray(keys))
        first = np.asarray(first)
        for i, s in enumerate(lanes):
            req = self._slots[s]
            nxt = int(self._prefill_next[s]) + real[i]
            if nxt >= int(req.prompt.size):
                self._wave_finish(s, req, int(first[i]))
            else:
                self._prefill_next[s] = nxt

    def _wave_finish(self, slot: int, req: Request, first: int) -> None:
        """A lane's final chunk ran: index the prompt's pages (prefix
        cache), commit the first token and unfreeze the slot — the
        exact epilogue of a monolithic placement, shared verbatim so
        wave and monolithic admissions are indistinguishable
        downstream."""
        self._prefill_next[slot] = -1
        self._slot_cow[slot] = (0, 0)
        if self.prefix_cache is not None:
            self._prefix_insert(req.chunk_keys, self._slot_pages[slot])
        if req.tokens:                # resumed: replay, don't resample
            tok = req.tokens[0]
            self._slot_forced[slot] = req.tokens[1:]
            req.tokens = [tok]
        else:
            self._slot_forced[slot] = []
            tok = first
            req.tokens.append(tok)
            req.t_first = time.perf_counter() - self._t0
            req.t_tokens.append(req.t_first)
        done = (req.max_new_tokens <= 1
                or (self.scfg.eos_id >= 0 and tok == self.scfg.eos_id))
        if done:
            self._finish(req, slot)
            self._slots[slot] = None
        else:
            self._token[slot, 0] = tok
            self._positions[slot] = int(req.prompt.size)
            self._active[slot] = True
            self._remaining[slot] = req.max_new_tokens - 1

    def _finish(self, req: Request, slot: int | None) -> None:
        req.t_done = time.perf_counter() - self._t0
        self._finished[req.id] = req
        self._req_keys.pop(req.id, None)
        if slot is not None:
            self._slot_forced[slot] = []
        if self._paged and slot is not None \
                and self._slot_pages[slot] is not None:
            # recycle: the freed pages may be handed to the very next
            # admission; the departing slot's table row is re-pointed at
            # the trash page so its frozen idempotent decode writes
            # cannot touch the new owner.  In incremental mode an
            # early-EOS request held only its live-token pages, so the
            # unreached tail was never booked at all.
            self.allocator.free(self._slot_pages[slot])
            self._slot_pages[slot] = None
            self.page_table.clear(slot)

    def _top_up(self, now: float) -> None:
        """Incremental mode: before a chunk, grow any active slot whose
        writes would cross its allocated page boundary.  When the pool
        is dry, preempt the weakest runner — possibly the needy slot
        itself, which then resumes once pages free up."""
        # rows a slot may make LIVE this dispatch: decode_chunk steps,
        # or — spec mode — up to spec_k accepted drafts plus the bonus
        # token.  Spec writes past the accepted length land on trash
        # (unbooked table tail) and are rolled back, so booking only
        # covers acceptable rows.
        chunk_steps = (self.scfg.spec_k + 1 if self._spec
                       else self.scfg.decode_chunk)
        for slot in range(self.scfg.batch):
            req = self._slots[slot]
            if req is None or not self._active[slot]:
                continue
            steps = min(chunk_steps, int(self._remaining[slot]))
            need = pages_needed(int(self._positions[slot]) + steps,
                                self._page_size)
            while need > self.page_table.live_len(slot):
                deficit = need - self.page_table.live_len(slot)
                got = self.allocator.alloc(deficit)
                if got is not None:
                    self.page_table.extend(slot, got)
                    self._slot_pages[slot].extend(got)
                    req.cache_rows = max(
                        req.cache_rows,
                        len(self._slot_pages[slot]) * self._page_size)
                    break
                # cold prefix pages go before any runner is preempted
                if self.prefix_cache is not None and \
                        self.prefix_cache.reclaim(
                            deficit - self.allocator.available):
                    continue
                victim = self._pick_victim(now)
                # never None: this slot itself is running, hence a
                # candidate; self-eviction ends its top-up
                self._evict(victim, now)
                if victim == slot:
                    break

    def _run_chunk(self, now: float) -> None:
        if self._incremental:
            self._top_up(now)
            if not self._active.any():
                return               # top-up evicted the last runner
        b = self.scfg.batch
        nsteps = self.scfg.decode_chunk
        forced = np.full((nsteps, b), -1, np.int32)
        forced_on = np.zeros((nsteps, b), bool)
        for slot in range(b):
            buf = self._slot_forced[slot]
            if buf and self._slots[slot] is not None:
                n = min(nsteps, len(buf))
                forced[:n, slot] = buf[:n]
                forced_on[:n, slot] = True
                del buf[:n]
        self._stat_samples += 1
        self._stat_running += sum(r is not None for r in self._slots)
        self.decode_chunks += 1
        if self._paged:
            self._stat_in_use += self.allocator.in_use
        counts = np.asarray(
            [len(r.tokens) if r is not None else 0 for r in self._slots],
            np.int32)
        (self._caches, token, positions, active, remaining,
         toks, valid) = self._chunk_fn(
            self.params, self._caches, jnp.asarray(self._token),
            jnp.asarray(self._positions), jnp.asarray(self._active),
            jnp.asarray(self._remaining),
            jnp.asarray(self.page_table.asarray()),
            jnp.asarray(forced), jnp.asarray(forced_on),
            jnp.asarray(self._slot_keys), jnp.asarray(counts))
        self._token = np.array(token)        # copies: host state is mutable
        self._positions = np.array(positions)
        self._active = np.array(active)
        self._remaining = np.array(remaining)
        toks, valid = np.asarray(toks), np.asarray(valid)
        tnow = time.perf_counter() - self._t0
        for slot, req in enumerate(self._slots):
            if req is None:
                continue
            for t in range(toks.shape[0]):
                if not valid[t, slot]:
                    break
                tok = int(toks[t, slot])
                req.tokens.append(tok)
                if len(req.t_tokens) < len(req.tokens):
                    # replayed tokens keep their original stamps
                    req.t_tokens.append(tnow)
                if (len(req.tokens) >= req.max_new_tokens
                        or (self.scfg.eos_id >= 0
                            and tok == self.scfg.eos_id)):
                    self._finish(req, slot)
                    self._slots[slot] = None
                    break

    def _spec_rollback(self, slot: int) -> None:
        """Roll a slot's rejected draft tail back as a page-table
        operation: truncate the live prefix to the pages its *accepted*
        rows need and return the tail pages to the allocator.  No cache
        row is copied or zeroed — the junk rows in the freed pages are
        exactly the idempotent writes the trash-page invariant already
        tolerates, and the truncated table entries point at the trash
        page so the freed pages' next owner is never aliased.  Only
        incremental mode books pages past the live prefix mid-stream;
        reserve-mode bookings are worst-case by contract and stay put.

        Shared prefix pages are unreachable by construction: the keep
        count covers at least the prompt rows plus one emitted token,
        which is strictly more pages than the prompt's shared full
        chunks."""
        if not self._incremental:
            return
        keep = pages_needed(int(self._positions[slot]), self._page_size)
        removed = self.page_table.truncate(slot, keep)
        if removed:
            self.allocator.free(removed)
            del self._slot_pages[slot][keep:]
            self.spec_rollback_pages += len(removed)

    def _run_spec_round(self, now: float) -> None:
        """One speculation round: quantized draft of ``spec_k`` tokens
        per slot, ONE dense multi-token verify forward over all draft
        positions, then a host-side walk that emits the accepted prefix
        (plus the correction or bonus token) and rolls back whatever
        the round over-wrote.  Greedy rounds emit exactly the stream
        the non-spec dense engine would."""
        if self._incremental:
            self._top_up(now)
            if not self._active.any():
                return               # top-up evicted the last runner
        b = self.scfg.batch
        k = self.scfg.spec_k
        forced = np.full((k, b), -1, np.int32)
        forced_on = np.zeros((k, b), bool)
        for slot in range(b):
            buf = self._slot_forced[slot]
            if buf and self._slots[slot] is not None:
                n = min(k, len(buf))
                forced[:n, slot] = buf[:n]
                forced_on[:n, slot] = True
                del buf[:n]
        self._stat_samples += 1
        self._stat_running += sum(r is not None for r in self._slots)
        if self._paged:
            self._stat_in_use += self.allocator.in_use
        counts = np.asarray(
            [len(r.tokens) if r is not None else 0 for r in self._slots],
            np.int32)
        start = self._positions.copy()
        keys = jnp.asarray(self._slot_keys)
        counts_j = jnp.asarray(counts)
        table = jnp.asarray(self.page_table.asarray())
        f_on = jnp.asarray(forced_on)
        token = jnp.asarray(self._token)
        start_j = jnp.asarray(start)
        # draft → verify stay device-side: the drafted tokens and their
        # distributions flow straight into the verify dispatch
        self._caches, drafts, dprobs = self._draft_fn(
            self.params, self._caches, token, start_j,
            jnp.asarray(self._active), table, jnp.asarray(forced), f_on,
            keys, counts_j)
        self._caches, out, accept = self._verify_fn(
            self.params, self._caches, token, drafts, start_j, table,
            f_on, dprobs, keys, counts_j)
        out = np.asarray(out)            # (B, k+1) emission candidates
        accept = np.asarray(accept)      # (B, k) per-draft verdicts
        self.spec_rounds += 1
        tnow = time.perf_counter() - self._t0
        eos = self.scfg.eos_id
        for slot in range(b):
            req = self._slots[slot]
            if req is None or not self._active[slot]:
                continue
            self.spec_slot_rounds += 1
            r = int(self._remaining[slot])
            e = 0
            # a replay longer than k spills into the next round: while
            # committed history remains buffered, the fresh bonus token
            # must NOT be emitted — it would splice a new token into a
            # stream the client has already seen
            more_forced = bool(self._slot_forced[slot])
            # emission walk: position j emits the accepted draft or the
            # correction token; the bonus position (j == k) is only
            # reached when every draft survived.  Positions past the
            # remaining budget or an EOS are never proposals.
            for j in range(k + 1):
                if j == k and more_forced:
                    break
                tok = int(out[slot, j])
                req.tokens.append(tok)
                if len(req.t_tokens) < len(req.tokens):
                    # replayed tokens keep their original stamps
                    req.t_tokens.append(tnow)
                e += 1
                r -= 1
                self.spec_tokens += 1
                if j < k and not forced_on[j, slot]:
                    self.spec_proposed += 1
                    if accept[slot, j]:
                        self.spec_accepted += 1
                if (r <= 0 or (eos >= 0 and tok == eos) or j == k
                        or not accept[slot, j]):
                    break
            self._positions[slot] = int(start[slot]) + e
            self._remaining[slot] = r
            self._token[slot, 0] = int(req.tokens[-1])
            if (len(req.tokens) >= req.max_new_tokens
                    or (eos >= 0 and int(req.tokens[-1]) == eos)):
                self._finish(req, slot)
                self._slots[slot] = None
                self._active[slot] = False
            elif self._paged:
                self._spec_rollback(slot)

    def start(self, t0: float | None = None) -> None:
        """Anchor the run clock (arrivals and latency stamps are
        relative to it).  The router starts every replica on one shared
        ``t0`` so fleet-level percentiles are comparable."""
        self._t0 = time.perf_counter() if t0 is None else t0

    def step(self, wait: bool = True) -> bool:
        """One scheduler iteration: admit arrived requests, then run one
        decode chunk (or speculation round) if anything is active.
        Returns ``False`` once the engine is drained — no queued and no
        running requests.  ``wait=False`` skips the idle sleep before a
        future arrival (the router drives many replicas from one thread
        and must not block on the idlest one)."""
        if not (len(self._queue)
                or any(r is not None for r in self._slots)):
            return False
        now = time.perf_counter() - self._t0
        self._admit(now)
        # wave mode: slots mid-prefill are inactive but NOT idle — they
        # make progress through _run_wave below, so neither the idle
        # sleep nor the stall check may fire while any lane prefills
        prefilling = self._wave and bool((self._prefill_next >= 0).any())
        if not self._active.any() and not prefilling:
            if not len(self._queue):
                return False           # drained this iteration
            nxt = self._queue.next_arrival()
            wait_s = nxt - (time.perf_counter() - self._t0)
            if wait_s > 0:             # idle until the next arrival
                if wait:
                    time.sleep(min(wait_s, 0.05))
                return True
            if nxt > now:
                # the request arrived *during* this iteration's _admit
                # window (arrival gating hid it from the `now` snapshot
                # _admit was given) — loop back and admit it with a
                # fresh clock, this is a healthy staggered workload,
                # not a stall
                return True
            # a request _admit could already see went unadmitted with
            # every slot idle.  An idle engine holds no pages, so this
            # is not backpressure — it is a page leak or an
            # unsatisfiable request, and overcommit/preemption make the
            # state reachable where it was once provably not.  Fail
            # loudly rather than spin on _admit forever.
            detail = ""
            if self._paged:
                cached = (len(self.prefix_cache.pages)
                          if self.prefix_cache is not None else 0)
                detail = (f" ({self.allocator.in_use} pages "
                          f"still in use — {cached} pinned by "
                          f"the prefix index — "
                          f"{self.allocator.available} free of "
                          f"{self.allocator.capacity} "
                          f"allocatable)")
                if self.host_pool is not None:
                    swapped = sum(
                        1 for e in self._queue._heap
                        if e[3].swap_pages is not None)
                    detail += (f" [host tier: "
                               f"{self.host_pool.in_use}/"
                               f"{self.host_pool.capacity} pages held, "
                               f"{swapped} swapped request(s) queued]")
            raise RuntimeError(
                f"serve scheduler stalled: {len(self._queue)} "
                f"arrived request(s) cannot be admitted with "
                f"all slots idle{detail}")
        now = time.perf_counter() - self._t0
        if prefilling:
            self._run_wave(now)
        if self._active.any():
            if self._spec:
                self._run_spec_round(now)
            else:
                self._run_chunk(now)
        return True

    def drain(self) -> dict[int, Request]:
        """Hand over (and clear) the finished-request map."""
        out, self._finished = self._finished, {}
        return out

    def run(self) -> dict[int, Request]:
        """Drain the queue: admit → chunked decode → refill, until every
        submitted request has finished.  Returns {id: Request} with
        per-request timing (t_first / t_done relative to run start)."""
        self.start()
        while self.step():
            pass
        return self.drain()

    def release_prefix_cache(self) -> None:
        """Drop every page reference the prefix index holds (teardown /
        leak checks: after a drained engine releases the cache, the
        allocator must report ``in_use == 0``)."""
        if self.prefix_cache is not None:
            self.prefix_cache.drop()

    def leaked_pages(self) -> int:
        """Pages still held after a drained engine has released every
        legitimate holder (call ``release_prefix_cache`` first when the
        prefix index is on) — anything non-zero is a leak, on the
        device pool *or* the host cold tier (a drained engine has no
        swapped requests and no cold entries left to hold host pages).
        0 in dense mode (there is no pool to leak from)."""
        if not self._paged:
            return 0
        host = self.host_pool.in_use if self.host_pool is not None else 0
        return self.allocator.in_use + host

    # ------------------------------------------------------------------
    # batch convenience API (examples / tests)
    # ------------------------------------------------------------------

    def generate(self, prompts: jax.Array, n_new: int,
                 rng=None) -> jax.Array:
        """prompts: (B, S) int32 → (B, S + n_new) tokens.

        Uniform-workload wrapper over submit/run: B must equal the slot
        count and every request decodes exactly ``n_new`` tokens, so
        the output is rectangular (build the engine with the default
        ``eos_id=-1``; early EOS stops raise)."""
        prompts = np.asarray(prompts, np.int32)
        b, s = prompts.shape
        if b != self.scfg.batch:
            raise ValueError(f"prompts batch {b} != ServeConfig.batch "
                             f"{self.scfg.batch}")
        if s + n_new > self.scfg.max_len:
            raise ValueError(f"prompt_len {s} + n_new {n_new} exceeds "
                             f"max_len {self.scfg.max_len}")
        self.reset(rng=rng if rng is not None else jax.random.PRNGKey(0))
        ids = [self.submit(prompts[i], n_new) for i in range(b)]
        done = self.run()
        short = [i for i in ids if len(done[i].tokens) != n_new]
        if short:
            # the max_len pre-check above rules out submit-time
            # truncation, so a short ragged output here can only be an
            # early EOS stop — say which, instead of guessing
            if any(done[i].truncated for i in short):
                raise RuntimeError(
                    f"generate() needs rectangular output but request(s) "
                    f"{short} were truncated at the max_len="
                    f"{self.scfg.max_len} budget")
            raise RuntimeError(
                f"generate() needs rectangular output but request(s) "
                f"{short} stopped at eos_id={self.scfg.eos_id} before "
                f"emitting n_new={n_new} tokens; use submit()/run() for "
                f"ragged workloads or build the engine with eos_id=-1")
        gen = np.stack([np.asarray(done[i].tokens, np.int32) for i in ids])
        return jnp.concatenate([jnp.asarray(prompts), jnp.asarray(gen)],
                               axis=1)
