"""The assigned input-shape grid + ``input_specs`` (ShapeDtypeStruct
stand-ins, no allocation) for every (arch × shape) dry-run cell."""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig

__all__ = ["SHAPES", "ShapeCase", "runnable", "skip_reason",
           "train_input_structs", "decode_input_structs",
           "prefill_input_structs"]


@dataclasses.dataclass(frozen=True)
class ShapeCase:
    name: str
    kind: str          # train | prefill | decode
    seq: int
    batch: int


SHAPES = {
    "train_4k": ShapeCase("train_4k", "train", 4096, 256),
    "prefill_32k": ShapeCase("prefill_32k", "prefill", 32_768, 32),
    "decode_32k": ShapeCase("decode_32k", "decode", 32_768, 128),
    "long_500k": ShapeCase("long_500k", "decode", 524_288, 1),
}

# long_500k requires sub-quadratic attention: runs for the SSM and the
# hybrid (jamba: only 1-in-8 layers hold KV); skipped for the 8 archs
# with periodic full-attention layers (DESIGN.md §6).
_LONG_OK = {"mamba2-780m", "jamba-v0.1-52b"}


def skip_reason(cfg: ModelConfig, case: ShapeCase) -> str | None:
    if case.name == "long_500k" and cfg.name not in _LONG_OK:
        return ("full-attention layers present: 500k dense KV decode is "
                "the mandated sub-quadratic skip (DESIGN.md §6)")
    return None


def runnable(cfg: ModelConfig, case: ShapeCase) -> bool:
    return skip_reason(cfg, case) is None


# ---------------------------------------------------------------------------
# ShapeDtypeStruct builders (weak-type-correct, shardable, no allocation)
# ---------------------------------------------------------------------------

def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def train_input_structs(cfg: ModelConfig, case: ShapeCase) -> dict:
    b, s = case.batch, case.seq
    batch = {
        "tokens": _sds((b, s), jnp.int32),
        "labels": _sds((b, s), jnp.int32),
    }
    if cfg.family == "vlm":
        batch["patch_embeds"] = _sds((b, cfg.n_patches, cfg.d_model),
                                     jnp.bfloat16)
    if cfg.is_encdec:
        batch["frames"] = _sds((b, cfg.enc_seq_len, cfg.d_model),
                               jnp.bfloat16)
    return batch


def prefill_input_structs(cfg: ModelConfig, case: ShapeCase) -> dict:
    out = {"tokens": _sds((case.batch, case.seq), jnp.int32)}
    if cfg.family == "vlm":
        out["patch_embeds"] = _sds((case.batch, cfg.n_patches, cfg.d_model),
                                   jnp.bfloat16)
    if cfg.is_encdec:
        out["frames"] = _sds((case.batch, cfg.enc_seq_len, cfg.d_model),
                             jnp.bfloat16)
    return out


def decode_input_structs(cfg: ModelConfig, case: ShapeCase) -> dict:
    """decode cells: one new token against a seq-len cache."""
    from repro.models import init_caches
    caches = jax.eval_shape(
        lambda: init_caches(cfg, case.batch, case.seq))
    out = {
        "token": _sds((case.batch, 1), jnp.int32),
        "caches": caches,
        "index": _sds((), jnp.int32),
    }
    if cfg.is_encdec:
        out["enc_out"] = _sds((case.batch, cfg.enc_seq_len, cfg.d_model),
                              jnp.bfloat16)
    return out
