"""Serving launcher: continuous-batching engine on a reduced config.

Batch mode (legacy lockstep generate):

    PYTHONPATH=src python -m repro.launch.serve --arch qwen3-4b \
        --batch 4 --prompt-len 32 --new-tokens 32 --quant w8a8_nibble

Request-level workloads (continuous batching: per-slot positions, slot
refill, per-request latency), optionally over the paged KV cache and
with a priority mix:

    PYTHONPATH=src python -m repro.launch.serve --arch qwen3-4b \
        --workload staggered --requests 16 --stagger-ms 50 \
        --cache-mode paged --page-size 8 --priority-mix 0.25

Overcommitted pool (incremental page allocation + evict-and-resume
preemption: pages are booked per live token, `--num-pages` may sit
below the sum of worst-case page counts):

    PYTHONPATH=src python -m repro.launch.serve --arch qwen3-4b \
        --workload uniform --requests 16 --cache-mode paged \
        --page-size 8 --alloc-mode incremental --num-pages 24

Prefix caching (shared system prompt served from refcounted read-only
pages; only the uncached suffix is prefilled):

    PYTHONPATH=src python -m repro.launch.serve --arch qwen3-4b \
        --workload staggered --requests 16 --cache-mode paged \
        --page-size 8 --prefix-cache --shared-prefix 0.75

Self-speculative decoding (the quantized program drafts --spec-k
tokens, one dense multi-token forward verifies them; greedy streams
stay bit-identical to the non-spec dense engine) under a bursty
heavy-tail workload:

    PYTHONPATH=src python -m repro.launch.serve --arch qwen3-4b \
        --workload bursty --requests 16 --stagger-ms 50 \
        --cache-mode paged --alloc-mode incremental \
        --spec-decode --spec-k 4 --spec-quant w8a8_nibble

Tail-latency engineering (chunked prefill and grouped admission through
one shared wave program, plus a host-tier page swap that makes
preemption resume an O(pages) copy) on an overcommitted bursty
workload:

    PYTHONPATH=src python -m repro.launch.serve --arch qwen3-4b \
        --workload bursty --requests 16 --stagger-ms 50 \
        --cache-mode paged --alloc-mode incremental --num-pages 24 \
        --prefill-chunk 8 --admit-group 4 --swap-mode host

Compile time is reported separately from steady-state throughput (a
warmup pass triggers every compilation before the timed run).
"""

from __future__ import annotations

import argparse
import time

import jax

from repro.configs import get_config, reduced
from repro.models import model_init
from repro.serve import Engine, Router, ServeConfig


def _parse_mesh(spec: str | None):
    """``"DATAxMODEL"`` (e.g. ``2x4``) → per-engine mesh shape tuple."""
    if spec is None:
        return None
    try:
        dp, tp = spec.lower().split("x")
        return (int(dp), int(tp))
    except ValueError:
        raise SystemExit(f"--mesh expects DATAxMODEL (e.g. 1x2), "
                         f"got {spec!r}")


def _build(args, *, reference: bool = False):
    """Build the serving stack for ``args``.  ``reference=True`` builds
    the plain baseline from the same argument set — single-device
    (tp=1, dp=1, no mesh) AND with every tail-latency mechanism off
    (monolithic prefill, serialized admission, replay-only resume), so
    --verify proves chunked/grouped prefill and the host-tier swap
    against the unmodified engine, not just against themselves."""
    tp = 1 if reference else args.tp
    dp = 1 if reference else args.dp
    mesh_shape = None if reference else _parse_mesh(args.mesh)
    prefill_chunk = 0 if reference else args.prefill_chunk
    admit_group = 1 if reference else args.admit_group
    swap_mode = "off" if reference else args.swap_mode
    cfg = reduced(get_config(args.arch)).replace(quant_mode=args.quant)
    params = model_init(jax.random.PRNGKey(0), cfg)
    max_len = args.prompt_len + args.new_tokens
    if args.cache_mode == "paged":
        # the paged pool is page-granular; round the budget up
        max_len += (-max_len) % args.page_size
    scfg = ServeConfig(batch=args.batch,
                       max_len=max_len,
                       prefill_len=args.prompt_len,
                       temperature=args.temperature,
                       decode_chunk=args.decode_chunk,
                       priority_aging_s=args.priority_aging_s,
                       alloc_mode=args.alloc_mode,
                       prefix_cache=args.prefix_cache,
                       quant_backend=args.quant_backend,
                       cache_mode=args.cache_mode,
                       page_size=args.page_size,
                       num_pages=args.num_pages or None,
                       spec_decode=args.spec_decode,
                       spec_k=args.spec_k,
                       spec_quant_mode=args.spec_quant,
                       prefill_chunk=prefill_chunk,
                       admit_group=admit_group,
                       swap_mode=swap_mode,
                       host_pages=args.host_pages,
                       prefix_cache_pages=args.prefix_cache_pages,
                       tp=tp,
                       mesh_shape=mesh_shape)
    if dp > 1:
        return cfg, params, Router(cfg, params, scfg, replicas=dp)
    return cfg, params, Engine(cfg, params, scfg)


def run_batch(args, cfg, engine):
    """Lockstep generate: every slot starts and stops together."""
    prompts = jax.random.randint(jax.random.PRNGKey(1),
                                 (args.batch, args.prompt_len), 0,
                                 cfg.vocab_size)
    if prompts.shape[0] != engine.scfg.batch:
        raise ValueError(f"prompt batch {prompts.shape[0]} != engine "
                         f"slot count {engine.scfg.batch}")
    # warmup: trigger prefill + decode-chunk compilation before timing
    t0 = time.perf_counter()
    engine.generate(prompts, min(args.new_tokens, 2)).block_until_ready()
    t_compile = time.perf_counter() - t0

    t0 = time.perf_counter()
    out = engine.generate(prompts, args.new_tokens)
    out.block_until_ready()
    dt = time.perf_counter() - t0
    toks = args.batch * args.new_tokens
    print(f"arch={cfg.name} quant={args.quant} backend={args.quant_backend} "
          f"workload=batch")
    print(f"  compile+warmup: {t_compile:.2f}s   "
          f"(compilations: {engine.compile_counts})")
    print(f"  steady-state:   {toks} tokens in {dt:.2f}s "
          f"({toks / dt:.1f} tok/s, batch={args.batch})")
    print("  sample token ids:", out[0, -16:].tolist())


def _run_workload(args, cfg, engine, collect_streams=False):
    from repro.serve import run_timed_workload
    stagger = args.stagger_ms / 1000.0 \
        if args.workload in ("staggered", "bursty") else 0.0
    return run_timed_workload(engine, cfg.vocab_size,
                              requests=args.requests,
                              prompt_budget=args.prompt_len,
                              new_tokens=args.new_tokens, stagger_s=stagger,
                              priority_mix=args.priority_mix,
                              shared_prefix=args.shared_prefix,
                              arrival_mode="bursty"
                              if args.workload == "bursty" else "uniform",
                              collect_streams=collect_streams)


def _check_leaks(args, engine):
    """Every page must be back in the allocator once the prefix index
    lets go — a leak here is an engine bug, so fail loudly."""
    if args.cache_mode != "paged":
        return
    engine.release_prefix_cache()
    leaked = engine.leaked_pages()
    if leaked:
        raise SystemExit(f"page leak: {leaked} page(s) still booked "
                         f"after drain + prefix-cache release")


def _verify(args, cfg, r):
    """Re-run the identical workload on a single-device tp=1/dp=1
    reference and demand token-for-token equality.  Greedy only — and
    dense-only when dp > 1: w8a8 activation scales are per-tensor over
    the batch, so changing which requests share a decode chunk (which
    dp placement does) legitimately shifts quantized streams."""
    if args.temperature > 0:
        raise SystemExit("--verify needs greedy streams "
                         "(--temperature 0)")
    if args.dp > 1 and args.quant != "dense":
        raise SystemExit("--verify with --dp > 1 needs --quant dense: "
                         "batched activation quantization is batch-"
                         "composition-dependent, so placement changes "
                         "quantized streams")
    cfg_ref, _, ref = _build(args, reference=True)
    ref_r = _run_workload(args, cfg_ref, ref, collect_streams=True)
    _check_leaks(args, ref)
    if r["streams"] != ref_r["streams"]:
        bad = [i for i in r["streams"]
               if r["streams"][i] != ref_r["streams"][i]]
        raise SystemExit(f"verify FAILED: {len(bad)}/{len(r['streams'])} "
                         f"stream(s) diverge from the single-device "
                         f"reference (request ids {bad[:8]})")
    print(f"  verify: {len(r['streams'])} streams bit-match the "
          f"single-device reference")


def _workload_shape(args):
    from repro.capacity import WorkloadShape
    stagger = args.stagger_ms / 1000.0 \
        if args.workload in ("staggered", "bursty") else 0.0
    return WorkloadShape(requests=args.requests,
                         prompt_budget=args.prompt_len,
                         new_tokens=args.new_tokens, stagger_s=stagger,
                         priority_mix=args.priority_mix,
                         shared_prefix=args.shared_prefix,
                         arrival_mode="bursty"
                         if args.workload == "bursty" else "uniform")


def _predict(args, cfg, engine, r):
    """Calibrate the live engine and print the capacity model's
    prediction for the workload that was just measured."""
    if args.dp > 1 or args.tp > 1 or args.mesh:
        print("  capacity model: (skipped — covers the single-device "
              "engine; tp/dp rows carry no prediction)")
        return
    from repro.capacity import predict
    from repro.capacity.calibrate import calibrate_engine
    costs = calibrate_engine(engine)
    p = predict(engine.scfg, _workload_shape(args), costs,
                cache_token_bytes=int(engine.cache_token_bytes),
                acceptance=(r["acceptance_rate"]
                            if args.spec_decode else None))
    if not p["feasible"] or "tok_per_s" not in p:
        print(f"  capacity model: infeasible — "
              f"{p['infeasible_reason']}")
        return
    err = 100.0 * abs(p["tok_per_s"] - r["tok_per_s"]) \
        / max(r["tok_per_s"], 1e-9)
    print(f"  capacity model: predicted {p['tok_per_s']:.1f} tok/s "
          f"(measured {r['tok_per_s']:.1f}, {err:.0f}% off), "
          f"ttft p50={p['ttft_p50_ms']:.0f}ms "
          f"p99={p['ttft_p99_ms']:.0f}ms, "
          f"preemptions {p['preemptions']}, "
          f"cache {p['cache_kb_per_req']:.1f} KiB/req")


def run_autotune(args):
    """--autotune: knob-grid search over the analytic capacity model
    for this launcher invocation's workload shape — prints the
    prediction table and the winning ServeConfig kwargs, no model
    run."""
    import json as _json

    from repro.capacity.tune import knob_grid, search, table_lines
    if args.workload == "batch":
        raise SystemExit("--autotune plans request workloads "
                         "(uniform/staggered/bursty), not batch mode")
    cfg = reduced(get_config(args.arch)).replace(quant_mode=args.quant)
    shape = _workload_shape(args)
    max_len = args.prompt_len + args.new_tokens
    max_len += (-max_len) % args.page_size
    cells = knob_grid(shape, batch=args.batch, max_len=max_len,
                      prefill_len=args.prompt_len)
    results, winner = search(cfg, shape, cells,
                             objective=args.autotune,
                             ttft_slo_ms=args.ttft_slo_ms, alpha=0.8)
    print(f"# autotune: {len(cells)} cells, objective={args.autotune}"
          + (f", ttft_slo={args.ttft_slo_ms}ms"
             if args.ttft_slo_ms else ""))
    for line in table_lines(results, winner):
        print(line)
    if winner is None:
        print("# no admissible configuration")
        return 1
    print("# winning ServeConfig kwargs:")
    print(_json.dumps(winner["knobs"].to_dict(), indent=1))
    return 0


def run_requests(args, cfg, engine):
    """Request-level workload: ``uniform`` submits everything at t=0,
    ``staggered`` spaces arrivals by --stagger-ms, ``bursty`` clusters
    Poisson bursts at the same mean load with Pareto heavy-tail prompt
    lengths (slots refill mid-stream in all three)."""
    r = _run_workload(args, cfg, engine, collect_streams=args.verify)
    _check_leaks(args, engine)
    print(f"arch={cfg.name} quant={args.quant} backend={args.quant_backend} "
          f"cache={args.cache_mode} workload={args.workload} "
          f"requests={args.requests} slots={args.batch}")
    print(f"  topology: {r['device_count']} device(s), per-engine mesh "
          f"{tuple(r['mesh_shape'])}, dp_replicas={r['dp_replicas']}")
    print(f"  compile+warmup: {r['compile_s']:.2f}s   "
          f"(compilations: {r['compile_counts']})")
    print(f"  steady-state:   {r['tokens']} tokens in {r['wall_s']:.2f}s "
          f"({r['tok_per_s']:.1f} tok/s)")
    print(f"  request latency p50={r['req_p50_ms']:.0f}ms "
          f"p99={r['req_p99_ms']:.0f}ms   "
          f"ttft p50={r['ttft_p50_ms']:.0f}ms "
          f"p99={r['ttft_p99_ms']:.0f}ms   "
          f"itl p50={r['itl_p50_ms']:.1f}ms p99={r['itl_p99_ms']:.1f}ms")
    print(f"  cache HBM/request: {r['cache_kb_per_req']:.1f} KiB")
    if args.spec_decode:
        print(f"  spec decode: k={args.spec_k} "
              f"draft={args.spec_quant or args.quant} "
              f"acceptance={r['acceptance_rate']:.0%} "
              f"tokens/step={r['tokens_per_step']:.2f} "
              f"rollback_pages={r['spec_rollback_pages']}")
    if args.cache_mode == "paged":
        print(f"  pool: {r['pool_pages']} pages, mean occupancy "
              f"{r['occupancy']:.0%}, mean concurrency "
              f"{r['concurrency']:.2f}, preemptions {r['preemptions']}")
    if args.prefix_cache:
        print(f"  prefix cache: hit rate {r['prefix_hit_rate']:.0%} of "
              f"prompt tokens, {r['prefill_tokens']} tokens prefilled")
    if args.prefill_chunk or args.admit_group > 1:
        print(f"  wave prefill: {r['prefill_waves']} waves "
              f"(chunk={args.prefill_chunk or args.prompt_len} "
              f"group={args.admit_group}), "
              f"{r['decode_chunks']} decode chunks")
    if args.swap_mode == "host":
        print(f"  host swap: {r['swap_out']} out / {r['swap_in']} in, "
              f"{r['replay_steps_saved']} replay steps saved, "
              f"{r['prefix_cold_hits']} cold prefix pages promoted")
    if "per_replica" in r:
        for pr in r["per_replica"]:
            print(f"  replica {pr['replica']}: {pr['placed']} placed, "
                  f"affinity hit rate {pr['affinity_hit_rate']:.0%}, "
                  f"prefix hit rate {pr['prefix_hit_rate']:.0%}, "
                  f"preemptions {pr['preemptions']}")
    if r["truncated"]:
        print(f"  WARNING: {r['truncated']} request(s) truncated at the "
              f"max_len budget")
    if "hi_req_p50_ms" in r:
        # an empty priority class reports None, not a number
        hi = r["hi_req_p50_ms"]
        lo = r["lo_req_p50_ms"]
        hi_s = "n/a (no hi requests)" if hi is None else f"p50={hi:.0f}ms"
        lo_s = "n/a (no lo requests)" if lo is None else f"p50={lo:.0f}ms"
        print(f"  priority split:  hi {hi_s}  lo {lo_s}")
    if args.predict:
        _predict(args, cfg, engine, r)
    if args.verify:
        _verify(args, cfg, r)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-4b")
    ap.add_argument("--batch", type=int, default=4,
                    help="decode slot count")
    ap.add_argument("--prompt-len", type=int, default=32,
                    help="slot prompt budget (requests pad up to this)")
    ap.add_argument("--new-tokens", type=int, default=32)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--decode-chunk", type=int, default=8,
                    help="tokens per jitted decode dispatch")
    ap.add_argument("--workload", default="batch",
                    choices=["batch", "uniform", "staggered", "bursty"],
                    help="batch = lockstep generate; uniform/staggered/"
                         "bursty = request queue with slot refill "
                         "(bursty clusters Poisson-burst arrivals with "
                         "Pareto heavy-tail prompt lengths)")
    ap.add_argument("--requests", type=int, default=8,
                    help="request count for queued workloads")
    ap.add_argument("--stagger-ms", type=float, default=50.0,
                    help="arrival spacing for the staggered workload; "
                         "mean inter-arrival for bursty")
    ap.add_argument("--quant", default="dense",
                    choices=["dense", "w8a8_nibble", "w4a8_nibble", "lut"])
    ap.add_argument("--quant-backend", default="xla",
                    choices=["xla", "pallas"],
                    help="pallas = fused single-pass kernels "
                         "(ops.quant_matmul, in-kernel dequant epilogue)")
    ap.add_argument("--cache-mode", default="dense",
                    choices=["dense", "paged"],
                    help="paged = shared page pools + page-table "
                         "indirection (cache HBM scales with live tokens)")
    ap.add_argument("--page-size", type=int, default=8,
                    help="tokens per KV page (paged cache mode)")
    ap.add_argument("--alloc-mode", default="reserve",
                    choices=["reserve", "incremental"],
                    help="reserve = book worst-case pages at admission; "
                         "incremental = book live-token pages per decode "
                         "chunk with evict-and-resume preemption "
                         "(allows an overcommitted --num-pages)")
    ap.add_argument("--num-pages", type=int, default=0,
                    help="KV pool size in pages (0 = parity with the "
                         "dense slab); set below the worst-case sum to "
                         "overcommit with --alloc-mode incremental")
    ap.add_argument("--prefix-cache", action="store_true",
                    help="share read-only prompt-prefix pages across "
                         "requests (paged cache mode): admission maps "
                         "cached page-aligned chunks and prefills only "
                         "the uncached suffix, copy-on-writing a fully "
                         "covered prompt's tail page")
    ap.add_argument("--shared-prefix", type=float, default=0.0,
                    help="fraction of workload requests that begin with "
                         "one fixed system-prompt head of prompt-len/2 "
                         "tokens (the workload prefix caching serves)")
    ap.add_argument("--priority-mix", type=float, default=0.0,
                    help="fraction of workload requests submitted at "
                         "priority 1 (rest 0); reports per-class latency")
    ap.add_argument("--priority-aging-s", type=float, default=1.0,
                    help="queue-wait seconds per +1 effective priority "
                         "(anti-starvation aging; 0 = strict priorities)")
    ap.add_argument("--spec-decode", action="store_true",
                    help="self-speculative decoding: the quantized "
                         "program drafts --spec-k tokens per slot, one "
                         "dense multi-token forward verifies them; "
                         "rejected tails roll back as a page-table "
                         "truncation.  Greedy streams stay bit-equal "
                         "to the non-spec dense engine")
    ap.add_argument("--spec-k", type=int, default=4,
                    help="draft tokens per speculation round")
    ap.add_argument("--spec-quant", default=None,
                    choices=["dense", "qat", "w8a8_nibble", "w4a8_nibble",
                             "lut"],
                    help="draft-side quant mode (default: the engine's "
                         "--quant; the verifier always runs dense)")
    ap.add_argument("--prefill-chunk", type=int, default=0,
                    help="chunked prefill: split every prompt into "
                         "chunks of this many tokens, one chunk per "
                         "scheduler step interleaved with decode "
                         "chunks (0 = monolithic one-dispatch prefill; "
                         "paged cache only)")
    ap.add_argument("--admit-group", type=int, default=1,
                    help="grouped admission: up to this many prefilling "
                         "requests advance per wave as one padded "
                         "batch through the single wave program "
                         "(paged cache only)")
    ap.add_argument("--swap-mode", default="off",
                    choices=["off", "host"],
                    help="host = on eviction copy the victim's live KV "
                         "pages to a host-memory cold pool and restore "
                         "them on resume (O(pages) copy instead of "
                         "O(generated) replay); also gives the prefix "
                         "cache a host cold tier")
    ap.add_argument("--host-pages", type=int, default=0,
                    help="host cold-pool capacity in pages for "
                         "--swap-mode host (0 = twice the device pool)")
    ap.add_argument("--prefix-cache-pages", type=int, default=0,
                    help="capacity cap on pages the prefix index may "
                         "pin; overflow reclaims LRU leaf-first, "
                         "demoting to the host cold tier when "
                         "--swap-mode host (0 = uncapped)")
    ap.add_argument("--tp", type=int, default=1,
                    help="tensor-parallel shards per engine: weights and "
                         "paged KV pools shard over a (1, tp) device "
                         "mesh's \"model\" axis (greedy streams stay "
                         "token-identical to tp=1)")
    ap.add_argument("--dp", type=int, default=1,
                    help="data-parallel engine replicas behind one "
                         "admission router (least-loaded placement with "
                         "priority ordering and prefix-cache affinity); "
                         "each replica gets its own disjoint --tp-sized "
                         "device group")
    ap.add_argument("--mesh", default=None,
                    help="per-engine mesh shape DATAxMODEL (e.g. 1x2); "
                         "overrides --tp when set")
    ap.add_argument("--verify", action="store_true",
                    help="re-run the workload on a single-device tp=1/"
                         "dp=1 reference and require token-for-token "
                         "stream equality (greedy only; dense quant "
                         "when --dp > 1)")
    ap.add_argument("--predict", action="store_true",
                    help="after the measured run, calibrate the "
                         "engine's per-dispatch stage costs and print "
                         "the analytic capacity model's prediction for "
                         "the same workload next to the measurement "
                         "(single-device request workloads)")
    ap.add_argument("--autotune", default=None, metavar="OBJECTIVE",
                    choices=["max-tok-s", "min-pages"],
                    help="skip the run: search the serving knob grid "
                         "with the analytic capacity model for this "
                         "workload shape and print the winning "
                         "ServeConfig (objectives: max-tok-s under "
                         "--ttft-slo-ms, min-pages at zero predicted "
                         "preemptions)")
    ap.add_argument("--ttft-slo-ms", type=float, default=None,
                    help="p99 TTFT SLO an --autotune max-tok-s winner "
                         "must meet")
    args = ap.parse_args(argv)

    if args.workload == "batch" and args.dp > 1:
        raise SystemExit("--dp applies to request workloads "
                         "(uniform/staggered/bursty), not batch mode")
    if args.autotune:
        return run_autotune(args)
    cfg, _, engine = _build(args)
    if args.workload == "batch":
        run_batch(args, cfg, engine)
    else:
        run_requests(args, cfg, engine)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
