"""Serving launcher: batched prefill + decode on a reduced config.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen3-4b \
        --batch 4 --prompt-len 32 --new-tokens 32 --quant w8a8_nibble
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import get_config, reduced
from repro.models import model_init
from repro.serve import Engine, ServeConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-4b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--new-tokens", type=int, default=32)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--quant", default="dense",
                    choices=["dense", "w8a8_nibble", "w4a8_nibble", "lut"])
    ap.add_argument("--quant-backend", default="xla",
                    choices=["xla", "pallas"],
                    help="pallas = fused single-pass kernels "
                         "(ops.quant_matmul, in-kernel dequant epilogue)")
    args = ap.parse_args()

    cfg = reduced(get_config(args.arch)).replace(quant_mode=args.quant)
    params = model_init(jax.random.PRNGKey(0), cfg)
    scfg = ServeConfig(batch=args.batch,
                       max_len=args.prompt_len + args.new_tokens,
                       temperature=args.temperature,
                       quant_backend=args.quant_backend)
    engine = Engine(cfg, params, scfg)

    prompts = jax.random.randint(jax.random.PRNGKey(1),
                                 (args.batch, args.prompt_len), 0,
                                 cfg.vocab_size)
    t0 = time.time()
    out = engine.generate(prompts, args.new_tokens)
    out.block_until_ready()
    dt = time.time() - t0
    toks = args.batch * args.new_tokens
    print(f"arch={cfg.name} quant={args.quant} "
          f"generated {toks} tokens in {dt:.2f}s "
          f"({toks / dt:.1f} tok/s, batch={args.batch})")
    print("sample token ids:", out[0, -16:].tolist())
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
