"""Serving launcher: continuous-batching engine on a reduced config.

Batch mode (legacy lockstep generate):

    PYTHONPATH=src python -m repro.launch.serve --arch qwen3-4b \
        --batch 4 --prompt-len 32 --new-tokens 32 --quant w8a8_nibble

Request-level workloads (continuous batching: per-slot positions, slot
refill, per-request latency), optionally over the paged KV cache and
with a priority mix:

    PYTHONPATH=src python -m repro.launch.serve --arch qwen3-4b \
        --workload staggered --requests 16 --stagger-ms 50 \
        --cache-mode paged --page-size 8 --priority-mix 0.25

Overcommitted pool (incremental page allocation + evict-and-resume
preemption: pages are booked per live token, `--num-pages` may sit
below the sum of worst-case page counts):

    PYTHONPATH=src python -m repro.launch.serve --arch qwen3-4b \
        --workload uniform --requests 16 --cache-mode paged \
        --page-size 8 --alloc-mode incremental --num-pages 24

Prefix caching (shared system prompt served from refcounted read-only
pages; only the uncached suffix is prefilled):

    PYTHONPATH=src python -m repro.launch.serve --arch qwen3-4b \
        --workload staggered --requests 16 --cache-mode paged \
        --page-size 8 --prefix-cache --shared-prefix 0.75

Self-speculative decoding (the quantized program drafts --spec-k
tokens, one dense multi-token forward verifies them; greedy streams
stay bit-identical to the non-spec dense engine) under a bursty
heavy-tail workload:

    PYTHONPATH=src python -m repro.launch.serve --arch qwen3-4b \
        --workload bursty --requests 16 --stagger-ms 50 \
        --cache-mode paged --alloc-mode incremental \
        --spec-decode --spec-k 4 --spec-quant w8a8_nibble

Compile time is reported separately from steady-state throughput (a
warmup pass triggers every compilation before the timed run).
"""

from __future__ import annotations

import argparse
import time

import jax

from repro.configs import get_config, reduced
from repro.models import model_init
from repro.serve import Engine, ServeConfig


def _build(args):
    cfg = reduced(get_config(args.arch)).replace(quant_mode=args.quant)
    params = model_init(jax.random.PRNGKey(0), cfg)
    max_len = args.prompt_len + args.new_tokens
    if args.cache_mode == "paged":
        # the paged pool is page-granular; round the budget up
        max_len += (-max_len) % args.page_size
    scfg = ServeConfig(batch=args.batch,
                       max_len=max_len,
                       prefill_len=args.prompt_len,
                       temperature=args.temperature,
                       decode_chunk=args.decode_chunk,
                       priority_aging_s=args.priority_aging_s,
                       alloc_mode=args.alloc_mode,
                       prefix_cache=args.prefix_cache,
                       quant_backend=args.quant_backend,
                       cache_mode=args.cache_mode,
                       page_size=args.page_size,
                       num_pages=args.num_pages or None,
                       spec_decode=args.spec_decode,
                       spec_k=args.spec_k,
                       spec_quant_mode=args.spec_quant)
    return cfg, params, Engine(cfg, params, scfg)


def run_batch(args, cfg, engine):
    """Lockstep generate: every slot starts and stops together."""
    prompts = jax.random.randint(jax.random.PRNGKey(1),
                                 (args.batch, args.prompt_len), 0,
                                 cfg.vocab_size)
    if prompts.shape[0] != engine.scfg.batch:
        raise ValueError(f"prompt batch {prompts.shape[0]} != engine "
                         f"slot count {engine.scfg.batch}")
    # warmup: trigger prefill + decode-chunk compilation before timing
    t0 = time.perf_counter()
    engine.generate(prompts, min(args.new_tokens, 2)).block_until_ready()
    t_compile = time.perf_counter() - t0

    t0 = time.perf_counter()
    out = engine.generate(prompts, args.new_tokens)
    out.block_until_ready()
    dt = time.perf_counter() - t0
    toks = args.batch * args.new_tokens
    print(f"arch={cfg.name} quant={args.quant} backend={args.quant_backend} "
          f"workload=batch")
    print(f"  compile+warmup: {t_compile:.2f}s   "
          f"(compilations: {engine.compile_counts})")
    print(f"  steady-state:   {toks} tokens in {dt:.2f}s "
          f"({toks / dt:.1f} tok/s, batch={args.batch})")
    print("  sample token ids:", out[0, -16:].tolist())


def run_requests(args, cfg, engine):
    """Request-level workload: ``uniform`` submits everything at t=0,
    ``staggered`` spaces arrivals by --stagger-ms, ``bursty`` clusters
    Poisson bursts at the same mean load with Pareto heavy-tail prompt
    lengths (slots refill mid-stream in all three)."""
    from repro.serve import run_timed_workload
    stagger = args.stagger_ms / 1000.0 \
        if args.workload in ("staggered", "bursty") else 0.0
    r = run_timed_workload(engine, cfg.vocab_size, requests=args.requests,
                           prompt_budget=args.prompt_len,
                           new_tokens=args.new_tokens, stagger_s=stagger,
                           priority_mix=args.priority_mix,
                           shared_prefix=args.shared_prefix,
                           arrival_mode="bursty"
                           if args.workload == "bursty" else "uniform")
    print(f"arch={cfg.name} quant={args.quant} backend={args.quant_backend} "
          f"cache={args.cache_mode} workload={args.workload} "
          f"requests={args.requests} slots={args.batch}")
    print(f"  compile+warmup: {r['compile_s']:.2f}s   "
          f"(compilations: {r['compile_counts']})")
    print(f"  steady-state:   {r['tokens']} tokens in {r['wall_s']:.2f}s "
          f"({r['tok_per_s']:.1f} tok/s)")
    print(f"  request latency p50={r['req_p50_ms']:.0f}ms "
          f"p99={r['req_p99_ms']:.0f}ms   "
          f"ttft p50={r['ttft_p50_ms']:.0f}ms "
          f"p99={r['ttft_p99_ms']:.0f}ms   "
          f"itl p50={r['itl_p50_ms']:.1f}ms p99={r['itl_p99_ms']:.1f}ms")
    print(f"  cache HBM/request: {r['cache_kb_per_req']:.1f} KiB")
    if args.spec_decode:
        print(f"  spec decode: k={args.spec_k} "
              f"draft={args.spec_quant or args.quant} "
              f"acceptance={r['acceptance_rate']:.0%} "
              f"tokens/step={r['tokens_per_step']:.2f} "
              f"rollback_pages={r['spec_rollback_pages']}")
    if args.cache_mode == "paged":
        print(f"  pool: {r['pool_pages']} pages, mean occupancy "
              f"{r['occupancy']:.0%}, mean concurrency "
              f"{r['concurrency']:.2f}, preemptions {r['preemptions']}")
    if args.prefix_cache:
        print(f"  prefix cache: hit rate {r['prefix_hit_rate']:.0%} of "
              f"prompt tokens, {r['prefill_tokens']} tokens prefilled")
    if r["truncated"]:
        print(f"  WARNING: {r['truncated']} request(s) truncated at the "
              f"max_len budget")
    if "hi_req_p50_ms" in r:
        print(f"  priority split:  hi p50={r['hi_req_p50_ms']:.0f}ms  "
              f"lo p50={r['lo_req_p50_ms']:.0f}ms")


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-4b")
    ap.add_argument("--batch", type=int, default=4,
                    help="decode slot count")
    ap.add_argument("--prompt-len", type=int, default=32,
                    help="slot prompt budget (requests pad up to this)")
    ap.add_argument("--new-tokens", type=int, default=32)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--decode-chunk", type=int, default=8,
                    help="tokens per jitted decode dispatch")
    ap.add_argument("--workload", default="batch",
                    choices=["batch", "uniform", "staggered", "bursty"],
                    help="batch = lockstep generate; uniform/staggered/"
                         "bursty = request queue with slot refill "
                         "(bursty clusters Poisson-burst arrivals with "
                         "Pareto heavy-tail prompt lengths)")
    ap.add_argument("--requests", type=int, default=8,
                    help="request count for queued workloads")
    ap.add_argument("--stagger-ms", type=float, default=50.0,
                    help="arrival spacing for the staggered workload; "
                         "mean inter-arrival for bursty")
    ap.add_argument("--quant", default="dense",
                    choices=["dense", "w8a8_nibble", "w4a8_nibble", "lut"])
    ap.add_argument("--quant-backend", default="xla",
                    choices=["xla", "pallas"],
                    help="pallas = fused single-pass kernels "
                         "(ops.quant_matmul, in-kernel dequant epilogue)")
    ap.add_argument("--cache-mode", default="dense",
                    choices=["dense", "paged"],
                    help="paged = shared page pools + page-table "
                         "indirection (cache HBM scales with live tokens)")
    ap.add_argument("--page-size", type=int, default=8,
                    help="tokens per KV page (paged cache mode)")
    ap.add_argument("--alloc-mode", default="reserve",
                    choices=["reserve", "incremental"],
                    help="reserve = book worst-case pages at admission; "
                         "incremental = book live-token pages per decode "
                         "chunk with evict-and-resume preemption "
                         "(allows an overcommitted --num-pages)")
    ap.add_argument("--num-pages", type=int, default=0,
                    help="KV pool size in pages (0 = parity with the "
                         "dense slab); set below the worst-case sum to "
                         "overcommit with --alloc-mode incremental")
    ap.add_argument("--prefix-cache", action="store_true",
                    help="share read-only prompt-prefix pages across "
                         "requests (paged cache mode): admission maps "
                         "cached page-aligned chunks and prefills only "
                         "the uncached suffix, copy-on-writing a fully "
                         "covered prompt's tail page")
    ap.add_argument("--shared-prefix", type=float, default=0.0,
                    help="fraction of workload requests that begin with "
                         "one fixed system-prompt head of prompt-len/2 "
                         "tokens (the workload prefix caching serves)")
    ap.add_argument("--priority-mix", type=float, default=0.0,
                    help="fraction of workload requests submitted at "
                         "priority 1 (rest 0); reports per-class latency")
    ap.add_argument("--priority-aging-s", type=float, default=1.0,
                    help="queue-wait seconds per +1 effective priority "
                         "(anti-starvation aging; 0 = strict priorities)")
    ap.add_argument("--spec-decode", action="store_true",
                    help="self-speculative decoding: the quantized "
                         "program drafts --spec-k tokens per slot, one "
                         "dense multi-token forward verifies them; "
                         "rejected tails roll back as a page-table "
                         "truncation.  Greedy streams stay bit-equal "
                         "to the non-spec dense engine")
    ap.add_argument("--spec-k", type=int, default=4,
                    help="draft tokens per speculation round")
    ap.add_argument("--spec-quant", default=None,
                    choices=["dense", "qat", "w8a8_nibble", "w4a8_nibble",
                             "lut"],
                    help="draft-side quant mode (default: the engine's "
                         "--quant; the verifier always runs dense)")
    args = ap.parse_args(argv)

    cfg, _, engine = _build(args)
    if args.workload == "batch":
        run_batch(args, cfg, engine)
    else:
        run_requests(args, cfg, engine)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
