import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

This is the proof that the distribution config is coherent without real
hardware: 512 placeholder host devices stand in for 2 pods × 256 chips,
``jax.jit(step).lower(...).compile()`` must succeed for every cell, and
the compiled artifact yields the memory/cost/collective numbers the
roofline analysis consumes.

Usage:
    PYTHONPATH=src python -m repro.launch.dryrun \
        [--arch gemma3-1b ...] [--shape train_4k ...] \
        [--multipod | --singlepod | --both] [--out results.json]
"""

import argparse       # noqa: E402
import json           # noqa: E402
import re             # noqa: E402
import time           # noqa: E402
import traceback      # noqa: E402

import jax            # noqa: E402
import jax.numpy as jnp  # noqa: E402
from jax.sharding import NamedSharding, PartitionSpec as P  # noqa: E402

from repro.configs import ARCH_NAMES, get_config  # noqa: E402
from repro.distributed.sharding import (  # noqa: E402
    batch_specs,
    cache_specs,
    opt_state_specs,
    param_specs,
)
from repro.launch.mesh import make_production_mesh  # noqa: E402
from repro.launch.shapes import (  # noqa: E402
    SHAPES,
    decode_input_structs,
    prefill_input_structs,
    skip_reason,
    train_input_structs,
)
from repro.models import decode_step, init_caches, model_init, prefill  # noqa: E402
from repro.optim import AdamWConfig, adamw_init  # noqa: E402
from repro.train import TrainConfig, make_train_step  # noqa: E402

# Post-partitioning HLO collective lines look like
#   %all-reduce.3 = f32[1024,128]{1,0} all-reduce(%x), replica_groups=...
#   %ag = (bf16[...], bf16[...]) all-gather-start(...), ...
# The output shape(s) sit between '=' and the op name; we sum their bytes.
_COLLECTIVE_LINE_RE = re.compile(
    r"=\s*(?P<shapes>\(?[^=]*?)\s*"
    r"(?P<op>all-gather|all-reduce|reduce-scatter|all-to-all|"
    r"collective-permute)"
    r"(?P<variant>-start|-done)?(?:\.\d+)?\(")
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")

_DTYPE_BYTES = {"f32": 4, "bf16": 2, "f16": 2, "s32": 4, "u32": 4,
                "s8": 1, "u8": 1, "pred": 1, "f64": 8, "s64": 8, "u64": 8,
                "s16": 2, "u16": 2, "f8e4m3fn": 1, "f8e5m2": 1}


def collective_bytes_from_hlo(hlo_text: str) -> dict:
    """Per-kind byte totals of every collective in the compiled HLO.

    Async pairs are counted once (the ``-done`` is skipped; ``-start``
    carries the shapes).  Bytes are the op's *output* bytes on this
    device's program — the per-device wire volume proxy the roofline
    collective term divides by link bandwidth."""
    totals: dict[str, float] = {}
    count = 0
    for line in hlo_text.splitlines():
        m = _COLLECTIVE_LINE_RE.search(line)
        if not m or m.group("variant") == "-done":
            continue
        kind = m.group("op")
        nbytes = 0
        for dt, dims in _SHAPE_RE.findall(m.group("shapes")):
            if dt not in _DTYPE_BYTES:
                continue
            n = 1
            for d in dims.split(","):
                if d:
                    n *= int(d)
            nbytes += n * _DTYPE_BYTES[dt]
        if nbytes:
            totals[kind] = totals.get(kind, 0) + nbytes
            count += 1
    totals["n_ops"] = count
    return totals


def _tp_compatible(cfg, mesh):
    """Adjust configs whose head counts don't divide the TP axis — the
    sharding rules already fall back to sequence sharding for caches;
    parameters shard on d_model/d_ff which are 128-multiples, fine."""
    return cfg


def build_cell(cfg, case, mesh, *, quant_moments: bool):
    """Returns (fn, args, in_shardings, donate) for one dry-run cell."""

    if case.kind == "train":
        tcfg = TrainConfig(optimizer=AdamWConfig(
            quantize_moments=quant_moments))
        step = make_train_step(cfg, tcfg)
        params = jax.eval_shape(
            lambda: model_init(jax.random.PRNGKey(0), cfg))
        opt = jax.eval_shape(
            lambda: adamw_init(params, tcfg.optimizer))
        batch = train_input_structs(cfg, case)
        pspec = param_specs(params, mesh)
        ospec = opt_state_specs(opt, pspec)
        bspec = batch_specs(cfg, mesh)
        return (step, (params, opt, batch), (pspec, ospec, bspec), (0, 1))

    if case.kind == "prefill":
        def fn(params, batch):
            tokens = batch["tokens"]
            kw = {}
            if "patch_embeds" in batch:
                kw["extra_embeds"] = batch["patch_embeds"]
            if "frames" in batch:
                kw["frames"] = batch["frames"]
            logits, caches, _ = prefill(params, cfg, tokens, **kw)
            return logits, caches

        params = jax.eval_shape(
            lambda: model_init(jax.random.PRNGKey(0), cfg))
        batch = prefill_input_structs(cfg, case)
        pspec = param_specs(params, mesh)
        bspec = {k: batch_specs(cfg, mesh).get(
            k, P(tuple(a for a in mesh.axis_names if a != "model"), None))
            for k in batch}
        bspec["tokens"] = batch_specs(cfg, mesh)["tokens"]
        return (fn, (params, batch), (pspec, bspec), ())

    # decode ------------------------------------------------------------
    def fn(params, caches, token, index, enc_out=None):
        logits, new_caches = decode_step(params, cfg, token, caches,
                                         index, enc_out=enc_out)
        nxt = jnp.argmax(logits[:, -1].astype(jnp.float32), axis=-1)
        return nxt[:, None].astype(jnp.int32), new_caches

    params = jax.eval_shape(lambda: model_init(jax.random.PRNGKey(0), cfg))
    ins = decode_input_structs(cfg, case)
    pspec = param_specs(params, mesh)
    cspec = cache_specs(cfg, ins["caches"], mesh, batch=case.batch)
    dp = tuple(a for a in mesh.axis_names if a != "model")
    tok_spec = P(dp, None) if case.batch > 1 else P(None, None)
    args = [params, ins["caches"], ins["token"], ins["index"]]
    specs = [pspec, cspec, tok_spec, P()]
    if "enc_out" in ins:
        args.append(ins["enc_out"])
        specs.append(P(dp, None, None) if case.batch > 1
                     else P(None, None, None))
    return (fn, tuple(args), tuple(specs), (1,))


def _with_blocks(cfg, k: int):
    """Config with exactly k repeated blocks (layer-scan trip count k)."""
    n_fixed = len(cfg.prefix_pattern) + len(cfg.suffix_pattern)
    kw = {"n_layers": n_fixed + k * len(cfg.block_pattern)}
    if cfg.is_encdec:
        kw["n_enc_layers"] = k     # encoder scan scales in lockstep
    return cfg.replace(**kw)


def _lower_cost(cfg, case, mesh, quant_moments):
    """Compile one variant, return (flops, bytes, collective_bytes)."""
    fn, args, in_shardings, donate = build_cell(
        cfg, case, mesh, quant_moments=quant_moments)
    in_shardings = jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s), in_shardings,
        is_leaf=lambda x: isinstance(x, P))
    from repro.distributed.sharding import ambient_mesh
    from repro.models.attention import unrolled_chunks
    from repro.models.transformer import unrolled_blocks
    with mesh, ambient_mesh(mesh), unrolled_chunks(), unrolled_blocks():
        compiled = jax.jit(fn, in_shardings=in_shardings,
                           donate_argnums=donate).lower(*args).compile()
    cost = compiled.cost_analysis() or {}
    coll = collective_bytes_from_hlo(compiled.as_text())
    return (cost.get("flops", 0.0), cost.get("bytes accessed", 0.0),
            {k: v for k, v in coll.items() if k != "n_ops"})


def scan_extrapolated_cost(cfg, case, mesh, quant_moments) -> dict:
    """XLA's cost_analysis counts a while-loop body ONCE regardless of
    trip count (verified: a 10-step scan of matmuls reports 1 matmul).
    All models here scan over layer blocks, so raw numbers undercount by
    ~n_blocks×.  Fix, from the compiled artifacts themselves: compile
    1-block and 2-block variants; the difference isolates one body, and
    ``cost(n) = cost(1) + (n-1)·(cost(2) - cost(1))`` reconstructs the
    full-depth program (prefix/suffix/embed/loss are in both, counted
    once).  Collectives inside the body extrapolate identically."""
    n = cfg.n_blocks
    if n <= 1:
        f, b, c = _lower_cost(cfg, case, mesh, quant_moments)
        return {"flops_extrapolated": f, "bytes_extrapolated": b,
                "collective_bytes_extrapolated": c, "scan_trips": n}
    f1, b1, c1 = _lower_cost(_with_blocks(cfg, 1), case, mesh, quant_moments)
    f2, b2, c2 = _lower_cost(_with_blocks(cfg, 2), case, mesh, quant_moments)
    coll = {}
    for k in set(c1) | set(c2):
        v = c1.get(k, 0) + (n - 1) * (c2.get(k, 0) - c1.get(k, 0))
        coll[k] = max(0.0, v)
    return {
        "flops_extrapolated": f1 + (n - 1) * (f2 - f1),
        "bytes_extrapolated": b1 + (n - 1) * (b2 - b1),
        "collective_bytes_extrapolated": coll,
        "scan_trips": n,
    }


def run_cell(arch: str, shape: str, mesh, mesh_tag: str,
             *, verbose: bool = True) -> dict:
    cfg = get_config(arch)
    case = SHAPES[shape]
    reason = skip_reason(cfg, case)
    if reason:
        return {"arch": arch, "shape": shape, "mesh": mesh_tag,
                "status": "skip", "reason": reason}

    quant_moments = cfg.param_count() > 1e11   # 671B/400B: int8 moments
    t0 = time.time()
    try:
        fn, args, in_shardings, donate = build_cell(
            cfg, case, mesh, quant_moments=quant_moments)
        in_shardings = jax.tree_util.tree_map(
            lambda s: NamedSharding(mesh, s), in_shardings,
            is_leaf=lambda x: isinstance(x, P))
        from repro.distributed.sharding import ambient_mesh
        with mesh, ambient_mesh(mesh):
            jitted = jax.jit(fn, in_shardings=in_shardings,
                             donate_argnums=donate)
            lowered = jitted.lower(*args)
            t_lower = time.time() - t0
            compiled = lowered.compile()
            t_compile = time.time() - t0 - t_lower
            # collectives exist only after SPMD partitioning → compiled HLO
            coll = collective_bytes_from_hlo(compiled.as_text())
            mem = compiled.memory_analysis()
            cost = compiled.cost_analysis()
        result = {
            "arch": arch, "shape": shape, "mesh": mesh_tag,
            "status": "ok",
            "lower_s": round(t_lower, 1),
            "compile_s": round(t_compile, 1),
            "collective_bytes": coll,
            "flops": cost.get("flops", 0.0) if cost else 0.0,
            "bytes_accessed": cost.get("bytes accessed", 0.0)
            if cost else 0.0,
            "params": cfg.param_count(),
            "params_active": cfg.param_count(active_only=True),
        }
        if mem is not None:
            for k in ("temp_size_in_bytes", "argument_size_in_bytes",
                      "output_size_in_bytes", "alias_size_in_bytes",
                      "generated_code_size_in_bytes"):
                v = getattr(mem, k, None)
                if v is not None:
                    result[k] = int(v)
        if "2pod" not in mesh_tag:
            # roofline table is single-pod (per the brief): the cost-
            # extrapolation pass (2 extra unrolled compiles) runs only
            # there; the multi-pod cell is the compile/sharding proof.
            result.update(scan_extrapolated_cost(cfg, case, mesh,
                                                 quant_moments))
        if verbose:
            print(f"[ok] {arch:28s} {shape:12s} {mesh_tag:9s} "
                  f"lower={t_lower:6.1f}s compile={t_compile:6.1f}s "
                  f"flops={result['flops']:.3e}")
        return result
    except Exception as e:  # a failed cell is a bug — surface it loudly
        if verbose:
            print(f"[FAIL] {arch} {shape} {mesh_tag}: "
                  f"{type(e).__name__}: {e}")
            traceback.print_exc()
        return {"arch": arch, "shape": shape, "mesh": mesh_tag,
                "status": "fail", "error": f"{type(e).__name__}: {e}"}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", nargs="*", default=None)
    ap.add_argument("--shape", nargs="*", default=None)
    ap.add_argument("--multipod", action="store_true")
    ap.add_argument("--singlepod", action="store_true")
    ap.add_argument("--out", default="dryrun_results.json")
    ap.add_argument("--append", action="store_true")
    args = ap.parse_args()

    archs = args.arch or list(ARCH_NAMES)   # get_config accepts both forms
    shapes = args.shape or list(SHAPES)
    meshes = []
    if args.singlepod or not args.multipod:
        meshes.append(("1pod_16x16", make_production_mesh(multi_pod=False)))
    if args.multipod or not args.singlepod:
        meshes.append(("2pod_2x16x16", make_production_mesh(multi_pod=True)))

    results = []
    if args.append and os.path.exists(args.out):
        results = json.load(open(args.out))
    done = {(r["arch"], r["shape"], r["mesh"]) for r in results}

    for mesh_tag, mesh in meshes:
        for arch in archs:
            for shape in shapes:
                if (arch, shape, mesh_tag) in done:
                    continue
                r = run_cell(arch, shape, mesh, mesh_tag)
                results.append(r)
                with open(args.out, "w") as f:
                    json.dump(results, f, indent=1)

    n_ok = sum(r["status"] == "ok" for r in results)
    n_skip = sum(r["status"] == "skip" for r in results)
    n_fail = sum(r["status"] == "fail" for r in results)
    print(f"\ndry-run: {n_ok} ok, {n_skip} skip, {n_fail} FAIL "
          f"→ {args.out}")
    return 1 if n_fail else 0


if __name__ == "__main__":
    raise SystemExit(main())
