"""Training launcher.

    PYTHONPATH=src python -m repro.launch.train --arch gemma3-1b \
        --steps 200 --seq 512 --batch 8 --quant qat [--reduced]

``--reduced`` runs the smoke-scale variant of the arch (CPU-friendly);
full-size configs are for real TPU meshes (the dry-run proves they
lower/compile; actually training them here would melt the container).
"""

from __future__ import annotations

import argparse
import json

import jax
import jax.numpy as jnp

from repro.configs import get_config, reduced
from repro.data import DataConfig
from repro.optim import AdamWConfig
from repro.train import TrainConfig
from repro.train.trainer import Trainer, TrainerConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma3-1b")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--quant", default="dense",
                    choices=["dense", "qat"])
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--full", dest="reduced", action="store_false")
    ap.add_argument("--checkpoint-dir", default=None)
    ap.add_argument("--checkpoint-every", type=int, default=50)
    ap.add_argument("--out", default=None)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = reduced(cfg)
    cfg = cfg.replace(quant_mode=args.quant)

    tcfg = TrainConfig(
        optimizer=AdamWConfig(lr=args.lr),
        microbatches=args.microbatches,
        total_steps=args.steps,
        warmup_steps=max(1, args.steps // 10),
    )
    rcfg = TrainerConfig(steps=args.steps,
                         checkpoint_dir=args.checkpoint_dir,
                         checkpoint_every=args.checkpoint_every)
    dcfg = DataConfig(vocab_size=cfg.vocab_size, seq_len=args.seq,
                      global_batch=args.batch)

    extra = None
    if cfg.family == "vlm":
        def extra(step):
            k = jax.random.PRNGKey(step)
            return {"patch_embeds": jax.random.normal(
                k, (args.batch, cfg.n_patches, cfg.d_model),
                jnp.bfloat16) * 0.02}
    if cfg.is_encdec:
        def extra(step):
            k = jax.random.PRNGKey(step)
            return {"frames": jax.random.normal(
                k, (args.batch, cfg.enc_seq_len, cfg.d_model),
                jnp.bfloat16) * 0.02}

    trainer = Trainer(cfg, tcfg, rcfg, dcfg, extra_batch_fn=extra)
    history = trainer.run()

    first, last = history[0]["loss"], history[-1]["loss"]
    print(f"\nloss {first:.4f} → {last:.4f} "
          f"({'improved' if last < first else 'NOT improved'})")
    if args.out:
        with open(args.out, "w") as f:
            json.dump(history, f, indent=1)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
