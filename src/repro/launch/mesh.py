"""Production meshes.

Functions, not module constants — importing this module never touches
jax device state (the dry-run sets XLA_FLAGS *before* any jax init; the
trainer uses whatever devices exist).
"""

from __future__ import annotations

import jax

__all__ = ["make_production_mesh", "make_local_mesh", "HW"]


def make_production_mesh(*, multi_pod: bool = False):
    """16×16 = 256 chips per pod; 2 pods for the multi-pod config."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_local_mesh():
    """Whatever this process has (CPU: 1 device) as a (data, model) mesh."""
    n = len(jax.devices())
    return jax.make_mesh((n, 1), ("data", "model"))


class HW:
    """TPU v5e hardware constants used by the roofline analysis."""
    PEAK_BF16_FLOPS = 197e12        # per chip
    HBM_BW = 819e9                  # bytes/s per chip
    ICI_BW = 50e9                   # bytes/s per link (~per-direction)
    HBM_BYTES = 16 * 2 ** 30        # 16 GiB per chip
