"""Production meshes.

Functions, not module constants — importing this module never touches
jax device state (the dry-run sets XLA_FLAGS *before* any jax init; the
trainer uses whatever devices exist).
"""

from __future__ import annotations

import jax

__all__ = ["make_production_mesh", "make_local_mesh", "HW"]


def make_production_mesh(*, multi_pod: bool = False):
    """16×16 = 256 chips per pod; 2 pods for the multi-pod config."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_local_mesh(*, dp: int = 0, tp: int = 1, devices=None):
    """Local ``(data, model)`` mesh over this process's devices.

    ``tp`` sizes the "model" (tensor-parallel) axis; ``dp`` sizes the
    "data" axis, with ``dp=0`` meaning "all remaining devices"
    (``len(devices) // tp``).  ``devices`` restricts the mesh to an
    explicit device list (the serve router hands each engine replica a
    disjoint slice); default is every device jax sees.  Divisibility is
    validated up front — GSPMD would reject an uneven mesh anyway, but
    the error here names the sizes.  The no-argument call keeps the old
    behaviour: an ``(n, 1)`` data-only mesh.
    """
    import numpy as np

    if devices is None:
        devices = jax.devices()
    devices = list(devices)
    n = len(devices)
    if tp < 1:
        raise ValueError(f"tp must be >= 1, got {tp}")
    if dp < 0:
        raise ValueError(f"dp must be >= 0 (0 = all remaining devices), "
                         f"got {dp}")
    if dp == 0:
        if n % tp:
            raise ValueError(f"tp={tp} does not divide the {n} available "
                             f"device(s)")
        dp = n // tp
    if dp * tp > n:
        raise ValueError(f"mesh ({dp}, {tp}) needs {dp * tp} devices but "
                         f"only {n} are available")
    grid = np.array(devices[:dp * tp], dtype=object).reshape(dp, tp)
    from jax.sharding import Mesh
    return Mesh(grid, ("data", "model"))


class HW:
    """TPU v5e hardware constants used by the roofline analysis."""
    PEAK_BF16_FLOPS = 197e12        # per chip
    HBM_BW = 819e9                  # bytes/s per chip
    ICI_BW = 50e9                   # bytes/s per link (~per-direction)
    HBM_BYTES = 16 * 2 ** 30        # 16 GiB per chip
