"""Trainer: the integration loop — data, step, checkpoint, fault hooks.

Single-host on CPU here, but structured exactly like the multi-pod
driver: deterministic data shards, checkpoint-restart that reproduces
the exact batch sequence, heartbeat/straggler hooks around the step.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Callable

import jax
import numpy as np

from repro.checkpoint import Checkpointer
from repro.configs.base import ModelConfig
from repro.data import DataConfig, SyntheticLM
from repro.models import model_init
from repro.optim import adamw_init
from repro.runtime import HeartbeatMonitor, StragglerDetector
from repro.train.step import TrainConfig, make_train_step

__all__ = ["TrainerConfig", "Trainer"]


@dataclasses.dataclass(frozen=True)
class TrainerConfig:
    steps: int = 100
    log_every: int = 10
    checkpoint_every: int = 50
    checkpoint_dir: str | None = None
    seed: int = 0
    host_id: int = 0
    n_hosts: int = 1


class Trainer:
    def __init__(self, cfg: ModelConfig, tcfg: TrainConfig,
                 rcfg: TrainerConfig, dcfg: DataConfig,
                 extra_batch_fn: Callable | None = None):
        self.cfg, self.tcfg, self.rcfg = cfg, tcfg, rcfg
        self.data = SyntheticLM(dcfg, rcfg.host_id, rcfg.n_hosts)
        self.extra_batch_fn = extra_batch_fn
        self.step_fn = jax.jit(make_train_step(cfg, tcfg),
                               donate_argnums=(0, 1))
        self.ckpt = (Checkpointer(rcfg.checkpoint_dir)
                     if rcfg.checkpoint_dir else None)
        self.heartbeat = HeartbeatMonitor(rcfg.n_hosts)
        self.straggler = StragglerDetector(rcfg.n_hosts)

        key = jax.random.PRNGKey(rcfg.seed)
        self.params = model_init(key, cfg)
        self.opt_state = adamw_init(self.params, tcfg.optimizer)
        self.start_step = 0

        if self.ckpt and self.ckpt.latest_step() is not None:
            state = {"params": self.params, "opt": self.opt_state}
            state, step = self.ckpt.restore(state)
            self.params = state["params"]
            self.opt_state = state["opt"]
            self.start_step = step
            print(f"[trainer] restored checkpoint at step {step}")

    def _batch(self, step: int) -> dict:
        batch = self.data.batch(step)
        if self.extra_batch_fn:
            batch.update(self.extra_batch_fn(step))
        return batch

    def run(self) -> list[dict]:
        history = []
        rcfg = self.rcfg
        for step in range(self.start_step, rcfg.steps):
            t0 = time.time()
            batch = self._batch(step)
            self.params, self.opt_state, metrics = self.step_fn(
                self.params, self.opt_state, batch)
            dt = time.time() - t0

            self.heartbeat.beat(rcfg.host_id, time.time())
            self.straggler.record(rcfg.host_id, dt)

            if step % rcfg.log_every == 0 or step == rcfg.steps - 1:
                m = {k: float(np.asarray(v)) for k, v in metrics.items()}
                m.update(step=step, step_time_s=round(dt, 3))
                history.append(m)
                print(f"[trainer] step {step:5d} loss={m['loss']:.4f} "
                      f"gnorm={m['grad_norm']:.3f} {dt*1e3:.0f} ms")

            if (self.ckpt and rcfg.checkpoint_every
                    and (step + 1) % rcfg.checkpoint_every == 0):
                self.ckpt.save(step + 1, {"params": self.params,
                                          "opt": self.opt_state},
                               host_id=rcfg.host_id,
                               n_hosts=rcfg.n_hosts)
        if self.ckpt:
            self.ckpt.wait()
        return history
