"""Training step: loss, gradient accumulation, compression hooks, MTP.

``make_train_step`` builds the jit-able step function used both by the
single-host trainer and the multi-pod dry-run.  Design points:

* next-token cross-entropy with label masking (-1 = ignore), plus the
  MoE aux loss and optional multi-token-prediction (MTP) auxiliary head
  objective (deepseek-v3's extra objective, implemented as an extra
  shifted CE term — cheap, no separate head params needed for depth-1);
* gradient accumulation via ``lax.scan`` over microbatches — the
  reduce-while-compute overlap happens naturally: XLA schedules each
  microbatch's reduce-scatter against the next microbatch's compute
  because the scan carries the running gradient sum;
* optional int8 gradient compression (error feedback) applied at the
  *cross-pod* boundary (see distributed/compression.py) before the
  optimizer — the slow inter-pod hop moves 4× fewer bytes.
"""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import forward
from repro.optim import AdamWConfig, adamw_update, warmup_cosine

__all__ = ["TrainConfig", "make_loss_fn", "make_train_step"]


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    optimizer: AdamWConfig = AdamWConfig()
    microbatches: int = 1            # gradient accumulation factor
    aux_loss_weight: float = 0.01
    mtp_weight: float = 0.0          # deepseek-v3 multi-token prediction
    mtp_depth: int = 1
    z_loss_weight: float = 1e-4      # logit normalization regularizer
    warmup_steps: int = 100
    total_steps: int = 10_000
    compress_grads: bool = False


def cross_entropy(logits, labels):
    """Masked next-token CE.  labels == -1 are ignored.

    Written as ``logsumexp - one_hot·logits`` (no vocab-axis gather):
    under a vocab-sharded (TP) logits layout both terms are sharded
    reductions, so neither forward nor backward materializes replicated
    (B,S,V) temporaries — gather-based CE forces an all-gather."""
    logits = logits.astype(jnp.float32)
    mask = (labels >= 0).astype(jnp.float32)
    safe = jnp.maximum(labels, 0)
    lse = jax.nn.logsumexp(logits, axis=-1)
    hot = jax.nn.one_hot(safe, logits.shape[-1], dtype=logits.dtype)
    label_logit = jnp.sum(logits * hot, axis=-1)
    nll = lse - label_logit
    denom = jnp.maximum(mask.sum(), 1.0)
    return (nll * mask).sum() / denom


def z_loss(logits, labels):
    """(log Z)² regularizer — keeps the softmax normalizer bounded, a
    production stabilizer for large-vocab models."""
    mask = (labels >= 0).astype(jnp.float32)
    lse = jax.nn.logsumexp(logits.astype(jnp.float32), axis=-1)
    denom = jnp.maximum(mask.sum(), 1.0)
    return (jnp.square(lse) * mask).sum() / denom


def make_loss_fn(cfg: ModelConfig, tcfg: TrainConfig) -> Callable:
    def loss_fn(params, batch):
        extra = {}
        if cfg.family == "vlm":
            extra["extra_embeds"] = batch["patch_embeds"]
        if cfg.is_encdec:
            extra["frames"] = batch["frames"]
        logits, aux = forward(params, cfg, batch["tokens"], **extra)
        # VLM prepends patches: align logits back onto the token grid
        if cfg.family == "vlm":
            logits = logits[:, -batch["tokens"].shape[1]:]
        labels = batch["labels"]
        loss = cross_entropy(logits[:, :-1], labels[:, :-1])
        loss += tcfg.aux_loss_weight * aux
        loss += tcfg.z_loss_weight * z_loss(logits[:, :-1], labels[:, :-1])
        if tcfg.mtp_weight > 0.0:
            # depth-d MTP: predict token t+1+d from position t.  Uses the
            # same trunk logits (shared-head variant).
            for d in range(1, tcfg.mtp_depth + 1):
                sh_logits = logits[:, :-(1 + d)]
                sh_labels = labels[:, d:-1]
                loss += tcfg.mtp_weight * cross_entropy(sh_logits, sh_labels)
        metrics = {"ce": loss, "aux": aux}
        return loss, metrics

    return loss_fn


def accumulate_grads(loss_fn, params, batch, n_micro: int):
    """lax.scan gradient accumulation over the leading batch dim."""
    if n_micro == 1:
        (loss, metrics), grads = jax.value_and_grad(
            loss_fn, has_aux=True)(params, batch)
        return loss, metrics, grads

    def reshape(x):
        b = x.shape[0]
        assert b % n_micro == 0, (b, n_micro)
        return x.reshape(n_micro, b // n_micro, *x.shape[1:])

    micro = jax.tree_util.tree_map(reshape, batch)
    zero_g = jax.tree_util.tree_map(
        lambda p: jnp.zeros(p.shape, jnp.float32), params)

    def body(carry, mb):
        g_acc, loss_acc = carry
        (loss, _), g = jax.value_and_grad(loss_fn, has_aux=True)(params, mb)
        g_acc = jax.tree_util.tree_map(
            lambda a, b: a + b.astype(jnp.float32), g_acc, g)
        return (g_acc, loss_acc + loss), None

    (grads, loss_sum), _ = jax.lax.scan(body, (zero_g, 0.0), micro)
    scale = 1.0 / n_micro
    grads = jax.tree_util.tree_map(lambda g: g * scale, grads)
    loss = loss_sum * scale
    return loss, {"ce": loss}, grads


def make_train_step(cfg: ModelConfig, tcfg: TrainConfig) -> Callable:
    loss_fn = make_loss_fn(cfg, tcfg)

    def train_step(params, opt_state, batch):
        loss, metrics, grads = accumulate_grads(loss_fn, params, batch,
                                                tcfg.microbatches)
        if tcfg.compress_grads:
            from repro.distributed.compression import compress_tree_int8
            grads, _ = compress_tree_int8(grads)
        lr_scale = warmup_cosine(opt_state["step"],
                                 warmup=tcfg.warmup_steps,
                                 total=tcfg.total_steps)
        params, opt_state, opt_metrics = adamw_update(
            params, grads, opt_state, tcfg.optimizer, lr_scale)
        metrics = dict(metrics, loss=loss, **opt_metrics)
        return params, opt_state, metrics

    return train_step
