from repro.train.step import TrainConfig, make_loss_fn, make_train_step  # noqa: F401
