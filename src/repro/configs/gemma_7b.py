"""gemma-7b [dense]: 28L, d_model 3072, 16H (kv=16, MHA), head_dim 256,
d_ff 24576, vocab 256000 — GeGLU.  [arXiv:2403.08295; hf]"""

from repro.configs.base import LayerSpec, ModelConfig

CONFIG = ModelConfig(
    name="gemma-7b",
    family="dense",
    n_layers=28,
    d_model=3072,
    n_heads=16,
    n_kv_heads=16,
    head_dim=256,
    d_ff=24576,
    vocab_size=256_000,
    block_pattern=(LayerSpec(mixer="attn", attn_kind="full", ffn="mlp"),),
    act="gelu",
    tie_embeddings=True,
    emb_scale_by_sqrt_dim=True,
)
