"""Model configuration schema + registry for the architecture zoo.

Every assigned architecture is a ``ModelConfig`` instance in its own
module under ``repro.configs``; ``get_config(name)`` resolves them, and
``reduced(cfg)`` derives the CPU-smoke-test variant (same family, same
layer pattern, tiny dims).
"""

from __future__ import annotations

import dataclasses
import importlib
from typing import Sequence

__all__ = ["ModelConfig", "LayerSpec", "get_config", "reduced",
           "spec_split", "ARCH_NAMES"]

# QuantLinear execution modes a draft model may run under (mirrors
# core.linear.QuantMode); the verifier side of a self-speculative pair
# is always "dense" — accepted tokens must be exactly what the dense
# model would have emitted.
QUANT_MODES = ("dense", "qat", "w8a8_nibble", "w4a8_nibble", "lut")


@dataclasses.dataclass(frozen=True)
class LayerSpec:
    """One layer's composition inside the (possibly heterogeneous) stack."""
    mixer: str = "attn"       # "attn" | "mamba" | "none"
    attn_kind: str = "full"   # "full" | "local" | "mla" (when mixer=attn)
    ffn: str = "mlp"          # "mlp" | "moe" | "none"


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                  # dense | ssm | moe | hybrid | encdec | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    head_dim: int
    d_ff: int
    vocab_size: int

    # --- heterogeneous stacking ------------------------------------------
    # The layer stack is ``prefix + block * n + suffix`` where ``block``
    # repeats; scan runs over the repeated blocks (compile-time friendly).
    block_pattern: Sequence[LayerSpec] = (LayerSpec(),)
    prefix_pattern: Sequence[LayerSpec] = ()
    suffix_pattern: Sequence[LayerSpec] = ()

    # --- attention ---------------------------------------------------------
    qk_norm: bool = False
    rope_theta: float = 10_000.0
    rope_theta_local: float = 10_000.0
    sliding_window: int = 0            # for attn_kind="local"
    attn_logit_softcap: float = 0.0
    attn_scale: float = 0.0            # 0 => 1/sqrt(head_dim)

    # --- MLA (deepseek) ------------------------------------------------------
    q_lora_rank: int = 0
    kv_lora_rank: int = 0
    qk_rope_dim: int = 0
    qk_nope_dim: int = 0
    v_head_dim: int = 0

    # --- MoE -----------------------------------------------------------------
    n_experts: int = 0
    n_shared_experts: int = 0
    top_k: int = 0
    d_ff_expert: int = 0
    capacity_factor: float = 1.25

    # --- SSM (mamba2 SSD) ----------------------------------------------------
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_head_dim: int = 64
    ssm_groups: int = 1
    conv_width: int = 4
    ssm_chunk: int = 256

    # --- encoder-decoder -------------------------------------------------------
    n_enc_layers: int = 0
    enc_seq_len: int = 0               # stub-frontend frame count (whisper)

    # --- VLM -------------------------------------------------------------------
    n_patches: int = 0                 # stub-frontend patch count

    # --- activations / embeddings ------------------------------------------
    act: str = "silu"                  # "silu" (SwiGLU) | "gelu" (GeGLU)
    tie_embeddings: bool = True
    emb_scale_by_sqrt_dim: bool = False

    # --- execution -----------------------------------------------------------
    quant_mode: str = "dense"          # QuantLinear mode for projections
    quant_backend: str = "xla"         # "xla" | "pallas" (fused kernels;
    #   pallas routes projections through ops.quant_matmul with the
    #   in-kernel dequant epilogue — int32 acc never leaves VMEM)
    remat: bool = True
    norm_eps: float = 1e-6
    attn_impl: str = "chunked"         # "chunked" | "flash" (Pallas kernel)
    kv_cache_dtype: str = "bf16"       # "bf16" | "int8" (paper-aligned:
    #   per-token-per-head symmetric int8 KV storage halves decode bytes)
    cache_mode: str = "dense"          # "dense" (per-slot max_len slab) |
    #   "paged" (shared page pools + page-table indirection: the paper's
    #   fixed-width-reusable-unit idea applied to KV storage — capacity
    #   scales with live tokens, not worst-case request shape)
    page_size: int = 16                # tokens per KV page (paged mode)
    num_pages: int = 0                 # shared pool size incl. the trash
    #   page; 0 = auto (slots × max_len / page_size + 1, capacity parity
    #   with the dense slab — shrink it to bank the HBM win)
    attn_core_bypass: bool = False     # ablation: skip the score/softmax
    #   core (projections kept) — used by the roofline attention-byte
    #   measurement (EXPERIMENTS.md §Perf), never in real runs

    # ------------------------------------------------------------------
    @property
    def layer_specs(self) -> list[LayerSpec]:
        """The fully unrolled layer stack."""
        n_fixed = len(self.prefix_pattern) + len(self.suffix_pattern)
        n_rep = self.n_layers - n_fixed
        if n_rep % len(self.block_pattern):
            raise ValueError(
                f"{self.name}: {n_rep} repeated layers not divisible by "
                f"block of {len(self.block_pattern)}")
        blocks = n_rep // len(self.block_pattern)
        return (list(self.prefix_pattern)
                + list(self.block_pattern) * blocks
                + list(self.suffix_pattern))

    @property
    def n_blocks(self) -> int:
        n_fixed = len(self.prefix_pattern) + len(self.suffix_pattern)
        return (self.n_layers - n_fixed) // len(self.block_pattern)

    @property
    def d_inner(self) -> int:          # SSM inner width
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_head_dim

    @property
    def is_encdec(self) -> bool:
        return self.n_enc_layers > 0

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)

    # rough parameter counts (roofline MODEL_FLOPS term) -------------------
    def param_count(self, active_only: bool = False) -> int:
        from repro.models.params import count_params_analytical
        return count_params_analytical(self, active_only=active_only)


ARCH_NAMES = [
    "gemma3_1b", "gemma_7b", "qwen3_4b", "yi_6b", "mamba2_780m",
    "phi3_vision_4_2b", "whisper_base", "deepseek_v3_671b",
    "llama4_maverick_400b_a17b", "jamba_v0_1_52b",
]

_ALIASES = {
    "gemma3-1b": "gemma3_1b",
    "gemma-7b": "gemma_7b",
    "qwen3-4b": "qwen3_4b",
    "yi-6b": "yi_6b",
    "mamba2-780m": "mamba2_780m",
    "phi-3-vision-4.2b": "phi3_vision_4_2b",
    "whisper-base": "whisper_base",
    "deepseek-v3-671b": "deepseek_v3_671b",
    "llama4-maverick-400b-a17b": "llama4_maverick_400b_a17b",
    "jamba-v0.1-52b": "jamba_v0_1_52b",
}


def get_config(name: str, **overrides) -> ModelConfig:
    mod_name = _ALIASES.get(name, name)
    mod = importlib.import_module(f"repro.configs.{mod_name}")
    cfg: ModelConfig = mod.CONFIG
    return cfg.replace(**overrides) if overrides else cfg


def spec_split(cfg: ModelConfig, draft_mode: str | None = None
               ) -> tuple[ModelConfig, ModelConfig]:
    """``(draft_cfg, verify_cfg)`` for self-speculative decoding.

    The paper's low-power nibble path and the dense reference are two
    execution modes over the *same weights*; self-speculation runs them
    as a draft/verify pair.  The draft keeps every serving knob of
    ``cfg`` but executes under ``draft_mode`` (default: ``cfg``'s own
    ``quant_mode`` — i.e. "the quantized deployment drafts for itself");
    the verifier is the same config pinned to ``quant_mode="dense"``,
    because the acceptance contract is defined against what the dense
    model would emit.  Cache layout, page geometry and attention
    settings are shared — both programs read and write the *same* KV
    pools."""
    draft = draft_mode or cfg.quant_mode
    if draft not in QUANT_MODES:
        raise ValueError(f"unknown draft quant mode {draft!r}; expected "
                         f"one of {QUANT_MODES}")
    return cfg.replace(quant_mode=draft), cfg.replace(quant_mode="dense")


def reduced(cfg: ModelConfig) -> ModelConfig:
    """Smoke-test variant: same family & layer pattern, tiny dimensions."""
    kw = dict(
        n_layers=len(cfg.prefix_pattern) + len(cfg.block_pattern)
        + len(cfg.suffix_pattern),
        d_model=64,
        n_heads=4,
        n_kv_heads=max(1, min(cfg.n_kv_heads, 2)),
        head_dim=16,
        d_ff=128,
        vocab_size=256,
    )
    if cfg.n_experts:
        kw.update(n_experts=4, top_k=min(cfg.top_k, 2), d_ff_expert=64)
    if cfg.q_lora_rank or cfg.kv_lora_rank:
        kw.update(q_lora_rank=32, kv_lora_rank=16, qk_rope_dim=8,
                  qk_nope_dim=8, v_head_dim=16)
    if cfg.ssm_state:
        kw.update(ssm_state=16, ssm_head_dim=16, ssm_chunk=16)
    if cfg.n_enc_layers:
        kw.update(n_enc_layers=2, enc_seq_len=32)
        kw["n_layers"] = 2
    if cfg.n_patches:
        kw.update(n_patches=8)
    if cfg.sliding_window:
        kw.update(sliding_window=8)
    return cfg.replace(**kw)
