"""mamba2-780m [ssm]: 48L, d_model 1536, attention-free, vocab 50280,
ssm_state 128 — SSD (state-space duality).  [arXiv:2405.21060; unverified]

Pure stack of SSD mixer blocks (no FFN — mamba2 convention: the block's
expansion is inside the mixer).  d_inner = 2·1536 = 3072, head_dim 64 →
48 SSD heads, 1 B/C group.
"""

from repro.configs.base import LayerSpec, ModelConfig

CONFIG = ModelConfig(
    name="mamba2-780m",
    family="ssm",
    n_layers=48,
    d_model=1536,
    n_heads=0,
    n_kv_heads=0,
    head_dim=0,
    d_ff=0,
    vocab_size=50_280,
    block_pattern=(LayerSpec(mixer="mamba", ffn="none"),),
    ssm_state=128,
    ssm_expand=2,
    ssm_head_dim=64,
    ssm_groups=1,
    conv_width=4,
    ssm_chunk=256,
    tie_embeddings=True,
)
