"""whisper-base [audio]: 6L enc + 6L dec, d_model 512, 8H, d_ff 2048,
vocab 51865 — encoder-decoder; conv frontend STUB (``input_specs``
provides precomputed frame embeddings (B, 1500, 512)).
[arXiv:2212.04356; unverified]"""

from repro.configs.base import LayerSpec, ModelConfig

CONFIG = ModelConfig(
    name="whisper-base",
    family="encdec",
    n_layers=6,                  # decoder layers
    n_enc_layers=6,
    enc_seq_len=1500,            # 30 s of audio at 50 Hz after the conv stub
    d_model=512,
    n_heads=8,
    n_kv_heads=8,
    head_dim=64,
    d_ff=2048,
    vocab_size=51_865,
    block_pattern=(LayerSpec(mixer="attn", attn_kind="full", ffn="mlp"),),
    act="gelu",
    tie_embeddings=True,
)
