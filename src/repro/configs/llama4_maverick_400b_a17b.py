"""llama4-maverick-400b-a17b [moe]: 48L, d_model 5120, 40H GQA kv=8,
expert d_ff 8192, vocab 202048 — MoE 128 experts top-1 + shared expert,
dense/MoE interleave every other layer; early-fusion multimodal (text
backbone only here, per the brief).
[hf:meta-llama/Llama-4-Scout-17B-16E; unverified]"""

from repro.configs.base import LayerSpec, ModelConfig

_DENSE = LayerSpec(mixer="attn", attn_kind="full", ffn="mlp")
_MOE = LayerSpec(mixer="attn", attn_kind="full", ffn="moe")

CONFIG = ModelConfig(
    name="llama4-maverick-400b-a17b",
    family="moe",
    n_layers=48,
    d_model=5120,
    n_heads=40,
    n_kv_heads=8,
    head_dim=128,
    d_ff=16384,                # dense-layer FFN
    vocab_size=202_048,
    block_pattern=(_DENSE, _MOE),
    n_experts=128,
    n_shared_experts=1,
    top_k=1,
    d_ff_expert=8192,
    rope_theta=500_000.0,
    act="silu",
    tie_embeddings=False,
)
