"""phi-3-vision-4.2b [vlm]: 32L, d_model 3072, 32H (kv=32), d_ff 8192,
vocab 32064 — phi3-mini backbone + CLIP frontend (STUB: ``input_specs``
provides precomputed patch embeddings, per the brief).
[hf:microsoft/Phi-3-vision-128k-instruct; hf]"""

from repro.configs.base import LayerSpec, ModelConfig

CONFIG = ModelConfig(
    name="phi-3-vision-4.2b",
    family="vlm",
    n_layers=32,
    d_model=3072,
    n_heads=32,
    n_kv_heads=32,
    head_dim=96,
    d_ff=8192,
    vocab_size=32_064,
    block_pattern=(LayerSpec(mixer="attn", attn_kind="full", ffn="mlp"),),
    n_patches=576,            # stub CLIP-ViT-L/14 336px patch count
    rope_theta=10_000.0,
    act="silu",
    tie_embeddings=False,
)
