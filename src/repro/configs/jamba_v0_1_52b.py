"""jamba-v0.1-52b [hybrid]: 32L, d_model 4096, 32H GQA kv=8, d_ff 14336,
vocab 65536 — Mamba+attention 1:7 interleave, MoE 16 experts top-2 every
other layer.  [arXiv:2403.19887; hf]

Block of 8 layers: attention at in-block index 4, Mamba elsewhere; MoE
FFN on odd in-block indices, dense FFN on even (the paper's e=2, a=8
configuration).  Jamba's Mamba layers use state 16.
"""

from repro.configs.base import LayerSpec, ModelConfig


def _spec(i: int) -> LayerSpec:
    mixer = "attn" if i == 4 else "mamba"
    ffn = "moe" if i % 2 == 1 else "mlp"
    return LayerSpec(mixer=mixer, attn_kind="full", ffn=ffn)


CONFIG = ModelConfig(
    name="jamba-v0.1-52b",
    family="hybrid",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    head_dim=128,
    d_ff=14336,
    vocab_size=65_536,
    block_pattern=tuple(_spec(i) for i in range(8)),
    n_experts=16,
    n_shared_experts=0,
    top_k=2,
    d_ff_expert=14336,
    ssm_state=16,
    ssm_expand=2,
    ssm_head_dim=64,
    ssm_groups=1,
    conv_width=4,
    ssm_chunk=256,
    act="silu",
    tie_embeddings=False,
)
