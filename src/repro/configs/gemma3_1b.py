"""gemma3-1b [dense]: 26L, d_model 1152, 4H GQA kv=1 (MQA), d_ff 6912,
vocab 262144 — 5:1 local:global attention, 128k context.
[hf:google/gemma-3-1b-pt; unverified]

Layer pattern: repeating block of 5 sliding-window (local) layers + 1
global layer; 26 = 4×6 + 2 trailing local layers.  Local layers use the
short RoPE base, global layers the long base (gemma3 convention).
"""

from repro.configs.base import LayerSpec, ModelConfig

_LOCAL = LayerSpec(mixer="attn", attn_kind="local", ffn="mlp")
_GLOBAL = LayerSpec(mixer="attn", attn_kind="full", ffn="mlp")

CONFIG = ModelConfig(
    name="gemma3-1b",
    family="dense",
    n_layers=26,
    d_model=1152,
    n_heads=4,
    n_kv_heads=1,
    head_dim=256,
    d_ff=6912,
    vocab_size=262_144,
    block_pattern=(_LOCAL,) * 5 + (_GLOBAL,),
    suffix_pattern=(_LOCAL, _LOCAL),
    sliding_window=512,
    rope_theta=1_000_000.0,
    rope_theta_local=10_000.0,
    act="gelu",
    qk_norm=True,
    tie_embeddings=True,
    emb_scale_by_sqrt_dim=True,
)
