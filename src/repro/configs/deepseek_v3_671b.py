"""deepseek-v3-671b [moe]: 61L, d_model 7168, 128H (MLA), expert d_ff
2048, vocab 129280, MoE 256 routed top-8 + 1 shared.
[arXiv:2412.19437; hf]

Faithful structure: first 3 layers dense (d_ff 18432), remaining 58 MoE;
MLA with q_lora 1536 / kv_lora 512 / rope 64 / nope 128 / v 128.
MTP (multi-token prediction) is a training-objective add-on, exposed via
``repro.train.step``'s ``mtp_weight`` option rather than the config.
"""

from repro.configs.base import LayerSpec, ModelConfig

_DENSE = LayerSpec(mixer="attn", attn_kind="mla", ffn="mlp")
_MOE = LayerSpec(mixer="attn", attn_kind="mla", ffn="moe")

CONFIG = ModelConfig(
    name="deepseek-v3-671b",
    family="moe",
    n_layers=61,
    d_model=7168,
    n_heads=128,
    n_kv_heads=128,
    head_dim=128,
    d_ff=18432,                 # the 3 dense layers
    vocab_size=129_280,
    prefix_pattern=(_DENSE, _DENSE, _DENSE),
    block_pattern=(_MOE,),
    q_lora_rank=1536,
    kv_lora_rank=512,
    qk_rope_dim=64,
    qk_nope_dim=128,
    v_head_dim=128,
    n_experts=256,
    n_shared_experts=1,
    top_k=8,
    d_ff_expert=2048,
    rope_theta=10_000.0,
    act="silu",
    tie_embeddings=False,
)
