"""yi-6b [dense]: 32L, d_model 4096, 32H GQA kv=4, d_ff 11008,
vocab 64000 — llama-arch GQA.  [arXiv:2403.04652; hf]"""

from repro.configs.base import LayerSpec, ModelConfig

CONFIG = ModelConfig(
    name="yi-6b",
    family="dense",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=4,
    head_dim=128,
    d_ff=11008,
    vocab_size=64_000,
    block_pattern=(LayerSpec(mixer="attn", attn_kind="full", ffn="mlp"),),
    rope_theta=5_000_000.0,
    act="silu",
    tie_embeddings=False,
)
