"""Architecture configs for the assigned zoo.  ``get_config(name)``
accepts both the assignment ids (``gemma3-1b``) and module names
(``gemma3_1b``)."""

from repro.configs.base import (  # noqa: F401
    ARCH_NAMES,
    LayerSpec,
    ModelConfig,
    get_config,
    reduced,
)
