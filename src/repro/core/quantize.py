"""Quantization substrate: symmetric int8/int4 quantization + QAT STE.

The paper's multipliers operate on 8-bit (and nibble/4-bit) integers;
this module is the bridge between the bf16 model world and that integer
world.  Conventions:

* **symmetric, zero-point-free** quantization (scale only) — matches the
  multiplier datapaths, which have no zero-point correction adders;
* per-tensor or per-channel (last-axis) scales;
* int4 values live in ``[-8, 8)`` and are *stored packed* two-per-byte
  (:func:`repro.core.nibble.pack_int4`) — the storage halving the
  nibble decomposition buys on TPU.

``fake_quant`` provides the straight-through estimator used for QAT so
the training graph and the serving graph quantize identically.
"""

from __future__ import annotations

import dataclasses
from typing import Literal

import jax
import jax.numpy as jnp

__all__ = [
    "QTensor",
    "quantize",
    "dequantize",
    "fake_quant",
    "abs_max_scale",
]

Granularity = Literal["per_tensor", "per_channel"]

_QMAX = {8: 127.0, 4: 7.0}


@dataclasses.dataclass
class QTensor:
    """An integer tensor plus its dequantization scale.

    ``values`` is int8 for bits=8; for bits=4 it is int8 holding values in
    [-8, 8) (packing to bytes is the kernel's storage concern, kept
    orthogonal so the reference path stays readable).
    """

    values: jax.Array
    scale: jax.Array        # f32; shape () or (..., 1) broadcastable
    bits: int = 8

    def dequantize(self) -> jax.Array:
        return self.values.astype(jnp.float32) * self.scale

    @property
    def shape(self):
        return self.values.shape


jax.tree_util.register_pytree_node(
    QTensor,
    lambda q: ((q.values, q.scale), q.bits),
    lambda bits, leaves: QTensor(leaves[0], leaves[1], bits),
)


def abs_max_scale(x, bits: int = 8,
                  granularity: Granularity = "per_channel",
                  axis: int = -1) -> jax.Array:
    """Scale s.t. the abs-max of ``x`` maps to the integer max."""
    qmax = _QMAX[bits]
    if granularity == "per_tensor":
        amax = jnp.max(jnp.abs(x))
    else:
        amax = jnp.max(jnp.abs(x), axis=axis, keepdims=True)
    return jnp.maximum(amax, 1e-8) / qmax


def quantize(x, bits: int = 8,
             granularity: Granularity = "per_channel",
             axis: int = -1,
             scale: jax.Array | None = None) -> QTensor:
    """Symmetric round-to-nearest quantization of a float tensor."""
    if scale is None:
        scale = abs_max_scale(x, bits, granularity, axis)
    qmax = _QMAX[bits]
    q = jnp.clip(jnp.round(x / scale), -qmax - 1, qmax).astype(jnp.int8)
    return QTensor(q, scale.astype(jnp.float32), bits)


def dequantize(q: QTensor) -> jax.Array:
    return q.dequantize()


@jax.custom_vjp
def _ste_round(x):
    return jnp.round(x)


def _ste_round_fwd(x):
    return jnp.round(x), None


def _ste_round_bwd(_, g):
    return (g,)  # straight-through: identity gradient


_ste_round.defvjp(_ste_round_fwd, _ste_round_bwd)


def fake_quant(x, bits: int = 8,
               granularity: Granularity = "per_channel",
               axis: int = -1) -> jax.Array:
    """Quantize-dequantize with a straight-through gradient (QAT).

    Forward is numerically identical to quantize→dequantize; backward
    passes gradients through the rounding (clipped to the representable
    range), so the same framework trains what it serves.
    """
    qmax = _QMAX[bits]
    scale = abs_max_scale(jax.lax.stop_gradient(x), bits, granularity, axis)
    clipped = jnp.clip(x / scale, -qmax - 1, qmax)
    return _ste_round(clipped) * scale
