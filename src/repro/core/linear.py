"""QuantLinear — the projection layer every architecture in the zoo uses.

This is where the paper's technique becomes a first-class framework
feature: one linear layer, five interchangeable execution modes.

===============  ==========================================================
mode             semantics
===============  ==========================================================
``dense``        plain bf16 matmul (the "no paper" baseline)
``qat``          bf16 matmul over fake-quantized operands (training mode;
                 straight-through gradients, serves what it trains)
``w8a8_nibble``  int8 activations × int8 weights via the two-pass nibble
                 decomposition (Algorithm 2 lifted to matmul):
                 ``X·W = 16·(X_hi·W) + X_lo·W``
``w4a8_nibble``  int8 activations × int4 weights: the weight *is* a single
                 nibble plane, stored packed two-per-byte — the paper's
                 storage story (half the weight bytes moved from HBM)
``lut``          the LUT-array formulation: selection (one-hot matmul)
                 from a precomputed scaled-value table instead of
                 arithmetic — the paper's throughput-oriented baseline
===============  ==========================================================

Two execution backends: ``backend="xla"`` (default — lowers to int8
``dot_general`` + shifts; used for the distributed dry-runs) and
``backend="pallas"`` (the hand-tiled kernels in ``repro.kernels``; used
on real chips and validated here under ``interpret=True``).
"""

from __future__ import annotations

import dataclasses
from typing import Literal

import jax
import jax.numpy as jnp

from repro.core import quantize as q
from repro.core.nibble import split_nibbles_signed

QuantMode = Literal["dense", "qat", "w8a8_nibble", "w4a8_nibble", "lut"]

__all__ = ["QuantMode", "linear_init", "linear_apply", "nibble_matmul_xla",
           "lut_matmul_xla"]


def linear_init(key, in_dim: int, out_dim: int,
                dtype=jnp.bfloat16) -> dict:
    """He-style init.  Weights are stored (in_dim, out_dim); quantized
    modes quantize on the fly (weights stay bf16 in the param pytree so
    one checkpoint serves every mode — the serving path folds the
    quantization constant at compile time)."""
    scale = (2.0 / (in_dim + out_dim)) ** 0.5
    w = jax.random.normal(key, (in_dim, out_dim), jnp.float32) * scale
    return {"w": w.astype(dtype)}


# ---------------------------------------------------------------------------
# XLA-backend quantized matmuls (the distributable formulations)
# ---------------------------------------------------------------------------

def nibble_matmul_xla(x_q: jax.Array, w_q: jax.Array,
                      *, w_bits: int = 8) -> jax.Array:
    """Single-pass plane-concatenated nibble matmul, int32 accumulation.

    ``x_q``: (..., K) int8.  ``w_q``: (K, N) int8 (w_bits=8) or int4
    values in int8 storage (w_bits=4).  Returns (..., N) int32.

    This is Algorithm 2 with the vector-lane loop replaced by the MXU,
    and the fixed ``<< 4`` alignment folded into the operand layout: the
    high plane is pre-shifted at the operand edge (``hi << 4 == x - lo``
    stays int8-safe) and both planes are concatenated along K, so one
    ``dot_general`` against the twice-stacked weight evaluates both
    "deterministic cycles" in a single MXU pass — the same dataflow the
    Pallas kernels use.
    """
    del w_bits  # int4-in-int8 storage goes through the identical dot
    x_lo, x_hi = split_nibbles_signed(x_q)          # int32 planes, [0,16) / [-8,8)
    x_cat = jnp.concatenate([x_lo, x_hi << 4], axis=-1).astype(jnp.int8)
    w_q = w_q.astype(jnp.int8)
    w_cat = jnp.concatenate([w_q, w_q], axis=0)      # shared tile, reused
    return jax.lax.dot_general(
        x_cat, w_cat, (((x_cat.ndim - 1,), (0,)), ((), ())),
        preferred_element_type=jnp.int32)


def lut_matmul_xla(x_q: jax.Array, w_q: jax.Array) -> jax.Array:
    """LUT-array formulation: selection instead of multiplication.

    For every activation nibble value k in [0,16) the scaled weight
    ``k·W`` row is conceptually precomputed (the hex string); selection
    of the right row is a one-hot(16) matmul — the TPU-idiomatic
    realisation of the paper's 16:1 slice mux.  Equivalent arithmetic,
    selection-dominated dataflow, exactly the paper's LM design point.
    """
    x_lo, x_hi = split_nibbles_signed(x_q)
    # one-hot over the 16 nibble values: (..., K, 16)
    hot_lo = jax.nn.one_hot(x_lo, 16, dtype=jnp.int8)
    hot_hi = jax.nn.one_hot(x_hi & 0xF, 16, dtype=jnp.int8)
    k_scales = jnp.arange(16, dtype=jnp.int32)
    # signed value of the hi nibble pattern
    k_signed = k_scales - ((k_scales >> 3) << 4)

    # selected scale per (.., K) position — "slice extraction"
    sel_lo = jax.lax.dot_general(hot_lo, k_scales.astype(jnp.int8)[:, None],
                                 (((hot_lo.ndim - 1,), (0,)), ((), ())),
                                 preferred_element_type=jnp.int32)[..., 0]
    sel_hi = jax.lax.dot_general(hot_hi, k_signed.astype(jnp.int8)[:, None],
                                 (((hot_hi.ndim - 1,), (0,)), ((), ())),
                                 preferred_element_type=jnp.int32)[..., 0]
    x_rec = (sel_lo + (sel_hi << 4)).astype(jnp.int8)  # == x_q, via selection
    return jax.lax.dot_general(
        x_rec, w_q.astype(jnp.int8), (((x_rec.ndim - 1,), (0,)), ((), ())),
        preferred_element_type=jnp.int32)


# ---------------------------------------------------------------------------
# The layer
# ---------------------------------------------------------------------------

def linear_apply(params: dict, x: jax.Array, *,
                 mode: QuantMode = "dense",
                 backend: str = "xla") -> jax.Array:
    """Apply the projection in the selected quantization mode.

    Output dtype follows ``x`` (bf16 in the models); integer modes
    dequantize the int32 accumulator with the folded scales.
    """
    w = params["w"]
    if mode == "dense":
        return jnp.dot(x, w.astype(x.dtype))

    if mode == "qat":
        xq = q.fake_quant(x.astype(jnp.float32), bits=8, axis=-1)
        wq = q.fake_quant(w.astype(jnp.float32), bits=8, axis=0)
        return jnp.dot(xq, wq).astype(x.dtype)

    # integer serving modes -------------------------------------------------
    w_bits = 4 if mode == "w4a8_nibble" else 8
    x_f = x.astype(jnp.float32)
    x_qt = q.quantize(x_f, bits=8, granularity="per_tensor")
    w_qt = q.quantize(w.astype(jnp.float32), bits=w_bits,
                      granularity="per_channel", axis=0)

    if backend == "pallas":
        from repro.kernels import ops  # deferred: kernels import pallas
        # single dispatch path; nibble modes fuse the dequant epilogue
        # in-kernel and emit x.dtype directly (no int32 HBM round-trip)
        return ops.quant_matmul(
            x_qt.values, w_qt.values,
            x_scale=x_qt.scale, w_scale=w_qt.scale.reshape(1, -1),
            w_format="lut" if mode == "lut" else "int8",
            out_dtype=x.dtype)

    if mode == "lut":
        acc = lut_matmul_xla(x_qt.values, w_qt.values)
    else:
        acc = nibble_matmul_xla(x_qt.values, w_qt.values, w_bits=w_bits)

    out = acc.astype(jnp.float32) * x_qt.scale * w_qt.scale.reshape(1, -1)
    return out.astype(x.dtype)
