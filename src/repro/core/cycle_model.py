"""Analytical cycle / area / power model reproducing Table 2 and Fig. 4.

The paper's synthesis numbers are properties of TSMC 28 nm standard
cells, which we obviously cannot re-synthesise here.  What we *can*
reproduce — and validate the paper's claims against — is the structural
model behind them:

1. **Cycle complexity (Table 2)** is purely architectural: W, W/2, W/4
   cycles per operand for shift-add / Booth / nibble, 1 for Wallace and
   the LUT array.  Reproduced exactly from the dataflow definitions.

2. **Area / power scaling (Fig. 4)** follows an affine law in vector
   width N: ``cost(N) = shared + per_lane · N``.  The *shared* term is
   the logic the paper's "reuse" amortises across lanes (the broadcast-B
   nibble selector, control FSM, and — for the LUT design — the hex
   strings); the *per-lane* term is the replicated datapath.  We derive
   gate-count proxies per architecture from the datapath structure,
   calibrate the single gate→µm² and gate→mW constants on the shift-add
   baseline (as the paper normalises to shift-add), and check that the
   resulting model reproduces the paper's reported µm²/mW within
   tolerance and — more importantly — the claimed ratios (1.69× area,
   1.63× power vs shift-add; ~2.6×/2.7× vs LUT array at 16 operands).

Everything here is plain Python/NumPy — it is the "napkin math" layer
the hillclimbing methodology asks for, made executable and tested.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.nibble import pl_adder_count

__all__ = [
    "cycles_per_operand",
    "total_cycles",
    "gate_counts",
    "area_um2",
    "power_mw",
    "paper_reported",
    "ARCHES",
]

ARCHES = ("shift_add", "booth_radix2", "nibble_precompute", "wallace",
          "lut_array")


# ---------------------------------------------------------------------------
# Table 2 — cycle complexity
# ---------------------------------------------------------------------------

def cycles_per_operand(arch: str, width: int = 8) -> int:
    if arch == "shift_add":
        return width                    # O(W)
    if arch == "booth_radix2":
        return width // 2               # O(W/2)
    if arch == "nibble_precompute":
        return width // 4               # O(W/4): fixed 4-bit decomposition
    if arch in ("wallace", "lut_array"):
        return 1                        # O(1) combinational
    raise KeyError(arch)


def total_cycles(arch: str, n_operands: int, width: int = 8) -> int:
    """Table 2 right column: N-operand latency.

    Sequential designs stream operands through shared control: N × per-op.
    Combinational designs replicate lanes and finish in one cycle.
    """
    per = cycles_per_operand(arch, width)
    if arch in ("wallace", "lut_array"):
        return 1
    return per * n_operands


# ---------------------------------------------------------------------------
# Structural gate-count proxies (NAND2-equivalent units)
# ---------------------------------------------------------------------------
# Unit costs (NAND2 equivalents) for the structures each datapath uses.
_FA = 6        # full adder
_FF = 5        # flip-flop (register bit)
_MUX2 = 3      # 2:1 mux bit
_AND = 1


@dataclasses.dataclass(frozen=True)
class GateCount:
    per_lane: float     # replicated per vector element
    shared: float       # amortised across the vector (the paper's "reuse")
    activity: float     # relative switching activity per completed product


def gate_counts(arch: str, width: int = 8) -> GateCount:
    """Structural gate counts per architecture for W-bit operands."""
    w = width
    if arch == "shift_add":
        # per lane: W-bit adder, 2W-bit product/shift register, W-bit
        # multiplicand reg, and the add-enable gating; W cycles of
        # register+adder switching per product.
        per = w * _FA + 2 * w * _FF + w * _FF + w * _AND
        shared = 8 * _FF + 10          # cycle counter + FSM
        return GateCount(per, shared, activity=float(w))
    if arch == "booth_radix2":
        # per lane: W+2-bit adder/subtractor (+ negation row), 2W+2
        # product reg, recode logic (3-bit window decode) per step.
        per = (w + 2) * _FA * 1.4 + (2 * w + 2) * _FF + w * _FF + 12
        shared = 6 * _FF + 12
        return GateCount(per, shared, activity=float(w // 2) * 1.15)
    if arch == "nibble_precompute":
        # per lane (Fig. 2(c)): PL block = up to 3 narrow additions of
        # shifted A (avg adders over the 16 recipes), a (W+4)-bit
        # accumulate adder, A register and accumulator register.
        avg_pl_adders = float(np.mean([pl_adder_count(k) for k in range(16)]))
        per = (avg_pl_adders * (w + 4) * _FA          # PL adder tree
               + (2 * w) * _FA                        # accumulator adder
               + w * _FF + 2 * w * _FF)               # A reg + acc reg
        # shared: broadcast-B nibble selector + FSM — reused by ALL lanes.
        shared = (2 * w * _FF + 16 * _MUX2 + 14)
        return GateCount(per, shared, activity=float(w // 4))
    if arch == "wallace":
        # per lane: W^2 PP AND gates + ~(W^2 - 2W) FAs of reduction tree
        # + 2W-bit CPA; no registers (combinational), but high glitch
        # activity in the deep tree.
        per = w * w * _AND + (w * w - 2 * w) * _FA + 2 * w * _FA
        shared = 0.0
        return GateCount(per, shared, activity=2.6)
    if arch == "lut_array":
        # per lane: four 16:1 × 8-bit slice muxes (15 MUX2-levels each)
        # + alignment adders; shared: the two hex-string constant
        # networks selected by B's nibbles (16-entry × 120-bit constant
        # mux each) — large, and interconnect-heavy (×1.5 routing).
        per = 4 * (15 * 8 * _MUX2) * 1.5 + 3 * (2 * w) * _FA
        shared = 2 * (15 * 120 * _MUX2) * 1.5
        return GateCount(per, shared, activity=3.2)
    raise KeyError(arch)


# ---------------------------------------------------------------------------
# Calibration against the paper's synthesis numbers
# ---------------------------------------------------------------------------
# Paper-reported datapoints (Fig. 4; §III.C text).  Missing cells in the
# paper's prose are reconstructed from its stated normalized ratios and
# marked derived=True in ``paper_reported``.
_PAPER_AREA = {   # µm² at (4, 8, 16) operands
    "shift_add":         (528.57, 982.42, 1913.57),   # 16-op from 1.69× ratio
    "booth_radix2":      (465.32, None, None),
    "nibble_precompute": (463.55, 673.60, 1132.29),
    "wallace":           (584.14, None, 2336.54),
    "lut_array":         (806.78, 1523.72, 2954.20),
}
_PAPER_POWER = {  # mW at (4, 8, 16) operands, 1 GHz
    "shift_add":         (0.0269, 0.0510, 0.0988),
    "booth_radix2":      (0.0257, None, None),
    "nibble_precompute": (0.0325, 0.0442, 0.0605),
    "wallace":           (0.0540, 0.1080, 0.2160),
    "lut_array":         (0.0727, 0.1380, 0.2760),
}


def paper_reported(metric: str, arch: str) -> tuple:
    """Raw paper datapoints; None where the paper omits the number."""
    table = _PAPER_AREA if metric == "area" else _PAPER_POWER
    return table[arch]


def _affine_fit(points: tuple, ns=(4, 8, 16)) -> tuple[float, float]:
    """Least-squares (shared, per_lane) over the available datapoints."""
    xs = [n for n, p in zip(ns, points) if p is not None]
    ys = [p for p in points if p is not None]
    if len(xs) == 1:
        return 0.0, ys[0] / xs[0]
    a = np.vstack([np.ones(len(xs)), xs]).T
    coef, *_ = np.linalg.lstsq(a, np.asarray(ys), rcond=None)
    return float(coef[0]), float(coef[1])


# The paper's Fig. 4 data is affine in vector width N to within ~2%
# (verified in tests/test_cycle_model.py): cost(N) = shared + per_lane·N.
# That affine structure *is* the paper's logic-reuse claim made
# quantitative — the nibble design has a large shared term (broadcast-B
# precompute selection + control, amortised across lanes) and a small
# per-lane term, so it wins asymptotically; shift-add is the opposite.
# We fit (shared, per_lane) per architecture from the reported points and
# use the fit as the calibrated model.  Booth has a single reported point
# (N=4); we assume its shared control matches shift-add's (both are
# sequential FSM designs) and solve the per-lane term from that point.
# ``gate_counts`` above remains as the structural *explanation* of why
# the per-lane ordering comes out the way it does; it is deliberately not
# used as the quantitative model (standard-cell mapping, wire load and
# synthesis optimisation dominate absolute µm², which no gate-count proxy
# reproduces honestly).

def _calibrate(table: dict) -> dict[str, tuple[float, float]]:
    coefs: dict[str, tuple[float, float]] = {}
    sa = _affine_fit(table["shift_add"])
    for arch, pts in table.items():
        n_pts = sum(p is not None for p in pts)
        if n_pts >= 2:
            coefs[arch] = _affine_fit(pts)
        else:  # booth: one point; share shift-add's intercept
            shared = sa[0]
            n, p = next((n, p) for n, p in zip((4, 8, 16), pts)
                        if p is not None)
            coefs[arch] = (shared, (p - shared) / n)
    return coefs


_AREA_COEF = _calibrate(_PAPER_AREA)
_POWER_COEF = _calibrate(_PAPER_POWER)


def area_um2(arch: str, n_operands: int, width: int = 8) -> float:
    """Calibrated area model (µm², TSMC 28 nm HPC+), affine in N.

    Interpolates/extrapolates the paper's Fig. 4(a); exact at the
    reported (arch, N) points to within the affine residual (~2%).
    """
    if width != 8:
        raise NotImplementedError("Fig. 4 calibration is for 8-bit operands")
    shared, lane = _AREA_COEF[arch]
    return shared + lane * n_operands


def power_mw(arch: str, n_operands: int, width: int = 8) -> float:
    """Calibrated total-power model (mW at 1 GHz, 1.05 V), affine in N."""
    if width != 8:
        raise NotImplementedError("Fig. 4 calibration is for 8-bit operands")
    shared, lane = _POWER_COEF[arch]
    return shared + lane * n_operands


def energy_per_product_pj(arch: str, n_operands: int, width: int = 8,
                          freq_ghz: float = 1.0) -> float:
    """Energy per completed product (power × time / throughput)."""
    p_mw = power_mw(arch, n_operands, width)
    cyc = total_cycles(arch, n_operands, width)
    t_ns = cyc / freq_ghz
    return p_mw * t_ns / n_operands  # mW·ns = pJ


def improvement_vs(baseline: str, arch: str, metric: str,
                   n_operands: int) -> float:
    """Paper-style normalized improvement (baseline / arch)."""
    fn = area_um2 if metric == "area" else power_mw
    return fn(baseline, n_operands) / fn(arch, n_operands)
