"""Nibble decomposition and precompute-logic (PL) primitives.

This module is the bit-level heart of the paper: every operand is treated
as a composition of 4-bit nibbles, and multiplication by a nibble value
``k`` is realised as a fixed shift-and-add *recipe* (the paper's
"precompute logic", Fig. 2(b)) rather than as a generic multiply.

Two operand conventions are supported:

* **unsigned** (the paper's convention): an 8-bit operand ``x`` is
  ``x = (hi << 4) | lo`` with ``hi, lo`` in ``[0, 16)``.
* **signed** (what int8 inference uses): ``x = hi * 16 + lo`` with the
  high nibble *arithmetic*-shifted (``hi in [-8, 8)``) and the low nibble
  unsigned (``lo in [0, 16)``).  This keeps both planes representable in
  int8 and makes the two-pass nibble matmul exact for signed operands.

Everything here is pure ``jnp`` and shape-polymorphic; the Pallas kernels
in ``repro.kernels`` reuse these helpers inside kernel bodies (they are
traceable on any backend, including the Pallas interpreter).
"""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np

__all__ = [
    "split_nibbles_unsigned",
    "split_nibbles_signed",
    "combine_nibbles",
    "pl_scale",
    "pl_recipe_table",
    "pl_adder_count",
    "pack_int4",
    "unpack_int4",
]


# ---------------------------------------------------------------------------
# Nibble decomposition
# ---------------------------------------------------------------------------

def split_nibbles_unsigned(x):
    """Split unsigned 8-bit values into (lo, hi) nibbles, both in [0, 16).

    ``x`` may be any integer dtype holding values in [0, 256).
    Returns int32 planes so downstream shift-add arithmetic cannot wrap.
    """
    x = x.astype(jnp.int32) & 0xFF
    lo = x & 0xF
    hi = (x >> 4) & 0xF
    return lo, hi


def split_nibbles_signed(x):
    """Split signed int8 values into (lo, hi): ``x == hi * 16 + lo``.

    ``lo`` is the unsigned low nibble in [0, 16); ``hi`` is the
    arithmetically shifted high nibble in [-8, 8).  Exact for all int8.
    """
    x = x.astype(jnp.int32)
    lo = x & 0xF
    hi = (x - lo) >> 4  # arithmetic shift; exact since x - lo is a multiple of 16
    return lo, hi


def combine_nibbles(lo, hi):
    """Inverse of the splits above: ``hi * 16 + lo`` in int32."""
    return hi.astype(jnp.int32) * 16 + lo.astype(jnp.int32)


# ---------------------------------------------------------------------------
# Precompute logic (PL): k * A as fixed shift-and-add recipes, k in [0, 16)
# ---------------------------------------------------------------------------

# The paper's Fig. 2(b) table: each nibble value selects a structured
# combination of fixed shifts of A.  Add-only recipes (no Booth-style
# subtraction) — the recipe for k is exactly the set-bit expansion of k,
# which is what "structured combination of fixed shifts and limited
# additions" synthesises to.  Shift amounts per nibble value:
_PL_RECIPES: list[tuple[int, ...]] = [
    (),            # 0:  0
    (0,),          # 1:  A
    (1,),          # 2:  A<<1
    (1, 0),        # 3:  A<<1 + A
    (2,),          # 4:  A<<2
    (2, 0),        # 5:  A<<2 + A
    (2, 1),        # 6:  A<<2 + A<<1
    (2, 1, 0),     # 7:  A<<2 + A<<1 + A
    (3,),          # 8:  A<<3
    (3, 0),        # 9:  A<<3 + A
    (3, 1),        # 10: A<<3 + A<<1
    (3, 1, 0),     # 11
    (3, 2),        # 12
    (3, 2, 0),     # 13
    (3, 2, 1),     # 14
    (3, 2, 1, 0),  # 15
]


def pl_recipe_table() -> list[tuple[int, ...]]:
    """The sixteen shift-and-add configurations (Fig. 2(b))."""
    return list(_PL_RECIPES)


def pl_adder_count(k: int) -> int:
    """Number of two-input additions the PL block performs for nibble k.

    Used by the analytical area/power model: recipe with m shifted terms
    needs m-1 adders (shifts are free wiring in the datapath).
    """
    terms = len(_PL_RECIPES[k & 0xF])
    return max(0, terms - 1)


def pl_scale(a, k):
    """``k * a`` computed via the shift-and-add precompute logic.

    ``a``: integer array (int32 recommended).  ``k``: integer array of
    nibble values in [0, 16), broadcastable against ``a``.

    Hardware realisation: the nibble value one-hot-selects one of the 16
    fixed recipes.  In JAX we express the same dataflow as the four
    bit-gated shifted terms — identical arithmetic, and it lowers to
    shifts/ands/adds only (no general multiplier), which is the point.
    """
    a = a.astype(jnp.int32)
    k = k.astype(jnp.int32)
    out = jnp.zeros(jnp.broadcast_shapes(a.shape, k.shape), jnp.int32)
    for bit in range(4):
        gate = (k >> bit) & 1          # is the (A << bit) term in the recipe?
        out = out + gate * (a << bit)  # gate is 0/1: pure add of a shifted term
    return out


# ---------------------------------------------------------------------------
# int4 packing (two nibbles per byte) — storage format for W4A8 weights
# ---------------------------------------------------------------------------

def pack_int4(w):
    """Pack signed int4 values (range [-8, 8)) pairwise into int8 bytes.

    ``w``: int array whose *last* dimension is even; values must be in
    [-8, 8).  Returns int8 array with last dim halved: byte = (hi<<4)|lo
    with lo/hi the two's-complement low nibbles of consecutive elements.
    """
    w = jnp.asarray(w)
    if w.shape[-1] % 2:
        raise ValueError("pack_int4: last dimension must be even")
    lo = w[..., 0::2].astype(jnp.int32) & 0xF
    hi = w[..., 1::2].astype(jnp.int32) & 0xF
    packed = (hi << 4) | lo
    # Map [0,256) to int8 two's complement.
    return ((packed + 128) % 256 - 128).astype(jnp.int8)


def unpack_int4(packed):
    """Inverse of :func:`pack_int4`; returns int8 values in [-8, 8).

    The unpacking *is* the paper's shift-based precompute: each nibble is
    recovered with a shift and a sign-extension add — no multiplier.
    """
    p = packed.astype(jnp.int32) & 0xFF
    lo = p & 0xF
    hi = (p >> 4) & 0xF
    # sign-extend 4-bit two's complement
    lo = lo - ((lo >> 3) << 4)
    hi = hi - ((hi >> 3) << 4)
    out = jnp.stack([lo, hi], axis=-1).reshape(*packed.shape[:-1], -1)
    return out.astype(jnp.int8)


def pl_scale_reference(a, k):
    """Plain-multiply oracle for :func:`pl_scale` (tests only)."""
    return (a.astype(jnp.int32) * (k.astype(jnp.int32) & 0xF)).astype(jnp.int32)


def numpy_pl_scale(a: np.ndarray, k: int) -> np.ndarray:
    """NumPy mirror of the recipe dataflow, used by exhaustive tests."""
    out = np.zeros_like(a, dtype=np.int64)
    for shift in _PL_RECIPES[k & 0xF]:
        out = out + (a.astype(np.int64) << shift)
    return out
