"""Bit-faithful JAX models of the paper's five multiplier architectures.

Each function computes an N-lane vector-scalar product ``R[i] = A[i] * B``
exactly the way the corresponding RTL datapath does — same decomposition,
same per-cycle partial values, same accumulation order — and returns a
:class:`MultiplyTrace` carrying the result *and* the cycle/structural
accounting used by the Table-2 / Fig-4 reproductions.

Architectures (paper §II–III):

* ``shift_add``        — sequential, 1 bit/cycle, W cycles/operand.
* ``booth_radix2``     — sequential Booth recoding, W/2 cycles/operand.
  (The paper labels this "Booth (Radix-2)" while quoting O(W/2)/4-cycle
  latency; that latency corresponds to *modified Booth* two-bit recoding,
  which is what we implement — noted in DESIGN.md.)
* ``nibble_precompute``— the paper's contribution (Algorithm 2): two
  nibble passes through the precompute logic, W/4 cycles/operand.
* ``wallace``          — combinational partial-product reduction, 1 cycle.
* ``lut_array``        — the paper's LUT-based array multiplier
  (Algorithm 1): hex-string lookup + slice + shift + add, 1 cycle.

All models operate on unsigned 8-bit operands (the paper's setting) and
produce exact 16-bit products; ``nibble_precompute`` additionally
supports signed int8 via the signed nibble split (used by the kernels).
"""

from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.nibble import (
    pl_scale,
    split_nibbles_signed,
    split_nibbles_unsigned,
)

__all__ = [
    "MultiplyTrace",
    "shift_add",
    "booth_radix2",
    "nibble_precompute",
    "wallace",
    "lut_array",
    "build_hex_string_lut",
    "MULTIPLIERS",
]


@dataclasses.dataclass(frozen=True)
class MultiplyTrace:
    """Result of a vector-scalar multiply plus architectural accounting."""

    products: jax.Array          # (N,) int32 exact products
    cycles: int                  # total clock cycles for the N-lane op
    cycles_per_operand: int      # latency per vector element
    name: str

    def __iter__(self):  # allow ``products, cycles = trace``-style unpacking
        yield self.products
        yield self.cycles


def _as_lanes(a) -> jax.Array:
    a = jnp.atleast_1d(jnp.asarray(a))
    return a.astype(jnp.int32)


# ---------------------------------------------------------------------------
# Sequential baselines
# ---------------------------------------------------------------------------

def shift_add(a, b, width: int = 8) -> MultiplyTrace:
    """Classic shift-add: one multiplicand bit examined per cycle."""
    a = _as_lanes(a) & ((1 << width) - 1)
    b = jnp.asarray(b, jnp.int32) & ((1 << width) - 1)

    def cycle(step, acc):
        bit = (b >> step) & 1                 # the bit under the scan head
        return acc + bit * (a << step)        # add shifted multiplicand if set

    products = jax.lax.fori_loop(0, width, cycle, jnp.zeros_like(a))
    n = int(a.shape[0])
    return MultiplyTrace(products, cycles=width * n, cycles_per_operand=width,
                         name="shift_add")


def booth_radix2(a, b, width: int = 8) -> MultiplyTrace:
    """Modified-Booth recoding: two multiplier bits retired per cycle.

    Recodes b into width/2 digits in {-2,-1,0,+1,+2}; each cycle adds one
    recoded, shifted multiple of ``a``.  Booth recoding is a *signed*
    (two's-complement) scheme, so this model takes signed operands —
    exact for the full int8 × int8 range in ``width//2`` cycles.
    """
    a = _as_lanes(a)
    b = jnp.asarray(b, jnp.int32)
    b_ext = b << 1  # append the Booth guard zero below bit 0

    def cycle(step, acc):
        window = (b_ext >> (2 * step)) & 0x7          # bits [2i+1 : 2i-1]
        # Booth digit for each 3-bit window value 0..7:
        digits = jnp.array([0, 1, 1, 2, -2, -1, -1, 0], jnp.int32)
        d = digits[window]
        return acc + d * (a << (2 * step))

    products = jax.lax.fori_loop(0, width // 2, cycle, jnp.zeros_like(a))
    n = int(a.shape[0])
    return MultiplyTrace(products, cycles=(width // 2) * n,
                         cycles_per_operand=width // 2, name="booth_radix2")


# ---------------------------------------------------------------------------
# The paper's contribution: precompute-reuse nibble multiplier (Algorithm 2)
# ---------------------------------------------------------------------------

def nibble_precompute(a, b, *, signed: bool = False) -> MultiplyTrace:
    """Algorithm 2: two nibble passes through the precompute logic (PL).

    The broadcast scalar ``b`` is decomposed once into (lo, hi) nibbles;
    every vector lane then evaluates ``PL(A, b_lo) + (PL(A, b_hi) << 4)``.
    The per-lane datapath is exactly Fig. 2(c): PL block → fixed shift →
    accumulate; two cycles per 8-bit element in sequential mode.
    """
    a = _as_lanes(a)
    b = jnp.asarray(b, jnp.int32)
    if signed:
        b_lo, b_hi = split_nibbles_signed(b.astype(jnp.int8))
        # hi nibble may be negative: PL handles magnitudes; fold the sign.
        hi_sign = jnp.where(b_hi < 0, -1, 1)
        partial_lo = pl_scale(a, b_lo)
        partial_hi = hi_sign * pl_scale(a, jnp.abs(b_hi))
    else:
        b_lo, b_hi = split_nibbles_unsigned(b)
        partial_lo = pl_scale(a, b_lo)          # cycle 0: PL pass, shift 0
        partial_hi = pl_scale(a, b_hi)          # cycle 1: PL pass, shift 4
    acc = partial_lo + (partial_hi << 4)        # fixed alignment + accumulate
    n = int(a.shape[0])
    return MultiplyTrace(acc, cycles=2 * n, cycles_per_operand=2,
                         name="nibble_precompute")


# ---------------------------------------------------------------------------
# Combinational baselines
# ---------------------------------------------------------------------------

def wallace(a, b, width: int = 8) -> MultiplyTrace:
    """Wallace-tree model: all partial products formed, reduced in one cycle.

    Software is cycle-exact trivially (1 cycle); we still materialise the
    full partial-product matrix so the dataflow mirrors the RTL.
    """
    a = _as_lanes(a) & ((1 << width) - 1)
    b = jnp.asarray(b, jnp.int32) & ((1 << width) - 1)
    pp = [(((b >> i) & 1) * (a << i)) for i in range(width)]  # all PPs at once
    products = jnp.sum(jnp.stack(pp, 0), axis=0)
    return MultiplyTrace(products, cycles=1, cycles_per_operand=1,
                         name="wallace")


def build_hex_string_lut() -> np.ndarray:
    """The hex-string LUT of Fig. 1(a) as a (16, 16) uint16 product table.

    Row ``b`` is the paper's ResString for nibble value ``b``: the
    concatenation of 8-bit segments ``b*1 … b*15`` (segment 0 is the
    implicit zero handled by the ``A != 0`` guards in Algorithm 1).
    table[b, a] == the 8-bit segment extracted by slice index ``a``.
    """
    b = np.arange(16, dtype=np.uint16)[:, None]
    a = np.arange(16, dtype=np.uint16)[None, :]
    return (b * a).astype(np.uint16)  # every entry < 256: fits the 8-bit slice


def lut_array(a, b, width: int = 8) -> MultiplyTrace:
    """Algorithm 1: LUT-based array multiplier (the paper's LM block).

    Lines 5: select ResString0/1 with the B nibbles.  Lines 6-13: each A
    nibble slices an 8-bit segment from each string.  Lines 14-15: fixed
    shifts + accumulation.  One combinational cycle.
    """
    if width != 8:
        raise NotImplementedError("LM block is specified for 8-bit operands")
    a = _as_lanes(a) & 0xFF
    b = jnp.asarray(b, jnp.int32) & 0xFF
    lut = jnp.asarray(build_hex_string_lut(), jnp.int32)

    a0, a1 = split_nibbles_unsigned(a)       # A nibble slice indices
    b0, b1 = split_nibbles_unsigned(b)
    res_string0 = lut[b0]                    # (16,) selected hex string rows
    res_string1 = lut[b1]

    p0 = res_string0[a0]                     # slice extraction (Alg.1 L6-9)
    p2 = res_string1[a0]
    p1 = res_string0[a1]
    p3 = res_string1[a1]
    out = p0 + (p2 << 4) + (p1 << 4) + (p3 << 8)   # Alg.1 L14
    return MultiplyTrace(out, cycles=1, cycles_per_operand=1, name="lut_array")


def lut_array_16bit(a16, b) -> tuple[jax.Array, jax.Array]:
    """Algorithm 1 in full: 16-bit A (4 nibbles), 8-bit B, two 16-bit outs.

    Returns (Out1, Out2) per Alg. 1 lines 14-15: Out1 covers A[7:0]*B and
    Out2 covers A[15:8]*B; the caller composes ``Out1 + (Out2 << 8)``.
    """
    a16 = _as_lanes(a16) & 0xFFFF
    b = jnp.asarray(b, jnp.int32) & 0xFF
    lut = jnp.asarray(build_hex_string_lut(), jnp.int32)
    b0, b1 = split_nibbles_unsigned(b)
    rs0, rs1 = lut[b0], lut[b1]
    a0 = a16 & 0xF
    a1 = (a16 >> 4) & 0xF
    a2 = (a16 >> 8) & 0xF
    a3 = (a16 >> 12) & 0xF
    out1 = rs0[a0] + (rs1[a0] << 4) + (rs0[a1] << 4) + (rs1[a1] << 8)
    out2 = rs0[a2] + (rs1[a2] << 4) + (rs0[a3] << 4) + (rs1[a3] << 8)
    return out1, out2


MULTIPLIERS = {
    "shift_add": shift_add,
    "booth_radix2": booth_radix2,
    "nibble_precompute": nibble_precompute,
    "wallace": wallace,
    "lut_array": lut_array,
}
