"""Core: the paper's contribution (nibble precompute-reuse multiplication).

Layers:
* ``nibble``      — nibble decomposition + the 16 precompute-logic recipes
* ``multipliers`` — bit-faithful models of all five architectures
* ``quantize``    — int8/int4 quantization substrate + QAT STE
* ``cycle_model`` — analytical Table-2 / Fig-4 reproduction
* ``linear``      — QuantLinear, the framework-facing layer
"""

from repro.core import cycle_model, linear, multipliers, nibble, quantize  # noqa: F401
from repro.core.linear import linear_apply, linear_init  # noqa: F401
from repro.core.multipliers import MULTIPLIERS  # noqa: F401
