"""Pallas TPU kernel: LUT-based array multiplier as selection matmul.

The paper's Fig. 1 design: multiplication by lookup — precomputed scaled
values of the *shared* operand are selected by the other operand's
nibbles, then aligned and summed.  TPUs have no per-lane 16:1 mux in the
MXU datapath, so the TPU-idiomatic realisation of the selection network
is a **one-hot matmul** against the precomputed table (DESIGN.md §2):

* per (bk, bn) weight tile, build the hex-string analogue
  ``table[k*16+v, n] = scale(v) · w[k, n]`` — sixteen scaled copies of
  the broadcast tile, precomputed once per grid step and held in VMEM
  (the paper's ResStrings);
* the activation nibble plane becomes a one-hot matrix
  ``onehot[m, k*16+v] = (x_nibble[m, k] == v)`` — the mux select lines;
* the product is ``onehot @ table`` — deterministic selection +
  accumulation, no arithmetic partial products.

Like the nibble kernel, the two plane selections are fused into **one**
MXU pass: the lo/hi one-hot planes are concatenated along the selection
dimension and the hi table carries the fixed ``<< 4`` alignment folded
in (int16-safe: ``|8·127| << 4 < 2^15``).  The K loop accumulates into a
VMEM scratch block and the int32 output block is written exactly once,
at the last K step.

This preserves the paper's design point exactly: single-pass,
selection-dominated, and more expensive per element than the nibble
kernel (the selection matmul has 16× the contraction width) — which is
precisely the area/power story Fig. 4 tells, translated to FLOPs/bytes.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["lut_matmul_pallas"]


def _lut_matmul_kernel(x_ref, w_ref, o_ref, acc_ref):
    k_step = pl.program_id(2)

    @pl.when(k_step == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    x = x_ref[...].astype(jnp.int32)                    # (bm, bk)
    w = w_ref[...].astype(jnp.int32)                    # (bk, bn)
    bm, bk = x.shape
    _, bn = w.shape

    # --- precompute: sixteen scaled copies of the shared weight tile ----
    # lo rows use unsigned scales 0..15; hi rows use the signed nibble
    # values (v - 16 for v >= 8) with the fixed << 4 alignment folded
    # into the table (int16 range is sufficient: |8·127·16| < 2^15).
    v = jnp.arange(16, dtype=jnp.int32)
    v_signed = v - ((v >> 3) << 4)
    # (bk, 16, bn) -> (bk*16, bn); "ResString" layout: nibble-major per k
    table_lo = (w[:, None, :] * v[None, :, None]).reshape(bk * 16, bn)
    table_hi = (w[:, None, :] * (v_signed << 4)[None, :, None]) \
        .reshape(bk * 16, bn)

    # --- selection: one-hot of each nibble plane, concatenated ----------
    x_lo = x & 0xF
    x_hi = (x >> 4) & 0xF                               # raw hi pattern
    col = jax.lax.broadcasted_iota(jnp.int32, (bm, bk, 16), 2)

    def onehot(nib):
        return (nib[:, :, None] == col).astype(jnp.int8).reshape(bm, bk * 16)

    hot = jnp.concatenate([onehot(x_lo), onehot(x_hi)], axis=1)
    table = jnp.concatenate([table_lo, table_hi], axis=0).astype(jnp.int16)
    acc_ref[...] += jax.lax.dot_general(                # one selection pass
        hot, table,
        (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.int32)

    @pl.when(k_step == pl.num_programs(2) - 1)
    def _flush():
        o_ref[...] = acc_ref[...]


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk", "interpret"))
def lut_matmul_pallas(x_q: jax.Array, w_q: jax.Array, *,
                      bm: int = 128, bn: int = 128, bk: int = 128,
                      interpret: bool = True) -> jax.Array:
    """int8 (M,K) × int8 (K,N) → int32 (M,N) via LUT selection, exact.

    VMEM note: the precomputed table is 2 × (bk·16, bn) int16 — at the
    128/128 defaults that is 16 MiB-scale-safe (2 × 128·16·128·2 B =
    1 MiB) but it *is* the dominant footprint, exactly as the hex strings
    dominate the RTL design's area.
    """
    m, k = x_q.shape
    k2, n = w_q.shape
    assert k == k2
    assert m % bm == 0 and n % bn == 0 and k % bk == 0

    grid = (m // bm, n // bn, k // bk)
    return pl.pallas_call(
        _lut_matmul_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.int32),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.int32)],
        compiler_params=pltpu.TPUCompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(x_q, w_q)
