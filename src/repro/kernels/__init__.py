"""Pallas TPU kernels for the paper's multiplier design points.

Layout:

* ``nibble_matmul``     — single-pass plane-fused nibble matmul (the
                          tentpole kernel: plane-concatenated dot, VMEM
                          scratch accumulation, fused dequant epilogue)
* ``lut_matmul``        — LUT/selection design point (one-hot matmul)
* ``quant_matmul_fused``— bf16→bf16 hot path, shim over the nibble path
* ``flash_attention``   — flash MHA fwd/bwd
* ``ops``               — public entry points; ``ops.quant_matmul`` is
                          the single dispatch path for every quantized
                          matmul (padding, format, epilogue, backend)
* ``ref``               — pure-jnp oracles the tests assert against
"""
