"""Pallas TPU kernels: flash attention, forward + backward (custom VJP).

Beyond-paper optimization (EXPERIMENTS.md §Perf): the chunked-jnp
attention path materializes (B,H,qc,Sk) f32 logits in HBM on every
forward/recompute/backward pass — measured at ~68 TB/device of HLO byte
traffic on deepseek-v3 train_4k (B·H·S²·4 B ≈ 137 GB per pass per layer
× 58 layers × ~4 passes).  Flash tiling keeps the running max /
denominator / accumulator in VMEM scratch and streams K/V blocks, so the
probs never touch HBM; the backward recomputes p per tile from the saved
log-sum-exp.

Layout: grid (BH, ·, ·) with the reduction axis innermost; blocks are
MXU-aligned.  GQA: K/V carry (B·KVH) rows and the BlockSpec index map
pulls block ``bh // group`` — queries of a group share the K/V tile with
no materialized repeat.  Causal masking by absolute positions; optional
sliding window and logit softcap (gemma-style) are folded into the mask.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["flash_attention_fwd_pallas", "flash_attention_bwd_pallas",
           "paged_decode_attention_pallas"]

_NEG_INF = -1e30


def _mask(s, qi, ki, bq, bk, causal, window):
    q_pos = qi * bq + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
    k_pos = ki * bk + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    m = jnp.ones(s.shape, jnp.bool_)
    if causal:
        m = m & (k_pos <= q_pos)
    if window:
        m = m & (q_pos - k_pos < window)
    return jnp.where(m, s, _NEG_INF)


# ---------------------------------------------------------------------------
# Forward
# ---------------------------------------------------------------------------

def _fwd_kernel(q_ref, k_ref, v_ref, o_ref, lse_ref, m_scr, l_scr, acc_scr,
                *, scale, causal, window, softcap, bq, bk):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, _NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0]
    k = k_ref[0]
    v = v_ref[0]
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    if softcap:
        s = jnp.tanh(s / softcap) * softcap
    s = _mask(s, qi, ki, bq, bk, causal, window)

    m_prev, l_prev = m_scr[...], l_scr[...]
    m_new = jnp.maximum(m_prev, s.max(axis=-1, keepdims=True))
    p = jnp.exp(s - m_new)
    corr = jnp.exp(m_prev - m_new)
    l_new = l_prev * corr + p.sum(axis=-1, keepdims=True)
    acc_scr[...] = acc_scr[...] * corr + jax.lax.dot_general(
        p.astype(v.dtype), v, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    m_scr[...] = m_new
    l_scr[...] = l_new

    @pl.when(ki == pl.num_programs(2) - 1)
    def _finish():
        l_safe = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0] = (acc_scr[...] / l_safe).astype(o_ref.dtype)
        lse_ref[0] = (m_scr[...] + jnp.log(l_safe))[:, 0]


@functools.partial(jax.jit, static_argnames=("scale", "causal", "window",
                                             "softcap", "group", "bq", "bk",
                                             "interpret"))
def flash_attention_fwd_pallas(q, k, v, *, scale, causal=True, window=0,
                               softcap=0.0, group=1, bq=128, bk=128,
                               interpret=True):
    """q: (BH, Sq, d); k/v: (BKV, Sk, d/dv), BH = BKV·group.
    Returns (o (BH,Sq,dv), lse (BH,Sq) f32)."""
    bh, sq, d = q.shape
    bkv, sk, dv = v.shape
    assert bh == bkv * group
    assert sq % bq == 0 and sk % bk == 0

    grid = (bh, sq // bq, sk // bk)
    kernel = functools.partial(_fwd_kernel, scale=scale, causal=causal,
                               window=window, softcap=softcap, bq=bq, bk=bk)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bq, d), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, bk, d), lambda b, i, j, g=group: (b // g, j, 0)),
            pl.BlockSpec((1, bk, dv), lambda b, i, j, g=group: (b // g, j, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, bq, dv), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, bq), lambda b, i, j: (b, i)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bh, sq, dv), q.dtype),
            jax.ShapeDtypeStruct((bh, sq), jnp.float32),
        ],
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, dv), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)


# ---------------------------------------------------------------------------
# Paged decode: one query per slot against a page-table-indexed KV pool
# ---------------------------------------------------------------------------

def _paged_decode_kernel(table_ref, pos_ref, q_ref, k_ref, v_ref, o_ref,
                         m_scr, l_scr, acc_scr, *, scale, window, softcap,
                         page_size):
    """Grid (B, KVH, max_pages), pages innermost.  The page id never
    enters the kernel body: the K/V BlockSpec index maps read the
    scalar-prefetched table (``table[b, j]``) to aim each block's DMA,
    so the pool gather costs no HBM copy — the paper's
    composition-through-indexing move on the decode data path."""
    b = pl.program_id(0)
    j = pl.program_id(2)

    @pl.when(j == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, _NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0, 0]                       # (G, d)
    k = k_ref[0, :, 0, :]                 # (page_size, d)
    v = v_ref[0, :, 0, :]                 # (page_size, dv)
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    if softcap:
        s = jnp.tanh(s / softcap) * softcap
    q_pos = pos_ref[b]
    k_pos = j * page_size + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    m = k_pos <= q_pos                    # causal vs the one live query
    if window:
        m = m & (q_pos - k_pos < window)
    s = jnp.where(m, s, _NEG_INF)

    m_prev, l_prev = m_scr[...], l_scr[...]
    m_new = jnp.maximum(m_prev, s.max(axis=-1, keepdims=True))
    p = jnp.exp(s - m_new)
    corr = jnp.exp(m_prev - m_new)
    l_new = l_prev * corr + p.sum(axis=-1, keepdims=True)
    acc_scr[...] = acc_scr[...] * corr + jax.lax.dot_general(
        p.astype(v.dtype), v, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    m_scr[...] = m_new
    l_scr[...] = l_new

    @pl.when(j == pl.num_programs(2) - 1)
    def _finish():
        o_ref[0, 0] = (acc_scr[...] / jnp.maximum(l_scr[...], 1e-30)) \
            .astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("scale", "window", "softcap",
                                             "interpret"))
def paged_decode_attention_pallas(q, k_pool, v_pool, table, q_pos, *,
                                  scale, window=0, softcap=0.0,
                                  interpret=True):
    """Single-token decode attention over a paged KV pool.

    ``q``: (B, KVH, G, d) — grouped queries, one token per slot;
    ``k_pool``/``v_pool``: (num_pages, page_size, KVH, d/dv);
    ``table``: (B, max_pages) int32 page table; ``q_pos``: (B,) int32
    per-slot query positions.  Returns (B, KVH, G, dv).

    Pages past a slot's live length resolve to the trash page; their
    rows are garbage but the position mask writes ``-inf`` before the
    softmax, so they contribute exp(-inf)=0.  Production TPU lowering
    wants d/dv lane-aligned (the ops wrapper pads) and a page_size that
    is a multiple of the sublane tile; interpret mode takes any shape.
    """
    b, kvh, g, d = q.shape
    num_pages, page_size, _, dv = v_pool.shape
    max_pages = table.shape[1]

    grid = (b, kvh, max_pages)
    kernel = functools.partial(_paged_decode_kernel, scale=scale,
                               window=window, softcap=softcap,
                               page_size=page_size)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,            # table, q_pos
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, g, d), lambda b, h, j, tbl, pos: (b, h, 0, 0)),
            pl.BlockSpec((1, page_size, 1, d),
                         lambda b, h, j, tbl, pos: (tbl[b, j], 0, h, 0)),
            pl.BlockSpec((1, page_size, 1, dv),
                         lambda b, h, j, tbl, pos: (tbl[b, j], 0, h, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, g, dv),
                               lambda b, h, j, tbl, pos: (b, h, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((g, 1), jnp.float32),
            pltpu.VMEM((g, 1), jnp.float32),
            pltpu.VMEM((g, dv), jnp.float32),
        ],
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, kvh, g, dv), q.dtype),
        interpret=interpret,
    )(table.astype(jnp.int32), q_pos.astype(jnp.int32), q, k_pool, v_pool)


# ---------------------------------------------------------------------------
# Backward: dq kernel (K innermost) and dk/dv kernel (Q innermost)
# ---------------------------------------------------------------------------

def _recompute_p(q, k, lse, qi, ki, *, scale, causal, window, softcap,
                 bq, bk):
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    s_raw = s
    if softcap:
        s = jnp.tanh(s / softcap) * softcap
    s = _mask(s, qi, ki, bq, bk, causal, window)
    p = jnp.exp(s - lse[:, None])
    return p, s_raw


def _softcap_jac(s_raw, softcap):
    """d tanh-softcap / d s_raw = sech² (s/c)."""
    if not softcap:
        return 1.0
    t = jnp.tanh(s_raw / softcap)
    return 1.0 - t * t


def _dq_kernel(q_ref, k_ref, v_ref, do_ref, lse_ref, dmat_ref, dq_ref,
               dq_scr, *, scale, causal, window, softcap, bq, bk):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        dq_scr[...] = jnp.zeros_like(dq_scr)

    q, k, v = q_ref[0], k_ref[0], v_ref[0]
    do = do_ref[0].astype(jnp.float32)
    lse = lse_ref[0]
    dmat = dmat_ref[0]

    p, s_raw = _recompute_p(q, k, lse, qi, ki, scale=scale, causal=causal,
                            window=window, softcap=softcap, bq=bq, bk=bk)
    dp = jax.lax.dot_general(do.astype(v.dtype), v, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)
    ds = p * (dp - dmat[:, None]) * _softcap_jac(s_raw, softcap) * scale
    dq_scr[...] += jax.lax.dot_general(
        ds.astype(k.dtype), k, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)

    @pl.when(ki == pl.num_programs(2) - 1)
    def _finish():
        dq_ref[0] = dq_scr[...].astype(dq_ref.dtype)


def _dkv_kernel(q_ref, k_ref, v_ref, do_ref, lse_ref, dmat_ref,
                dk_ref, dv_ref, dk_scr, dv_scr,
                *, scale, causal, window, softcap, bq, bk):
    ki = pl.program_id(1)
    qi = pl.program_id(2)

    @pl.when(qi == 0)
    def _init():
        dk_scr[...] = jnp.zeros_like(dk_scr)
        dv_scr[...] = jnp.zeros_like(dv_scr)

    q, k, v = q_ref[0], k_ref[0], v_ref[0]
    do = do_ref[0].astype(jnp.float32)
    lse = lse_ref[0]
    dmat = dmat_ref[0]

    p, s_raw = _recompute_p(q, k, lse, qi, ki, scale=scale, causal=causal,
                            window=window, softcap=softcap, bq=bq, bk=bk)
    dv_scr[...] += jax.lax.dot_general(
        p.astype(do.dtype), do, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    dp = jax.lax.dot_general(do.astype(v.dtype), v, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)
    ds = p * (dp - dmat[:, None]) * _softcap_jac(s_raw, softcap) * scale
    dk_scr[...] += jax.lax.dot_general(
        ds.astype(q.dtype), q, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)

    @pl.when(qi == pl.num_programs(2) - 1)
    def _finish():
        dk_ref[0] = dk_scr[...].astype(dk_ref.dtype)
        dv_ref[0] = dv_scr[...].astype(dv_ref.dtype)


@functools.partial(jax.jit, static_argnames=("scale", "causal", "window",
                                             "softcap", "group", "bq", "bk",
                                             "interpret"))
def flash_attention_bwd_pallas(q, k, v, o, lse, do, *, scale, causal=True,
                               window=0, softcap=0.0, group=1,
                               bq=128, bk=128, interpret=True):
    """Returns (dq (BH,Sq,d), dk_h (BH,Sk,d), dv_h (BH,Sk,dv)).

    dk/dv come back *per q-head*; the wrapper sums groups back onto the
    KV heads (exact — dk_kv = Σ_g dk_head)."""
    bh, sq, d = q.shape
    bkv, sk, dv = v.shape
    dmat = jnp.sum(do.astype(jnp.float32) * o.astype(jnp.float32), axis=-1)

    common = dict(scale=scale, causal=causal, window=window,
                  softcap=softcap, bq=bq, bk=bk)
    kv_idx = (lambda b, i, j, g=group: (b // g, j, 0))
    kv_idx_swapped = (lambda b, j, i, g=group: (b // g, j, 0))

    dq = pl.pallas_call(
        functools.partial(_dq_kernel, **common),
        grid=(bh, sq // bq, sk // bk),
        in_specs=[
            pl.BlockSpec((1, bq, d), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, bk, d), kv_idx),
            pl.BlockSpec((1, bk, dv), kv_idx),
            pl.BlockSpec((1, bq, dv), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, bq), lambda b, i, j: (b, i)),
            pl.BlockSpec((1, bq), lambda b, i, j: (b, i)),
        ],
        out_specs=pl.BlockSpec((1, bq, d), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, sq, d), jnp.float32),
        scratch_shapes=[pltpu.VMEM((bq, d), jnp.float32)],
        interpret=interpret,
    )(q, k, v, do, lse, dmat)

    dk, dv_out = pl.pallas_call(
        functools.partial(_dkv_kernel, **common),
        grid=(bh, sk // bk, sq // bq),
        in_specs=[
            pl.BlockSpec((1, bq, d), lambda b, j, i: (b, i, 0)),
            pl.BlockSpec((1, bk, d), kv_idx_swapped),
            pl.BlockSpec((1, bk, dv), kv_idx_swapped),
            pl.BlockSpec((1, bq, dv), lambda b, j, i: (b, i, 0)),
            pl.BlockSpec((1, bq), lambda b, j, i: (b, i)),
            pl.BlockSpec((1, bq), lambda b, j, i: (b, i)),
        ],
        out_specs=[
            pl.BlockSpec((1, bk, d), lambda b, j, i: (b, j, 0)),
            pl.BlockSpec((1, bk, dv), lambda b, j, i: (b, j, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bh, sk, d), jnp.float32),
            jax.ShapeDtypeStruct((bh, sk, dv), jnp.float32),
        ],
        scratch_shapes=[
            pltpu.VMEM((bk, d), jnp.float32),
            pltpu.VMEM((bk, dv), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v, do, lse, dmat)

    return dq, dk, dv_out
