"""Pallas TPU kernel: single-pass plane-fused nibble-decomposed matmul.

The paper's Algorithm 2, lifted from a scalar vector lane to an MXU tile
and then fused so each K grid step costs exactly one MXU pass and the
output block touches HBM exactly once.

Kernel dataflow
===============

**Plane-concatenated single dot.**  The int8 activation tile is split
into the paper's fixed 4-bit decomposition — a low-nibble plane
(unsigned, ``[0,16)``) and a high-nibble plane (signed, ``[-8,8)``).
Instead of issuing one ``dot_general`` per plane and aligning the high
pass with ``<< 4`` afterwards (two MXU passes per K step), the fixed
alignment is folded into the *operand layout*: the high plane is
pre-shifted at the operand edge (``hi << 4 == x - lo``, which stays
int8-safe because ``hi`` is in ``[-8,8)``), and the two planes are
concatenated along the contraction dimension into one ``(bm, 2·bk)``
int8 tile.  The matching ``(2·bk, bn)`` weight tile is the shared weight
block stacked twice — the paper's broadcast-operand reuse made literal:
the same VMEM-resident weight tile serves both nibble planes inside a
single MXU pass.

    [ lo | hi<<4 ] @ [ W ]   ==  lo·W + (hi·W) << 4  ==  x·W
                     [ W ]

This preserves the paper's two-cycle semantics — both nibble planes are
still evaluated as structurally separate halves of the contraction, the
precompute (split + fixed shift) happens once per operand at the edge
rather than per partial product (cf. the sign-magnitude-encoder
argument in PAPERS.md), and the weight operand is loaded once and reused
by both planes — while issuing **one** MXU pass per K step instead of
two.

**VMEM scratch accumulation.**  The K loop accumulates into a
``pltpu.VMEM``-allocated int32 scratch block that lives across the K
grid steps (K is the innermost, "arbitrary"-semantics dimension; M and N
are "parallel" so Mosaic can pipeline).  The HBM output block is written
exactly once, at the last K step — replacing the seed kernel's
``o_ref[...] +=`` read-modify-write of the int32 block on every K step.

**Fused dequantization epilogue.**  When scales are supplied, the
last-K-step flush applies the per-row activation scale ``(bm, 1)`` and
per-channel weight scale ``(1, bn)`` to the int32 accumulator and emits
``out_dtype`` (bf16 by default) directly — the int32 accumulator never
materializes in HBM and output traffic is halved.

The packed-int4 weight variant unpacks two nibbles per byte in-kernel
(shift, mask, sign-extend — the paper's shift-based precompute, no
multiplier), halving HBM→VMEM weight traffic, then runs the identical
plane-concatenated dot.

Tiling: grid ``(M/bm, N/bn, K/bk)``.  Block defaults are MXU-aligned
(multiples of 128 in every matmul dimension; int8 native lane tiling is
(32, 128), which 128-multiples satisfy).  The concatenated contraction
width ``2·bk`` remains a multiple of 128.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = [
    "fused_nibble_matmul_pallas",
    "nibble_matmul_pallas",
    "nibble_matmul_w4_pallas",
]

_DIM_SEMANTICS = ("parallel", "parallel", "arbitrary")


def _split_planes(x_i32):
    """(lo, hi) planes of an int8 tile held in int32: x == hi*16 + lo."""
    lo = x_i32 & 0xF
    hi = (x_i32 - lo) >> 4  # arithmetic shift — hi is signed
    return lo, hi


def _plane_concat(x_i32):
    """Concatenate the nibble planes along K with the alignment folded in.

    Returns the ``(bm, 2·bk)`` int8 tile ``[lo | hi<<4]``.  The fixed
    ``<< 4`` lives in the operand: ``hi << 4 == x - lo`` is in
    ``[-128, 112]`` so the pre-shifted plane is int8-exact.
    """
    lo = x_i32 & 0xF
    hi_shifted = x_i32 - lo            # == hi << 4, int8-safe
    return jnp.concatenate([lo, hi_shifted], axis=-1).astype(jnp.int8)


def _unpack_w4(wp_ref):
    """Unpack a (bk, bn//2) packed-int4 tile to (bk, bn) int8 in-kernel.

    Exactly the paper's shift-based precompute: shift, mask, sign-extend
    — no multiplier.  Even output columns take the low nibble, odd the
    high nibble.
    """
    wp = wp_ref[...].astype(jnp.int32) & 0xFF
    w_lo = wp & 0xF
    w_lo = w_lo - ((w_lo >> 3) << 4)
    w_hi = (wp >> 4) & 0xF
    w_hi = w_hi - ((w_hi >> 3) << 4)
    bk_, half = wp.shape
    return jnp.stack([w_lo, w_hi], axis=-1).reshape(bk_, 2 * half) \
        .astype(jnp.int8)


def _single_pass_dot(x_i32, w_i8):
    """One MXU pass over the concatenated planes: exact int32 x·W."""
    xcat = _plane_concat(x_i32)                        # (bm, 2·bk)
    wcat = jnp.concatenate([w_i8, w_i8], axis=0)       # (2·bk, bn), shared tile
    return jax.lax.dot_general(
        xcat, wcat,
        (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.int32)


def _fused_kernel(x_ref, w_ref, o_ref, acc_ref, *, w_packed: bool):
    """int32 output path: scratch-accumulated, flushed at the last K step."""
    k_step = pl.program_id(2)

    @pl.when(k_step == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    w = _unpack_w4(w_ref) if w_packed else w_ref[...]
    acc_ref[...] += _single_pass_dot(x_ref[...].astype(jnp.int32), w)

    @pl.when(k_step == pl.num_programs(2) - 1)
    def _flush():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


def _fused_scaled_kernel(x_ref, xs_ref, w_ref, ws_ref, o_ref, acc_ref, *,
                         w_packed: bool):
    """Scaled output path: dequant epilogue fused into the final flush."""
    k_step = pl.program_id(2)

    @pl.when(k_step == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    w = _unpack_w4(w_ref) if w_packed else w_ref[...]
    acc_ref[...] += _single_pass_dot(x_ref[...].astype(jnp.int32), w)

    @pl.when(k_step == pl.num_programs(2) - 1)
    def _flush():
        x_scale = xs_ref[...].astype(jnp.float32)      # (bm, 1)
        w_scale = ws_ref[...].astype(jnp.float32)      # (1, bn)
        o_ref[...] = (acc_ref[...].astype(jnp.float32)
                      * x_scale * w_scale).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("w_packed", "bm", "bn", "bk",
                                             "out_dtype", "interpret"))
def fused_nibble_matmul_pallas(x_q: jax.Array, w: jax.Array,
                               x_scale: jax.Array | None = None,
                               w_scale: jax.Array | None = None, *,
                               w_packed: bool = False,
                               bm: int = 128, bn: int = 128, bk: int = 128,
                               out_dtype=None,
                               interpret: bool = True) -> jax.Array:
    """The single fused entry point behind every nibble design.

    ``x_q``: int8 (M, K).  ``w``: int8 (K, N), or packed int4 (K, N//2)
    when ``w_packed``.  Unscaled → exact int32 (M, N).  With both
    ``x_scale`` (M, 1) and ``w_scale`` (1, N) f32 → the dequant epilogue
    runs in-kernel and emits ``out_dtype`` (default bf16) without an
    int32 HBM round-trip.

    Dimensions must be multiples of the block sizes (``ops.quant_matmul``
    handles padding).  ``interpret=True`` runs the kernel body on CPU for
    validation; pass ``False`` on a real TPU.
    """
    m, k = x_q.shape
    k2, n_stored = w.shape
    n = 2 * n_stored if w_packed else n_stored
    assert k == k2, (x_q.shape, w.shape)
    assert m % bm == 0 and n % bn == 0 and k % bk == 0, \
        ((m, n, k), (bm, bn, bk))
    scaled = x_scale is not None or w_scale is not None
    if scaled:
        assert x_scale is not None and w_scale is not None, \
            "pass both scales (use ones for the identity scale)"
        out_dtype = jnp.bfloat16 if out_dtype is None else out_dtype
    else:
        out_dtype = jnp.int32 if out_dtype is None else out_dtype

    grid = (m // bm, n // bn, k // bk)
    w_spec = pl.BlockSpec((bk, bn // 2 if w_packed else bn),
                          lambda i, j, kk: (kk, j))
    x_spec = pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk))
    common = dict(
        grid=grid,
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), out_dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.int32)],
        compiler_params=pltpu.TPUCompilerParams(
            dimension_semantics=_DIM_SEMANTICS),
        interpret=interpret,
    )

    if not scaled:
        kernel = functools.partial(_fused_kernel, w_packed=w_packed)
        return pl.pallas_call(
            kernel,
            in_specs=[x_spec, w_spec],
            **common,
        )(x_q, w)

    kernel = functools.partial(_fused_scaled_kernel, w_packed=w_packed)
    return pl.pallas_call(
        kernel,
        in_specs=[
            x_spec,
            pl.BlockSpec((bm, 1), lambda i, j, kk: (i, 0)),
            w_spec,
            pl.BlockSpec((1, bn), lambda i, j, kk: (0, j)),
        ],
        **common,
    )(x_q, x_scale.reshape(m, 1).astype(jnp.float32), w,
      w_scale.reshape(1, n).astype(jnp.float32))


# ---------------------------------------------------------------------------
# Back-compat shims — the seed entry points, now thin wrappers
# ---------------------------------------------------------------------------

def nibble_matmul_pallas(x_q: jax.Array, w_q: jax.Array, *,
                         bm: int = 128, bn: int = 128, bk: int = 128,
                         unroll_passes: bool = True,
                         interpret: bool = True) -> jax.Array:
    """int8 (M,K) × int8 (K,N) → int32 (M,N), exact.

    ``unroll_passes`` is retained for API compatibility; both of the
    seed's execution profiles now lower to the same plane-concatenated
    single-pass kernel (the "sequential vs unrolled" distinction moved
    from two dot issues to two halves of one contraction).
    """
    del unroll_passes
    return fused_nibble_matmul_pallas(x_q, w_q, bm=bm, bn=bn, bk=bk,
                                      interpret=interpret)


def nibble_matmul_w4_pallas(x_q: jax.Array, w_packed: jax.Array, *,
                            bm: int = 128, bn: int = 128, bk: int = 128,
                            interpret: bool = True) -> jax.Array:
    """int8 (M,K) × packed-int4 (K, N//2) → int32 (M,N), exact."""
    return fused_nibble_matmul_pallas(x_q, w_packed, w_packed=True,
                                      bm=bm, bn=bn, bk=bk,
                                      interpret=interpret)
