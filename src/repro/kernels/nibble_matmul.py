"""Pallas TPU kernel: two-pass nibble-decomposed quantized matmul.

The paper's Algorithm 2, lifted from a scalar vector lane to an MXU tile:

* the int8 activation tile is split into a low-nibble plane (unsigned,
  ``[0,16)``) and a high-nibble plane (signed, ``[-8,8)``) — the paper's
  fixed 4-bit decomposition;
* each plane takes one pass through the MXU against the shared weight
  tile — the two "deterministic cycles";
* the high pass is aligned with a fixed ``<< 4`` and accumulated —
  Fig. 2(c)'s shift logic + adder.

The broadcast-operand reuse becomes VMEM reuse: the weight tile is the
operand shared by every row of the activation block, loaded once per
(n, k) grid step and consumed by both nibble passes.

Tiling: grid ``(M/bm, N/bn, K/bk)`` with K innermost ("arbitrary"
semantics); the int32 output block is revisited across K steps and
accumulated in place.  Block defaults are MXU-aligned (multiples of 128
in every matmul dimension; int8 native lane tiling is (32, 128), which
128-multiples satisfy).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["nibble_matmul_pallas", "nibble_matmul_w4_pallas"]


def _split_planes(x_i32):
    """(lo, hi) planes of an int8 tile held in int32: x == hi*16 + lo."""
    lo = x_i32 & 0xF
    hi = (x_i32 - lo) >> 4  # arithmetic shift — hi is signed
    return lo, hi


def _nibble_matmul_kernel(x_ref, w_ref, o_ref, *, unroll_passes: bool):
    """One (bm, bn) output tile, one (bk) K-slab.

    ``unroll_passes=True`` is the paper's *unrolled* mode: both nibble
    planes evaluated in the same kernel invocation (single "cycle",
    duplicated precompute logic).  ``False`` mirrors the sequential mode
    dataflow — still one invocation, but structured as two dependent
    accumulations (the compiler may not exploit pass-level parallelism).
    Both are bit-exact; the switch exists to mirror the paper's two
    execution profiles and for perf experiments on real hardware.
    """
    k_step = pl.program_id(2)

    @pl.when(k_step == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    x = x_ref[...].astype(jnp.int32)
    w = w_ref[...]
    lo, hi = _split_planes(x)

    def mxu_pass(plane):
        return jax.lax.dot_general(
            plane.astype(jnp.int8), w,
            (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.int32)

    if unroll_passes:
        acc = mxu_pass(lo) + (mxu_pass(hi) << 4)
        o_ref[...] += acc
    else:
        o_ref[...] += mxu_pass(lo)              # cycle 0: low plane
        o_ref[...] += mxu_pass(hi) << 4         # cycle 1: high plane, shifted


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk", "unroll_passes",
                                             "interpret"))
def nibble_matmul_pallas(x_q: jax.Array, w_q: jax.Array, *,
                         bm: int = 128, bn: int = 128, bk: int = 128,
                         unroll_passes: bool = True,
                         interpret: bool = True) -> jax.Array:
    """int8 (M,K) × int8 (K,N) → int32 (M,N), exact.

    Dimensions must be multiples of the block sizes (``ops.nibble_matmul``
    handles padding).  ``interpret=True`` runs the kernel body on CPU for
    validation; pass ``False`` on a real TPU.
    """
    m, k = x_q.shape
    k2, n = w_q.shape
    assert k == k2, (x_q.shape, w_q.shape)
    assert m % bm == 0 and n % bn == 0 and k % bk == 0

    grid = (m // bm, n // bn, k // bk)
    kernel = functools.partial(_nibble_matmul_kernel,
                               unroll_passes=unroll_passes)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.int32),
        interpret=interpret,
    )(x_q, w_q)


# ---------------------------------------------------------------------------
# W4A8: packed int4 weights, unpacked in-kernel by the precompute logic
# ---------------------------------------------------------------------------

def _nibble_matmul_w4_kernel(x_ref, wp_ref, o_ref):
    """Weights arrive as two int4 nibbles per byte along N; the in-kernel
    unpack is exactly the paper's shift-based precompute: shift, mask,
    sign-extend — no multiplier.  Halves the HBM→VMEM weight traffic,
    which is the memory-roofline payoff of nibble storage."""
    k_step = pl.program_id(2)

    @pl.when(k_step == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    x = x_ref[...].astype(jnp.int32)
    wp = wp_ref[...].astype(jnp.int32) & 0xFF          # (bk, bn//2)

    # unpack both nibble planes (two's-complement sign extension)
    w_lo = wp & 0xF
    w_lo = w_lo - ((w_lo >> 3) << 4)
    w_hi = (wp >> 4) & 0xF
    w_hi = w_hi - ((w_hi >> 3) << 4)
    # interleave back to (bk, bn): even cols = lo, odd cols = hi
    bk_, half = wp.shape
    w = jnp.stack([w_lo, w_hi], axis=-1).reshape(bk_, 2 * half)

    lo, hi = _split_planes(x)

    def mxu_pass(plane):
        return jax.lax.dot_general(
            plane.astype(jnp.int8), w.astype(jnp.int8),
            (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.int32)

    o_ref[...] += mxu_pass(lo) + (mxu_pass(hi) << 4)


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk", "interpret"))
def nibble_matmul_w4_pallas(x_q: jax.Array, w_packed: jax.Array, *,
                            bm: int = 128, bn: int = 128, bk: int = 128,
                            interpret: bool = True) -> jax.Array:
    """int8 (M,K) × packed-int4 (K, N//2) → int32 (M,N), exact."""
    m, k = x_q.shape
    k2, n_half = w_packed.shape
    n = 2 * n_half
    assert k == k2
    assert m % bm == 0 and n % bn == 0 and k % bk == 0

    grid = (m // bm, n // bn, k // bk)
    return pl.pallas_call(
        _nibble_matmul_w4_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn // 2), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.int32),
        interpret=interpret,
    )(x_q, w_packed)
