"""Pure-jnp oracles for every Pallas kernel in this package.

Each ``*_ref`` function defines the *semantics* its kernel must match
bit-exactly (integer kernels) or to float tolerance (dequant kernels).
Tests sweep shapes/dtypes and assert against these.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.nibble import split_nibbles_signed, unpack_int4

__all__ = [
    "nibble_matmul_ref",
    "nibble_matmul_w4_ref",
    "lut_matmul_ref",
    "quant_dequant_matmul_ref",
]


def _int_dot(a, b):
    return jax.lax.dot_general(a, b, (((a.ndim - 1,), (0,)), ((), ())),
                               preferred_element_type=jnp.int32)


def nibble_matmul_ref(x_q: jax.Array, w_q: jax.Array) -> jax.Array:
    """int8 (M,K) × int8 (K,N) → int32 (M,N), exact."""
    return _int_dot(x_q.astype(jnp.int32), w_q.astype(jnp.int32))


def nibble_matmul_w4_ref(x_q: jax.Array, w_packed: jax.Array) -> jax.Array:
    """int8 (M,K) × packed-int4 (K, N//2) → int32 (M,N), exact.

    The packed weight holds two int4 values per byte along the output
    dimension; the oracle unpacks and does the exact integer dot.
    """
    w = unpack_int4(w_packed)  # (K, N) int8 in [-8, 8)
    return _int_dot(x_q.astype(jnp.int32), w.astype(jnp.int32))


def lut_matmul_ref(x_q: jax.Array, w_q: jax.Array) -> jax.Array:
    """Same product as nibble_matmul_ref — the LUT path changes the
    dataflow (precompute k·W table + select), not the mathematics."""
    return nibble_matmul_ref(x_q, w_q)


def quant_dequant_matmul_ref(x: jax.Array, w_q: jax.Array,
                             w_scale: jax.Array) -> jax.Array:
    """Fused quantize→nibble-matmul→dequant oracle.

    ``x``: float (M,K); quantized per-row symmetric int8 inside.
    ``w_q``: int8 (K,N); ``w_scale``: (1,N) or () f32.
    Returns float32 (M,N).
    """
    amax = jnp.maximum(jnp.max(jnp.abs(x), axis=-1, keepdims=True), 1e-8)
    x_scale = amax / 127.0
    x_q = jnp.clip(jnp.round(x / x_scale), -128, 127).astype(jnp.int8)
    acc = nibble_matmul_ref(x_q, w_q)
    return acc.astype(jnp.float32) * x_scale * w_scale


def nibble_planes_ref(x_q: jax.Array) -> tuple[jax.Array, jax.Array]:
    """The (lo, hi) int8 planes the kernels split activations into."""
    lo, hi = split_nibbles_signed(x_q)
    return lo.astype(jnp.int8), hi.astype(jnp.int8)
