"""Public jit'd entry points for the kernels (padding, backend dispatch).

``interpret`` defaults to auto: interpret-mode on CPU (validation), real
Mosaic lowering on TPU.  All wrappers accept arbitrary (unaligned)
shapes and pad to the block grid internally; results are exact.

Every quantized-matmul execution path now routes through one dispatcher,
:func:`quant_matmul`: weight format (int8 dense / packed int4 / LUT
selection) and the optional fused dequantization epilogue are arguments,
not separate entry points.  The seed entry points (``nibble_matmul``,
``nibble_matmul_w4``, ``lut_matmul``, ``quant_matmul_fused``) remain as
thin shims over it.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.lut_matmul import lut_matmul_pallas
from repro.kernels.nibble_matmul import fused_nibble_matmul_pallas
from repro.kernels.quant_matmul_fused import quantize_rows

__all__ = ["quant_matmul", "nibble_matmul", "nibble_matmul_w4", "lut_matmul",
           "quant_matmul_fused", "flash_mha", "paged_flash_decode"]

W_FORMATS = ("int8", "int4_packed", "lut")


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _pad_to(x, m0, m1):
    p0 = (-x.shape[0]) % m0
    p1 = (-x.shape[1]) % m1
    if p0 or p1:
        x = jnp.pad(x, ((0, p0), (0, p1)))
    return x


def _flatten_leading(x):
    """Collapse leading dims to a matrix; return (mat, unflatten)."""
    lead = x.shape[:-1]
    mat = x.reshape(-1, x.shape[-1])

    def unflatten(y):
        return y.reshape(*lead, y.shape[-1])

    return mat, unflatten


def _row_scale(s, m):
    """Normalize a scalar / (M,) / (M,1) scale to f32 (M, 1)."""
    s = jnp.asarray(s, jnp.float32).reshape(-1)[:, None]
    return jnp.broadcast_to(s, (m, 1))


def _col_scale(s, n):
    """Normalize a scalar / (N,) / (1,N) scale to f32 (1, N)."""
    s = jnp.asarray(s, jnp.float32).reshape(-1)[None, :]
    return jnp.broadcast_to(s, (1, n))


def quant_matmul(x_q: jax.Array, w: jax.Array, *,
                 x_scale: jax.Array | None = None,
                 w_scale: jax.Array | None = None,
                 w_format: str = "int8",
                 bm: int = 128, bn: int = 128, bk: int = 128,
                 out_dtype=None,
                 interpret: bool | None = None) -> jax.Array:
    """The single dispatch path for every quantized matmul.

    ``x_q``: int8 (..., K).  ``w``: int8 (K, N) for ``w_format`` "int8"
    or "lut"; packed int4 (K, N//2) for "int4_packed".

    Unscaled → exact int32 (..., N).  With scales (``x_scale``
    broadcastable to (M, 1), ``w_scale`` to (1, N)) the dequantization
    runs as the kernel's final-K-step epilogue and the result is
    ``out_dtype`` (bf16 by default) — the int32 accumulator never leaves
    VMEM.  The "lut" format is int32-only (its selection kernel models
    the paper's LUT array); scales there are applied as an XLA epilog.
    """
    if w_format not in W_FORMATS:
        raise ValueError(f"w_format must be one of {W_FORMATS}: {w_format}")
    if interpret is None:
        interpret = not _on_tpu()
    mat, unflatten = _flatten_leading(x_q)
    m, k = mat.shape
    n = 2 * w.shape[1] if w_format == "int4_packed" else w.shape[1]
    scaled = x_scale is not None or w_scale is not None

    xp = _pad_to(mat, bm, bk)
    wp = _pad_to(w, bk, bn // 2 if w_format == "int4_packed" else bn)

    if w_format == "lut":
        out = lut_matmul_pallas(xp, wp, bm=bm, bn=bn, bk=bk,
                                interpret=interpret)
        out = out[:m, :n]
        if scaled:
            out = out.astype(jnp.float32)
            if x_scale is not None:
                out = out * _row_scale(x_scale, m)
            if w_scale is not None:
                out = out * _col_scale(w_scale, n)
            out = out.astype(jnp.bfloat16 if out_dtype is None else out_dtype)
        elif out_dtype is not None:
            out = out.astype(out_dtype)
        return unflatten(out)

    if scaled:
        xs = jnp.ones((m, 1), jnp.float32) if x_scale is None \
            else _row_scale(x_scale, m)
        ws = jnp.ones((1, n), jnp.float32) if w_scale is None \
            else _col_scale(w_scale, n)
        xsp = _pad_to(xs, bm, 1)
        wsp = _pad_to(ws, 1, bn)
    else:
        xsp = wsp = None

    out = fused_nibble_matmul_pallas(
        xp, wp, xsp, wsp,
        w_packed=(w_format == "int4_packed"),
        bm=bm, bn=bn, bk=bk, out_dtype=out_dtype, interpret=interpret)
    return unflatten(out[:m, :n])


# ---------------------------------------------------------------------------
# Seed entry points — thin shims over quant_matmul
# ---------------------------------------------------------------------------

def nibble_matmul(x_q: jax.Array, w_q: jax.Array, *,
                  bm: int = 128, bn: int = 128, bk: int = 128,
                  unroll_passes: bool = True,
                  interpret: bool | None = None) -> jax.Array:
    """int8 (..., K) × int8 (K, N) → int32 (..., N) — the paper's kernel.

    ``unroll_passes`` is retained for API compatibility; both profiles
    lower to the plane-concatenated single-pass kernel.
    """
    del unroll_passes
    return quant_matmul(x_q, w_q, w_format="int8", bm=bm, bn=bn, bk=bk,
                        interpret=interpret)


def nibble_matmul_w4(x_q: jax.Array, w_packed: jax.Array, *,
                     bm: int = 128, bn: int = 128, bk: int = 128,
                     interpret: bool | None = None) -> jax.Array:
    """int8 (..., K) × packed-int4 (K, N//2) → int32 (..., N)."""
    return quant_matmul(x_q, w_packed, w_format="int4_packed",
                        bm=bm, bn=bn, bk=bk, interpret=interpret)


def lut_matmul(x_q: jax.Array, w_q: jax.Array, *,
               bm: int = 128, bn: int = 128, bk: int = 128,
               interpret: bool | None = None) -> jax.Array:
    """int8 (..., K) × int8 (K, N) → int32 (..., N) via LUT selection."""
    return quant_matmul(x_q, w_q, w_format="lut", bm=bm, bn=bn, bk=bk,
                        interpret=interpret)


def quant_matmul_fused(x: jax.Array, w_q: jax.Array, w_scale: jax.Array, *,
                       bm: int = 128, bn: int = 128, bk: int = 128,
                       out_dtype=jnp.bfloat16,
                       interpret: bool | None = None) -> jax.Array:
    """float (..., K) × int8 (K, N) + scales → out_dtype (..., N), fused.

    Per-row symmetric int8 activation quantization runs as a cheap XLA
    prolog on the unpadded rows; the matmul and the scale fold run in the
    single-pass kernel with the bf16 epilogue (no int32 HBM round-trip).
    """
    mat, unflatten = _flatten_leading(x)
    x_q, x_scale = quantize_rows(mat)
    out = quant_matmul(x_q, w_q, x_scale=x_scale, w_scale=w_scale,
                       w_format="int8", bm=bm, bn=bn, bk=bk,
                       out_dtype=out_dtype, interpret=interpret)
    return unflatten(out)


# ---------------------------------------------------------------------------
# Flash attention (custom VJP over the Pallas forward/backward kernels)
# ---------------------------------------------------------------------------

def _pad_seq(x, mult):
    p = (-x.shape[1]) % mult
    if p:
        x = jnp.pad(x, ((0, 0), (0, p), (0, 0)))
    return x


def _pad_dim(x, mult):
    p = (-x.shape[2]) % mult
    if p:
        x = jnp.pad(x, ((0, 0), (0, 0), (0, p)))
    return x


@functools.partial(jax.custom_vjp,
                   nondiff_argnums=(3, 4, 5, 6, 7, 8))
def flash_mha(q, k, v, scale, causal=True, window=0, softcap=0.0,
              group=1, interpret=None):
    """Flash attention over flat head-major layouts.

    q: (B·H, Sq, d); k/v: (B·KVH, Sk, d/dv) with H = KVH·group and the
    q heads ordered (kv_head, group) so head ``bh`` reads kv row
    ``bh // group``.  Differentiable (custom VJP, both passes in Pallas).
    Unaligned Sq/Sk/d are padded to the 128 grid internally.
    """
    o, _ = _flash_fwd_impl(q, k, v, scale, causal, window, softcap, group,
                           interpret)
    return o


def _flash_fwd_impl(q, k, v, scale, causal, window, softcap, group,
                    interpret):
    from repro.kernels.flash_attention import flash_attention_fwd_pallas
    if interpret is None:
        interpret = not _on_tpu()
    sq, sk, dv = q.shape[1], k.shape[1], v.shape[2]
    qp, kp, vp = _pad_seq(q, 128), _pad_seq(k, 128), _pad_seq(v, 128)
    qp, kp = _pad_dim(qp, 128), _pad_dim(kp, 128)
    vp = _pad_dim(vp, 128)
    o, lse = flash_attention_fwd_pallas(
        qp, kp, vp, scale=scale, causal=causal, window=window,
        softcap=softcap, group=group, interpret=interpret)
    return o[:, :sq, :dv], lse[:, :sq]


def _flash_mha_fwd(q, k, v, scale, causal, window, softcap, group,
                   interpret):
    o, lse = _flash_fwd_impl(q, k, v, scale, causal, window, softcap,
                             group, interpret)
    return o, (q, k, v, o, lse)


def _flash_mha_bwd(scale, causal, window, softcap, group, interpret,
                   res, do):
    from repro.kernels.flash_attention import flash_attention_bwd_pallas
    q, k, v, o, lse = res
    if interpret is None:
        interpret = not _on_tpu()
    sq, sk = q.shape[1], k.shape[1]
    d, dv = q.shape[2], v.shape[2]
    qp, kp, vp = _pad_seq(q, 128), _pad_seq(k, 128), _pad_seq(v, 128)
    qp, kp, vp = _pad_dim(qp, 128), _pad_dim(kp, 128), _pad_dim(vp, 128)
    op = _pad_dim(_pad_seq(o, 128), 128)
    dop = _pad_dim(_pad_seq(do, 128), 128)
    lsep = jnp.pad(lse, ((0, 0), (0, (-sq) % 128)),
                   constant_values=0.0)
    dq, dk_h, dv_h = flash_attention_bwd_pallas(
        qp, kp, vp, op, lsep, dop, scale=scale, causal=causal,
        window=window, softcap=softcap, group=group, interpret=interpret)
    dq = dq[:, :sq, :d].astype(q.dtype)
    # fold per-q-head dk/dv back onto the kv heads (sum over the group)
    bh = q.shape[0]
    bkv = k.shape[0]
    dk_h = dk_h[:, :sk, :d].reshape(bkv, group, sk, d).sum(1)
    dv_h = dv_h[:, :sk, :dv].reshape(bkv, group, sk, dv).sum(1)
    return dq, dk_h.astype(k.dtype), dv_h.astype(v.dtype)


flash_mha.defvjp(_flash_mha_fwd, _flash_mha_bwd)


# ---------------------------------------------------------------------------
# Paged decode attention (page-table-indexed KV pool)
# ---------------------------------------------------------------------------

def paged_flash_decode(q, k_pool, v_pool, table, q_pos, *, scale,
                       window=0, softcap=0.0, interpret=None):
    """Single-token decode attention against a paged KV cache.

    ``q``: (B, 1, H, d) with heads ordered (kv_head, group);
    ``k_pool``/``v_pool``: (num_pages, page_size, KVH, d/dv) shared
    pools; ``table``: (B, max_pages) int32 page table; ``q_pos``: (B,)
    per-slot query positions.  Returns (B, 1, H, dv).

    The kernel walks the page table through scalar-prefetched BlockSpec
    index maps — no gathered (B, max_len, ...) copy of the cache is
    materialized, unlike the XLA reference path.  Head dims are padded
    to the 128-lane grid here; page_size/group alignment is the
    caller's concern on real TPUs (interpret mode takes any shape).
    """
    from repro.kernels.flash_attention import paged_decode_attention_pallas
    if interpret is None:
        interpret = not _on_tpu()
    b, s, h, d = q.shape
    if s != 1:
        raise ValueError(f"paged decode takes one query per slot, got "
                         f"S={s}")
    kvh = k_pool.shape[2]
    g = h // kvh
    dv = v_pool.shape[-1]
    qg = q.reshape(b, kvh, g, d)                  # (B, KVH, G, d)

    def pad_last(x, mult=128):
        p = (-x.shape[-1]) % mult
        return jnp.pad(x, [(0, 0)] * (x.ndim - 1) + [(0, p)]) if p else x

    o = paged_decode_attention_pallas(
        pad_last(qg), pad_last(k_pool), pad_last(v_pool), table, q_pos,
        scale=scale, window=window, softcap=softcap, interpret=interpret)
    return o[..., :dv].reshape(b, 1, h, dv)
