"""Public jit'd entry points for the kernels (padding, backend dispatch).

``interpret`` defaults to auto: interpret-mode on CPU (validation), real
Mosaic lowering on TPU.  All wrappers accept arbitrary (unaligned)
shapes and pad to the block grid internally; results are exact.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.lut_matmul import lut_matmul_pallas
from repro.kernels.nibble_matmul import (
    nibble_matmul_pallas,
    nibble_matmul_w4_pallas,
)
from repro.kernels.quant_matmul_fused import quant_matmul_fused_pallas

__all__ = ["nibble_matmul", "nibble_matmul_w4", "lut_matmul",
           "quant_matmul_fused", "flash_mha"]


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _pad_to(x, m0, m1):
    p0 = (-x.shape[0]) % m0
    p1 = (-x.shape[1]) % m1
    if p0 or p1:
        x = jnp.pad(x, ((0, p0), (0, p1)))
    return x


def _flatten_leading(x):
    """Collapse leading dims to a matrix; return (mat, unflatten)."""
    lead = x.shape[:-1]
    mat = x.reshape(-1, x.shape[-1])

    def unflatten(y):
        return y.reshape(*lead, y.shape[-1])

    return mat, unflatten


def nibble_matmul(x_q: jax.Array, w_q: jax.Array, *,
                  bm: int = 128, bn: int = 128, bk: int = 128,
                  unroll_passes: bool = True,
                  interpret: bool | None = None) -> jax.Array:
    """int8 (..., K) × int8 (K, N) → int32 (..., N) — the paper's kernel."""
    if interpret is None:
        interpret = not _on_tpu()
    mat, unflatten = _flatten_leading(x_q)
    m, k = mat.shape
    n = w_q.shape[1]
    xp = _pad_to(mat, bm, bk)
    wp = _pad_to(w_q, bk, bn)
    out = nibble_matmul_pallas(xp, wp, bm=bm, bn=bn, bk=bk,
                               unroll_passes=unroll_passes,
                               interpret=interpret)
    return unflatten(out[:m, :n])


def nibble_matmul_w4(x_q: jax.Array, w_packed: jax.Array, *,
                     bm: int = 128, bn: int = 128, bk: int = 128,
                     interpret: bool | None = None) -> jax.Array:
    """int8 (..., K) × packed-int4 (K, N//2) → int32 (..., N)."""
    if interpret is None:
        interpret = not _on_tpu()
    mat, unflatten = _flatten_leading(x_q)
    m, k = mat.shape
    n = 2 * w_packed.shape[1]
    xp = _pad_to(mat, bm, bk)
    wp = _pad_to(w_packed, bk, bn // 2)
    out = nibble_matmul_w4_pallas(xp, wp, bm=bm, bn=bn, bk=bk,
                                  interpret=interpret)
    return unflatten(out[:m, :n])


def lut_matmul(x_q: jax.Array, w_q: jax.Array, *,
               bm: int = 128, bn: int = 128, bk: int = 128,
               interpret: bool | None = None) -> jax.Array:
    """int8 (..., K) × int8 (K, N) → int32 (..., N) via LUT selection."""
    if interpret is None:
        interpret = not _on_tpu()
    mat, unflatten = _flatten_leading(x_q)
    m, k = mat.shape
    n = w_q.shape[1]
    xp = _pad_to(mat, bm, bk)
    wp = _pad_to(w_q, bk, bn)
    out = lut_matmul_pallas(xp, wp, bm=bm, bn=bn, bk=bk, interpret=interpret)
    return unflatten(out[:m, :n])


def quant_matmul_fused(x: jax.Array, w_q: jax.Array, w_scale: jax.Array, *,
                       bm: int = 128, bn: int = 128,
                       out_dtype=jnp.bfloat16,
                       interpret: bool | None = None) -> jax.Array:
    """float (..., K) × int8 (K, N) + scales → out_dtype (..., N), fused."""
    if interpret is None:
        interpret = not _on_tpu()
    mat, unflatten = _flatten_leading(x)
    m, k = mat.shape
    n = w_q.shape[1]
    # K must stay whole (per-row scale exactness): pad only M and N.
    xp = _pad_to(mat, bm, 1)
    wp = _pad_to(w_q, 1, bn)
    sp = _pad_to(w_scale.reshape(1, -1), 1, bn)
    out = quant_matmul_fused_pallas(xp, wp, sp, bm=bm, bn=bn,
                                    out_dtype=out_dtype, interpret=interpret)
    return unflatten(out[:m, :n])


# ---------------------------------------------------------------------------
# Flash attention (custom VJP over the Pallas forward/backward kernels)
# ---------------------------------------------------------------------------

def _pad_seq(x, mult):
    p = (-x.shape[1]) % mult
    if p:
        x = jnp.pad(x, ((0, 0), (0, p), (0, 0)))
    return x


def _pad_dim(x, mult):
    p = (-x.shape[2]) % mult
    if p:
        x = jnp.pad(x, ((0, 0), (0, 0), (0, p)))
    return x


@functools.partial(jax.custom_vjp,
                   nondiff_argnums=(3, 4, 5, 6, 7, 8))
def flash_mha(q, k, v, scale, causal=True, window=0, softcap=0.0,
              group=1, interpret=None):
    """Flash attention over flat head-major layouts.

    q: (B·H, Sq, d); k/v: (B·KVH, Sk, d/dv) with H = KVH·group and the
    q heads ordered (kv_head, group) so head ``bh`` reads kv row
    ``bh // group``.  Differentiable (custom VJP, both passes in Pallas).
    Unaligned Sq/Sk/d are padded to the 128 grid internally.
    """
    o, _ = _flash_fwd_impl(q, k, v, scale, causal, window, softcap, group,
                           interpret)
    return o


def _flash_fwd_impl(q, k, v, scale, causal, window, softcap, group,
                    interpret):
    from repro.kernels.flash_attention import flash_attention_fwd_pallas
    if interpret is None:
        interpret = not _on_tpu()
    sq, sk, dv = q.shape[1], k.shape[1], v.shape[2]
    qp, kp, vp = _pad_seq(q, 128), _pad_seq(k, 128), _pad_seq(v, 128)
    qp, kp = _pad_dim(qp, 128), _pad_dim(kp, 128)
    vp = _pad_dim(vp, 128)
    o, lse = flash_attention_fwd_pallas(
        qp, kp, vp, scale=scale, causal=causal, window=window,
        softcap=softcap, group=group, interpret=interpret)
    return o[:, :sq, :dv], lse[:, :sq]


def _flash_mha_fwd(q, k, v, scale, causal, window, softcap, group,
                   interpret):
    o, lse = _flash_fwd_impl(q, k, v, scale, causal, window, softcap,
                             group, interpret)
    return o, (q, k, v, o, lse)


def _flash_mha_bwd(scale, causal, window, softcap, group, interpret,
                   res, do):
    from repro.kernels.flash_attention import flash_attention_bwd_pallas
    q, k, v, o, lse = res
    if interpret is None:
        interpret = not _on_tpu()
    sq, sk = q.shape[1], k.shape[1]
    d, dv = q.shape[2], v.shape[2]
    qp, kp, vp = _pad_seq(q, 128), _pad_seq(k, 128), _pad_seq(v, 128)
    qp, kp, vp = _pad_dim(qp, 128), _pad_dim(kp, 128), _pad_dim(vp, 128)
    op = _pad_dim(_pad_seq(o, 128), 128)
    dop = _pad_dim(_pad_seq(do, 128), 128)
    lsep = jnp.pad(lse, ((0, 0), (0, (-sq) % 128)),
                   constant_values=0.0)
    dq, dk_h, dv_h = flash_attention_bwd_pallas(
        qp, kp, vp, op, lsep, dop, scale=scale, causal=causal,
        window=window, softcap=softcap, group=group, interpret=interpret)
    dq = dq[:, :sq, :d].astype(q.dtype)
    # fold per-q-head dk/dv back onto the kv heads (sum over the group)
    bh = q.shape[0]
    bkv = k.shape[0]
    dk_h = dk_h[:, :sk, :d].reshape(bkv, group, sk, d).sum(1)
    dv_h = dv_h[:, :sk, :dv].reshape(bkv, group, sk, dv).sum(1)
    return dq, dk_h.astype(k.dtype), dv_h.astype(v.dtype)


flash_mha.defvjp(_flash_mha_fwd, _flash_mha_bwd)
