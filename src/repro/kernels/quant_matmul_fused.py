"""Pallas TPU kernel: fused quantize → nibble-matmul → dequantize.

The deployment hot path: bf16 activations in, bf16 activations out, with
the whole integer pipeline — per-row symmetric int8 quantization, the
two nibble MXU passes, and the scale fold — inside one kernel, so the
int8 planes and int32 accumulator never touch HBM.

Tiling: the K dimension is kept whole inside the block (bk = K) so the
per-row abs-max is exact; the grid runs over (M/bm, N/bn).  For the
d_model sizes in the model zoo (≤ 8192) the working set is
bm·K·2 (x, bf16) + K·bn (w, int8) + bm·bn·4 (acc) ≈ 2–3 MiB at the
128-block defaults — comfortably inside a v5e core's 16 MiB VMEM.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["quant_matmul_fused_pallas"]


def _fused_kernel(x_ref, w_ref, ws_ref, o_ref):
    x = x_ref[...].astype(jnp.float32)                  # (bm, K)
    w = w_ref[...]                                      # (K, bn) int8
    w_scale = ws_ref[...].astype(jnp.float32)           # (1, bn)

    # --- per-row symmetric int8 quantization (exact: full K in block) ---
    amax = jnp.maximum(jnp.max(jnp.abs(x), axis=-1, keepdims=True), 1e-8)
    x_scale = amax / 127.0
    x_q = jnp.clip(jnp.round(x / x_scale), -128, 127).astype(jnp.int32)

    # --- the paper's two nibble passes ----------------------------------
    lo = x_q & 0xF
    hi = (x_q - lo) >> 4

    def mxu_pass(plane):
        return jax.lax.dot_general(
            plane.astype(jnp.int8), w,
            (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.int32)

    acc = mxu_pass(lo) + (mxu_pass(hi) << 4)

    # --- dequantize with folded scales -----------------------------------
    o_ref[...] = (acc.astype(jnp.float32) * x_scale * w_scale) \
        .astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bm", "bn", "interpret",
                                             "out_dtype"))
def quant_matmul_fused_pallas(x: jax.Array, w_q: jax.Array,
                              w_scale: jax.Array, *,
                              bm: int = 128, bn: int = 128,
                              out_dtype=jnp.bfloat16,
                              interpret: bool = True) -> jax.Array:
    """bf16/f32 (M,K) × int8 (K,N) with (1,N) f32 scales → out_dtype (M,N)."""
    m, k = x.shape
    k2, n = w_q.shape
    assert k == k2
    assert m % bm == 0 and n % bn == 0
    w_scale = w_scale.reshape(1, n).astype(jnp.float32)

    grid = (m // bm, n // bn)
    return pl.pallas_call(
        _fused_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, k), lambda i, j: (i, 0)),
            pl.BlockSpec((k, bn), lambda i, j: (0, j)),
            pl.BlockSpec((1, bn), lambda i, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), out_dtype),
        interpret=interpret,
    )(x, w_q, w_scale)
