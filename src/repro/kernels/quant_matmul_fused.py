"""Fused quantize → nibble-matmul → dequantize (absorbed into the nibble path).

The deployment hot path: bf16 activations in, bf16 activations out.  The
seed kept a separate whole-K kernel here; it is now a thin shim over
:func:`repro.kernels.nibble_matmul.fused_nibble_matmul_pallas` — the
per-row symmetric int8 quantization runs as a cheap VPU-class XLA prolog
(an abs-max reduction plus a rounding pass over the activations), and the
nibble matmul + scale fold run in the single-pass plane-fused kernel.
The int8 planes and the int32 accumulator never touch HBM; the output is
written once, as ``out_dtype``.

Compared with the seed kernel this also lifts the whole-K block
restriction: the fused path tiles K like every other kernel, so
arbitrarily large contractions no longer have to fit one VMEM block.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.nibble_matmul import fused_nibble_matmul_pallas

__all__ = ["quant_matmul_fused_pallas", "quantize_rows"]


def quantize_rows(x: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Per-row symmetric int8 quantization: (x_q int8, x_scale f32 (M,1)).

    Exact over full rows — run this *before* any K padding (zero pads
    cannot raise the abs-max, so padding afterwards is also safe).
    """
    x = x.astype(jnp.float32)
    amax = jnp.maximum(jnp.max(jnp.abs(x), axis=-1, keepdims=True), 1e-8)
    x_scale = amax / 127.0
    x_q = jnp.clip(jnp.round(x / x_scale), -128, 127).astype(jnp.int8)
    return x_q, x_scale


def quant_matmul_fused_pallas(x: jax.Array, w_q: jax.Array,
                              w_scale: jax.Array, *,
                              bm: int = 128, bn: int = 128, bk: int = 128,
                              out_dtype=jnp.bfloat16,
                              interpret: bool = True) -> jax.Array:
    """bf16/f32 (M,K) × int8 (K,N) with (1,N) f32 scales → out_dtype (M,N).

    Shim kept for the seed call sites; new code should go through
    ``ops.quant_matmul`` / ``ops.quant_matmul_fused``.
    """
    m, k = x.shape
    n = w_q.shape[1]
    x_q, x_scale = quantize_rows(x)
    return fused_nibble_matmul_pallas(
        x_q, w_q, x_scale, w_scale.reshape(1, n),
        bm=bm, bn=bn, bk=bk, out_dtype=out_dtype, interpret=interpret)
