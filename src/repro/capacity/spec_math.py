"""Geometric-run speculative-decoding estimator.

Self-speculative decoding (``ServeConfig.spec_decode``) drafts
``spec_k`` tokens with the quantized program and verifies them in one
dense multi-token forward.  Its payoff is governed by a single scalar —
the per-draft acceptance rate ``alpha`` — through the standard
geometric-run model: a round emits the accepted draft prefix plus one
more token (the correction on the first rejection, or the bonus token
when everything survives), so

    E[tokens/round](alpha, k) = 1 + alpha + ... + alpha^k
                              = (1 - alpha^(k+1)) / (1 - alpha)

and the per-token speedup over an autoregressive dense engine (one
dense forward per token) is

    speedup = E[tokens/round] / (k * c_draft + c_verify)

where ``c_draft`` is a draft forward's cost relative to a dense decode
forward and ``c_verify`` the (k+1)-token verify forward's.

This is the single home of the geometric math: ``tools/spec_report.py``
(the planning CLI) and ``repro.capacity.model`` (the serving-capacity
predictor, which uses E[tokens/round] as each spec slot's per-round
emission rate) both import from here, so the estimator the report
tabulates and the one capacity predictions are built on cannot drift
apart.
"""

from __future__ import annotations

__all__ = ["expected_tokens_per_round", "speedup",
           "acceptance_from_tokens_per_step"]


def expected_tokens_per_round(alpha: float, k: int) -> float:
    """E[tokens emitted per draft+verify round] for per-draft
    acceptance ``alpha`` and draft length ``k`` (geometric-run model:
    accepted prefix + correction/bonus)."""
    if not 0.0 <= alpha <= 1.0:
        raise ValueError(f"alpha must be in [0, 1], got {alpha}")
    if k < 1:
        raise ValueError(f"k must be >= 1, got {k}")
    if alpha == 1.0:
        return float(k + 1)
    return (1.0 - alpha ** (k + 1)) / (1.0 - alpha)


def speedup(alpha: float, k: int, c_draft: float = 0.5,
            c_verify: float = 1.0) -> float:
    """Per-token speedup over the autoregressive dense engine.  Costs
    are relative to one dense single-token decode forward; c_draft is
    the *quantized* draft forward (< 1 when the nibble path is cheaper,
    which is the paper's premise), c_verify the one (k+1)-token dense
    forward (≈ 1 while decode stays memory-bound: the weights are read
    once either way)."""
    if c_draft <= 0 or c_verify <= 0:
        raise ValueError("relative costs must be positive")
    return expected_tokens_per_round(alpha, k) / (k * c_draft + c_verify)


def acceptance_from_tokens_per_step(tps: float, k: int,
                                    tol: float = 1e-9) -> float:
    """Invert E[tokens/round] for ``alpha`` by bisection (the map is
    strictly increasing on [0, 1]).  ``tps`` must lie in
    [1, k + 1]; the endpoints invert exactly."""
    if k < 1:
        raise ValueError(f"k must be >= 1, got {k}")
    if not 1.0 <= tps <= k + 1:
        raise ValueError(f"tokens_per_step {tps} outside [1, {k + 1}] "
                         f"for k={k}")
    lo, hi = 0.0, 1.0
    while hi - lo > tol:
        mid = 0.5 * (lo + hi)
        if expected_tokens_per_round(mid, k) < tps:
            lo = mid
        else:
            hi = mid
    return 0.5 * (lo + hi)
