"""Model-vs-measured validation over ``BENCH_serve.json`` rows.

Every benchmark row carries a ``capacity`` blob — the exact knob set,
workload shape, calibrated per-dispatch stage costs, measured
speculative acceptance and cache bytes/token its prediction was
computed from.  Validation **replays** the prediction from that blob
(it does not trust the stored numbers) and compares against the row's
measured ``tok_per_s`` / ``ttft_p50_ms``, so the committed JSON is a
self-contained regression fixture: any machine can re-run the analytic
model against the measurements without re-benchmarking, and a model
change that breaks agreement fails ``tools/autotune.py --validate``
and ``tests/test_capacity.py`` alike.

Tolerance policy (documented in ``docs/capacity.md``): a metric passes
when ``|predicted - measured| <= max(rel * measured, abs_floor)``.
The relative band absorbs CPU-proxy timer noise and the model's known
simplifications; the absolute floor keeps sub-millisecond TTFT rows
from failing on microsecond jitter.  Only rows the model claims to
cover (``gated: true`` — single-device, no prefix cache) gate; the
rest still carry predictions for trend-watching.
"""

from __future__ import annotations

import json

from repro.capacity.model import (Knobs, StageCosts, WorkloadShape,
                                  predict)

__all__ = ["TOLERANCE", "check_row", "validate_rows", "load_bench"]

# metric -> (relative tolerance, absolute floor in the metric's unit).
# 0.40 relative: the CPU functional proxy's run-to-run wall-clock
# variance on the fast uniform rows is ~25% by itself; the model's
# structural predictions (dispatch counts, preemptions, swap events)
# are exact, so the band is timer noise, not model slack.
TOLERANCE = {
    "tok_per_s": (0.40, 0.0),
    "ttft_p50_ms": (0.40, 5.0),
}


def load_bench(path: str) -> list[dict]:
    with open(path) as f:
        return json.load(f)["results"]


def predict_row(row: dict) -> dict | None:
    """Replay the analytic prediction from a row's embedded capacity
    blob; None when the row carries no blob (mesh/router rows)."""
    blob = row.get("capacity")
    if not blob:
        return None
    return predict(Knobs.from_dict(blob["knobs"]),
                   WorkloadShape.from_dict(blob["shape"]),
                   StageCosts.from_dict(blob["costs"]),
                   cache_token_bytes=blob.get("cache_token_bytes", 0),
                   acceptance=blob.get("acceptance"))


def check_row(row: dict, tolerance: dict | None = None) -> dict | None:
    """One row's model-vs-measured verdict: per-metric predicted /
    measured / err_pct / ok plus the row-level ``ok`` (vacuously true
    for ungated rows).  None when the row has no capacity blob."""
    tol = tolerance or TOLERANCE
    pred = predict_row(row)
    if pred is None:
        return None
    gated = bool(row["capacity"].get("gated"))
    metrics = {}
    ok = pred.get("feasible", False)
    for name, (rel, floor) in tol.items():
        measured = float(row[name])
        predicted = float(pred.get(name, float("nan")))
        err = abs(predicted - measured)
        bound = max(rel * measured, floor)
        m_ok = err <= bound
        metrics[name] = {
            "measured": measured, "predicted": round(predicted, 3),
            "err_pct": round(100.0 * err / max(measured, 1e-9), 1),
            "bound": round(bound, 3), "ok": m_ok,
        }
        ok = ok and m_ok
    return {
        "workload": row.get("workload"), "quant": row.get("quant"),
        "backend": row.get("backend"), "cache": row.get("cache"),
        "alloc": row.get("alloc"), "spec": row.get("spec"),
        "tail": row.get("tail", "-"), "gated": gated,
        "metrics": metrics, "ok": ok or not gated, "within": ok,
    }


def validate_rows(rows: list[dict],
                  tolerance: dict | None = None) -> tuple[bool, list]:
    """Check every row carrying a capacity blob.  Returns
    ``(all_gated_rows_pass, per_row_checks)``; fails (False) if no
    gated row exists at all — an empty gate guards nothing."""
    checks = [c for c in (check_row(r, tolerance) for r in rows)
              if c is not None]
    gated = [c for c in checks if c["gated"]]
    ok = bool(gated) and all(c["within"] for c in gated)
    return ok, checks
