"""Knob-space search over the analytic capacity model.

The grid, the objective-driven search and the report table shared by
``tools/autotune.py`` (the CLI) and the serving launcher's
``--autotune`` flag.  Costs come from :meth:`StageCosts.from_model`
(static MACs/bytes through the roofline constants) — ranking knob
settings needs relative fidelity, not wall-clock truth; the calibrated
path (``repro.capacity.calibrate``) owns model-vs-measured validation.
"""

from __future__ import annotations

from repro.capacity.model import (CapacityError, Knobs, StageCosts,
                                  WorkloadShape,
                                  analytic_cache_token_bytes, predict)

__all__ = ["knob_grid", "search", "table_lines"]


def knob_grid(shape: WorkloadShape, *, batch: int, max_len: int,
              prefill_len: int, page_size_opts=(4, 8),
              small: bool = False):
    """The structured knob space: one dense baseline sweep plus paged
    variants crossing allocation mode, pool size, wave prefill, swap
    and speculative decoding.  ``small`` is the CI grid."""
    chunks = (1, 8) if small else (1, 4, 8)
    pools_frac = (0.5, 1.0) if small else (0.25, 0.5, 1.0)
    cells = []
    for dc in chunks:
        cells.append(Knobs(batch=batch, max_len=max_len,
                           prefill_len=prefill_len, decode_chunk=dc,
                           cache_mode="dense"))
    for ps in (page_size_opts[:1] if small else page_size_opts):
        parity = batch * (max_len // ps) + 1
        pools = sorted({max(2, int(parity * f)) | 1 for f in pools_frac})
        for np_ in pools:
            for alloc in ("reserve", "incremental"):
                for dc in chunks:
                    cells.append(Knobs(
                        batch=batch, max_len=max_len,
                        prefill_len=prefill_len, decode_chunk=dc,
                        cache_mode="paged", page_size=ps, num_pages=np_,
                        alloc_mode=alloc))
            # wave prefill + grouped admission (+ host swap)
            for swap in (("off",) if small else ("off", "host")):
                cells.append(Knobs(
                    batch=batch, max_len=max_len,
                    prefill_len=prefill_len, decode_chunk=8,
                    cache_mode="paged", page_size=ps, num_pages=np_,
                    alloc_mode="incremental",
                    prefill_chunk=max(1, prefill_len // 4),
                    admit_group=batch, swap_mode=swap))
            # speculative decoding on the parity pool
            for k in ((4,) if small else (2, 4)):
                cells.append(Knobs(
                    batch=batch, max_len=max_len,
                    prefill_len=prefill_len, decode_chunk=1,
                    cache_mode="paged", page_size=ps, num_pages=parity,
                    alloc_mode="incremental", spec_decode=True,
                    spec_k=k, quant_mode="w8a8_nibble"))
    # Knobs is frozen/hashable: drop duplicate cells, keep first-seen
    return list(dict.fromkeys(cells))


def search(cfg, shape: WorkloadShape, cells, *, objective: str,
           ttft_slo_ms: float | None, alpha: float,
           dispatch_s: float = 5e-5):
    """Predict every cell and rank the feasible ones.  Returns
    (ranked results, winner) where each result is
    ``{knobs, prediction, admissible}``."""
    ctb = analytic_cache_token_bytes(cfg)
    results = []
    for knobs in cells:
        try:
            costs = StageCosts.from_model(
                cfg, knobs, prompt_budget=shape.prompt_budget,
                dispatch_s=dispatch_s)
            pred = predict(knobs, shape, costs, cache_token_bytes=ctb,
                           acceptance=alpha if knobs.spec_decode
                           else None)
        except CapacityError as e:
            results.append({"knobs": knobs, "prediction": None,
                            "admissible": False, "reason": str(e)})
            continue
        admissible = bool(pred["feasible"])
        if admissible and ttft_slo_ms is not None:
            admissible = pred["ttft_p99_ms"] <= ttft_slo_ms
        if admissible and objective == "min-pages":
            admissible = (knobs.paged and pred["preemptions"] == 0)
        results.append({"knobs": knobs, "prediction": pred,
                        "admissible": admissible,
                        "reason": pred.get("infeasible_reason")})
    ranked = [r for r in results if r["admissible"]]
    if objective == "min-pages":
        ranked.sort(key=lambda r: (r["knobs"].resolved_num_pages,
                                   -r["prediction"]["tok_per_s"]))
    else:
        ranked.sort(key=lambda r: -r["prediction"]["tok_per_s"])
    winner = ranked[0] if ranked else None
    return results, winner


def table_lines(results, winner):
    yield ("cache,alloc,page_size,pool_pages,decode_chunk,wave,swap,"
           "spec,tok_per_s,ttft_p50_ms,ttft_p99_ms,preempt,"
           "cache_kb_per_req,admissible")
    for r in sorted(results,
                    key=lambda r: -(r["prediction"]["tok_per_s"]
                                    if r["prediction"]
                                    and "tok_per_s" in r["prediction"]
                                    else -1.0)):
        k, p = r["knobs"], r["prediction"]
        mark = " <== winner" if winner is not None \
            and k == winner["knobs"] else ""
        if p is None or "tok_per_s" not in p:
            yield (f"{k.cache_mode},{k.alloc_mode},{k.page_size},"
                   f"{k.resolved_num_pages},{k.decode_chunk},"
                   f"{'on' if k.wave else '-'},{k.swap_mode},"
                   f"{k.spec_k if k.spec_decode else '-'},"
                   f"-,-,-,-,-,no ({r.get('reason')})")
            continue
        yield (f"{k.cache_mode},{k.alloc_mode},{k.page_size},"
               f"{k.resolved_num_pages},{k.decode_chunk},"
               f"{'on' if k.wave else '-'},{k.swap_mode},"
               f"{k.spec_k if k.spec_decode else '-'},"
               f"{p['tok_per_s']:.0f},{p['ttft_p50_ms']:.1f},"
               f"{p['ttft_p99_ms']:.1f},{p['preemptions']},"
               f"{p['cache_kb_per_req']:.1f},"
               f"{'yes' if r['admissible'] else 'no'}{mark}")
