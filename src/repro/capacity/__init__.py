"""Analytic serving-capacity model: predict tok/s, TTFT, cache
footprint, concurrency and preemption risk from (knobs, workload
shape, per-stage costs) without running the model — then hold every
prediction to account against measured ``BENCH_serve.json`` rows.

Layout:

- ``spec_math``  — geometric-run speculative-decoding estimator (the
  single home of the math ``tools/spec_report.py`` tabulates).
- ``model``      — :class:`Knobs` / :class:`WorkloadShape` /
  :class:`StageCosts` and :func:`predict`, the discrete-event replay
  of the engine scheduler.
- ``calibrate``  — measured per-dispatch stage costs from a live
  engine (what bench rows embed).
- ``validate``   — model-vs-measured tolerance checks over bench rows
  (shared by ``tools/autotune.py --validate`` and
  ``tests/test_capacity.py``).
"""

from repro.capacity.model import (CapacityError, Knobs, StageCosts,
                                  WorkloadShape,
                                  analytic_cache_token_bytes, predict)
from repro.capacity.spec_math import (acceptance_from_tokens_per_step,
                                      expected_tokens_per_round, speedup)

__all__ = ["CapacityError", "Knobs", "StageCosts", "WorkloadShape",
           "analytic_cache_token_bytes", "predict",
           "acceptance_from_tokens_per_step",
           "expected_tokens_per_round", "speedup"]
