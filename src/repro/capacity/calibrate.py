"""Measured stage-cost calibration for the capacity model.

:func:`calibrate_engine` turns a *live* engine into a
:class:`~repro.capacity.model.StageCosts`: each compiled stage program
is re-invoked on concrete zero arrays rebuilt from its recorded
abstract signature (``_CountingJit.signatures`` → ``abstract_args`` —
the same replay surface ``repro.staticcheck`` lowers through) and
timed under ``block_until_ready``, taking the min over a few repeats.
That isolates the *device* cost of one dispatch; the *host* cost per
dispatch (scheduler walk, array conversion, callback bookkeeping) is
solved from a tiny zero-arrival probe run:

    overhead_s = max(0, (wall_probe - sum(stage_s * dispatches))
                        / total_dispatches)

The probe is deliberately separate from any workload being predicted —
calibration constants are measured once per engine build and never
fitted to the row they validate against, which is what makes the
``BENCH_serve.json`` replay in ``tools/autotune.py --validate`` a real
model-vs-measured check rather than a tautology.
"""

from __future__ import annotations

import time

import numpy as np
import jax
import jax.numpy as jnp

from repro.capacity.model import StageCosts

__all__ = ["calibrate_engine", "time_stage"]


def _concrete_args(stage, signature):
    """Zero-filled concrete arrays for one recorded abstract signature
    (donation-safe: callers rebuild per invocation)."""
    abstract = stage.abstract_args(signature)
    return jax.tree_util.tree_map(
        lambda leaf: (jnp.zeros(leaf.shape, leaf.dtype)
                      if hasattr(leaf, "shape") else leaf), abstract)


def time_stage(stage, *, iters: int = 3) -> float:
    """Seconds per dispatch of one compiled stage program: min over
    ``iters`` timed calls on its first recorded signature (fresh zero
    args every call — the stage may donate its cache operands)."""
    sig = stage.signatures[0]
    best = float("inf")
    for _ in range(iters + 1):      # first call warms any lazy paths
        args = _concrete_args(stage, sig)
        t0 = time.perf_counter()
        out = stage.jit_fn(*args)
        jax.block_until_ready(out)
        dt = time.perf_counter() - t0
        best = min(best, dt)
    return best


def _time_swap_event(engine, *, iters: int = 3) -> float:
    """Seconds per host-tier swap *event*: extract and insert each
    gather all of an event's pages in one dispatch, so the cost is flat
    in page count.  Times a one-page extract/insert round trip on the
    live caches (page 1 always exists — page 0 is the trash page) and
    halves it — the round trip is one swap-out plus one swap-in."""
    from repro.models.transformer import (extract_cache_pages,
                                          insert_cache_pages)
    best = float("inf")
    for _ in range(iters):
        t0 = time.perf_counter()
        payload = extract_cache_pages(engine._caches, [1],
                                      pad_to=engine._swap_pad)
        engine._caches = insert_cache_pages(engine._caches, [1], payload,
                                            pad_to=engine._swap_pad)
        jax.block_until_ready(engine._caches)
        best = min(best, time.perf_counter() - t0)
    return best / 2.0


def _probe_overhead(engine, costs: StageCosts) -> float:
    """Per-scheduler-iteration host overhead solved from a zero-arrival
    probe run at full slot width (the per-dispatch python walk scales
    with the batch, so the probe must exercise the same width the
    predicted workload will).  The divisor counts *iterations* the way
    the simulator charges them: one per monolithic prefill, decode
    chunk, wave dispatch, or draft+verify spec round."""
    scfg = engine.scfg
    p_len = scfg.prefill_len or max(1, scfg.max_len // 4)
    # decode-heavy probe: the decode/spec iterations carry the host
    # walk whose cost we are solving for, so they must dominate the
    # dispatch mix the way they dominate real workloads — but shrink
    # until the pool can hold the whole probe (a preempting probe
    # replays tokens and muddies the solve; small pools accept new=4)
    new = max(2, min(32, scfg.max_len - p_len))
    if getattr(engine, "allocator", None) is not None:
        from repro.serve.paging import pages_needed
        cap = engine.allocator.capacity
        while new > 4 and (scfg.batch
                           * pages_needed(p_len + new - 1,
                                          scfg.page_size)) > cap:
            new //= 2
    requests = scfg.batch

    def _submit_all(rng):
        for _ in range(requests):
            engine.submit(rng.integers(0, 8, p_len, dtype=np.int64),
                          new)

    # untimed warmup pass: the probe's token mix can hit stage
    # signatures the workload so far never compiled (e.g. a partial
    # final chunk) — a compile landing inside the timed wall inflates
    # the solved overhead several-fold on slow-compile backends
    engine.reset()
    try:
        _submit_all(np.random.default_rng(0xCA11B))
    except ValueError:
        engine.reset()
        return 0.0
    engine.run()

    engine.reset()
    base = engine.stats
    base_counts = (base["decode_chunks"], base["prefill_waves"],
                   base["spec_rounds"], base["prefill_tokens"])
    _submit_all(np.random.default_rng(0xCA11B))
    t0 = time.perf_counter()
    engine.run()
    wall = time.perf_counter() - t0
    stats = engine.stats
    n_chunks = stats["decode_chunks"] - base_counts[0]
    n_waves = stats["prefill_waves"] - base_counts[1]
    n_spec = stats["spec_rounds"] - base_counts[2]
    # placements, not submissions: a preempted probe request re-prefills
    # its prompt on resume and every placement is one dispatch
    n_prefill = (0 if n_waves else
                 (stats["prefill_tokens"] - base_counts[3]) // p_len)
    engine.reset()

    modeled = (n_prefill * costs.prefill_s
               + n_chunks * costs.decode_chunk_s
               + n_waves * costs.prefill_chunk_s
               + n_spec * (costs.draft_s + costs.verify_s))
    dispatches = n_prefill + n_chunks + n_waves + n_spec
    if dispatches == 0:
        return 0.0
    return max(0.0, (wall - modeled) / dispatches)


def calibrate_engine(engine, *, iters: int = 3,
                     probe: bool = True) -> StageCosts:
    """Measure per-dispatch stage costs for a live (already compiled)
    engine.  Call after at least one run so every stage has a recorded
    signature; stages the mode never built stay at 0.0."""
    costs = StageCosts(source="measured")
    names = {"prefill": "prefill_s",
             "prefill_chunk": "prefill_chunk_s",
             "decode_chunk": "decode_chunk_s",
             "draft": "draft_s",
             "verify": "verify_s"}
    for name, stage in engine.stage_programs().items():
        if not stage.signatures:
            continue
        setattr(costs, names[name], time_stage(stage, iters=iters))
    if getattr(engine, "host_pool", None) is not None:
        try:
            costs.swap_event_s = _time_swap_event(engine, iters=iters)
        except Exception:
            costs.swap_event_s = 0.0
    if probe:
        costs.overhead_s = _probe_overhead(engine, costs)
    return costs
