"""Analytic serving-capacity model (ECM-style, kerncraft's spirit).

Predicts **tok/s, TTFT p50/p99, HBM cache footprint, steady-state
concurrency and preemption risk** for a ``(ServeConfig knobs,
workload shape)`` pair WITHOUT running the model: the scheduler loop of
``repro.serve.engine`` is replayed as a deterministic discrete-event
simulation in which every compiled-stage dispatch costs a constant —
the :class:`StageCosts` — instead of a real forward.  The structure
(dispatch counts, admission/eviction order, arrival gaps, page-pool
occupancy) is *derived*, exactly as the paper derives cycles from
nibble structure before measuring anything; only the per-dispatch
constants are calibrated, either

* **measured** once per engine build (``repro.capacity.calibrate``
  times each compiled stage on its recorded abstract signature — the
  constants a bench row embeds, making its prediction replayable on
  any machine), or
* **modeled** from the static per-stage MACs/bytes that
  ``repro.staticcheck.flops`` + ``repro.roofline`` already produce
  (:meth:`StageCosts.from_model` — no hardware in the loop; the
  planning path ``tools/autotune.py`` ranks knob settings with).

Fidelity contract: the simulation mirrors ``Engine.step()`` —
arrival-gated priority admission with the same ``(eff, arrival, seq)``
ordering, page-pool backpressure via ``_can_admit``/``_evictable``,
reserve vs incremental booking with per-chunk top-ups, evict-and-resume
preemption with token replay (or host-tier page swap), chunked/grouped
wave prefill, and speculative rounds whose per-slot emission rate is
the geometric-run expectation from ``repro.capacity.spec_math``.  The
workload itself comes from the SAME seeded draw the timed driver uses
(``repro.serve.workload.draw_workload``), so predicted and measured
rows see identical arrival/length processes.

Known simplifications (documented in ``docs/capacity.md``): prefix-
cache page sharing is not modeled (predictions for ``prefix_cache=on``
rows treat every prompt as cold), EOS never fires (greedy serving of
random-weight checkpoints never emits ``eos_id``), and speculative
acceptance enters as one scalar ``alpha`` rather than a per-round coin
flip — the expected-value emission is accumulated fractionally so the
long-run token count is exact.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from repro.serve.paging import pages_needed
from repro.capacity.spec_math import expected_tokens_per_round

__all__ = ["WorkloadShape", "Knobs", "StageCosts", "CapacityError",
           "predict", "analytic_cache_token_bytes"]


class CapacityError(ValueError):
    """A knob/workload combination the engine itself would reject at
    submit time (mirrors ``Engine.validate``'s ValueError)."""


@dataclasses.dataclass(frozen=True)
class WorkloadShape:
    """The workload half of the prediction input — the exact knob set
    ``run_timed_workload`` draws its request stream from."""

    requests: int
    prompt_budget: int
    new_tokens: int
    stagger_s: float = 0.0
    seed: int = 0
    priority_mix: float = 0.0
    shared_prefix: float = 0.0
    arrival_mode: str = "uniform"

    def draw(self):
        """The realized request stream (lengths/arrivals/priorities) —
        bit-identical to the timed driver's, minus the prompt bodies."""
        from repro.serve.workload import draw_workload
        return draw_workload(2, requests=self.requests,
                             prompt_budget=self.prompt_budget,
                             new_tokens=self.new_tokens,
                             stagger_s=self.stagger_s, seed=self.seed,
                             priority_mix=self.priority_mix,
                             shared_prefix=self.shared_prefix,
                             arrival_mode=self.arrival_mode,
                             materialize=False)

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)

    @classmethod
    def from_dict(cls, d: dict) -> "WorkloadShape":
        names = {f.name for f in dataclasses.fields(cls)}
        return cls(**{k: v for k, v in d.items() if k in names})


@dataclasses.dataclass(frozen=True)
class Knobs:
    """The ServeConfig subset the capacity model depends on — a
    JSON-stable mirror so bench rows can embed the exact knob set their
    prediction was computed from."""

    batch: int
    max_len: int
    prefill_len: int = 0
    decode_chunk: int = 8
    cache_mode: str = "dense"
    page_size: int = 8
    num_pages: int | None = None
    alloc_mode: str = "reserve"
    spec_decode: bool = False
    spec_k: int = 4
    prefill_chunk: int = 0
    admit_group: int = 1
    swap_mode: str = "off"
    host_pages: int = 0
    priority_aging_s: float = 0.0
    quant_mode: str = "dense"
    quant_backend: str = "xla"

    @classmethod
    def from_serve_config(cls, scfg) -> "Knobs":
        return cls(batch=scfg.batch, max_len=scfg.max_len,
                   prefill_len=scfg.prefill_len,
                   decode_chunk=scfg.decode_chunk,
                   cache_mode=scfg.cache_mode, page_size=scfg.page_size,
                   num_pages=scfg.num_pages, alloc_mode=scfg.alloc_mode,
                   spec_decode=scfg.spec_decode, spec_k=scfg.spec_k,
                   prefill_chunk=scfg.prefill_chunk,
                   admit_group=scfg.admit_group, swap_mode=scfg.swap_mode,
                   host_pages=scfg.host_pages,
                   priority_aging_s=scfg.priority_aging_s,
                   quant_mode=scfg.quant_mode,
                   quant_backend=scfg.quant_backend)

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)

    @classmethod
    def from_dict(cls, d: dict) -> "Knobs":
        names = {f.name for f in dataclasses.fields(cls)}
        return cls(**{k: v for k, v in d.items() if k in names})

    def to_serve_config(self, **overrides):
        """The real ServeConfig for this knob set (spec rows get the
        standard self-speculative draft program)."""
        from repro.serve.engine import ServeConfig
        kw = self.to_dict()
        if kw["spec_decode"] and "spec_quant_mode" not in overrides:
            kw["spec_quant_mode"] = (self.quant_mode
                                     if self.quant_mode != "dense"
                                     else "w8a8_nibble")
        kw.update(overrides)
        return ServeConfig(**kw)

    # --- derived geometry (identical to the engine's resolution) ------
    @property
    def paged(self) -> bool:
        return self.cache_mode == "paged"

    @property
    def wave(self) -> bool:
        return self.prefill_chunk > 0 or self.admit_group > 1

    @property
    def resolved_num_pages(self) -> int:
        """Pool size incl. the reserved trash page (0 in dense mode):
        ``num_pages`` or capacity parity with the dense slab."""
        if not self.paged:
            return 0
        return (self.num_pages
                or self.batch * (self.max_len // self.page_size) + 1)


@dataclasses.dataclass
class StageCosts:
    """Seconds per compiled-stage dispatch, plus the per-dispatch host
    overhead (scheduler walk, array conversions) and the per-*event*
    cost of a host-tier swap (extract or insert is one gather dispatch
    over all of the event's pages, so the cost is dispatch-dominated
    and flat in page count — charging per page overstates multi-page
    events several-fold on the CPU proxy).  ``source`` records
    provenance: "measured" (calibrated on a live engine), "modeled"
    (static MACs/bytes through the roofline) or "manual"."""

    prefill_s: float = 0.0
    decode_chunk_s: float = 0.0
    prefill_chunk_s: float = 0.0
    draft_s: float = 0.0
    verify_s: float = 0.0
    swap_event_s: float = 0.0
    overhead_s: float = 0.0
    source: str = "manual"

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)

    @classmethod
    def from_dict(cls, d: dict) -> "StageCosts":
        names = {f.name for f in dataclasses.fields(cls)}
        return cls(**{k: v for k, v in d.items() if k in names})

    @classmethod
    def from_model(cls, cfg, knobs: Knobs, *,
                   prompt_budget: int | None = None,
                   dispatch_s: float = 5e-5) -> "StageCosts":
        """Static cost model: per-stage MACs from the closed-form
        ``staticcheck`` MAC model, bytes from weight streaming plus KV
        traffic, bridged through the roofline HW constants.  No
        hardware in the loop — intended for *ranking* knob settings
        (``tools/autotune.py``), not for wall-clock validation; the
        calibrated path owns that."""
        from repro.staticcheck.flops import analytic_macs
        from repro.launch.mesh import HW

        ctb = analytic_cache_token_bytes(cfg)
        p_len = knobs.prefill_len or prompt_budget or knobs.max_len // 2

        def stage_s(tokens, kv_len, logits, n_seqs, quantized):
            macs = analytic_macs(cfg, tokens=tokens, kv_len=kv_len,
                                 logit_positions=logits,
                                 quantized=quantized)["total_macs"]
            flops = 2.0 * macs
            # weight streaming reads each token's MAC operands once;
            # the KV term covers the cache rows the dispatch attends
            io = 2.0 * macs / max(tokens, 1) + n_seqs * kv_len * ctb
            return max(flops / HW.PEAK_BF16_FLOPS,
                       io / HW.HBM_BW) + dispatch_s

        quant = knobs.quant_mode != "dense"
        spec = knobs.spec_decode
        wave_chunk = knobs.prefill_chunk or knobs.prefill_len or p_len
        out = cls(source="modeled")
        if knobs.wave:
            out.prefill_chunk_s = stage_s(
                knobs.admit_group * wave_chunk, knobs.max_len,
                knobs.admit_group * wave_chunk, knobs.admit_group, quant)
        else:
            # spec pins the prefill dense
            out.prefill_s = stage_s(p_len, p_len, 1, 1,
                                    quant and not spec)
        if spec:
            out.draft_s = stage_s(knobs.batch * knobs.spec_k,
                                  knobs.max_len,
                                  knobs.batch * knobs.spec_k,
                                  knobs.batch, quant)
            out.verify_s = stage_s(knobs.batch * (knobs.spec_k + 1),
                                   knobs.max_len,
                                   knobs.batch * (knobs.spec_k + 1),
                                   knobs.batch, False)
        else:
            out.decode_chunk_s = stage_s(
                knobs.batch * knobs.decode_chunk, knobs.max_len,
                knobs.batch * knobs.decode_chunk, knobs.batch, quant)
        return out


def analytic_cache_token_bytes(cfg) -> int:
    """Closed-form KV-cache bytes per cached token — the analytic dual
    of ``Engine.cache_token_bytes`` (which counts the live buffers):
    per attention layer two ``n_kv_heads × head_dim`` rows (int8 adds
    the per-(token, head) f32 scales), MLA layers the compressed latent
    plus the shared rope key; mamba layers have no sequence axis."""
    item = 1 if cfg.kv_cache_dtype == "int8" else 2
    total = 0
    for spec in cfg.layer_specs:
        if spec.mixer != "attn":
            continue
        if spec.attn_kind == "mla":
            total += (cfg.kv_lora_rank + cfg.qk_rope_dim) * 2
        else:
            total += 2 * cfg.n_kv_heads * cfg.head_dim * item
            if cfg.kv_cache_dtype == "int8":
                total += 2 * cfg.n_kv_heads * 4
    return total


# ----------------------------------------------------------------------
# the discrete-event scheduler simulation
# ----------------------------------------------------------------------

class _SimReq:
    __slots__ = ("seq", "arrival", "prio", "p_len", "max_new",
                 "truncated", "ntok", "t_first", "t_done", "cache_rows",
                 "preemptions", "swap_rows", "swap_hp")

    def __init__(self, seq, arrival, prio, p_len, max_new, truncated):
        self.seq = seq
        self.arrival = arrival
        self.prio = prio
        self.p_len = p_len
        self.max_new = max_new
        self.truncated = truncated
        self.ntok = 0               # distinct tokens emitted so far
        self.t_first = None
        self.t_done = None
        self.cache_rows = 0
        self.preemptions = 0
        self.swap_rows = 0          # rows parked in the host tier
        self.swap_hp = 0            # host pages holding them


class _Sim:
    """Deterministic replay of ``Engine.step()`` with dispatch costs in
    place of forwards.  Every scheduling decision — admission order,
    backpressure, victim choice, top-up growth, wave lane rotation —
    follows the engine's code path for the same state."""

    MAX_ITERS = 200_000

    def __init__(self, knobs: Knobs, shape: WorkloadShape,
                 costs: StageCosts, cache_token_bytes: int,
                 acceptance: float | None):
        self.k = knobs
        self.shape = shape
        self.c = costs
        self.ctb = cache_token_bytes
        self.paged = knobs.paged
        self.incremental = knobs.alloc_mode == "incremental"
        self.wave = knobs.wave
        self.spec = knobs.spec_decode
        self.swap = knobs.swap_mode == "host"
        self._validate(knobs)
        self.ps = knobs.page_size
        self.num_pages = knobs.resolved_num_pages
        self.capacity = max(0, self.num_pages - 1)
        self.host_free = ((knobs.host_pages or 2 * self.capacity)
                          if self.swap else 0)
        self.wave_chunk = knobs.prefill_chunk or knobs.prefill_len
        self.wave_group = knobs.admit_group
        self.aging = knobs.priority_aging_s
        if self.spec:
            if acceptance is None:
                raise CapacityError(
                    "spec_decode prediction needs an acceptance rate "
                    "(calibrate one or pass an assumption)")
            self.alpha = float(min(max(acceptance, 0.0), 1.0))
        b = knobs.batch
        self.slots: list[_SimReq | None] = [None] * b
        self.active = [False] * b
        self.position = [0] * b
        self.remaining = [0] * b
        self.slot_len = [0] * b         # len(req.tokens) equivalent
        self.pending = [0] * b          # forced-replay tokens queued
        self.pages = [0] * b            # pages booked by the slot
        self.prefill_next = [-1] * b    # wave lane cursor
        self.spec_acc = [0.0] * b       # fractional spec emissions
        self.free = self.capacity
        self.queue: list[_SimReq] = []
        self.all_reqs: list[_SimReq] = []
        self.t = 0.0
        # counters mirroring engine.stats
        self.preempt = 0
        self.decode_chunks = 0
        self.prefill_waves = 0
        self.spec_rounds = 0
        self.spec_slot_rounds = 0
        self.spec_tokens = 0.0
        self.swap_out = 0
        self.swap_in = 0
        self.replay_steps_saved = 0
        self.stat_samples = 0
        self.stat_running = 0
        self.stat_in_use = 0
        self.infeasible = None

    def _validate(self, k: Knobs):
        if k.batch < 1:
            raise CapacityError(f"batch must be >= 1, got {k.batch}")
        if self.incremental and not self.paged:
            raise CapacityError("alloc_mode='incremental' requires "
                                "cache_mode='paged'")
        if self.paged:
            if k.page_size < 1:
                raise CapacityError(f"page_size must be >= 1, got "
                                    f"{k.page_size}")
            if k.max_len % k.page_size:
                raise CapacityError(f"max_len {k.max_len} must be a "
                                    f"multiple of page_size "
                                    f"{k.page_size}")
        if (self.wave or self.swap) and not self.paged:
            raise CapacityError("prefill_chunk/admit_group/swap_mode "
                                "require cache_mode='paged'")
        if self.spec and k.spec_k < 1:
            raise CapacityError(f"spec_k must be >= 1, got {k.spec_k}")
        if self.wave and not (k.prefill_chunk or k.prefill_len):
            raise CapacityError("admit_group > 1 with prefill_chunk=0 "
                                "needs prefill_len > 0")

    # --- submit-time validation (Engine.validate) ---------------------
    def submit_all(self):
        draw = self.shape.draw()
        eff = draw.eff_lens
        for i in range(self.shape.requests):
            p_len = int(eff[i])
            if p_len == 0 or p_len >= self.k.max_len:
                raise CapacityError(
                    f"prompt length {p_len} must be in [1, "
                    f"max_len={self.k.max_len})")
            if self.k.prefill_len and p_len > self.k.prefill_len:
                raise CapacityError(
                    f"prompt length {p_len} exceeds the slot budget "
                    f"prefill_len={self.k.prefill_len}")
            budget = self.k.max_len - p_len
            clamped = min(self.shape.new_tokens, budget)
            if self.paged:
                need = pages_needed(p_len + clamped - 1, self.ps)
                if need > self.capacity:
                    raise CapacityError(
                        f"request needs {need} pages but the pool "
                        f"capacity is {self.capacity}")
            req = _SimReq(
                seq=i, arrival=float(draw.arrivals[i]),
                prio=int(draw.prios[i]), p_len=p_len, max_new=clamped,
                truncated=self.shape.new_tokens > budget)
            self.queue.append(req)
            self.all_reqs.append(req)

    # --- queue / priority helpers (mirror _PriorityQueue) -------------
    def _eff(self, req: _SimReq, now: float) -> int:
        if self.aging <= 0:
            return req.prio
        return req.prio + int(max(0.0, now - req.arrival) / self.aging)

    def _peek(self, now: float) -> _SimReq | None:
        best, bkey = None, None
        for r in self.queue:
            if r.arrival > now:
                continue
            key = (-self._eff(r, now), r.arrival, r.seq)
            if bkey is None or key < bkey:
                best, bkey = r, key
        return best

    # --- paging helpers (mirror Engine._pages_for etc.) ---------------
    def _pages_for(self, req: _SimReq) -> int:
        return pages_needed(req.p_len + req.max_new - 1, self.ps)

    def _alloc_pages_for(self, req: _SimReq) -> int:
        if not self.incremental:
            return self._pages_for(req)
        if req.swap_rows:
            return pages_needed(req.swap_rows + 1, self.ps)
        rows = req.p_len + (1 if req.max_new > 1 else 0)
        return pages_needed(rows, self.ps)

    def _can_admit(self, req: _SimReq) -> bool:
        if not self.paged:
            return True
        return self.free >= self._alloc_pages_for(req)

    def _evictable_pages(self, now: float, cutoff: int) -> int:
        freed = sum(self.pages[s] for s, r in enumerate(self.slots)
                    if r is not None and self._eff(r, now) < cutoff)
        return self.free + freed

    def _pick_victim(self, now: float, below: int | None = None
                     ) -> int | None:
        best, bkey = None, None
        for s, r in enumerate(self.slots):
            if r is None:
                continue
            eff = self._eff(r, now)
            if below is not None and eff >= below:
                continue
            key = (eff, -r.arrival, -r.seq)
            if bkey is None or key < bkey:
                best, bkey = s, key
        return best

    def _evict(self, slot: int, now: float) -> None:
        req = self.slots[slot]
        if self.pending[slot]:
            # mid-replay: the unreplayed tail rides back on the request
            self.pending[slot] = 0
        if self.wave and self.prefill_next[slot] >= 0:
            self.prefill_next[slot] = -1      # restart prompt on resume
        elif self.swap and req.ntok and self.pages[slot]:
            rows = self.position[slot]
            hp = pages_needed(rows, self.ps)
            if self.host_free >= hp:
                self.host_free -= hp
                req.swap_rows = rows
                req.swap_hp = hp
                self.swap_out += 1
                self.t += self.c.swap_event_s
        self.free += self.pages[slot]
        self.pages[slot] = 0
        self.slots[slot] = None
        self.active[slot] = False
        req.preemptions += 1
        self.preempt += 1
        self.queue.append(req)

    # --- admission (mirror Engine._admit/_place) ----------------------
    def _admit(self, now: float) -> None:
        while True:
            free_slot = next((s for s in range(self.k.batch)
                              if self.slots[s] is None), None)
            cand = self._peek(now)
            if cand is None:
                return
            cutoff = self._eff(cand, now)
            if free_slot is None:
                if self.paged and (self._evictable_pages(now, cutoff)
                                   < self._alloc_pages_for(cand)):
                    return
                victim = self._pick_victim(now, below=cutoff)
                if victim is None:
                    return
                self._evict(victim, now)
                continue
            if not self._can_admit(cand):
                if (self._evictable_pages(now, cutoff)
                        < self._alloc_pages_for(cand)):
                    return
                while not self._can_admit(cand):
                    victim = self._pick_victim(now, below=cutoff)
                    if victim is None:
                        return
                    self._evict(victim, now)
            self.queue.remove(cand)
            self._place(free_slot, cand, now)

    def _book(self, slot: int, req: _SimReq) -> None:
        n = self._alloc_pages_for(req)
        self.free -= n
        self.pages[slot] = n
        req.cache_rows = max(req.cache_rows, n * self.ps)

    def _place(self, slot: int, req: _SimReq, now: float) -> None:
        if req.swap_rows:
            self._swap_in(slot, req)
            return
        if self.wave:
            self._book(slot, req)
            self.slots[slot] = req
            self.prefill_next[slot] = 0
            self.active[slot] = False
            return
        if self.paged:
            self._book(slot, req)
        else:
            req.cache_rows = self.k.max_len
        # one serialized monolithic prefill dispatch
        self.t += self.c.prefill_s + self.c.overhead_s
        resumed = req.ntok > 0
        if resumed:
            self.pending[slot] = req.ntok - 1
        else:
            self.pending[slot] = 0
            req.ntok = 1
            req.t_first = self.t
        self.slot_len[slot] = 1
        if req.max_new <= 1:
            self._finish(req, slot)
            return
        self.slots[slot] = req
        self.position[slot] = req.p_len
        self.active[slot] = True
        self.remaining[slot] = req.max_new - 1

    def _swap_in(self, slot: int, req: _SimReq) -> None:
        n = self._alloc_pages_for(req)
        self.free -= n
        self.pages[slot] = n
        req.cache_rows = max(req.cache_rows, n * self.ps)
        self.t += self.c.swap_event_s
        self.host_free += req.swap_hp
        committed = req.swap_rows - req.p_len + 1
        self.pending[slot] = req.ntok - committed
        self.slot_len[slot] = committed
        self.slots[slot] = req
        self.position[slot] = req.swap_rows
        self.active[slot] = True
        self.remaining[slot] = req.max_new - committed
        self.swap_in += 1
        self.replay_steps_saved += req.swap_rows - req.p_len
        req.swap_rows = 0
        req.swap_hp = 0

    def _finish(self, req: _SimReq, slot: int | None) -> None:
        req.t_done = self.t
        req.ntok = max(req.ntok, req.max_new)
        if slot is not None:
            self.pending[slot] = 0
            self.free += self.pages[slot]
            self.pages[slot] = 0
            self.slots[slot] = None
            self.active[slot] = False

    # --- wave prefill (mirror _run_wave/_wave_finish) -----------------
    def _run_wave(self) -> None:
        lanes = [s for s in range(self.k.batch)
                 if self.prefill_next[s] >= 0][:self.wave_group]
        self.prefill_waves += 1
        self.t += self.c.prefill_chunk_s + self.c.overhead_s
        for s in lanes:
            req = self.slots[s]
            st = self.prefill_next[s]
            n = min(self.wave_chunk, req.p_len - st)
            nxt = st + n
            if nxt >= req.p_len:
                # _wave_finish epilogue
                self.prefill_next[s] = -1
                resumed = req.ntok > 0
                if resumed:
                    self.pending[s] = req.ntok - 1
                else:
                    self.pending[s] = 0
                    req.ntok = 1
                    req.t_first = self.t
                self.slot_len[s] = 1
                if req.max_new <= 1:
                    self._finish(req, s)
                    continue
                self.position[s] = req.p_len
                self.active[s] = True
                self.remaining[s] = req.max_new - 1
            else:
                self.prefill_next[s] = nxt

    # --- decode (mirror _top_up/_run_chunk/_run_spec_round) -----------
    def _top_up(self, now: float) -> None:
        chunk_steps = (self.k.spec_k + 1 if self.spec
                       else self.k.decode_chunk)
        for slot in range(self.k.batch):
            req = self.slots[slot]
            if req is None or not self.active[slot]:
                continue
            steps = min(chunk_steps, self.remaining[slot])
            need = pages_needed(self.position[slot] + steps, self.ps)
            while need > self.pages[slot]:
                deficit = need - self.pages[slot]
                if self.free >= deficit:
                    self.free -= deficit
                    self.pages[slot] += deficit
                    req.cache_rows = max(req.cache_rows,
                                         self.pages[slot] * self.ps)
                    break
                victim = self._pick_victim(now)
                self._evict(victim, now)
                if victim == slot:
                    break

    def _sample_stats(self) -> None:
        self.stat_samples += 1
        self.stat_running += sum(r is not None for r in self.slots)
        if self.paged:
            self.stat_in_use += self.capacity - self.free

    def _emit(self, slot: int, n: int) -> None:
        """Commit ``n`` tokens to the slot's stream: replays first (no
        new emissions), fresh tokens extend the request."""
        req = self.slots[slot]
        self.pending[slot] -= min(self.pending[slot], n)
        self.slot_len[slot] += n
        self.remaining[slot] -= n
        if self.slot_len[slot] > req.ntok:
            req.ntok = self.slot_len[slot]
        if self.slot_len[slot] >= req.max_new:
            self._finish(req, slot)

    def _run_chunk(self, now: float) -> None:
        if self.incremental:
            self._top_up(now)
            if not any(self.active):
                return
        self._sample_stats()
        self.decode_chunks += 1
        self.t += self.c.decode_chunk_s + self.c.overhead_s
        for slot in range(self.k.batch):
            if self.slots[slot] is None or not self.active[slot]:
                continue
            n = min(self.k.decode_chunk, self.remaining[slot])
            self.position[slot] += n
            self._emit(slot, n)

    def _run_spec_round(self, now: float) -> None:
        if self.incremental:
            self._top_up(now)
            if not any(self.active):
                return
        self._sample_stats()
        self.spec_rounds += 1
        k = self.k.spec_k
        self.t += self.c.draft_s + self.c.verify_s + self.c.overhead_s
        for slot in range(self.k.batch):
            if self.slots[slot] is None or not self.active[slot]:
                continue
            self.spec_slot_rounds += 1
            r = self.remaining[slot]
            p = self.pending[slot]
            if p > k:
                # every draft position replays committed history and the
                # bonus is withheld (more_forced)
                e = min(k, r)
            else:
                # forced prefix force-accepts, fresh tail is geometric;
                # the fractional expectation accumulates so the long-run
                # token count is exact
                e_f = p + expected_tokens_per_round(self.alpha, k - p) \
                    if p < k else float(k + 1)
                self.spec_acc[slot] += e_f
                e = int(self.spec_acc[slot])
                e = max(1, min(e, k + 1, r))
                self.spec_acc[slot] -= e
            self.spec_tokens += e
            self.position[slot] += e
            self._emit(slot, e)
            # _spec_rollback: truncate the tail pages the top-up booked
            # past the accepted rows
            if (self.incremental and self.slots[slot] is not None):
                keep = pages_needed(self.position[slot], self.ps)
                if keep < self.pages[slot]:
                    self.free += self.pages[slot] - keep
                    self.pages[slot] = keep

    # --- the loop (mirror Engine.step/run) ----------------------------
    def run(self) -> None:
        self.submit_all()
        for _ in range(self.MAX_ITERS):
            if not self.queue and all(r is None for r in self.slots):
                return
            now = self.t
            self._admit(now)
            prefilling = self.wave and any(p >= 0
                                           for p in self.prefill_next)
            if not any(self.active) and not prefilling:
                if not self.queue:
                    return
                nxt = min(r.arrival for r in self.queue)
                if nxt > self.t:
                    self.t = nxt
                    continue
                self.infeasible = (
                    f"scheduler stall: {len(self.queue)} arrived "
                    f"request(s) cannot be admitted with all slots "
                    f"idle ({self.capacity - self.free} pages in use, "
                    f"{self.free} free of {self.capacity})")
                return
            now = self.t
            if prefilling:
                self._run_wave()
            if any(self.active):
                if self.spec:
                    self._run_spec_round(now)
                else:
                    self._run_chunk(now)
        self.infeasible = (f"no convergence after {self.MAX_ITERS} "
                           f"scheduler iterations")

    # --- report -------------------------------------------------------
    def report(self) -> dict:
        done = [r for r in self.all_reqs if r.t_done is not None]
        out = {
            "feasible": self.infeasible is None,
            "infeasible_reason": self.infeasible,
            "requests": self.shape.requests,
            "tokens": int(sum(r.ntok for r in self.all_reqs)),
            "wall_s": self.t,
            "pool_pages": self.num_pages,
            "preemptions": self.preempt,
            "preemption_risk": self.preempt / max(1, len(self.all_reqs)),
            "decode_chunks": self.decode_chunks,
            "prefill_waves": self.prefill_waves,
            "spec_rounds": self.spec_rounds,
            "tokens_per_step": (self.spec_tokens
                                / max(1, self.spec_slot_rounds)),
            "swap_out": self.swap_out,
            "swap_in": self.swap_in,
            "replay_steps_saved": self.replay_steps_saved,
            "concurrency": self.stat_running / max(1, self.stat_samples),
            "occupancy": (self.stat_in_use
                          / max(1, self.stat_samples * self.capacity)
                          if self.paged else 0.0),
            "truncated": int(sum(r.truncated for r in self.all_reqs)),
        }
        if done and self.infeasible is None:
            lat = np.asarray([r.t_done - r.arrival for r in done])
            ttft = np.asarray([r.t_first - r.arrival for r in done
                               if r.t_first is not None])
            rows = np.asarray([float(r.cache_rows) for r in done])
            wall = max(self.t, 1e-12)
            out.update({
                "tok_per_s": out["tokens"] / wall,
                "req_p50_ms": float(np.percentile(lat, 50)) * 1e3,
                "req_p99_ms": float(np.percentile(lat, 99)) * 1e3,
                "ttft_p50_ms": float(np.percentile(ttft, 50)) * 1e3,
                "ttft_p99_ms": float(np.percentile(ttft, 99)) * 1e3,
                "cache_kb_per_req": float(rows.mean()) * self.ctb
                / 1024.0,
            })
        return out


def predict(knobs: Knobs | object, shape: WorkloadShape,
            costs: StageCosts, *, cache_token_bytes: int = 0,
            acceptance: float | None = None) -> dict:
    """Predict serving capacity for one knob/workload pair.

    ``knobs`` may be a :class:`Knobs` or a real ``ServeConfig``.
    Returns the prediction dict (see ``docs/capacity.md`` for metric
    semantics); raises :class:`CapacityError` for combinations the
    engine itself would reject, and reports scheduler-stall
    infeasibility via ``feasible=False`` instead of raising (the
    autotuner filters on it)."""
    if not isinstance(knobs, Knobs):
        knobs = Knobs.from_serve_config(knobs)
    sim = _Sim(knobs, shape, costs, cache_token_bytes, acceptance)
    sim.run()
    return sim.report()
