"""Partition rules: DP/FSDP × TP × EP (+ SP for caches) for every arch.

One rule table covers the whole zoo because parameters are named
consistently (models/*).  Conventions on the production mesh
(("pod",) "data", "model"):

* **FSDP axes** = ("pod", "data") when multi-pod else ("data",) — weight
  shards gather on use (GSPMD), gradients reduce-scatter back.
* **TP axis** = "model" — megatron-style column/row parallel pairs; MoE
  experts (EP) and the vocab dimension also live on "model".
* **Sequence/cache sharding**: decode caches put batch on the DP axes
  and KV-heads on "model" when divisible, else the sequence axis goes to
  "model"; the batch-1 ``long_500k`` cells shard sequence over the DP
  axes instead (there is no batch to split).

Everything returns ``PartitionSpec`` trees matching the exact pytrees
the models produce, including the scan-stacked block dimension.
"""

from __future__ import annotations

from typing import Any

import jax
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig

__all__ = ["param_specs", "opt_state_specs", "batch_specs", "cache_specs",
           "page_table_spec", "fsdp_axes", "TP_AXIS", "maybe_shard"]

TP_AXIS = "model"


# Ambient mesh for in-model sharding constraints.  Set explicitly by the
# launch layer (dryrun/trainer) — deterministic, no reliance on jax's
# evolving context-mesh APIs; unit tests leave it unset and every
# constraint is a no-op.
import contextlib

_AMBIENT_MESH = None


@contextlib.contextmanager
def ambient_mesh(mesh):
    global _AMBIENT_MESH
    prev, _AMBIENT_MESH = _AMBIENT_MESH, mesh
    try:
        yield mesh
    finally:
        _AMBIENT_MESH = prev


def maybe_shard(x, kind: str, kv_heads: int | None = None):
    """Apply a sharding constraint when an ambient mesh is installed;
    no-op otherwise (unit tests, single-device runs).

    kinds: "activation" (B,S,D)→(dp,∅,∅); "logits" (B,S,V)→(dp,∅,tp) —
    the vocab-sharded softmax constraint that keeps the CE loss from
    materializing replicated (B,S,V) temporaries.
    """
    mesh = _AMBIENT_MESH
    if mesh is None or TP_AXIS not in mesh.axis_names:
        return x
    dp = tuple(a for a in mesh.axis_names if a != TP_AXIS)
    if kind == "activation":
        spec = P(dp, *([None] * (x.ndim - 1)))
    elif kind == "logits":
        spec = P(dp, *([None] * (x.ndim - 2)), TP_AXIS)
    elif kind == "heads":
        # (B, S, H, D): heads on TP when the *KV* head count divides the
        # axis (q and k must agree or the grouped einsum reshards),
        # otherwise explicitly replicated.  Without this, GSPMD resolves
        # indivisible head counts by sharding the head_dim *contraction*
        # of QK^T and all-reducing the probs — measured 1.9 TB/device on
        # llama4 prefill_32k (EXPERIMENTS.md §Perf).
        decider = kv_heads if kv_heads is not None else x.shape[2]
        head_ax = TP_AXIS if decider % mesh.shape[TP_AXIS] == 0 else None
        spec = P(dp, None, head_ax, None)
    else:
        raise KeyError(kind)
    spec = _fit_spec(spec, x.shape, mesh)
    from jax.sharding import NamedSharding
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


def fsdp_axes(mesh) -> tuple:
    return ("pod", "data") if "pod" in mesh.axis_names else ("data",)


# ---------------------------------------------------------------------------
# Parameter rules
# ---------------------------------------------------------------------------

# (parent-key, leaf-key) → spec builder.  COL = (fsdp, tp); ROW = (tp, fsdp).
_COL_PARENTS = {"wq", "wk", "wv", "gate", "up", "wq_b", "wkv_b", "in_proj",
                "lm_head"}
_ROW_PARENTS = {"wo", "down", "out_proj"}
_PLAIN_PARENTS = {"wq_a", "wkv_a"}       # low-rank downs: FSDP only


def _param_rule(path: tuple[str, ...], leaf, fsdp: tuple) -> P:
    keys = [k for k in path]
    leafk = keys[-1]
    parent = keys[-2] if len(keys) >= 2 else ""
    stacked = "blocks" in keys          # scan-stacked: leading block axis

    def wrap(*spec):
        return P(*( (None,) + spec if stacked else spec ))

    if leafk == "emb":
        # (V, D): vocab on TP, D replicated.  Sharding D on the FSDP/data
        # axes looks attractive but makes the embedding-grad contraction
        # doubly-data-sharded (batch on data × D on data) — XLA resolves
        # that by all-gathering the *global batch* of f32 logits, which
        # is catastrophic (measured: 64 GiB/device temps on gemma3).
        return wrap(TP_AXIS, None)
    if leafk == "w":
        if parent in _COL_PARENTS:
            return wrap(fsdp, TP_AXIS)
        if parent in _ROW_PARENTS:
            return wrap(TP_AXIS, fsdp)
        if parent in _PLAIN_PARENTS:
            return wrap(fsdp, None)
        return wrap(fsdp, None)          # unknown linear: FSDP the in-dim
    if leafk in ("w_gate", "w_up"):
        # (E, D, F): EP on the expert axis × FSDP on the inner dim for
        # *storage* (97% of deepseek's params are experts — EP-only
        # storage is 81 GB/device).  The shard_map MoE path requests
        # P(TP, ∅, ∅); pjit inserts the per-layer FSDP gather at the
        # shard_map boundary (one layer's experts live at a time).
        return wrap(TP_AXIS, fsdp, None)
    if leafk == "w_down":                # (E, F, D)
        return wrap(TP_AXIS, None, fsdp)
    if leafk == "router":                # small, replicate
        return wrap(None, None)
    if leafk == "conv_w":                # (W, C): channels on TP
        return wrap(None, TP_AXIS)
    if leafk == "conv_b":
        return wrap(TP_AXIS)
    # norms, A_log, D, dt_bias, scalars: replicated
    return wrap(*([None] * (leaf.ndim - (1 if stacked else 0))))


def _tree_map_with_str_path(fn, tree):
    def keyify(entry) -> str:
        if hasattr(entry, "key"):
            return str(entry.key)
        if hasattr(entry, "idx"):
            return str(entry.idx)
        return str(entry)

    return jax.tree_util.tree_map_with_path(
        lambda path, leaf: fn(tuple(keyify(p) for p in path), leaf), tree)


def _fit_spec(spec: P, shape, mesh) -> P:
    """Drop sharded axes that do not divide the dimension they shard
    (odd vocab sizes, batch=1 long-context cells, tiny head counts).
    GSPMD requires exact divisibility; replication is always valid."""
    out = []
    for i, dim in enumerate(shape):
        axes = spec[i] if i < len(spec) else None
        if axes is None:
            out.append(None)
            continue
        ax_tuple = axes if isinstance(axes, tuple) else (axes,)
        size = 1
        for a in ax_tuple:
            size *= mesh.shape[a]
        out.append(axes if dim % size == 0 else None)
    return P(*out)


def param_specs(params: Any, mesh) -> Any:
    fsdp = fsdp_axes(mesh)
    return _tree_map_with_str_path(
        lambda path, leaf: _fit_spec(_param_rule(path, leaf, fsdp),
                                     leaf.shape, mesh), params)


def opt_state_specs(opt_state: Any, params_spec: Any) -> Any:
    """Moments mirror parameter sharding (ZeRO falls out of the FSDP axis
    already in the param specs); int8-moment scales are replicated."""
    def moment_spec(pspec, leaf_or_subtree):
        if isinstance(leaf_or_subtree, dict) and "q" in leaf_or_subtree:
            return {"q": pspec, "scale": P()}
        return pspec

    mu = jax.tree_util.tree_map(
        moment_spec, params_spec, opt_state["mu"],
        is_leaf=lambda x: isinstance(x, dict) and "q" in x)
    nu = jax.tree_util.tree_map(
        moment_spec, params_spec, opt_state["nu"],
        is_leaf=lambda x: isinstance(x, dict) and "q" in x)
    return {"step": P(), "mu": mu, "nu": nu}


# ---------------------------------------------------------------------------
# Batch / cache rules
# ---------------------------------------------------------------------------

def batch_specs(cfg: ModelConfig, mesh) -> dict:
    dp = fsdp_axes(mesh)                 # batch over pod+data
    spec = {"tokens": P(dp, None), "labels": P(dp, None)}
    if cfg.family == "vlm":
        spec["patch_embeds"] = P(dp, None, None)
    if cfg.is_encdec:
        spec["frames"] = P(dp, None, None)
    return spec


def _kv_spec(cfg: ModelConfig, mesh, batch: int, stacked: bool,
             seq_to_dp: bool) -> P:
    """(B, S, KVH, D) spec."""
    tp_size = mesh.shape[TP_AXIS]
    dp = fsdp_axes(mesh)
    if seq_to_dp:                        # batch=1 long-context cells
        head_ax = TP_AXIS if cfg.n_kv_heads % tp_size == 0 else None
        spec = (None, dp, head_ax, None)
    elif cfg.n_kv_heads % tp_size == 0:
        spec = (dp, None, TP_AXIS, None)
    else:                                # few KV heads: sequence on TP
        spec = (dp, TP_AXIS, None, None)
    return P(*((None,) + spec if stacked else spec))


def _mla_spec(mesh, stacked: bool, seq_to_dp: bool) -> P:
    dp = fsdp_axes(mesh)
    spec = (None, dp, None) if seq_to_dp else (dp, None, None)
    return P(*((None,) + spec if stacked else spec))


def _paged_kv_spec(cfg: ModelConfig, mesh, stacked: bool) -> P:
    """(num_pages, page_size, KVH, D-or-1) pool spec.

    KV heads go on the TP axis when they divide it — the attention
    math is head-parallel, so each shard holds whole heads and the
    gather/scatter through the page table stays local.  When heads do
    not divide (GQA models reduced to 1 KV head), fall back to the
    in-page sequence axis; the page-id axis itself is NEVER sharded —
    page ids are data, and splitting the pool by page id would turn
    every host-side allocation decision into a placement decision."""
    tp_size = mesh.shape[TP_AXIS]
    if cfg.n_kv_heads % tp_size == 0:
        spec = (None, None, TP_AXIS, None)
    else:
        spec = (None, TP_AXIS, None, None)
    return P(*((None,) + spec if stacked else spec))


def _paged_mla_spec(stacked: bool) -> P:
    """(num_pages, page_size, rank) latent pool: no head axis exists, so
    the in-page sequence axis is the only shardable one."""
    spec = (None, TP_AXIS, None)
    return P(*((None,) + spec if stacked else spec))


def page_table_spec(mesh) -> P:
    """The (batch, max_pages) page table stays host-authored and fully
    replicated: every shard walks the same logical table (the pool's
    sharded axis is heads/rows *within* a page, never the page id)."""
    del mesh
    return P(None, None)


def _mamba_cache_spec(mesh, leafk: str, stacked: bool) -> P:
    dp = fsdp_axes(mesh)
    if leafk == "conv":                  # (B, W-1, C)
        spec = (dp, None, TP_AXIS)
    else:                                # ssm state (B, H, N, P)
        spec = (dp, TP_AXIS, None, None)
    return P(*((None,) + spec if stacked else spec))


def cache_specs(cfg: ModelConfig, caches: Any, mesh, *,
                batch: int) -> Any:
    seq_to_dp = batch == 1
    paged = cfg.cache_mode == "paged"

    def rule(path, leaf):
        keys = [k for k in path]
        stacked = "blocks" in keys
        leafk = keys[-1]
        if leafk in ("k", "v", "k_scale", "v_scale"):
            # paged pools drop the batch axis — (num_pages, page_size,
            # KVH, D) with int8 scale pools at D=1 — and get their own
            # head-or-sequence rule; mamba state stays per-slot (B, ...)
            # even in paged mode, so only the KV/MLA leaves switch
            spec = (_paged_kv_spec(cfg, mesh, stacked) if paged
                    else _kv_spec(cfg, mesh, batch, stacked, seq_to_dp))
        elif leafk in ("c_kv", "k_rope"):
            spec = (_paged_mla_spec(stacked) if paged
                    else _mla_spec(mesh, stacked, seq_to_dp))
        elif leafk in ("conv", "ssm"):
            spec = _mamba_cache_spec(mesh, leafk, stacked)
        else:
            spec = P(*([None] * leaf.ndim))
        return _fit_spec(spec, leaf.shape, mesh)

    return _tree_map_with_str_path(rule, caches)
