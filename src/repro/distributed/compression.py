"""Gradient compression: int8 + per-tensor scale with error feedback.

The paper's thesis — 8-bit integers with per-structure scales preserve
what matters — applied to the cross-pod gradient hop.  Intra-pod
reduce-scatter stays full precision (ICI is fast); the inter-pod
all-reduce moves int8 (4× fewer bytes on the slow axis).

Error feedback: the quantization residual is carried to the next step
(``state``), so compression noise is unbiased over time rather than per
step — the standard convergence-preserving trick.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["compress_tree_int8", "decompress_tree_int8",
           "ef_compress", "compressed_bytes"]


def compress_tree_int8(grads):
    """Quantize every leaf to (int8 values, f32 scale).  Returns
    (dequantized grads, compressed pytree).  The dequantized result is
    what the optimizer consumes after the wire transfer."""
    def comp(g):
        g = g.astype(jnp.float32)
        scale = jnp.maximum(jnp.max(jnp.abs(g)), 1e-12) / 127.0
        q = jnp.clip(jnp.round(g / scale), -127, 127).astype(jnp.int8)
        return q, scale

    flat, tdef = jax.tree_util.tree_flatten(grads)
    qs = [comp(g) for g in flat]
    deq = tdef.unflatten([q.astype(jnp.float32) * s for q, s in qs])
    packed = tdef.unflatten([{"q": q, "scale": s} for q, s in qs])
    return deq, packed


def decompress_tree_int8(packed):
    return jax.tree_util.tree_map(
        lambda leaf: leaf["q"].astype(jnp.float32) * leaf["scale"],
        packed, is_leaf=lambda x: isinstance(x, dict) and "q" in x)


def ef_compress(grads, residual):
    """Error-feedback compression: compress (grad + residual), carry the
    new residual.  ``residual=None`` initializes to zero."""
    if residual is None:
        residual = jax.tree_util.tree_map(
            lambda g: jnp.zeros(g.shape, jnp.float32), grads)
    corrected = jax.tree_util.tree_map(
        lambda g, r: g.astype(jnp.float32) + r, grads, residual)
    deq, packed = compress_tree_int8(corrected)
    new_residual = jax.tree_util.tree_map(lambda c, d: c - d, corrected, deq)
    return deq, packed, new_residual


def compressed_bytes(grads) -> tuple[int, int]:
    """(raw_bytes, compressed_bytes) for the wire-savings report."""
    raw = sum(g.size * g.dtype.itemsize
              for g in jax.tree_util.tree_leaves(grads))
    comp = sum(g.size * 1 + 4 for g in jax.tree_util.tree_leaves(grads))
    return raw, comp
