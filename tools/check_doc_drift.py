"""Doc-drift check: serving docs must name every serving knob.

Asserts that every ``--flag`` registered by ``repro.launch.serve``'s
argparse parser and every field of ``repro.serve.ServeConfig`` appears
(verbatim, backtick-quoted or not) in ``docs/serving.md``.  Wired into
CI so the reference doc cannot silently rot when a knob is added — the
check fails the build until the doc names it.

Parses source with ``ast`` (no imports of the package, so it runs
before dependencies are installed):

    python tools/check_doc_drift.py [--repo PATH]

Exit status 0 when the doc covers everything, 1 with a listing of the
missing names otherwise.
"""

from __future__ import annotations

import argparse
import ast
import pathlib
import sys

SERVE_LAUNCHER = "src/repro/launch/serve.py"
SERVE_CONFIG = "src/repro/serve/engine.py"
SERVING_DOC = "docs/serving.md"


def argparse_flags(path: pathlib.Path) -> list[str]:
    """Every ``--flag`` string literal passed to ``add_argument``."""
    tree = ast.parse(path.read_text())
    flags = []
    for node in ast.walk(tree):
        if (isinstance(node, ast.Call)
                and isinstance(node.func, ast.Attribute)
                and node.func.attr == "add_argument"):
            for arg in node.args:
                if (isinstance(arg, ast.Constant)
                        and isinstance(arg.value, str)
                        and arg.value.startswith("--")):
                    flags.append(arg.value)
    return flags


def dataclass_fields(path: pathlib.Path, cls_name: str) -> list[str]:
    """Annotated field names of class ``cls_name``."""
    tree = ast.parse(path.read_text())
    for node in ast.walk(tree):
        if isinstance(node, ast.ClassDef) and node.name == cls_name:
            return [stmt.target.id for stmt in node.body
                    if isinstance(stmt, ast.AnnAssign)
                    and isinstance(stmt.target, ast.Name)]
    raise SystemExit(f"class {cls_name} not found in {path}")


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--repo", default=None,
                    help="repo root (default: this script's parent's "
                         "parent)")
    args = ap.parse_args(argv)
    root = (pathlib.Path(args.repo) if args.repo
            else pathlib.Path(__file__).resolve().parent.parent)

    doc = (root / SERVING_DOC).read_text()
    missing = []
    for flag in argparse_flags(root / SERVE_LAUNCHER):
        if flag not in doc:
            missing.append(f"launcher flag {flag}")
    for field in dataclass_fields(root / SERVE_CONFIG, "ServeConfig"):
        if f"`{field}`" not in doc:
            missing.append(f"ServeConfig field `{field}`")

    if missing:
        print(f"{SERVING_DOC} is missing {len(missing)} serving "
              f"knob(s):", file=sys.stderr)
        for m in missing:
            print(f"  - {m}", file=sys.stderr)
        print("document every knob in the ServeConfig reference table / "
              "launcher-flags section of docs/serving.md",
              file=sys.stderr)
        return 1
    print(f"doc-drift check OK: every launcher flag and ServeConfig "
          f"field appears in {SERVING_DOC}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
