"""Speculative-decoding planning report: what acceptance buys.

Self-speculative decoding (``ServeConfig.spec_decode``) drafts
``spec_k`` tokens with the quantized program and verifies them in one
dense multi-token forward.  Its payoff is governed by a single scalar —
the per-draft acceptance rate ``alpha`` — through the geometric-run
model that lives in ``repro.capacity.spec_math`` (this file re-exports
it; the serving-capacity predictor builds on the same functions, so the
table below and capacity predictions cannot drift apart).  The report
tabulates expected tokens/round and speedup across acceptance rates
and ``k``, inverts measured ``tokens_per_step`` back to an implied
acceptance, and — given a ``BENCH_serve.json`` with spec rows — checks
the live engine against the model: the measured ``acceptance_rate``
must sit within 10 points of the value implied by its own
``tokens_per_step`` (they are coupled through the geometric model; a
larger gap means the engine is emitting tokens the model can't
explain, i.e. an accounting bug).  ``tests/test_capacity.py`` runs the
same check in tier-1 against the committed bench.

    PYTHONPATH=src python tools/spec_report.py
    PYTHONPATH=src python tools/spec_report.py \
        --bench benchmarks/BENCH_serve.json
    PYTHONPATH=src python tools/spec_report.py --k 4 --alpha 0.8
"""

from __future__ import annotations

import argparse
import json
import os
import sys

sys.path.insert(0, os.path.join(
    os.path.dirname(os.path.dirname(os.path.abspath(__file__))), "src"))

from repro.capacity.spec_math import (  # noqa: E402  (re-exported API)
    acceptance_from_tokens_per_step,
    expected_tokens_per_round,
    speedup,
)

__all__ = ["expected_tokens_per_round", "speedup",
           "acceptance_from_tokens_per_step", "validate_bench"]


def report_lines(k_values=(2, 4, 8), alphas=(0.5, 0.6, 0.7, 0.8, 0.9,
                                             0.95, 0.99),
                 c_draft: float = 0.5, c_verify: float = 1.0):
    """The planning table: expected tokens/round and speedup per
    (acceptance, k)."""
    yield (f"# speculative-decoding model (c_draft={c_draft}, "
           f"c_verify={c_verify}; costs relative to one dense decode "
           f"forward)")
    yield "alpha," + ",".join(f"tok/step_k{k},speedup_k{k}"
                              for k in k_values)
    for a in alphas:
        cells = []
        for k in k_values:
            cells.append(f"{expected_tokens_per_round(a, k):.2f}")
            cells.append(f"{speedup(a, k, c_draft, c_verify):.2f}")
        yield f"{a}," + ",".join(cells)


def prompt_length_lines(k: int, alpha: float, new_tokens=(16, 64, 256),
                        prompt_lens=(16, 128, 1024),
                        c_draft: float = 0.5, c_verify: float = 1.0):
    """Per-prompt-length view: the draft/verify split is independent of
    prompt length (decode reads the whole cache either way), but the
    *round count* a request needs is new_tokens / E[tokens/round] — the
    dispatch-savings column is what a long generation banks."""
    e = expected_tokens_per_round(alpha, k)
    s = speedup(alpha, k, c_draft, c_verify)
    yield (f"# per-request round counts at alpha={alpha}, k={k} "
           f"(E[tok/round]={e:.2f}, speedup={s:.2f}x)")
    yield "prompt_len,new_tokens,dense_forwards,spec_rounds,forwards_saved"
    for p in prompt_lens:
        for n in new_tokens:
            rounds = max(1.0, n / e)
            # each round = 1 verify forward (+ k cheap draft steps)
            yield (f"{p},{n},{n},{rounds:.1f},"
                   f"{n - rounds:.1f}")


def validate_bench(path: str, tolerance: float = 0.10):
    """Check BENCH_serve.json spec rows against the geometric model:
    measured acceptance_rate vs the acceptance implied by the measured
    tokens_per_step must agree within ``tolerance`` (10 points by
    default).  Returns (lines, ok)."""
    with open(path) as f:
        payload = json.load(f)
    rows = [r for r in payload.get("results", [])
            if r.get("spec") == "on"]
    lines = [f"# validating {len(rows)} spec row(s) from {path} "
             f"(tolerance {tolerance:.0%})"]
    if not rows:
        lines.append("# no spec rows found — run benchmarks/"
                     "serve_bench.py first")
        return lines, False
    ok = True
    lines.append("workload,tokens_per_step,measured_acceptance,"
                 "implied_acceptance,delta,verdict")
    for r in rows:
        k = int(r.get("spec_k", 4))
        tps = float(r["tokens_per_step"])
        meas = float(r["acceptance_rate"])
        implied = acceptance_from_tokens_per_step(
            min(max(tps, 1.0), k + 1), k)
        delta = abs(meas - implied)
        good = delta <= tolerance
        ok = ok and good
        lines.append(f"{r['workload']},{tps},{meas},{implied:.3f},"
                     f"{delta:.3f},{'OK' if good else 'DRIFT'}")
    return lines, ok


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--k", type=int, default=4,
                    help="draft length for the per-prompt-length table")
    ap.add_argument("--alpha", type=float, default=0.8,
                    help="acceptance rate for the per-prompt-length "
                         "table")
    ap.add_argument("--c-draft", type=float, default=0.5,
                    help="draft forward cost relative to a dense decode "
                         "forward")
    ap.add_argument("--c-verify", type=float, default=1.0,
                    help="(k+1)-token verify forward cost relative to a "
                         "dense decode forward")
    ap.add_argument("--bench", default=None,
                    help="BENCH_serve.json to validate spec rows "
                         "against the model (exit 1 on drift)")
    args = ap.parse_args(argv)
    for line in report_lines(c_draft=args.c_draft, c_verify=args.c_verify):
        print(line)
    print()
    for line in prompt_length_lines(args.k, args.alpha,
                                    c_draft=args.c_draft,
                                    c_verify=args.c_verify):
        print(line)
    if args.bench:
        print()
        lines, ok = validate_bench(args.bench)
        for line in lines:
            print(line)
        return 0 if ok else 1
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
