#!/usr/bin/env python
"""Static-analysis gate CLI — see docs/staticcheck.md.

Usage:
    python tools/staticcheck.py                 # full gate (AST + jaxpr grid)
    python tools/staticcheck.py --ast-only      # fast lint, no engine builds
    python tools/staticcheck.py --report out.json
    python tools/staticcheck.py --update-baseline   # rewrite suppressions

Exit status: 0 = clean (every finding suppressed, no stale
suppressions); 1 = unsuppressed findings or stale baseline entries.
"""

from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path

REPO_ROOT = Path(__file__).resolve().parent.parent
SRC = REPO_ROOT / "src"
if str(SRC) not in sys.path:
    sys.path.insert(0, str(SRC))

DEFAULT_BASELINE = REPO_ROOT / "tools" / "staticcheck_baseline.json"


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--root", default=str(SRC / "repro"),
                    help="tree to lint (default: src/repro)")
    ap.add_argument("--baseline", default=str(DEFAULT_BASELINE),
                    help="suppression baseline JSON")
    ap.add_argument("--ast-only", action="store_true",
                    help="skip the jaxpr grid (no engine builds)")
    ap.add_argument("--report", metavar="PATH",
                    help="write a JSON summary (rules run, findings, "
                         "per-stage flop/byte table)")
    ap.add_argument("--update-baseline", action="store_true",
                    help="rewrite the baseline to suppress every "
                         "current finding (review the diff!)")
    args = ap.parse_args(argv)

    from repro.staticcheck import run_gate
    from repro.staticcheck.findings import load_baseline, apply_baseline

    findings, report = run_gate(args.root, REPO_ROOT,
                                ast_only=args.ast_only)

    if args.update_baseline:
        data = {"version": 1, "suppressions": [
            {"key": f.key, "reason": "TODO: justify or fix"}
            for f in sorted(findings, key=lambda f: f.key)]}
        Path(args.baseline).write_text(json.dumps(data, indent=2) + "\n")
        print(f"baseline rewritten with {len(findings)} suppressions: "
              f"{args.baseline}")
        return 0

    baseline = load_baseline(args.baseline)
    unsuppressed, suppressed, stale = apply_baseline(findings, baseline)
    report["suppressed"] = [f.to_dict() for f in suppressed]
    report["stale_suppressions"] = stale
    report["findings"] = [f.to_dict() for f in unsuppressed]

    if args.report:
        Path(args.report).write_text(json.dumps(report, indent=2,
                                                default=str) + "\n")

    for f in unsuppressed:
        print(f.render())
    for key in stale:
        print(f"STALE suppression (no longer fires — remove it): {key}")

    n_cost = len(report.get("stage_costs", []))
    status = "FAIL" if (unsuppressed or stale) else "OK"
    print(f"staticcheck {status}: {len(unsuppressed)} finding(s), "
          f"{len(suppressed)} suppressed, {len(stale)} stale "
          f"suppression(s), {n_cost} stage lowering(s) analysed")
    return 1 if (unsuppressed or stale) else 0


if __name__ == "__main__":
    raise SystemExit(main())
