"""Serving-knob autotuner over the analytic capacity model.

Enumerates a structured ServeConfig knob grid (page_size, num_pages,
decode_chunk, prefill_chunk, admit_group, spec_k, alloc/cache/swap
modes), predicts each cell with ``repro.capacity`` — **without running
the model**: per-stage costs come from the static MACs/bytes model
bridged through the roofline constants — and ranks the feasible cells
for a stated objective:

* ``max-tok-s``  — highest predicted tok/s, optionally subject to a
  p99 TTFT SLO (``--ttft-slo-ms``);
* ``min-pages``  — smallest page pool that serves the workload with
  zero predicted preemptions (cheapest HBM reservation that never
  evicts), tie-broken by predicted tok/s.

Emits the prediction table plus the winning knob set as a ServeConfig
kwargs dict.  ``--validate BENCH.json`` switches to the
model-vs-measured mode: replay every committed bench row's prediction
from its embedded calibration blob (``repro.capacity.validate``) and
exit 1 if any gated row falls outside the documented tolerance —
the same check ``tests/test_capacity.py`` runs in tier-1.

    PYTHONPATH=src python tools/autotune.py --objective max-tok-s \
        --ttft-slo-ms 50
    PYTHONPATH=src python tools/autotune.py --objective min-pages
    PYTHONPATH=src python tools/autotune.py \
        --validate benchmarks/BENCH_serve.json
"""

from __future__ import annotations

import argparse
import json
import os
import sys

sys.path.insert(0, os.path.join(
    os.path.dirname(os.path.dirname(os.path.abspath(__file__))), "src"))

from repro.capacity import WorkloadShape  # noqa: E402
from repro.capacity.tune import (knob_grid, search,  # noqa: E402
                                 table_lines)


def run_validate(path: str) -> int:
    from repro.capacity.validate import TOLERANCE, load_bench, \
        validate_rows
    ok, checks = validate_rows(load_bench(path))
    tol = ", ".join(f"{m}: {rel:.0%} rel / {floor:g} abs floor"
                    for m, (rel, floor) in TOLERANCE.items())
    print(f"# replaying {len(checks)} prediction(s) from {path} "
          f"({tol})")
    print("workload,quant,backend,cache,alloc,spec,tail,gated,"
          "tok_per_s,pred_tok_per_s,err%,ttft_p50,pred_ttft_p50,"
          "err%,verdict")
    for c in checks:
        t, f = c["metrics"]["tok_per_s"], c["metrics"]["ttft_p50_ms"]
        verdict = ("OK" if c["within"]
                   else ("DRIFT" if c["gated"] else "drift (ungated)"))
        print(f"{c['workload']},{c['quant']},{c['backend']},"
              f"{c['cache']},{c['alloc']},{c['spec']},{c['tail']},"
              f"{'yes' if c['gated'] else '-'},"
              f"{t['measured']:.0f},{t['predicted']:.0f},"
              f"{t['err_pct']},{f['measured']:.1f},"
              f"{f['predicted']:.1f},{f['err_pct']},{verdict}")
    n_gated = sum(c["gated"] for c in checks)
    print(f"# {n_gated} gated row(s); "
          f"{'all within tolerance' if ok else 'VALIDATION FAILED'}")
    return 0 if ok else 1


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="yi-6b")
    ap.add_argument("--full-config", action="store_true",
                    help="use the full ModelConfig (default: reduced(), "
                         "matching the benchmark proxy)")
    ap.add_argument("--objective", choices=("max-tok-s", "min-pages"),
                    default="max-tok-s")
    ap.add_argument("--ttft-slo-ms", type=float, default=None,
                    help="p99 TTFT SLO the winner must meet")
    ap.add_argument("--alpha", type=float, default=0.8,
                    help="assumed speculative acceptance for spec cells")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--max-len", type=int, default=64)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--prompt-budget", type=int, default=16)
    ap.add_argument("--new-tokens", type=int, default=16)
    ap.add_argument("--stagger-ms", type=float, default=0.0)
    ap.add_argument("--arrival", choices=("uniform", "bursty"),
                    default="uniform")
    ap.add_argument("--grid", choices=("small", "full"), default="full",
                    help="small = the CI smoke grid")
    ap.add_argument("--json", default=None,
                    help="write winner + full prediction table here")
    ap.add_argument("--validate", default=None, metavar="BENCH_JSON",
                    help="instead of searching: replay every committed "
                         "bench row's prediction from its calibration "
                         "blob and fail outside tolerance")
    args = ap.parse_args(argv)

    if args.validate:
        return run_validate(args.validate)

    from repro.configs import get_config, reduced
    cfg = get_config(args.arch)
    if not args.full_config:
        cfg = reduced(cfg)
    shape = WorkloadShape(requests=args.requests,
                          prompt_budget=args.prompt_budget,
                          new_tokens=args.new_tokens,
                          stagger_s=args.stagger_ms / 1e3,
                          arrival_mode=args.arrival)
    cells = knob_grid(shape, batch=args.batch, max_len=args.max_len,
                      prefill_len=args.prompt_budget,
                      small=args.grid == "small")
    results, winner = search(cfg, shape, cells,
                             objective=args.objective,
                             ttft_slo_ms=args.ttft_slo_ms,
                             alpha=args.alpha)
    print(f"# autotune: {len(cells)} cells, objective={args.objective}"
          + (f", ttft_slo={args.ttft_slo_ms}ms"
             if args.ttft_slo_ms else ""))
    for line in table_lines(results, winner):
        print(line)
    if winner is None:
        print("# no admissible configuration")
        return 1
    print("# winning ServeConfig kwargs:")
    print(json.dumps(winner["knobs"].to_dict(), indent=1))
    if args.json:
        payload = {
            "objective": args.objective,
            "ttft_slo_ms": args.ttft_slo_ms,
            "workload": shape.to_dict(),
            "winner": winner["knobs"].to_dict(),
            "winner_prediction": winner["prediction"],
            "table": [{"knobs": r["knobs"].to_dict(),
                       "prediction": r["prediction"],
                       "admissible": r["admissible"]}
                      for r in results],
        }
        with open(args.json, "w") as f:
            json.dump(payload, f, indent=1)
        print(f"# wrote {args.json}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
