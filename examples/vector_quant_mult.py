"""The paper's own experiment, end to end: N-operand vector × broadcast
scalar across all five multiplier architectures, with cycle counts and
the calibrated area/power/energy model — Fig. 3 + Table 2 + Fig. 4 in
one script.

    PYTHONPATH=src python examples/vector_quant_mult.py [--n 16]
"""

import argparse

import jax.numpy as jnp
import numpy as np

from repro.core import cycle_model as cm
from repro.core.multipliers import MULTIPLIERS


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=16, help="vector lanes")
    ap.add_argument("--b", type=int, default=0x9D, help="broadcast scalar")
    args = ap.parse_args()

    rng = np.random.default_rng(7)
    a = jnp.asarray(rng.integers(0, 256, args.n), jnp.int32)
    expected = np.asarray(a, np.int64) * args.b

    print(f"{args.n}-operand vector × scalar 0x{args.b:02X}\n")
    print(f"{'design':20s} {'exact':>6s} {'cycles':>7s} {'area µm²':>10s} "
          f"{'power mW':>9s} {'pJ/prod':>8s}")
    for name, fn in MULTIPLIERS.items():
        if name == "booth_radix2":
            # Booth is a two's-complement (signed) scheme: evaluate it on
            # the signed interpretation of the same bit patterns.
            a_s = ((np.asarray(a) + 128) % 256 - 128).astype(np.int64)
            b_s = (args.b + 128) % 256 - 128
            tr = fn(jnp.asarray(a_s, jnp.int32), b_s)
            ok = bool(np.array_equal(np.asarray(tr.products), a_s * b_s))
        else:
            tr = fn(a, args.b)
            ok = bool(np.array_equal(np.asarray(tr.products), expected))
        area = cm.area_um2(name, args.n)
        power = cm.power_mw(name, args.n)
        epp = cm.energy_per_product_pj(name, args.n)
        print(f"{name:20s} {str(ok):>6s} {tr.cycles:7d} {area:10.1f} "
              f"{power:9.4f} {epp:8.4f}")

    print("\npaper claims at 16 operands:")
    print(f"  nibble vs shift-add area  : "
          f"{cm.improvement_vs('shift_add', 'nibble_precompute', 'area', 16):.2f}×"
          f"  (paper: 1.69×)")
    print(f"  nibble vs shift-add power : "
          f"{cm.improvement_vs('shift_add', 'nibble_precompute', 'power', 16):.2f}×"
          f"  (paper: 1.63×)")
    print(f"  nibble vs LUT-array area  : "
          f"{cm.area_um2('lut_array', 16) / cm.area_um2('nibble_precompute', 16):.2f}×"
          f"  (paper: ≈2.6×)")


if __name__ == "__main__":
    main()
