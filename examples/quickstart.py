"""Quickstart: the paper's multipliers, the quantized layer, a tiny model.

    PYTHONPATH=src python examples/quickstart.py
"""

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, reduced
from repro.core.linear import linear_apply, linear_init
from repro.core.multipliers import MULTIPLIERS
from repro.kernels import ops
from repro.models import forward, model_init


def main():
    # 1 — the paper's five multiplier architectures, bit-exact
    a = jnp.asarray([12, 200, 7, 255], jnp.int32)
    b = 0x5A
    print("== vector-scalar 8-bit multiply, A =", list(np.asarray(a)),
          "B =", b)
    for name, fn in MULTIPLIERS.items():
        tr = fn(a, b)
        print(f"  {name:20s} products={list(np.asarray(tr.products))} "
              f"cycles={tr.cycles}")

    # 2 — the same idea at MXU scale: nibble-decomposed quantized matmul
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.integers(-128, 128, (8, 256)), jnp.int8)
    w = jnp.asarray(rng.integers(-128, 128, (256, 128)), jnp.int8)
    acc = ops.nibble_matmul(x, w, interpret=True)   # Pallas kernel
    exact = np.asarray(x, np.int64) @ np.asarray(w, np.int64)
    print("\n== Pallas nibble matmul exact:",
          bool(np.array_equal(np.asarray(acc), exact)))

    # 3 — QuantLinear: one layer, every execution mode
    params = linear_init(jax.random.PRNGKey(0), 128, 64)
    xb = jax.random.normal(jax.random.PRNGKey(1), (4, 128)) \
        .astype(jnp.bfloat16)
    dense = linear_apply(params, xb, mode="dense").astype(jnp.float32)
    for mode in ("qat", "w8a8_nibble", "w4a8_nibble", "lut"):
        y = linear_apply(params, xb, mode=mode).astype(jnp.float32)
        rel = float(jnp.linalg.norm(y - dense) / jnp.linalg.norm(dense))
        print(f"  QuantLinear[{mode:12s}] rel-err vs dense = {rel:.4f}")

    # 4 — a reduced gemma3 forward pass with nibble-quantized projections
    cfg = reduced(get_config("gemma3-1b")).replace(quant_mode="qat")
    mparams = model_init(jax.random.PRNGKey(0), cfg)
    tokens = jnp.zeros((1, 16), jnp.int32)
    logits, _ = forward(mparams, cfg, tokens)
    print(f"\n== reduced gemma3-1b (QAT) logits: {logits.shape}, "
          f"finite={bool(jnp.isfinite(logits).all())}")


if __name__ == "__main__":
    main()
