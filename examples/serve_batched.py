"""Batched serving with the nibble-quantized weight path.

Prefill + continuous greedy decode on a reduced model, comparing dense
vs w8a8-nibble vs w4a8-nibble execution (same checkpoint, same requests).

    PYTHONPATH=src python examples/serve_batched.py
"""

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, reduced
from repro.models import model_init
from repro.serve import Engine, ServeConfig


def main():
    base = reduced(get_config("yi-6b"))
    params = model_init(jax.random.PRNGKey(0), base)
    prompts = jax.random.randint(jax.random.PRNGKey(1), (4, 24), 0,
                                 base.vocab_size)
    scfg = ServeConfig(batch=4, max_len=64)

    outs = {}
    for mode in ("dense", "w8a8_nibble", "w4a8_nibble"):
        cfg = base.replace(quant_mode=mode)
        engine = Engine(cfg, params, scfg)
        # warmup: trigger prefill + decode-chunk compilation outside the
        # timed window (matching launch.serve), and report it separately
        # — otherwise the dense-vs-nibble tok/s gap is mostly whichever
        # path compiles slower, not whichever runs slower
        t0 = time.time()
        engine.generate(prompts, n_new=2).block_until_ready()
        t_compile = time.time() - t0
        t0 = time.time()
        out = engine.generate(prompts, n_new=24)
        out.block_until_ready()
        dt = time.time() - t0
        outs[mode] = np.asarray(out)
        print(f"{mode:14s}: {4 * 24 / dt:7.1f} tok/s   "
              f"(compile+warmup {t_compile:5.1f}s)   "
              f"first-request tail: {out[0, -8:].tolist()}")

    # the integer paths should mostly agree with dense greedy decoding
    agree8 = float((outs["dense"] == outs["w8a8_nibble"]).mean())
    agree4 = float((outs["dense"] == outs["w4a8_nibble"]).mean())
    print(f"\ntoken agreement vs dense: w8a8={agree8:.2%}, w4a8={agree4:.2%}")


if __name__ == "__main__":
    main()
