"""End-to-end training driver: ~100M-param LM for a few hundred steps.

Uses the full framework path — config, data pipeline, QAT quantization
(the paper's technique in training form), AdamW, checkpointing, fault-
tolerance hooks — on a CPU-sized model derived from the qwen3 family.

    PYTHONPATH=src python examples/train_lm.py [--steps 300]
"""

import argparse

from repro.configs import get_config
from repro.data import DataConfig
from repro.optim import AdamWConfig
from repro.train import TrainConfig
from repro.train.trainer import Trainer, TrainerConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--quant", default="qat", choices=["dense", "qat"])
    ap.add_argument("--checkpoint-dir", default="/tmp/repro_train_lm")
    args = ap.parse_args()

    # ~100M params: qwen3 family geometry, shrunk
    cfg = get_config("qwen3-4b").replace(
        n_layers=12, d_model=640, n_heads=10, n_kv_heads=5, head_dim=64,
        d_ff=2560, vocab_size=32_768, quant_mode=args.quant)
    n = cfg.param_count()
    print(f"model: {n/1e6:.1f}M params, quant={args.quant}")

    tcfg = TrainConfig(
        optimizer=AdamWConfig(lr=1e-3),
        total_steps=args.steps, warmup_steps=args.steps // 10,
        z_loss_weight=1e-4)
    rcfg = TrainerConfig(steps=args.steps, log_every=20,
                         checkpoint_every=100,
                         checkpoint_dir=args.checkpoint_dir)
    dcfg = DataConfig(vocab_size=cfg.vocab_size, seq_len=256,
                      global_batch=8)

    trainer = Trainer(cfg, tcfg, rcfg, dcfg)
    history = trainer.run()
    first, last = history[0]["loss"], history[-1]["loss"]
    print(f"\nloss {first:.4f} → {last:.4f}")
    assert last < first, "training must reduce loss"
    print("OK: loss decreased; checkpoint at", args.checkpoint_dir)


if __name__ == "__main__":
    main()
