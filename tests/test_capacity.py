"""Tier-1 gate for the analytic capacity model (``repro.capacity``):
closed-form predictor units with hand-computed expectations,
monotonicity properties of the knob space, the autotuner's
admissibility logic, the analytic-vs-engine cache-bytes cross-check,
and the model-vs-measured replay of every committed
``benchmarks/BENCH_serve.json`` row — the same check
``tools/autotune.py --validate`` runs, so a model change that breaks
agreement with the committed measurements fails here first."""

import os
import sys

import pytest

from repro.capacity import (
    CapacityError,
    Knobs,
    StageCosts,
    WorkloadShape,
    analytic_cache_token_bytes,
    expected_tokens_per_round,
    predict,
)
from repro.capacity.validate import (
    TOLERANCE,
    load_bench,
    validate_rows,
)

sys.path.insert(0, os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "tools"))
import spec_report  # noqa: E402

BENCH = os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "benchmarks", "BENCH_serve.json")

# prompt_budget=2 pins every drawn prompt length to exactly 2 tokens
# (lengths are uniform in [max(2, budget // 2), budget]), which is what
# makes the closed forms below exact rather than distributional
_SHAPE2 = dict(prompt_budget=2, stagger_s=0.0)
_COSTS = StageCosts(prefill_s=0.01, decode_chunk_s=0.004)


# ---------------------------------------------------------------------------
# Closed-form units
# ---------------------------------------------------------------------------

def test_zero_arrival_batch_closed_form():
    """Two simultaneous requests, dense cache: two serialized prefills
    (t=0.01, 0.02), then two batched decode chunks of 4 steps cover the
    remaining 8 tokens each — wall 0.028 s for 18 tokens."""
    shape = WorkloadShape(requests=2, new_tokens=9, **_SHAPE2)
    knobs = Knobs(batch=2, max_len=16, decode_chunk=4,
                  cache_mode="dense")
    r = predict(knobs, shape, _COSTS)
    assert r["feasible"]
    assert r["tokens"] == 18
    assert r["decode_chunks"] == 2
    assert r["preemptions"] == 0
    assert r["wall_s"] == pytest.approx(0.028)
    assert r["tok_per_s"] == pytest.approx(18 / 0.028)
    # TTFTs are 10 ms and 20 ms; the interpolated p50 is their midpoint
    assert r["ttft_p50_ms"] == pytest.approx(15.0)
    assert r["ttft_p99_ms"] == pytest.approx(19.9)


def test_single_stream_decode_closed_form():
    """One request decoding alone: prefill emits token 1, two 8-step
    chunks emit the other 16 — wall 0.018 s, TTFT exactly the prefill
    latency."""
    shape = WorkloadShape(requests=1, new_tokens=17, **_SHAPE2)
    knobs = Knobs(batch=2, max_len=32, decode_chunk=8,
                  cache_mode="dense")
    r = predict(knobs, shape, _COSTS)
    assert r["feasible"]
    assert r["tokens"] == 17
    assert r["decode_chunks"] == 2
    assert r["wall_s"] == pytest.approx(0.018)
    assert r["tok_per_s"] == pytest.approx(17 / 0.018)
    assert r["ttft_p50_ms"] == pytest.approx(10.0)
    assert r["ttft_p99_ms"] == pytest.approx(10.0)


def test_saturated_pool_serializes_closed_form():
    """A reserve-mode pool holding exactly one placement (capacity 3 =
    pages_needed(2 + 9 - 1)) serializes two requests: the second admits
    only after the first frees its pages, so the wall doubles."""
    shape = WorkloadShape(requests=2, new_tokens=9, **_SHAPE2)
    knobs = Knobs(batch=2, max_len=16, decode_chunk=4,
                  cache_mode="paged", page_size=4, num_pages=4,
                  alloc_mode="reserve")
    r = predict(knobs, shape, _COSTS)
    assert r["feasible"]
    assert r["tokens"] == 18
    assert r["preemptions"] == 0
    # each request alone: prefill 0.01 + two 4-step chunks 0.008
    assert r["wall_s"] == pytest.approx(0.036)
    assert r["ttft_p99_ms"] == pytest.approx(28.0, rel=0.01)
    assert r["pool_pages"] == 4


def test_pool_too_small_raises():
    """A request that can never fit the pool is a submit-time
    CapacityError (mirroring Engine.validate), not a silent stall."""
    shape = WorkloadShape(requests=1, new_tokens=9, **_SHAPE2)
    knobs = Knobs(batch=2, max_len=16, decode_chunk=4,
                  cache_mode="paged", page_size=4, num_pages=2,
                  alloc_mode="reserve")
    with pytest.raises(CapacityError, match="pool"):
        predict(knobs, shape, _COSTS)


def test_spec_emission_matches_geometric_model():
    """Speculative prediction integerizes the geometric closed form
    exactly: total emitted tokens equal the request budgets, and the
    round count tracks new_tokens / E[tokens per round]."""
    alpha, k = 0.8, 4
    shape = WorkloadShape(requests=2, new_tokens=17, **_SHAPE2)
    knobs = Knobs(batch=2, max_len=32, decode_chunk=8,
                  cache_mode="paged", page_size=4,
                  spec_decode=True, spec_k=k)
    costs = StageCosts(prefill_s=0.01, draft_s=0.002, verify_s=0.004)
    r = predict(knobs, shape, costs, acceptance=alpha)
    assert r["feasible"]
    assert r["tokens"] == 34
    e = expected_tokens_per_round(alpha, k)
    per_req_rounds = 16 / e          # 16 post-prefill tokens each
    assert r["spec_rounds"] == pytest.approx(per_req_rounds, abs=1.5)
    assert 1.0 <= r["tokens_per_step"] <= k + 1


# ---------------------------------------------------------------------------
# Monotonicity properties
# ---------------------------------------------------------------------------

def test_more_pages_never_lower_tok_s():
    """Growing the page pool (all else fixed) never lowers predicted
    throughput — backpressure and preemptions can only relax."""
    shape = WorkloadShape(requests=8, prompt_budget=8, new_tokens=8)
    costs = StageCosts(prefill_s=0.005, decode_chunk_s=0.002,
                       overhead_s=0.0005)
    prev = 0.0
    for pages in (9, 13, 17, 25, 33, 65):
        r = predict(Knobs(batch=4, max_len=32, decode_chunk=4,
                          cache_mode="paged", page_size=4,
                          num_pages=pages, alloc_mode="incremental"),
                    shape, costs)
        assert r["feasible"], pages
        assert r["tok_per_s"] >= prev - 1e-9, pages
        prev = r["tok_per_s"]


def test_larger_decode_chunk_never_worse_throughput():
    """With an affine chunk cost (per-step work plus fixed dispatch
    overhead), a larger decode_chunk amortizes the overhead over more
    steps and predicted throughput is non-decreasing."""
    shape = WorkloadShape(requests=4, prompt_budget=8, new_tokens=16)
    prev = 0.0
    for dc in (1, 2, 4, 8, 16):
        costs = StageCosts(prefill_s=0.005,
                           decode_chunk_s=0.001 * dc + 0.0005)
        r = predict(Knobs(batch=4, max_len=32, decode_chunk=dc,
                          cache_mode="dense"),
                    shape, costs)
        assert r["feasible"], dc
        assert r["tok_per_s"] >= prev - 1e-9, dc
        prev = r["tok_per_s"]


# ---------------------------------------------------------------------------
# Autotuner admissibility
# ---------------------------------------------------------------------------

def test_autotune_search_objectives():
    from repro.capacity.tune import knob_grid, search
    from repro.configs import get_config, reduced

    cfg = reduced(get_config("yi-6b"))
    shape = WorkloadShape(requests=4, prompt_budget=8, new_tokens=8)
    cells = knob_grid(shape, batch=2, max_len=32, prefill_len=8,
                      small=True)
    assert len(cells) == len(set(cells)), "grid must be duplicate-free"

    results, winner = search(cfg, shape, cells,
                             objective="max-tok-s", ttft_slo_ms=None,
                             alpha=0.8)
    assert winner is not None and winner["admissible"]
    best = max(r["prediction"]["tok_per_s"] for r in results
               if r["admissible"])
    assert winner["prediction"]["tok_per_s"] == pytest.approx(best)

    results, winner = search(cfg, shape, cells,
                             objective="min-pages", ttft_slo_ms=None,
                             alpha=0.8)
    assert winner is not None
    assert winner["knobs"].paged
    assert winner["prediction"]["preemptions"] == 0
    # no admissible paged cell has a smaller pool
    for r in results:
        if r["admissible"] and r["knobs"].paged:
            assert (r["knobs"].resolved_num_pages
                    >= winner["knobs"].resolved_num_pages)


# ---------------------------------------------------------------------------
# Analytic cache bytes vs the live engine
# ---------------------------------------------------------------------------

def test_analytic_cache_bytes_match_engine():
    import jax
    from repro.configs import get_config, reduced
    from repro.models import model_init
    from repro.serve import Engine, ServeConfig

    cfg = reduced(get_config("yi-6b"))
    params = model_init(jax.random.PRNGKey(0), cfg)
    engine = Engine(cfg, params, ServeConfig(
        batch=2, max_len=16, prefill_len=8, cache_mode="paged",
        page_size=4))
    assert analytic_cache_token_bytes(cfg) == int(
        engine.cache_token_bytes)


# ---------------------------------------------------------------------------
# Model-vs-measured: the committed bench is a regression fixture
# ---------------------------------------------------------------------------

def test_bench_predictions_within_tolerance():
    """Replay every committed row's prediction from its embedded
    calibration blob and hold the gated rows to the documented
    tolerance — identical to ``tools/autotune.py --validate``."""
    ok, checks = validate_rows(load_bench(BENCH))
    gated = [c for c in checks if c["gated"]]
    assert len(gated) >= 20, "the gated regression surface shrank"
    drifted = [
        (c["workload"], c["quant"], c["backend"], c["alloc"], c["tail"],
         name, m)
        for c in gated for name, m in c["metrics"].items()
        if not m["ok"]]
    assert ok and not drifted, drifted


def test_bench_gating_covers_expected_cells():
    """The gate must span the scheduler paths the model claims:
    arrival modes, both quant paths, spec decode and the swap tail."""
    _, checks = validate_rows(load_bench(BENCH))
    gated = [c for c in checks if c["gated"]]
    assert {c["workload"] for c in gated} >= {
        "uniform", "staggered", "overcommit", "bursty", "burst_tail"}
    assert any(c["spec"] == "on" for c in gated)
    assert any(c["tail"] == "on" for c in gated)
    # multi-device and prefix-cache rows never gate (unmodeled)
    for c in checks:
        if c["workload"] == "mesh":
            assert not c["gated"]


def test_tolerance_policy_shape():
    """The documented policy: both metrics bounded, TTFT carries an
    absolute floor so millisecond rows don't fail on jitter."""
    assert set(TOLERANCE) == {"tok_per_s", "ttft_p50_ms"}
    rel, floor = TOLERANCE["ttft_p50_ms"]
    assert floor > 0.0
    assert 0.0 < rel < 1.0


# ---------------------------------------------------------------------------
# spec_report --bench promotion (satellite of the capacity gate)
# ---------------------------------------------------------------------------

def test_spec_report_bench_validation_passes():
    """The spec-report acceptance check — measured acceptance_rate vs
    the acceptance implied by tokens_per_step through the shared
    geometric model — holds on the committed bench."""
    lines, ok = spec_report.validate_bench(BENCH)
    assert ok, "\n".join(lines)
    assert any("OK" in line for line in lines)
