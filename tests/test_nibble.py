"""Unit + property tests for nibble decomposition and precompute logic."""

import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core.nibble import (
    combine_nibbles,
    numpy_pl_scale,
    pack_int4,
    pl_adder_count,
    pl_recipe_table,
    pl_scale,
    pl_scale_reference,
    split_nibbles_signed,
    split_nibbles_unsigned,
    unpack_int4,
)


def test_unsigned_split_roundtrip_exhaustive():
    x = jnp.arange(256, dtype=jnp.int32)
    lo, hi = split_nibbles_unsigned(x)
    assert int(lo.max()) == 15 and int(lo.min()) == 0
    assert int(hi.max()) == 15 and int(hi.min()) == 0
    np.testing.assert_array_equal(np.asarray(combine_nibbles(lo, hi)),
                                  np.arange(256))


def test_signed_split_roundtrip_exhaustive():
    x = jnp.arange(-128, 128, dtype=jnp.int8)
    lo, hi = split_nibbles_signed(x)
    assert int(lo.min()) >= 0 and int(lo.max()) <= 15
    assert int(hi.min()) >= -8 and int(hi.max()) <= 7
    np.testing.assert_array_equal(np.asarray(combine_nibbles(lo, hi)),
                                  np.arange(-128, 128))


def test_pl_recipes_are_binary_expansions():
    """Fig. 2(b): recipe for k is the set-bit shift set; ≤3 adders."""
    for k, shifts in enumerate(pl_recipe_table()):
        assert sum(1 << s for s in shifts) == k
        assert pl_adder_count(k) <= 3


def test_pl_scale_exhaustive():
    a = jnp.arange(256, dtype=jnp.int32)
    for k in range(16):
        np.testing.assert_array_equal(
            np.asarray(pl_scale(a, jnp.int32(k))),
            np.asarray(pl_scale_reference(a, jnp.int32(k))))
        # the numpy recipe mirror agrees too (same dataflow, two impls)
        np.testing.assert_array_equal(numpy_pl_scale(np.arange(256), k),
                                      np.arange(256) * k)


@given(st.lists(st.integers(-8, 7), min_size=2, max_size=64)
       .filter(lambda v: len(v) % 2 == 0))
@settings(max_examples=100, deadline=None)
def test_pack_unpack_roundtrip(vals):
    w = jnp.asarray(vals, jnp.int32).reshape(1, -1)
    np.testing.assert_array_equal(np.asarray(unpack_int4(pack_int4(w))),
                                  np.asarray(w))


def test_pack_halves_storage():
    w = jnp.zeros((4, 128), jnp.int32)
    assert pack_int4(w).shape == (4, 64)
    assert pack_int4(w).dtype == jnp.int8
