"""Per-kernel validation: shape/dtype sweeps vs the pure-jnp oracles.

Every Pallas kernel runs in interpret mode on CPU; integer kernels must
be bit-exact against ref.py, the fused float kernel allclose.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core.nibble import pack_int4
from repro.kernels import ops, ref

RNG = np.random.default_rng(1234)


def _rand_i8(*shape):
    return jnp.asarray(RNG.integers(-128, 128, shape, dtype=np.int64),
                       jnp.int8)


SHAPES = [
    (128, 128, 128),        # single block
    (256, 128, 384),        # multi-block K
    (384, 256, 128),        # multi-block M, N
    (64, 96, 200),          # unaligned everything (padding path)
    (1, 8, 16),             # tiny
    (130, 129, 131),        # off-by-one on every dim
]


@pytest.mark.parametrize("m,n,k", SHAPES)
def test_nibble_matmul_exact(m, n, k):
    x, w = _rand_i8(m, k), _rand_i8(k, n)
    got = ops.nibble_matmul(x, w, interpret=True)
    np.testing.assert_array_equal(np.asarray(got),
                                  np.asarray(ref.nibble_matmul_ref(x, w)))


@pytest.mark.parametrize("m,n,k", SHAPES)
def test_lut_matmul_exact(m, n, k):
    x, w = _rand_i8(m, k), _rand_i8(k, n)
    got = ops.lut_matmul(x, w, interpret=True)
    np.testing.assert_array_equal(np.asarray(got),
                                  np.asarray(ref.lut_matmul_ref(x, w)))


@pytest.mark.parametrize("m,n,k", [(128, 128, 128), (64, 64, 96),
                                   (32, 250, 40)])
def test_nibble_matmul_w4_exact(m, n, k):
    x = _rand_i8(m, k)
    w4 = jnp.asarray(RNG.integers(-8, 8, (k, n), dtype=np.int64), jnp.int8)
    wp = pack_int4(w4)
    got = ops.nibble_matmul_w4(x, wp, interpret=True)
    np.testing.assert_array_equal(np.asarray(got),
                                  np.asarray(ref.nibble_matmul_w4_ref(x, wp)))


def test_nibble_matmul_both_pass_modes_agree():
    x, w = _rand_i8(256, 256), _rand_i8(256, 128)
    seq = ops.nibble_matmul(x, w, unroll_passes=False, interpret=True)
    unr = ops.nibble_matmul(x, w, unroll_passes=True, interpret=True)
    np.testing.assert_array_equal(np.asarray(seq), np.asarray(unr))


def test_nibble_matmul_batched_leading_dims():
    x = _rand_i8(2, 3, 64)
    w = _rand_i8(64, 32)
    got = ops.nibble_matmul(x, w, interpret=True)
    assert got.shape == (2, 3, 32)
    np.testing.assert_array_equal(
        np.asarray(got),
        np.asarray(ref.nibble_matmul_ref(x.reshape(6, 64), w)).reshape(2, 3, 32))


@pytest.mark.parametrize("block", [(128, 128, 128), (128, 256, 128),
                                   (256, 128, 256)])
def test_nibble_matmul_block_sweep(block):
    bm, bn, bk = block
    x, w = _rand_i8(256, 512), _rand_i8(512, 256)
    got = ops.nibble_matmul(x, w, bm=bm, bn=bn, bk=bk, interpret=True)
    np.testing.assert_array_equal(np.asarray(got),
                                  np.asarray(ref.nibble_matmul_ref(x, w)))


@pytest.mark.parametrize("m,n,k", [(128, 128, 256), (32, 48, 100)])
@pytest.mark.parametrize("out_dtype", [jnp.bfloat16, jnp.float32])
def test_quant_matmul_fused(m, n, k, out_dtype):
    x = jnp.asarray(RNG.normal(size=(m, k)), jnp.float32)
    w = jnp.asarray(RNG.normal(size=(k, n)), jnp.float32)
    from repro.core import quantize as q
    wq = q.quantize(w, bits=8, granularity="per_channel", axis=0)
    got = ops.quant_matmul_fused(x, wq.values, wq.scale, out_dtype=out_dtype,
                                 interpret=True).astype(jnp.float32)
    want = ref.quant_dequant_matmul_ref(x, wq.values,
                                        wq.scale.reshape(1, -1))
    tol = 0.02 if out_dtype == jnp.bfloat16 else 1e-5
    rel = float(jnp.linalg.norm(got - want) / jnp.linalg.norm(want))
    assert rel < tol, rel


@given(m=st.integers(1, 96), n=st.integers(1, 96), k=st.integers(1, 96))
@settings(max_examples=8, deadline=None)
def test_nibble_matmul_property_random_shapes(m, n, k):
    """Property: exactness holds for arbitrary shapes via padding."""
    x, w = _rand_i8(m, k), _rand_i8(k, n)
    got = ops.nibble_matmul(x, w, bm=32, bn=32, bk=32, interpret=True)
    np.testing.assert_array_equal(np.asarray(got),
                                  np.asarray(ref.nibble_matmul_ref(x, w)))


def test_extreme_values():
    """Saturating corners: ±127/−128 everywhere must stay exact (int32
    accumulator headroom: 128·128·16384 < 2^31 requires K ≤ 2^17 — checked)."""
    for xv in (-128, 127):
        for wv in (-128, 127):
            x = jnp.full((32, 256), xv, jnp.int8)
            w = jnp.full((256, 32), wv, jnp.int8)
            got = ops.nibble_matmul(x, w, interpret=True)
            np.testing.assert_array_equal(
                np.asarray(got), np.full((32, 32), xv * wv * 256, np.int64))
