"""DP serve router: least-loaded placement honoring priorities and
prefix-cache affinity, fleet-level stat aggregation, and bit-match of
routed greedy streams against the single-engine baseline.

Everything here runs on the one real CPU device — the router's engine
replicas share it (TP sharding has its own forced-host subprocess test
in test_mesh_serve.py).  Dense quant throughout the bit-match cases:
w8a8 activation scales are per-tensor over the batch, so changing which
requests a replica co-batches (the whole point of placement) would
legitimately shift quantized streams.
"""

import jax
import numpy as np
import pytest

from repro.configs import get_config, reduced
from repro.models import model_init
from repro.serve import Engine, Router, ServeConfig
from repro.serve.workload import _pct, run_timed_workload


@pytest.fixture(scope="module")
def model():
    cfg = reduced(get_config("yi-6b"))
    params = model_init(jax.random.PRNGKey(0), cfg)
    return cfg, params


def _scfg(**over):
    kw = dict(batch=2, max_len=16, prefill_len=8, decode_chunk=3,
              cache_mode="paged", page_size=4, alloc_mode="incremental")
    kw.update(over)
    return ServeConfig(**kw)


def _prompts(vocab, n=6, seed=0):
    rng = np.random.default_rng(seed)
    return [rng.integers(0, vocab, rng.integers(3, 8)) for _ in range(n)]


def test_router_streams_bitmatch_single_engine(model):
    """The fleet is observationally one engine: every routed greedy
    stream equals the solo engine's for the same submissions, keyed by
    the router's global ids."""
    cfg, params = model
    prompts = _prompts(cfg.vocab_size)

    solo = Engine(cfg, params, _scfg())
    ids = [solo.submit(p, 6) for p in prompts]
    solo_done = solo.run()
    want = [solo_done[i].tokens for i in ids]

    router = Router(cfg, params, _scfg(), replicas=2)
    gids = [router.submit(p, 6) for p in prompts]
    done = router.run()
    assert [done[g].tokens for g in gids] == want
    assert router.leaked_pages() == 0
    assert router.compile_counts == {"prefill": 1, "decode_chunk": 1}


def test_router_jsq_spreads_uniform_arrivals(model):
    """Simultaneous arrivals on an idle fleet split evenly — the
    join-shortest-queue key counts queued plus running requests."""
    cfg, params = model
    router = Router(cfg, params, _scfg(), replicas=2)
    for p in _prompts(cfg.vocab_size, n=8, seed=1):
        router.submit(p, 4)
    router.run()
    assert router.placements == [4, 4]
    st = router.stats
    assert st["dp_replicas"] == 2
    assert sum(st["placements"]) == 8
    assert [r["placed"] for r in st["per_replica"]] == [4, 4]


def test_router_places_high_priority_first(model):
    """With both classes queued at t=0, the router's own priority
    queue hands the high-priority request to a replica first, even
    though the low-priority one was submitted earlier."""
    cfg, params = model
    router = Router(cfg, params, _scfg(), replicas=2)
    lo = router.submit(_prompts(cfg.vocab_size)[0], 4, priority=0)
    hi = router.submit(_prompts(cfg.vocab_size)[1], 4, priority=1)
    router.run()
    assert router.placement_order[:2] == [hi, lo]


def test_router_prefix_affinity_follows_cached_pages(model):
    """A request whose prompt head is already cached on one replica
    routes there over the JSQ tiebreak — driven in two drain cycles so
    the affinity decision sees a populated index, with no wall-clock
    dependence."""
    cfg, params = model
    rng = np.random.default_rng(5)
    head = rng.integers(0, cfg.vocab_size, 4)          # one full page
    mk = lambda: np.concatenate(
        [head, rng.integers(0, cfg.vocab_size, 3)])

    router = Router(cfg, params, _scfg(prefix_cache=True), replicas=2)
    router.submit(mk(), 4)
    router.run()                     # cycle 1: seeds one replica's index
    seeded = router.placements.index(1)
    for _ in range(3):
        router.submit(mk(), 4)
    router.run()                     # cycle 2: all follow the cache
    assert router.placements[seeded] == 4
    assert router.affinity_hits[seeded] == 3
    st = router.stats
    assert st["per_replica"][seeded]["affinity_hit_rate"] == 0.75
    router.release_prefix_cache()
    assert router.leaked_pages() == 0


def test_router_rejects_bad_sizing(model):
    cfg, params = model
    with pytest.raises(ValueError, match="replicas must be >= 1"):
        Router(cfg, params, _scfg(), replicas=0)
    # every tp=2 replica needs its own disjoint 2-device group; ask for
    # one group more than the process can seat and the router must
    # refuse rather than oversubscribe shards.  (Relative to
    # jax.device_count() because the count is process-global state: 1
    # standalone, but importing repro.launch.dryrun anywhere earlier in
    # the pytest run forces a 512-device host platform.)
    too_many = jax.device_count() // 2 + 1
    with pytest.raises(ValueError, match="devices"):
        Router(cfg, params, _scfg(tp=2), replicas=too_many)


def test_workload_driver_runs_a_router_fleet(model):
    """run_timed_workload drives a Router unchanged: per-replica
    warmup keeps the compile pins at one per stage, the result rows
    carry the fleet topology, and the pool drains leak-free."""
    cfg, params = model
    router = Router(cfg, params, _scfg(prefix_cache=True), replicas=2)
    r = run_timed_workload(router, cfg.vocab_size, requests=6,
                           prompt_budget=8, new_tokens=4,
                           shared_prefix=0.5)
    assert r["dp_replicas"] == 2
    assert r["device_count"] == 1          # replicas share the one CPU
    assert r["mesh_shape"] == [1, 1]
    assert len(r["per_replica"]) == 2
    assert sum(p["placed"] for p in r["per_replica"]) == 6
    assert r["compile_counts"] == {"prefill": 1, "decode_chunk": 1}
    router.release_prefix_cache()
    assert router.leaked_pages() == 0


def test_workload_priority_split_survives_empty_class(model):
    """priority_mix=1.0 makes every request high priority; the low
    class is empty and its percentile must come back None (a stable
    schema), not NaN or a crash."""
    cfg, params = model
    engine = Engine(cfg, params, _scfg())
    r = run_timed_workload(engine, cfg.vocab_size, requests=3,
                           prompt_budget=8, new_tokens=4,
                           priority_mix=1.0)
    assert r["lo_req_p50_ms"] is None
    assert r["hi_req_p50_ms"] is not None


def test_pct_helper_nan_safe():
    assert _pct(np.asarray([]), 50) is None
    assert _pct(None, 99) is None
    assert _pct(np.asarray([1.0, 3.0]), 50) == 2.0
