"""Serve engine: generation plumbing, determinism, quant-mode parity."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, reduced
from repro.models import model_init
from repro.serve import Engine, ServeConfig, make_serve_step


def _setup(quant="dense"):
    cfg = reduced(get_config("yi-6b")).replace(quant_mode=quant)
    params = model_init(jax.random.PRNGKey(0), cfg)
    return cfg, params


def test_generate_shapes_and_determinism():
    cfg, params = _setup()
    engine = Engine(cfg, params, ServeConfig(batch=2, max_len=32))
    prompts = jax.random.randint(jax.random.PRNGKey(1), (2, 8), 0,
                                 cfg.vocab_size)
    out1 = engine.generate(prompts, n_new=8)
    out2 = engine.generate(prompts, n_new=8)
    assert out1.shape == (2, 16)
    np.testing.assert_array_equal(np.asarray(out1), np.asarray(out2))
    # prompt is preserved
    np.testing.assert_array_equal(np.asarray(out1[:, :8]),
                                  np.asarray(prompts))


def test_greedy_matches_manual_argmax_rollout():
    cfg, params = _setup()
    from repro.models import decode_step, prefill
    prompts = jax.random.randint(jax.random.PRNGKey(2), (1, 8), 0,
                                 cfg.vocab_size)
    engine = Engine(cfg, params, ServeConfig(batch=1, max_len=24))
    out = engine.generate(prompts, n_new=4)

    logits, caches, _ = prefill(params, cfg, prompts, max_len=24)
    tok = jnp.argmax(logits[:, -1].astype(jnp.float32), -1)[:, None] \
        .astype(jnp.int32)
    manual = [int(tok[0, 0])]
    for i in range(3):
        lg, caches = decode_step(params, cfg, tok, caches, 8 + i)
        tok = jnp.argmax(lg[:, -1].astype(jnp.float32), -1)[:, None] \
            .astype(jnp.int32)
        manual.append(int(tok[0, 0]))
    assert out[0, 8:].tolist() == manual


def test_serve_step_jits_once_for_all_positions():
    cfg, params = _setup()
    scfg = ServeConfig(batch=2, max_len=16)
    step = jax.jit(make_serve_step(cfg, scfg))
    from repro.models import init_caches
    caches = init_caches(cfg, 2, 16)
    tok = jnp.zeros((2, 1), jnp.int32)
    rng = jax.random.PRNGKey(0)
    # different trace-time-identical positions: single compilation
    tok, caches = step(params, caches, tok, 3, rng)
    tok, caches = step(params, caches, tok, 4, rng)
    assert step._cache_size() == 1


def test_temperature_sampling_varies():
    cfg, params = _setup()
    scfg = ServeConfig(batch=1, max_len=16, temperature=5.0)
    step = jax.jit(make_serve_step(cfg, scfg))
    from repro.models import init_caches
    caches = init_caches(cfg, 1, 16)
    tok = jnp.zeros((1, 1), jnp.int32)
    outs = set()
    for s in range(8):
        t, _ = step(params, caches, tok, 2, jax.random.PRNGKey(s))
        outs.add(int(t[0, 0]))
    assert len(outs) > 1     # high temperature: not deterministic argmax


def test_int8_kv_cache_decode_accuracy():
    """int8 KV cache (paper-aligned low-precision storage): teacher-forced
    decode must track the bf16-cache path closely."""
    import numpy as np

    from repro.models import decode_step, forward, prefill
    cfg, params = _setup()
    cfg8 = cfg.replace(kv_cache_dtype="int8")
    tokens = jax.random.randint(jax.random.PRNGKey(3), (1, 8), 0,
                                cfg.vocab_size)
    full, _ = forward(params, cfg, tokens)
    logits, caches, _ = prefill(params, cfg8, tokens[:, :4], max_len=8)
    got = [np.asarray(logits[:, -1].astype(jnp.float32))]
    for t in range(4, 8):
        lg, caches = decode_step(params, cfg8, tokens[:, t:t + 1], caches, t)
        got.append(np.asarray(lg[:, -1].astype(jnp.float32)))
    want = np.asarray(full[:, 3:, :].astype(jnp.float32))
    got = np.stack(got, 1)[:, :want.shape[1]]
    rel = np.abs(got - want).max() / max(np.abs(want).max(), 1e-6)
    assert rel < 0.05, rel


def test_int8_kv_cache_halves_bytes():
    from repro.models import init_caches
    cfg, _ = _setup()
    big = init_caches(cfg.replace(kv_cache_dtype="bf16"), 2, 512)
    small = init_caches(cfg.replace(kv_cache_dtype="int8"), 2, 512)

    def nbytes(t):
        return sum(v.size * v.dtype.itemsize
                   for v in jax.tree_util.tree_leaves(t))

    # 2× minus the per-(token,head) f32 scales: at the reduced head_dim
    # of 16 the scale overhead is 4/16 = 25% → 1.6×; at production head
    # dims (128) it is 4/128 → 1.94×.
    ratio = nbytes(big) / nbytes(small)
    assert ratio > 1.55, ratio
    assert abs(ratio - 2 / (1 + 4 / cfg.head_dim)) < 1e-6  # exact accounting
