"""Serve engine: generation plumbing, determinism, continuous batching
(per-slot positions, slot refill without recompile), quant-mode parity."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, reduced
from repro.models import model_init
from repro.serve import Engine, ServeConfig, make_serve_step


def _setup(quant="dense"):
    cfg = reduced(get_config("yi-6b")).replace(quant_mode=quant)
    params = model_init(jax.random.PRNGKey(0), cfg)
    return cfg, params


def _solo_greedy(params, cfg, prompt, n_new, max_len):
    """Reference: one sequence decoded alone at scalar positions."""
    from repro.models import decode_step, prefill
    logits, caches, _ = prefill(params, cfg, prompt[None], max_len=max_len)
    tok = jnp.argmax(logits[:, -1].astype(jnp.float32), -1)[:, None] \
        .astype(jnp.int32)
    out = [int(tok[0, 0])]
    for i in range(n_new - 1):
        lg, caches = decode_step(params, cfg, tok, caches,
                                 prompt.shape[0] + i)
        tok = jnp.argmax(lg[:, -1].astype(jnp.float32), -1)[:, None] \
            .astype(jnp.int32)
        out.append(int(tok[0, 0]))
    return out


def test_generate_shapes_and_determinism():
    cfg, params = _setup()
    engine = Engine(cfg, params, ServeConfig(batch=2, max_len=32))
    prompts = jax.random.randint(jax.random.PRNGKey(1), (2, 8), 0,
                                 cfg.vocab_size)
    out1 = engine.generate(prompts, n_new=8)
    out2 = engine.generate(prompts, n_new=8)
    assert out1.shape == (2, 16)
    np.testing.assert_array_equal(np.asarray(out1), np.asarray(out2))
    # prompt is preserved
    np.testing.assert_array_equal(np.asarray(out1[:, :8]),
                                  np.asarray(prompts))


def test_greedy_matches_manual_argmax_rollout():
    cfg, params = _setup()
    from repro.models import decode_step, prefill
    prompts = jax.random.randint(jax.random.PRNGKey(2), (1, 8), 0,
                                 cfg.vocab_size)
    engine = Engine(cfg, params, ServeConfig(batch=1, max_len=24))
    out = engine.generate(prompts, n_new=4)

    logits, caches, _ = prefill(params, cfg, prompts, max_len=24)
    tok = jnp.argmax(logits[:, -1].astype(jnp.float32), -1)[:, None] \
        .astype(jnp.int32)
    manual = [int(tok[0, 0])]
    for i in range(3):
        lg, caches = decode_step(params, cfg, tok, caches, 8 + i)
        tok = jnp.argmax(lg[:, -1].astype(jnp.float32), -1)[:, None] \
            .astype(jnp.int32)
        manual.append(int(tok[0, 0]))
    assert out[0, 8:].tolist() == manual


def test_serve_step_jits_once_for_all_positions():
    from repro.serve.engine import _CountingJit
    cfg, params = _setup()
    scfg = ServeConfig(batch=2, max_len=16)
    step = _CountingJit(make_serve_step(cfg, scfg))
    from repro.models import init_caches
    caches = init_caches(cfg, 2, 16)
    tok = jnp.zeros((2, 1), jnp.int32)
    rng = jax.random.PRNGKey(0)
    # different trace-time-identical positions: single compilation
    tok, caches = step(params, caches, tok, 3, rng)
    tok, caches = step(params, caches, tok, 4, rng)
    assert step.compile_count == 1
    # cross-check against the real jit cache when the (private,
    # version-dependent) probe exists — skip silently when it moved
    probe = getattr(step._fn, "_cache_size", None)
    if probe is not None:
        assert probe() == 1


def test_temperature_sampling_varies():
    cfg, params = _setup()
    scfg = ServeConfig(batch=1, max_len=16, temperature=5.0)
    step = jax.jit(make_serve_step(cfg, scfg))
    from repro.models import init_caches
    caches = init_caches(cfg, 1, 16)
    tok = jnp.zeros((1, 1), jnp.int32)
    outs = set()
    for s in range(8):
        t, _ = step(params, caches, tok, 2, jax.random.PRNGKey(s))
        outs.add(int(t[0, 0]))
    assert len(outs) > 1     # high temperature: not deterministic argmax

    # the FIRST post-prefill token must go through the same path — the
    # seed engine hardcoded argmax for it regardless of temperature
    engine = Engine(cfg, params, scfg)
    prompts = jax.random.randint(jax.random.PRNGKey(1), (1, 6), 0,
                                 cfg.vocab_size)
    firsts = {int(engine.generate(prompts, 2,
                                  rng=jax.random.PRNGKey(s))[0, 6])
              for s in range(8)}
    assert len(firsts) > 1


def test_int8_kv_cache_decode_accuracy():
    """int8 KV cache (paper-aligned low-precision storage): teacher-forced
    decode must track the bf16-cache path closely."""
    import numpy as np

    from repro.models import decode_step, forward, prefill
    cfg, params = _setup()
    cfg8 = cfg.replace(kv_cache_dtype="int8")
    tokens = jax.random.randint(jax.random.PRNGKey(3), (1, 8), 0,
                                cfg.vocab_size)
    full, _ = forward(params, cfg, tokens)
    logits, caches, _ = prefill(params, cfg8, tokens[:, :4], max_len=8)
    got = [np.asarray(logits[:, -1].astype(jnp.float32))]
    for t in range(4, 8):
        lg, caches = decode_step(params, cfg8, tokens[:, t:t + 1], caches, t)
        got.append(np.asarray(lg[:, -1].astype(jnp.float32)))
    want = np.asarray(full[:, 3:, :].astype(jnp.float32))
    got = np.stack(got, 1)[:, :want.shape[1]]
    rel = np.abs(got - want).max() / max(np.abs(want).max(), 1e-6)
    assert rel < 0.05, rel


def test_int8_kv_cache_halves_bytes():
    from repro.models import init_caches
    cfg, _ = _setup()
    big = init_caches(cfg.replace(kv_cache_dtype="bf16"), 2, 512)
    small = init_caches(cfg.replace(kv_cache_dtype="int8"), 2, 512)

    def nbytes(t):
        return sum(v.size * v.dtype.itemsize
                   for v in jax.tree_util.tree_leaves(t))

    # 2× minus the per-(token,head) f32 scales: at the reduced head_dim
    # of 16 the scale overhead is 4/16 = 25% → 1.6×; at production head
    # dims (128) it is 4/128 → 1.94×.
    ratio = nbytes(big) / nbytes(small)
    assert ratio > 1.55, ratio
    assert abs(ratio - 2 / (1 + 4 / cfg.head_dim)) < 1e-6  # exact accounting


# ---------------------------------------------------------------------------
# Continuous batching: per-slot positions, refill, compile stability
# ---------------------------------------------------------------------------

def test_decode_step_vector_positions_match_scalar():
    """A (B,) position vector with all-equal entries must bit-match the
    scalar path (same math, vmapped scatter)."""
    from repro.models import decode_step, prefill
    cfg, params = _setup()
    prompts = jax.random.randint(jax.random.PRNGKey(1), (2, 8), 0,
                                 cfg.vocab_size)
    _, caches, _ = prefill(params, cfg, prompts, max_len=16)
    tok = jnp.zeros((2, 1), jnp.int32)
    l_s, c_s = decode_step(params, cfg, tok, caches, 8)
    l_v, c_v = decode_step(params, cfg, tok, caches,
                           jnp.full((2,), 8, jnp.int32))
    np.testing.assert_array_equal(np.asarray(l_s), np.asarray(l_v))
    for a, b in zip(jax.tree_util.tree_leaves(c_s),
                    jax.tree_util.tree_leaves(c_v)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


@pytest.mark.parametrize("quant,backend", [
    ("dense", "xla"), ("dense", "pallas"),
    ("w8a8_nibble", "xla"), ("w8a8_nibble", "pallas"),
])
def test_staggered_batch_matches_solo(quant, backend):
    """The per-slot-position tentpole: a staggered batch (every slot a
    different prompt length, prefilled padded to the slot budget) must
    BIT-match each sequence decoded alone at scalar positions."""
    cfg, params = _setup(quant)
    cfg = cfg.replace(quant_backend=backend)
    max_len, n_new = 16, 4
    rng = np.random.default_rng(0)
    prompts = [jnp.asarray(rng.integers(0, cfg.vocab_size, p), jnp.int32)
               for p in (3, 5, 7)]

    engine = Engine(cfg, params, ServeConfig(batch=3, max_len=max_len,
                                             prefill_len=8, decode_chunk=3))
    ids = [engine.submit(p, n_new) for p in prompts]
    done = engine.run()
    for rid, prompt in zip(ids, prompts):
        want = _solo_greedy(params, cfg, prompt, n_new, max_len)
        assert done[rid].tokens == want, (quant, backend, done[rid].tokens,
                                          want)


def test_slot_refill_without_recompile():
    """More requests than slots, mixed prompt lengths and budgets: every
    refill must reuse the two compiled programs (prefill, decode chunk).
    Bit-exactness of the refilled slots is covered against solo decoding
    too — a refilled slot starts mid-stream next to older sequences."""
    cfg, params = _setup()
    engine = Engine(cfg, params, ServeConfig(batch=2, max_len=24,
                                             prefill_len=8, decode_chunk=4))
    rng = np.random.default_rng(1)
    spec = [(4, 6), (8, 3), (5, 7), (6, 1), (3, 5)]
    prompts = [jnp.asarray(rng.integers(0, cfg.vocab_size, p), jnp.int32)
               for p, _ in spec]
    ids = [engine.submit(p, n) for p, (_, n) in zip(prompts, spec)]
    done = engine.run()
    assert engine.compile_counts == {"prefill": 1, "decode_chunk": 1}
    for rid, prompt, (_, n) in zip(ids, prompts, spec):
        assert len(done[rid].tokens) == n
        assert done[rid].tokens == _solo_greedy(params, cfg, prompt, n, 24)


def test_eos_stops_slot_early():
    cfg, params = _setup()
    # pick an eos id the greedy path actually emits: probe a solo run
    probe = _solo_greedy(params, cfg,
                         jnp.asarray([1, 2, 3, 4], jnp.int32), 8, 16)
    eos = probe[3]   # stop where the solo run emits this token
    engine = Engine(cfg, params, ServeConfig(batch=2, max_len=16,
                                             prefill_len=4, eos_id=eos,
                                             decode_chunk=4))
    rid = engine.submit(jnp.asarray([1, 2, 3, 4], jnp.int32), 8)
    done = engine.run()
    toks = done[rid].tokens
    assert toks == probe[:probe.index(eos) + 1]   # truncated at first eos
    assert toks[-1] == eos


def test_generate_validates_batch():
    cfg, params = _setup()
    engine = Engine(cfg, params, ServeConfig(batch=2, max_len=16))
    prompts = jax.random.randint(jax.random.PRNGKey(1), (3, 4), 0,
                                 cfg.vocab_size)
    with pytest.raises(ValueError, match="batch"):
        engine.generate(prompts, 2)
    with pytest.raises(ValueError, match="max_len"):
        engine.generate(prompts[:2], 20)
