"""Unit tests for the dry-run tooling: HLO collective parser, shape
grid/skip policy, roofline math."""

import pytest

from repro.configs import get_config
from repro.launch.dryrun import collective_bytes_from_hlo
from repro.launch.shapes import SHAPES, skip_reason
from repro.roofline.analysis import analyze_cell

HLO_SAMPLE = """
  %all-reduce.3 = f32[1024,128]{1,0} all-reduce(%x), replica_groups=[16,16]<=[256]
  %ag = bf16[64,2048]{1,0} all-gather(%y), channel_id=4, dimensions={0}
  %ag2 = (bf16[32,32]{1,0}, bf16[32,32]{1,0}) all-gather-start(%z), channel_id=5
  %agd = bf16[32,32]{1,0} all-gather-done(%ag2), channel_id=5
  %rs = f32[512]{0} reduce-scatter(%w), channel_id=6
  %cp = bf16[8,8]{1,0} collective-permute(%v), channel_id=7
  %a2a = s8[16,16]{1,0} all-to-all(%u), channel_id=8
  %dot.1 = f32[128,128]{1,0} dot(%a, %b), lhs_contracting_dims={1}
"""


def test_collective_parser_kinds_and_bytes():
    out = collective_bytes_from_hlo(HLO_SAMPLE)
    assert out["all-reduce"] == 1024 * 128 * 4
    # plain all-gather + the -start tuple (2×32×32 bf16); -done not counted
    assert out["all-gather"] == 64 * 2048 * 2 + 2 * 32 * 32 * 2
    assert out["reduce-scatter"] == 512 * 4
    assert out["collective-permute"] == 8 * 8 * 2
    assert out["all-to-all"] == 16 * 16 * 1
    assert out["n_ops"] == 6


def test_collective_parser_ignores_compute_ops():
    out = collective_bytes_from_hlo("%d = f32[4,4]{1,0} dot(%a, %b)")
    assert out["n_ops"] == 0


def test_shape_grid_is_the_assignment():
    assert SHAPES["train_4k"].seq == 4096
    assert SHAPES["train_4k"].batch == 256
    assert SHAPES["prefill_32k"].batch == 32
    assert SHAPES["decode_32k"].batch == 128
    assert SHAPES["long_500k"].seq == 524_288


@pytest.mark.parametrize("arch,expect_skip", [
    ("mamba2-780m", False), ("jamba-v0.1-52b", False),
    ("gemma3-1b", True), ("yi-6b", True), ("deepseek-v3-671b", True),
    ("whisper-base", True),
])
def test_long_500k_skip_policy(arch, expect_skip):
    reason = skip_reason(get_config(arch), SHAPES["long_500k"])
    assert (reason is not None) == expect_skip


def test_no_skips_outside_long():
    for arch in ("gemma3-1b", "deepseek-v3-671b", "whisper-base"):
        for shape in ("train_4k", "prefill_32k", "decode_32k"):
            assert skip_reason(get_config(arch), SHAPES[shape]) is None


def test_roofline_cell_math():
    record = {
        "status": "ok", "arch": "x", "shape": "train_4k",
        "mesh": "1pod_16x16",
        "flops": 1.97e12,                       # raw (ignored)
        "bytes_accessed": 8.19e11,
        "flops_extrapolated": 1.97e13,          # = 0.1 s at 197 TF/s
        "bytes_extrapolated": 8.19e11,          # = 1.0 s at 819 GB/s
        "collective_bytes_extrapolated": {"all-reduce": 5.0e10},  # 1.0 s
        "params": 1e9, "params_active": 1e9,
    }
    t = analyze_cell(record)
    assert t.compute_s == pytest.approx(0.1)
    assert t.memory_s == pytest.approx(1.0)
    assert t.collective_s == pytest.approx(1.0)
    assert t.dominant in ("memory", "collective")
    # model flops = 6e9·(256·4096)/256 chips = 2.4576e13 per device
    assert t.model_flops_per_device == pytest.approx(6e9 * 4096, rel=1e-6)


def test_roofline_skips_failed_cells():
    assert analyze_cell({"status": "fail"}) is None
    assert analyze_cell({"status": "skip"}) is None
