"""Tail-latency engineering: chunked prefill and grouped admission
through one compiled wave program (greedy streams BIT-match the
monolithic engine, the wave program compiles exactly once), the
host-tier page swap that makes preemption resume an O(pages) copy
instead of an O(generated) replay, the prefix cache's host cold tier
with a capacity cap, and the HostPagePool refcount/payload units."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.configs import get_config, reduced
from repro.models import model_init
from repro.serve import Engine, ServeConfig
from repro.serve.paging import HostPagePool


@pytest.fixture(scope="module")
def model():
    cfg = reduced(get_config("yi-6b"))
    params = model_init(jax.random.PRNGKey(0), cfg)
    return cfg, params


def _scfg(**over):
    kw = dict(batch=3, max_len=16, prefill_len=8, decode_chunk=3,
              cache_mode="paged", page_size=4)
    kw.update(over)
    return ServeConfig(**kw)


def _drive(cfg, params, prompts, budgets, scfg, priorities=None):
    engine = Engine(cfg, params, scfg)
    priorities = priorities or [0] * len(prompts)
    ids = [engine.submit(p, n, priority=pr)
           for p, n, pr in zip(prompts, budgets, priorities)]
    done = engine.run()
    return engine, [done[i] for i in ids]


def _leaks(engine) -> int:
    engine.release_prefix_cache()
    return engine.leaked_pages()


def _prompts(vocab, lens, seed=0):
    rng = np.random.default_rng(seed)
    return [jnp.asarray(rng.integers(0, vocab, n), jnp.int32)
            for n in lens]


WAVE_COUNTS = {"prefill": 0, "decode_chunk": 1, "prefill_chunk": 1}


# ---------------------------------------------------------------------------
# HostPagePool units: refcounts, payload lifecycle, backpressure
# ---------------------------------------------------------------------------

def test_host_pool_alloc_store_load_free():
    pool = HostPagePool(4)
    assert pool.capacity == 4 and pool.available == 4
    pages = pool.alloc(2)
    assert pool.in_use == 2
    pool.store(pages[0], {"rows": 123})
    assert pool.load(pages[0]) == {"rows": 123}
    pool.free(pages)
    assert pool.in_use == 0 and pool.available == 4


def test_host_pool_payload_dies_with_last_holder():
    pool = HostPagePool(2)
    (p,) = pool.alloc(1)
    pool.store(p, "payload")
    pool.share([p])                     # second holder
    pool.free([p])                      # first release: payload survives
    assert pool.load(p) == "payload"
    pool.free([p])                      # last release: payload dropped
    (q,) = pool.alloc(1)                # id may be recycled...
    with pytest.raises(ValueError, match="no stored payload"):
        pool.load(q)                    # ...but never its old payload


def test_host_pool_store_load_errors():
    pool = HostPagePool(2)
    (p,) = pool.alloc(1)
    with pytest.raises(ValueError, match="no stored payload"):
        pool.load(p)                    # nothing stored yet
    pool.free([p])
    with pytest.raises(ValueError, match="no outstanding references"):
        pool.store(p, "stale")          # freed id must not resurrect
    with pytest.raises(ValueError, match="not currently allocated"):
        pool.free([p])                  # double free


def test_host_pool_backpressure():
    pool = HostPagePool(2)
    held = pool.alloc(2)
    assert pool.alloc(1) is None        # full: swap falls back to replay
    assert not pool.can_alloc(1)
    pool.free(held[:1])
    assert pool.alloc(1) is not None


@given(num_pages=st.integers(1, 6),
       ops=st.lists(st.sampled_from(["alloc", "share", "free", "store"]),
                    min_size=1, max_size=40))
@settings(max_examples=60, deadline=None)
def test_host_pool_refcount_invariants(num_pages, ops):
    """Property: under any interleaving of alloc/share/free/store, the
    pool's counters match a shadow refcount model exactly, payloads are
    readable iff stored on a page with a live holder, and a page's
    payload dies with its last reference."""
    pool = HostPagePool(num_pages)
    rc: dict[int, int] = {}             # shadow refcounts
    stored: dict[int, object] = {}      # shadow payloads
    for step, op in enumerate(ops):
        held = sorted(p for p, n in rc.items() if n > 0)
        if op == "alloc":
            got = pool.alloc(1)
            if len(held) >= num_pages:
                assert got is None      # backpressure, never overcommit
            else:
                assert got is not None
                (p,) = got
                assert rc.get(p, 0) == 0, "live id handed out twice"
                rc[p] = 1
                # a recycled id's old payload must have died already
                assert p not in stored
        elif op == "share" and held:
            p = held[step % len(held)]
            pool.share([p])
            rc[p] += 1
        elif op == "free" and held:
            p = held[step % len(held)]
            pool.free([p])
            rc[p] -= 1
            if rc[p] == 0:
                stored.pop(p, None)
                with pytest.raises(ValueError):
                    pool.load(p)        # payload died with last holder
        elif op == "store" and held:
            p = held[step % len(held)]
            pool.store(p, ("payload", step))
            stored[p] = ("payload", step)
        # the pool's view must equal the shadow model after every op
        assert pool.in_use == sum(1 for n in rc.values() if n > 0)
        assert pool.available == pool.capacity - pool.in_use
        for p, n in rc.items():
            assert pool.refcount(p) == n
        for p, payload in stored.items():
            assert pool.load(p) == payload


# ---------------------------------------------------------------------------
# Chunked prefill: bit-match vs monolithic, single wave compilation
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("chunk", [2, 3, 8])
def test_chunked_prefill_bitmatches_monolithic(model, chunk):
    """Every chunk width — including one that never splits (8 >= all
    prompts) — reproduces the monolithic engine's greedy streams
    through the ONE wave program; the monolithic prefill is never
    built."""
    cfg, params = model
    prompts = _prompts(cfg.vocab_size, (7, 5, 6, 4), seed=0)
    _, want = _drive(cfg, params, prompts, [6] * 4, _scfg())
    engine, got = _drive(cfg, params, prompts, [6] * 4,
                         _scfg(prefill_chunk=chunk))
    assert [r.tokens for r in got] == [r.tokens for r in want]
    assert engine.compile_counts == WAVE_COUNTS
    assert engine.stats["prefill_waves"] >= 1
    assert _leaks(engine) == 0


def test_chunked_prefill_batch1_backlog_no_stall(model):
    """Regression for the idle-wait stall check: with one slot mid-
    prefill (inactive but progressing) and a second request queued, the
    scheduler must keep running waves — the PR 4 stall RuntimeError is
    for genuinely idle engines only."""
    cfg, params = model
    prompts = _prompts(cfg.vocab_size, (7, 5), seed=1)
    _, want = _drive(cfg, params, prompts, [5, 5], _scfg(batch=1))
    engine, got = _drive(cfg, params, prompts, [5, 5],
                         _scfg(batch=1, prefill_chunk=2))
    assert [r.tokens for r in got] == [r.tokens for r in want]
    assert _leaks(engine) == 0


# ---------------------------------------------------------------------------
# Grouped admission: bit-match vs serialized, one padded wave
# ---------------------------------------------------------------------------

def test_grouped_admission_bitmatches_serialized(model):
    """A simultaneous burst admitted as one (G, prefill_len) wave emits
    the serialized engine's exact streams, in fewer prefill
    dispatches."""
    cfg, params = model
    prompts = _prompts(cfg.vocab_size, (7, 5, 6), seed=2)
    _, want = _drive(cfg, params, prompts, [6] * 3, _scfg())
    engine, got = _drive(cfg, params, prompts, [6] * 3,
                         _scfg(admit_group=3))
    assert [r.tokens for r in got] == [r.tokens for r in want]
    assert engine.compile_counts == WAVE_COUNTS
    # all three prompts fit one wave each lane: one dispatch total
    assert engine.stats["prefill_waves"] == 1
    assert _leaks(engine) == 0


def test_chunked_plus_grouped_bitmatch(model):
    """Chunked and grouped compose: several lanes advance chunk-by-
    chunk through the same program, still bit-matching monolithic
    serialized prefill."""
    cfg, params = model
    prompts = _prompts(cfg.vocab_size, (7, 6, 5, 7, 4), seed=3)
    _, want = _drive(cfg, params, prompts, [6] * 5, _scfg())
    engine, got = _drive(cfg, params, prompts, [6] * 5,
                         _scfg(prefill_chunk=3, admit_group=2))
    assert [r.tokens for r in got] == [r.tokens for r in want]
    assert engine.compile_counts == WAVE_COUNTS
    assert _leaks(engine) == 0


def test_wave_requires_paged_cache(model):
    cfg, params = model
    with pytest.raises(ValueError, match="require"):
        Engine(cfg, params, _scfg(cache_mode="dense", page_size=None,
                                  prefill_chunk=4))
    with pytest.raises(ValueError, match="prefill_len"):
        Engine(cfg, params, _scfg(admit_group=2, prefill_len=0))


# ---------------------------------------------------------------------------
# Host-tier swap: O(pages) resume, bit-match, zero leaks (both pools)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("quant,backend", [
    ("dense", "xla"), ("dense", "pallas"),
    ("w8a8_nibble", "xla"), ("w8a8_nibble", "pallas"),
])
def test_swap_roundtrip_bitmatch(quant, backend):
    """The acceptance scenario: an overcommitted pool forces evictions
    mid-stream; with swap_mode="host" every resume restores KV rows by
    page copy (swap_in > 0, replayed decode steps saved) and the greedy
    streams still equal an uncontended dense-slab run's — across the
    quant x backend grid, with zero pages leaked on the device AND the
    host pool."""
    cfg = reduced(get_config("yi-6b")).replace(quant_mode=quant,
                                               quant_backend=backend)
    params = model_init(jax.random.PRNGKey(0), cfg)
    prompts = _prompts(cfg.vocab_size, (4, 6, 5, 7), seed=4)
    budgets = [8] * 4

    _, want = _drive(cfg, params, prompts, budgets,
                     _scfg(cache_mode="dense", page_size=None))
    engine, got = _drive(cfg, params, prompts, budgets,
                         _scfg(alloc_mode="incremental", num_pages=7,
                               swap_mode="host"))
    assert [r.tokens for r in got] == [r.tokens for r in want]
    assert engine.stats["preemptions"] >= 1
    assert engine.stats["swap_out"] >= 1
    assert engine.stats["swap_in"] == engine.stats["swap_out"]
    assert engine.stats["replay_steps_saved"] >= 1
    assert engine.compile_counts == {"prefill": 1, "decode_chunk": 1}
    assert _leaks(engine) == 0          # device + host pools both clean
    assert engine.host_pool.in_use == 0


def test_swap_saves_decode_steps_vs_replay(model):
    """Same overcommitted workload with swap off vs on: the page-copy
    resume must spend strictly fewer decode-chunk dispatches than
    replaying every generated token through the forced lane."""
    cfg, params = model
    prompts = _prompts(cfg.vocab_size, (4, 6, 5, 7), seed=5)
    budgets = [8] * 4
    off, got_off = _drive(cfg, params, prompts, budgets,
                          _scfg(alloc_mode="incremental", num_pages=7))
    on, got_on = _drive(cfg, params, prompts, budgets,
                        _scfg(alloc_mode="incremental", num_pages=7,
                              swap_mode="host"))
    assert [r.tokens for r in got_on] == [r.tokens for r in got_off]
    assert on.stats["replay_steps_saved"] >= 1
    assert on.stats["decode_chunks"] < off.stats["decode_chunks"]
    assert _leaks(on) == 0 and _leaks(off) == 0


def test_swap_resume_bit_stable_under_temperature(model):
    """The restore is a bit-copy, so *sampled* streams also continue
    exactly (replay already guaranteed this via index-derived RNG; swap
    must not regress it)."""
    cfg, params = model
    prompts = _prompts(cfg.vocab_size, (4, 6, 5, 7), seed=6)
    budgets = [8] * 4
    scfg = _scfg(alloc_mode="incremental", num_pages=7,
                 temperature=0.7)
    _, want = _drive(cfg, params, prompts, budgets, scfg)
    engine, got = _drive(cfg, params, prompts, budgets,
                         dataclasses.replace(scfg, swap_mode="host"))
    assert [r.tokens for r in got] == [r.tokens for r in want]
    assert engine.stats["swap_in"] >= 1
    assert _leaks(engine) == 0


def test_all_three_mechanisms_compose(model):
    """Chunked + grouped + swap on one overcommitted engine still
    bit-matches the plain engine, holds the wave compile pins, and
    leaks nothing on either pool."""
    cfg, params = model
    prompts = _prompts(cfg.vocab_size, (4, 6, 5, 7, 6), seed=7)
    budgets = [8] * 5
    _, want = _drive(cfg, params, prompts, budgets,
                     _scfg(alloc_mode="incremental", num_pages=7))
    engine, got = _drive(cfg, params, prompts, budgets,
                         _scfg(alloc_mode="incremental", num_pages=7,
                               prefill_chunk=3, admit_group=2,
                               swap_mode="host"))
    assert [r.tokens for r in got] == [r.tokens for r in want]
    assert engine.compile_counts == WAVE_COUNTS
    assert _leaks(engine) == 0


def test_spec_decode_composes_with_wave_and_swap(model):
    """Speculative decoding keeps its draft/verify pins while the wave
    program replaces the prefill, and greedy spec streams still equal
    the plain non-spec engine's."""
    cfg, params = model
    prompts = _prompts(cfg.vocab_size, (7, 5, 6), seed=8)
    budgets = [6] * 3
    _, want = _drive(cfg, params, prompts, budgets, _scfg())
    engine, got = _drive(cfg, params, prompts, budgets,
                         _scfg(spec_decode=True, spec_k=3,
                               prefill_chunk=4, swap_mode="host"))
    assert [r.tokens for r in got] == [r.tokens for r in want]
    assert engine.compile_counts == {"prefill": 0, "decode_chunk": 0,
                                     "prefill_chunk": 1, "draft": 1,
                                     "verify": 1}
    assert _leaks(engine) == 0


# ---------------------------------------------------------------------------
# Prefix-cache cold tier + capacity cap
# ---------------------------------------------------------------------------

def _shared_head_prompts(vocab, n, head_len=4, tail=3, seed=9):
    rng = np.random.default_rng(seed)
    head = rng.integers(0, vocab, head_len)
    return [jnp.asarray(np.concatenate(
        [head, rng.integers(0, vocab, tail)]), jnp.int32)
        for _ in range(n)]


def test_prefix_cache_pages_cap_reclaims(model):
    """The prefix_cache_pages cap bounds the index after drain: distinct
    prompts would otherwise pin one page each, the cap reclaims down to
    the budget (best-effort while slots run, exact once idle)."""
    cfg, params = model
    prompts = _prompts(cfg.vocab_size, (5, 6, 7, 5, 6, 7), seed=10)
    engine, _ = _drive(cfg, params, prompts, [4] * 6,
                       _scfg(batch=2, num_pages=24, prefix_cache=True,
                             prefix_cache_pages=2))
    assert len(engine.prefix_cache) <= 2
    assert engine.prefix_capacity_reclaims >= 1
    assert _leaks(engine) == 0


def test_cold_tier_demotes_and_promotes(model):
    """With the host tier attached, capacity-capped reclaim demotes
    chunks to host pages instead of dropping them, and a later request
    whose chain reaches the cold run promotes it back — counted as a
    prefix hit (the hit-rate stat composes across tiers)."""
    cfg, params = model
    vocab = cfg.vocab_size
    first, again = _shared_head_prompts(vocab, 2)  # same head, own tails
    evictors = _prompts(vocab, (5, 6, 7), seed=11)
    engine = Engine(cfg, params,
                    _scfg(batch=1, num_pages=24, prefix_cache=True,
                          prefix_cache_pages=1, swap_mode="host"))
    ids = [engine.submit(p, 4) for p in (first, *evictors, again)]
    done = engine.run()
    st = engine.stats
    # the shared 4-token head chunk was demoted by the cap, then
    # promoted back for the final request
    assert st["prefix_demotions"] >= 1
    assert st["prefix_cold_hits"] >= 1
    assert st["prefix_hits"] >= 1
    assert st["prefix_hit_rate"] > 0.0
    # promoted-prefix stream equals the same request run uncached
    _, solo = _drive(cfg, params, [again], [4], _scfg(batch=1))
    assert done[ids[-1]].tokens == solo[0].tokens
    assert _leaks(engine) == 0
    assert engine.host_pool.in_use == 0


def test_cold_tier_composes_with_chunked_prefill(model):
    """Prefix hits (hot and cold) + chunked prefill: the wave engine's
    suffix chunks start past the cached prefix and streams still
    bit-match the plain uncached engine."""
    cfg, params = model
    prompts = _shared_head_prompts(cfg.vocab_size, 3, seed=13)
    _, want = _drive(cfg, params, prompts, [4] * 3, _scfg(batch=1))
    engine, got = _drive(cfg, params, prompts, [4] * 3,
                         _scfg(batch=1, prefix_cache=True,
                               prefill_chunk=2, swap_mode="host"))
    assert [r.tokens for r in got] == [r.tokens for r in want]
    assert engine.stats["prefix_hits"] >= 1
    assert engine.compile_counts == WAVE_COUNTS
    assert _leaks(engine) == 0


# ---------------------------------------------------------------------------
# Config validation
# ---------------------------------------------------------------------------

def test_swap_requires_paged(model):
    cfg, params = model
    with pytest.raises(ValueError, match="swap_mode='host' requires"):
        Engine(cfg, params, _scfg(cache_mode="dense", page_size=None,
                                  swap_mode="host"))
    with pytest.raises(ValueError, match="swap_mode must be"):
        Engine(cfg, params, _scfg(swap_mode="disk"))
    with pytest.raises(ValueError, match="prefill_chunk must be"):
        Engine(cfg, params, _scfg(prefill_chunk=-1))
    with pytest.raises(ValueError, match="admit_group must be"):
        Engine(cfg, params, _scfg(admit_group=0))
