"""Prefix caching with copy-on-write pages: refcounted allocation,
hash-chained prefix index with LRU reclaim, suffix-only prefill that
BIT-matches uncached runs, COW on fully covered prompts, and
evict-while-shared survival."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.configs import get_config, reduced
from repro.models import model_init
from repro.serve import (
    Engine,
    PageAllocator,
    PrefixCache,
    ServeConfig,
)


@pytest.fixture(scope="module")
def model():
    cfg = reduced(get_config("yi-6b"))
    params = model_init(jax.random.PRNGKey(0), cfg)
    return cfg, params


def _scfg(**over):
    kw = dict(batch=2, max_len=16, prefill_len=8, decode_chunk=3,
              cache_mode="paged", page_size=4)
    kw.update(over)
    return ServeConfig(**kw)


def _drive(cfg, params, prompts, budgets, scfg, priorities=None):
    engine = Engine(cfg, params, scfg)
    priorities = priorities or [0] * len(prompts)
    ids = [engine.submit(p, n, priority=pr)
           for p, n, pr in zip(prompts, budgets, priorities)]
    done = engine.run()
    return engine, [done[i] for i in ids]


def _shared_prompts(vocab, head_len=4, tails=(2, 3), seed=0):
    rng = np.random.default_rng(seed)
    head = rng.integers(0, vocab, head_len)
    return [jnp.asarray(np.concatenate(
        [head, rng.integers(0, vocab, t)]), jnp.int32) for t in tails]


# ---------------------------------------------------------------------------
# Allocator refcount units
# ---------------------------------------------------------------------------

def test_allocator_refcount_share_free():
    a = PageAllocator(8, reserved=1)
    pages = a.alloc(2)
    assert all(a.refcount(p) == 1 for p in pages)
    a.share(pages)                          # second holder
    assert all(a.refcount(p) == 2 for p in pages)
    a.free(pages)                           # first holder releases
    assert a.in_use == 2                    # pages survive: one holder left
    assert a.available == 5
    a.free(pages)                           # last holder releases
    assert a.in_use == 0 and a.available == 7


def test_allocator_double_decrement_raises():
    a = PageAllocator(4, reserved=1)
    pages = a.alloc(1)
    a.free(pages)
    with pytest.raises(ValueError, match="not currently allocated"):
        a.free(pages)                       # refcount already hit zero
    with pytest.raises(ValueError, match="sharing pages not"):
        a.share(pages)                      # cannot share a freed page


def test_allocator_shared_page_not_recycled_early():
    """A page with a second holder must not reappear on the free list
    until both release it."""
    a = PageAllocator(3, reserved=1)        # capacity 2
    p = a.alloc(1)
    a.share(p)
    a.free(p)
    got = a.alloc(1)
    assert got is not None and got[0] != p[0]
    assert a.alloc(1) is None               # pool exhausted; p still held
    a.free(p)
    assert a.alloc(1) == p                  # now recycled


# ---------------------------------------------------------------------------
# PrefixCache index units
# ---------------------------------------------------------------------------

def test_prefix_cache_chain_keys_commit_to_whole_prefix():
    a = PageAllocator(8, reserved=1)
    c = PrefixCache(4, a)
    k1 = c.chunk_keys(np.arange(8))
    k2 = c.chunk_keys(np.concatenate([np.arange(4) + 1, np.arange(4, 8)]))
    assert k1[0] != k2[0]
    assert k1[1] != k2[1]                   # same chunk 1 tokens, new key
    assert len(c.chunk_keys(np.arange(7))) == 1   # partial tail unindexed


def test_prefix_cache_insert_match_acquire():
    a = PageAllocator(8, reserved=1)
    c = PrefixCache(4, a)
    pages = a.alloc(2)
    keys = c.chunk_keys(np.arange(8))
    assert c.match(keys) == []
    c.insert(keys, pages)
    assert all(a.refcount(p) == 2 for p in pages)  # owner + index
    assert c.match(keys) == pages
    assert c.match(keys[:1]) == pages[:1]
    got = c.acquire(keys)
    assert got == pages
    assert all(a.refcount(p) == 3 for p in pages)
    a.free(got)
    a.free(pages)
    assert a.in_use == 2                    # index refs keep them live
    c.drop()
    assert a.in_use == 0


def test_prefix_cache_reclaim_is_lru_leaf_first():
    """An interior chunk is never dropped before its descendant, and
    pages another holder still maps (refcount > 1) are skipped."""
    a = PageAllocator(8, reserved=1)
    c = PrefixCache(2, a)
    pages = a.alloc(3)
    keys = c.chunk_keys(np.arange(6))
    c.insert(keys, pages)
    a.free(pages)                           # only the index holds them
    assert c.reclaimable() == 3
    # the leaf (chunk 2) must go before chunk 1, chunk 1 before chunk 0
    assert c.reclaim(1) == 1
    assert c.match(keys) == pages[:2]
    # a page with another holder is not reclaimable
    c.acquire(keys[:2])
    assert c.reclaimable() == 0
    assert c.reclaim(2) == 0
    a.free(pages[:2])
    assert c.reclaim(2) == 2
    assert a.in_use == 0


# ---------------------------------------------------------------------------
# Engine: suffix-only prefill, bit-match, accounting
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("quant,backend", [
    ("dense", "xla"), ("dense", "pallas"),
    ("w8a8_nibble", "xla"), ("w8a8_nibble", "pallas"),
])
def test_shared_prefix_bitmatch_and_suffix_only_prefill(quant, backend):
    """The acceptance scenario: two requests sharing a page-aligned
    prompt head through a prefix-cache engine BIT-match the uncached
    engine's streams, the second admission prefills only its suffix
    (prefill-token accounting), both compiled programs stay single,
    and the allocator reports zero leaks once the index lets go."""
    cfg = reduced(get_config("yi-6b")).replace(quant_mode=quant,
                                               quant_backend=backend)
    params = model_init(jax.random.PRNGKey(0), cfg)
    prompts = _shared_prompts(cfg.vocab_size)   # 4-token head = 1 page
    budgets = [4, 4]

    _, want = _drive(cfg, params, prompts, budgets, _scfg())
    engine, got = _drive(cfg, params, prompts, budgets,
                         _scfg(prefix_cache=True))
    assert [r.tokens for r in got] == [r.tokens for r in want]
    # request 0 prefilled fully (6), request 1 only its 3-token suffix
    assert engine.prefill_tokens == 6 + 3
    assert engine.stats["prefix_hits"] == 1
    assert engine.compile_counts == {"prefill": 1, "decode_chunk": 1}
    assert engine.allocator.in_use == len(engine.prefix_cache.pages)
    engine.release_prefix_cache()
    assert engine.allocator.in_use == 0     # zero leaks


def test_shared_prefix_matches_solo_uncached_runs(model):
    """Each shared-prefix stream equals the same request run alone
    through an uncached engine — sharing must be observationally
    invisible."""
    cfg, params = model
    prompts = _shared_prompts(cfg.vocab_size, seed=3)
    engine, got = _drive(cfg, params, prompts, [4, 4],
                         _scfg(prefix_cache=True))
    for p, r in zip(prompts, got):
        _, solo = _drive(cfg, params, [p], [4], _scfg())
        assert r.tokens == solo[0].tokens


def test_cow_fires_exactly_on_fully_covered_prompt(model):
    """A prompt fully covered by cached pages triggers exactly one
    copy-on-write page duplication (the partial tail page), prefills
    exactly one token, and still bit-matches the uncached engine."""
    cfg, params = model
    rng = np.random.default_rng(7)
    p = jnp.asarray(rng.integers(0, cfg.vocab_size, 8), jnp.int32)

    _, want = _drive(cfg, params, [p, p], [4, 4], _scfg())
    engine, got = _drive(cfg, params, [p, p], [4, 4],
                         _scfg(prefix_cache=True))
    assert [r.tokens for r in got] == [r.tokens for r in want]
    assert engine.cow_copies == 1
    assert engine.prefill_tokens == 8 + 1   # full prompt, then one token
    # partial hits never COW: a 6-token prompt over 4-token pages leaves
    # a 2-token uncached tail that lands on a private page anyway
    engine2, _ = _drive(cfg, params,
                        [jnp.asarray(np.asarray(p)[:6], jnp.int32)] * 2,
                        [4, 4], _scfg(prefix_cache=True))
    assert engine2.cow_copies == 0
    assert engine2.prefill_tokens == 6 + 2


def test_cow_leaves_shared_page_intact_for_other_holder(model):
    """After a COW admission writes into its private copy, a third
    request hitting the same prefix still reads the original cached
    page — its stream must stay identical."""
    cfg, params = model
    rng = np.random.default_rng(11)
    p = jnp.asarray(rng.integers(0, cfg.vocab_size, 8), jnp.int32)
    _, want = _drive(cfg, params, [p] * 3, [4] * 3,
                     _scfg(batch=1))        # one slot: strictly serial
    engine, got = _drive(cfg, params, [p] * 3, [4] * 3,
                         _scfg(batch=1, prefix_cache=True))
    assert [r.tokens for r in got] == [r.tokens for r in want]
    assert engine.cow_copies == 2           # admissions 2 and 3
    engine.release_prefix_cache()
    assert engine.allocator.in_use == 0


def test_paged_flash_engine_shared_prefix(model):
    """The Pallas paged-decode path (attn_impl=flash) over shared
    prefix pages: greedy streams must equal the uncached flash
    engine's (argmax is stable across the prefill summation orders on
    this model, as in test_paging's flash e2e)."""
    cfg, params = model
    fcfg = cfg.replace(attn_impl="flash")
    prompts = _shared_prompts(cfg.vocab_size, seed=5)
    _, want = _drive(fcfg, params, prompts, [4, 4], _scfg())
    engine, got = _drive(fcfg, params, prompts, [4, 4],
                         _scfg(prefix_cache=True))
    assert [r.tokens for r in got] == [r.tokens for r in want]
    assert engine.stats["prefix_hits"] == 1


def test_mla_shared_prefix_bitmatch():
    """Latent-cache (deepseek MLA) pools share prefix pages too: the
    spliced latents decompress bit-identically."""
    cfg = reduced(get_config("deepseek-v3-671b"))
    params = model_init(jax.random.PRNGKey(0), cfg)
    prompts = _shared_prompts(cfg.vocab_size, seed=9)
    _, want = _drive(cfg, params, prompts, [4, 4], _scfg())
    engine, got = _drive(cfg, params, prompts, [4, 4],
                         _scfg(prefix_cache=True))
    assert [r.tokens for r in got] == [r.tokens for r in want]
    engine.release_prefix_cache()
    assert engine.allocator.in_use == 0


# ---------------------------------------------------------------------------
# Preemption interplay: evict-while-shared, reclaim under pressure
# ---------------------------------------------------------------------------

def test_evict_while_shared_survivor_keeps_pages(model):
    """Preempting a request whose prefix pages are shared must not
    yank them from the other holder: the survivor's stream and the
    victim's resumed stream both bit-match the uncached engine, and
    every page comes back once the index releases."""
    cfg, params = model
    rng = np.random.default_rng(13)
    head = rng.integers(0, cfg.vocab_size, 4)
    prompts = [jnp.asarray(np.concatenate(
        [head, rng.integers(0, cfg.vocab_size, 3)]), jnp.int32)
        for _ in range(3)]
    budgets = [8, 8, 8]
    # capacity 9 < 3 × 4-page worst case: incremental top-ups run the
    # pool dry and preempt a sharing runner mid-stream
    over = dict(batch=3, max_len=16, num_pages=10,
                alloc_mode="incremental")
    _, want = _drive(cfg, params, prompts, budgets,
                     _scfg(cache_mode="dense", page_size=None, batch=3))
    engine, got = _drive(cfg, params, prompts, budgets,
                         _scfg(prefix_cache=True, **over))
    assert engine.preemptions > 0           # the scenario actually fired
    assert [r.tokens for r in got] == [r.tokens for r in want]
    engine.release_prefix_cache()
    assert engine.allocator.in_use == 0
    assert engine.allocator.available == engine.allocator.capacity


def test_cold_prefix_pages_reclaimed_under_pressure(model):
    """Distinct prompts through a small pool: index entries pinned by
    nobody else are reclaimed LRU-first instead of blocking admission,
    and the run drains without a stall."""
    cfg, params = model
    rng = np.random.default_rng(17)
    prompts = [jnp.asarray(rng.integers(0, cfg.vocab_size, 6), jnp.int32)
               for _ in range(4)]
    # capacity 4 fits one 2-page request plus its pages' index pins —
    # each admission must reclaim the previous request's cold entries
    engine, got = _drive(cfg, params, prompts, [4] * 4,
                         _scfg(batch=1, num_pages=5, prefix_cache=True))
    assert all(len(r.tokens) == 4 for r in got)
    _, want = _drive(cfg, params, prompts, [4] * 4, _scfg(batch=1))
    assert [r.tokens for r in got] == [r.tokens for r in want]
    engine.release_prefix_cache()
    assert engine.allocator.in_use == 0


@given(n_chunks=st.integers(1, 6), host_pages=st.integers(1, 8))
@settings(max_examples=40, deadline=None)
def test_cold_tier_demote_promote_roundtrip(n_chunks, host_pages):
    """Property: reclaiming an ``n_chunks`` hash chain through a
    ``host_pages``-deep cold tier always leaves a contiguous head run
    of ``min(n_chunks, host_pages)`` cold entries (overflow kills the
    oldest demotions — the leaf-most chunks), every promoted payload
    reads back exactly what demotion stored, and the full round trip
    leaks neither device nor host pages."""
    from repro.serve.paging import HostPagePool

    alloc = PageAllocator(16, reserved=1)
    host = HostPagePool(host_pages)
    cache = PrefixCache(2, alloc)
    stored = {}

    def demote(page):
        hid = host.alloc(1)
        if hid is None:
            return None
        host.store(hid[0], ("rows-of", page))
        stored[hid[0]] = ("rows-of", page)
        return hid[0]

    cache.attach_cold_tier(demote, lambda hid: host.free([hid]))

    keys = cache.chunk_keys(np.arange(n_chunks * 2, dtype=np.int64))
    assert len(keys) == n_chunks
    pages = alloc.alloc(n_chunks)
    cache.insert(keys, pages)
    alloc.free(pages)                   # cache now holds the only refs

    freed = cache.reclaim(n_chunks)
    assert freed == n_chunks
    assert alloc.in_use == 0            # device side fully released
    n_cold = min(n_chunks, host_pages)
    assert cache.cold_size == n_cold
    assert host.in_use == n_cold
    # leaf-first demotion + oldest-first overflow keeps the chain head
    assert cache.match_cold(keys, 0) == n_cold

    hids = cache.pop_cold(keys[:n_cold])
    for hid in hids:
        assert host.load(hid) == stored[hid]
        host.free([hid])
    assert cache.cold_size == 0
    assert host.in_use == 0             # host side fully released
    with pytest.raises(ValueError, match="not in the cold index"):
        cache.pop_cold(keys[:1])
    cache.drop()
    assert alloc.in_use == 0


def test_resume_after_eviction_hits_own_prefix(model):
    """A preempted request's indexed prompt chunks survive its
    eviction, so its teacher-forced resume re-prefills only the
    uncached tail — visible as fewer prefill tokens than two full
    prompts."""
    cfg, params = model
    rng = np.random.default_rng(19)
    p_hi = jnp.asarray(rng.integers(0, cfg.vocab_size, 5), jnp.int32)
    p_lo = jnp.asarray(rng.integers(0, cfg.vocab_size, 8), jnp.int32)
    engine = Engine(cfg, params, _scfg(batch=1, prefix_cache=True,
                                       alloc_mode="incremental",
                                       num_pages=5))
    lo = engine.submit(p_lo, 8)
    hi = engine.submit(p_hi, 4, arrival=0.01, priority=5)
    done = engine.run()
    assert done[lo].preemptions >= 1
    # lo prefilled 8 fresh + resumed via its cached 2 full chunks: the
    # resume's suffix is < 8 tokens
    assert engine.prefill_tokens < 8 + len(p_hi) + 8
    engine.release_prefix_cache()
    assert engine.allocator.in_use == 0


# ---------------------------------------------------------------------------
# Config gating + workload plumbing
# ---------------------------------------------------------------------------

def test_exact_length_prefill_shared_prefix_bitmatch(model):
    """prefill_len=0 (exact-length prefill, the ServeConfig default):
    the suffix buffer must pad to the FULL prompt length so the context
    splice spans every cached key position — regression for the short
    sfx_len buffer that rolled the fresh keys off the end."""
    cfg, params = model
    prompts = _shared_prompts(cfg.vocab_size, seed=21)
    _, want = _drive(cfg, params, prompts, [4, 4], _scfg(prefill_len=0))
    engine, got = _drive(cfg, params, prompts, [4, 4],
                         _scfg(prefill_len=0, prefix_cache=True))
    assert [r.tokens for r in got] == [r.tokens for r in want]
    assert engine.stats["prefix_hits"] == 1
    engine.release_prefix_cache()
    assert engine.allocator.in_use == 0


def test_prefix_cache_requires_paged(model):
    cfg, params = model
    with pytest.raises(ValueError, match="requires"):
        Engine(cfg, params, _scfg(cache_mode="dense", page_size=None,
                                  prefix_cache=True))


def test_prefix_cache_rejects_mamba_and_int8_kv():
    cfg = reduced(get_config("jamba-v0.1-52b"))
    params = model_init(jax.random.PRNGKey(0), cfg)
    with pytest.raises(ValueError, match="mamba"):
        Engine(cfg, params, _scfg(prefix_cache=True))
    cfg = reduced(get_config("yi-6b")).replace(kv_cache_dtype="int8")
    params = model_init(jax.random.PRNGKey(0), cfg)
    with pytest.raises(ValueError, match="int8"):
        Engine(cfg, params, _scfg(prefix_cache=True))


def test_workload_shared_prefix_reports_hit_rate(model):
    from repro.serve import run_timed_workload
    cfg, params = model
    engine = Engine(cfg, params, _scfg(batch=2, max_len=24,
                                       prefix_cache=True))
    r = run_timed_workload(engine, cfg.vocab_size, requests=6,
                           prompt_budget=8, new_tokens=3,
                           shared_prefix=1.0)
    assert r["prefix_hit_rate"] > 0.0
    assert r["prefill_tokens"] > 0

    with pytest.raises(ValueError, match="shared_prefix"):
        run_timed_workload(engine, cfg.vocab_size, requests=2,
                           prompt_budget=8, new_tokens=2,
                           shared_prefix=1.5)
