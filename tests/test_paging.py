"""Paged KV cache: allocator invariants, page-table attention parity
(paged decode must BIT-match the dense slab), recompile-free recycling,
admission backpressure, and the priority scheduler."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, reduced
from repro.models import model_init
from repro.serve import Engine, PageAllocator, PageTable, ServeConfig
from repro.serve.paging import pages_needed


def _setup(quant="dense", **cfg_over):
    cfg = reduced(get_config("yi-6b")).replace(quant_mode=quant, **cfg_over)
    params = model_init(jax.random.PRNGKey(0), cfg)
    return cfg, params


def _run_stream(cfg, params, prompts, n_new, *, cache_mode, **scfg_over):
    kw = dict(batch=3, max_len=16, prefill_len=8, decode_chunk=3)
    kw.update(scfg_over)
    engine = Engine(cfg, params, ServeConfig(**kw, cache_mode=cache_mode,
                                             page_size=4))
    ids = [engine.submit(p, n_new) for p in prompts]
    done = engine.run()
    return engine, [done[i].tokens for i in ids]


# ---------------------------------------------------------------------------
# Allocator + table units
# ---------------------------------------------------------------------------

def test_allocator_alloc_free_reuse():
    a = PageAllocator(8, reserved=1)
    assert a.capacity == 7 and a.available == 7 and a.in_use == 0
    p1 = a.alloc(3)
    assert len(p1) == 3 and len(set(p1)) == 3
    assert 0 not in p1                     # reserved trash page stays home
    assert a.available == 4 and a.in_use == 3
    a.free(p1)
    assert a.available == 7 and a.in_use == 0
    # LIFO: the freshly freed pages come back first
    p2 = a.alloc(3)
    assert set(p2) == set(p1)


def test_allocator_exhaustion_backpressure():
    a = PageAllocator(4, reserved=1)
    got = a.alloc(3)
    assert got is not None
    assert a.alloc(1) is None              # None, not an exception: defer
    assert a.available == 0
    a.free(got[:1])
    assert a.alloc(1) is not None


def test_allocator_double_free_raises():
    a = PageAllocator(4, reserved=1)
    pages = a.alloc(2)
    a.free(pages)
    with pytest.raises(ValueError, match="not currently allocated"):
        a.free(pages)
    with pytest.raises(ValueError, match="not currently allocated"):
        a.free([0])                        # reserved page was never handed out


def test_pages_needed():
    assert pages_needed(0, 4) == 0
    assert pages_needed(1, 4) == 1
    assert pages_needed(4, 4) == 1
    assert pages_needed(5, 4) == 2


def test_page_table_assign_clear():
    t = PageTable(batch=2, max_pages=4, trash_page=0)
    t.assign(0, [5, 7])
    np.testing.assert_array_equal(t.row(0), [5, 7, 0, 0])
    np.testing.assert_array_equal(t.row(1), [0, 0, 0, 0])
    t.clear(0)
    np.testing.assert_array_equal(t.row(0), [0, 0, 0, 0])
    with pytest.raises(ValueError, match="exceed"):
        t.assign(0, [1, 2, 3, 4, 5])


def test_page_table_rejects_corrupting_ids():
    """assign/extend must refuse out-of-pool, reserved, duplicate and
    cross-slot-aliased page ids — the silent-corruption class where a
    buggy caller points two slots' decode writes at one page."""
    t = PageTable(batch=2, max_pages=4, trash_page=0, num_pages=8,
                  reserved=1)
    with pytest.raises(ValueError, match="out of pool range"):
        t.assign(0, [8])
    with pytest.raises(ValueError, match="out of pool range"):
        t.assign(0, [-1])
    with pytest.raises(ValueError, match="reserved"):
        t.assign(0, [0, 2])                # trash page as a live page
    with pytest.raises(ValueError, match="duplicate"):
        t.assign(0, [3, 3])
    with pytest.raises(ValueError, match="out of range"):
        t.assign(5, [2])
    t.assign(0, [3, 4])
    with pytest.raises(ValueError, match="already live in slot 0"):
        t.assign(1, [4, 5])                # aliases slot 0's live page
    t.clear(0)
    t.assign(1, [4, 5])                    # fine once slot 0 released it


def test_page_table_extend_grows_live_prefix():
    t = PageTable(batch=2, max_pages=3, trash_page=0, num_pages=8,
                  reserved=1)
    t.assign(0, [2])
    assert t.live_len(0) == 1
    t.extend(0, [3])
    np.testing.assert_array_equal(t.row(0), [2, 3, 0])
    assert t.live_len(0) == 2
    with pytest.raises(ValueError, match="already live in slot 0"):
        t.extend(0, [2])                   # duplicate within own row
    with pytest.raises(ValueError, match="already live in slot 0"):
        t.extend(1, [3])                   # cross-slot alias
    with pytest.raises(ValueError, match="exceeds the per-slot"):
        t.extend(0, [4, 5])                # 2 + 2 > max_pages 3
    t.clear(0)
    assert t.live_len(0) == 0


def test_page_table_truncate_returns_tail_and_repoints_trash():
    t = PageTable(batch=2, max_pages=4, trash_page=0, num_pages=8,
                  reserved=1)
    t.assign(0, [2, 3, 4])
    removed = t.truncate(0, 1)
    assert removed == [3, 4]               # tail pages, table order
    np.testing.assert_array_equal(t.row(0), [2, 0, 0, 0])
    assert t.live_len(0) == 1
    # the trash entries are dead, not live: extending re-grows from the
    # truncation point
    t.extend(0, [5])
    np.testing.assert_array_equal(t.row(0), [2, 5, 0, 0])


def test_page_table_truncate_noop_and_validation():
    t = PageTable(batch=1, max_pages=3, trash_page=0, num_pages=8,
                  reserved=1)
    t.assign(0, [2, 3])
    assert t.truncate(0, 2) == []          # keep >= live: nothing freed
    assert t.truncate(0, 5) == []
    assert t.live_len(0) == 2
    with pytest.raises(ValueError, match="cannot truncate"):
        t.truncate(0, -1)
    assert t.truncate(0, 0) == [2, 3]      # full rollback of the row
    assert t.live_len(0) == 0


def test_truncate_free_cycle_no_leak_no_trash_violation():
    # rollback protocol: truncate the table, free exactly the removed
    # ids — the pool must return to balance and the trash page must
    # never enter the free list
    a = PageAllocator(8, reserved=1)
    t = PageTable(batch=1, max_pages=6, trash_page=0, num_pages=8,
                  reserved=1)
    pages = a.alloc(5)
    t.assign(0, pages)
    removed = t.truncate(0, 2)
    assert removed == pages[2:]
    a.free(removed)
    assert a.in_use == 2 and a.available == 5
    with pytest.raises(ValueError):        # freed tail cannot double-free
        a.free(removed[:1])
    assert 0 not in a.alloc(5)             # trash page still reserved


def test_truncate_preserves_refcounted_shared_pages():
    # a rollback in one slot must never free pages another holder still
    # shares (prefix-cache pages sit below any rollback target, but the
    # allocator-level invariant is what guarantees it)
    a = PageAllocator(8, reserved=1)
    t = PageTable(batch=2, max_pages=4, trash_page=0, num_pages=8,
                  reserved=1)
    shared = a.alloc(1)
    a.share(shared)                        # second holder
    priv = a.alloc(2)
    t.assign(0, shared + priv, shared=set(shared))
    t.assign(1, shared, shared=set(shared))  # other slot, read-only
    removed = t.truncate(0, 1)             # roll slot 0 back to shared
    assert removed == priv
    a.free(removed)
    a.free(shared)                         # slot 0's reference
    assert a.in_use == 1                   # survives for slot 1
    np.testing.assert_array_equal(t.row(1)[:1], shared)
    a.free(shared)
    assert a.in_use == 0


# ---------------------------------------------------------------------------
# Paged decode parity: BIT-identical to the dense slab
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("quant,backend", [
    ("dense", "xla"), ("dense", "pallas"),
    ("w8a8_nibble", "xla"), ("w8a8_nibble", "pallas"),
])
def test_paged_matches_dense_bitwise(quant, backend):
    """Same request stream through a dense-slab engine and a paged
    engine: after the page gather the attention math is shape- and
    value-identical, so greedy decode must BIT-match."""
    cfg, params = _setup(quant, quant_backend=backend)
    rng = np.random.default_rng(0)
    prompts = [jnp.asarray(rng.integers(0, cfg.vocab_size, p), jnp.int32)
               for p in (3, 5, 7)]
    _, want = _run_stream(cfg, params, prompts, 4, cache_mode="dense")
    engine, got = _run_stream(cfg, params, prompts, 4, cache_mode="paged")
    assert got == want, (quant, backend, got, want)
    assert engine.allocator.in_use == 0    # every page returned


def test_paged_int8_kv_matches_dense():
    """The int8 KV cache quantizes identically through pool scatter and
    slab scatter — still bit-exact between the two layouts."""
    cfg, params = _setup(kv_cache_dtype="int8")
    rng = np.random.default_rng(1)
    prompts = [jnp.asarray(rng.integers(0, cfg.vocab_size, p), jnp.int32)
               for p in (4, 6)]
    _, want = _run_stream(cfg, params, prompts, 4, cache_mode="dense",
                          batch=2)
    _, got = _run_stream(cfg, params, prompts, 4, cache_mode="paged",
                         batch=2)
    assert got == want


def test_paged_mla_and_hybrid_match_dense():
    """MLA latent pools (deepseek) and the mamba/attn hybrid (jamba,
    exact-length prefill + per-slot SSM state next to paged attention
    layers) both bit-match their dense duals."""
    rng = np.random.default_rng(2)
    for arch in ("deepseek-v3-671b", "jamba-v0.1-52b"):
        cfg = reduced(get_config(arch))
        params = model_init(jax.random.PRNGKey(0), cfg)
        prompts = [jnp.asarray(rng.integers(0, cfg.vocab_size, p),
                               jnp.int32) for p in (3, 6)]
        _, want = _run_stream(cfg, params, prompts, 4, cache_mode="dense",
                              batch=2)
        _, got = _run_stream(cfg, params, prompts, 4, cache_mode="paged",
                             batch=2)
        assert got == want, arch


# ---------------------------------------------------------------------------
# Recycling: refill + page reuse without recompiles or leaks
# ---------------------------------------------------------------------------

def test_paged_refill_no_recompile_no_leak():
    """More requests than slots with mixed lengths/budgets: slots refill
    onto RECYCLED pages (the pool is sized so late requests must reuse
    early requests' pages) with both compiled programs intact, every
    page returned, and output equal to the dense engine's."""
    cfg, params = _setup()
    rng = np.random.default_rng(1)
    spec = [(4, 6), (8, 3), (5, 7), (6, 1), (3, 5)]
    prompts = [jnp.asarray(rng.integers(0, cfg.vocab_size, p), jnp.int32)
               for p, _ in spec]

    def drive(cache_mode, num_pages=None):
        engine = Engine(cfg, params, ServeConfig(
            batch=2, max_len=24, prefill_len=8, decode_chunk=4,
            cache_mode=cache_mode, page_size=4, num_pages=num_pages))
        ids = [engine.submit(p, n) for p, (_, n) in zip(prompts, spec)]
        done = engine.run()
        return engine, [done[i].tokens for i in ids]

    _, want = drive("dense")
    # 13 pages = trash + two concurrent worst-case requests (2 × 6);
    # five requests therefore cannot run without recycling
    engine, got = drive("paged", num_pages=13)
    assert got == want
    assert engine.compile_counts == {"prefill": 1, "decode_chunk": 1}
    assert engine.allocator.in_use == 0
    assert engine.allocator.available == engine.allocator.capacity


def test_paged_admission_backpressure_serializes():
    """A pool that only fits one request at a time: admission defers
    instead of OOMing, every request still completes, and the decode
    stream is unchanged from the roomy-pool run."""
    cfg, params = _setup()
    rng = np.random.default_rng(3)
    prompts = [jnp.asarray(rng.integers(0, cfg.vocab_size, 5), jnp.int32)
               for _ in range(3)]

    def drive(num_pages):
        engine = Engine(cfg, params, ServeConfig(
            batch=3, max_len=16, prefill_len=8, decode_chunk=3,
            cache_mode="paged", page_size=4, num_pages=num_pages))
        ids = [engine.submit(p, 4) for p in prompts]
        done = engine.run()
        return engine, [done[i].tokens for i in ids]

    # pages_for(5 prompt + 4 new) = ceil(8/4) = 2 → capacity 2 fits one
    _, want = drive(num_pages=None)        # roomy auto pool
    engine, got = drive(num_pages=3)
    assert got == want
    assert engine.allocator.in_use == 0


def test_paged_request_too_big_for_pool_raises():
    cfg, params = _setup()
    engine = Engine(cfg, params, ServeConfig(
        batch=1, max_len=16, prefill_len=8, decode_chunk=2,
        cache_mode="paged", page_size=4, num_pages=2))
    with pytest.raises(ValueError, match="pool"):
        engine.submit(jnp.asarray([1, 2, 3, 4, 5], jnp.int32), 8)


def test_paged_cache_rows_scale_with_live_tokens():
    """The HBM claim: a short request reserves only its pages (prompt +
    decode-written rows rounded to page_size), not the max_len slab."""
    cfg, params = _setup()
    engine = Engine(cfg, params, ServeConfig(
        batch=1, max_len=32, prefill_len=8, decode_chunk=2,
        cache_mode="paged", page_size=4))
    rid = engine.submit(jnp.asarray([1, 2, 3], jnp.int32), 2)
    done = engine.run()
    # 3 prompt rows + 1 decode write = 4 rows → exactly 1 page
    assert done[rid].cache_rows == 4
    dense = Engine(cfg, params, ServeConfig(batch=1, max_len=32,
                                            prefill_len=8, decode_chunk=2))
    rid = dense.submit(jnp.asarray([1, 2, 3], jnp.int32), 2)
    assert dense.run()[rid].cache_rows == 32
    # same per-token bytes either way: the layout moves rows, not widths
    assert engine.cache_token_bytes == dense.cache_token_bytes


def test_paged_vs_dense_hbm_per_request():
    """Workload-level accounting: cache_kb_per_req in paged mode sits
    measurably below the dense max_len slab on short requests."""
    from repro.serve import run_timed_workload
    cfg, params = _setup()

    def measure(cache_mode):
        engine = Engine(cfg, params, ServeConfig(
            batch=2, max_len=32, prefill_len=8, decode_chunk=4,
            cache_mode=cache_mode, page_size=4))
        return run_timed_workload(engine, cfg.vocab_size, requests=4,
                                  prompt_budget=8, new_tokens=4)

    dense = measure("dense")
    paged = measure("paged")
    # dense reserves 32 rows/request; paged at most ceil(11/4)=3 pages
    # = 12 rows
    assert paged["cache_kb_per_req"] < dense["cache_kb_per_req"] / 2


# ---------------------------------------------------------------------------
# Pallas paged-decode kernel (fast path)
# ---------------------------------------------------------------------------

def test_paged_flash_decode_kernel_matches_gather_reference():
    from repro.kernels.ops import paged_flash_decode
    from repro.models.attention import attention_core, gather_pages
    rng = np.random.default_rng(0)
    b, kvh, g, d, num_pages, ps, mp = 3, 2, 2, 16, 13, 4, 4
    h = kvh * g
    q = jnp.asarray(rng.standard_normal((b, 1, h, d)), jnp.float32)
    k_pool = jnp.asarray(rng.standard_normal((num_pages, ps, kvh, d)),
                         jnp.float32)
    v_pool = jnp.asarray(rng.standard_normal((num_pages, ps, kvh, d)),
                         jnp.float32)
    table = jnp.asarray(rng.permutation(num_pages)[:b * mp]
                        .reshape(b, mp), jnp.int32)
    q_pos = jnp.asarray([3, 7, 14], jnp.int32)

    out = paged_flash_decode(q, k_pool, v_pool, table, q_pos, scale=0.25)
    k_full = gather_pages(k_pool, table)
    v_full = gather_pages(v_pool, table)
    k_pos = jnp.broadcast_to(jnp.arange(mp * ps)[None], (b, mp * ps))
    ref = attention_core(q, k_full, v_full, q_pos[:, None], k_pos,
                         scale=0.25, causal=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=1e-5, rtol=1e-5)


def test_paged_flash_engine_end_to_end():
    """attn_impl=flash routes paged decode through the page-walking
    Pallas kernel; the engine must still produce the same greedy stream
    as the XLA gather reference (same math, flash summation order —
    greedy argmax is stable across the two on this model)."""
    cfg, params = _setup(attn_impl="flash")
    rng = np.random.default_rng(4)
    prompts = [jnp.asarray(rng.integers(0, cfg.vocab_size, p), jnp.int32)
               for p in (3, 6)]
    ref_cfg, _ = _setup()                  # chunked reference
    _, want = _run_stream(ref_cfg, params, prompts, 4, cache_mode="paged",
                          batch=2)
    _, got = _run_stream(cfg, params, prompts, 4, cache_mode="paged",
                         batch=2)
    assert got == want


# ---------------------------------------------------------------------------
# Priority scheduler
# ---------------------------------------------------------------------------

def test_priority_orders_admission():
    """With one slot, the high-priority request is admitted first even
    though it was submitted last."""
    cfg, params = _setup()
    engine = Engine(cfg, params, ServeConfig(batch=1, max_len=16,
                                             prefill_len=8, decode_chunk=2))
    rng = np.random.default_rng(5)
    lo = [engine.submit(jnp.asarray(rng.integers(0, cfg.vocab_size, 4),
                                    jnp.int32), 3) for _ in range(2)]
    hi = engine.submit(jnp.asarray(rng.integers(0, cfg.vocab_size, 4),
                                   jnp.int32), 3, priority=5)
    done = engine.run()
    assert done[hi].t_first < min(done[i].t_first for i in lo)
    # equal-priority requests keep FIFO order (arrival, then submission)
    assert done[lo[0]].t_first < done[lo[1]].t_first


def test_priority_aging_prevents_starvation():
    """_PriorityQueue unit: with aging, a long-waiting low-priority
    request eventually outranks a fresh high-priority one."""
    from repro.serve.engine import _PriorityQueue, Request

    def req(rid, prio, arrival):
        return Request(id=rid, prompt=np.zeros(1, np.int32),
                       max_new_tokens=1, arrival=arrival, priority=prio)

    q = _PriorityQueue(aging_s=1.0)
    q.push(req(0, 0, arrival=0.0))
    q.push(req(1, 3, arrival=9.5))
    # at t=10 the low-priority request has aged +10 levels > 3
    assert q.pop(10.0).id == 0
    assert q.pop(10.0).id == 1

    q2 = _PriorityQueue(aging_s=0.0)       # aging off: strict priority
    q2.push(req(0, 0, arrival=0.0))
    q2.push(req(1, 3, arrival=0.0))
    assert q2.pop(10.0).id == 1

    # arrival gating: the future request is invisible
    q3 = _PriorityQueue()
    q3.push(req(0, 5, arrival=99.0))
    q3.push(req(1, 0, arrival=0.0))
    assert q3.pop(1.0).id == 1
    assert q3.pop(1.0) is None


def test_priority_backpressure_veto_keeps_request():
    from repro.serve.engine import _PriorityQueue, Request
    q = _PriorityQueue()
    r = Request(id=0, prompt=np.zeros(1, np.int32), max_new_tokens=1)
    q.push(r)
    assert q.pop(0.0, admit=lambda _: False) is None
    assert len(q) == 1                     # vetoed, not dropped
    assert q.pop(0.0).id == 0


# ---------------------------------------------------------------------------
# Workload input validation (serve/workload.py bugfix)
# ---------------------------------------------------------------------------

def test_workload_validates_inputs():
    from repro.serve import run_timed_workload

    class _StubEngine:                     # never reached past validation
        pass

    with pytest.raises(ValueError, match="requests must be >= 1"):
        run_timed_workload(_StubEngine(), 256, requests=0,
                           prompt_budget=8, new_tokens=4)
    with pytest.raises(ValueError, match="prompt_budget must be >= 2"):
        run_timed_workload(_StubEngine(), 256, requests=4,
                           prompt_budget=1, new_tokens=4)
    with pytest.raises(ValueError, match="new_tokens"):
        run_timed_workload(_StubEngine(), 256, requests=4,
                           prompt_budget=8, new_tokens=0)
    with pytest.raises(ValueError, match="priority_mix"):
        run_timed_workload(_StubEngine(), 256, requests=4,
                           prompt_budget=8, new_tokens=4,
                           priority_mix=1.5)


# ---------------------------------------------------------------------------
# Engine config validation
# ---------------------------------------------------------------------------

def test_paged_engine_validates_page_geometry():
    cfg, params = _setup()
    with pytest.raises(ValueError, match="multiple of page_size"):
        Engine(cfg, params, ServeConfig(batch=1, max_len=18,
                                        cache_mode="paged", page_size=4))
    with pytest.raises(ValueError, match="cache_mode"):
        Engine(cfg, params, ServeConfig(batch=1, max_len=16,
                                        cache_mode="sparse"))


def test_make_serve_step_rejects_paged():
    from repro.serve import make_serve_step
    cfg, _ = _setup()
    with pytest.raises(ValueError, match="paged"):
        make_serve_step(cfg, ServeConfig(batch=1, max_len=16,
                                         cache_mode="paged", page_size=4))
