"""staticcheck gate: rule units (violation + clean twin per rule),
whole-tree pass on HEAD, and the static-flops-vs-cycle-model tolerance
check on the benched shapes."""

import json
import subprocess
import sys
import textwrap
import warnings
from pathlib import Path

import pytest

jax = pytest.importorskip("jax")
import jax.numpy as jnp  # noqa: E402

from repro.serve.engine import _CountingJit  # noqa: E402
from repro.staticcheck import jaxpr_rules, runner  # noqa: E402
from repro.staticcheck.ast_rules import run_ast_rules  # noqa: E402
from repro.staticcheck.findings import (Finding, apply_baseline,  # noqa: E402
                                        load_baseline)
from repro.staticcheck.flops import walk_jaxpr  # noqa: E402
from repro.core.cycle_model import cycles_per_operand  # noqa: E402

REPO = Path(__file__).resolve().parent.parent


# ---------------------------------------------------------------------------
# AST rule units: each rule flags an injected violation and passes its
# clean twin
# ---------------------------------------------------------------------------

def _lint(tmp_path, source, relname="src/repro/mod.py"):
    p = tmp_path / relname
    p.parent.mkdir(parents=True, exist_ok=True)
    p.write_text(textwrap.dedent(source))
    return [f.rule for f in run_ast_rules(tmp_path / "src",
                                          repo_root=tmp_path)]


def test_sc101_item_on_traced(tmp_path):
    bad = """
        import jax
        @jax.jit
        def f(x):
            return x.item()
    """
    good = """
        import jax
        @jax.jit
        def f(x):
            return x.sum()
    """
    assert "SC101" in _lint(tmp_path / "bad", bad)
    assert _lint(tmp_path / "good", good) == []


def test_sc102_cast_on_traced(tmp_path):
    bad = """
        import jax
        @jax.jit
        def f(x):
            return x * float(x[0])
    """
    good = """
        import jax
        @jax.jit
        def f(x):
            scale = float(1.5)
            return x * scale
    """
    assert "SC102" in _lint(tmp_path / "bad", bad)
    assert _lint(tmp_path / "good", good) == []


def test_sc103_numpy_on_traced(tmp_path):
    bad = """
        import jax
        import numpy as np
        @jax.jit
        def f(x):
            return np.asarray(x)
    """
    good = """
        import jax
        import numpy as np
        @jax.jit
        def f(x):
            iota = np.arange(4)
            return x + iota
    """
    assert "SC103" in _lint(tmp_path / "bad", bad)
    assert _lint(tmp_path / "good", good) == []


def test_sc104_branch_on_traced(tmp_path):
    bad = """
        import jax
        @jax.jit
        def f(x):
            if x > 0:
                return x
            return -x
    """
    good_shape = """
        import jax
        @jax.jit
        def f(x):
            if x.shape[0] > 1:
                return x
            return -x
    """
    good_static = """
        import functools
        import jax
        @functools.partial(jax.jit, static_argnames=("flag",))
        def f(x, flag):
            if flag:
                return x
            return -x
    """
    good_none = """
        import jax
        @jax.jit
        def f(x, y=None):
            if y is not None:
                return x + y
            return x
    """
    assert "SC104" in _lint(tmp_path / "bad", bad)
    assert _lint(tmp_path / "g1", good_shape) == []
    assert _lint(tmp_path / "g2", good_static) == []
    assert _lint(tmp_path / "g3", good_none) == []


def test_sc105_host_sync_in_serve(tmp_path):
    bad = """
        import jax
        def step(x):
            return jax.device_get(x)
    """
    good = """
        import numpy as np
        def step(x):
            return np.asarray(x)
    """
    rel = "src/repro/serve/stepper.py"
    assert "SC105" in _lint(tmp_path / "bad", bad, rel)
    assert _lint(tmp_path / "good", good, rel) == []
    # outside serve/ the same code is not an engine step path
    assert _lint(tmp_path / "other", bad, "src/repro/launch/x.py") == []


def test_sc201_cache_jit_must_donate(tmp_path):
    bad = """
        import jax
        def fwd(params, caches, tok):
            return tok, caches
        fn = jax.jit(fwd)
    """
    bad_idx = """
        import jax
        def fwd(params, caches, tok):
            return tok, caches
        fn = jax.jit(fwd, donate_argnums=0)
    """
    good = """
        import jax
        def fwd(params, caches, tok):
            return tok, caches
        fn = jax.jit(fwd, donate_argnums=1)
    """
    assert "SC201" in _lint(tmp_path / "bad", bad)
    assert "SC201" in _lint(tmp_path / "bad_idx", bad_idx)
    assert _lint(tmp_path / "good", good) == []


def test_sc202_paging_stays_numpy(tmp_path):
    bad = """
        import jax.numpy as jnp
        def alloc(n):
            return jnp.zeros(n)
    """
    good = """
        import numpy as np
        def alloc(n):
            return np.zeros(n)
    """
    rel = "src/repro/serve/paging.py"
    assert "SC202" in _lint(tmp_path / "bad", bad, rel)
    assert _lint(tmp_path / "good", good, rel) == []


# ---------------------------------------------------------------------------
# jaxpr rule units
# ---------------------------------------------------------------------------

def test_sc301_quant_widening():
    def bad(x_q, w):
        return x_q.astype(jnp.float32) @ w.astype(jnp.float32)

    def good(x_q, w):
        out = jax.lax.dot_general(x_q, w, (((1,), (0,)), ((), ())),
                                  preferred_element_type=jnp.int32)
        return out.astype(jnp.float32)

    x = jax.ShapeDtypeStruct((8, 16), "int8")
    w = jax.ShapeDtypeStruct((16, 4), "int8")
    bad_f = jaxpr_rules.check_quant_widening(
        jax.jit(bad).trace(x, w).jaxpr, "t", "bad")
    good_f = jaxpr_rules.check_quant_widening(
        jax.jit(good).trace(x, w).jaxpr, "t", "good")
    assert {f.rule for f in bad_f} == {"SC301"}
    assert good_f == []


def test_sc302_dead_donation():
    def dead(x, caches):
        return x + 1.0  # caches unused: donation cannot alias

    def alive(x, caches):
        return x + 1.0, {k: v + 1 for k, v in caches.items()}

    caches = {"k": jnp.ones((8,)), "v": jnp.ones((8,))}
    bad = _CountingJit(dead, donate_argnums=1)
    with warnings.catch_warnings():
        warnings.simplefilter("ignore")
        bad(jnp.ones((4,)), caches)
    f, _ = jaxpr_rules.check_stage(bad, "dead", "unit")
    assert "SC302" in {x.rule for x in f}

    good = _CountingJit(alive, donate_argnums=1)
    good(jnp.ones((4,)), caches)
    f, costs = jaxpr_rules.check_stage(good, "alive", "unit")
    assert "SC302" not in {x.rule for x in f}
    assert costs[0]["aliased_outputs"] == costs[0]["donated_leaves"] == 2


def test_sc303_callback_in_body():
    def bad(x):
        return jax.pure_callback(
            lambda v: v, jax.ShapeDtypeStruct(x.shape, x.dtype), x)

    x = jax.ShapeDtypeStruct((4,), "float32")
    bad_f = jaxpr_rules.check_callbacks(jax.jit(bad).trace(x).jaxpr,
                                        "t", "bad")
    good_f = jaxpr_rules.check_callbacks(
        jax.jit(lambda x: x * 2).trace(x).jaxpr, "t", "good")
    assert {f.rule for f in bad_f} == {"SC303"}
    assert good_f == []


def test_sc304_signature_pins():
    class FakeEngine:
        def __init__(self, stage):
            self._stage = stage

        def stage_programs(self):
            return {"decode_chunk": self._stage}

    churner = _CountingJit(lambda x: x + 1)
    churner(jnp.ones((4,)))
    churner(jnp.ones((8,)))        # second distinct signature
    f = jaxpr_rules.check_pins(FakeEngine(churner),
                               {"decode_chunk": 1}, "unit")
    assert [x.rule for x in f] == ["SC304"]

    stable = _CountingJit(lambda x: x + 1)
    stable(jnp.ones((4,)))
    stable(jnp.ones((4,)))         # same signature twice
    assert jaxpr_rules.check_pins(FakeEngine(stable),
                                  {"decode_chunk": 1}, "unit") == []


# ---------------------------------------------------------------------------
# baseline mechanics
# ---------------------------------------------------------------------------

def test_baseline_suppression_and_staleness():
    f1 = Finding("SC101", "src/a.py", "f", "msg")
    f2 = Finding("SC104", "src/b.py", "g", "msg")
    baseline = {"version": 1, "suppressions": [
        {"key": f1.key, "reason": "known"},
        {"key": "SC999:src/gone.py:h", "reason": "fixed long ago"},
    ]}
    unsup, sup, stale = apply_baseline([f1, f2], baseline)
    assert [f.rule for f in unsup] == ["SC104"]
    assert [f.rule for f in sup] == ["SC101"]
    assert stale == ["SC999:src/gone.py:h"]


def test_committed_baseline_empty_for_serve_and_kernels():
    baseline = load_baseline(REPO / "tools" / "staticcheck_baseline.json")
    for entry in baseline["suppressions"]:
        assert "src/repro/serve" not in entry["key"]
        assert "src/repro/kernels" not in entry["key"]


# ---------------------------------------------------------------------------
# whole-tree runs on HEAD
# ---------------------------------------------------------------------------

def test_ast_layer_clean_on_head():
    findings = run_ast_rules(REPO / "src" / "repro", repo_root=REPO)
    baseline = load_baseline(REPO / "tools" / "staticcheck_baseline.json")
    unsup, _sup, _stale = apply_baseline(findings, baseline)
    assert unsup == [], "\n".join(f.render() for f in unsup)


def test_cli_ast_only_exits_clean(tmp_path):
    report = tmp_path / "report.json"
    proc = subprocess.run(
        [sys.executable, str(REPO / "tools" / "staticcheck.py"),
         "--ast-only", "--report", str(report)],
        capture_output=True, text=True)
    assert proc.returncode == 0, proc.stdout + proc.stderr
    data = json.loads(report.read_text())
    assert data["findings"] == []
    assert "SC101" in data["rules"]["ast"]


# ---------------------------------------------------------------------------
# jaxpr layer on a real grid cell + the cycle-model tolerance check
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def nibble_cell():
    cell = runner.GRID_CELLS[1]
    assert cell.name == "nibble-xla"
    return cell, runner.build_cell_engine(cell)


def test_grid_cell_contracts_clean(nibble_cell):
    cell, engine = nibble_cell
    findings = jaxpr_rules.check_pins(engine, cell.expected_pins,
                                      cell.name)
    for name, stage in engine.stage_programs().items():
        f, _ = jaxpr_rules.check_stage(stage, name, cell.name)
        findings += f
    assert findings == [], "\n".join(f.render() for f in findings)


def test_static_flops_match_cycle_model(nibble_cell):
    """The jaxpr walk and the closed-form MAC model (the cycle model's
    geometry) must agree within ``runner.ANALYTIC_RTOL`` (2%) on the
    benched shapes; the cycle bridge must reproduce Table 2's W/4
    ratio."""
    cell, engine = nibble_cell
    for name, stage in engine.stage_programs().items():
        analytic = runner.analytic_stage_macs(name, cell)
        assert analytic is not None
        for sig in stage.signatures:
            cost = walk_jaxpr(stage.jit_fn.trace(
                *stage.abstract_args(sig)).jaxpr)
            rel = (abs(cost.dot_macs - analytic["total_macs"])
                   / analytic["total_macs"])
            assert rel <= runner.ANALYTIC_RTOL, (
                f"{name}: static {cost.dot_macs} vs analytic "
                f"{analytic['total_macs']} ({rel:.1%})")
            # quantized stages carry the nibble 2x-K int-dot load
            assert cost.int_dot_macs > 0
            # Table 2 bridge: nibble streams W/4=2 cycles/operand,
            # shift-add W=8 — a strict 4x cycle win at equal MACs
            from repro.staticcheck.flops import cycle_bridge
            nib = cycle_bridge(cost.dot_macs, "nibble_precompute")
            sa = cycle_bridge(cost.dot_macs, "shift_add")
            assert nib == cost.dot_macs * cycles_per_operand(
                "nibble_precompute", 8)
            assert sa == 4 * nib


def test_stage_roofline_static_front_end(nibble_cell):
    """A stage-cost row converts into roofline terms (compute/memory
    seconds, dominant bound, arithmetic intensity) without any dry-run
    artifact — the capacity model's static front-end."""
    from repro.roofline.analysis import stage_roofline
    cell, engine = nibble_cell
    stage = engine.stage_programs()["decode_chunk"]
    sig = stage.signatures[0]
    cost = walk_jaxpr(stage.jit_fn.trace(*stage.abstract_args(sig)).jaxpr)
    terms = stage_roofline(cost.to_dict())
    assert terms["compute_s"] > 0 and terms["memory_s"] > 0
    assert terms["step_s"] == max(terms["compute_s"], terms["memory_s"])
    low_intensity = terms["arithmetic_intensity"] < terms["ridge_intensity"]
    assert terms["dominant"] == ("memory" if low_intensity else "compute")


def test_static_bytes_bracket_xla(nibble_cell):
    """Static io_bytes (top-level avals) is a floor on XLA's reported
    bytes-accessed for every stage signature."""
    cell, engine = nibble_cell
    for name, stage in engine.stage_programs().items():
        for sig in stage.signatures:
            args = stage.abstract_args(sig)
            cost = walk_jaxpr(stage.jit_fn.trace(*args).jaxpr)
            ca = stage.jit_fn.lower(*args).compile().cost_analysis()
            if isinstance(ca, (list, tuple)):
                ca = ca[0] if ca else {}
            xla_bytes = float(ca.get("bytes accessed", 0.0) or 0.0)
            if xla_bytes:
                assert cost.io_bytes <= xla_bytes * 1.5, name
            xla_flops = float(ca.get("flops", 0.0) or 0.0)
            if xla_flops:
                assert (cost.scan_once_flops * 0.5 <= xla_flops
                        <= cost.total_flops * 1.5), name
