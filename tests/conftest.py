"""Pytest config: no XLA device-count fakery here — smoke tests and
benches must see the real (single) CPU device; only the dry-run and
explicitly-marked subprocess tests use placeholder device counts.

``hypothesis`` is an optional dev dependency (see requirements-dev.txt).
When it is missing we install a stub into ``sys.modules`` before test
modules import it, so property-based tests *skip* instead of erroring
the whole collection.  Those are the only perma-skips in the suite
(audited: 9 ``@given`` property tests across test_attention /
test_kernels / test_moe_mamba / test_multipliers / test_nibble); CI
installs requirements-dev.txt, so there the stub must never fire — the
report header below and ``-rs`` in the CI pytest invocation make any
regression of that visible instead of silently shrinking coverage.
"""


import sys
import types

import pytest

_HYPOTHESIS_STUBBED = False


def pytest_configure(config):
    config.addinivalue_line("markers", "slow: long-running integration test")


def pytest_report_header(config):
    if _HYPOTHESIS_STUBBED:
        return ("hypothesis: NOT INSTALLED — property-based tests will "
                "skip (pip install -r requirements-dev.txt)")
    return "hypothesis: installed (property-based tests run)"


try:
    import hypothesis  # noqa: F401
except ImportError:  # pragma: no cover - exercised only without hypothesis
    def _given(*_a, **_k):
        def deco(fn):
            # zero-arg wrapper (no functools.wraps: pytest must not see
            # the strategy parameters, or it hunts for fixtures)
            def wrapper():
                pytest.skip("hypothesis not installed "
                            "(pip install -r requirements-dev.txt)")
            wrapper.__name__ = fn.__name__
            wrapper.__doc__ = fn.__doc__
            return wrapper
        return deco

    def _settings(*_a, **_k):
        def deco(fn):
            return fn
        return deco

    class _FakeStrategy:
        """Chainable stand-in: absorbs .filter/.map/... at collect time."""

        def __getattr__(self, name):
            def chain(*_a, **_k):
                return self
            return chain

    class _Strategies(types.ModuleType):
        def __getattr__(self, name):
            def strategy(*_a, **_k):
                return _FakeStrategy()
            return strategy

    _HYPOTHESIS_STUBBED = True
    _hyp = types.ModuleType("hypothesis")
    _hyp.given = _given
    _hyp.settings = _settings
    _hyp.strategies = _Strategies("hypothesis.strategies")
    sys.modules["hypothesis"] = _hyp
    sys.modules["hypothesis.strategies"] = _hyp.strategies
