"""Pytest config: no XLA device-count fakery here — smoke tests and
benches must see the real (single) CPU device; only the dry-run and
explicitly-marked subprocess tests use placeholder device counts."""

import pytest


def pytest_configure(config):
    config.addinivalue_line("markers", "slow: long-running integration test")
