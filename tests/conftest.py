"""Pytest config: no XLA device-count fakery here — smoke tests and
benches must see the real (single) CPU device; only the dry-run and
explicitly-marked subprocess tests use placeholder device counts.

``hypothesis`` is a dev dependency (see requirements-dev.txt) and the
property-based tests (9 ``@given`` properties across test_substrate /
test_attention / test_quantize / test_kernels / test_moe_mamba /
test_multipliers / test_nibble) always *execute*.  When the wheel is
missing we install a **mini-runner** into ``sys.modules`` before test
modules import it: deterministic seeded draws, boundary values first
(min, max, 0, 1, empty/full list lengths), bounded ``.filter``
retries, and a reduced example budget.  No shrinking and no example
database — install the real wheel for those — but a property that
fails under the real runner fails here too, instead of silently
skipping.  CI installs requirements-dev.txt, so the fallback must
never fire there; the report header below makes a regression of that
visible.
"""


import sys
import types
import zlib

import numpy as np
import pytest

_HYPOTHESIS_FALLBACK = False

# the fallback's example budget: enough to exercise every boundary
# case plus a seeded random spread, small enough that the 200-example
# multiplier properties don't dominate the tier-1 wall clock
_MINI_MAX_EXAMPLES = 20
_MINI_FILTER_RETRIES = 100


def pytest_configure(config):
    config.addinivalue_line("markers", "slow: long-running integration test")


def pytest_report_header(config):
    if _HYPOTHESIS_FALLBACK:
        return ("hypothesis: NOT INSTALLED — property-based tests run "
                "under the built-in mini-runner (deterministic draws, "
                f"<= {_MINI_MAX_EXAMPLES} examples, no shrinking; "
                "pip install -r requirements-dev.txt for the real "
                "runner)")
    return "hypothesis: installed (property-based tests run)"


try:
    import hypothesis  # noqa: F401
except ImportError:  # pragma: no cover - exercised only without hypothesis
    class _MiniStrategy:
        """Executable stand-in for a hypothesis strategy: ``example``
        draws the ``i``-th example — boundary values for small ``i``,
        seeded random draws after (``i=None`` forces a random draw)."""

        def __init__(self, draw):
            self._draw = draw

        def example(self, rng, i):
            return self._draw(rng, i)

        def filter(self, pred):
            base = self

            def draw(rng, i):
                v = base.example(rng, i)
                for _ in range(_MINI_FILTER_RETRIES):
                    if pred(v):
                        return v
                    v = base.example(rng, None)
                raise RuntimeError(
                    "mini-hypothesis: .filter predicate rejected "
                    f"{_MINI_FILTER_RETRIES} consecutive draws")
            return _MiniStrategy(draw)

        def map(self, fn):
            base = self
            return _MiniStrategy(lambda rng, i: fn(base.example(rng, i)))

    def _mini_integers(min_value, max_value):
        bounds = []
        for b in (min_value, max_value, 0, 1):
            if min_value <= b <= max_value and b not in bounds:
                bounds.append(b)

        def draw(rng, i):
            if i is not None and i < len(bounds):
                return bounds[i]
            return int(rng.integers(min_value, max_value + 1))
        return _MiniStrategy(draw)

    def _mini_sampled_from(elements):
        seq = list(elements)

        def draw(rng, i):
            if i is not None and i < len(seq):
                return seq[i]
            return seq[int(rng.integers(len(seq)))]
        return _MiniStrategy(draw)

    def _mini_lists(elements, min_size=0, max_size=None):
        hi = max_size if max_size is not None else min_size + 10

        def draw(rng, i):
            if i == 0:
                n = min_size
            elif i == 1:
                n = hi
            else:
                n = int(rng.integers(min_size, hi + 1))
            return [elements.example(rng, None) for _ in range(n)]
        return _MiniStrategy(draw)

    def _given(*arg_strats, **kw_strats):
        def deco(fn):
            budget = getattr(fn, "_mini_settings", {}).get(
                "max_examples", _MINI_MAX_EXAMPLES)
            budget = min(budget, _MINI_MAX_EXAMPLES)

            # zero-arg wrapper (no functools.wraps: pytest must not see
            # the strategy parameters, or it hunts for fixtures)
            def wrapper():
                rng = np.random.default_rng(
                    zlib.crc32(fn.__name__.encode()))
                for i in range(budget):
                    args = [s.example(rng, i) for s in arg_strats]
                    kwargs = {k: s.example(rng, i)
                              for k, s in kw_strats.items()}
                    try:
                        fn(*args, **kwargs)
                    except Exception as exc:
                        raise AssertionError(
                            f"mini-hypothesis falsified {fn.__name__} "
                            f"on example {i}: args={args!r} "
                            f"kwargs={kwargs!r}") from exc
            wrapper.__name__ = fn.__name__
            wrapper.__doc__ = fn.__doc__
            return wrapper
        return deco

    def _settings(**kwargs):
        def deco(fn):
            fn._mini_settings = kwargs
            return fn
        return deco

    _HYPOTHESIS_FALLBACK = True
    _st = types.ModuleType("hypothesis.strategies")
    _st.integers = _mini_integers
    _st.sampled_from = _mini_sampled_from
    _st.lists = _mini_lists
    _hyp = types.ModuleType("hypothesis")
    _hyp.given = _given
    _hyp.settings = _settings
    _hyp.strategies = _st
    sys.modules["hypothesis"] = _hyp
    sys.modules["hypothesis.strategies"] = _st
