"""Substrate tests: optimizer, schedule, data, checkpoint, compression,
fault tolerance, serve engine."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.checkpoint import Checkpointer
from repro.data import DataConfig, SyntheticLM, host_batch_slice
from repro.distributed.compression import (
    compress_tree_int8,
    compressed_bytes,
    ef_compress,
)
from repro.optim import AdamWConfig, adamw_init, adamw_update, warmup_cosine
from repro.runtime import (
    HeartbeatMonitor,
    StragglerDetector,
    plan_elastic_mesh,
)


# ---------------------------------------------------------------------------
# Optimizer
# ---------------------------------------------------------------------------

def _quadratic_params():
    return {"w": jnp.array([3.0, -2.0, 1.5]), "b": jnp.array([[0.5, -0.5]])}


@pytest.mark.parametrize("quantized", [False, True])
def test_adamw_converges_on_quadratic(quantized):
    cfg = AdamWConfig(lr=0.05, weight_decay=0.0, grad_clip=0.0,
                      quantize_moments=quantized)
    params = _quadratic_params()
    state = adamw_init(params, cfg)

    def loss(p):
        return sum(jnp.sum(jnp.square(x))
                   for x in jax.tree_util.tree_leaves(p))

    for _ in range(300):
        grads = jax.grad(loss)(params)
        params, state, _ = adamw_update(params, grads, state, cfg)
    assert float(loss(params)) < 1e-2


def test_adamw_weight_decay_only_on_matrices():
    cfg = AdamWConfig(lr=0.1, weight_decay=1.0, grad_clip=0.0)
    params = {"mat": jnp.ones((2, 2)), "vec": jnp.ones((2,))}
    state = adamw_init(params, cfg)
    zero_g = jax.tree_util.tree_map(jnp.zeros_like, params)
    new, _, _ = adamw_update(params, zero_g, state, cfg)
    assert float(jnp.abs(new["mat"]).max()) < 1.0   # decayed
    assert float(jnp.abs(new["vec"]).max()) == 1.0  # untouched


def test_grad_clip_reported():
    cfg = AdamWConfig(grad_clip=1.0)
    params = {"w": jnp.zeros((4,))}
    state = adamw_init(params, cfg)
    _, _, metrics = adamw_update(params, {"w": jnp.full((4,), 100.0)},
                                 state, cfg)
    assert float(metrics["grad_norm"]) == pytest.approx(200.0)


def test_warmup_cosine_shape():
    assert float(warmup_cosine(0, warmup=10, total=100)) == 0.0
    assert float(warmup_cosine(10, warmup=10, total=100)) == pytest.approx(1.0)
    assert float(warmup_cosine(100, warmup=10, total=100)) == pytest.approx(0.1)
    mid = float(warmup_cosine(55, warmup=10, total=100))
    assert 0.1 < mid < 1.0


# ---------------------------------------------------------------------------
# Data pipeline
# ---------------------------------------------------------------------------

def test_data_deterministic_and_restartable():
    cfg = DataConfig(vocab_size=1000, seq_len=32, global_batch=8)
    a, b = SyntheticLM(cfg), SyntheticLM(cfg)
    for step in (0, 5, 17):
        np.testing.assert_array_equal(np.asarray(a.batch(step)["tokens"]),
                                      np.asarray(b.batch(step)["tokens"]))


def test_data_host_sharding_disjoint():
    cfg = DataConfig(vocab_size=1000, seq_len=16, global_batch=8)
    h0 = SyntheticLM(cfg, host_id=0, n_hosts=2)
    h1 = SyntheticLM(cfg, host_id=1, n_hosts=2)
    assert h0.local_batch == h1.local_batch == 4
    t0, t1 = h0.batch(3)["tokens"], h1.batch(3)["tokens"]
    assert not np.array_equal(np.asarray(t0), np.asarray(t1))


def test_labels_are_shifted_tokens():
    cfg = DataConfig(vocab_size=50, seq_len=16, global_batch=2)
    b = SyntheticLM(cfg).batch(0)
    np.testing.assert_array_equal(np.asarray(b["labels"][:, :-1]),
                                  np.asarray(b["tokens"][:, 1:]))
    assert int(b["labels"][0, -1]) == -1


@given(n_hosts=st.sampled_from([1, 2, 4, 8]), host=st.integers(0, 7))
@settings(max_examples=20, deadline=None)
def test_host_slices_partition_batch(n_hosts, host):
    if host >= n_hosts:
        return
    start, size = host_batch_slice(64, host, n_hosts)
    assert size == 64 // n_hosts
    assert start == host * size


# ---------------------------------------------------------------------------
# Checkpointing
# ---------------------------------------------------------------------------

def test_checkpoint_roundtrip(tmp_path):
    ck = Checkpointer(str(tmp_path), async_save=False)
    state = {"params": {"w": jnp.arange(6.0).reshape(2, 3)},
             "opt": {"step": jnp.int32(7),
                     "mu": [jnp.ones((2,)), jnp.zeros((3,))]}}
    ck.save(7, state)
    template = jax.tree_util.tree_map(jnp.zeros_like, state)
    restored, step = ck.restore(template)
    assert step == 7
    np.testing.assert_array_equal(np.asarray(restored["params"]["w"]),
                                  np.arange(6.0).reshape(2, 3))


def test_checkpoint_gc_keeps_latest(tmp_path):
    ck = Checkpointer(str(tmp_path), keep=2, async_save=False)
    state = {"w": jnp.zeros((2,))}
    for s in (1, 2, 3, 4):
        ck.save(s, state)
    assert ck.latest_step() == 4
    steps = sorted(int(n.split("_")[1]) for n in os.listdir(tmp_path)
                   if n.startswith("step_"))
    assert steps == [3, 4]


def test_checkpoint_structure_mismatch_raises(tmp_path):
    ck = Checkpointer(str(tmp_path), async_save=False)
    ck.save(1, {"a": jnp.zeros((2,))})
    with pytest.raises(ValueError, match="mismatch"):
        ck.restore({"b": jnp.zeros((2,))})


def test_checkpoint_async_save(tmp_path):
    ck = Checkpointer(str(tmp_path), async_save=True)
    ck.save(3, {"w": jnp.ones((4,))})
    ck.wait()
    assert ck.latest_step() == 3


def test_checkpoint_restart_reproduces_training(tmp_path):
    """checkpoint → restart == uninterrupted run (exactness of failover)."""
    cfg = AdamWConfig(lr=0.1)
    params = {"w": jnp.ones((3,))}
    state = adamw_init(params, cfg)

    def grad_at(step):
        return {"w": jnp.full((3,), 0.1 * (step + 1))}

    # uninterrupted 6 steps
    p1, s1 = params, state
    for t in range(6):
        p1, s1, _ = adamw_update(p1, grad_at(t), s1, cfg)

    # interrupted at 3 + restore + continue
    ck = Checkpointer(str(tmp_path), async_save=False)
    p2, s2 = params, state
    for t in range(3):
        p2, s2, _ = adamw_update(p2, grad_at(t), s2, cfg)
    ck.save(3, {"p": p2, "s": s2})
    restored, step = ck.restore({"p": p2, "s": s2})
    p3, s3 = restored["p"], restored["s"]
    for t in range(step, 6):
        p3, s3, _ = adamw_update(p3, grad_at(t), s3, cfg)
    np.testing.assert_allclose(np.asarray(p1["w"]), np.asarray(p3["w"]),
                               rtol=1e-6)


# ---------------------------------------------------------------------------
# Gradient compression
# ---------------------------------------------------------------------------

def test_compression_relative_error_bounded():
    g = {"a": jax.random.normal(jax.random.PRNGKey(0), (128,)),
         "b": jax.random.normal(jax.random.PRNGKey(1), (64, 4))}
    deq, _ = compress_tree_int8(g)
    for k in g:
        err = float(jnp.max(jnp.abs(deq[k] - g[k])))
        scale = float(jnp.max(jnp.abs(g[k]))) / 127.0
        assert err <= scale * 0.5 + 1e-7


def test_error_feedback_reduces_bias():
    """Accumulated EF-compressed gradients track the true sum."""
    key = jax.random.PRNGKey(2)
    true_sum = jnp.zeros((32,))
    ef_sum = jnp.zeros((32,))
    residual = None
    for i in range(50):
        key, sub = jax.random.split(key)
        g = {"w": jax.random.normal(sub, (32,)) * 0.01}
        true_sum = true_sum + g["w"]
        deq, _, residual = ef_compress(g, residual)
        ef_sum = ef_sum + deq["w"]
    drift = float(jnp.linalg.norm(ef_sum - true_sum)
                  / jnp.linalg.norm(true_sum))
    assert drift < 0.05, drift


def test_compression_ratio_about_4x():
    g = {"w": jnp.zeros((1024, 1024), jnp.float32)}
    raw, comp = compressed_bytes(g)
    assert raw / comp > 3.9


# ---------------------------------------------------------------------------
# Fault tolerance
# ---------------------------------------------------------------------------

def test_heartbeat_detects_dead_host():
    hb = HeartbeatMonitor(n_hosts=3, timeout_s=10.0)
    hb.beat(0, 100.0)
    hb.beat(1, 100.0)
    hb.beat(2, 95.0)
    assert hb.dead_hosts(104.0) == []
    assert hb.dead_hosts(106.0) == [2]
    assert not hb.healthy(200.0)


def test_straggler_detection_with_patience():
    sd = StragglerDetector(n_hosts=4, threshold=1.5, patience=2)
    for step in range(5):
        for h in range(4):
            sd.record(h, 1.0 if h != 3 else 2.5)
        flagged = sd.stragglers()
    assert flagged == [3]


def test_straggler_rebalance_conserves_microbatches():
    sd = StragglerDetector(n_hosts=4)
    for h, t in enumerate([1.0, 1.0, 1.0, 3.0]):
        sd.record(h, t)
    alloc = sd.rebalance_microbatches(16)
    assert sum(alloc.values()) == 16
    assert alloc[3] < alloc[0]          # slow host gets less work


def test_elastic_mesh_plan():
    plan = plan_elastic_mesh(surviving_hosts=30, chips_per_host=8,
                             model_axis=16, global_batch=256)
    assert plan.model_axis == 16
    assert plan.data_axis * 16 <= 240
    assert plan.global_batch % plan.data_axis == 0


def test_elastic_mesh_insufficient_chips_raises():
    with pytest.raises(ValueError):
        plan_elastic_mesh(surviving_hosts=1, chips_per_host=8,
                          model_axis=16, global_batch=64)
