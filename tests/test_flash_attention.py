"""Flash-attention Pallas kernels vs the jnp oracle (fwd + custom VJP)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.ops import flash_mha


def _oracle(q, k, v, scale, causal, window, softcap, group):
    """Dense attention in f32 with the same GQA head mapping."""
    bh, sq, d = q.shape
    bkv, sk, dv = v.shape
    kk = jnp.repeat(k, group, axis=0)
    vv = jnp.repeat(v, group, axis=0)
    s = jnp.einsum("bqd,bkd->bqk", q.astype(jnp.float32),
                   kk.astype(jnp.float32)) * scale
    if softcap:
        s = jnp.tanh(s / softcap) * softcap
    qpos = jnp.arange(sq)[:, None]
    kpos = jnp.arange(sk)[None, :]
    mask = jnp.ones((sq, sk), bool)
    if causal:
        mask &= kpos <= qpos
    if window:
        mask &= (qpos - kpos) < window
    s = jnp.where(mask[None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bqk,bkd->bqd", p, vv.astype(jnp.float32))


CASES = [
    # (bh_kv, group, sq, sk, d, dv, causal, window, softcap)
    (2, 1, 128, 128, 128, 128, True, 0, 0.0),
    (2, 1, 256, 256, 128, 128, True, 0, 0.0),
    (1, 4, 128, 128, 128, 128, True, 0, 0.0),      # GQA
    (2, 1, 128, 128, 128, 128, True, 64, 0.0),     # sliding window
    (2, 1, 128, 128, 128, 128, True, 0, 30.0),     # softcap
    (1, 2, 96, 96, 64, 64, True, 0, 0.0),          # unaligned (padding)
]


@pytest.mark.parametrize("bkv,group,sq,sk,d,dv,causal,window,softcap", CASES)
def test_flash_forward_matches_oracle(bkv, group, sq, sk, d, dv, causal,
                                      window, softcap):
    keys = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(keys[0], (bkv * group, sq, d), jnp.float32) * 0.3
    k = jax.random.normal(keys[1], (bkv, sk, d), jnp.float32) * 0.3
    v = jax.random.normal(keys[2], (bkv, sk, dv), jnp.float32) * 0.3
    scale = 1.0 / d ** 0.5
    got = flash_mha(q, k, v, scale, causal, window, softcap, group, True)
    want = _oracle(q, k, v, scale, causal, window, softcap, group)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want), rtol=2e-3, atol=2e-3)


@pytest.mark.parametrize("bkv,group,sq,sk,d,dv,causal,window,softcap",
                         CASES[:5])
def test_flash_backward_matches_oracle(bkv, group, sq, sk, d, dv, causal,
                                       window, softcap):
    keys = jax.random.split(jax.random.PRNGKey(1), 3)
    q = jax.random.normal(keys[0], (bkv * group, sq, d), jnp.float32) * 0.3
    k = jax.random.normal(keys[1], (bkv, sk, d), jnp.float32) * 0.3
    v = jax.random.normal(keys[2], (bkv, sk, dv), jnp.float32) * 0.3
    scale = 1.0 / d ** 0.5

    def loss_flash(q, k, v):
        o = flash_mha(q, k, v, scale, causal, window, softcap, group, True)
        return jnp.sum(jnp.sin(o))       # nontrivial cotangent

    def loss_ref(q, k, v):
        o = _oracle(q, k, v, scale, causal, window, softcap, group)
        return jnp.sum(jnp.sin(o))

    g_flash = jax.grad(loss_flash, argnums=(0, 1, 2))(q, k, v)
    g_ref = jax.grad(loss_ref, argnums=(0, 1, 2))(q, k, v)
    for name, a, b in zip("qkv", g_flash, g_ref):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   rtol=5e-3, atol=5e-3,
                                   err_msg=f"d{name}")


def test_flash_numerically_stable_long_tail():
    """Large logits (pre-softmax) must not overflow the online softmax."""
    q = jnp.full((1, 128, 128), 8.0, jnp.float32)
    k = jnp.full((1, 128, 128), 8.0, jnp.float32)
    v = jax.random.normal(jax.random.PRNGKey(0), (1, 128, 128))
    o = flash_mha(q, k, v, 1.0, True, 0, 0.0, 1, True)
    assert bool(jnp.isfinite(o).all())


def test_model_level_flash_equivalence():
    """Whole-model logits: chunked vs flash paths agree (dense arch —
    MoE archs differ by routing flips under bf16 noise)."""
    import jax
    from repro.configs import get_config, reduced
    from repro.models import forward, model_init

    cfg = reduced(get_config("qwen3-4b"))
    params = model_init(jax.random.PRNGKey(0), cfg)
    tokens = jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0,
                                cfg.vocab_size)
    lc, _ = forward(params, cfg.replace(attn_impl="chunked"), tokens)
    lf, _ = forward(params, cfg.replace(attn_impl="flash"), tokens)
    np.testing.assert_allclose(np.asarray(lc, np.float32),
                               np.asarray(lf, np.float32),
                               rtol=0.03, atol=0.03)
