"""Training-step semantics: loss properties, grad accumulation
equivalence, compression integration, MTP objective."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, reduced
from repro.data import DataConfig, SyntheticLM
from repro.models import model_init
from repro.optim import AdamWConfig, adamw_init, adamw_update
from repro.train import TrainConfig, make_loss_fn, make_train_step
from repro.train.step import accumulate_grads, cross_entropy, z_loss


def test_cross_entropy_matches_gather_formulation():
    key = jax.random.PRNGKey(0)
    logits = jax.random.normal(key, (2, 8, 32))
    labels = jax.random.randint(jax.random.PRNGKey(1), (2, 8), 0, 32)
    got = float(cross_entropy(logits, labels))
    logp = jax.nn.log_softmax(logits, -1)
    want = float(-jnp.take_along_axis(logp, labels[..., None], -1).mean())
    assert abs(got - want) < 1e-5


def test_cross_entropy_ignores_masked_labels():
    logits = jax.random.normal(jax.random.PRNGKey(0), (1, 4, 16))
    labels = jnp.array([[3, -1, -1, 5]])
    got = float(cross_entropy(logits, labels))
    # equals mean over only the two valid positions
    logp = jax.nn.log_softmax(logits, -1)
    want = float(-(logp[0, 0, 3] + logp[0, 3, 5]) / 2)
    assert abs(got - want) < 1e-5


def test_z_loss_positive_and_masked():
    logits = jax.random.normal(jax.random.PRNGKey(0), (1, 4, 16)) * 5
    labels = jnp.array([[1, 2, -1, -1]])
    assert float(z_loss(logits, labels)) > 0


def _tiny_cfg():
    return reduced(get_config("yi-6b")).replace(vocab_size=128)


def test_grad_accumulation_equivalence():
    """N-microbatch accumulation == single-batch gradients (linearity)."""
    cfg = _tiny_cfg()
    tcfg = TrainConfig(z_loss_weight=0.0)
    loss_fn = make_loss_fn(cfg, tcfg)
    params = model_init(jax.random.PRNGKey(0), cfg)
    batch = {
        "tokens": jax.random.randint(jax.random.PRNGKey(1), (4, 16), 0,
                                     cfg.vocab_size),
        "labels": jax.random.randint(jax.random.PRNGKey(2), (4, 16), 0,
                                     cfg.vocab_size),
    }
    _, _, g1 = accumulate_grads(loss_fn, params, batch, 1)
    _, _, g4 = accumulate_grads(loss_fn, params, batch, 4)
    flat1 = jax.tree_util.tree_leaves(g1)
    flat4 = jax.tree_util.tree_leaves(g4)
    for a, b in zip(flat1, flat4):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   rtol=2e-2, atol=2e-3)


def test_train_step_reduces_loss_on_repeated_batch():
    cfg = _tiny_cfg()
    tcfg = TrainConfig(optimizer=AdamWConfig(lr=3e-3), warmup_steps=1,
                       total_steps=100)
    step = jax.jit(make_train_step(cfg, tcfg))
    params = model_init(jax.random.PRNGKey(0), cfg)
    opt = adamw_init(params, tcfg.optimizer)
    data = SyntheticLM(DataConfig(vocab_size=cfg.vocab_size, seq_len=32,
                                  global_batch=4))
    batch = data.batch(0)
    first = None
    for _ in range(20):
        params, opt, metrics = step(params, opt, batch)
        if first is None:
            first = float(metrics["loss"])
    last = float(metrics["loss"])
    assert last < first, (first, last)


def test_train_step_with_compression_still_learns():
    cfg = _tiny_cfg()
    tcfg = TrainConfig(optimizer=AdamWConfig(lr=3e-3), warmup_steps=1,
                       total_steps=100, compress_grads=True)
    step = jax.jit(make_train_step(cfg, tcfg))
    params = model_init(jax.random.PRNGKey(0), cfg)
    opt = adamw_init(params, tcfg.optimizer)
    data = SyntheticLM(DataConfig(vocab_size=cfg.vocab_size, seq_len=32,
                                  global_batch=4))
    batch = data.batch(0)
    first = None
    for _ in range(20):
        params, opt, metrics = step(params, opt, batch)
        if first is None:
            first = float(metrics["loss"])
    assert float(metrics["loss"]) < first


def test_mtp_objective_adds_loss():
    cfg = _tiny_cfg()
    batch = {
        "tokens": jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0,
                                     cfg.vocab_size),
        "labels": jax.random.randint(jax.random.PRNGKey(2), (2, 16), 0,
                                     cfg.vocab_size),
    }
    params = model_init(jax.random.PRNGKey(0), cfg)
    base = make_loss_fn(cfg, TrainConfig(z_loss_weight=0.0))
    mtp = make_loss_fn(cfg, TrainConfig(z_loss_weight=0.0, mtp_weight=0.5,
                                        mtp_depth=1))
    l0, _ = base(params, batch)
    l1, _ = mtp(params, batch)
    assert float(l1) > float(l0)


def test_quantized_moments_track_fp32_training():
    """int8-moment AdamW must land near fp32-moment AdamW on a small task
    (the low-precision-optimizer-state claim)."""
    def loss(p):
        return jnp.sum(jnp.square(p["w"] - 3.0))

    runs = {}
    for quant in (False, True):
        cfg = AdamWConfig(lr=0.1, weight_decay=0.0, grad_clip=0.0,
                          quantize_moments=quant)
        p = {"w": jnp.zeros((8,))}
        s = adamw_init(p, cfg)
        for _ in range(150):
            g = jax.grad(loss)(p)
            p, s, _ = adamw_update(p, g, s, cfg)
        runs[quant] = float(loss(p))
    assert runs[True] < 1e-2
    assert abs(runs[True] - runs[False]) < 1e-2


