"""The analytical model must reproduce the paper's Table 2 and Fig. 4."""

import numpy as np
import pytest

from repro.core import cycle_model as cm


# ---------------------------------------------------------------------------
# Table 2
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("arch,per", [
    ("shift_add", 8), ("booth_radix2", 4), ("nibble_precompute", 2),
    ("wallace", 1), ("lut_array", 1),
])
def test_table2_per_operand(arch, per):
    assert cm.cycles_per_operand(arch) == per


def test_table2_n_operand_latency():
    # paper §III.B: 4/8/16-operand nibble arrays take 8/16/32 cycles
    assert [cm.total_cycles("nibble_precompute", n) for n in (4, 8, 16)] \
        == [8, 16, 32]
    assert cm.total_cycles("shift_add", 16) == 128
    assert cm.total_cycles("booth_radix2", 16) == 64
    assert cm.total_cycles("wallace", 16) == 1
    assert cm.total_cycles("lut_array", 16) == 1


# ---------------------------------------------------------------------------
# Fig. 4 — every number the paper reports, within the affine residual
# ---------------------------------------------------------------------------

def _check(metric, fn, tol):
    for arch in cm.ARCHES:
        for n, reported in zip((4, 8, 16), cm.paper_reported(metric, arch)):
            if reported is None:
                continue
            model = fn(arch, n)
            err = abs(model - reported) / reported
            assert err < tol, (metric, arch, n, model, reported)


def test_fig4_area_reproduction():
    _check("area", cm.area_um2, tol=0.03)


def test_fig4_power_reproduction():
    _check("power", cm.power_mw, tol=0.05)


# ---------------------------------------------------------------------------
# The paper's headline claims
# ---------------------------------------------------------------------------

def test_headline_area_claim_169x():
    """'up to 1.69x area reduction ... over shift-add' at 16 operands."""
    r = cm.improvement_vs("shift_add", "nibble_precompute", "area", 16)
    assert abs(r - 1.69) < 0.02


def test_headline_power_claim_163x():
    """'1.63x power improvement over shift-add' at 16 operands."""
    r = cm.improvement_vs("shift_add", "nibble_precompute", "power", 16)
    assert abs(r - 1.63) < 0.03


def test_headline_vs_lut_array():
    """'nearly 2.6x area ... savings compared to LUT-based array'.

    NOTE the paper also claims 2.7x *power* vs the LUT array, but its own
    Fig. 4(b) numbers give 0.276/0.0605 = 4.56x — the figure data wins;
    we assert the area claim (consistent) and that power saving is at
    least the claimed 2.7x (it is larger).  Recorded in EXPERIMENTS.md.
    """
    area = cm.area_um2("lut_array", 16) / cm.area_um2("nibble_precompute", 16)
    power = cm.power_mw("lut_array", 16) / cm.power_mw("nibble_precompute", 16)
    assert abs(area - 2.6) < 0.1
    assert power > 2.7


def test_crossover_nibble_beats_shift_add_only_at_scale():
    """Fig. 4(b): nibble loses on power at N=4 (0.83x), wins from N=8."""
    assert cm.improvement_vs("shift_add", "nibble_precompute", "power", 4) < 1.0
    assert cm.improvement_vs("shift_add", "nibble_precompute", "power", 8) > 1.0
    assert cm.improvement_vs("shift_add", "nibble_precompute", "power", 16) > 1.5


def test_logic_reuse_is_the_mechanism():
    """The nibble design's fitted shared term must dominate its per-lane
    term relative to shift-add — that is the 'logic reuse' thesis."""
    nib_shared, nib_lane = cm._POWER_COEF["nibble_precompute"]
    sa_shared, sa_lane = cm._POWER_COEF["shift_add"]
    assert nib_shared > sa_shared          # more amortised logic
    assert nib_lane < sa_lane              # cheaper replicated lane


def test_energy_per_product_ordering():
    """Energy/product: nibble must beat both sequential baselines at 16."""
    e = {a: cm.energy_per_product_pj(a, 16) for a in cm.ARCHES}
    assert e["nibble_precompute"] < e["booth_radix2"] < e["shift_add"]


def test_extrapolation_to_128_lanes():
    """Abstract's 128-lane point: savings must grow monotonically with N."""
    r16 = cm.improvement_vs("shift_add", "nibble_precompute", "power", 16)
    r128 = cm.improvement_vs("shift_add", "nibble_precompute", "power", 128)
    assert r128 > r16 > 1.0
    a128 = cm.improvement_vs("shift_add", "nibble_precompute", "area", 128)
    assert a128 > 1.69
