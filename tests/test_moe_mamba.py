"""Deep correctness tests for the MoE dispatch and the Mamba2 SSD scan."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.configs import get_config, reduced
from repro.models.mamba import (
    init_mamba_cache,
    mamba_apply,
    mamba_init,
    mamba_step,
)
from repro.models.moe import _capacity, _route, moe_apply, moe_init


def _moe_cfg(**kw):
    base = dict(n_experts=4, top_k=2, d_ff_expert=32, capacity_factor=8.0,
                n_shared_experts=0)
    base.update(kw)
    return reduced(get_config("jamba-v0.1-52b")).replace(**base)


def _moe_dense_reference(params, cfg, x):
    """Oracle: run EVERY expert on EVERY token, weight by router top-k."""
    b, s, d = x.shape
    xt = x.reshape(b * s, d).astype(jnp.float32)
    top_w, top_e, _ = _route(params, cfg, x.reshape(b * s, d))
    outs = []
    for e in range(cfg.n_experts):
        g = xt @ params["w_gate"][e].astype(jnp.float32)
        u = xt @ params["w_up"][e].astype(jnp.float32)
        o = (jax.nn.silu(g) * u) @ params["w_down"][e].astype(jnp.float32)
        outs.append(o)
    outs = jnp.stack(outs, 1)                       # (T, E, D)
    w_full = jnp.zeros((b * s, cfg.n_experts))
    for j in range(cfg.top_k):
        w_full = w_full.at[jnp.arange(b * s), top_e[:, j]].add(top_w[:, j])
    ref = jnp.einsum("te,ted->td", w_full, outs)
    return ref.reshape(b, s, d)


def test_moe_matches_dense_reference_with_ample_capacity():
    cfg = _moe_cfg()
    key = jax.random.PRNGKey(0)
    params = moe_init(key, cfg)
    x = (jax.random.normal(jax.random.PRNGKey(1), (2, 8, cfg.d_model))
         * 0.5).astype(jnp.bfloat16)
    got, aux = moe_apply(params, cfg, x)
    ref = _moe_dense_reference(params, cfg, x)
    rel = float(jnp.linalg.norm(got.astype(jnp.float32) - ref)
                / jnp.linalg.norm(ref))
    assert rel < 0.05, rel
    assert float(aux) > 0


def test_moe_capacity_drops_dont_corrupt():
    """With capacity 8 (minimum), overflow tokens drop; the output stays
    finite and the kept tokens still route correctly."""
    cfg = _moe_cfg(capacity_factor=0.01)
    params = moe_init(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(2), (2, 32, cfg.d_model)) \
        .astype(jnp.bfloat16)
    got, _ = moe_apply(params, cfg, x)
    assert bool(jnp.isfinite(got.astype(jnp.float32)).all())


def test_moe_capacity_formula():
    cfg = _moe_cfg(capacity_factor=1.25, top_k=2, n_experts=4)
    assert _capacity(cfg, 64, 4) == 40      # 2*64/4*1.25
    assert _capacity(cfg, 1, 4) == 8        # floor


def test_moe_shared_expert_added():
    cfg = _moe_cfg(n_shared_experts=1)
    params = moe_init(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 4, cfg.d_model)) \
        .astype(jnp.bfloat16)
    with_shared, _ = moe_apply(params, cfg, x)
    params_no = dict(params)
    params_no["shared"] = jax.tree_util.tree_map(jnp.zeros_like,
                                                 params["shared"])
    without, _ = moe_apply(params_no, cfg, x)
    assert float(jnp.abs(with_shared.astype(jnp.float32)
                         - without.astype(jnp.float32)).max()) > 0


# ---------------------------------------------------------------------------
# Mamba2 SSD
# ---------------------------------------------------------------------------

def _mamba_cfg(chunk=8):
    return reduced(get_config("mamba2-780m")).replace(ssm_chunk=chunk)


def test_ssd_chunk_invariance():
    """The chunked SSD algorithm must give identical output for any chunk
    size (it's an exact reformulation, not an approximation)."""
    key = jax.random.PRNGKey(0)
    x = (jax.random.normal(jax.random.PRNGKey(1), (2, 32, 64)) * 0.3) \
        .astype(jnp.bfloat16)
    outs = []
    for chunk in (4, 8, 16, 32):
        cfg = _mamba_cfg(chunk)
        params = mamba_init(key, cfg)
        out, _ = mamba_apply(params, cfg, x)
        outs.append(np.asarray(out.astype(jnp.float32)))
    for o in outs[1:]:
        np.testing.assert_allclose(o, outs[0], rtol=0.05, atol=0.05)


def test_ssd_decode_matches_full_sequence():
    """Step-by-step recurrence == chunked parallel scan (duality)."""
    cfg = _mamba_cfg(8)
    key = jax.random.PRNGKey(0)
    params = mamba_init(key, cfg)
    b, s = 1, 16
    x = (jax.random.normal(jax.random.PRNGKey(3), (b, s, cfg.d_model))
         * 0.3).astype(jnp.bfloat16)

    full, _ = mamba_apply(params, cfg, x)

    cache = init_mamba_cache(cfg, b)
    steps = []
    for t in range(s):
        out, cache = mamba_step(params, cfg, x[:, t:t + 1], cache)
        steps.append(np.asarray(out.astype(jnp.float32)))
    stepwise = np.concatenate(steps, axis=1)
    np.testing.assert_allclose(stepwise,
                               np.asarray(full.astype(jnp.float32)),
                               rtol=0.08, atol=0.08)


def test_ssd_prefill_state_continues_decode():
    """prefill(first half) state + decode(second half) == full decode."""
    cfg = _mamba_cfg(4)
    params = mamba_init(jax.random.PRNGKey(0), cfg)
    b, s = 1, 16
    x = (jax.random.normal(jax.random.PRNGKey(4), (b, s, cfg.d_model))
         * 0.3).astype(jnp.bfloat16)
    full, _ = mamba_apply(params, cfg, x)

    _, cache = mamba_apply(params, cfg, x[:, :8], return_cache=True)
    outs = []
    for t in range(8, s):
        out, cache = mamba_step(params, cfg, x[:, t:t + 1], cache)
        outs.append(np.asarray(out.astype(jnp.float32)))
    got = np.concatenate(outs, axis=1)
    want = np.asarray(full[:, 8:].astype(jnp.float32))
    np.testing.assert_allclose(got, want, rtol=0.08, atol=0.08)


@given(seed=st.integers(0, 10_000))
@settings(max_examples=10, deadline=None)
def test_ssd_state_bounded(seed):
    """Property: the SSM state stays finite for random inputs (negative
    A guarantees a contractive recurrence)."""
    cfg = _mamba_cfg(8)
    params = mamba_init(jax.random.PRNGKey(0), cfg)
    x = (jax.random.normal(jax.random.PRNGKey(seed), (1, 16, cfg.d_model))
         * 2.0).astype(jnp.bfloat16)
    _, cache = mamba_apply(params, cfg, x, return_cache=True)
    assert bool(jnp.isfinite(cache["ssm"]).all())
