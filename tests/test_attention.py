"""Attention correctness: GQA grouping, sliding window, chunk invariance,
RoPE properties, MLA latent cache equivalence."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.configs import get_config, reduced
from repro.models.attention import (
    attention_core,
    attn_apply,
    attn_init,
    mla_apply,
    mla_init,
    unrolled_chunks,
)
from repro.models.layers import apply_rope, rope


def _naive_attention(q, k, v, q_pos, k_pos, scale, causal, window):
    """O(S²) reference with explicit head repetition."""
    b, sq, h, d = q.shape
    kvh = k.shape[2]
    rep = h // kvh
    k_full = np.repeat(np.asarray(k, np.float32), rep, axis=2)
    v_full = np.repeat(np.asarray(v, np.float32), rep, axis=2)
    qn = np.asarray(q, np.float32)
    out = np.zeros((b, sq, h, v.shape[-1]), np.float32)
    for bi in range(b):
        for hi in range(h):
            logits = qn[bi, :, hi] @ k_full[bi, :, hi].T * scale
            for i in range(sq):
                for j in range(k.shape[1]):
                    if causal and k_pos[bi, j] > q_pos[bi, i]:
                        logits[i, j] = -1e30
                    if window and q_pos[bi, i] - k_pos[bi, j] >= window:
                        logits[i, j] = -1e30
            p = np.exp(logits - logits.max(-1, keepdims=True))
            p /= p.sum(-1, keepdims=True)
            out[bi, :, hi] = p @ v_full[bi, :, hi]
    return out


@pytest.mark.parametrize("h,kvh", [(4, 4), (4, 2), (4, 1)])
@pytest.mark.parametrize("causal,window", [(True, 0), (True, 3), (False, 0)])
def test_attention_core_vs_naive(h, kvh, causal, window):
    key = jax.random.PRNGKey(0)
    b, sq, sk, d = 2, 6, 6, 8
    q = jax.random.normal(key, (b, sq, h, d), jnp.float32)
    k = jax.random.normal(jax.random.PRNGKey(1), (b, sk, kvh, d))
    v = jax.random.normal(jax.random.PRNGKey(2), (b, sk, kvh, d))
    pos = jnp.broadcast_to(jnp.arange(sq)[None], (b, sq))
    got = attention_core(q, k, v, pos, pos, scale=0.35, causal=causal,
                         window=window)
    want = _naive_attention(q, k, v, np.asarray(pos), np.asarray(pos),
                            0.35, causal, window)
    np.testing.assert_allclose(np.asarray(got, np.float32), want,
                               rtol=2e-2, atol=2e-2)


def test_query_chunking_invariance():
    """Chunked evaluation must equal unchunked (and the unrolled cost-pass
    variant must equal the scan variant)."""
    key = jax.random.PRNGKey(3)
    b, s, h, d = 1, 64, 2, 8
    q = jax.random.normal(key, (b, s, h, d))
    k = jax.random.normal(jax.random.PRNGKey(4), (b, s, h, d))
    v = jax.random.normal(jax.random.PRNGKey(5), (b, s, h, d))
    pos = jnp.broadcast_to(jnp.arange(s)[None], (b, s))
    full = attention_core(q, k, v, pos, pos, scale=0.5, q_chunk=1024)
    chunked = attention_core(q, k, v, pos, pos, scale=0.5, q_chunk=16)
    np.testing.assert_allclose(np.asarray(full), np.asarray(chunked),
                               rtol=1e-5, atol=1e-5)
    with unrolled_chunks():
        unrolled = attention_core(q, k, v, pos, pos, scale=0.5, q_chunk=16)
    np.testing.assert_allclose(np.asarray(full), np.asarray(unrolled),
                               rtol=1e-5, atol=1e-5)


def test_sliding_window_blocks_distant_keys():
    """A distant key must not influence the output of a local layer."""
    cfg = reduced(get_config("gemma3-1b")).replace(sliding_window=4,
                                                   qk_norm=False)
    params = attn_init(jax.random.PRNGKey(0), cfg)
    b, s = 1, 16
    x = jax.random.normal(jax.random.PRNGKey(1), (b, s, cfg.d_model)) \
        .astype(jnp.bfloat16)
    pos = jnp.broadcast_to(jnp.arange(s)[None], (b, s))
    out1, _ = attn_apply(params, cfg, x, positions=pos, kind="local")
    # perturb position 0 hugely; outputs at positions ≥ 4 must not change
    x2 = x.at[:, 0].set(100.0)
    out2, _ = attn_apply(params, cfg, x2, positions=pos, kind="local")
    d_far = float(jnp.abs(out1[:, 6:].astype(jnp.float32)
                          - out2[:, 6:].astype(jnp.float32)).max())
    d_near = float(jnp.abs(out1[:, 0].astype(jnp.float32)
                           - out2[:, 0].astype(jnp.float32)).max())
    assert d_far == 0.0
    assert d_near > 0.0


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------

def test_rope_preserves_norm():
    x = jax.random.normal(jax.random.PRNGKey(0), (2, 8, 4, 16))
    pos = jnp.broadcast_to(jnp.arange(8)[None], (2, 8))
    sin, cos = rope(pos, 16)
    y = apply_rope(x, sin, cos)
    np.testing.assert_allclose(np.linalg.norm(np.asarray(y), axis=-1),
                               np.linalg.norm(np.asarray(x), axis=-1),
                               rtol=1e-5)


@given(shift=st.integers(0, 32))
@settings(max_examples=10, deadline=None)
def test_rope_relative_property(shift):
    """⟨rope(q,i), rope(k,j)⟩ depends only on i−j (translation invariance)."""
    q = jax.random.normal(jax.random.PRNGKey(1), (1, 1, 1, 16))
    k = jax.random.normal(jax.random.PRNGKey(2), (1, 1, 1, 16))

    def dot_at(i, j):
        sq, cq = rope(jnp.array([[i]]), 16)
        sk, ck = rope(jnp.array([[j]]), 16)
        return float(jnp.sum(apply_rope(q, sq, cq)
                             * apply_rope(k, sk, ck)))

    base = dot_at(5, 3)
    shifted = dot_at(5 + shift, 3 + shift)
    assert abs(base - shifted) < 1e-3


# ---------------------------------------------------------------------------
# MLA
# ---------------------------------------------------------------------------

def test_mla_cache_equivalence():
    """Decoding from the compressed latent cache must equal the full pass
    — the cache stores (c_kv, k_rope) only, K/V are re-expanded."""
    cfg = reduced(get_config("deepseek-v3-671b"))
    params = mla_init(jax.random.PRNGKey(0), cfg)
    b, s = 1, 8
    x = (jax.random.normal(jax.random.PRNGKey(1), (b, s, cfg.d_model))
         * 0.5).astype(jnp.bfloat16)
    pos = jnp.broadcast_to(jnp.arange(s)[None], (b, s))
    full, _ = mla_apply(params, cfg, x, positions=pos)

    # prefill 4, decode 4
    out_pre, cache = mla_apply(params, cfg, x[:, :4], positions=pos[:, :4],
                               return_cache=True)
    from repro.models.attention import init_mla_cache
    big = init_mla_cache(cfg, b, s)
    big["c_kv"] = big["c_kv"].at[:, :4].set(cache["c_kv"])
    big["k_rope"] = big["k_rope"].at[:, :4].set(cache["k_rope"])
    outs = [np.asarray(out_pre.astype(jnp.float32))]
    for t in range(4, s):
        o, big = mla_apply(params, cfg, x[:, t:t + 1],
                           positions=pos[:, t:t + 1], cache=big,
                           cache_index=t)
        outs.append(np.asarray(o.astype(jnp.float32)))
    got = np.concatenate(outs, axis=1)
    np.testing.assert_allclose(got,
                               np.asarray(full.astype(jnp.float32)),
                               rtol=0.06, atol=0.06)


def test_mla_cache_is_compressed():
    """Per-token MLA cache bytes << full K/V bytes (the MLA win)."""
    cfg = get_config("deepseek-v3-671b")
    mla_per_tok = cfg.kv_lora_rank + cfg.qk_rope_dim          # 576
    full_per_tok = 2 * cfg.n_heads * (cfg.qk_nope_dim + cfg.qk_rope_dim)
    assert mla_per_tok * 40 < full_per_tok                    # >40× smaller
