"""Self-speculative decoding: the nibble-quantized program drafts,
ONE dense multi-token forward verifies.  Greedy spec streams must
BIT-match the non-spec dense engine token-for-token (across the quant ×
backend grid, and across a preemption mid-stream), rollback must be a
pure page-table operation (zero leaks after drain), the compiled
program set must stay pinned at one draft + one verify, and the
``tools/spec_report.py`` planning model must agree with itself.

Satellite: the index-derived per-slot RNG makes *sampled* (non-spec)
streams bit-stable under evict-and-resume too — preemption can no
longer fork a temperature stream's future.
"""

import sys
import os
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, reduced
from repro.configs.base import spec_split
from repro.models import model_init
from repro.serve import Engine, ServeConfig

sys.path.insert(0, os.path.join(
    os.path.dirname(os.path.dirname(os.path.abspath(__file__))), "tools"))
import spec_report


@pytest.fixture(scope="module")
def model():
    cfg = reduced(get_config("yi-6b"))
    params = model_init(jax.random.PRNGKey(0), cfg)
    return cfg, params


def _scfg(**over):
    kw = dict(batch=2, max_len=16, prefill_len=8, decode_chunk=3,
              cache_mode="paged", page_size=4)
    kw.update(over)
    return ServeConfig(**kw)


def _drive(cfg, params, prompts, budgets, scfg):
    engine = Engine(cfg, params, scfg)
    ids = [engine.submit(p, n) for p, n in zip(prompts, budgets)]
    done = engine.run()
    return engine, [done[i] for i in ids]


def _prompts(cfg, n, seed=0):
    rng = np.random.default_rng(seed)
    return [jnp.asarray(rng.integers(0, cfg.vocab_size,
                                     int(rng.integers(3, 7))), jnp.int32)
            for _ in range(n)]


# ---------------------------------------------------------------------------
# Greedy spec ≡ non-spec dense, across the quant × backend grid
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("quant,backend", [
    ("dense", "xla"), ("dense", "pallas"),
    ("w8a8_nibble", "xla"), ("w8a8_nibble", "pallas"),
])
def test_spec_greedy_bitmatches_dense_engine(model, quant, backend, ):
    """The acceptance contract: whatever drafts the quantized program
    proposes, the emitted greedy stream is exactly the non-spec dense
    engine's — the draft only changes *when* tokens appear, never
    *which*."""
    cfg, params = model
    prompts = _prompts(cfg, 3)
    budgets = [6, 6, 6]
    _, want = _drive(cfg, params, prompts, budgets,
                     _scfg(quant_mode="dense", quant_backend=backend))
    engine, got = _drive(
        cfg, params, prompts, budgets,
        _scfg(quant_mode="dense", quant_backend=backend,
              alloc_mode="incremental", spec_decode=True, spec_k=3,
              spec_quant_mode=quant))
    assert [r.tokens for r in got] == [r.tokens for r in want]
    assert engine.allocator.in_use == 0            # zero page leaks
    assert engine.compile_counts == {"prefill": 1, "decode_chunk": 0,
                                     "draft": 1, "verify": 1}
    st = engine.stats
    assert st["spec_rounds"] > 0
    assert 0.0 <= st["acceptance_rate"] <= 1.0
    assert 1.0 <= st["tokens_per_step"] <= 3 + 1
    # no replay happened, so every token except each request's
    # prefill-emitted first one went through a round
    assert engine.spec_tokens == sum(len(r.tokens) - 1 for r in got)


def test_spec_dense_cache_mode_bitmatches(model):
    """Spec decode is cache-layout-agnostic: the dense slab works too
    (rollback is simply a no-op — junk rows are overwritten in place)."""
    cfg, params = model
    prompts = _prompts(cfg, 2, seed=3)
    _, want = _drive(cfg, params, prompts, [5, 5],
                     _scfg(cache_mode="dense", page_size=None))
    engine, got = _drive(cfg, params, prompts, [5, 5],
                         _scfg(cache_mode="dense", page_size=None,
                               spec_decode=True, spec_k=4,
                               spec_quant_mode="w8a8_nibble"))
    assert [r.tokens for r in got] == [r.tokens for r in want]


def test_spec_temperature_drains_and_accounts(model):
    """temperature > 0 exercises the rejection-sampling verify path:
    the run must drain, leak nothing, and keep the accounting coupled
    (every emitted token was emitted by some round)."""
    cfg, params = model
    prompts = _prompts(cfg, 3, seed=5)
    engine, got = _drive(
        cfg, params, prompts, [6, 6, 6],
        _scfg(temperature=0.8, alloc_mode="incremental",
              spec_decode=True, spec_k=3,
              spec_quant_mode="w8a8_nibble"))
    assert all(len(r.tokens) == 6 for r in got)
    assert engine.allocator.in_use == 0
    assert engine.spec_tokens == 15        # 18 emitted − 3 prefill firsts
    assert 0.0 <= engine.stats["acceptance_rate"] <= 1.0


# ---------------------------------------------------------------------------
# Preemption: spec streams resume, sampled non-spec streams stay bit-stable
# ---------------------------------------------------------------------------

def test_spec_stream_preempted_mid_draft_resumes_bitmatch(model):
    """A spec request evicted between speculation rounds resumes via
    prefill + forced-draft replay and must still emit the exact
    non-spec dense stream (forced drafts are force-accepted committed
    history, excluded from acceptance stats)."""
    cfg, params = model
    rng = np.random.default_rng(7)
    lo_p = jnp.asarray(rng.integers(0, cfg.vocab_size, 5), jnp.int32)
    hi_p = jnp.asarray(rng.integers(0, cfg.vocab_size, 4), jnp.int32)
    scfg = _scfg(batch=1, alloc_mode="incremental", spec_decode=True,
                 spec_k=3, spec_quant_mode="w8a8_nibble")

    engine = Engine(cfg, params, scfg)
    lo = engine.submit(lo_p, 7)
    engine._t0 = time.perf_counter()
    engine._admit(0.0)
    assert engine._slots[0] is not None and engine._slots[0].id == lo
    # one speculation round so the victim carries emitted tokens (more
    # than one draft round's worth gets replayed through forced lanes)
    engine._run_spec_round(0.0)
    assert len(engine._slots[0].tokens) >= 1
    proposed_before = engine.spec_proposed
    hi = engine.submit(hi_p, 5, priority=5)
    engine._admit(0.0)                         # full batch: must evict lo
    assert engine._slots[0].id == hi
    assert engine.preemptions == 1
    done = engine.run()
    assert engine.allocator.in_use == 0
    assert done[lo].preemptions == 1

    for rid, prompt, n in ((lo, lo_p, 7), (hi, hi_p, 5)):
        _, (ref,) = _drive(cfg, params, [prompt], [n],
                           _scfg(batch=1, quant_mode="dense"))
        assert done[rid].tokens == ref.tokens, rid
    assert engine.compile_counts == {"prefill": 1, "decode_chunk": 0,
                                     "draft": 1, "verify": 1}
    # replayed tokens never re-enter the acceptance statistics: the
    # fresh-proposal count cannot exceed rounds × k even though the
    # victim's whole stream went through the draft lanes twice
    assert engine.spec_proposed <= engine.spec_rounds * 3
    assert engine.spec_proposed > proposed_before


def test_sampled_stream_bitstable_under_preemption(model):
    """Satellite: the index-derived per-request RNG makes *sampled*
    non-spec streams resume bit-identically after eviction — the draw
    for token i of request r depends only on (r.id, i), not on batch
    composition or how many chunks ran."""
    cfg, params = model
    rng = np.random.default_rng(11)
    lo_p = jnp.asarray(rng.integers(0, cfg.vocab_size, 5), jnp.int32)
    hi_p = jnp.asarray(rng.integers(0, cfg.vocab_size, 4), jnp.int32)

    # uninterrupted reference: both requests sampled side by side
    # (ids 0 and 1, same submission order as the preempted run)
    ref_engine = Engine(cfg, params, _scfg(temperature=0.7))
    r_lo = ref_engine.submit(lo_p, 6)
    r_hi = ref_engine.submit(hi_p, 5)
    ref = ref_engine.run()

    engine = Engine(cfg, params, _scfg(batch=1, temperature=0.7))
    lo = engine.submit(lo_p, 6)
    engine._t0 = time.perf_counter()
    engine._admit(0.0)
    engine._run_chunk(0.0)                     # generate, then get evicted
    hi = engine.submit(hi_p, 5, priority=5)
    engine._admit(0.0)
    assert engine.preemptions == 1
    done = engine.run()

    assert done[lo].tokens == ref[r_lo].tokens
    assert done[hi].tokens == ref[r_hi].tokens
    assert done[lo].preemptions == 1


# ---------------------------------------------------------------------------
# Validation / config plumbing
# ---------------------------------------------------------------------------

def test_spec_split_pins_dense_verifier():
    cfg = reduced(get_config("yi-6b")).replace(quant_mode="w4a8_nibble")
    draft, verify = spec_split(cfg)
    assert draft.quant_mode == "w4a8_nibble"   # deployment drafts itself
    assert verify.quant_mode == "dense"
    draft2, _ = spec_split(cfg, "w8a8_nibble")
    assert draft2.quant_mode == "w8a8_nibble"
    with pytest.raises(ValueError, match="unknown draft quant mode"):
        spec_split(cfg, "int2")


def test_spec_rejects_mamba_and_bad_k(model):
    cfg, params = model
    mcfg = reduced(get_config("mamba2-780m"))
    mparams = model_init(jax.random.PRNGKey(0), mcfg)
    with pytest.raises(ValueError, match="mamba"):
        Engine(mcfg, mparams, ServeConfig(batch=1, max_len=16,
                                          spec_decode=True))
    with pytest.raises(ValueError, match="spec_k"):
        Engine(cfg, params, _scfg(spec_decode=True, spec_k=0))


def test_workload_arrival_mode_validown(model):
    from repro.serve import run_timed_workload
    cfg, params = model
    engine = Engine(cfg, params, _scfg())
    with pytest.raises(ValueError, match="arrival_mode"):
        run_timed_workload(engine, cfg.vocab_size, requests=2,
                           prompt_budget=6, new_tokens=2,
                           arrival_mode="chaotic")


def test_bursty_workload_reports_tail_columns(model):
    """Bursty arrivals + Pareto lengths drain through the spec engine;
    the report must carry the new tail/spec columns."""
    from repro.serve import run_timed_workload
    cfg, params = model
    engine = Engine(cfg, params,
                    _scfg(alloc_mode="incremental", spec_decode=True,
                          spec_k=3, spec_quant_mode="w8a8_nibble"))
    r = run_timed_workload(engine, cfg.vocab_size, requests=4,
                           prompt_budget=6, new_tokens=4,
                           stagger_s=0.005, seed=3,
                           arrival_mode="bursty")
    assert r["arrival_mode"] == "bursty"
    assert r["spec"] is True
    for col in ("ttft_p99_ms", "itl_p50_ms", "itl_p99_ms",
                "acceptance_rate", "tokens_per_step",
                "spec_rollback_pages"):
        assert col in r, col
    assert r["tokens"] == 16
    assert r["compile_counts"] == {"prefill": 1, "decode_chunk": 0,
                                   "draft": 1, "verify": 1}
    assert engine.allocator.in_use == 0


# ---------------------------------------------------------------------------
# tools/spec_report.py: the planning model
# ---------------------------------------------------------------------------

def test_spec_report_expected_tokens_endpoints():
    assert spec_report.expected_tokens_per_round(0.0, 4) == 1.0
    assert spec_report.expected_tokens_per_round(1.0, 4) == 5.0
    # strictly increasing in alpha
    vals = [spec_report.expected_tokens_per_round(a, 4)
            for a in (0.1, 0.3, 0.5, 0.7, 0.9)]
    assert all(b > a for a, b in zip(vals, vals[1:]))
    with pytest.raises(ValueError):
        spec_report.expected_tokens_per_round(1.5, 4)
    with pytest.raises(ValueError):
        spec_report.expected_tokens_per_round(0.5, 0)


def test_spec_report_inversion_roundtrip():
    for k in (2, 4, 8):
        for alpha in (0.0, 0.25, 0.5, 0.8, 0.95, 1.0):
            tps = spec_report.expected_tokens_per_round(alpha, k)
            back = spec_report.acceptance_from_tokens_per_step(tps, k)
            assert abs(back - alpha) < 1e-6, (k, alpha)
    with pytest.raises(ValueError):
        spec_report.acceptance_from_tokens_per_step(0.5, 4)
    with pytest.raises(ValueError):
        spec_report.acceptance_from_tokens_per_step(6.0, 4)


def test_spec_report_speedup_model():
    # free drafts + full acceptance: (k+1)-for-1
    assert spec_report.speedup(1.0, 4, c_draft=1e-9) == \
        pytest.approx(5.0, rel=1e-3)
    # zero acceptance with costly drafts is a slowdown
    assert spec_report.speedup(0.0, 4, c_draft=0.5) < 1.0
    with pytest.raises(ValueError):
        spec_report.speedup(0.5, 4, c_draft=0.0)


def test_spec_report_validates_bench_rows(tmp_path):
    import json
    # a self-consistent row (tokens_per_step generated from its own
    # acceptance) passes; a decoupled row is flagged
    tps = spec_report.expected_tokens_per_round(0.8, 4)
    good = {"results": [{"workload": "uniform", "spec": "on",
                         "tokens_per_step": tps,
                         "acceptance_rate": 0.8}]}
    bad = {"results": [{"workload": "uniform", "spec": "on",
                        "tokens_per_step": tps,
                        "acceptance_rate": 0.3}]}
    p = tmp_path / "bench.json"
    p.write_text(json.dumps(good))
    _, ok = spec_report.validate_bench(str(p))
    assert ok
    p.write_text(json.dumps(bad))
    lines, ok = spec_report.validate_bench(str(p))
    assert not ok and any("DRIFT" in ln for ln in lines)
    p.write_text(json.dumps({"results": []}))
    _, ok = spec_report.validate_bench(str(p))
    assert not ok                          # no spec rows = not validated
