"""CI doc-drift check: docs/serving.md must name every serving knob,
and the checker must actually fail when one goes missing."""

import pathlib
import shutil
import subprocess
import sys

REPO = pathlib.Path(__file__).resolve().parent.parent
CHECKER = REPO / "tools" / "check_doc_drift.py"


def _run(repo):
    return subprocess.run([sys.executable, str(CHECKER), "--repo",
                           str(repo)], capture_output=True, text=True)


def test_docs_cover_every_flag_and_field():
    r = _run(REPO)
    assert r.returncode == 0, r.stderr


def test_checker_fails_when_doc_drops_a_flag(tmp_path):
    """Remove one flag from a copy of the doc: the check must fail and
    name it (the whole point — a removed/undocumented knob cannot pass
    CI silently)."""
    for rel in ("src/repro/launch/serve.py", "src/repro/serve/engine.py",
                "docs/serving.md"):
        dst = tmp_path / rel
        dst.parent.mkdir(parents=True, exist_ok=True)
        shutil.copy(REPO / rel, dst)
    doc = tmp_path / "docs" / "serving.md"
    doc.write_text(doc.read_text().replace("--prefix-cache", "--x"))
    r = _run(tmp_path)
    assert r.returncode == 1
    assert "--prefix-cache" in r.stderr


def test_checker_fails_when_doc_drops_a_config_field(tmp_path):
    for rel in ("src/repro/launch/serve.py", "src/repro/serve/engine.py",
                "docs/serving.md"):
        dst = tmp_path / rel
        dst.parent.mkdir(parents=True, exist_ok=True)
        shutil.copy(REPO / rel, dst)
    doc = tmp_path / "docs" / "serving.md"
    doc.write_text(doc.read_text().replace("`prefix_cache`", "`x`"))
    r = _run(tmp_path)
    assert r.returncode == 1
    assert "prefix_cache" in r.stderr
