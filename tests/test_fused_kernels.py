"""Single-pass plane-fused kernel validation.

Bit-exactness of the plane-concatenated single-dot kernel vs kernels/ref
across signed edge cases (-128, the ±8 nibble boundaries) and unaligned
shapes exercising ``ops._pad_to`` on all three dims, plus the fused
dequant epilogue (bf16, no int32 round-trip) and the single
``quant_matmul`` dispatch path.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import quantize as q
from repro.core.nibble import pack_int4
from repro.kernels import ops, ref

RNG = np.random.default_rng(20260730)


def _rand_i8(*shape):
    return jnp.asarray(RNG.integers(-128, 128, shape, dtype=np.int64),
                       jnp.int8)


# --- signed edge cases: extremes and the nibble-boundary values ----------
# ±8 is where the signed high-nibble plane flips sign; 15→16 is where the
# low plane wraps; -128 is the asymmetric int8 extreme whose hi<<4 plane
# saturates the int8 range of the pre-shifted operand.
EDGE_VALUES = [-128, -127, -17, -16, -9, -8, -7, -1, 0, 1, 7, 8, 9, 15,
               16, 17, 126, 127]


def test_edge_value_grid_exact():
    """Every (x, w) pair of edge values through a whole-block matmul."""
    vals = np.array(EDGE_VALUES, np.int64)
    # x rows cycle through edge values; w cols likewise → all pairs occur
    x = jnp.asarray(np.tile(vals, (32, 8))[:, :128], jnp.int8)
    w = jnp.asarray(np.tile(vals[:, None], (8, 32))[:128, :], jnp.int8)
    got = ops.nibble_matmul(x, w, interpret=True)
    np.testing.assert_array_equal(np.asarray(got),
                                  np.asarray(ref.nibble_matmul_ref(x, w)))


@pytest.mark.parametrize("xv", [-128, -8, -1, 8, 127])
@pytest.mark.parametrize("wv", [-128, -8, 8, 127])
def test_constant_extremes_exact(xv, wv):
    x = jnp.full((32, 256), xv, jnp.int8)
    w = jnp.full((256, 32), wv, jnp.int8)
    got = ops.nibble_matmul(x, w, interpret=True)
    np.testing.assert_array_equal(
        np.asarray(got), np.full((32, 32), xv * wv * 256, np.int64))


# --- unaligned shapes: _pad_to must fire on each dim separately ----------
UNALIGNED = [
    (129, 128, 128),   # pad M only
    (128, 129, 128),   # pad N only
    (128, 128, 129),   # pad K only
    (130, 129, 131),   # pad all three
    (1, 1, 1),         # degenerate
    (127, 255, 383),   # just below block multiples
]


@pytest.mark.parametrize("m,n,k", UNALIGNED)
def test_unaligned_shapes_exact(m, n, k):
    x, w = _rand_i8(m, k), _rand_i8(k, n)
    got = ops.quant_matmul(x, w, interpret=True)
    np.testing.assert_array_equal(np.asarray(got),
                                  np.asarray(ref.nibble_matmul_ref(x, w)))


@pytest.mark.parametrize("m,n,k", [(129, 130, 257), (64, 64, 64)])
def test_w4_packed_unaligned_exact(m, n, k):
    x = _rand_i8(m, k)
    w4 = jnp.asarray(RNG.integers(-8, 8, (k, n), dtype=np.int64), jnp.int8)
    wp = pack_int4(w4)
    got = ops.quant_matmul(x, wp, w_format="int4_packed", interpret=True)
    np.testing.assert_array_equal(np.asarray(got),
                                  np.asarray(ref.nibble_matmul_w4_ref(x, wp)))


def test_multiblock_k_accumulation_exact():
    """K spanning several blocks exercises the VMEM-scratch accumulation
    and the single final flush."""
    x, w = _rand_i8(128, 640), _rand_i8(640, 128)
    got = ops.quant_matmul(x, w, bk=128, interpret=True)
    np.testing.assert_array_equal(np.asarray(got),
                                  np.asarray(ref.nibble_matmul_ref(x, w)))


# --- the fused dequant epilogue ------------------------------------------

@pytest.mark.parametrize("m,n,k", [(128, 128, 256), (32, 48, 100),
                                   (130, 129, 131)])
def test_scaled_epilogue_matches_xla_dequant(m, n, k):
    """bf16-epilogue output must equal the int32 kernel + XLA dequant,
    i.e. fusing the scale fold must not change the arithmetic."""
    x, w = _rand_i8(m, k), _rand_i8(k, n)
    xs = jnp.asarray(RNG.uniform(0.01, 0.1, (m, 1)), jnp.float32)
    ws = jnp.asarray(RNG.uniform(0.01, 0.1, (1, n)), jnp.float32)
    fused = ops.quant_matmul(x, w, x_scale=xs, w_scale=ws,
                             out_dtype=jnp.float32, interpret=True)
    acc = ops.quant_matmul(x, w, interpret=True)
    want = acc.astype(jnp.float32) * xs * ws
    np.testing.assert_allclose(np.asarray(fused), np.asarray(want),
                               rtol=1e-6)


def test_scaled_epilogue_emits_requested_dtype():
    x, w = _rand_i8(64, 64), _rand_i8(64, 64)
    xs = jnp.ones((64, 1), jnp.float32)
    ws = jnp.ones((1, 64), jnp.float32)
    out = ops.quant_matmul(x, w, x_scale=xs, w_scale=ws, interpret=True)
    assert out.dtype == jnp.bfloat16          # default fused out dtype
    out32 = ops.quant_matmul(x, w, interpret=True)
    assert out32.dtype == jnp.int32           # unscaled stays exact int32


@pytest.mark.parametrize("m,n,k", [(64, 96, 200), (130, 64, 96)])
def test_quant_matmul_fused_vs_oracle(m, n, k):
    x = jnp.asarray(RNG.normal(size=(m, k)), jnp.float32)
    w = jnp.asarray(RNG.normal(size=(k, n)), jnp.float32)
    wq = q.quantize(w, bits=8, granularity="per_channel", axis=0)
    got = ops.quant_matmul_fused(x, wq.values, wq.scale,
                                 interpret=True).astype(jnp.float32)
    want = ref.quant_dequant_matmul_ref(x, wq.values, wq.scale.reshape(1, -1))
    rel = float(jnp.linalg.norm(got - want) / jnp.linalg.norm(want))
    assert rel < 0.02, rel


def test_quant_matmul_fused_batched_leading_dims():
    x = jnp.asarray(RNG.normal(size=(2, 3, 96)), jnp.float32)
    w = jnp.asarray(RNG.normal(size=(96, 40)), jnp.float32)
    wq = q.quantize(w, bits=8, granularity="per_channel", axis=0)
    out = ops.quant_matmul_fused(x, wq.values, wq.scale, interpret=True)
    assert out.shape == (2, 3, 40)
    flat = ops.quant_matmul_fused(x.reshape(6, 96), wq.values, wq.scale,
                                  interpret=True)
    np.testing.assert_array_equal(np.asarray(out).reshape(6, 40),
                                  np.asarray(flat))


# --- dispatch-path coherence ---------------------------------------------

def test_unscaled_out_dtype_honored():
    """out_dtype without scales must cast (both fused and lut formats)."""
    x, w = _rand_i8(33, 40), _rand_i8(40, 32)
    want = np.asarray(ref.nibble_matmul_ref(x, w), np.float32)
    o = ops.quant_matmul(x, w, out_dtype=jnp.float32, interpret=True)
    assert o.dtype == jnp.float32
    np.testing.assert_array_equal(np.asarray(o), want)
    ol = ops.quant_matmul(x, w, w_format="lut", out_dtype=jnp.float32,
                          interpret=True)
    assert ol.dtype == jnp.float32
    np.testing.assert_array_equal(np.asarray(ol), want)


@pytest.mark.parametrize("w_format", ["int8", "lut"])
def test_scalar_scales_accepted(w_format):
    """Per-tensor (scalar) scales are part of the documented contract."""
    x, w = _rand_i8(33, 40), _rand_i8(40, 32)
    xs = jnp.asarray(RNG.uniform(0.01, 0.1, (33, 1)), jnp.float32)
    got = ops.quant_matmul(x, w, x_scale=xs, w_scale=jnp.float32(0.05),
                           w_format=w_format, out_dtype=jnp.float32,
                           interpret=True)
    want = ops.quant_matmul(x, w, interpret=True).astype(jnp.float32) \
        * xs * 0.05
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-6)


def test_w_format_validation():
    x, w = _rand_i8(32, 32), _rand_i8(32, 32)
    with pytest.raises(ValueError):
        ops.quant_matmul(x, w, w_format="int2")


def test_lut_format_through_dispatch():
    x, w = _rand_i8(64, 96), _rand_i8(96, 64)
    got = ops.quant_matmul(x, w, w_format="lut", interpret=True)
    np.testing.assert_array_equal(np.asarray(got),
                                  np.asarray(ref.lut_matmul_ref(x, w)))


def test_linear_apply_pallas_matches_xla():
    """The rewired layer path: fused pallas backend vs XLA backend."""
    from repro.core.linear import linear_apply, linear_init
    params = linear_init(jax.random.PRNGKey(0), 96, 64)
    x = jnp.asarray(RNG.normal(size=(4, 96)), jnp.bfloat16)
    for mode in ("w8a8_nibble", "w4a8_nibble", "lut"):
        a = linear_apply(params, x, mode=mode, backend="pallas")
        b = linear_apply(params, x, mode=mode, backend="xla")
        assert a.dtype == x.dtype
        an = np.asarray(a, np.float32)
        bn = np.asarray(b, np.float32)
        rel = np.linalg.norm(an - bn) / (np.linalg.norm(bn) + 1e-9)
        assert rel < 0.05, (mode, rel)
