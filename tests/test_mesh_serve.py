"""Mesh-native serving acceptance: TP-sharded engines bit-match the
single-device engine, token for token, on a forced-host multi-device
CPU platform.

Runs in a subprocess because the forced device count must be set before
jax initializes (and must never leak into this process).  One process
covers all four quant×backend combos — the engine build is the
expensive part, and the contract is the same for each: greedy streams
from a tp=2 engine (weights and paged KV pools sharded over the mesh's
"model" axis) equal the tp=1 engine's streams exactly, with the
compiled-program pins unchanged and zero page leaks.

Token-for-token equality under TP is a property of the workload as well
as the code: psum changes float reduction order, so a prompt whose
logits plateau into near-ties can legitimately flip an argmax.  The
prompts here are fixed (seeded) and verified well-separated; a failure
on these seeds means sharding changed the computation, not the
arithmetic's last ulp.
"""

import json
import os
import subprocess
import sys
import textwrap

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

_CHILD = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import json
    import numpy as np, jax
    from repro.configs import get_config, reduced
    from repro.models import model_init
    from repro.serve import Engine, ServeConfig

    cfg = reduced(get_config("qwen3-4b"))
    params = model_init(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, cfg.vocab_size, rng.integers(4, 9))
               for _ in range(4)]

    def run(quant, backend, tp):
        scfg = ServeConfig(batch=2, max_len=24, prefill_len=8,
                           decode_chunk=4, quant_mode=quant,
                           quant_backend=backend, cache_mode="paged",
                           page_size=4, alloc_mode="incremental",
                           num_pages=10, tp=tp)
        eng = Engine(cfg, params, scfg)
        ids = [eng.submit(p, 8) for p in prompts]
        done = eng.run()
        return ([done[i].tokens for i in ids], dict(eng.compile_counts),
                eng.leaked_pages(), list(eng.mesh_shape),
                eng.device_count)

    out = {}
    for quant, backend in [("dense", "xla"), ("dense", "pallas"),
                           ("w8a8_nibble", "xla"),
                           ("w8a8_nibble", "pallas")]:
        s1, c1, l1, m1, d1 = run(quant, backend, 1)
        s2, c2, l2, m2, d2 = run(quant, backend, 2)
        out[f"{quant}/{backend}"] = {
            "match": s1 == s2, "counts1": c1, "counts2": c2,
            "leaks": l1 + l2, "mesh2": m2, "devices2": d2}
    print(json.dumps(out))
""")


@pytest.mark.slow
def test_tp2_engine_bitmatches_single_device_all_combos():
    env = dict(os.environ, PYTHONPATH=os.path.join(REPO, "src"))
    out = subprocess.run([sys.executable, "-c", _CHILD], env=env,
                         capture_output=True, text=True, timeout=540)
    assert out.returncode == 0, out.stderr[-3000:]
    results = json.loads(out.stdout.strip().splitlines()[-1])
    pins = {"prefill": 1, "decode_chunk": 1}
    assert set(results) == {"dense/xla", "dense/pallas",
                            "w8a8_nibble/xla", "w8a8_nibble/pallas"}
    for combo, r in results.items():
        assert r["match"], f"{combo}: tp=2 streams diverge from tp=1"
        assert r["counts1"] == pins, (combo, r["counts1"])
        assert r["counts2"] == pins, (combo, r["counts2"])
        assert r["leaks"] == 0, (combo, r["leaks"])
        assert r["mesh2"] == [1, 2], (combo, r["mesh2"])
        assert r["devices2"] == 2, (combo, r["devices2"])
