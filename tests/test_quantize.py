"""Quantization substrate tests (incl. QAT straight-through gradients)."""

import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import quantize as q
from repro.core.linear import (
    linear_apply,
    linear_init,
    lut_matmul_xla,
    nibble_matmul_xla,
)


def test_quant_dequant_error_bound():
    x = jax.random.normal(jax.random.PRNGKey(0), (64, 128))
    qt = q.quantize(x, bits=8, granularity="per_channel")
    err = jnp.abs(qt.dequantize() - x)
    # max error is half an LSB = scale/2 per channel
    assert bool(jnp.all(err <= qt.scale / 2 + 1e-6))


def test_per_tensor_vs_per_channel():
    x = jnp.array([[100.0, 0.01], [50.0, 0.02]])
    pt = q.quantize(x, granularity="per_tensor")
    pc = q.quantize(x, granularity="per_channel", axis=0)
    # per-channel must represent the small column far better
    err_pt = float(jnp.abs(pt.dequantize() - x)[0, 1])
    err_pc = float(jnp.abs(pc.dequantize() - x)[0, 1])
    assert err_pc < err_pt


def test_int8_range_respected():
    x = jnp.linspace(-10, 10, 1000)
    qt = q.quantize(x, bits=8, granularity="per_tensor")
    assert int(qt.values.max()) <= 127 and int(qt.values.min()) >= -128


def test_int4_range_respected():
    x = jax.random.normal(jax.random.PRNGKey(1), (32, 32))
    qt = q.quantize(x, bits=4)
    assert int(qt.values.max()) <= 7 and int(qt.values.min()) >= -8


def test_fake_quant_forward_matches_quant_dequant():
    x = jax.random.normal(jax.random.PRNGKey(2), (16, 64))
    fq = q.fake_quant(x, bits=8, axis=-1)
    qt = q.quantize(x, bits=8, granularity="per_channel", axis=-1)
    np.testing.assert_allclose(np.asarray(fq), np.asarray(qt.dequantize()),
                               rtol=0, atol=1e-6)


def test_fake_quant_gradient_is_straight_through():
    x = jnp.ones((8,)) * 0.5
    g = jax.grad(lambda v: jnp.sum(q.fake_quant(v, bits=8, axis=-1)))(x)
    # gradient flows (not zero, as hard rounding would give)
    assert bool(jnp.all(jnp.abs(g) > 0))


def test_qtensor_is_pytree():
    qt = q.quantize(jnp.ones((4, 4)))
    leaves, tdef = jax.tree_util.tree_flatten(qt)
    assert len(leaves) == 2
    qt2 = jax.tree_util.tree_unflatten(tdef, leaves)
    assert qt2.bits == qt.bits


# ---------------------------------------------------------------------------
# QuantLinear end-to-end
# ---------------------------------------------------------------------------

@given(mode=st.sampled_from(["w8a8_nibble", "w4a8_nibble", "lut"]))
@settings(max_examples=12, deadline=None)
def test_linear_quant_modes_close_to_dense(mode):
    key = jax.random.PRNGKey(42)
    params = linear_init(key, 64, 32)
    x = jax.random.normal(jax.random.PRNGKey(7), (8, 64), jnp.float32) \
        .astype(jnp.bfloat16)
    dense = linear_apply(params, x, mode="dense").astype(jnp.float32)
    quant = linear_apply(params, x, mode=mode).astype(jnp.float32)
    # int8 per-tensor activations: expect small relative error
    rel = float(jnp.linalg.norm(quant - dense) / jnp.linalg.norm(dense))
    tol = 0.15 if mode == "w4a8_nibble" else 0.08
    assert rel < tol, (mode, rel)


def test_nibble_matmul_xla_exact_int():
    rng = np.random.default_rng(3)
    x = rng.integers(-128, 128, (5, 48)).astype(np.int8)
    w = rng.integers(-128, 128, (48, 16)).astype(np.int8)
    got = np.asarray(nibble_matmul_xla(jnp.asarray(x), jnp.asarray(w)))
    np.testing.assert_array_equal(got, x.astype(np.int32) @ w.astype(np.int32))


def test_lut_matmul_xla_exact_int():
    rng = np.random.default_rng(4)
    x = rng.integers(-128, 128, (5, 48)).astype(np.int8)
    w = rng.integers(-128, 128, (48, 16)).astype(np.int8)
    got = np.asarray(lut_matmul_xla(jnp.asarray(x), jnp.asarray(w)))
    np.testing.assert_array_equal(got, x.astype(np.int32) @ w.astype(np.int32))


def test_qat_mode_differentiable():
    params = linear_init(jax.random.PRNGKey(0), 32, 16)
    x = jax.random.normal(jax.random.PRNGKey(1), (4, 32), jnp.float32)

    def loss(p):
        return jnp.sum(linear_apply(p, x, mode="qat") ** 2).astype(jnp.float32)

    g = jax.grad(loss)(params)
    assert float(jnp.abs(g["w"]).sum()) > 0
