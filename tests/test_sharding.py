"""Sharding-rule unit tests + an end-to-end mini dry-run in a subprocess
(subprocess so XLA_FLAGS device-count fakery never leaks into this
process — smoke tests must see 1 device)."""

import json
import os
import subprocess
import sys
import textwrap

import jax
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import get_config
from repro.distributed.sharding import _fit_spec, _param_rule

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


class FakeMesh:
    axis_names = ("data", "model")
    shape = {"data": 4, "model": 2}


class FakeLeaf:
    def __init__(self, *shape):
        self.shape = shape
        self.ndim = len(shape)


def test_param_rules_col_row():
    fsdp = ("data",)
    assert _param_rule(("wq", "w"), FakeLeaf(64, 32), fsdp) \
        == P(("data",), "model")
    assert _param_rule(("wo", "w"), FakeLeaf(32, 64), fsdp) \
        == P("model", ("data",))
    assert _param_rule(("blocks", "0", "mlp", "gate", "w"),
                       FakeLeaf(4, 64, 128), fsdp) \
        == P(None, ("data",), "model")     # stacked: leading block axis


def test_param_rules_embed_and_experts():
    fsdp = ("data",)
    assert _param_rule(("embed", "emb"), FakeLeaf(1000, 64), fsdp) \
        == P("model", None)
    assert _param_rule(("moe", "w_gate"), FakeLeaf(8, 64, 128), fsdp) \
        == P("model", ("data",), None)
    assert _param_rule(("moe", "w_down"), FakeLeaf(8, 128, 64), fsdp) \
        == P("model", None, ("data",))
    assert _param_rule(("norm", "scale"), FakeLeaf(64), fsdp) == P(None)


def test_fit_spec_drops_nondivisible():
    mesh = FakeMesh()
    # 50280 % 2 == 0 → keeps; 50281 % 2 → drops
    assert _fit_spec(P("model", None), (50280, 64), mesh) \
        == P("model", None)
    assert _fit_spec(P("model", None), (50281, 64), mesh) == P(None, None)
    # batch=1 over data axis is dropped
    assert _fit_spec(P(("data",), None, "model", None),
                     (1, 128, 2, 16), mesh) == P(None, None, "model", None)


def test_fit_spec_tuple_axes():
    mesh = FakeMesh()
    # ("data","model") product = 8; 64 % 8 == 0 keeps, 12 % 8 drops
    assert _fit_spec(P(("data", "model"),), (64,), mesh) \
        == P(("data", "model"))
    assert _fit_spec(P(("data", "model"),), (12,), mesh) == P(None)


@pytest.mark.slow
def test_mini_dryrun_subprocess():
    """Lower+compile one reduced arch on a fake 8-device (4,2) mesh, with
    the real sharding rules, in a clean subprocess."""
    code = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import json, jax
        import jax.numpy as jnp
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.configs import get_config, reduced
        from repro.distributed.sharding import (ambient_mesh, batch_specs,
            opt_state_specs, param_specs)
        from repro.models import model_init
        from repro.optim import AdamWConfig, adamw_init
        from repro.train import TrainConfig, make_train_step

        mesh = jax.make_mesh((4, 2), ("data", "model"))
        cfg = reduced(get_config("qwen3-4b")).replace(
            d_model=64, n_heads=4, n_kv_heads=2, head_dim=16, vocab_size=256)
        tcfg = TrainConfig()
        step = make_train_step(cfg, tcfg)
        params = jax.eval_shape(lambda: model_init(jax.random.PRNGKey(0), cfg))
        opt = jax.eval_shape(lambda: adamw_init(params, tcfg.optimizer))
        batch = {"tokens": jax.ShapeDtypeStruct((8, 32), jnp.int32),
                 "labels": jax.ShapeDtypeStruct((8, 32), jnp.int32)}
        specs = (param_specs(params, mesh),
                 opt_state_specs(adamw_init(params, tcfg.optimizer) if 0 else opt,
                                 param_specs(params, mesh)),
                 batch_specs(cfg, mesh))
        shardings = jax.tree_util.tree_map(
            lambda s: NamedSharding(mesh, s), specs,
            is_leaf=lambda x: isinstance(x, P))
        with mesh, ambient_mesh(mesh):
            compiled = jax.jit(step, in_shardings=shardings) \\
                .lower(params, opt, batch).compile()
        cost = compiled.cost_analysis()
        if isinstance(cost, list):      # older jax: one dict per computation
            cost = cost[0] if cost else {}
        print(json.dumps({"ok": True, "flops": cost.get("flops", 0.0)}))
    """)
    env = dict(os.environ, PYTHONPATH=os.path.join(REPO, "src"))
    out = subprocess.run([sys.executable, "-c", code], env=env,
                         capture_output=True, text=True, timeout=420)
    assert out.returncode == 0, out.stderr[-3000:]
    result = json.loads(out.stdout.strip().splitlines()[-1])
    assert result["ok"]


def test_paged_kv_pool_shards_kv_heads_when_divisible():
    """(num_pages, page_size, KVH, D) pools put KV heads on the model
    axis when they divide it; the page-id axis is never sharded."""
    from types import SimpleNamespace

    from repro.distributed.sharding import cache_specs
    cfg = SimpleNamespace(cache_mode="paged", n_kv_heads=2)
    caches = {"k": FakeLeaf(10, 4, 2, 16), "v": FakeLeaf(10, 4, 2, 16)}
    specs = cache_specs(cfg, caches, FakeMesh(), batch=2)
    assert specs["k"] == P(None, None, "model", None)
    assert specs["v"] == P(None, None, "model", None)


def test_paged_kv_pool_falls_back_to_page_sequence_axis():
    """KV heads that don't divide the model axis (GQA reduced to odd
    head counts) shard the in-page sequence axis instead — and when
    page_size doesn't divide either, the pool stays replicated rather
    than letting GSPMD reject the program."""
    from types import SimpleNamespace

    from repro.distributed.sharding import cache_specs
    cfg = SimpleNamespace(cache_mode="paged", n_kv_heads=3)
    specs = cache_specs(cfg, {"k": FakeLeaf(10, 4, 3, 16)}, FakeMesh(),
                        batch=2)
    assert specs["k"] == P(None, "model", None, None)
    specs = cache_specs(cfg, {"k": FakeLeaf(10, 5, 3, 16)}, FakeMesh(),
                        batch=2)
    assert specs["k"] == P(None, None, None, None)


def test_paged_scale_pools_follow_the_kv_rule():
    """int8 quant scale pools (..., 1) shard exactly like their KV
    pools — a shard must hold the scales for the rows it owns."""
    from types import SimpleNamespace

    from repro.distributed.sharding import cache_specs
    cfg = SimpleNamespace(cache_mode="paged", n_kv_heads=2)
    specs = cache_specs(cfg, {"k_scale": FakeLeaf(10, 4, 2, 1)},
                        FakeMesh(), batch=2)
    assert specs["k_scale"] == P(None, None, "model", None)


def test_paged_mla_and_stacked_and_mamba_rules():
    """MLA latent pools (num_pages, page_size, rank) have no head axis
    — the in-page sequence axis shards; a stacked (blocks-leading)
    pool gets a leading None; mamba state keeps its per-slot dense
    rule even in paged mode."""
    from types import SimpleNamespace

    from repro.distributed.sharding import cache_specs
    cfg = SimpleNamespace(cache_mode="paged", n_kv_heads=2)
    specs = cache_specs(
        cfg, {"c_kv": FakeLeaf(10, 4, 8),
              "blocks": {"k": FakeLeaf(3, 10, 4, 2, 16)},
              "conv": FakeLeaf(4, 3, 8)},
        FakeMesh(), batch=2)
    assert specs["c_kv"] == P(None, "model", None)
    assert specs["blocks"]["k"] == P(None, None, None, "model", None)
    assert specs["conv"] == P(("data",), None, "model")


def test_page_table_spec_is_replicated():
    from repro.distributed.sharding import page_table_spec
    assert page_table_spec(FakeMesh()) == P(None, None)


def test_make_local_mesh_sizing_and_validation():
    """tp/dp sizing on the single local device: tp=1 works (and the
    no-argument call keeps the (n, 1) shape), anything needing more
    devices than exist raises with the sizes in the message."""
    from repro.launch.mesh import make_local_mesh
    n = len(jax.devices())
    mesh = make_local_mesh()
    assert mesh.axis_names == ("data", "model")
    assert mesh.shape == {"data": n, "model": 1}
    mesh = make_local_mesh(dp=1, tp=1)
    assert mesh.shape == {"data": 1, "model": 1}
    with pytest.raises(ValueError, match="tp must be >= 1"):
        make_local_mesh(tp=0)
    with pytest.raises(ValueError, match="dp must be >= 0"):
        make_local_mesh(dp=-1)
    with pytest.raises(ValueError, match=f"does not divide the {n}"):
        make_local_mesh(tp=2 * n)
    with pytest.raises(ValueError, match="needs"):
        make_local_mesh(dp=n, tp=2)


def test_maybe_shard_noop_without_mesh():
    """No ambient mesh → constraints are identity (unit-test safety)."""
    import jax.numpy as jnp

    from repro.distributed.sharding import maybe_shard
    x = jnp.ones((4, 8, 16))
    y = maybe_shard(x, "activation")
    assert y is x
