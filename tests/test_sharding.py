"""Sharding-rule unit tests + an end-to-end mini dry-run in a subprocess
(subprocess so XLA_FLAGS device-count fakery never leaks into this
process — smoke tests must see 1 device)."""

import json
import os
import subprocess
import sys
import textwrap

import jax
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import get_config
from repro.distributed.sharding import _fit_spec, _param_rule

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


class FakeMesh:
    axis_names = ("data", "model")
    shape = {"data": 4, "model": 2}


class FakeLeaf:
    def __init__(self, *shape):
        self.shape = shape
        self.ndim = len(shape)


def test_param_rules_col_row():
    fsdp = ("data",)
    assert _param_rule(("wq", "w"), FakeLeaf(64, 32), fsdp) \
        == P(("data",), "model")
    assert _param_rule(("wo", "w"), FakeLeaf(32, 64), fsdp) \
        == P("model", ("data",))
    assert _param_rule(("blocks", "0", "mlp", "gate", "w"),
                       FakeLeaf(4, 64, 128), fsdp) \
        == P(None, ("data",), "model")     # stacked: leading block axis


def test_param_rules_embed_and_experts():
    fsdp = ("data",)
    assert _param_rule(("embed", "emb"), FakeLeaf(1000, 64), fsdp) \
        == P("model", None)
    assert _param_rule(("moe", "w_gate"), FakeLeaf(8, 64, 128), fsdp) \
        == P("model", ("data",), None)
    assert _param_rule(("moe", "w_down"), FakeLeaf(8, 128, 64), fsdp) \
        == P("model", None, ("data",))
    assert _param_rule(("norm", "scale"), FakeLeaf(64), fsdp) == P(None)


def test_fit_spec_drops_nondivisible():
    mesh = FakeMesh()
    # 50280 % 2 == 0 → keeps; 50281 % 2 → drops
    assert _fit_spec(P("model", None), (50280, 64), mesh) \
        == P("model", None)
    assert _fit_spec(P("model", None), (50281, 64), mesh) == P(None, None)
    # batch=1 over data axis is dropped
    assert _fit_spec(P(("data",), None, "model", None),
                     (1, 128, 2, 16), mesh) == P(None, None, "model", None)


def test_fit_spec_tuple_axes():
    mesh = FakeMesh()
    # ("data","model") product = 8; 64 % 8 == 0 keeps, 12 % 8 drops
    assert _fit_spec(P(("data", "model"),), (64,), mesh) \
        == P(("data", "model"))
    assert _fit_spec(P(("data", "model"),), (12,), mesh) == P(None)


@pytest.mark.slow
def test_mini_dryrun_subprocess():
    """Lower+compile one reduced arch on a fake 8-device (4,2) mesh, with
    the real sharding rules, in a clean subprocess."""
    code = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import json, jax
        import jax.numpy as jnp
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.configs import get_config, reduced
        from repro.distributed.sharding import (ambient_mesh, batch_specs,
            opt_state_specs, param_specs)
        from repro.models import model_init
        from repro.optim import AdamWConfig, adamw_init
        from repro.train import TrainConfig, make_train_step

        mesh = jax.make_mesh((4, 2), ("data", "model"))
        cfg = reduced(get_config("qwen3-4b")).replace(
            d_model=64, n_heads=4, n_kv_heads=2, head_dim=16, vocab_size=256)
        tcfg = TrainConfig()
        step = make_train_step(cfg, tcfg)
        params = jax.eval_shape(lambda: model_init(jax.random.PRNGKey(0), cfg))
        opt = jax.eval_shape(lambda: adamw_init(params, tcfg.optimizer))
        batch = {"tokens": jax.ShapeDtypeStruct((8, 32), jnp.int32),
                 "labels": jax.ShapeDtypeStruct((8, 32), jnp.int32)}
        specs = (param_specs(params, mesh),
                 opt_state_specs(adamw_init(params, tcfg.optimizer) if 0 else opt,
                                 param_specs(params, mesh)),
                 batch_specs(cfg, mesh))
        shardings = jax.tree_util.tree_map(
            lambda s: NamedSharding(mesh, s), specs,
            is_leaf=lambda x: isinstance(x, P))
        with mesh, ambient_mesh(mesh):
            compiled = jax.jit(step, in_shardings=shardings) \\
                .lower(params, opt, batch).compile()
        cost = compiled.cost_analysis()
        if isinstance(cost, list):      # older jax: one dict per computation
            cost = cost[0] if cost else {}
        print(json.dumps({"ok": True, "flops": cost.get("flops", 0.0)}))
    """)
    env = dict(os.environ, PYTHONPATH=os.path.join(REPO, "src"))
    out = subprocess.run([sys.executable, "-c", code], env=env,
                         capture_output=True, text=True, timeout=420)
    assert out.returncode == 0, out.stderr[-3000:]
    result = json.loads(out.stdout.strip().splitlines()[-1])
    assert result["ok"]


def test_maybe_shard_noop_without_mesh():
    """No ambient mesh → constraints are identity (unit-test safety)."""
    import jax.numpy as jnp

    from repro.distributed.sharding import maybe_shard
    x = jnp.ones((4, 8, 16))
    y = maybe_shard(x, "activation")
    assert y is x
