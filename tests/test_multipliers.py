"""Functional verification of the five multiplier architectures.

This is the paper's Fig. 3 testbench done exhaustively: every
architecture must produce bit-exact products over the full 8-bit operand
space, and the cycle accounting must match Table 2.
"""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core.multipliers import (
    MULTIPLIERS,
    booth_radix2,
    build_hex_string_lut,
    lut_array,
    lut_array_16bit,
    nibble_precompute,
    shift_add,
    wallace,
)

UNSIGNED_ARCHES = ["shift_add", "nibble_precompute", "wallace", "lut_array"]


@pytest.mark.parametrize("arch", UNSIGNED_ARCHES)
def test_exhaustive_unsigned_8bit(arch):
    """Every (a, b) in [0,256)²: architecture output == a*b exactly."""
    fn = MULTIPLIERS[arch]
    a = jnp.arange(256, dtype=jnp.int32)
    expected = np.arange(256, dtype=np.int64)
    for b in range(256):
        got = np.asarray(fn(a, b).products)
        np.testing.assert_array_equal(got, expected * b,
                                      err_msg=f"{arch} b={b}")


def test_exhaustive_booth_signed():
    """Booth is a two's-complement scheme: exact over signed int8 × int8."""
    a = jnp.arange(-128, 128, dtype=jnp.int32)
    expected = np.arange(-128, 128, dtype=np.int64)
    for b in range(-128, 128):
        got = np.asarray(booth_radix2(a, b).products)
        np.testing.assert_array_equal(got, expected * b, err_msg=f"b={b}")


def test_exhaustive_nibble_signed():
    """The signed nibble split keeps Algorithm 2 exact for int8 operands."""
    a = jnp.arange(-128, 128, dtype=jnp.int32)
    expected = np.arange(-128, 128, dtype=np.int64)
    for b in range(-128, 128):
        got = np.asarray(nibble_precompute(a, jnp.int32(b), signed=True).products)
        np.testing.assert_array_equal(got, expected * b, err_msg=f"b={b}")


def test_lut_16bit_operand_path():
    """Algorithm 1's full 16-bit-A path: Out1 + (Out2 << 8) == A*B."""
    a16 = jnp.arange(0, 65536, 251, dtype=jnp.int32)
    exp = np.arange(0, 65536, 251, dtype=np.int64)
    for b in (0, 1, 15, 16, 171, 255):
        o1, o2 = lut_array_16bit(a16, b)
        np.testing.assert_array_equal(np.asarray(o1) + (np.asarray(o2) << 8),
                                      exp * b)


def test_hex_string_lut_contents():
    """Fig. 1(a): row b, slice a holds the 8-bit product b*a (< 256)."""
    lut = build_hex_string_lut()
    assert lut.shape == (16, 16)
    assert lut.max() == 225 < 256  # every segment fits 8 bits
    for b in range(16):
        np.testing.assert_array_equal(lut[b], np.arange(16) * b)


# ---------------------------------------------------------------------------
# Table 2: cycle accounting
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("arch,per_op,total_16", [
    ("shift_add", 8, 128),
    ("booth_radix2", 4, 64),
    ("nibble_precompute", 2, 32),
    ("wallace", 1, 1),
    ("lut_array", 1, 1),
])
def test_table2_cycles(arch, per_op, total_16):
    a = jnp.arange(16, dtype=jnp.int32)
    tr = MULTIPLIERS[arch](a, 7)
    assert tr.cycles_per_operand == per_op
    assert tr.cycles == total_16


@given(n=st.integers(1, 64))
@settings(max_examples=20, deadline=None)
def test_cycles_scale_linearly_for_sequential(n):
    a = jnp.zeros((n,), jnp.int32)
    assert shift_add(a, 3).cycles == 8 * n
    assert nibble_precompute(a, 3).cycles == 2 * n
    assert wallace(a, 3).cycles == 1


# ---------------------------------------------------------------------------
# Property tests: all architectures agree with each other (Fig. 3's claim)
# ---------------------------------------------------------------------------

@given(a=st.lists(st.integers(0, 255), min_size=1, max_size=32),
       b=st.integers(0, 255))
@settings(max_examples=200, deadline=None)
def test_architectures_agree_unsigned(a, b):
    arr = jnp.asarray(a, jnp.int32)
    outs = {n: np.asarray(MULTIPLIERS[n](arr, b).products)
            for n in UNSIGNED_ARCHES}
    ref = outs["wallace"]
    for name, got in outs.items():
        np.testing.assert_array_equal(got, ref, err_msg=name)


@given(a=st.lists(st.integers(-128, 127), min_size=1, max_size=32),
       b=st.integers(-128, 127))
@settings(max_examples=200, deadline=None)
def test_signed_paths_agree(a, b):
    arr = jnp.asarray(a, jnp.int32)
    booth = np.asarray(booth_radix2(arr, b).products)
    nib = np.asarray(nibble_precompute(arr, jnp.int32(b), signed=True).products)
    np.testing.assert_array_equal(booth, np.asarray(a, np.int64) * b)
    np.testing.assert_array_equal(nib, booth)
