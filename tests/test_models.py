"""Per-architecture smoke tests (reduced configs) + decode consistency.

Every assigned architecture: instantiate reduced config, run one forward
(train) step asserting shapes/finiteness, one prefill+decode, and — the
strong check — teacher-forced decode logits must match the parallel
forward pass (the KV-cache path and the full path are the same function).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_NAMES, get_config, reduced
from repro.models import decode_step, forward, model_init, prefill
from repro.models.transformer import encode


def _inputs(cfg, key, b=2, s=8):
    tokens = jax.random.randint(key, (b, s), 0, cfg.vocab_size)
    kw = {}
    if cfg.family == "vlm":
        kw["extra_embeds"] = jax.random.normal(
            key, (b, cfg.n_patches, cfg.d_model)).astype(jnp.bfloat16)
    if cfg.is_encdec:
        kw["frames"] = jax.random.normal(
            key, (b, cfg.enc_seq_len, cfg.d_model)).astype(jnp.bfloat16)
    return tokens, kw


@pytest.fixture(scope="module")
def rngs():
    return jax.random.PRNGKey(0), jax.random.PRNGKey(1)


@pytest.mark.parametrize("arch", ARCH_NAMES)
def test_forward_smoke(arch, rngs):
    key, dkey = rngs
    cfg = reduced(get_config(arch))
    params = model_init(key, cfg)
    tokens, kw = _inputs(cfg, dkey)
    logits, aux = forward(params, cfg, tokens, **kw)
    s_out = tokens.shape[1] + (cfg.n_patches if cfg.family == "vlm" else 0)
    assert logits.shape == (2, s_out, cfg.vocab_size)
    assert bool(jnp.isfinite(logits).all()), "NaN/Inf in logits"
    assert bool(jnp.isfinite(aux)), "NaN/Inf in aux loss"
    if cfg.n_experts:
        assert float(aux) > 0.0, "MoE aux loss should be positive"


@pytest.mark.parametrize("arch", ARCH_NAMES)
def test_train_gradients_finite(arch, rngs):
    key, dkey = rngs
    cfg = reduced(get_config(arch))
    params = model_init(key, cfg)
    tokens, kw = _inputs(cfg, dkey, b=1, s=8)

    def loss_fn(p):
        logits, aux = forward(p, cfg, tokens, **kw)
        tok_logits = logits[:, -tokens.shape[1]:]
        logp = jax.nn.log_softmax(tok_logits.astype(jnp.float32), -1)
        nll = -jnp.take_along_axis(logp[:, :-1], tokens[:, 1:, None],
                                   -1).mean()
        return nll + 0.01 * aux

    loss, grads = jax.value_and_grad(loss_fn)(params)
    assert bool(jnp.isfinite(loss))
    flat = jax.tree_util.tree_leaves(grads)
    assert all(bool(jnp.isfinite(g).all()) for g in flat), "non-finite grad"
    norms = sum(float(jnp.abs(g).sum()) for g in flat)
    assert norms > 0.0, "gradients identically zero"


@pytest.mark.parametrize("arch", ARCH_NAMES)
def test_decode_matches_forward(arch, rngs):
    """Teacher-forced: logits from step-by-step decode == parallel forward."""
    key, dkey = rngs
    cfg = reduced(get_config(arch))
    params = model_init(key, cfg)
    b, s = 1, 8
    tokens, kw = _inputs(cfg, dkey, b=b, s=s)
    n_pre = cfg.n_patches if cfg.family == "vlm" else 0

    enc = encode(params, cfg, kw["frames"]) if cfg.is_encdec else None
    full_logits, _ = forward(params, cfg, tokens, **kw)

    # prefill on the first half, decode the second half teacher-forced
    split = s // 2
    pre_tokens = tokens[:, :split]
    logits, caches, _ = prefill(params, cfg, pre_tokens,
                                max_len=s + n_pre, **kw)
    got = [np.asarray(logits[:, -1].astype(jnp.float32))]
    for t in range(split, s):
        step_tok = tokens[:, t:t + 1]
        lg, caches = decode_step(params, cfg, step_tok, caches,
                                 n_pre + t, enc_out=enc)
        got.append(np.asarray(lg[:, -1].astype(jnp.float32)))

    want = np.asarray(full_logits[:, n_pre + split - 1:, :]
                      .astype(jnp.float32))
    got = np.stack(got, axis=1)[:, :want.shape[1]]
    # the cache path recomputes identical math; only bf16 noise allowed
    np.testing.assert_allclose(got, want, rtol=0.02, atol=0.02)


def test_param_counts_match_analytical():
    """Analytical counter == actual pytree size for the reduced configs."""
    key = jax.random.PRNGKey(0)
    for arch in ARCH_NAMES:
        cfg = reduced(get_config(arch))
        params = model_init(key, cfg)
        actual = sum(int(np.prod(p.shape))
                     for p in jax.tree_util.tree_leaves(params))
        analytical = cfg.param_count()
        err = abs(actual - analytical) / actual
        assert err < 0.05, (arch, actual, analytical)


def test_full_config_param_counts():
    """Full-size analytical counts are in the advertised ballparks."""
    expected_b = {   # billions, loose bands (total params)
        "gemma3_1b": (0.7, 1.6),
        "gemma_7b": (7.0, 10.0),
        "qwen3_4b": (3.0, 5.0),
        "yi_6b": (5.5, 7.0),
        "mamba2_780m": (0.6, 1.0),
        "phi3_vision_4_2b": (3.4, 4.6),
        "whisper_base": (0.05, 0.11),
        "deepseek_v3_671b": (600.0, 720.0),
        "llama4_maverick_400b_a17b": (330.0, 480.0),
        "jamba_v0_1_52b": (45.0, 60.0),
    }
    for arch, (lo, hi) in expected_b.items():
        n = get_config(arch).param_count() / 1e9
        assert lo <= n <= hi, f"{arch}: {n:.2f}B not in [{lo},{hi}]"


def test_moe_active_params():
    cfg = get_config("deepseek_v3_671b")
    active = cfg.param_count(active_only=True) / 1e9
    assert 25.0 <= active <= 55.0, active   # ~37B active


def test_grow_caches_batch_equals_prompt_len():
    """Regression: _grow_caches used to pick the pad axis by comparing
    sizes (``axis = 1 if shape[1] == cur_len else 2``) — with
    ``batch == prompt_len`` that padded the *batch* axis of block-stacked
    leaves and corrupted the cache.  Axis detection is now structural
    (block-stack subtree ⇒ seq axis 2)."""
    cfg = reduced(get_config("yi_6b"))
    b = s = 4                       # the coincidence that broke it
    max_len = 10
    tokens = jax.random.randint(jax.random.PRNGKey(0), (b, s + 2), 0,
                                cfg.vocab_size)
    params = model_init(jax.random.PRNGKey(1), cfg)
    _, caches, _ = prefill(params, cfg, tokens[:, :s], max_len=max_len)

    k = caches["blocks"]["0"]["attn"]["k"]
    assert k.shape[1] == b, k.shape        # batch axis NOT padded
    assert k.shape[2] == max_len, k.shape  # seq axis grown to budget

    # functional check: teacher-forced decode on the grown cache must
    # match the parallel forward pass (a corrupted cache cannot)
    full, _ = forward(params, cfg, tokens)
    got, want = [], np.asarray(full[:, s:].astype(jnp.float32))
    for t in range(s, s + 2):
        lg, caches = decode_step(params, cfg, tokens[:, t:t + 1], caches, t)
        got.append(np.asarray(lg[:, -1].astype(jnp.float32)))
    got = np.stack(got, axis=1)
    rel = np.abs(got - want).max() / max(np.abs(want).max(), 1e-6)
    assert rel < 0.05, rel
