"""Preemptive, incrementally-paged serving: live-token page allocation,
evict-and-resume scheduling (preempted greedy streams must bit-match
uninterrupted ones), overcommitted-pool draining with zero page leaks,
and the serve-layer bugfix regressions (engine-owned compile counter,
explicit truncation, scheduler-stall detection)."""

import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, reduced
from repro.models import model_init
from repro.serve import Engine, ServeConfig


@pytest.fixture(scope="module")
def model():
    cfg = reduced(get_config("yi-6b"))
    params = model_init(jax.random.PRNGKey(0), cfg)
    return cfg, params


def _scfg(**over):
    kw = dict(batch=3, max_len=16, prefill_len=8, decode_chunk=3,
              cache_mode="paged", page_size=4)
    kw.update(over)
    return ServeConfig(**kw)


def _drive(cfg, params, prompts, budgets, scfg, priorities=None):
    engine = Engine(cfg, params, scfg)
    priorities = priorities or [0] * len(prompts)
    ids = [engine.submit(p, n, priority=pr)
           for p, n, pr in zip(prompts, budgets, priorities)]
    done = engine.run()
    return engine, [done[i] for i in ids]


# ---------------------------------------------------------------------------
# Incremental allocation: overcommitted pool, zero leaks, bit-match
# ---------------------------------------------------------------------------

def test_overcommitted_pool_drains_bitmatch(model):
    """The acceptance scenario: a pool sized well below the sum of
    worst-case page counts (4 requests x 4 pages worst case, capacity
    6).  Incremental allocation + preemption must drain every request,
    return every page, keep both compiled programs single, and produce
    the exact token streams of an uncontended dense engine."""
    cfg, params = model
    rng = np.random.default_rng(0)
    budgets = [8, 8, 8, 8]
    prompts = [jnp.asarray(rng.integers(0, cfg.vocab_size, p), jnp.int32)
               for p in (4, 6, 5, 7)]

    _, want = _drive(cfg, params, prompts, budgets,
                     _scfg(cache_mode="dense", page_size=None))
    engine, got = _drive(cfg, params, prompts, budgets,
                         _scfg(alloc_mode="incremental", num_pages=7))
    assert [r.tokens for r in got] == [r.tokens for r in want]
    assert engine.allocator.in_use == 0            # zero page leaks
    assert engine.allocator.available == engine.allocator.capacity
    assert engine.compile_counts == {"prefill": 1, "decode_chunk": 1}
    # the pool cannot hold two worst-case requests, so finishing all
    # four forcibly exercised eviction and resume
    assert engine.stats["preemptions"] >= 1
    assert sum(r.preemptions for r in got) == engine.stats["preemptions"]
    assert 0.0 < engine.stats["occupancy"] <= 1.0


def test_overcommit_raises_concurrency_vs_reserve(model):
    """Same overcommitted pool, reserve vs incremental bookkeeping:
    booking live tokens instead of worst cases must admit more
    concurrent requests per page of pool (the benchmark's claim)."""
    cfg, params = model
    rng = np.random.default_rng(1)
    prompts = [jnp.asarray(rng.integers(0, cfg.vocab_size, 5), jnp.int32)
               for _ in range(4)]
    # worst case ceil((5+8-1)/4) = 3 pages; capacity 4 fits ONE
    # worst-case booking but two-plus live-token footprints
    res, _ = _drive(cfg, params, prompts, [8] * 4,
                    _scfg(alloc_mode="reserve", num_pages=5))
    inc, _ = _drive(cfg, params, prompts, [8] * 4,
                    _scfg(alloc_mode="incremental", num_pages=5))
    assert res.stats["concurrency"] <= 1.0 + 1e-9
    assert inc.stats["concurrency"] > res.stats["concurrency"]
    assert inc.allocator.in_use == 0 and res.allocator.in_use == 0


def test_incremental_frees_tail_pages_on_early_eos(model):
    """An early-EOS request under incremental allocation never books the
    pages its unreached tail would have needed; reserve mode books the
    worst case up front.  cache_rows records the peak booking."""
    cfg, params = model

    def run_mode(alloc_mode, eos_id=-1):
        engine = Engine(cfg, params, _scfg(
            batch=1, max_len=32, decode_chunk=2, alloc_mode=alloc_mode,
            eos_id=eos_id))
        rid = engine.submit(jnp.asarray([1, 2, 3, 4], jnp.int32), 20)
        return engine, engine.run()[rid]

    _, probe = run_mode("reserve")             # find a token it emits
    eos = probe.tokens[2]
    _, res = run_mode("reserve", eos_id=eos)
    _, inc = run_mode("incremental", eos_id=eos)
    assert res.tokens == inc.tokens            # same (short) stream
    # reserve booked ceil((4+20-1)/4)=6 pages; incremental only the
    # pages its live rows touched before stopping
    assert res.cache_rows == 24
    assert inc.cache_rows < res.cache_rows


# ---------------------------------------------------------------------------
# Preemption: evict-and-resume, bit-identical greedy resume
# ---------------------------------------------------------------------------

def test_high_priority_arrival_preempts_and_victim_resumes(model):
    """batch=1: a high-priority arrival evicts the running low-priority
    request (slot preemption); the victim later resumes and its full
    greedy stream must bit-match an uninterrupted solo run."""
    cfg, params = model
    rng = np.random.default_rng(2)
    lo_p = jnp.asarray(rng.integers(0, cfg.vocab_size, 5), jnp.int32)
    hi_p = jnp.asarray(rng.integers(0, cfg.vocab_size, 4), jnp.int32)
    scfg = _scfg(batch=1, decode_chunk=2)

    engine = Engine(cfg, params, scfg)
    lo = engine.submit(lo_p, 6)
    engine._t0 = time.perf_counter()
    engine._admit(0.0)
    assert engine._slots[0] is not None and engine._slots[0].id == lo
    # decode a couple of chunks so the victim has generated tokens to
    # carry through eviction and replay on resume
    engine._run_chunk(0.0)
    hi = engine.submit(hi_p, 5, priority=5)
    engine._admit(0.0)                         # full batch: must evict lo
    assert engine._slots[0].id == hi
    assert engine.preemptions == 1
    done = engine.run()
    assert engine.allocator.in_use == 0
    assert done[lo].preemptions == 1
    assert done[hi].t_done <= done[lo].t_done  # hi finished first

    for rid, prompt, n in ((lo, lo_p, 6), (hi, hi_p, 5)):
        ref_engine, (ref,) = _drive(cfg, params, [prompt], [n],
                                    _scfg(batch=1, decode_chunk=2))
        assert done[rid].tokens == ref.tokens, rid
    assert engine.compile_counts == {"prefill": 1, "decode_chunk": 1}


def test_no_slot_eviction_for_page_infeasible_arrival(model):
    """A high-priority arrival whose pages could never be covered even
    after evicting every strictly-weaker runner must not cost anyone
    their slot (same feasibility bound as the page-backpressure path)."""
    cfg, params = model
    # capacity 5: A (prio 10) books 3 pages, B (prio 1) books 2
    engine = Engine(cfg, params, _scfg(batch=2, decode_chunk=2,
                                       num_pages=6))
    a = engine.submit(jnp.asarray([1, 2, 3, 4, 5], jnp.int32), 8,
                      priority=10)
    b = engine.submit(jnp.asarray([6, 7, 8, 9, 10], jnp.int32), 4,
                      priority=1)
    engine._t0 = time.perf_counter()
    engine._admit(0.0)
    assert {r.id for r in engine._slots if r is not None} == {a, b}
    assert engine.allocator.available == 0
    # C needs 4 pages; evicting B recovers only 2 and A outranks C
    c = engine.submit(jnp.asarray(np.arange(1, 8), jnp.int32), 9,
                      priority=5)
    engine._admit(0.0)
    assert engine.preemptions == 0             # nobody lost a slot
    assert {r.id for r in engine._slots if r is not None} == {a, b}
    done = engine.run()                        # C admitted once B frees
    assert set(done) == {a, b, c}
    assert engine.allocator.in_use == 0


def test_arrival_during_admission_window_is_not_a_stall(model):
    """A request whose arrival lands inside the previous _admit call's
    execution window (prefill takes real milliseconds) must be admitted
    on the next loop, not misdiagnosed as a scheduler stall."""
    cfg, params = model
    engine = Engine(cfg, params, _scfg(batch=1))
    # finishes at prefill (max_new=1), so the engine goes idle with the
    # second request's arrival already in the past by wall clock
    a = engine.submit(jnp.asarray([1, 2, 3], jnp.int32), 1, arrival=0.0)
    b = engine.submit(jnp.asarray([4, 5, 6], jnp.int32), 3,
                      arrival=1e-4)
    done = engine.run()                        # must not raise "stalled"
    assert set(done) == {a, b}
    assert len(done[b].tokens) == 3
    assert engine.allocator.in_use == 0


def test_equal_priority_never_preempts(model):
    """Preemption requires *strictly* higher effective priority — an
    equal-priority arrival waits (no eviction ping-pong)."""
    cfg, params = model
    engine = Engine(cfg, params, _scfg(batch=1, decode_chunk=2))
    a = engine.submit(jnp.asarray([1, 2, 3], jnp.int32), 4, priority=2)
    engine._t0 = time.perf_counter()
    engine._admit(0.0)
    engine.submit(jnp.asarray([4, 5, 6], jnp.int32), 4, priority=2)
    engine._admit(0.0)
    assert engine._slots[0] is not None and engine._slots[0].id == a
    assert engine.preemptions == 0
    engine.run()
    assert engine.allocator.in_use == 0


def test_preemption_in_dense_mode(model):
    """Slot preemption does not depend on paging: the dense engine
    evicts and resumes bit-identically too (no allocator involved)."""
    cfg, params = model
    rng = np.random.default_rng(3)
    lo_p = jnp.asarray(rng.integers(0, cfg.vocab_size, 5), jnp.int32)
    hi_p = jnp.asarray(rng.integers(0, cfg.vocab_size, 3), jnp.int32)
    scfg = _scfg(batch=1, decode_chunk=2, cache_mode="dense",
                 page_size=None)
    engine = Engine(cfg, params, scfg)
    lo = engine.submit(lo_p, 6)
    engine._t0 = time.perf_counter()
    engine._admit(0.0)
    engine._run_chunk(0.0)
    hi = engine.submit(hi_p, 4, priority=9)
    engine._admit(0.0)
    assert engine._slots[0].id == hi and engine.preemptions == 1
    done = engine.run()
    _, (ref,) = _drive(cfg, params, [lo_p], [6], scfg)
    assert done[lo].tokens == ref.tokens


def test_preempted_mid_replay_carries_full_stream(model):
    """Evicting a slot that is itself still replaying must splice the
    unreplayed tail back onto the requeued request — nothing of the
    client-visible stream is lost or duplicated."""
    cfg, params = model
    rng = np.random.default_rng(4)
    prompt = jnp.asarray(rng.integers(0, cfg.vocab_size, 4), jnp.int32)
    scfg = _scfg(batch=1, decode_chunk=2)
    engine = Engine(cfg, params, scfg)
    rid = engine.submit(prompt, 8)
    engine._t0 = time.perf_counter()
    engine._admit(0.0)
    for _ in range(3):                        # generate 1 + 3x2 tokens
        engine._run_chunk(0.0)
    # evict, resume, then evict again after a single replay chunk (the
    # replay lane is 2 tokens/chunk, 6 tokens pending -> mid-replay)
    engine._evict(0, 0.0)
    engine._admit(0.0)
    engine._run_chunk(0.0)
    assert engine._slot_forced[0]             # replay still pending
    engine._evict(0, 0.0)
    done = engine.run()
    _, (ref,) = _drive(cfg, params, [prompt], [8], scfg)
    assert done[rid].tokens == ref.tokens
    assert done[rid].preemptions == 2
    assert engine.allocator.in_use == 0


# ---------------------------------------------------------------------------
# Bugfix regressions
# ---------------------------------------------------------------------------

def test_counting_jit_tracks_signatures():
    from repro.serve.engine import _CountingJit

    calls = []

    def f(x, n):
        calls.append(1)
        return x * n

    g = _CountingJit(f)
    g(jnp.ones((2, 2)), 3)
    g(jnp.zeros((2, 2)), 7)                   # same signature
    assert g.compile_count == 1
    g(jnp.ones((4, 2)), 3)                    # new shape
    assert g.compile_count == 2
    g(jnp.ones((2, 2), jnp.int32), 3)         # new dtype
    assert g.compile_count == 3


def test_submit_truncation_is_explicit(model):
    cfg, params = model
    engine = Engine(cfg, params, _scfg(batch=1, cache_mode="dense",
                                       page_size=None))
    rid = engine.submit(jnp.asarray([1, 2, 3, 4, 5], jnp.int32), 100)
    done = engine.run()
    assert done[rid].truncated                # not silently clamped
    assert len(done[rid].tokens) == 16 - 5
    ok = engine.submit(jnp.asarray([1, 2, 3], jnp.int32), 4)
    assert not engine.run()[ok].truncated


def test_generate_eos_error_names_eos(model):
    """generate()'s ragged-output error must name the actual cause (an
    EOS stop) instead of guessing — the old message fired for truncation
    too."""
    cfg, params = model
    probe = Engine(cfg, params, ServeConfig(batch=1, max_len=16))
    out = probe.generate(jnp.asarray([[1, 2, 3, 4]], jnp.int32), 6)
    eos = int(out[0, 5])                      # second generated token
    engine = Engine(cfg, params, ServeConfig(batch=1, max_len=16,
                                             eos_id=eos))
    with pytest.raises(RuntimeError, match=f"eos_id={eos}"):
        engine.generate(jnp.asarray([[1, 2, 3, 4]], jnp.int32), 6)


def test_scheduler_stall_raises_not_spins(model):
    """Backpressure with every slot idle used to be declared impossible
    and busy-spun; with overcommit it is reachable through a page leak —
    the engine must fail loudly instead."""
    cfg, params = model
    engine = Engine(cfg, params, _scfg(batch=2, num_pages=5))
    engine.allocator.alloc(3)                 # simulate a leak
    engine.submit(jnp.asarray([1, 2, 3, 4, 5], jnp.int32), 4)
    with pytest.raises(RuntimeError, match="stalled"):
        engine.run()


def test_incremental_requires_paged(model):
    cfg, params = model
    with pytest.raises(ValueError, match="incremental"):
        Engine(cfg, params, ServeConfig(batch=1, max_len=16,
                                        alloc_mode="incremental"))
    with pytest.raises(ValueError, match="alloc_mode"):
        Engine(cfg, params, ServeConfig(batch=1, max_len=16,
                                        alloc_mode="lazy"))


def test_workload_reports_scheduler_stats(model):
    from repro.serve import run_timed_workload
    cfg, params = model
    engine = Engine(cfg, params, _scfg(batch=2, alloc_mode="incremental",
                                       num_pages=7))
    r = run_timed_workload(engine, cfg.vocab_size, requests=4,
                           prompt_budget=6, new_tokens=6)
    for key in ("preemptions", "occupancy", "concurrency", "pool_pages",
                "truncated"):
        assert key in r, key
    assert r["pool_pages"] == 7
    assert r["truncated"] == 0
    assert 0.0 < r["occupancy"] <= 1.0
    assert r["compile_counts"] == {"prefill": 1, "decode_chunk": 1}
    assert engine.allocator.in_use == 0
